//! Hot-path microbenchmarks for the L3 performance pass (EXPERIMENTS.md
//! §Perf): skiplist ops, scheduler pick/steal, license-machine observe,
//! block execution, event queue, and whole-simulator throughput.
//!
//! Custom harness (criterion is not in the offline registry): median of
//! `REPS` batches with warmup, reporting ns/op.

use avxfreq::cpu::freq::{FreqParams, License, LicenseState};
use avxfreq::cpu::ipc::IpcParams;
use avxfreq::cpu::turbo::TurboTable;
use avxfreq::cpu::Core;
use avxfreq::isa::block::{Block, ClassMix, InsnClass};
use avxfreq::sched::skiplist::SkipList;
use avxfreq::sched::task::TaskId;
use avxfreq::sched::{PolicyKind, SchedParams, Scheduler, TaskType};
use avxfreq::sim::{EventQueue, SEC};
use avxfreq::util::Rng;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{run_webserver_machine, WebCfg};
use std::hint::black_box;
use std::time::Instant;

fn bench<F: FnMut() -> u64>(name: &str, mut f: F) {
    // Warmup.
    let mut ops = 0u64;
    for _ in 0..3 {
        ops = f();
    }
    let mut samples = Vec::new();
    for _ in 0..9 {
        let t0 = Instant::now();
        let n = f();
        let dt = t0.elapsed().as_nanos() as f64;
        samples.push(dt / n as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    let min = samples[0];
    println!("{name:<44} {med:>10.1} ns/op (min {min:.1}, {ops} ops/batch)");
}

fn bench_skiplist() {
    let mut rng = Rng::new(1);
    bench("skiplist insert+pop (256 live)", || {
        let mut s = SkipList::new();
        let mut keys = Vec::new();
        for i in 0..256 {
            keys.push(s.insert(rng.next_u64() % 100_000, TaskId(i)));
        }
        let n = 20_000;
        for i in 0..n {
            s.insert(rng.next_u64() % 100_000, TaskId(i));
            black_box(s.pop());
        }
        2 * n as u64
    });
}

fn bench_scheduler_pick() {
    bench("scheduler pick+requeue (12 cores, 24 tasks)", || {
        let mut s = Scheduler::new(
            PolicyKind::CoreSpec { avx_cores: 2 },
            SchedParams::default(),
            12,
        );
        let tasks: Vec<TaskId> = (0..24)
            .map(|i| {
                s.add_task(if i % 3 == 0 { TaskType::Avx } else { TaskType::Scalar }, 0)
            })
            .collect();
        for (i, t) in tasks.iter().enumerate() {
            s.enqueue(0, *t, i % 12, &|_| false, None);
        }
        let n = 50_000u64;
        let mut now = 1;
        for i in 0..n {
            let core = (i % 12) as usize;
            if let Some(_t) = s.pick(now, core) {
                now += 1000;
                s.requeue_running(now, core, i % 4 == 0, &|_| false);
            }
        }
        n
    });
}

fn bench_license_observe() {
    bench("license state machine observe", || {
        let mut m = LicenseState::new(FreqParams::default());
        let n = 1_000_000u64;
        let mut now = 0;
        for i in 0..n {
            now += 2_000;
            let d = match i % 97 {
                0..=2 => License::L2,
                3..=9 => License::L1,
                _ => License::L0,
            };
            black_box(m.observe(now, d));
        }
        n
    });
}

fn bench_run_block() {
    let turbo = TurboTable::xeon_gold_6130();
    bench("core run_block (scalar 10k insns)", || {
        let mut c = Core::new(0, FreqParams::default(), IpcParams::default());
        let b = Block {
            mix: ClassMix::scalar(10_000),
            mem_ops: 500,
            branches: 1500,
            license_exempt: false,
        };
        let n = 200_000u64;
        let mut now = 0;
        for i in 0..n {
            let out = c.run_block(now, &b, i % 12, 12, &turbo);
            now += out.ns;
        }
        n
    });
    bench("core run_block (avx512 10k insns)", || {
        let mut c = Core::new(0, FreqParams::default(), IpcParams::default());
        let b = Block {
            mix: ClassMix::of(InsnClass::Avx512Heavy, 10_000),
            mem_ops: 100,
            branches: 200,
            license_exempt: false,
        };
        let n = 200_000u64;
        let mut now = 0;
        for i in 0..n {
            let out = c.run_block(now, &b, i % 3, 12, &turbo);
            now += out.ns;
        }
        n
    });
}

fn bench_event_queue() {
    bench("event queue schedule+pop", || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..128 {
            q.schedule_at(i, i);
        }
        let n = 500_000u64;
        for i in 0..n {
            let (t, _) = q.pop().unwrap();
            q.schedule_at(t + 1 + i % 1000, i);
        }
        n
    });
}

fn bench_full_sim() {
    use avxfreq::sim::MS;
    println!();
    for (name, policy) in [
        ("unmodified", PolicyKind::Unmodified),
        ("core-spec", PolicyKind::CoreSpec { avx_cores: 2 }),
    ] {
        let mut cfg = WebCfg::paper_default(Isa::Avx512, policy);
        cfg.warmup = 200 * MS;
        cfg.measure = SEC;
        let t0 = Instant::now();
        let (run, m) = run_webserver_machine(&cfg);
        let wall = t0.elapsed().as_secs_f64();
        let total = m.total_perf();
        println!(
            "full web sim [{name:<10}] {:>6.2}s wall for 1.2s sim | {:>6.1} M simulated insns/s | {:>6.0} req/s",
            wall,
            total.instructions as f64 / wall / 1e6,
            run.throughput_rps,
        );
    }
}

fn main() {
    println!("== avxfreq hot-path microbenchmarks ==\n");
    bench_skiplist();
    bench_event_queue();
    bench_license_observe();
    bench_run_block();
    bench_scheduler_pick();
    bench_full_sim();
}
