//! Bench harness for paper experiment `fig5` (see DESIGN.md §5).
//! Full windows by default; set AVXFREQ_QUICK=1 for a fast pass.
fn main() -> anyhow::Result<()> {
    let quick = std::env::var("AVXFREQ_QUICK").is_ok();
    let seed = std::env::var("AVXFREQ_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0x5EED);
    let t0 = std::time::Instant::now();
    let r = avxfreq::repro::run("fig5", quick, seed)?;
    print!("{}", r.render());
    r.save_csvs()?;
    println!("[bench fig5_throughput] wallclock {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
