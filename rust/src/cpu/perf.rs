//! PMU counter model.
//!
//! The paper's identification workflow (§3.3) and frequency evaluation
//! (§4.1) are built on four Skylake-SP core events plus the fixed
//! counters; all of them fall directly out of the license state machine:
//!
//! * `CORE_POWER.LVL0_TURBO_LICENSE` — cycles at license L0,
//! * `CORE_POWER.LVL1_TURBO_LICENSE` — cycles at L1,
//! * `CORE_POWER.LVL2_TURBO_LICENSE` — cycles at L2,
//! * `CORE_POWER.THROTTLE` — cycles with reduced performance while a
//!   power-license request is pending.

use super::freq::License;
use crate::sim::Time;

/// Per-core counter file. All cycle counts are in core cycles; `*_ns`
/// integrals are wall-clock nanoseconds used for average-frequency
/// reporting (paper Fig 6 measures average frequency, not cycles).
#[derive(Clone, Debug, Default)]
pub struct PerfCounters {
    pub instructions: u64,
    pub cycles: u64,
    pub branches: u64,
    pub mispredicts: u64,
    pub mem_stall_cycles: u64,
    /// CORE_POWER.LVLn_TURBO_LICENSE.
    pub license_cycles: [u64; 3],
    /// CORE_POWER.THROTTLE.
    pub throttle_cycles: u64,
    /// Busy wall-clock time at each license level.
    pub license_ns: [Time; 3],
    /// Busy wall-clock time total / idle time.
    pub busy_ns: Time,
    pub idle_ns: Time,
    /// ∫ frequency·dt over busy time (GHz·ns) — for average frequency.
    pub freq_integral: f64,
    /// License requests and actual frequency switches.
    pub license_requests: u64,
    pub freq_switches: u64,
    /// PLL stall time.
    pub stall_ns: Time,
    /// Energy consumed while executing (J), integrated exactly per
    /// slice by the charging sites (see [`crate::cpu::power`]).
    pub active_energy_j: f64,
    /// Energy consumed while idle (J).
    pub idle_energy_j: f64,
}

impl PerfCounters {
    /// Record one executed slice.
    #[allow(clippy::too_many_arguments)]
    pub fn record_slice(
        &mut self,
        license: License,
        throttled: bool,
        cycles: f64,
        ns: Time,
        ghz: f64,
        insns: u64,
        branches: u64,
        mispredicts: f64,
        mem_stall_cycles: f64,
    ) {
        let cyc = cycles.round() as u64;
        self.instructions += insns;
        self.cycles += cyc;
        self.branches += branches;
        self.mispredicts += mispredicts.round() as u64;
        self.mem_stall_cycles += mem_stall_cycles.round() as u64;
        self.license_cycles[license.index()] += cyc;
        if throttled {
            self.throttle_cycles += cyc;
        }
        self.license_ns[license.index()] += ns;
        self.busy_ns += ns;
        self.freq_integral += ghz * ns as f64;
    }

    pub fn record_idle(&mut self, ns: Time) {
        self.idle_ns += ns;
    }

    pub fn record_stall(&mut self, ns: Time) {
        self.stall_ns += ns;
        self.busy_ns += ns;
    }

    /// Charge energy drawn while executing (J).
    pub fn record_active_energy(&mut self, joules: f64) {
        self.active_energy_j += joules;
    }

    /// Charge energy drawn while idle (J).
    pub fn record_idle_energy(&mut self, joules: f64) {
        self.idle_energy_j += joules;
    }

    /// Total energy consumed (J), active + idle. (Average watts are a
    /// reporting concern — [`crate::metrics::EnergyRow::avg_w`] divides
    /// by the measurement window, the one denominator every table
    /// uses.)
    pub fn energy_j(&self) -> f64 {
        self.active_energy_j + self.idle_energy_j
    }

    /// Average busy frequency in GHz (Fig 6 metric). Idle time excluded,
    /// matching how the paper measures "average frequency of the cores
    /// executing the web server".
    pub fn avg_busy_ghz(&self) -> f64 {
        if self.busy_ns == 0 {
            return 0.0;
        }
        self.freq_integral / self.busy_ns as f64
    }

    /// Instructions per cycle (§4.2 metric).
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instructions as f64 / self.cycles as f64
        }
    }

    /// Fraction of cycles spent throttled.
    pub fn throttle_ratio(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.throttle_cycles as f64 / self.cycles as f64
        }
    }

    /// Fraction of busy time spent at each license level.
    pub fn license_time_share(&self) -> [f64; 3] {
        let total: Time = self.license_ns.iter().sum();
        if total == 0 {
            return [0.0; 3];
        }
        [
            self.license_ns[0] as f64 / total as f64,
            self.license_ns[1] as f64 / total as f64,
            self.license_ns[2] as f64 / total as f64,
        ]
    }

    pub fn merge(&mut self, o: &PerfCounters) {
        self.instructions += o.instructions;
        self.cycles += o.cycles;
        self.branches += o.branches;
        self.mispredicts += o.mispredicts;
        self.mem_stall_cycles += o.mem_stall_cycles;
        for i in 0..3 {
            self.license_cycles[i] += o.license_cycles[i];
            self.license_ns[i] += o.license_ns[i];
        }
        self.throttle_cycles += o.throttle_cycles;
        self.busy_ns += o.busy_ns;
        self.idle_ns += o.idle_ns;
        self.freq_integral += o.freq_integral;
        self.license_requests += o.license_requests;
        self.freq_switches += o.freq_switches;
        self.stall_ns += o.stall_ns;
        self.active_energy_j += o.active_energy_j;
        self.idle_energy_j += o.idle_energy_j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_frequency_weighted_by_time() {
        let mut c = PerfCounters::default();
        // 1 ms at 2.8 GHz, 1 ms at 1.9 GHz → 2.35 GHz average.
        c.record_slice(License::L0, false, 2.8e6, 1_000_000, 2.8, 1000, 0, 0.0, 0.0);
        c.record_slice(License::L2, false, 1.9e6, 1_000_000, 1.9, 1000, 0, 0.0, 0.0);
        assert!((c.avg_busy_ghz() - 2.35).abs() < 1e-9);
    }

    #[test]
    fn license_counters_accumulate() {
        let mut c = PerfCounters::default();
        c.record_slice(License::L1, true, 100.0, 50, 2.4, 90, 10, 1.0, 5.0);
        assert_eq!(c.license_cycles[1], 100);
        assert_eq!(c.throttle_cycles, 100);
        assert_eq!(c.mispredicts, 1);
        assert_eq!(c.license_time_share()[1], 1.0);
    }

    #[test]
    fn ipc_ratio() {
        let mut c = PerfCounters::default();
        c.record_slice(License::L0, false, 500.0, 100, 2.8, 1000, 0, 0.0, 0.0);
        assert!((c.ipc() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_everything() {
        let mut a = PerfCounters::default();
        let mut b = PerfCounters::default();
        a.record_slice(License::L0, false, 10.0, 10, 2.8, 10, 2, 1.0, 0.0);
        b.record_slice(License::L2, true, 20.0, 20, 1.9, 20, 4, 2.0, 3.0);
        b.record_idle(7);
        a.merge(&b);
        assert_eq!(a.instructions, 30);
        assert_eq!(a.cycles, 30);
        assert_eq!(a.throttle_cycles, 20);
        assert_eq!(a.idle_ns, 7);
    }

    #[test]
    fn empty_counters_safe() {
        let c = PerfCounters::default();
        assert_eq!(c.avg_busy_ghz(), 0.0);
        assert_eq!(c.ipc(), 0.0);
        assert_eq!(c.license_time_share(), [0.0; 3]);
        assert_eq!(c.energy_j(), 0.0);
    }

    #[test]
    fn energy_accumulates_and_merges() {
        let mut a = PerfCounters::default();
        a.record_slice(License::L0, false, 2.8e6, 1_000_000, 2.8, 1000, 0, 0.0, 0.0);
        a.record_active_energy(2.0);
        a.record_idle(1_000_000);
        a.record_idle_energy(0.5);
        assert_eq!(a.energy_j(), 2.5);
        let mut b = PerfCounters::default();
        b.record_active_energy(1.0);
        a.merge(&b);
        assert_eq!(a.active_energy_j, 3.0);
        assert_eq!(a.idle_energy_j, 0.5);
    }
}
