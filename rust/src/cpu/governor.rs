//! Pluggable DVFS governors: the policy layer above the license state
//! machine.
//!
//! The hardware state machine in [`freq`](super::freq) fixes *what*
//! transitions exist (request → throttled phase → grant; hold window →
//! relax). The governor decides *how expensive and how eager* they are:
//! how long a grant takes, how long an actual frequency switch stalls
//! the core (the voltage ramp), and how wide the AVX hysteresis timer
//! is. "Dim Silicon and the Case for Improved DVFS Policies"
//! (Gottschlag et al.) and "Energy Efficiency Features of the Intel
//! Skylake-SP Processor" (Schöne et al.) both show these policy knobs
//! materially change the cost of AVX-induced transitions, so they are a
//! scenario axis here, selectable per machine:
//!
//! * [`IntelLegacy`] — the shipped Skylake-SP behaviour and the
//!   differential anchor: fixed ~2 ms AVX timer, effectively instant
//!   voltage ramps. Returns every base [`FreqParams`] value verbatim,
//!   so a machine running this governor is **bit-for-bit identical** to
//!   the pre-governor simulator (pinned by `rust/tests/power.rs`).
//! * [`SlowRamp`] — Skylake-SP with the *measured* transition costs:
//!   every actual frequency switch additionally pays a voltage-ramp
//!   stall proportional to the number of license levels crossed
//!   (Mazouz et al. / Schöne et al. report tens of µs per transition).
//! * [`DimSilicon`] — an improved-DVFS policy: under transition *churn*
//!   (switches arriving back-to-back) it widens the AVX timer, trading
//!   a longer stay at the low frequency for fewer PLL stalls and fewer
//!   oscillations — the "don't thrash the PLL" policy the Dim Silicon
//!   paper argues for.
//!
//! [`GovernorSpec`] is the serializable handle (config keys, CLI flags,
//! the scenario-matrix axis); [`GovernorSpec::build`] instantiates the
//! boxed state. Governors may keep internal state (e.g. churn
//! tracking), which is why the switch/hold hooks take `&mut self`.

use super::freq::{FreqParams, License};
use crate::sim::{Time, MS, US};

/// Which governor to run — the config/CLI/matrix-axis handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GovernorSpec {
    /// Shipped Skylake-SP policy (the pre-governor differential anchor).
    IntelLegacy,
    /// Measured voltage-ramp transition stalls on every switch.
    SlowRamp,
    /// Widens the AVX hysteresis timer under transition churn.
    DimSilicon,
}

impl Default for GovernorSpec {
    fn default() -> Self {
        GovernorSpec::IntelLegacy
    }
}

impl GovernorSpec {
    /// Stable name used in tables, configs, and CLI flags.
    pub fn name(self) -> &'static str {
        match self {
            GovernorSpec::IntelLegacy => "intel-legacy",
            GovernorSpec::SlowRamp => "slow-ramp",
            GovernorSpec::DimSilicon => "dim-silicon",
        }
    }

    /// Parse a config/CLI name; unknown names are an error, never a
    /// silent default (a typo would otherwise run the wrong policy and
    /// label the tables with it).
    pub fn parse(s: &str) -> anyhow::Result<GovernorSpec> {
        match s {
            "intel-legacy" => Ok(GovernorSpec::IntelLegacy),
            "slow-ramp" => Ok(GovernorSpec::SlowRamp),
            "dim-silicon" => Ok(GovernorSpec::DimSilicon),
            other => anyhow::bail!(
                "unknown governor {other:?} (intel-legacy|slow-ramp|dim-silicon)"
            ),
        }
    }

    /// Every governor, in sweep order.
    pub fn all() -> [GovernorSpec; 3] {
        [GovernorSpec::IntelLegacy, GovernorSpec::SlowRamp, GovernorSpec::DimSilicon]
    }

    /// Instantiate the governor with its default tuning.
    pub fn build(self) -> Box<dyn Governor> {
        match self {
            GovernorSpec::IntelLegacy => Box::new(IntelLegacy),
            GovernorSpec::SlowRamp => Box::new(SlowRamp::default()),
            GovernorSpec::DimSilicon => Box::new(DimSilicon::default()),
        }
    }
}

/// The policy hooks the license state machine consults. Implementations
/// must be deterministic functions of their own state and the arguments
/// (no wall clock, no RNG) — machine determinism depends on it.
pub trait Governor: std::fmt::Debug {
    /// Which spec built this governor (for labels and cloning checks).
    fn spec(&self) -> GovernorSpec;

    /// Latency from license request to PCU grant.
    fn grant_latency(&self, base: &FreqParams) -> Time;

    /// Stall charged on an *actual* frequency switch from `from` to
    /// `to` completing at `now` (the voltage-ramp / PLL-relock cost).
    /// Called exactly once per switch, so stateful governors may use it
    /// to observe transition churn.
    fn switch_stall(&mut self, base: &FreqParams, now: Time, from: License, to: License)
        -> Time;

    /// Length of the hold (AVX hysteresis) window opened at `now`
    /// before the core may relax to a faster license.
    fn hold(&mut self, base: &FreqParams, now: Time) -> Time;

    /// Clone into a fresh box ([`LicenseState`](super::freq::LicenseState)
    /// derives `Clone`).
    fn clone_box(&self) -> Box<dyn Governor>;
}

impl Clone for Box<dyn Governor> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// The shipped Skylake-SP policy: every hook returns the base
/// [`FreqParams`] value verbatim. This is the load-bearing differential
/// property — with this governor the state machine's arithmetic is
/// exactly the pre-governor code path.
#[derive(Clone, Debug)]
pub struct IntelLegacy;

impl Governor for IntelLegacy {
    fn spec(&self) -> GovernorSpec {
        GovernorSpec::IntelLegacy
    }

    fn grant_latency(&self, base: &FreqParams) -> Time {
        base.grant_latency
    }

    fn switch_stall(&mut self, base: &FreqParams, _now: Time, _from: License, _to: License) -> Time {
        base.switch_stall
    }

    fn hold(&mut self, base: &FreqParams, _now: Time) -> Time {
        base.hold
    }

    fn clone_box(&self) -> Box<dyn Governor> {
        Box::new(self.clone())
    }
}

/// Skylake-SP with measured voltage-ramp costs: each actual switch
/// stalls for the base PLL relock *plus* `ramp_per_level` per license
/// level crossed (L0→L2 crosses two). Schöne et al. measure per-
/// transition latencies in the tens of microseconds on Skylake-SP.
#[derive(Clone, Debug)]
pub struct SlowRamp {
    pub ramp_per_level: Time,
}

impl Default for SlowRamp {
    fn default() -> Self {
        SlowRamp { ramp_per_level: 25 * US }
    }
}

impl Governor for SlowRamp {
    fn spec(&self) -> GovernorSpec {
        GovernorSpec::SlowRamp
    }

    fn grant_latency(&self, base: &FreqParams) -> Time {
        base.grant_latency
    }

    fn switch_stall(&mut self, base: &FreqParams, _now: Time, from: License, to: License) -> Time {
        let levels = from.index().abs_diff(to.index()).max(1) as Time;
        base.switch_stall + self.ramp_per_level * levels
    }

    fn hold(&mut self, base: &FreqParams, _now: Time) -> Time {
        base.hold
    }

    fn clone_box(&self) -> Box<dyn Governor> {
        Box::new(self.clone())
    }
}

/// Improved-DVFS policy ("Dim Silicon"): when frequency switches arrive
/// back-to-back (within `churn_window` of each other), each one raises
/// a churn level (capped at `max_widen`), and the AVX hysteresis timer
/// widens to `base.hold × (1 + churn)`. A quiet `churn_window` resets
/// the level, restoring the stock 2 ms timer. The effect: oscillating
/// AVX/scalar phases stop thrashing the PLL — the core simply stays at
/// the low license through short scalar gaps — at the cost of more time
/// spent slow (the energy/latency trade `repro energydelay` measures).
#[derive(Clone, Debug)]
pub struct DimSilicon {
    /// Two switches closer than this count as churn.
    pub churn_window: Time,
    /// Cap on the widening level (timer grows to at most
    /// `hold × (1 + max_widen)`).
    pub max_widen: u32,
    churn: u32,
    last_switch: Option<Time>,
}

impl Default for DimSilicon {
    fn default() -> Self {
        DimSilicon { churn_window: 10 * MS, max_widen: 3, churn: 0, last_switch: None }
    }
}

impl DimSilicon {
    /// Current widening level (diagnostics/tests).
    pub fn churn(&self) -> u32 {
        self.churn
    }

    fn decay_if_quiet(&mut self, now: Time) {
        if let Some(t) = self.last_switch {
            if now.saturating_sub(t) > self.churn_window {
                self.churn = 0;
            }
        }
    }
}

impl Governor for DimSilicon {
    fn spec(&self) -> GovernorSpec {
        GovernorSpec::DimSilicon
    }

    fn grant_latency(&self, base: &FreqParams) -> Time {
        base.grant_latency
    }

    fn switch_stall(&mut self, base: &FreqParams, now: Time, _from: License, _to: License) -> Time {
        match self.last_switch {
            Some(t) if now.saturating_sub(t) <= self.churn_window => {
                self.churn = (self.churn + 1).min(self.max_widen);
            }
            Some(_) => self.churn = 0,
            None => {}
        }
        self.last_switch = Some(now);
        base.switch_stall
    }

    fn hold(&mut self, base: &FreqParams, now: Time) -> Time {
        self.decay_if_quiet(now);
        base.hold * (1 + self.churn as Time)
    }

    fn clone_box(&self) -> Box<dyn Governor> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for spec in GovernorSpec::all() {
            assert_eq!(GovernorSpec::parse(spec.name()).unwrap(), spec);
            assert_eq!(spec.build().spec(), spec);
        }
        assert!(GovernorSpec::parse("ondemand").is_err());
    }

    #[test]
    fn intel_legacy_returns_base_params_verbatim() {
        let base = FreqParams::default();
        let mut g = IntelLegacy;
        assert_eq!(g.grant_latency(&base), base.grant_latency);
        assert_eq!(g.hold(&base, 123), base.hold);
        assert_eq!(
            g.switch_stall(&base, 456, License::L0, License::L2),
            base.switch_stall
        );
    }

    #[test]
    fn slow_ramp_scales_with_levels_crossed() {
        let base = FreqParams::default();
        let mut g = SlowRamp::default();
        let one = g.switch_stall(&base, 0, License::L0, License::L1);
        let two = g.switch_stall(&base, 0, License::L0, License::L2);
        assert!(one > base.switch_stall);
        assert_eq!(two - base.switch_stall, 2 * (one - base.switch_stall));
        assert_eq!(g.hold(&base, 0), base.hold, "slow-ramp keeps the stock timer");
    }

    #[test]
    fn dim_silicon_widens_under_churn_and_resets_when_quiet() {
        let base = FreqParams::default();
        let mut g = DimSilicon::default();
        assert_eq!(g.hold(&base, 0), base.hold);
        // Back-to-back switches raise the churn level…
        g.switch_stall(&base, 0, License::L0, License::L2);
        g.switch_stall(&base, 2 * MS, License::L2, License::L0);
        g.switch_stall(&base, 4 * MS, License::L0, License::L2);
        assert_eq!(g.churn(), 2);
        assert_eq!(g.hold(&base, 5 * MS), 3 * base.hold);
        // …and the cap binds…
        g.switch_stall(&base, 5 * MS, License::L2, License::L0);
        g.switch_stall(&base, 6 * MS, License::L0, License::L2);
        assert_eq!(g.churn(), g.max_widen);
        // …while a quiet window resets to the stock timer.
        assert_eq!(g.hold(&base, 6 * MS + g.churn_window + 1), base.hold);
    }
}
