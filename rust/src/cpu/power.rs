//! Per-core power model and exact energy integration.
//!
//! The license mechanism exists because wide instructions *draw more
//! power*: the PCU lowers frequency precisely so the package stays
//! inside its electrical limits (paper §2; Schöne et al. measure the
//! Skylake-SP power/frequency trade directly). Modeling power closes
//! the loop: the simulator can now report what the mitigation *costs*
//! and *saves* in Joules, not just microseconds.
//!
//! The model is deliberately simple and exactly integrable: a core
//! draws `idle_w` while idle and `active_w_per_ghz[license] × f` while
//! executing at frequency `f`. Dynamic power is linear in frequency at
//! a fixed voltage, and the license level is the voltage proxy — wide
//! execution units switching at the higher AVX voltage cost more per
//! GHz, which is why `active_w_per_ghz` *rises* with license severity
//! even as the frequency falls. Within one execution slice the license
//! and frequency are constant, so the slice's energy is exactly
//! `P × dt` — no quadrature error, and per-core energies merge by
//! addition (the same law the latency recorders obey, property-tested
//! in `rust/tests/power.rs`).
//!
//! Charging points: [`Core::run_block`](super::Core::run_block) and
//! [`Core::idle_until`](super::Core::idle_until) for workload
//! execution and idle time, and the machine's scheduler-overhead path
//! (`sched/machine.rs::charge_overhead`) for kernel time — every
//! nanosecond the frequency model accounts for is also
//! energy-accounted.

use super::freq::License;
use crate::sim::Time;

/// Per-core power-model parameters. Defaults are Skylake-SP-shaped:
/// ~4.5 W/core active at the 2.8 GHz scalar all-core turbo, ~6.5 W at
/// the 2.4 GHz AVX2 license, ~8 W at the 1.9 GHz AVX-512 license
/// (per-core shares of the package numbers Schöne et al. report),
/// 1.5 W idle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PowerParams {
    /// Power drawn by an idle core (W).
    pub idle_w: f64,
    /// Active power per GHz at each license level (W/GHz). Rises with
    /// license severity: the AVX voltage/capacitance costs more per
    /// cycle even though the cycles come slower.
    pub active_w_per_ghz: [f64; 3],
}

impl Default for PowerParams {
    fn default() -> Self {
        PowerParams { idle_w: 1.5, active_w_per_ghz: [1.6, 2.7, 4.2] }
    }
}

impl PowerParams {
    /// Active power (W) for a core holding `license` at `ghz`.
    pub fn active_w(&self, license: License, ghz: f64) -> f64 {
        self.active_w_per_ghz[license.index()] * ghz
    }

    /// Exact energy (J) of drawing `w` watts for `ns` nanoseconds.
    pub fn energy_j(w: f64, ns: Time) -> f64 {
        w * ns as f64 * 1e-9
    }

    /// Reject parameter sets that would silently corrupt the energy
    /// accounting (negative power would make energy non-monotone).
    pub fn validate(&self) -> Result<(), String> {
        let ok = |x: f64| x.is_finite() && x >= 0.0;
        if !ok(self.idle_w) {
            return Err(format!("power.idle_w = {} must be finite and ≥ 0", self.idle_w));
        }
        for (i, w) in self.active_w_per_ghz.iter().enumerate() {
            if !ok(*w) {
                return Err(format!(
                    "power.active_w_per_ghz[{i}] = {w} must be finite and ≥ 0"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_tell_the_avx_power_story() {
        let p = PowerParams::default();
        // Watts at the documented all-core turbos: AVX licenses draw
        // more power despite running slower.
        let l0 = p.active_w(License::L0, 2.8);
        let l1 = p.active_w(License::L1, 2.4);
        let l2 = p.active_w(License::L2, 1.9);
        assert!(l0 < l1 && l1 < l2, "{l0} {l1} {l2}");
        assert!(p.idle_w < l0);
        p.validate().unwrap();
    }

    #[test]
    fn energy_is_exact_power_times_time() {
        // 4 W for 250 ms = 1 J, exactly representable.
        assert_eq!(PowerParams::energy_j(4.0, 250_000_000), 1.0);
        assert_eq!(PowerParams::energy_j(0.0, 1_000_000), 0.0);
    }

    #[test]
    fn validate_rejects_nonsense() {
        let mut p = PowerParams::default();
        p.idle_w = -1.0;
        assert!(p.validate().is_err());
        p.idle_w = 1.0;
        p.active_w_per_ghz[2] = f64::NAN;
        assert!(p.validate().is_err());
    }
}
