//! The core execution model: runs instruction blocks at the frequency the
//! license state machine allows, charging cycles from the IPC model and
//! advancing the PMU counters.
//!
//! Execution is sliced at basic-block granularity; workload builders keep
//! blocks at or below a few tens of microseconds so license transitions
//! (100 µs–2 ms scale) are observed promptly. The `max_slice_cycles`
//! guard splits oversized blocks defensively.

use super::freq::{FreqParams, License, LicenseState};
use super::ipc::{cost_block_with, license_demand, CostCache, FootprintTracker, IpcParams};
use super::perf::PerfCounters;
use super::power::PowerParams;
use super::turbo::TurboTable;
use crate::isa::block::Block;
use crate::sim::Time;

/// Where a slice's frequency comes from: the turbo table directly, or a
/// per-window cache of the three license levels' frequencies that the
/// machine's coalescing loop hoists out of the repetition loop (the
/// active-core count is constant inside a coalesced window, so the
/// three lookups happen once instead of once per repetition). Both
/// sources yield the identical `f64` for a given license.
enum FreqSource<'a> {
    Table(&'a TurboTable, usize),
    Cached(&'a [f64; 3]),
}

impl FreqSource<'_> {
    #[inline]
    fn ghz(&self, license: License) -> f64 {
        match self {
            FreqSource::Table(t, active) => t.ghz(license, *active),
            FreqSource::Cached(g) => g[license.index()],
        }
    }
}

/// Outcome of executing one block on a core.
#[derive(Clone, Copy, Debug)]
pub struct SliceOutcome {
    /// Wall-clock duration of the block (including any PLL stall).
    pub ns: Time,
    /// Core cycles consumed.
    pub cycles: f64,
    /// Cycles counted toward CORE_POWER.THROTTLE.
    pub throttle_cycles: f64,
    /// License level the block ran at.
    pub license: License,
    /// Frequency the block ran at (GHz).
    pub ghz: f64,
}

/// One physical core.
#[derive(Clone, Debug)]
pub struct Core {
    pub id: usize,
    pub license: LicenseState,
    pub perf: PerfCounters,
    pub footprint: FootprintTracker,
    /// Power model charged as the core runs (defaults are Skylake-SP
    /// shaped; the machine overrides them from its own parameters).
    pub power: PowerParams,
    /// Memoize the pressure-independent part of block costing (see
    /// [`CostCache`]). A hit is bit-identical to the direct computation,
    /// so this is purely a speed knob; the machine sets it from
    /// `MachineParams::fast_paths` so the bench harness can compare.
    pub memoize: bool,
    ipc_params: IpcParams,
    cost_cache: CostCache,
}

impl Core {
    pub fn new(id: usize, freq_params: FreqParams, ipc_params: IpcParams) -> Self {
        let cap = ipc_params.predictor_capacity;
        Core {
            id,
            license: LicenseState::new(freq_params),
            perf: PerfCounters::default(),
            footprint: FootprintTracker::new(cap),
            power: PowerParams::default(),
            memoize: true,
            ipc_params,
            cost_cache: CostCache::default(),
        }
    }

    /// Costing-cache hit/miss counters (diagnostics for the bench
    /// harness; zero when `memoize` is off).
    pub fn cost_cache_stats(&self) -> (u64, u64) {
        (self.cost_cache.hits, self.cost_cache.misses)
    }

    pub fn ipc_params(&self) -> &IpcParams {
        &self.ipc_params
    }

    /// Execute `block` belonging to function `func` starting at `now`,
    /// with `active` cores awake package-wide. Returns the slice outcome;
    /// the caller advances its clock by `outcome.ns`.
    pub fn run_block(
        &mut self,
        now: Time,
        block: &Block,
        func: u64,
        active: usize,
        turbo: &TurboTable,
    ) -> SliceOutcome {
        self.run_block_inner(now, block, func, FreqSource::Table(turbo, active))
    }

    /// [`Core::run_block`] with the per-license frequencies already
    /// looked up (`ghz_by_license[i]` = the turbo table's value for
    /// license *i* at the caller's active-core count). The machine's
    /// steady-state coalescing loop hoists the three lookups out of the
    /// repetition loop; results are bit-identical to [`Core::run_block`].
    #[inline]
    pub fn run_block_with_freqs(
        &mut self,
        now: Time,
        block: &Block,
        func: u64,
        ghz_by_license: &[f64; 3],
    ) -> SliceOutcome {
        self.run_block_inner(now, block, func, FreqSource::Cached(ghz_by_license))
    }

    #[inline]
    fn run_block_inner(
        &mut self,
        now: Time,
        block: &Block,
        func: u64,
        freq: FreqSource<'_>,
    ) -> SliceOutcome {
        // Pending PLL stall from a recent frequency switch.
        let stall = self.license.stall_ns(now);
        if stall > 0 {
            self.perf.record_stall(stall);
        }
        let start = now + stall;

        // Cost the block at the current footprint pressure. The memo
        // covers only the pressure-independent execution cycles, so a
        // cache hit reproduces the direct computation bit for bit (see
        // `CostCache`); `cost_block_with` runs the pressure-dependent
        // tail in the historical operation order either way.
        self.footprint.touch(func);
        let exec = if self.memoize {
            self.cost_cache.exec_cycles(&self.ipc_params, &block.mix)
        } else {
            super::ipc::exec_cycles(&self.ipc_params, &block.mix)
        };
        let cost = cost_block_with(&self.ipc_params, block, self.footprint.pressure(), exec);

        // License demand is a property of the block's densities.
        let demand = license_demand(self.license.params(), block, cost.cycles);
        let eff = self.license.observe(start, demand);

        let cycles = cost.cycles / eff.ipc_factor;
        let throttle_cycles = if eff.throttled { cycles } else { 0.0 };
        let ghz = freq.ghz(eff.license);
        let exec_ns = ((cycles / ghz).ceil() as Time).max(1);
        let ns = stall + exec_ns;

        self.perf.record_slice(
            eff.license,
            eff.throttled,
            cycles,
            exec_ns,
            ghz,
            block.insns(),
            block.branches,
            cost.mispredicts,
            cost.mem_stall_cycles,
        );
        self.perf.license_requests = self.license.requests;
        self.perf.freq_switches = self.license.switches;

        // Energy: the whole slice (PLL stall included — the core is
        // powered, just not retiring) draws active power at the slice's
        // license level and frequency. Constant within the slice, so
        // P × dt is the exact integral.
        let w = self.power.active_w(eff.license, ghz);
        self.perf.record_active_energy(PowerParams::energy_j(w, ns));

        SliceOutcome { ns, cycles, throttle_cycles, license: eff.license, ghz }
    }

    /// Let the license machine observe idle time (idle cores eventually
    /// relax their license: the hold window keeps running while idle).
    pub fn idle_until(&mut self, from: Time, to: Time) {
        debug_assert!(to >= from);
        self.perf.record_idle(to - from);
        self.perf
            .record_idle_energy(PowerParams::energy_j(self.power.idle_w, to - from));
        // Idle executes no heavy instructions: demand L0.
        self.license.observe(to, License::L0);
    }

    /// Next time at which this core's license state can change on its own.
    pub fn next_license_edge(&self) -> Option<Time> {
        self.license.next_edge()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::block::{ClassMix, InsnClass};
    use crate::sim::{MS, US};

    fn core() -> Core {
        Core::new(0, FreqParams::default(), IpcParams::default())
    }

    fn turbo() -> TurboTable {
        TurboTable::xeon_gold_6130_no_cstates()
    }

    fn scalar(n: u64) -> Block {
        Block { mix: ClassMix::scalar(n), mem_ops: 0, branches: 0, license_exempt: false }
    }

    fn avx512(n: u64) -> Block {
        Block { mix: ClassMix::of(InsnClass::Avx512Heavy, n), mem_ops: 0, branches: 0, license_exempt: false }
    }

    #[test]
    fn scalar_runs_at_l0_full_speed() {
        let mut c = core();
        let t = turbo();
        let out = c.run_block(0, &scalar(28_000), 1, 16, &t);
        assert_eq!(out.license, License::L0);
        assert_eq!(out.ghz, 2.8);
        // 28000 insns / 2.2 IPC ≈ 12727 cycles @2.8GHz ≈ 4545ns
        assert!((out.ns as f64 - 4546.0).abs() < 20.0, "ns={}", out.ns);
    }

    #[test]
    fn avx512_block_throttles_then_downclocks() {
        let mut c = core();
        let t = turbo();
        let out = c.run_block(0, &avx512(10_000), 2, 16, &t);
        assert!(out.throttle_cycles > 0.0, "first AVX block must throttle");
        assert_eq!(out.license, License::L0, "frequency not yet dropped");

        // Keep executing AVX past the grant latency.
        let mut now = out.ns;
        let mut granted_l2 = false;
        for _ in 0..200 {
            let o = c.run_block(now, &avx512(10_000), 2, 16, &t);
            now += o.ns;
            if o.license == License::L2 {
                granted_l2 = true;
                break;
            }
        }
        assert!(granted_l2, "L2 must be granted after the request latency");
        assert!(c.perf.throttle_cycles > 0);
        assert!(c.perf.license_cycles[2] > 0);
    }

    #[test]
    fn scalar_after_avx_suffers_for_two_ms() {
        let mut c = core();
        let t = turbo();
        // Drive the core to a granted L2.
        let mut now = 0;
        for _ in 0..400 {
            let o = c.run_block(now, &avx512(10_000), 2, 16, &t);
            now += o.ns;
            if o.license == License::L2 && o.throttle_cycles == 0.0 {
                break;
            }
        }
        // Scalar code now runs at 1.9 GHz until the hold expires.
        let mut slow_ns = 0;
        let mut saw_recovery = false;
        for _ in 0..4000 {
            let o = c.run_block(now, &scalar(5000), 2, 16, &t);
            now += o.ns;
            if o.license == License::L2 {
                slow_ns += o.ns;
            } else {
                saw_recovery = true;
                assert_eq!(o.ghz, 2.8);
                break;
            }
        }
        assert!(saw_recovery, "license must eventually relax");
        let slow_ms = slow_ns as f64 / MS as f64;
        assert!(
            (1.8..=2.4).contains(&slow_ms),
            "scalar code slowed for ~2ms, got {slow_ms}ms"
        );
    }

    #[test]
    fn idle_time_lets_license_relax() {
        let mut c = core();
        let t = turbo();
        let mut now = 0;
        for _ in 0..400 {
            let o = c.run_block(now, &avx512(10_000), 1, 16, &t);
            now += o.ns;
            if o.license == License::L2 {
                break;
            }
        }
        // First idle observation opens the hold window...
        c.idle_until(now, now + 10 * US);
        // ...and a long idle expires it.
        c.idle_until(now + 10 * US, now + 10 * US + 3 * MS);
        let o = c.run_block(now + 10 * US + 3 * MS, &scalar(1000), 1, 16, &t);
        assert_eq!(o.license, License::L0, "idle core must relax to L0");
    }

    #[test]
    fn footprint_miss_penalty_visible_in_ipc() {
        let t = turbo();
        // Same blocks, one core cycles through many functions, other through 2.
        let block = Block { mix: ClassMix::scalar(4000), mem_ops: 100, branches: 600, license_exempt: false };
        let mut hot = core();
        let mut cold = core();
        let mut now_h = 0;
        let mut now_c = 0;
        for i in 0..3000u64 {
            now_h += hot.run_block(now_h, &block, i % 2, 16, &t).ns;
            now_c += cold.run_block(now_c, &block, i % 64, 16, &t).ns;
        }
        assert!(
            hot.perf.ipc() > cold.perf.ipc() * 1.005,
            "hot {} vs cold {}",
            hot.perf.ipc(),
            cold.perf.ipc()
        );
    }

    #[test]
    fn energy_charged_for_busy_and_idle_time() {
        let mut c = core();
        let t = turbo();
        let out = c.run_block(0, &scalar(28_000), 1, 16, &t);
        let expected = c.power.active_w(out.license, out.ghz) * out.ns as f64 * 1e-9;
        assert!((c.perf.active_energy_j - expected).abs() < 1e-15);
        assert_eq!(c.perf.idle_energy_j, 0.0);
        c.idle_until(out.ns, out.ns + MS);
        let idle = c.power.idle_w * MS as f64 * 1e-9;
        assert!((c.perf.idle_energy_j - idle).abs() < 1e-15);
        assert!(c.perf.energy_j() > 0.0);
    }

    #[test]
    fn avx_slice_draws_more_power_than_scalar() {
        // Same wall-clock time at L2 costs more Joules than at L0 even
        // though the L2 clock is slower — the power story behind the
        // license mechanism.
        let t = turbo();
        let mut s = core();
        let mut a = core();
        let mut now_s = 0;
        let mut now_a = 0;
        while now_s < 20 * MS {
            now_s += s.run_block(now_s, &scalar(10_000), 2, 16, &t).ns;
        }
        while now_a < 20 * MS {
            now_a += a.run_block(now_a, &avx512(10_000), 2, 16, &t).ns;
        }
        let per_ns_s = s.perf.active_energy_j / s.perf.busy_ns as f64;
        let per_ns_a = a.perf.active_energy_j / a.perf.busy_ns as f64;
        assert!(
            per_ns_a > per_ns_s * 1.2,
            "AVX-512 watts must exceed scalar watts: {per_ns_a} vs {per_ns_s}"
        );
    }

    #[test]
    fn memoized_costing_is_bit_identical() {
        // Same block stream with the memo on and off: every outcome and
        // every counter (including the float accumulators) must be
        // bit-equal — memoization is a pure speed knob.
        let t = turbo();
        let mut fast = core();
        let mut slow = core();
        slow.memoize = false;
        // Two mixes, so the 2-slot memo alternates between hits; a
        // third distinct mix in rotation would defeat it (by design —
        // the cache is sized for the bulk-cipher ↔ MAC hot loop).
        let blocks = [scalar(10_000), avx512(10_000)];
        let (mut now_f, mut now_s) = (0, 0);
        for i in 0..600usize {
            let b = &blocks[i % blocks.len()];
            let of = fast.run_block(now_f, b, (i % 5) as u64, 2, &t);
            let os = slow.run_block(now_s, b, (i % 5) as u64, 2, &t);
            assert_eq!(of.ns, os.ns, "slice {i}");
            assert_eq!(of.cycles.to_bits(), os.cycles.to_bits(), "slice {i}");
            assert_eq!(of.license, os.license);
            now_f += of.ns;
            now_s += os.ns;
        }
        assert_eq!(fast.perf.instructions, slow.perf.instructions);
        assert_eq!(fast.perf.cycles, slow.perf.cycles);
        assert_eq!(fast.perf.busy_ns, slow.perf.busy_ns);
        assert_eq!(fast.perf.freq_integral.to_bits(), slow.perf.freq_integral.to_bits());
        assert_eq!(fast.perf.active_energy_j.to_bits(), slow.perf.active_energy_j.to_bits());
        let (hits, misses) = fast.cost_cache_stats();
        assert!(hits > 0 && misses >= 2, "memo must engage: {hits} hits / {misses} misses");
        assert_eq!(slow.cost_cache_stats(), (0, 0));
    }

    #[test]
    fn cached_freqs_match_table_lookup() {
        let t = TurboTable::xeon_gold_6130();
        let active = 7;
        let freqs = [
            t.ghz(License::L0, active),
            t.ghz(License::L1, active),
            t.ghz(License::L2, active),
        ];
        let mut a = core();
        let mut b = core();
        let blocks = [scalar(8_000), avx512(9_000)];
        let (mut now_a, mut now_b) = (0, 0);
        for i in 0..400usize {
            let blk = &blocks[i % 2];
            let oa = a.run_block(now_a, blk, 1, active, &t);
            let ob = b.run_block_with_freqs(now_b, blk, 1, &freqs);
            assert_eq!(oa.ns, ob.ns, "slice {i}");
            assert_eq!(oa.ghz.to_bits(), ob.ghz.to_bits());
            assert_eq!(oa.license, ob.license);
            now_a += oa.ns;
            now_b += ob.ns;
        }
        assert_eq!(a.perf.freq_integral.to_bits(), b.perf.freq_integral.to_bits());
    }

    #[test]
    fn active_core_count_changes_turbo() {
        let mut c = core();
        let t = TurboTable::xeon_gold_6130();
        let o1 = c.run_block(0, &scalar(1000), 0, 1, &t);
        assert_eq!(o1.ghz, 3.7, "single active core gets max turbo");
        let o2 = c.run_block(o1.ns, &scalar(1000), 0, 16, &t);
        assert_eq!(o2.ghz, 2.8);
    }
}
