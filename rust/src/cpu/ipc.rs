//! IPC model: converts an instruction mix into core cycles, and derives
//! the power-license *demand* of a slice from instruction densities.
//!
//! Fidelity target: the relative effects the paper measures — per-class
//! throughput differences, memory-stall sensitivity, and the §4.2
//! branch-misprediction effect of code-footprint reduction — not absolute
//! cycle accuracy.

use super::freq::{FreqParams, License};
use crate::isa::block::{Block, ClassMix, InsnClass};
use std::collections::VecDeque;

/// IPC model parameters.
#[derive(Clone, Debug)]
pub struct IpcParams {
    /// Peak IPC per instruction class (per-instruction throughput; wider
    /// instructions do more *work* per instruction, which the workload
    /// models as lower instruction counts, not higher IPC).
    pub base_ipc: [f64; 5],
    /// Average stall cycles per memory operation (cache-aware workloads
    /// fold their hit rates into this).
    pub mem_stall_cpi: f64,
    /// Branch misprediction penalty in cycles (Skylake ~16).
    pub mispredict_penalty: f64,
    /// Baseline misprediction rate for hot code.
    pub mispredict_rate_hot: f64,
    /// Additional misprediction rate for cold code (footprint miss).
    pub mispredict_rate_cold: f64,
    /// Number of distinct functions whose branch history fits the per-core
    /// predictor tables (paper §4.2: smaller per-core footprint → fewer
    /// mispredictions).
    pub predictor_capacity: usize,
}

impl Default for IpcParams {
    fn default() -> Self {
        IpcParams {
            base_ipc: [2.2, 1.9, 1.7, 1.5, 1.3],
            mem_stall_cpi: 1.1,
            mispredict_penalty: 16.0,
            mispredict_rate_hot: 0.015,
            mispredict_rate_cold: 0.10,
            predictor_capacity: 7,
        }
    }
}

/// Tracks the per-core code footprint: an LRU over function identifiers
/// standing in for the branch predictor's history tables. Executing many
/// distinct functions on one core keeps the miss ratio high; core
/// specialization shrinks the set and the miss ratio drops (§4.2).
#[derive(Clone, Debug)]
pub struct FootprintTracker {
    lru: VecDeque<u64>,
    cap: usize,
    /// EWMA of the miss indicator, reported as pressure ∈ [0,1].
    pressure: f64,
}

impl FootprintTracker {
    pub fn new(cap: usize) -> Self {
        FootprintTracker { lru: VecDeque::with_capacity(cap), cap, pressure: 0.0 }
    }

    /// Record execution of `func`; returns true on a footprint miss.
    pub fn touch(&mut self, func: u64) -> bool {
        const ALPHA: f64 = 0.02;
        let hit = if let Some(pos) = self.lru.iter().position(|&f| f == func) {
            let f = self.lru.remove(pos).unwrap();
            self.lru.push_front(f);
            true
        } else {
            if self.lru.len() == self.cap {
                self.lru.pop_back();
            }
            self.lru.push_front(func);
            false
        };
        self.pressure = (1.0 - ALPHA) * self.pressure + ALPHA * if hit { 0.0 } else { 1.0 };
        !hit
    }

    /// Long-run footprint miss ratio estimate ∈ [0,1].
    pub fn pressure(&self) -> f64 {
        self.pressure
    }

    pub fn distinct(&self) -> usize {
        self.lru.len()
    }
}

/// Result of costing one block.
#[derive(Clone, Copy, Debug)]
pub struct BlockCost {
    /// Core cycles to retire the block (before frequency conversion).
    pub cycles: f64,
    /// Cycles lost to branch mispredictions (reported for §4.2).
    pub mispredict_cycles: f64,
    /// Cycles lost to memory stalls.
    pub mem_stall_cycles: f64,
    /// Expected number of mispredicted branches.
    pub mispredicts: f64,
}

/// Execution cycles of a block's instruction mix alone — the part of
/// [`cost_block`] that is independent of footprint pressure (and of
/// `mem_ops`/`branches`), hence memoizable per [`ClassMix`]. Kept as a
/// named helper so the cached and uncached paths run the *same float
/// operations in the same order* (determinism: byte-identical outputs
/// with memoization on or off).
#[inline]
pub fn exec_cycles(p: &IpcParams, mix: &ClassMix) -> f64 {
    let mut cycles = 0.0;
    for (i, &n) in mix.counts.iter().enumerate() {
        if n > 0 {
            cycles += n as f64 / p.base_ipc[i];
        }
    }
    cycles
}

/// Finish costing a block from a precomputed [`exec_cycles`] value.
/// `cost_block` ≡ `cost_block_with(p, b, fp, exec_cycles(p, &b.mix))`
/// bit for bit: the total is accumulated in the same association order
/// (`(exec + mem) + mispredict`) as the historical single function.
#[inline]
pub fn cost_block_with(
    p: &IpcParams,
    block: &Block,
    footprint_pressure: f64,
    exec_cycles: f64,
) -> BlockCost {
    let mem_stall_cycles = block.mem_ops as f64 * p.mem_stall_cpi;
    let miss_rate = p.mispredict_rate_hot + p.mispredict_rate_cold * footprint_pressure;
    let mispredicts = block.branches as f64 * miss_rate;
    let mispredict_cycles = mispredicts * p.mispredict_penalty;
    BlockCost {
        cycles: exec_cycles + mem_stall_cycles + mispredict_cycles,
        mispredict_cycles,
        mem_stall_cycles,
        mispredicts,
    }
}

/// Pure function: cycles for a block given footprint pressure.
pub fn cost_block(p: &IpcParams, block: &Block, footprint_pressure: f64) -> BlockCost {
    cost_block_with(p, block, footprint_pressure, exec_cycles(p, &block.mix))
}

/// Per-core memo for the pressure-independent part of block costing.
///
/// The web server's hot loops re-execute a tiny set of block shapes
/// (brotli 8 KiB chunks, ChaCha 4 KiB chunks, the Poly1305 MAC), so two
/// slots with move-to-front replacement capture the common
/// bulk-cipher ↔ MAC alternation. The cache key is the full [`ClassMix`]
/// (not the function id: the crypto builders draw per-burst
/// trigger-eligibility, so one symbol maps to many blocks, and distinct
/// symbols share mixes). Only [`exec_cycles`] is cached — it does not
/// depend on footprint pressure, so there is nothing to invalidate on a
/// footprint change, and the pressure-dependent tail of the cost is
/// recomputed exactly per call via [`cost_block_with`]. A hit therefore
/// returns the bit-identical value the uncached path would compute.
#[derive(Clone, Debug, Default)]
pub struct CostCache {
    slots: [Option<(ClassMix, f64)>; 2],
    pub hits: u64,
    pub misses: u64,
}

impl CostCache {
    /// Cached [`exec_cycles`] for `mix` under `p`. Callers must use one
    /// cache per (core, [`IpcParams`]) pair: the params are part of the
    /// function being memoized but not of the key.
    #[inline]
    pub fn exec_cycles(&mut self, p: &IpcParams, mix: &ClassMix) -> f64 {
        if let Some((k, v)) = self.slots[0] {
            if k == *mix {
                self.hits += 1;
                return v;
            }
        }
        if let Some((k, v)) = self.slots[1] {
            if k == *mix {
                self.hits += 1;
                self.slots.swap(0, 1);
                return v;
            }
        }
        let v = exec_cycles(p, mix);
        self.misses += 1;
        self.slots[1] = Some((*mix, v));
        self.slots.swap(0, 1);
        v
    }
}

/// License demand of a slice: Intel reduces frequency only when heavy
/// instructions are *dense* — roughly one per cycle sustained, or a
/// sufficiently dense mix of the two categories (SDM §15.26, Lemire [14]).
/// Density below `dense_threshold` leaves the license at L0.
#[inline]
pub fn license_demand(fp: &FreqParams, block: &Block, cycles: f64) -> License {
    if cycles <= 0.0 || block.license_exempt {
        return License::L0;
    }
    // Integer fast path: a block with no license-relevant instructions
    // (the common case — all scalar/kernel/brotli work, plus light AVX2,
    // which the SDM exempts) has every density zero, so the threshold
    // comparisons below land on L0 without the divisions.
    // (`dense_threshold > 0.0` guards the degenerate zero-threshold
    // configuration, where even zero density trips the comparisons.)
    if fp.dense_threshold > 0.0
        && block.mix.get(InsnClass::Avx512Heavy) == 0
        && block.mix.get(InsnClass::Avx2Heavy) == 0
        && block.mix.get(InsnClass::Avx512Light) == 0
    {
        return License::L0;
    }
    let d2 = block.mix.get(InsnClass::Avx512Heavy) as f64 / cycles;
    let d1 = (block.mix.get(InsnClass::Avx2Heavy) + block.mix.get(InsnClass::Avx512Light)) as f64
        / cycles;
    // A dense mix of level-1 and level-2 instructions also triggers L2
    // (SDM: "sufficiently dense mixture of instructions from two
    // different categories"), at half weight.
    if d2 >= fp.dense_threshold || (d2 > 0.0 && d2 + 0.5 * d1 >= fp.dense_threshold) {
        License::L2
    } else if d1 + d2 >= fp.dense_threshold {
        License::L1
    } else {
        License::L0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::block::ClassMix;

    fn scalar_block(n: u64) -> Block {
        Block::new(ClassMix::scalar(n))
    }

    #[test]
    fn scalar_block_costs_expected_cycles() {
        let p = IpcParams::default();
        let b = Block { mix: ClassMix::scalar(2200), mem_ops: 0, branches: 0, license_exempt: false };
        let c = cost_block(&p, &b, 0.0);
        assert!((c.cycles - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn footprint_pressure_raises_cost() {
        let p = IpcParams::default();
        let b = scalar_block(6000);
        let hot = cost_block(&p, &b, 0.0);
        let cold = cost_block(&p, &b, 1.0);
        assert!(cold.cycles > hot.cycles);
        assert!(cold.mispredicts > hot.mispredicts);
        // Effect size should be percent-scale, not 2x.
        assert!(cold.cycles / hot.cycles < 1.5);
    }

    #[test]
    fn lru_tracks_distinct_functions() {
        let mut t = FootprintTracker::new(4);
        for f in 0..4u64 {
            assert!(t.touch(f), "first touch is a miss");
        }
        assert!(!t.touch(3), "recent function is a hit");
        assert!(t.touch(99), "new function evicts");
        assert!(t.touch(0), "evicted function misses");
        assert_eq!(t.distinct(), 4);
    }

    #[test]
    fn pressure_converges_under_thrash_and_hit() {
        let mut t = FootprintTracker::new(2);
        for i in 0..5000u64 {
            t.touch(i % 16); // thrash
        }
        assert!(t.pressure() > 0.8, "thrash pressure {}", t.pressure());
        let mut t2 = FootprintTracker::new(8);
        for i in 0..5000u64 {
            t2.touch(i % 3); // fits
        }
        assert!(t2.pressure() < 0.05, "hit pressure {}", t2.pressure());
    }

    #[test]
    fn dense_avx512_demands_l2() {
        let fp = FreqParams::default();
        let b = Block { mix: ClassMix::of(InsnClass::Avx512Heavy, 1000), mem_ops: 0, branches: 0, license_exempt: false };
        assert_eq!(license_demand(&fp, &b, 1000.0), License::L2);
    }

    #[test]
    fn dense_avx2_heavy_demands_l1() {
        let fp = FreqParams::default();
        let b = Block { mix: ClassMix::of(InsnClass::Avx2Heavy, 1000), mem_ops: 0, branches: 0, license_exempt: false };
        assert_eq!(license_demand(&fp, &b, 1000.0), License::L1);
    }

    #[test]
    fn sparse_wide_ops_stay_l0() {
        // memcpy-style: a few wide moves inside lots of scalar code must not
        // drop the frequency (paper §3.3: memcpy should not trigger).
        let fp = FreqParams::default();
        let b = Block {
            mix: ClassMix::scalar(10_000).with(InsnClass::Avx512Light, 50),
            mem_ops: 0,
            branches: 0, license_exempt: false,
        };
        let cycles = 5000.0;
        assert_eq!(license_demand(&fp, &b, cycles), License::L0);
    }

    #[test]
    fn mixed_dense_categories_escalate() {
        let fp = FreqParams::default();
        // Not enough L2 density alone, but a dense mixed stream → L2.
        let b = Block {
            mix: ClassMix::of(InsnClass::Avx512Heavy, 600).with(InsnClass::Avx512Light, 900),
            mem_ops: 0,
            branches: 0, license_exempt: false,
        };
        let cycles = 1000.0;
        assert_eq!(license_demand(&fp, &b, cycles), License::L2);
    }

    #[test]
    fn idle_demands_l0() {
        let fp = FreqParams::default();
        let b = scalar_block(0);
        assert_eq!(license_demand(&fp, &b, 0.0), License::L0);
    }

    #[test]
    fn cost_cache_is_bit_identical_to_direct_costing() {
        let p = IpcParams::default();
        let mut cache = CostCache::default();
        let blocks = [
            Block { mix: ClassMix::scalar(4000), mem_ops: 100, branches: 600, license_exempt: false },
            Block { mix: ClassMix::of(InsnClass::Avx512Heavy, 900).with(InsnClass::Scalar, 120), mem_ops: 64, branches: 14, license_exempt: false },
            Block { mix: ClassMix::scalar(4000), mem_ops: 50, branches: 10, license_exempt: false },
        ];
        // Alternate the shapes (incl. same mix with different mem/branch
        // metadata) at varying pressures; every field must be bit-equal.
        let mut pressure = 0.0;
        for i in 0..200 {
            let b = &blocks[i % blocks.len()];
            let direct = cost_block(&p, b, pressure);
            let via = cost_block_with(&p, b, pressure, cache.exec_cycles(&p, &b.mix));
            assert_eq!(direct.cycles.to_bits(), via.cycles.to_bits());
            assert_eq!(direct.mispredicts.to_bits(), via.mispredicts.to_bits());
            assert_eq!(direct.mem_stall_cycles.to_bits(), via.mem_stall_cycles.to_bits());
            assert_eq!(direct.mispredict_cycles.to_bits(), via.mispredict_cycles.to_bits());
            pressure = (1.0 - 0.02) * pressure + 0.02 * ((i % 3) as f64 / 2.0);
        }
        assert!(cache.hits > cache.misses, "alternating shapes must mostly hit: {cache:?}");
    }

    #[test]
    fn cost_cache_two_slots_cover_alternation() {
        let p = IpcParams::default();
        let mut cache = CostCache::default();
        let a = ClassMix::scalar(1000);
        let b = ClassMix::of(InsnClass::Avx512Light, 500);
        cache.exec_cycles(&p, &a);
        cache.exec_cycles(&p, &b);
        let (h0, m0) = (cache.hits, cache.misses);
        for _ in 0..10 {
            cache.exec_cycles(&p, &a);
            cache.exec_cycles(&p, &b);
        }
        assert_eq!(cache.hits - h0, 20, "a↔b alternation must be all hits");
        assert_eq!(cache.misses, m0);
    }

    #[test]
    fn license_demand_zero_threshold_keeps_division_semantics() {
        // Degenerate threshold 0: even density-0 streams satisfy the
        // comparisons, so the integer fast path must not short-circuit.
        let mut fp = FreqParams::default();
        fp.dense_threshold = 0.0;
        let b = scalar_block(1000);
        // d2 = 0 ≥ 0 trips the first comparison exactly as it always did.
        assert_eq!(license_demand(&fp, &b, 1000.0), License::L2);
    }
}
