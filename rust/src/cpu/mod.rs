//! Skylake-SP core model: power-license frequency state machine, turbo
//! frequency tables, IPC model with code-footprint effects, and PMU
//! counters.
//!
//! This is the hardware substrate the paper's evaluation runs on (an Intel
//! Xeon Gold 6130). Every mechanism implemented here is taken from the
//! paper's §2 analysis and the documents it cites (Intel SDM §15.26, the
//! Xeon Scalable specification update):
//!
//! * three per-core frequency levels (licenses L0/L1/L2),
//! * license *demand* determined by the density of heavy AVX2 / AVX-512
//!   instructions per cycle,
//! * a throttled transition phase of up to 500 µs while the PCU grants a
//!   new license (Fig 1),
//! * ~2 ms hysteresis before reverting to a higher-frequency level,
//! * `CORE_POWER.LVL{0,1,2}_TURBO_LICENSE` / `CORE_POWER.THROTTLE` PMU
//!   counter semantics defined directly by this state machine,
//! * a per-core power model with exact per-slice energy integration
//!   ([`power`]) and pluggable DVFS governors ([`governor`]) deciding
//!   grant latency, voltage-ramp stalls, and the AVX-timer width.

pub mod turbo;
pub mod freq;
pub mod governor;
pub mod ipc;
pub mod perf;
pub mod power;
pub mod core;
pub mod topology;

pub use core::{Core, SliceOutcome};
pub use freq::{FreqParams, License, LicenseState};
pub use governor::{Governor, GovernorSpec};
pub use perf::PerfCounters;
pub use power::PowerParams;
pub use topology::{CoreClass, HybridSpec, Topology};
pub use turbo::TurboTable;
