//! Turbo frequency tables per power license.
//!
//! The evaluation machine is an Intel Xeon Gold 6130: 16 physical cores,
//! all-core turbo of 2.8 GHz (license 0, "non-AVX"), 2.4 GHz (license 1,
//! heavy AVX2 / light AVX-512) and 1.9 GHz (license 2, heavy AVX-512) —
//! the numbers in paper §2 and §4. Real parts also scale turbo with the
//! number of active cores; the table supports that axis because it matters
//! for the microbenchmark (paper §4.3 disables C-states precisely to
//! avoid single-core turbo inflating the baseline).

use super::freq::License;

/// GHz per (license, active-core-count) pair.
#[derive(Clone, Debug)]
pub struct TurboTable {
    pub name: String,
    /// `ghz[license][active_cores - 1]`.
    ghz: [Vec<f64>; 3],
}

impl TurboTable {
    /// Xeon Gold 6130 (Skylake-SP, 16C): max single-core turbo 3.7 GHz,
    /// stepping down to the documented all-core turbos 2.8 / 2.4 / 1.9 GHz.
    /// Steps follow the published frequency-bin table for the part.
    pub fn xeon_gold_6130() -> Self {
        let cores = 16;
        // (active-core breakpoints, GHz) per the specification update:
        // L0: 3.7 (1-2), 3.5 (3-4), 3.4 (5-8), 2.8 (9-16)
        // L1: 3.6 (1-2), 3.4 (3-4), 3.3 (5-8), 2.4 (9-16)
        // L2: 3.5 (1-2), 3.3 (3-4), 2.7 (5-8), 1.9 (9-16)
        fn expand(bins: &[(usize, f64)], cores: usize) -> Vec<f64> {
            let mut v = Vec::with_capacity(cores);
            for n in 1..=cores {
                let ghz = bins.iter().find(|(upto, _)| n <= *upto).map(|(_, g)| *g).unwrap();
                v.push(ghz);
            }
            v
        }
        TurboTable {
            name: "Xeon Gold 6130".to_string(),
            ghz: [
                expand(&[(2, 3.7), (4, 3.5), (8, 3.4), (16, 2.8)], cores),
                expand(&[(2, 3.6), (4, 3.4), (8, 3.3), (16, 2.4)], cores),
                expand(&[(2, 3.5), (4, 3.3), (8, 2.7), (16, 1.9)], cores),
            ],
        }
    }

    /// A flat table (no active-core scaling) — used by unit tests and by
    /// the microbenchmark scenario where C-states are disabled, pinning
    /// all-core turbo regardless of idle cores (paper §4.3).
    pub fn flat(l0: f64, l1: f64, l2: f64, cores: usize) -> Self {
        TurboTable {
            name: "flat".to_string(),
            ghz: [vec![l0; cores], vec![l1; cores], vec![l2; cores]],
        }
    }

    /// All-core-turbo-only variant of the 6130 used when C-states are off.
    pub fn xeon_gold_6130_no_cstates() -> Self {
        Self::flat(2.8, 2.4, 1.9, 16)
    }

    /// Turbo table of one E-core *module* (Gracemont-shaped): the
    /// active-core axis counts cores awake in the module, which shares a
    /// single clock/PLL. Lower peak than any P-core bin and a shallower
    /// active-core slope; the part has no 512-bit path, so the L2 row
    /// merely duplicates L1 to keep `ghz(L2, _)` defined (the license
    /// ceiling in [`super::freq::FreqParams::efficiency_core`] prevents
    /// L2 from ever being granted).
    pub fn e_core_module(module_size: usize) -> Self {
        let n = module_size.max(1);
        let slope = |one: f64, all: f64| -> Vec<f64> {
            (1..=n)
                .map(|active| if active <= 1 { one } else { all })
                .collect()
        };
        let l1 = slope(2.5, 2.3);
        TurboTable {
            name: format!("E-module x{n}"),
            ghz: [slope(3.1, 2.9), l1.clone(), l1],
        }
    }

    pub fn cores(&self) -> usize {
        self.ghz[0].len()
    }

    /// Frequency in GHz for a core holding `license` while `active` cores
    /// are awake package-wide.
    pub fn ghz(&self, license: License, active: usize) -> f64 {
        let idx = active.clamp(1, self.cores()) - 1;
        self.ghz[license.index()][idx]
    }

    /// Frequency in cycles per nanosecond (== GHz), convenience alias.
    pub fn cycles_per_ns(&self, license: License, active: usize) -> f64 {
        self.ghz(license, active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documented_all_core_turbos() {
        let t = TurboTable::xeon_gold_6130();
        assert_eq!(t.cores(), 16);
        assert_eq!(t.ghz(License::L0, 16), 2.8);
        assert_eq!(t.ghz(License::L1, 16), 2.4);
        assert_eq!(t.ghz(License::L2, 16), 1.9);
    }

    #[test]
    fn single_core_turbo_higher() {
        let t = TurboTable::xeon_gold_6130();
        assert!(t.ghz(License::L0, 1) > t.ghz(License::L0, 16));
        assert_eq!(t.ghz(License::L0, 1), 3.7);
    }

    #[test]
    fn license_monotone_at_any_active_count() {
        let t = TurboTable::xeon_gold_6130();
        for active in 1..=16 {
            assert!(t.ghz(License::L0, active) >= t.ghz(License::L1, active));
            assert!(t.ghz(License::L1, active) >= t.ghz(License::L2, active));
        }
    }

    #[test]
    fn active_clamped() {
        let t = TurboTable::xeon_gold_6130();
        assert_eq!(t.ghz(License::L0, 0), t.ghz(License::L0, 1));
        assert_eq!(t.ghz(License::L0, 99), t.ghz(License::L0, 16));
    }

    #[test]
    fn flat_table_ignores_active() {
        let t = TurboTable::xeon_gold_6130_no_cstates();
        assert_eq!(t.ghz(License::L0, 1), t.ghz(License::L0, 16));
    }

    #[test]
    fn e_core_module_table_is_slower_and_l2_safe() {
        let e = TurboTable::e_core_module(4);
        let p = TurboTable::xeon_gold_6130();
        assert_eq!(e.cores(), 4);
        // Slower than the P-core table at every license/occupancy.
        for active in 1..=4 {
            for lic in [License::L0, License::L1] {
                assert!(e.ghz(lic, active) < p.ghz(lic, active), "{lic:?}@{active}");
            }
        }
        // Single-core-in-module turbo exceeds the all-module clock.
        assert!(e.ghz(License::L0, 1) > e.ghz(License::L0, 4));
        // The L2 row stays defined (and equals L1 — no deeper level).
        for active in 1..=4 {
            assert_eq!(e.ghz(License::L2, active), e.ghz(License::L1, active));
        }
    }
}
