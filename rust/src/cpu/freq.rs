//! Per-core AVX power-license state machine (paper Fig 1, Intel SDM §15.26).
//!
//! A core holds a *granted* license (L0 = full turbo … L2 = heavy-AVX-512
//! turbo). The instruction stream produces a *demand* level per execution
//! slice. Transitions:
//!
//! * demand **above** granted → the core immediately enters a *throttled*
//!   phase (reduced IPC at the old frequency) while it requests a higher
//!   license from the package PCU; the grant arrives after up to 500 µs.
//! * demand **below** granted → the core keeps the low-frequency license
//!   for a ~2 ms *hold window* (hysteresis to bound the frequency-change
//!   rate); only if demand stays low for the whole window does the core
//!   revert, taking a short PLL stall.
//!
//! The `CORE_POWER.*` PMU events are defined by this machine: time spent
//! at each level increments `LVLn_TURBO_LICENSE`, time in the throttled
//! phase increments `THROTTLE`.
//!
//! The *policy* knobs of the machine — grant latency, the stall an
//! actual switch pays (voltage ramp), and the hold-window width — are
//! delegated to a pluggable [`Governor`](super::governor::Governor)
//! selected by [`FreqParams::governor`]. The default
//! ([`GovernorSpec::IntelLegacy`]) uses the base values verbatim, so the
//! machine behaves bit-for-bit as it did before governors existed.

use super::governor::{Governor, GovernorSpec};
use crate::sim::{Time, MS, US};

/// Power license levels. Ordering: `L0 < L1 < L2` in *severity* (L2 is the
/// slowest frequency).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum License {
    /// Full turbo — scalar/SSE and light AVX2.
    L0,
    /// Heavy AVX2 or light AVX-512.
    L1,
    /// Heavy (FP multiply / FMA) AVX-512.
    L2,
}

impl License {
    pub fn index(self) -> usize {
        match self {
            License::L0 => 0,
            License::L1 => 1,
            License::L2 => 2,
        }
    }

    pub fn from_index(i: usize) -> License {
        match i {
            0 => License::L0,
            1 => License::L1,
            2 => License::L2,
            _ => panic!("license index {i}"),
        }
    }

    pub fn name(self) -> &'static str {
        ["L0", "L1", "L2"][self.index()]
    }
}

/// Tunable hardware parameters, defaulting to the paper's numbers for the
/// Xeon Gold 6130 / Skylake-SP.
#[derive(Clone, Debug)]
pub struct FreqParams {
    /// Time from license request to PCU grant ("up to 500 µs", SDM §15.26).
    pub grant_latency: Time,
    /// IPC multiplier while the request is pending ("executes at reduced
    /// performance", Fig 1). Measured dispatch throttling is ~4×.
    pub throttle_ipc_factor: f64,
    /// Hysteresis before reverting to a faster license (~2 ms, SDM §15.26).
    pub hold: Time,
    /// PLL relock stall on an actual frequency switch (Mazouz et al. [16]).
    pub switch_stall: Time,
    /// Detection latency from first heavy instruction to request, expressed
    /// in instructions (~100, paper §3.3).
    pub detect_insns: u64,
    /// Density (insns/cycle) of heavy instructions that sustains a license
    /// demand — "approximately one instruction of the corresponding type
    /// executed per cycle" (paper §2, Lemire [14]). Dense vectorized loops
    /// exceed this; sporadic wide moves and stall-bound streams do not.
    pub dense_threshold: f64,
    /// DVFS governor policy applied on top of these base values. The
    /// default, [`GovernorSpec::IntelLegacy`], uses every base value
    /// verbatim — bit-for-bit the pre-governor behaviour.
    pub governor: GovernorSpec,
    /// Deepest license this core can demand. `L2` (the default) is the
    /// full P-core ladder; E-cores have no 512-bit path, so their
    /// ceiling is `L1` — heavier demand is clamped before it reaches the
    /// state machine (the hardware never issues the L2 request).
    pub max_license: License,
}

impl Default for FreqParams {
    fn default() -> Self {
        FreqParams {
            // SDM bounds the request phase at 500 µs; measured transition
            // latencies on Skylake-SP are tens of µs (Mazouz et al. [16],
            // Schöne et al.) — default to a typical grant, not the bound.
            grant_latency: 40 * US,
            throttle_ipc_factor: 0.35,
            hold: 2 * MS,
            switch_stall: 8 * US,
            detect_insns: 100,
            dense_threshold: 1.0,
            governor: GovernorSpec::IntelLegacy,
            max_license: License::L2,
        }
    }
}

impl FreqParams {
    /// E-core variant: same timing parameters, license ceiling L1 (no
    /// AVX-512 pipeline, so the L2 license does not exist on the part).
    pub fn efficiency_core(mut self) -> Self {
        self.max_license = License::L1;
        self
    }
}

/// Transition phase of the state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// Running at the granted license.
    Stable,
    /// Requested a lower-frequency license; throttled until `grant_at`.
    Throttled { target: License, grant_at: Time },
}

/// Per-core license state machine.
#[derive(Clone, Debug)]
pub struct LicenseState {
    params: FreqParams,
    /// Governor consulted for grant latency, switch stalls, and hold
    /// windows (built from `params.governor`; may carry its own state).
    gov: Box<dyn Governor>,
    granted: License,
    phase: Phase,
    /// Deadline at which the hold window expires (set while demand < granted).
    relax_at: Option<Time>,
    /// Highest demand observed during the current hold window.
    window_demand: License,
    /// Stall until this time after an actual frequency switch.
    stall_until: Time,
    /// Statistics.
    pub requests: u64,
    pub switches: u64,
}

/// What the core model needs to know to cost a slice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EffectiveState {
    /// License whose frequency the core currently runs at.
    pub license: License,
    /// IPC multiplier (1.0 normally, `throttle_ipc_factor` mid-transition).
    pub ipc_factor: f64,
    /// True if the core is in the throttled request phase (THROTTLE event).
    pub throttled: bool,
}

impl LicenseState {
    pub fn new(params: FreqParams) -> Self {
        let gov = params.governor.build();
        LicenseState {
            params,
            gov,
            granted: License::L0,
            phase: Phase::Stable,
            relax_at: None,
            window_demand: License::L0,
            stall_until: 0,
            requests: 0,
            switches: 0,
        }
    }

    pub fn params(&self) -> &FreqParams {
        &self.params
    }

    /// The governor this state machine runs under.
    pub fn governor(&self) -> GovernorSpec {
        self.gov.spec()
    }

    /// Currently granted license (the frequency the core runs at).
    pub fn granted(&self) -> License {
        self.granted
    }

    /// Is a down-transition (request) in flight?
    pub fn in_transition(&self) -> bool {
        matches!(self.phase, Phase::Throttled { .. })
    }

    /// PLL stall time remaining at `now`, to be added to the next slice.
    pub fn stall_ns(&self, now: Time) -> Time {
        self.stall_until.saturating_sub(now)
    }

    /// Advance the machine to `now` and report demand for the *next* slice.
    ///
    /// Returns the effective state to cost the slice with. Call order per
    /// slice: `observe(now, demand)` → run slice of duration `dt` → next
    /// call has `now' = now + dt`.
    pub fn observe(&mut self, now: Time, demand: License) -> EffectiveState {
        // 0. Clamp demand to the part's license ceiling (E-cores top out
        // at L1; a no-op at the default ceiling of L2).
        let demand = demand.min(self.params.max_license);

        // 1. Complete an in-flight grant whose latency has elapsed.
        if let Phase::Throttled { target, grant_at } = self.phase {
            if now >= grant_at {
                let from = self.granted;
                self.granted = target;
                self.phase = Phase::Stable;
                self.switches += 1;
                self.stall_until =
                    grant_at + self.gov.switch_stall(&self.params, grant_at, from, target);
                // A fresh grant starts a fresh observation window.
                self.relax_at = None;
                self.window_demand = License::L0;
            }
        }

        // 2. Demand above granted (or above in-flight target): request.
        let effective_target = match self.phase {
            Phase::Throttled { target, .. } => target.max(self.granted),
            Phase::Stable => self.granted,
        };
        if demand > effective_target {
            self.requests += 1;
            let grant_at = now + self.gov.grant_latency(&self.params);
            self.phase = Phase::Throttled { target: demand, grant_at };
            self.relax_at = None;
        }

        // 3. Demand below granted: run (or continue) the hold window.
        if demand < self.granted && matches!(self.phase, Phase::Stable) {
            match self.relax_at {
                None => {
                    let hold = self.gov.hold(&self.params, now);
                    self.relax_at = Some(now + hold);
                    self.window_demand = demand;
                }
                Some(deadline) => {
                    self.window_demand = self.window_demand.max(demand);
                    if now >= deadline {
                        // Hold expired: revert to the highest demand seen in
                        // the window (direct transition, per observed hardware
                        // behaviour — no intermediate-step requirement).
                        let to = self.window_demand.max(demand);
                        if to < self.granted {
                            let from = self.granted;
                            self.granted = to;
                            self.switches += 1;
                            self.stall_until =
                                now + self.gov.switch_stall(&self.params, now, from, to);
                        }
                        self.relax_at = None;
                        self.window_demand = License::L0;
                    }
                }
            }
        } else if demand >= self.granted {
            // Demand meets the license again: cancel any pending relax.
            self.relax_at = None;
            self.window_demand = License::L0;
        }

        match self.phase {
            Phase::Throttled { .. } => EffectiveState {
                license: self.granted,
                ipc_factor: self.params.throttle_ipc_factor,
                throttled: true,
            },
            Phase::Stable => {
                EffectiveState { license: self.granted, ipc_factor: 1.0, throttled: false }
            }
        }
    }

    /// Earliest future time at which this machine's state can change
    /// without new demand: the grant completion or the relax deadline.
    /// The core model uses this to bound slice lengths so transitions are
    /// observed promptly.
    pub fn next_edge(&self) -> Option<Time> {
        match self.phase {
            Phase::Throttled { grant_at, .. } => Some(grant_at),
            Phase::Stable => self.relax_at,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> LicenseState {
        LicenseState::new(FreqParams::default())
    }

    #[test]
    fn starts_at_l0_stable() {
        let mut m = machine();
        let s = m.observe(0, License::L0);
        assert_eq!(s.license, License::L0);
        assert!(!s.throttled);
        assert_eq!(s.ipc_factor, 1.0);
    }

    #[test]
    fn downclock_goes_through_throttle_phase() {
        let mut m = machine();
        let s = m.observe(0, License::L2);
        // Request issued; still at L0 frequency but throttled.
        assert_eq!(s.license, License::L0);
        assert!(s.throttled);
        assert!(s.ipc_factor < 1.0);
        assert_eq!(m.requests, 1);

        // Before the grant latency: still throttled.
        let grant = FreqParams::default().grant_latency;
        let s = m.observe(grant / 2, License::L2);
        assert!(s.throttled);

        // After the grant: L2, not throttled.
        let s = m.observe(grant + 160 * US, License::L2);
        assert_eq!(s.license, License::L2);
        assert!(!s.throttled);
        assert_eq!(m.switches, 1);
    }

    #[test]
    fn upclock_delayed_by_hold_window() {
        let mut m = machine();
        m.observe(0, License::L2);
        m.observe(200 * US, License::L2); // granted L2
        assert_eq!(m.granted(), License::L2);

        // Scalar demand: hold window starts; license unchanged for 2 ms.
        let t0 = 300 * US;
        let s = m.observe(t0, License::L0);
        assert_eq!(s.license, License::L2);
        let s = m.observe(t0 + MS, License::L0);
        assert_eq!(s.license, License::L2, "still within hold window");
        // Window expires 2 ms after it started.
        let s = m.observe(t0 + 2 * MS + 1, License::L0);
        assert_eq!(s.license, License::L0);
        assert!(!s.throttled);
    }

    #[test]
    fn avx_burst_inside_hold_window_cancels_relax() {
        let mut m = machine();
        m.observe(0, License::L2);
        m.observe(200 * US, License::L2);
        m.observe(300 * US, License::L0); // window opens
        m.observe(MS, License::L2); // burst: window cancelled
        let s = m.observe(3 * MS, License::L0); // would have expired, but was reset at 1ms
        assert_eq!(s.license, License::L2, "burst must restart hysteresis");
        let s = m.observe(3 * MS + 2 * MS + 1, License::L0);
        assert_eq!(s.license, License::L0);
    }

    #[test]
    fn window_reverts_to_highest_demand_seen() {
        let mut m = machine();
        m.observe(0, License::L2);
        m.observe(200 * US, License::L2);
        // Mixed L1/L0 demand during the window → revert lands on L1.
        m.observe(300 * US, License::L0);
        m.observe(MS, License::L1);
        let s = m.observe(300 * US + 2 * MS + 1, License::L1);
        assert_eq!(s.license, License::L1);
    }

    #[test]
    fn escalation_l1_to_l2_rerequests() {
        let mut m = machine();
        m.observe(0, License::L1);
        m.observe(200 * US, License::L1);
        assert_eq!(m.granted(), License::L1);
        let s = m.observe(250 * US, License::L2);
        assert!(s.throttled);
        assert_eq!(m.requests, 2);
        let s = m.observe(500 * US, License::L2);
        assert_eq!(s.license, License::L2);
    }

    #[test]
    fn stall_after_switch() {
        let mut m = machine();
        m.observe(0, License::L2);
        let grant = FreqParams::default().grant_latency;
        m.observe(grant, License::L2);
        assert!(m.stall_ns(grant) > 0, "PLL stall right after a switch");
        assert_eq!(m.stall_ns(300 * US), 0);
    }

    #[test]
    fn governors_are_selectable_per_state_machine() {
        let mut p = FreqParams::default();
        p.governor = GovernorSpec::SlowRamp;
        let mut slow = LicenseState::new(p);
        assert_eq!(slow.governor(), GovernorSpec::SlowRamp);
        let mut legacy = machine();
        assert_eq!(legacy.governor(), GovernorSpec::IntelLegacy);
        let grant = FreqParams::default().grant_latency;
        for m in [&mut slow, &mut legacy] {
            m.observe(0, License::L2);
            m.observe(grant, License::L2); // grant completes: switch + stall
        }
        assert!(
            slow.stall_ns(grant) > legacy.stall_ns(grant),
            "slow-ramp must pay a voltage-ramp stall on top of the PLL relock: {} vs {}",
            slow.stall_ns(grant),
            legacy.stall_ns(grant)
        );
    }

    #[test]
    fn license_ceiling_clamps_demand() {
        let mut m = LicenseState::new(FreqParams::default().efficiency_core());
        let grant = FreqParams::default().grant_latency;
        // L2 demand on an E-core behaves exactly like L1 demand: the L2
        // request is never issued.
        let s = m.observe(0, License::L2);
        assert!(s.throttled, "the (clamped) L1 request still throttles");
        let s = m.observe(grant + US, License::L2);
        assert_eq!(s.license, License::L1, "granted license tops out at L1");
        assert!(!s.throttled);
        // And pure L1 demand is untouched.
        let mut p = LicenseState::new(FreqParams::default().efficiency_core());
        p.observe(0, License::L1);
        let s = p.observe(grant + US, License::L1);
        assert_eq!(s.license, License::L1);
    }

    #[test]
    fn next_edge_reports_grant_then_relax() {
        let mut m = machine();
        m.observe(0, License::L2);
        assert_eq!(m.next_edge(), Some(FreqParams::default().grant_latency));
        m.observe(200 * US, License::L2);
        assert_eq!(m.next_edge(), None);
        m.observe(300 * US, License::L0);
        assert_eq!(m.next_edge(), Some(300 * US + 2 * MS));
    }
}
