//! Machine topology: sockets (NUMA nodes / frequency domains), physical
//! cores, SMT threads, and the partition into server cores and
//! load-generator cores used by the paper's evaluation (12 of 16
//! physical cores run nginx, 4 run wrk2).
//!
//! The paper evaluates a single-socket Skylake-SP, but the follow-up
//! work (Dim Silicon, Schuchart et al.) shows frequency variation is a
//! *scale* problem, so the model supports multi-socket machines:
//!
//! * each socket is its own **frequency domain** — the turbo table's
//!   active-core axis counts only cores awake on the same socket;
//! * each socket is a **NUMA node** — the scheduler prefers same-node
//!   work stealing and charges extra for cross-socket migrations.
//!
//! Hybrid parts add a second axis the paper predates: a [`CoreClass`]
//! split into P-cores (AVX-512 capable, deep license levels) and E-cores
//! grouped into *modules* ([`HybridSpec`]) that share one clock/PLL — a
//! frequency domain nested inside the socket domain, with no 512-bit
//! path and a license ceiling of L1. The machine layer maps each E-core
//! module to its own frequency domain; [`HybridSpec::module_of`] is the
//! shared map.
//!
//! Core ids are global and contiguous; socket membership is a balanced
//! contiguous partition computed by [`socket_of_core`] / [`socket_span`]
//! so every layer (machine, scheduler, policy) derives the same map from
//! `(n_cores, sockets)` alone.

/// Topology description for a simulated machine.
///
/// Constructors validate at build time ([`Topology::validate`]): zero
/// cores/sockets, out-of-range core ids, and server/client overlap are
/// rejected once here instead of being `.max(1)`-clamped at every
/// consumer.
///
/// # Examples
///
/// Build the 2-socket evaluation machine and query the NUMA layout:
///
/// ```
/// use avxfreq::cpu::Topology;
///
/// let t = Topology::dual_socket_webserver();
/// assert_eq!(t.sockets, 2);
/// assert_eq!(t.n_server_cores(), 24);
/// assert_eq!(t.socket_of(0), 0);
/// assert_eq!(t.socket_of(23), 1);
/// assert!(t.same_socket(0, 11));
/// assert!(!t.same_socket(11, 12));
/// ```
#[derive(Clone, Debug)]
pub struct Topology {
    pub physical_cores: usize,
    pub smt: usize,
    /// Number of sockets (NUMA nodes / package frequency domains). The
    /// server cores are split over the sockets in contiguous balanced
    /// chunks; 1 = the paper's single-socket machine.
    pub sockets: usize,
    /// Physical cores available to the workload under test.
    pub server_cores: Vec<usize>,
    /// Cores reserved for the load generator (modeled implicitly — the
    /// client process does not consume simulated server CPU).
    pub client_cores: Vec<usize>,
}

/// Core class of a hybrid part: P-cores carry the full AVX-512 pipeline
/// and license ladder; E-cores have no 512-bit path (license ceiling L1)
/// and share a module-level clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreClass {
    Performance,
    Efficiency,
}

impl CoreClass {
    pub fn name(self) -> &'static str {
        match self {
            CoreClass::Performance => "P",
            CoreClass::Efficiency => "E",
        }
    }
}

/// Hybrid core-class layout: the first `p_cores` global core ids are
/// P-cores, followed by `e_cores` E-cores grouped into modules of
/// `module_size` (e.g. Alder-Lake-style 4-core Gracemont modules). Each
/// module is one shared frequency domain nested inside the socket
/// domain.
///
/// `e_cores == 0` describes an all-P part, which the machine layer
/// treats as exactly the homogeneous machine (pinned byte-for-byte by
/// `rust/tests/hybrid.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HybridSpec {
    pub p_cores: usize,
    pub e_cores: usize,
    /// E-cores per module (ignored when `e_cores == 0`).
    pub module_size: usize,
}

impl HybridSpec {
    /// Validated constructor: at least one core, and the E-cores must
    /// fill whole modules (partial modules have no hardware analogue and
    /// would make the module→domain map ambiguous).
    pub fn new(p_cores: usize, e_cores: usize, module_size: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(p_cores + e_cores > 0, "hybrid topology needs at least one core");
        if e_cores > 0 {
            anyhow::ensure!(module_size > 0, "module_size must be >= 1 when e_cores > 0");
            anyhow::ensure!(
                e_cores % module_size == 0,
                "e_cores ({e_cores}) must fill whole modules of {module_size}"
            );
        }
        Ok(HybridSpec { p_cores, e_cores, module_size })
    }

    /// A realistic desktop hybrid part: 8 P-cores plus 16 E-cores in
    /// four 4-core modules (Alder/Raptor-Lake shape).
    pub fn desktop_8p16e() -> Self {
        HybridSpec::new(8, 16, 4).expect("builder spec is valid")
    }

    pub fn n_cores(&self) -> usize {
        self.p_cores + self.e_cores
    }

    pub fn has_e_cores(&self) -> bool {
        self.e_cores > 0
    }

    /// Class of global core id `core` (P-cores first, then E-cores).
    pub fn class_of(&self, core: usize) -> CoreClass {
        if core < self.p_cores {
            CoreClass::Performance
        } else {
            CoreClass::Efficiency
        }
    }

    /// E-core module index of `core`, `None` for P-cores.
    pub fn module_of(&self, core: usize) -> Option<usize> {
        if core < self.p_cores || self.e_cores == 0 {
            None
        } else {
            Some((core - self.p_cores) / self.module_size.max(1))
        }
    }

    pub fn n_modules(&self) -> usize {
        if self.e_cores == 0 {
            0
        } else {
            self.e_cores / self.module_size.max(1)
        }
    }

    /// Per-core AVX-512 capability mask (true = P-core), the scheduler's
    /// hard placement constraint for 512-bit work.
    pub fn capability_mask(&self) -> Vec<bool> {
        (0..self.n_cores()).map(|c| self.class_of(c) == CoreClass::Performance).collect()
    }

    /// Table/config label, e.g. `8P+16E`.
    pub fn label(&self) -> String {
        format!("{}P+{}E", self.p_cores, self.e_cores)
    }
}

/// Socket owning global core `core` when `n_cores` are split over
/// `sockets` contiguous balanced chunks (first `n_cores % sockets`
/// sockets take one extra core).
///
/// Closed-form inverse of the partition [`socket_span`] lays out — O(1)
/// on the scheduler's steal/wake hot path (it used to scan the spans
/// linearly). The first `rem` sockets hold `base + 1` cores, so cores
/// below `rem * (base + 1)` divide by the long-span length and the rest
/// divide by `base` after removing the long prefix.
///
/// # Examples
///
/// ```
/// use avxfreq::cpu::topology::socket_of_core;
///
/// // 12 cores over 2 sockets: 0..6 on socket 0, 6..12 on socket 1.
/// assert_eq!(socket_of_core(5, 12, 2), 0);
/// assert_eq!(socket_of_core(6, 12, 2), 1);
/// // Uneven split: 7 cores over 2 sockets → 4 + 3.
/// assert_eq!(socket_of_core(3, 7, 2), 0);
/// assert_eq!(socket_of_core(4, 7, 2), 1);
/// ```
pub fn socket_of_core(core: usize, n_cores: usize, sockets: usize) -> usize {
    let s = sockets.max(1).min(n_cores.max(1));
    if n_cores == 0 {
        // Degenerate call: the historical scan fell through to the last
        // socket; keep that contract.
        return s - 1;
    }
    // Out-of-range cores land on the last socket (historical contract).
    let core = core.min(n_cores - 1);
    let base = n_cores / s; // >= 1 because s <= n_cores
    let rem = n_cores % s;
    let cut = rem * (base + 1);
    if core < cut {
        core / (base + 1)
    } else {
        rem + (core - cut) / base
    }
}

/// Half-open global-core range `[start, end)` of `socket` under the same
/// balanced contiguous partition as [`socket_of_core`].
pub fn socket_span(socket: usize, n_cores: usize, sockets: usize) -> (usize, usize) {
    let s = sockets.max(1).min(n_cores.max(1));
    let base = n_cores / s;
    let rem = n_cores % s;
    let start = socket * base + socket.min(rem);
    let len = base + usize::from(socket < rem);
    (start, start + len)
}

/// Per-core socket ids for an `(n_cores, sockets)` machine — the map the
/// machine and scheduler share.
pub fn socket_map(n_cores: usize, sockets: usize) -> Vec<usize> {
    (0..n_cores).map(|c| socket_of_core(c, n_cores, sockets)).collect()
}

impl Topology {
    /// The paper's evaluation machine: Xeon Gold 6130, 16 physical cores,
    /// web server on cores 0..12, client on 12..16.
    pub fn paper_webserver() -> Self {
        Topology {
            physical_cores: 16,
            smt: 2,
            sockets: 1,
            server_cores: (0..12).collect(),
            client_cores: (12..16).collect(),
        }
        .checked()
        .expect("builder topology is valid")
    }

    /// Microbenchmark topology (§4.3): 26 threads placed on 12 physical
    /// cores / 24 hardware threads; 4 cores idle, C-states disabled.
    pub fn paper_microbench() -> Self {
        Topology {
            physical_cores: 16,
            smt: 2,
            sockets: 1,
            server_cores: (0..12).collect(),
            client_cores: vec![],
        }
        .checked()
        .expect("builder topology is valid")
    }

    /// A dual-socket server built from two of the paper's machines:
    /// 2 × 16 physical cores, 12 server cores per socket (24 total),
    /// load generator on the last 4 cores of each socket (modeled
    /// implicitly, like the single-socket evaluation).
    pub fn dual_socket_webserver() -> Self {
        Topology {
            physical_cores: 32,
            smt: 2,
            sockets: 2,
            server_cores: (0..24).collect(),
            client_cores: (24..32).collect(),
        }
        .checked()
        .expect("builder topology is valid")
    }

    /// A uniform multi-socket machine: `sockets` × `cores_per_socket`
    /// physical cores, all available to the workload. Panics on a
    /// degenerate shape (zero sockets or cores) — validation happens
    /// once at construction instead of `.max(1)` clamps downstream.
    ///
    /// # Examples
    ///
    /// ```
    /// use avxfreq::cpu::Topology;
    ///
    /// let t = Topology::multi_socket(4, 8);
    /// assert_eq!(t.n_server_cores(), 32);
    /// assert_eq!(t.socket_of(31), 3);
    /// ```
    pub fn multi_socket(sockets: usize, cores_per_socket: usize) -> Self {
        let n = sockets * cores_per_socket;
        Topology {
            physical_cores: n,
            smt: 1,
            sockets,
            server_cores: (0..n).collect(),
            client_cores: vec![],
        }
        .checked()
        .expect("multi_socket needs sockets >= 1 and cores_per_socket >= 1")
    }

    /// A uniform machine with `cores` total server cores over `sockets`
    /// balanced (not necessarily equal) chunks — the general form
    /// [`Topology::multi_socket`] is the divisible special case of.
    pub fn uniform(cores: usize, sockets: usize) -> Self {
        Topology {
            physical_cores: cores,
            smt: 1,
            sockets,
            server_cores: (0..cores).collect(),
            client_cores: vec![],
        }
        .checked()
        .expect("uniform needs cores >= sockets >= 1")
    }

    /// Small single-socket topology for tests.
    pub fn small(cores: usize) -> Self {
        Topology {
            physical_cores: cores,
            smt: 1,
            sockets: 1,
            server_cores: (0..cores).collect(),
            client_cores: vec![],
        }
        .checked()
        .expect("small needs cores >= 1")
    }

    /// Structural validation, run once at construction: every consumer
    /// may then rely on `physical_cores >= 1`, `1 <= sockets <=
    /// physical_cores`, `smt >= 1`, a non-empty in-range server set, and
    /// disjoint server/client sets.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.sockets > 0, "topology needs at least one socket");
        anyhow::ensure!(self.physical_cores > 0, "topology needs at least one physical core");
        anyhow::ensure!(self.smt > 0, "smt must be >= 1");
        anyhow::ensure!(
            self.sockets <= self.physical_cores,
            "{} sockets cannot partition {} cores",
            self.sockets,
            self.physical_cores
        );
        anyhow::ensure!(!self.server_cores.is_empty(), "server core set is empty");
        let mut seen = vec![0u8; self.physical_cores];
        for &c in &self.server_cores {
            anyhow::ensure!(
                c < self.physical_cores,
                "server core {c} out of range (physical_cores = {})",
                self.physical_cores
            );
            anyhow::ensure!(seen[c] == 0, "server core {c} listed twice");
            seen[c] = 1;
        }
        for &c in &self.client_cores {
            anyhow::ensure!(
                c < self.physical_cores,
                "client core {c} out of range (physical_cores = {})",
                self.physical_cores
            );
            anyhow::ensure!(seen[c] != 1, "core {c} is both a server and a client core");
            anyhow::ensure!(seen[c] != 2, "client core {c} listed twice");
            seen[c] = 2;
        }
        Ok(())
    }

    /// [`Topology::validate`] in builder position.
    pub fn checked(self) -> anyhow::Result<Self> {
        self.validate()?;
        Ok(self)
    }

    pub fn n_server_cores(&self) -> usize {
        self.server_cores.len()
    }

    /// Number of sockets (NUMA nodes).
    pub fn n_sockets(&self) -> usize {
        self.sockets.max(1)
    }

    /// Socket owning *server core index* `core` (0-based index into the
    /// server-core list, the id space the simulated machine uses).
    pub fn socket_of(&self, core: usize) -> usize {
        socket_of_core(core, self.n_server_cores(), self.n_sockets())
    }

    /// Do two server cores share a socket (and thus a NUMA node and a
    /// frequency domain)?
    pub fn same_socket(&self, a: usize, b: usize) -> bool {
        self.socket_of(a) == self.socket_of(b)
    }

    /// Hardware threads available to the workload (MuQSS run queues are
    /// per *physical core* in the paper's configuration, so scheduling
    /// operates on physical cores; SMT contributes capacity via the IPC
    /// model instead).
    pub fn server_hw_threads(&self) -> usize {
        self.server_cores.len() * self.smt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topology_matches_evaluation_setup() {
        let t = Topology::paper_webserver();
        assert_eq!(t.physical_cores, 16);
        assert_eq!(t.n_server_cores(), 12);
        assert_eq!(t.client_cores.len(), 4);
        assert_eq!(t.server_hw_threads(), 24);
        assert_eq!(t.n_sockets(), 1);
        assert!(t.same_socket(0, 11));
    }

    #[test]
    fn small_topology() {
        let t = Topology::small(4);
        assert_eq!(t.n_server_cores(), 4);
        assert!(t.client_cores.is_empty());
        assert_eq!(t.n_sockets(), 1);
    }

    #[test]
    fn dual_socket_layout() {
        let t = Topology::dual_socket_webserver();
        assert_eq!(t.n_sockets(), 2);
        assert_eq!(t.n_server_cores(), 24);
        for c in 0..12 {
            assert_eq!(t.socket_of(c), 0, "core {c}");
        }
        for c in 12..24 {
            assert_eq!(t.socket_of(c), 1, "core {c}");
        }
    }

    #[test]
    fn socket_spans_partition_all_cores() {
        for (n, s) in [
            (12, 1),
            (12, 2),
            (7, 2),
            (24, 3),
            (5, 8),
            (16, 4),
            (1, 1),
            (2, 8),
            (31, 5),
            (64, 7),
            (97, 10),
            (3, 3),
            (128, 9),
        ] {
            let mut seen = vec![false; n];
            let eff = s.min(n).max(1);
            for socket in 0..eff {
                let (start, end) = socket_span(socket, n, s);
                assert!(start <= end && end <= n, "({n},{s}) socket {socket}");
                for c in start..end {
                    assert!(!seen[c], "core {c} in two sockets");
                    seen[c] = true;
                    assert_eq!(socket_of_core(c, n, s), socket);
                }
            }
            assert!(seen.iter().all(|&x| x), "({n},{s}) left cores unassigned");
        }
    }

    #[test]
    fn closed_form_matches_linear_scan_reference() {
        // The historical implementation, kept as the oracle.
        fn reference(core: usize, n_cores: usize, sockets: usize) -> usize {
            let s = sockets.max(1).min(n_cores.max(1));
            for socket in 0..s {
                let (start, end) = socket_span(socket, n_cores, s);
                if core >= start && core < end {
                    return socket;
                }
            }
            s - 1
        }
        for n in 0..=64 {
            for s in 1..=10 {
                // Includes out-of-range cores (>= n): both forms must
                // fall through to the last socket.
                for core in 0..=(n + 2) {
                    assert_eq!(
                        socket_of_core(core, n, s),
                        reference(core, n, s),
                        "core {core} of ({n},{s})"
                    );
                }
            }
        }
    }

    #[test]
    fn socket_map_matches_pointwise() {
        let map = socket_map(12, 3);
        assert_eq!(map.len(), 12);
        assert_eq!(map[0], 0);
        assert_eq!(map[4], 1);
        assert_eq!(map[11], 2);
    }

    #[test]
    fn more_sockets_than_cores_clamps() {
        // 2 cores, 8 sockets: clamps to one core per socket.
        assert_eq!(socket_of_core(0, 2, 8), 0);
        assert_eq!(socket_of_core(1, 2, 8), 1);
        let map = socket_map(2, 8);
        assert_eq!(map, vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "at least one socket")]
    fn multi_socket_rejects_zero_sockets() {
        let _ = Topology::multi_socket(0, 8);
    }

    #[test]
    #[should_panic(expected = "at least one physical core")]
    fn multi_socket_rejects_zero_cores_per_socket() {
        let _ = Topology::multi_socket(2, 0);
    }

    #[test]
    fn validate_rejects_each_degenerate_shape() {
        let good = Topology::small(4);
        assert!(good.validate().is_ok());

        let mut t = Topology::small(4);
        t.physical_cores = 0;
        t.server_cores = vec![];
        assert!(t.validate().unwrap_err().to_string().contains("physical core"));

        let mut t = Topology::small(4);
        t.smt = 0;
        assert!(t.validate().unwrap_err().to_string().contains("smt"));

        let mut t = Topology::small(4);
        t.sockets = 0;
        assert!(t.validate().unwrap_err().to_string().contains("socket"));

        let mut t = Topology::small(4);
        t.sockets = 5;
        assert!(t.validate().unwrap_err().to_string().contains("cannot partition"));

        let mut t = Topology::small(4);
        t.server_cores = vec![];
        assert!(t.validate().unwrap_err().to_string().contains("empty"));

        let mut t = Topology::small(4);
        t.server_cores = vec![0, 1, 4];
        assert!(t.validate().unwrap_err().to_string().contains("out of range"));

        let mut t = Topology::small(4);
        t.client_cores = vec![9];
        assert!(t.validate().unwrap_err().to_string().contains("out of range"));

        let mut t = Topology::small(4);
        t.client_cores = vec![1];
        assert!(t
            .validate()
            .unwrap_err()
            .to_string()
            .contains("both a server and a client"));

        let mut t = Topology::small(4);
        t.server_cores = vec![0, 1, 1];
        assert!(t.validate().unwrap_err().to_string().contains("listed twice"));
    }

    #[test]
    fn uniform_builds_balanced_unequal_chunks() {
        let t = Topology::uniform(7, 2);
        assert_eq!(t.n_server_cores(), 7);
        assert_eq!(t.socket_of(3), 0);
        assert_eq!(t.socket_of(4), 1);
    }

    #[test]
    fn hybrid_classes_and_modules() {
        let h = HybridSpec::desktop_8p16e();
        assert_eq!(h.n_cores(), 24);
        assert_eq!(h.n_modules(), 4);
        assert_eq!(h.label(), "8P+16E");
        for c in 0..8 {
            assert_eq!(h.class_of(c), CoreClass::Performance, "core {c}");
            assert_eq!(h.module_of(c), None, "core {c}");
        }
        assert_eq!(h.module_of(8), Some(0));
        assert_eq!(h.module_of(11), Some(0));
        assert_eq!(h.module_of(12), Some(1));
        assert_eq!(h.module_of(23), Some(3));
        let mask = h.capability_mask();
        assert_eq!(mask.iter().filter(|&&b| b).count(), 8);
        assert!(mask[7] && !mask[8]);
    }

    #[test]
    fn hybrid_rejects_partial_modules_and_empty_parts() {
        assert!(HybridSpec::new(8, 10, 4).is_err(), "partial module");
        assert!(HybridSpec::new(0, 0, 4).is_err(), "no cores");
        assert!(HybridSpec::new(8, 4, 0).is_err(), "zero module size");
        let all_p = HybridSpec::new(6, 0, 4).unwrap();
        assert!(!all_p.has_e_cores());
        assert_eq!(all_p.n_modules(), 0);
        assert_eq!(all_p.module_of(3), None);
        assert_eq!(all_p.class_of(5), CoreClass::Performance);
    }
}
