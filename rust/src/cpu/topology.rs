//! Machine topology: physical cores, SMT threads, and the partition into
//! server cores and load-generator cores used by the paper's evaluation
//! (12 of 16 physical cores run nginx, 4 run wrk2).

/// Topology description for a simulated machine.
#[derive(Clone, Debug)]
pub struct Topology {
    pub physical_cores: usize,
    pub smt: usize,
    /// Physical cores available to the workload under test.
    pub server_cores: Vec<usize>,
    /// Cores reserved for the load generator (modeled implicitly — the
    /// client process does not consume simulated server CPU).
    pub client_cores: Vec<usize>,
}

impl Topology {
    /// The paper's evaluation machine: Xeon Gold 6130, 16 physical cores,
    /// web server on cores 0..12, client on 12..16.
    pub fn paper_webserver() -> Self {
        Topology {
            physical_cores: 16,
            smt: 2,
            server_cores: (0..12).collect(),
            client_cores: (12..16).collect(),
        }
    }

    /// Microbenchmark topology (§4.3): 26 threads placed on 12 physical
    /// cores / 24 hardware threads; 4 cores idle, C-states disabled.
    pub fn paper_microbench() -> Self {
        Topology {
            physical_cores: 16,
            smt: 2,
            server_cores: (0..12).collect(),
            client_cores: vec![],
        }
    }

    /// Small topology for tests.
    pub fn small(cores: usize) -> Self {
        Topology {
            physical_cores: cores,
            smt: 1,
            server_cores: (0..cores).collect(),
            client_cores: vec![],
        }
    }

    pub fn n_server_cores(&self) -> usize {
        self.server_cores.len()
    }

    /// Hardware threads available to the workload (MuQSS run queues are
    /// per *physical core* in the paper's configuration, so scheduling
    /// operates on physical cores; SMT contributes capacity via the IPC
    /// model instead).
    pub fn server_hw_threads(&self) -> usize {
        self.server_cores.len() * self.smt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topology_matches_evaluation_setup() {
        let t = Topology::paper_webserver();
        assert_eq!(t.physical_cores, 16);
        assert_eq!(t.n_server_cores(), 12);
        assert_eq!(t.client_cores.len(), 4);
        assert_eq!(t.server_hw_threads(), 24);
    }

    #[test]
    fn small_topology() {
        let t = Topology::small(4);
        assert_eq!(t.n_server_cores(), 4);
        assert!(t.client_cores.is_empty());
    }
}
