//! Machine topology: sockets (NUMA nodes / frequency domains), physical
//! cores, SMT threads, and the partition into server cores and
//! load-generator cores used by the paper's evaluation (12 of 16
//! physical cores run nginx, 4 run wrk2).
//!
//! The paper evaluates a single-socket Skylake-SP, but the follow-up
//! work (Dim Silicon, Schuchart et al.) shows frequency variation is a
//! *scale* problem, so the model supports multi-socket machines:
//!
//! * each socket is its own **frequency domain** — the turbo table's
//!   active-core axis counts only cores awake on the same socket;
//! * each socket is a **NUMA node** — the scheduler prefers same-node
//!   work stealing and charges extra for cross-socket migrations.
//!
//! Core ids are global and contiguous; socket membership is a balanced
//! contiguous partition computed by [`socket_of_core`] / [`socket_span`]
//! so every layer (machine, scheduler, policy) derives the same map from
//! `(n_cores, sockets)` alone.

/// Topology description for a simulated machine.
///
/// # Examples
///
/// Build the 2-socket evaluation machine and query the NUMA layout:
///
/// ```
/// use avxfreq::cpu::Topology;
///
/// let t = Topology::dual_socket_webserver();
/// assert_eq!(t.sockets, 2);
/// assert_eq!(t.n_server_cores(), 24);
/// assert_eq!(t.socket_of(0), 0);
/// assert_eq!(t.socket_of(23), 1);
/// assert!(t.same_socket(0, 11));
/// assert!(!t.same_socket(11, 12));
/// ```
#[derive(Clone, Debug)]
pub struct Topology {
    pub physical_cores: usize,
    pub smt: usize,
    /// Number of sockets (NUMA nodes / package frequency domains). The
    /// server cores are split over the sockets in contiguous balanced
    /// chunks; 1 = the paper's single-socket machine.
    pub sockets: usize,
    /// Physical cores available to the workload under test.
    pub server_cores: Vec<usize>,
    /// Cores reserved for the load generator (modeled implicitly — the
    /// client process does not consume simulated server CPU).
    pub client_cores: Vec<usize>,
}

/// Socket owning global core `core` when `n_cores` are split over
/// `sockets` contiguous balanced chunks (first `n_cores % sockets`
/// sockets take one extra core).
///
/// # Examples
///
/// ```
/// use avxfreq::cpu::topology::socket_of_core;
///
/// // 12 cores over 2 sockets: 0..6 on socket 0, 6..12 on socket 1.
/// assert_eq!(socket_of_core(5, 12, 2), 0);
/// assert_eq!(socket_of_core(6, 12, 2), 1);
/// // Uneven split: 7 cores over 2 sockets → 4 + 3.
/// assert_eq!(socket_of_core(3, 7, 2), 0);
/// assert_eq!(socket_of_core(4, 7, 2), 1);
/// ```
pub fn socket_of_core(core: usize, n_cores: usize, sockets: usize) -> usize {
    let s = sockets.max(1).min(n_cores.max(1));
    for socket in 0..s {
        let (start, end) = socket_span(socket, n_cores, s);
        if core >= start && core < end {
            return socket;
        }
    }
    s - 1
}

/// Half-open global-core range `[start, end)` of `socket` under the same
/// balanced contiguous partition as [`socket_of_core`].
pub fn socket_span(socket: usize, n_cores: usize, sockets: usize) -> (usize, usize) {
    let s = sockets.max(1).min(n_cores.max(1));
    let base = n_cores / s;
    let rem = n_cores % s;
    let start = socket * base + socket.min(rem);
    let len = base + usize::from(socket < rem);
    (start, start + len)
}

/// Per-core socket ids for an `(n_cores, sockets)` machine — the map the
/// machine and scheduler share.
pub fn socket_map(n_cores: usize, sockets: usize) -> Vec<usize> {
    (0..n_cores).map(|c| socket_of_core(c, n_cores, sockets)).collect()
}

impl Topology {
    /// The paper's evaluation machine: Xeon Gold 6130, 16 physical cores,
    /// web server on cores 0..12, client on 12..16.
    pub fn paper_webserver() -> Self {
        Topology {
            physical_cores: 16,
            smt: 2,
            sockets: 1,
            server_cores: (0..12).collect(),
            client_cores: (12..16).collect(),
        }
    }

    /// Microbenchmark topology (§4.3): 26 threads placed on 12 physical
    /// cores / 24 hardware threads; 4 cores idle, C-states disabled.
    pub fn paper_microbench() -> Self {
        Topology {
            physical_cores: 16,
            smt: 2,
            sockets: 1,
            server_cores: (0..12).collect(),
            client_cores: vec![],
        }
    }

    /// A dual-socket server built from two of the paper's machines:
    /// 2 × 16 physical cores, 12 server cores per socket (24 total),
    /// load generator on the last 4 cores of each socket (modeled
    /// implicitly, like the single-socket evaluation).
    pub fn dual_socket_webserver() -> Self {
        Topology {
            physical_cores: 32,
            smt: 2,
            sockets: 2,
            server_cores: (0..24).collect(),
            client_cores: (24..32).collect(),
        }
    }

    /// A uniform multi-socket machine: `sockets` × `cores_per_socket`
    /// physical cores, all available to the workload.
    ///
    /// # Examples
    ///
    /// ```
    /// use avxfreq::cpu::Topology;
    ///
    /// let t = Topology::multi_socket(4, 8);
    /// assert_eq!(t.n_server_cores(), 32);
    /// assert_eq!(t.socket_of(31), 3);
    /// ```
    pub fn multi_socket(sockets: usize, cores_per_socket: usize) -> Self {
        let n = sockets * cores_per_socket;
        Topology {
            physical_cores: n,
            smt: 1,
            sockets,
            server_cores: (0..n).collect(),
            client_cores: vec![],
        }
    }

    /// Small single-socket topology for tests.
    pub fn small(cores: usize) -> Self {
        Topology {
            physical_cores: cores,
            smt: 1,
            sockets: 1,
            server_cores: (0..cores).collect(),
            client_cores: vec![],
        }
    }

    pub fn n_server_cores(&self) -> usize {
        self.server_cores.len()
    }

    /// Number of sockets (NUMA nodes).
    pub fn n_sockets(&self) -> usize {
        self.sockets.max(1)
    }

    /// Socket owning *server core index* `core` (0-based index into the
    /// server-core list, the id space the simulated machine uses).
    pub fn socket_of(&self, core: usize) -> usize {
        socket_of_core(core, self.n_server_cores(), self.n_sockets())
    }

    /// Do two server cores share a socket (and thus a NUMA node and a
    /// frequency domain)?
    pub fn same_socket(&self, a: usize, b: usize) -> bool {
        self.socket_of(a) == self.socket_of(b)
    }

    /// Hardware threads available to the workload (MuQSS run queues are
    /// per *physical core* in the paper's configuration, so scheduling
    /// operates on physical cores; SMT contributes capacity via the IPC
    /// model instead).
    pub fn server_hw_threads(&self) -> usize {
        self.server_cores.len() * self.smt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topology_matches_evaluation_setup() {
        let t = Topology::paper_webserver();
        assert_eq!(t.physical_cores, 16);
        assert_eq!(t.n_server_cores(), 12);
        assert_eq!(t.client_cores.len(), 4);
        assert_eq!(t.server_hw_threads(), 24);
        assert_eq!(t.n_sockets(), 1);
        assert!(t.same_socket(0, 11));
    }

    #[test]
    fn small_topology() {
        let t = Topology::small(4);
        assert_eq!(t.n_server_cores(), 4);
        assert!(t.client_cores.is_empty());
        assert_eq!(t.n_sockets(), 1);
    }

    #[test]
    fn dual_socket_layout() {
        let t = Topology::dual_socket_webserver();
        assert_eq!(t.n_sockets(), 2);
        assert_eq!(t.n_server_cores(), 24);
        for c in 0..12 {
            assert_eq!(t.socket_of(c), 0, "core {c}");
        }
        for c in 12..24 {
            assert_eq!(t.socket_of(c), 1, "core {c}");
        }
    }

    #[test]
    fn socket_spans_partition_all_cores() {
        for (n, s) in [(12, 1), (12, 2), (7, 2), (24, 3), (5, 8), (16, 4)] {
            let mut seen = vec![false; n];
            let eff = s.min(n).max(1);
            for socket in 0..eff {
                let (start, end) = socket_span(socket, n, s);
                assert!(start <= end && end <= n, "({n},{s}) socket {socket}");
                for c in start..end {
                    assert!(!seen[c], "core {c} in two sockets");
                    seen[c] = true;
                    assert_eq!(socket_of_core(c, n, s), socket);
                }
            }
            assert!(seen.iter().all(|&x| x), "({n},{s}) left cores unassigned");
        }
    }

    #[test]
    fn socket_map_matches_pointwise() {
        let map = socket_map(12, 3);
        assert_eq!(map.len(), 12);
        assert_eq!(map[0], 0);
        assert_eq!(map[4], 1);
        assert_eq!(map[11], 2);
    }

    #[test]
    fn more_sockets_than_cores_clamps() {
        // 2 cores, 8 sockets: clamps to one core per socket.
        assert_eq!(socket_of_core(0, 2, 8), 0);
        assert_eq!(socket_of_core(1, 2, 8), 1);
        let map = socket_map(2, 8);
        assert_eq!(map, vec![0, 1]);
    }
}
