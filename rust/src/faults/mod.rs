//! Deterministic fault injection for the closed-loop fleet.
//!
//! Schuchart et al. (PAPERS.md) argue that at scale the dominant
//! failure mode is not raw power draw but unpredictable per-node
//! performance *variation* — exactly what AVX frequency reduction
//! produces and what the closed-loop balancer (PR 7) exists to absorb.
//! This module makes machines actually fail: crashes with cold
//! restarts, thermal-style frequency degradation, lossy/slow front-end
//! links, and per-machine clock skew, all of it seeded.
//!
//! **Determinism contract.** Every decision here is a pure function of
//! `(config, seed, simulated time)`:
//!
//! * Fault *schedules* ([`Schedule`]) expand to concrete windows up
//!   front via [`FaultTimeline::build`] — one pass, before any machine
//!   is simulated, so no worker-thread ordering can influence them.
//! * Per-request *drop* decisions hash `(machine, arrival time)`
//!   through [`crate::util::mix64`] — no shared RNG stream, so two
//!   threads asking in any order get the same answers.
//! * Everything downstream (trace splitting, degradation windows on
//!   [`crate::sched::machine::MachineParams`], skewed arrival stamps)
//!   is derived from those windows with integer arithmetic.
//!
//! Consequently fault-enabled runs are byte-identical at any
//! `--threads`, and a faults-*disabled* run takes the literal pre-PR
//! code paths (every consumer gates on [`FaultsCfg::active`] /
//! empty window vectors), so it reproduces pre-PR bytes exactly —
//! the same differential contract as `fast_paths` and `incremental`
//! (`rust/tests/faults.rs`).
//!
//! Consumers: [`crate::fleet::balancer`] (crash visibility, lost →
//! timeout feedback, MTTR accounting), [`crate::sched::machine`]
//! (degradation windows), [`crate::scenario`] (the `faults` axis,
//! default `none`), `avxfreq chaos` + `configs/chaos.toml`, and
//! `repro faulttol`.

use crate::sim::{Time, MS, SEC};
use crate::util::{mix64, Config, Rng};

/// When a fault fires: a seeded schedule that expands to concrete
/// windows via [`Schedule::windows`] — a pure function of
/// `(schedule, duration, horizon, seed)`.
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    /// Fire once at `at`.
    OneShot { at: Time },
    /// Fire `count` times: `start`, `start + period`, …
    Periodic { start: Time, period: Time, count: u32 },
    /// Fire at seeded exponential gaps with mean `mean_gap`, starting
    /// from one gap after 0 (a homogeneous Poisson process over the
    /// horizon).
    Poisson { mean_gap: Time },
}

impl Schedule {
    /// Expand to concrete `[start, start + dur)` windows inside
    /// `[0, horizon)`.
    ///
    /// Windows whose *start* falls at or past the horizon are dropped.
    /// A window that *extends* past the horizon is split modularly when
    /// `wrap` is true (`[start, horizon)` plus `[0, overflow)`) —
    /// [`FaultsCfg::validate`] rejects such schedules when `wrap` is
    /// false, so the non-wrapping path never sees one.
    pub fn windows(&self, dur: Time, horizon: Time, wrap: bool, seed: u64) -> Vec<(Time, Time)> {
        let mut starts = Vec::new();
        match *self {
            Schedule::OneShot { at } => {
                if at < horizon {
                    starts.push(at);
                }
            }
            Schedule::Periodic { start, period, count } => {
                let mut t = start;
                for _ in 0..count {
                    if t >= horizon {
                        break;
                    }
                    starts.push(t);
                    t = t.saturating_add(period.max(1));
                }
            }
            Schedule::Poisson { mean_gap } => {
                let mut rng = Rng::new(mix64(seed ^ 0xFA_0175_C4ED));
                let mut t: Time = 0;
                loop {
                    let gap = rng.exponential(mean_gap.max(1) as f64) as Time;
                    t = t.saturating_add(gap.max(1));
                    if t >= horizon {
                        break;
                    }
                    starts.push(t);
                }
            }
        }
        let mut out = Vec::with_capacity(starts.len());
        for s in starts {
            let end = s.saturating_add(dur);
            if end <= horizon {
                out.push((s, end));
            } else if wrap {
                out.push((s, horizon));
                let overflow = end - horizon;
                if overflow > 0 {
                    out.push((0, overflow.min(horizon)));
                }
            } else {
                // validate() rejected this; clamp defensively anyway.
                out.push((s, horizon));
            }
        }
        out.sort_unstable();
        out
    }

    /// True if some window would extend past `horizon` (the condition
    /// [`FaultsCfg::validate`] rejects without `wrap`).
    fn overflows(&self, dur: Time, horizon: Time, seed: u64) -> bool {
        // Poisson windows are seeded, so expand and check the real ones.
        let mut starts: Vec<Time> = Vec::new();
        match *self {
            Schedule::OneShot { at } => starts.push(at),
            Schedule::Periodic { start, period, count } => {
                let mut t = start;
                for _ in 0..count {
                    if t >= horizon {
                        break;
                    }
                    starts.push(t);
                    t = t.saturating_add(period.max(1));
                }
            }
            Schedule::Poisson { .. } => {
                return self
                    .windows(dur, horizon, true, seed)
                    .iter()
                    .any(|&(s, _)| s == 0) // a wrapped tail landed at 0
                    && dur > 0;
            }
        }
        starts.iter().any(|&s| s < horizon && s.saturating_add(dur) > horizon)
    }
}

/// Which cores of the afflicted machine a degradation window covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradeScope {
    /// Every core (whole-package thermal event).
    Machine,
    /// One straggler core.
    Core(usize),
    /// One frequency domain / E-core module (matched against the
    /// machine's `domain_of` map).
    Module(usize),
}

/// One resolved degradation window on one machine: between `start` and
/// `end` (machine-local ns), cores in `scope` run their turbo tables
/// scaled by `scale` (< 1.0). Carried on
/// [`crate::sched::machine::MachineParams::degrade`]; an empty window
/// vector keeps the literal fault-free fast/slow paths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DegradeWindow {
    pub start: Time,
    pub end: Time,
    pub scale: f64,
    pub scope: DegradeScope,
}

impl DegradeWindow {
    /// Does this window scale `core` (whose frequency domain is
    /// `domain`) at time `t`?
    pub fn applies(&self, core: usize, domain: usize, t: Time) -> bool {
        if t < self.start || t >= self.end {
            return false;
        }
        match self.scope {
            DegradeScope::Machine => true,
            DegradeScope::Core(c) => c == core,
            DegradeScope::Module(m) => m == domain,
        }
    }

    /// Shift the window into a sub-interval's local time base,
    /// clipping to `[w0, w1)`; `None` when disjoint.
    pub fn rebased(&self, w0: Time, w1: Time) -> Option<DegradeWindow> {
        let s = self.start.max(w0);
        let e = self.end.min(w1);
        if s >= e {
            return None;
        }
        Some(DegradeWindow { start: s - w0, end: e - w0, ..*self })
    }
}

/// One crash fault: the machine goes dark for `down` ns at each
/// scheduled instant, then pays `cold_start` ns of restart before
/// accepting work again (with cold caches — each restart is a fresh
/// simulation, so license/EWMA state resets naturally).
#[derive(Clone, Debug, PartialEq)]
pub struct CrashFault {
    pub machine: usize,
    pub schedule: Schedule,
    pub down: Time,
    pub cold_start: Time,
}

/// One degradation fault (thermal event): `scope` of `machine` pinned
/// to `scale` × its turbo table for `dur` ns per scheduled window.
#[derive(Clone, Debug, PartialEq)]
pub struct DegradeFault {
    pub machine: usize,
    pub scope: DegradeScope,
    pub scale: f64,
    pub schedule: Schedule,
    pub dur: Time,
}

/// One network fault on the front-end → machine link: for `dur` ns per
/// scheduled window, deliveries to `machine` (or every machine when
/// `None`) are delayed by `delay` and dropped with probability
/// `drop_frac` (seeded per-request hash — see
/// [`FaultTimeline::dropped`]).
#[derive(Clone, Debug, PartialEq)]
pub struct LinkFault {
    pub machine: Option<usize>,
    pub delay: Time,
    pub drop_frac: f64,
    pub schedule: Schedule,
    pub dur: Time,
}

/// Constant per-machine clock offset (ns, may be negative): the
/// machine stamps arrivals `skew` earlier/later than the front end's
/// clock, so its *observed* latencies — and everything the epoch
/// feedback derives from them — are shifted by `skew`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SkewFault {
    pub machine: usize,
    pub skew_ns: i64,
}

/// The `[faults]` config section: which faults exist and when they
/// fire. `Default` is fully disabled and every consumer gates on
/// [`FaultsCfg::active`], so a default config reproduces pre-PR bytes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultsCfg {
    pub enabled: bool,
    /// Allow windows to wrap modularly around the measure window
    /// instead of being rejected by [`FaultsCfg::validate`].
    pub wrap: bool,
    /// Mixed into the run seed for every seeded fault decision, so two
    /// fault layers on the same run seed can differ.
    pub seed_salt: u64,
    pub crashes: Vec<CrashFault>,
    pub degrades: Vec<DegradeFault>,
    pub links: Vec<LinkFault>,
    pub skews: Vec<SkewFault>,
}

impl FaultsCfg {
    /// True when fault injection can affect the run at all.
    pub fn active(&self) -> bool {
        self.enabled
            && !(self.crashes.is_empty()
                && self.degrades.is_empty()
                && self.links.is_empty()
                && self.skews.is_empty())
    }

    /// The canonical chaos preset used by the scenario `faults=chaos`
    /// axis value, `avxfreq bench`'s chaos scenario, and `repro
    /// faulttol`: one mid-run crash on machine 0, a periodic
    /// whole-machine degradation on machine 1, a lossy slow link to
    /// every machine for the middle fifth of the window, and +300 µs of
    /// clock skew on the last machine. Pure function of
    /// `(measure, machines)`.
    pub fn chaos(measure: Time, machines: usize) -> FaultsCfg {
        let mut cfg = FaultsCfg { enabled: true, ..Default::default() };
        cfg.crashes.push(CrashFault {
            machine: 0,
            schedule: Schedule::OneShot { at: measure / 4 },
            down: measure / 8,
            cold_start: 2 * MS,
        });
        if machines > 1 {
            cfg.degrades.push(DegradeFault {
                machine: 1,
                scope: DegradeScope::Machine,
                scale: 0.6,
                schedule: Schedule::Periodic {
                    start: measure / 10,
                    period: measure * 2 / 5,
                    count: 2,
                },
                dur: measure / 6,
            });
        }
        cfg.links.push(LinkFault {
            machine: None,
            delay: 200 * crate::sim::US,
            drop_frac: 0.02,
            schedule: Schedule::OneShot { at: measure * 2 / 5 },
            dur: measure / 5,
        });
        if machines > 0 {
            cfg.skews.push(SkewFault { machine: machines - 1, skew_ns: 300_000 });
        }
        cfg
    }

    /// Parse the `[faults]` section. Absent keys leave the default
    /// (disabled) config, so existing configs are untouched. One fault
    /// of each kind is expressible from flags/TOML; presets
    /// ([`FaultsCfg::chaos`]) compose several.
    pub fn from_config(conf: &Config, measure: Time) -> anyhow::Result<FaultsCfg> {
        let mut cfg = FaultsCfg {
            enabled: conf.bool_or("faults.enabled", false),
            wrap: conf.bool_or("faults.wrap", false),
            seed_salt: conf.int_or("faults.seed_salt", 0) as u64,
            ..Default::default()
        };
        if conf.str_or("faults.preset", "") == "chaos" {
            let machines = conf.usize_or("fleet.machines", 4);
            let mut preset = FaultsCfg::chaos(measure, machines);
            preset.wrap = cfg.wrap;
            preset.seed_salt = cfg.seed_salt;
            return Ok(preset);
        }
        let schedule = |kind: &str| -> anyhow::Result<Option<Schedule>> {
            // Keyed on key *presence*, not sentinel values, so NaN and
            // negative edge values reach the rejection below instead of
            // silently deselecting the schedule.
            let akey = format!("faults.{kind}_at_s");
            let pkey = format!("faults.{kind}_period_s");
            let gkey = format!("faults.{kind}_poisson_gap_s");
            let secs = |v: f64| (v * SEC as f64) as Time;
            let at = conf.float_or(&akey, 0.0);
            if conf.get(&akey).is_some() {
                anyhow::ensure!(
                    at.is_finite() && at >= 0.0,
                    "{akey} = {at}: must be a finite value ≥ 0"
                );
            }
            if conf.get(&pkey).is_some() {
                let period = conf.float_or(&pkey, 0.0);
                anyhow::ensure!(
                    period.is_finite() && period > 0.0,
                    "{pkey} = {period}: must be a finite value > 0"
                );
                let count = conf.int_or(&format!("faults.{kind}_count"), 2);
                anyhow::ensure!(count > 0, "faults.{kind}_count = {count}: must be > 0");
                Ok(Some(Schedule::Periodic {
                    start: secs(at),
                    period: secs(period),
                    count: count as u32,
                }))
            } else if conf.get(&gkey).is_some() {
                let gap = conf.float_or(&gkey, 0.0);
                anyhow::ensure!(
                    gap.is_finite() && gap > 0.0,
                    "{gkey} = {gap}: must be a finite value > 0"
                );
                Ok(Some(Schedule::Poisson { mean_gap: secs(gap) }))
            } else if conf.get(&akey).is_some() {
                Ok(Some(Schedule::OneShot { at: secs(at) }))
            } else {
                Ok(None)
            }
        };
        if let Some(sched) = schedule("crash")? {
            let down_s = conf.float_or("faults.crash_down_s", 0.01);
            let cold_ms = conf.float_or("faults.crash_cold_start_ms", 1.0);
            cfg.crashes.push(CrashFault {
                machine: conf.usize_or("faults.crash_machine", 0),
                schedule: sched,
                down: (down_s * SEC as f64) as Time,
                cold_start: (cold_ms * MS as f64) as Time,
            });
        }
        if let Some(sched) = schedule("degrade")? {
            let scope = match conf.str_or("faults.degrade_scope", "machine") {
                "machine" => DegradeScope::Machine,
                s if s.starts_with("core:") => DegradeScope::Core(
                    s[5..].parse().map_err(|_| {
                        anyhow::anyhow!("faults.degrade_scope = {s:?}: core:<index> expected")
                    })?,
                ),
                s if s.starts_with("module:") => DegradeScope::Module(
                    s[7..].parse().map_err(|_| {
                        anyhow::anyhow!("faults.degrade_scope = {s:?}: module:<index> expected")
                    })?,
                ),
                other => anyhow::bail!(
                    "faults.degrade_scope = {other:?} (machine|core:<i>|module:<i>)"
                ),
            };
            cfg.degrades.push(DegradeFault {
                machine: conf.usize_or("faults.degrade_machine", 0),
                scope,
                scale: conf.float_or("faults.degrade_scale", 0.6),
                schedule: sched,
                dur: (conf.float_or("faults.degrade_dur_s", 0.02) * SEC as f64) as Time,
            });
        }
        if let Some(sched) = schedule("link")? {
            let m = conf.int_or("faults.link_machine", -1);
            cfg.links.push(LinkFault {
                machine: if m < 0 { None } else { Some(m as usize) },
                delay: (conf.float_or("faults.link_delay_us", 0.0) * crate::sim::US as f64)
                    as Time,
                drop_frac: conf.float_or("faults.link_drop_frac", 0.0),
                schedule: sched,
                dur: (conf.float_or("faults.link_dur_s", 0.02) * SEC as f64) as Time,
            });
        }
        let skew_us = conf.float_or("faults.skew_us", 0.0);
        if skew_us != 0.0 {
            anyhow::ensure!(
                skew_us.is_finite(),
                "faults.skew_us = {skew_us}: must be a finite value"
            );
            cfg.skews.push(SkewFault {
                machine: conf.usize_or("faults.skew_machine", 0),
                skew_ns: (skew_us * crate::sim::US as f64) as i64,
            });
        }
        cfg.validate(measure, usize::MAX)?;
        Ok(cfg)
    }

    /// Edge validation, PR 9 `load.*` style: every rejection names the
    /// offending key and value. `machines` bounds the per-fault machine
    /// indices (`usize::MAX` to skip when the fleet size is not yet
    /// known); `measure` is the window the schedules must fit unless
    /// `wrap` is set.
    pub fn validate(&self, measure: Time, machines: usize) -> anyhow::Result<()> {
        if !self.enabled {
            return Ok(());
        }
        anyhow::ensure!(measure > 0, "faults require a measure window > 0");
        for (i, c) in self.crashes.iter().enumerate() {
            anyhow::ensure!(
                c.machine < machines,
                "faults.crash_machine = {}: fleet has {machines} machines",
                c.machine
            );
            anyhow::ensure!(c.down > 0, "faults.crash_down_s: crash #{i} down time must be > 0");
            anyhow::ensure!(
                !self.overflowing(&c.schedule, c.down.saturating_add(c.cold_start), measure),
                "faults.crash: crash #{i} window (down + cold start) extends past the \
                 measure window; set faults.wrap = true to wrap it modularly"
            );
        }
        for (i, d) in self.degrades.iter().enumerate() {
            anyhow::ensure!(
                d.machine < machines,
                "faults.degrade_machine = {}: fleet has {machines} machines",
                d.machine
            );
            anyhow::ensure!(
                d.scale.is_finite() && d.scale > 0.0 && d.scale <= 1.0,
                "faults.degrade_scale = {}: must be a finite value in (0, 1]",
                d.scale
            );
            anyhow::ensure!(d.dur > 0, "faults.degrade_dur_s: window #{i} must be > 0");
            anyhow::ensure!(
                !self.overflowing(&d.schedule, d.dur, measure),
                "faults.degrade: window #{i} extends past the measure window; \
                 set faults.wrap = true to wrap it modularly"
            );
        }
        for (i, l) in self.links.iter().enumerate() {
            if let Some(m) = l.machine {
                anyhow::ensure!(
                    m < machines,
                    "faults.link_machine = {m}: fleet has {machines} machines"
                );
            }
            anyhow::ensure!(
                l.drop_frac.is_finite() && (0.0..=1.0).contains(&l.drop_frac),
                "faults.link_drop_frac = {}: must be a finite value in [0, 1]",
                l.drop_frac
            );
            anyhow::ensure!(l.dur > 0, "faults.link_dur_s: window #{i} must be > 0");
            anyhow::ensure!(
                !self.overflowing(&l.schedule, l.dur, measure),
                "faults.link: window #{i} extends past the measure window; \
                 set faults.wrap = true to wrap it modularly"
            );
        }
        for s in &self.skews {
            anyhow::ensure!(
                s.machine < machines,
                "faults.skew_machine = {}: fleet has {machines} machines",
                s.machine
            );
        }
        Ok(())
    }

    fn overflowing(&self, sched: &Schedule, dur: Time, measure: Time) -> bool {
        !self.wrap && sched.overflows(dur, measure, self.seed_salt)
    }

    /// A one-word summary for scenario labels and reports.
    pub fn label(&self) -> &'static str {
        if self.active() {
            "chaos"
        } else {
            "none"
        }
    }
}

/// One resolved link window: between `start` and `end`, deliveries are
/// delayed by `delay` and dropped with probability `drop_frac`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkWindow {
    pub start: Time,
    pub end: Time,
    pub delay: Time,
    pub drop_frac: f64,
}

/// The fully expanded, per-machine view of a [`FaultsCfg`] over one
/// measure window: crash dark intervals (down time + cold start,
/// merged when overlapping), degradation windows, link windows, and
/// clock offsets. Built once up front ([`FaultTimeline::build`]) —
/// a pure function of `(config, horizon, machines, seed)` — and then
/// only *read* by the (possibly parallel) simulation, which is what
/// keeps fault runs byte-identical at any thread count.
#[derive(Clone, Debug, Default)]
pub struct FaultTimeline {
    /// Per machine: sorted, disjoint dark intervals `[start, end)`.
    pub dark: Vec<Vec<(Time, Time)>>,
    /// Per machine: degradation windows (unsorted, checked per block).
    pub degrade: Vec<Vec<DegradeWindow>>,
    /// Per machine: sorted link-fault windows.
    pub link: Vec<Vec<LinkWindow>>,
    /// Per machine: constant clock offset (ns).
    pub skew: Vec<i64>,
    /// Seed for per-request drop hashing.
    drop_seed: u64,
}

impl FaultTimeline {
    /// Expand `cfg` over `[0, horizon)` for a fleet of `machines`.
    pub fn build(cfg: &FaultsCfg, horizon: Time, machines: usize, seed: u64) -> FaultTimeline {
        let base = mix64(seed ^ cfg.seed_salt.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xFA17);
        let mut tl = FaultTimeline {
            dark: vec![Vec::new(); machines],
            degrade: vec![Vec::new(); machines],
            link: vec![Vec::new(); machines],
            skew: vec![0; machines],
            drop_seed: mix64(base ^ 0xD50F),
        };
        if !cfg.active() {
            return tl;
        }
        for (i, c) in cfg.crashes.iter().enumerate() {
            if c.machine >= machines {
                continue;
            }
            let wseed = mix64(base ^ 0xC4A5_4EED ^ (i as u64) << 8);
            for (s, e) in c.schedule.windows(c.down, horizon, cfg.wrap, wseed) {
                // The machine is dark for the crash itself plus the
                // cold-start penalty before it accepts work again.
                let end = e.saturating_add(c.cold_start).min(horizon);
                tl.dark[c.machine].push((s, end));
            }
        }
        for m in &mut tl.dark {
            m.sort_unstable();
            // Merge overlapping dark intervals.
            let mut merged: Vec<(Time, Time)> = Vec::with_capacity(m.len());
            for &(s, e) in m.iter() {
                match merged.last_mut() {
                    Some(last) if s <= last.1 => last.1 = last.1.max(e),
                    _ => merged.push((s, e)),
                }
            }
            *m = merged;
        }
        for (i, d) in cfg.degrades.iter().enumerate() {
            if d.machine >= machines {
                continue;
            }
            let wseed = mix64(base ^ 0xDE64_ADE5 ^ (i as u64) << 8);
            for (s, e) in d.schedule.windows(d.dur, horizon, cfg.wrap, wseed) {
                tl.degrade[d.machine].push(DegradeWindow {
                    start: s,
                    end: e,
                    scale: d.scale,
                    scope: d.scope,
                });
            }
        }
        for (i, l) in cfg.links.iter().enumerate() {
            let wseed = mix64(base ^ 0x11_4BAD ^ (i as u64) << 8);
            for (s, e) in l.schedule.windows(l.dur, horizon, cfg.wrap, wseed) {
                let w = LinkWindow { start: s, end: e, delay: l.delay, drop_frac: l.drop_frac };
                match l.machine {
                    Some(m) if m < machines => tl.link[m].push(w),
                    Some(_) => {}
                    None => {
                        for m in 0..machines {
                            tl.link[m].push(w);
                        }
                    }
                }
            }
        }
        for m in &mut tl.link {
            m.sort_unstable_by_key(|w| (w.start, w.end));
        }
        for s in &cfg.skews {
            if s.machine < machines {
                tl.skew[s.machine] = tl.skew[s.machine].saturating_add(s.skew_ns);
            }
        }
        tl
    }

    /// Any fault anywhere? False for a disabled config — consumers use
    /// this to take the literal fault-free code path.
    pub fn any(&self) -> bool {
        self.dark.iter().any(|v| !v.is_empty())
            || self.degrade.iter().any(|v| !v.is_empty())
            || self.link.iter().any(|v| !v.is_empty())
            || self.skew.iter().any(|&s| s != 0)
    }

    /// Is machine `m` dark (crashed or cold-starting) at time `t`?
    pub fn is_dark(&self, m: usize, t: Time) -> bool {
        self.dark[m].iter().any(|&(s, e)| t >= s && t < e)
    }

    /// The up (not-dark) sub-intervals of `[w0, w1)` for machine `m`,
    /// in order. A machine with no crash windows returns the whole
    /// interval.
    pub fn up_segments(&self, m: usize, w0: Time, w1: Time) -> Vec<(Time, Time)> {
        let mut segs = Vec::new();
        let mut cur = w0;
        for &(s, e) in &self.dark[m] {
            if e <= cur || s >= w1 {
                continue;
            }
            if s > cur {
                segs.push((cur, s.min(w1)));
            }
            cur = cur.max(e);
            if cur >= w1 {
                break;
            }
        }
        if cur < w1 {
            segs.push((cur, w1));
        }
        segs
    }

    /// The link window covering delivery to machine `m` at time `t`.
    pub fn link_at(&self, m: usize, t: Time) -> Option<&LinkWindow> {
        self.link[m].iter().find(|w| t >= w.start && t < w.end)
    }

    /// Seeded per-request drop decision: a pure hash of
    /// `(machine, nominal arrival time)`, so the answer is independent
    /// of which worker thread asks, and in what order.
    pub fn dropped(&self, m: usize, t: Time) -> bool {
        match self.link_at(m, t) {
            Some(w) if w.drop_frac > 0.0 => {
                let h = mix64(self.drop_seed ^ (m as u64).wrapping_mul(0xA24B_AED4_963E_E407) ^ t);
                (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < w.drop_frac
            }
            _ => false,
        }
    }

    /// Inbound delivery delay to machine `m` for a request sent at `t`.
    pub fn delay(&self, m: usize, t: Time) -> Time {
        self.link_at(m, t).map_or(0, |w| w.delay)
    }

    /// Machine `m`'s clock offset: its local stamp for front-end time
    /// `t` is `t - skew`.
    pub fn skewed(&self, m: usize, t: Time) -> Time {
        let s = self.skew[m];
        if s >= 0 {
            t.saturating_sub(s as Time)
        } else {
            t.saturating_add(s.unsigned_abs())
        }
    }

    /// Degradation windows for machine `m` clipped and rebased into
    /// `[w0, w1)` local time (what a per-epoch / per-segment
    /// [`crate::sched::machine::MachineParams`] carries).
    pub fn degrade_in(&self, m: usize, w0: Time, w1: Time) -> Vec<DegradeWindow> {
        self.degrade[m].iter().filter_map(|w| w.rebased(w0, w1)).collect()
    }

    /// Total resolved windows of each kind (crash, degrade, link) —
    /// the counts [`crate::traffic::FaultOutcomes`] reports.
    pub fn window_counts(&self) -> (u64, u64, u64) {
        let c = self.dark.iter().map(|v| v.len() as u64).sum();
        let d = self.degrade.iter().map(|v| v.len() as u64).sum();
        let l: u64 = self.link.iter().map(|v| v.len() as u64).sum();
        (c, d, l)
    }
}

/// Per-fault-window SLO damage, computed by the closed loop at epoch
/// granularity (an epoch counts as "inside" a window when the two
/// overlap) and rendered by [`crate::metrics::fault_report`].
#[derive(Clone, Debug, PartialEq)]
pub struct FaultWindowStat {
    /// `"crash"`, `"degrade"`, or `"link"`.
    pub kind: &'static str,
    /// `"m<i>"`, `"m<i>+m<j>"`, or `"all"` for an every-machine link
    /// fault.
    pub machine: String,
    /// Window bounds, ns from the start of the measure window.
    pub start: Time,
    pub end: Time,
    /// Cluster p99 (µs) merged over the epochs overlapping the window.
    pub p99_in_us: f64,
    /// Cluster p99 (µs) merged over every other measured epoch.
    pub p99_out_us: f64,
    /// SLO violations inside the overlapping epochs.
    pub violations_in: u64,
    /// Crash windows only: epochs from ejection to readmission (MTTR).
    pub readmit_epochs: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_shot_expands_to_one_window_inside_horizon() {
        let s = Schedule::OneShot { at: 10 * MS };
        assert_eq!(s.windows(5 * MS, SEC, false, 1), vec![(10 * MS, 15 * MS)]);
        assert!(s.windows(5 * MS, 5 * MS, false, 1).is_empty(), "start past horizon drops");
    }

    #[test]
    fn periodic_expands_count_windows_and_stops_at_horizon() {
        let s = Schedule::Periodic { start: MS, period: 10 * MS, count: 3 };
        let w = s.windows(2 * MS, SEC, false, 1);
        assert_eq!(w, vec![(MS, 3 * MS), (11 * MS, 13 * MS), (21 * MS, 23 * MS)]);
        let clipped = s.windows(2 * MS, 12 * MS, true, 1);
        assert_eq!(clipped.len(), 2, "third window starts past the horizon");
    }

    #[test]
    fn poisson_windows_are_seed_deterministic_and_in_range() {
        let s = Schedule::Poisson { mean_gap: 20 * MS };
        let a = s.windows(MS, SEC, true, 7);
        let b = s.windows(MS, SEC, true, 7);
        assert_eq!(a, b, "same seed, same windows");
        assert!(!a.is_empty());
        assert!(a.iter().all(|&(s0, e0)| s0 < e0 && e0 <= SEC));
        let c = s.windows(MS, SEC, true, 8);
        assert_ne!(a, c, "different seed, different windows");
    }

    #[test]
    fn wrap_splits_an_overflowing_window_modularly() {
        let s = Schedule::OneShot { at: 90 * MS };
        let w = s.windows(20 * MS, 100 * MS, true, 1);
        assert_eq!(w, vec![(0, 10 * MS), (90 * MS, 100 * MS)]);
    }

    #[test]
    fn validate_rejects_overflow_without_wrap() {
        let mut cfg = FaultsCfg { enabled: true, ..Default::default() };
        cfg.crashes.push(CrashFault {
            machine: 0,
            schedule: Schedule::OneShot { at: 90 * MS },
            down: 20 * MS,
            cold_start: 0,
        });
        let err = cfg.validate(100 * MS, 4).unwrap_err().to_string();
        assert!(err.contains("faults.wrap"), "error should point at the wrap escape: {err}");
        cfg.wrap = true;
        cfg.validate(100 * MS, 4).unwrap();
    }

    #[test]
    fn validate_rejects_bad_scale_drop_frac_and_machine_index() {
        let mut cfg = FaultsCfg { enabled: true, ..Default::default() };
        cfg.degrades.push(DegradeFault {
            machine: 0,
            scope: DegradeScope::Machine,
            scale: 1.5,
            schedule: Schedule::OneShot { at: 0 },
            dur: MS,
        });
        assert!(cfg.validate(SEC, 4).unwrap_err().to_string().contains("degrade_scale"));
        cfg.degrades[0].scale = f64::NAN;
        assert!(cfg.validate(SEC, 4).unwrap_err().to_string().contains("degrade_scale"));
        cfg.degrades[0].scale = 0.5;
        cfg.degrades[0].machine = 9;
        assert!(cfg.validate(SEC, 4).unwrap_err().to_string().contains("degrade_machine"));
        cfg.degrades.clear();
        cfg.links.push(LinkFault {
            machine: Some(1),
            delay: 0,
            drop_frac: 1.5,
            schedule: Schedule::OneShot { at: 0 },
            dur: MS,
        });
        assert!(cfg.validate(SEC, 4).unwrap_err().to_string().contains("link_drop_frac"));
    }

    #[test]
    fn disabled_config_validates_and_builds_an_inert_timeline() {
        let cfg = FaultsCfg::default();
        cfg.validate(0, 0).unwrap();
        assert!(!cfg.active());
        let tl = FaultTimeline::build(&cfg, SEC, 4, 42);
        assert!(!tl.any());
        assert!(tl.up_segments(0, 0, SEC) == vec![(0, SEC)]);
        assert_eq!(tl.delay(0, 0), 0);
        assert!(!tl.dropped(0, 0));
        assert_eq!(tl.skewed(0, 5), 5);
    }

    #[test]
    fn crash_dark_interval_includes_cold_start_and_splits_segments() {
        let mut cfg = FaultsCfg { enabled: true, ..Default::default() };
        cfg.crashes.push(CrashFault {
            machine: 1,
            schedule: Schedule::OneShot { at: 40 * MS },
            down: 10 * MS,
            cold_start: 5 * MS,
        });
        let tl = FaultTimeline::build(&cfg, 100 * MS, 4, 42);
        assert!(tl.is_dark(1, 40 * MS));
        assert!(tl.is_dark(1, 54 * MS), "cold start keeps the machine dark");
        assert!(!tl.is_dark(1, 55 * MS));
        assert!(!tl.is_dark(0, 45 * MS), "other machines unaffected");
        assert_eq!(
            tl.up_segments(1, 0, 100 * MS),
            vec![(0, 40 * MS), (55 * MS, 100 * MS)]
        );
        assert_eq!(
            tl.up_segments(1, 45 * MS, 50 * MS),
            Vec::<(Time, Time)>::new(),
            "an epoch entirely inside the dark window has no up segments"
        );
        assert_eq!(tl.window_counts().0, 1);
    }

    #[test]
    fn overlapping_dark_intervals_merge() {
        let mut cfg = FaultsCfg { enabled: true, ..Default::default() };
        for at in [10 * MS, 15 * MS] {
            cfg.crashes.push(CrashFault {
                machine: 0,
                schedule: Schedule::OneShot { at },
                down: 10 * MS,
                cold_start: 0,
            });
        }
        let tl = FaultTimeline::build(&cfg, 100 * MS, 1, 1);
        assert_eq!(tl.dark[0], vec![(10 * MS, 25 * MS)]);
    }

    #[test]
    fn drop_decision_is_a_pure_seeded_hash_near_the_requested_rate() {
        let mut cfg = FaultsCfg { enabled: true, ..Default::default() };
        cfg.links.push(LinkFault {
            machine: None,
            delay: 7,
            drop_frac: 0.25,
            schedule: Schedule::OneShot { at: 0 },
            dur: SEC,
        });
        let tl = FaultTimeline::build(&cfg, SEC, 2, 9);
        let n = 20_000u64;
        let dropped = (0..n).filter(|&i| tl.dropped(0, i * 1_000)).count() as f64;
        let frac = dropped / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "drop rate {frac} far from 0.25");
        // Pure: asking twice (any "order") gives the same answer.
        assert_eq!(tl.dropped(0, 123_000), tl.dropped(0, 123_000));
        assert_eq!(tl.delay(0, 10), 7);
        assert_eq!(tl.delay(0, SEC + 10), 0, "outside the window: no delay");
    }

    #[test]
    fn skew_shifts_stamps_both_ways_and_saturates_at_zero() {
        let mut cfg = FaultsCfg { enabled: true, ..Default::default() };
        cfg.skews.push(SkewFault { machine: 0, skew_ns: 100 });
        cfg.skews.push(SkewFault { machine: 1, skew_ns: -100 });
        let tl = FaultTimeline::build(&cfg, SEC, 2, 1);
        assert_eq!(tl.skewed(0, 250), 150);
        assert_eq!(tl.skewed(0, 50), 0, "saturates instead of wrapping");
        assert_eq!(tl.skewed(1, 250), 350);
    }

    #[test]
    fn degrade_windows_rebase_and_scope_match() {
        let w = DegradeWindow {
            start: 10 * MS,
            end: 20 * MS,
            scale: 0.5,
            scope: DegradeScope::Core(2),
        };
        assert!(w.applies(2, 0, 15 * MS));
        assert!(!w.applies(1, 0, 15 * MS));
        assert!(!w.applies(2, 0, 20 * MS), "end-exclusive");
        let r = w.rebased(12 * MS, 30 * MS).unwrap();
        assert_eq!((r.start, r.end), (0, 8 * MS));
        assert!(w.rebased(20 * MS, 30 * MS).is_none());
        let m = DegradeWindow { scope: DegradeScope::Module(1), ..w };
        assert!(m.applies(5, 1, 15 * MS));
        assert!(!m.applies(5, 0, 15 * MS));
    }

    #[test]
    fn chaos_preset_is_active_and_validates() {
        let cfg = FaultsCfg::chaos(200 * MS, 4);
        assert!(cfg.active());
        cfg.validate(200 * MS, 4).unwrap();
        assert_eq!(cfg.label(), "chaos");
        assert_eq!(FaultsCfg::default().label(), "none");
        let tl = FaultTimeline::build(&cfg, 200 * MS, 4, 42);
        assert!(tl.any());
        let (c, d, l) = tl.window_counts();
        assert!(c >= 1 && d >= 1 && l >= 1, "crash={c} degrade={d} link={l}");
        assert_ne!(tl.skew[3], 0);
    }

    #[test]
    fn from_config_parses_and_rejects_edge_values() {
        let conf = Config::parse(
            "[faults]\nenabled = true\ncrash_at_s = 0.01\ncrash_down_s = 0.005\n\
             crash_cold_start_ms = 2.0\nlink_at_s = 0.02\nlink_dur_s = 0.01\n\
             link_delay_us = 150.0\nlink_drop_frac = 0.1\nskew_us = 250.0\nskew_machine = 1\n",
        )
        .unwrap();
        let cfg = FaultsCfg::from_config(&conf, 100 * MS).unwrap();
        assert!(cfg.active());
        assert_eq!(cfg.crashes.len(), 1);
        assert_eq!(cfg.crashes[0].cold_start, 2 * MS);
        assert_eq!(cfg.links[0].delay, 150 * crate::sim::US);
        assert_eq!(cfg.skews[0].skew_ns, 250_000);

        let reject = |toml: &str, key: &str| {
            let conf = Config::parse(toml).unwrap();
            let err = FaultsCfg::from_config(&conf, 100 * MS).unwrap_err().to_string();
            assert!(err.contains(key), "error {err:?} should name {key:?}");
        };
        reject("[faults]\nenabled = true\ncrash_period_s = 0.0\n", "crash_period_s");
        reject("[faults]\nenabled = true\ncrash_period_s = nan\n", "crash_period_s");
        reject(
            "[faults]\nenabled = true\ndegrade_at_s = 0.01\ndegrade_scale = 2.0\n",
            "degrade_scale",
        );
        reject(
            "[faults]\nenabled = true\nlink_at_s = 0.0\nlink_drop_frac = -0.5\n",
            "link_drop_frac",
        );
        reject(
            "[faults]\nenabled = true\ndegrade_at_s = 0.0\ndegrade_scope = \"socket\"\n",
            "degrade_scope",
        );
        // Past-the-window schedule without wrap is rejected; with wrap it parses.
        reject("[faults]\nenabled = true\ncrash_at_s = 0.09\ncrash_down_s = 0.05\n", "wrap");
        let conf = Config::parse(
            "[faults]\nenabled = true\nwrap = true\ncrash_at_s = 0.09\ncrash_down_s = 0.05\n",
        )
        .unwrap();
        FaultsCfg::from_config(&conf, 100 * MS).unwrap();
    }

    #[test]
    fn chaos_preset_key_builds_from_config() {
        let conf =
            Config::parse("[faults]\nenabled = true\npreset = \"chaos\"\n[fleet]\nmachines = 4\n")
                .unwrap();
        let cfg = FaultsCfg::from_config(&conf, 200 * MS).unwrap();
        assert_eq!(cfg, FaultsCfg::chaos(200 * MS, 4));
    }
}
