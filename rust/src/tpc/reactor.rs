//! Completion batching: the reactor half of the thread-per-core model.
//!
//! glommio's reactor drains the io_uring completion ring once per loop
//! iteration and wakes every affected task queue in one pass, rather
//! than signalling per completion. The simulated analogue: during one
//! external event, every placed/woken job *notes* its target core here;
//! the driver then flushes, notifying each distinct core's channel
//! once. The dedup is the batching — a burst of arrivals landing on one
//! core costs one wake, not N.

/// Collects wake targets during one external event and dedups them.
#[derive(Clone, Debug, Default)]
pub struct Reactor {
    /// Cores touched since the last flush, insertion-ordered and
    /// deduplicated (executor core counts are small; a linear scan beats
    /// a hash set and keeps flush order deterministic).
    pending: Vec<usize>,
    /// Completion batches flushed (one per external event with ≥1 job).
    pub batches: u64,
    /// Total jobs noted across all batches.
    pub batch_jobs: u64,
    /// Largest single batch (jobs per flush).
    pub max_batch: u64,
    /// Jobs noted in the current (unflushed) batch.
    current: u64,
}

impl Reactor {
    pub fn new() -> Self {
        Reactor::default()
    }

    /// Note that `core` has a newly runnable job.
    pub fn note(&mut self, core: usize) {
        self.current += 1;
        self.batch_jobs += 1;
        if !self.pending.contains(&core) {
            self.pending.push(core);
        }
    }

    /// End the batch: return the distinct cores to wake, in the order
    /// they were first noted. Empty batches (an external event that
    /// placed no jobs) are not counted.
    pub fn flush(&mut self) -> Vec<usize> {
        if self.current > 0 {
            self.batches += 1;
            self.max_batch = self.max_batch.max(self.current);
            self.current = 0;
        }
        std::mem::take(&mut self.pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flush_dedups_and_preserves_first_noted_order() {
        let mut r = Reactor::new();
        r.note(2);
        r.note(0);
        r.note(2);
        r.note(1);
        assert_eq!(r.flush(), vec![2, 0, 1]);
        assert_eq!(r.batches, 1);
        assert_eq!(r.batch_jobs, 4);
        assert_eq!(r.max_batch, 4);
    }

    #[test]
    fn empty_flushes_are_not_batches() {
        let mut r = Reactor::new();
        assert!(r.flush().is_empty());
        assert_eq!(r.batches, 0);
        r.note(0);
        r.flush();
        assert!(r.flush().is_empty());
        assert_eq!(r.batches, 1);
    }

    #[test]
    fn max_batch_tracks_the_largest_flush() {
        let mut r = Reactor::new();
        r.note(0);
        r.flush();
        for c in 0..3 {
            r.note(c);
        }
        r.flush();
        r.note(1);
        r.flush();
        assert_eq!(r.batches, 3);
        assert_eq!(r.batch_jobs, 5);
        assert_eq!(r.max_batch, 3);
    }
}
