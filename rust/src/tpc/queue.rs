//! Per-core task queues with shares and preemption budgets.
//!
//! Each executor core owns one FIFO queue of jobs. Cores carry a
//! *share*; [`grant_budgets`] converts the shares into per-stint
//! instruction budgets out of a global `quantum`, glommio's
//! `Shares`-style proportional split. The conservation law — the sum of
//! granted budgets never exceeds the quantum — is property-tested in
//! `rust/tests/tpc.rs`.

use std::collections::VecDeque;

/// A task queued on (or running on) an executor core.
#[derive(Clone, Debug)]
pub struct TpcJob<T> {
    pub payload: T,
    /// Spawned from an AVX-marked future (the runtime-visible analogue
    /// of the paper's `with_avx()` annotation).
    pub marked: bool,
    /// The executor core the job currently belongs to; wakes requeue
    /// here (see [`super::waker`]).
    pub home: usize,
    /// Set on the first `with_avx()` observed in the current AVX phase;
    /// cleared by `without_avx()`. Guards `avx-steer-lazy` against
    /// re-migrating within one phase.
    pub in_avx_phase: bool,
}

/// One executor core's FIFO run queue.
#[derive(Clone, Debug)]
pub struct TpcQueue<T> {
    /// Relative share of the preemption quantum this core is granted.
    pub share: u64,
    jobs: VecDeque<TpcJob<T>>,
}

impl<T> TpcQueue<T> {
    pub fn new(share: u64) -> Self {
        TpcQueue { share, jobs: VecDeque::new() }
    }

    pub fn push_back(&mut self, job: TpcJob<T>) {
        self.jobs.push_back(job);
    }

    pub fn pop_front(&mut self) -> Option<TpcJob<T>> {
        self.jobs.pop_front()
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

/// Split `quantum` instructions across cores proportionally to their
/// shares (floor division — the remainder is deliberately *not*
/// redistributed, so `sum(budgets) ≤ quantum` holds exactly, for every
/// input). Zero total share degrades to uniform shares; a zero budget
/// cannot livelock the core — the executor always completes the step it
/// started before checking its stint (see `ExecutorTask` in
/// `workload/webserver.rs`), so budget 0 just means "yield after every
/// step". `quantum = u64::MAX` (the default) effectively disables
/// preemption.
pub fn grant_budgets(quantum: u64, shares: &[u64]) -> Vec<u64> {
    if shares.is_empty() {
        return Vec::new();
    }
    let total: u128 = shares.iter().map(|&s| s as u128).sum();
    if total == 0 {
        return vec![quantum / shares.len() as u64; shares.len()];
    }
    shares.iter().map(|&s| (quantum as u128 * s as u128 / total) as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut q: TpcQueue<u32> = TpcQueue::new(1);
        for i in 0..3 {
            q.push_back(TpcJob { payload: i, marked: false, home: 0, in_avx_phase: false });
        }
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop_front().unwrap().payload, 0);
        assert_eq!(q.pop_front().unwrap().payload, 1);
        assert_eq!(q.pop_front().unwrap().payload, 2);
        assert!(q.is_empty());
    }

    #[test]
    fn budgets_split_proportionally() {
        assert_eq!(grant_budgets(100, &[1, 1, 2]), vec![25, 25, 50]);
        assert_eq!(grant_budgets(100, &[3]), vec![100]);
        // Floor division: 100 × 1/3 = 33, and the remainder stays
        // ungranted (33 + 33 + 33 = 99 ≤ 100).
        assert_eq!(grant_budgets(100, &[1, 1, 1]), vec![33, 33, 33]);
    }

    #[test]
    fn uniform_fallback_and_zero_shares() {
        assert_eq!(grant_budgets(90, &[0, 0, 0]), vec![30, 30, 30]);
        // A zero share grants a zero budget: the core yields after every
        // step but can never exceed the quantum.
        assert_eq!(grant_budgets(100, &[0, 1]), vec![0, 100]);
        assert_eq!(grant_budgets(0, &[0, 0]), vec![0, 0]);
        assert!(grant_budgets(5, &[]).is_empty());
    }

    #[test]
    fn max_quantum_never_overflows() {
        let b = grant_budgets(u64::MAX, &[1, 1, 1, 1]);
        assert_eq!(b.len(), 4);
        let sum: u128 = b.iter().map(|&x| x as u128).sum();
        assert!(sum <= u64::MAX as u128);
    }
}
