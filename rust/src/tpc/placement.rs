//! Placement policies for the thread-per-core runtime: which executor
//! core a future is spawned (or steered) onto.
//!
//! The three policies mirror the paper's kernel-level spectrum one layer
//! up the stack:
//!
//! * `home-core` — glommio's default: round-robin spawn, tasks then stay
//!   on their home core forever. No AVX awareness (the baseline).
//! * `avx-steer` — CoreSpec inside the runtime: AVX-*marked* futures are
//!   spawned/woken onto a designated core subset (the last `avx_cores`
//!   executor cores, matching [`crate::sched::PolicyKind`]'s last-K
//!   convention), unmarked futures onto the scalar complement.
//! * `avx-steer-lazy` — the runtime analogue of §6.1 fault-and-migrate:
//!   spawn like `home-core`, migrate a task to the AVX subset only on
//!   its first *observed* AVX license demand in a phase.
//! * `class-steer` — the hybrid-topology variant: marked futures spawn
//!   onto the *first* `p_cores` executor cores (P-cores lead the core id
//!   space on hybrid parts, matching
//!   [`crate::sched::PolicyKind::ClassNative`]), while unmarked futures
//!   may run anywhere — E-cores are a capacity pool, not a scalar jail.

/// Pluggable task-placement policy for [`super::TpcRuntime`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementSpec {
    /// Round-robin spawn, no AVX awareness (glommio default).
    HomeCore,
    /// Marked futures spawn/wake onto the last `avx_cores` executor
    /// cores; unmarked futures onto the scalar complement.
    AvxSteer { avx_cores: usize },
    /// Spawn anywhere; migrate to the AVX subset on first observed AVX
    /// demand (at most once per task per AVX phase).
    AvxSteerLazy { avx_cores: usize },
    /// Hybrid-native steering: marked futures onto the first `p_cores`
    /// executor cores (the P-cores), unmarked futures anywhere.
    ClassSteer { p_cores: usize },
}

impl PlacementSpec {
    /// Policy name as used in tables, configs and CLI flags.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementSpec::HomeCore => "home-core",
            PlacementSpec::AvxSteer { .. } => "avx-steer",
            PlacementSpec::AvxSteerLazy { .. } => "avx-steer-lazy",
            PlacementSpec::ClassSteer { .. } => "class-steer",
        }
    }

    /// Table label, including the AVX-core parameter.
    pub fn label(&self) -> String {
        match self {
            PlacementSpec::HomeCore => "home-core".to_string(),
            PlacementSpec::AvxSteer { avx_cores } => format!("avx-steer({avx_cores})"),
            PlacementSpec::AvxSteerLazy { avx_cores } => {
                format!("avx-steer-lazy({avx_cores})")
            }
            PlacementSpec::ClassSteer { p_cores } => format!("class-steer({p_cores})"),
        }
    }

    /// Parse a policy name; `avx_cores` parameterizes the steering arms.
    pub fn parse(s: &str, avx_cores: usize) -> anyhow::Result<Self> {
        match s {
            "home-core" => Ok(PlacementSpec::HomeCore),
            "avx-steer" => Ok(PlacementSpec::AvxSteer { avx_cores }),
            "avx-steer-lazy" => Ok(PlacementSpec::AvxSteerLazy { avx_cores }),
            "class-steer" => Ok(PlacementSpec::ClassSteer { p_cores: avx_cores }),
            other => anyhow::bail!(
                "tpc.placement = {other:?} (home-core|avx-steer|avx-steer-lazy|class-steer)"
            ),
        }
    }

    /// The size of the designated AVX subset (0 under `home-core`).
    pub fn avx_cores(&self) -> usize {
        match *self {
            PlacementSpec::HomeCore => 0,
            PlacementSpec::AvxSteer { avx_cores }
            | PlacementSpec::AvxSteerLazy { avx_cores } => avx_cores,
            PlacementSpec::ClassSteer { p_cores } => p_cores,
        }
    }

    /// Whether executor core `core` (of `n_cores`) belongs to the
    /// designated AVX subset. The steer variants use the same last-K
    /// convention as [`crate::sched::PolicyKind::is_avx_core`], so the
    /// runtime-level and kernel-level subsets line up in the
    /// head-to-head comparison; `class-steer` uses the *first*-K
    /// convention of [`crate::sched::PolicyKind::ClassNative`], since
    /// P-cores lead the core id space on hybrid machines.
    pub fn is_avx_core(&self, core: usize, n_cores: usize) -> bool {
        let k = self.avx_cores().min(n_cores);
        match self {
            PlacementSpec::ClassSteer { .. } => core < k,
            _ => k > 0 && core >= n_cores - k,
        }
    }

    /// The executor cores a task with the given mark may be *spawned*
    /// onto — the allowed set the placement property test pins.
    /// `avx-steer-lazy` spawns like `home-core` (everywhere); migration
    /// into the AVX subset happens later, on demand.
    pub fn allowed_cores(&self, marked: bool, n_cores: usize) -> Vec<usize> {
        match self {
            PlacementSpec::HomeCore | PlacementSpec::AvxSteerLazy { .. } => {
                (0..n_cores).collect()
            }
            PlacementSpec::AvxSteer { .. } => {
                let subset: Vec<usize> =
                    (0..n_cores).filter(|&c| self.is_avx_core(c, n_cores) == marked).collect();
                // A degenerate subset (avx_cores = 0 or ≥ n_cores) falls
                // back to all cores rather than an empty set.
                if subset.is_empty() {
                    (0..n_cores).collect()
                } else {
                    subset
                }
            }
            PlacementSpec::ClassSteer { .. } => {
                if marked {
                    // AVX work is confined to the P-cores — on a hybrid
                    // machine the E-cores cannot execute it at all.
                    let subset: Vec<usize> =
                        (0..n_cores).filter(|&c| self.is_avx_core(c, n_cores)).collect();
                    if subset.is_empty() {
                        (0..n_cores).collect()
                    } else {
                        subset
                    }
                } else {
                    // Scalar work uses the whole machine; the E-cores
                    // are extra capacity, not a dumping ground.
                    (0..n_cores).collect()
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn last_k_convention_matches_kernel_policy() {
        let spec = PlacementSpec::AvxSteer { avx_cores: 2 };
        let kernel = crate::sched::PolicyKind::CoreSpec { avx_cores: 2 };
        for core in 0..6 {
            assert_eq!(
                spec.is_avx_core(core, 6),
                kernel.is_avx_core(core, 6),
                "core {core}: runtime and kernel AVX subsets must agree"
            );
        }
        assert!(!spec.is_avx_core(3, 6));
        assert!(spec.is_avx_core(4, 6) && spec.is_avx_core(5, 6));
    }

    #[test]
    fn home_core_and_lazy_allow_every_core_at_spawn() {
        for spec in [PlacementSpec::HomeCore, PlacementSpec::AvxSteerLazy { avx_cores: 2 }] {
            for marked in [false, true] {
                assert_eq!(spec.allowed_cores(marked, 4), vec![0, 1, 2, 3], "{spec:?}");
            }
        }
    }

    #[test]
    fn avx_steer_partitions_the_cores() {
        let spec = PlacementSpec::AvxSteer { avx_cores: 2 };
        assert_eq!(spec.allowed_cores(true, 6), vec![4, 5]);
        assert_eq!(spec.allowed_cores(false, 6), vec![0, 1, 2, 3]);
        // Degenerate subsets fall back to all cores.
        let all = PlacementSpec::AvxSteer { avx_cores: 0 };
        assert_eq!(all.allowed_cores(true, 3), vec![0, 1, 2]);
        let everything = PlacementSpec::AvxSteer { avx_cores: 8 };
        assert_eq!(everything.allowed_cores(false, 3), vec![0, 1, 2]);
    }

    #[test]
    fn class_steer_uses_first_k_and_frees_scalar_work() {
        let spec = PlacementSpec::ClassSteer { p_cores: 2 };
        // First-K: the P-cores lead the id space, like ClassNative.
        let kernel = crate::sched::PolicyKind::ClassNative { p_cores: 2 };
        for core in 0..6 {
            assert_eq!(
                spec.is_avx_core(core, 6),
                kernel.is_avx_core(core, 6),
                "core {core}: class-steer must mirror the hardware partition"
            );
        }
        assert_eq!(spec.allowed_cores(true, 6), vec![0, 1]);
        // Scalar work may run anywhere — E-cores are capacity, not a jail.
        assert_eq!(spec.allowed_cores(false, 6), vec![0, 1, 2, 3, 4, 5]);
        // Degenerate P set falls back to all cores.
        let none = PlacementSpec::ClassSteer { p_cores: 0 };
        assert_eq!(none.allowed_cores(true, 3), vec![0, 1, 2]);
    }

    #[test]
    fn parse_roundtrips_names() {
        for spec in [
            PlacementSpec::HomeCore,
            PlacementSpec::AvxSteer { avx_cores: 2 },
            PlacementSpec::AvxSteerLazy { avx_cores: 2 },
            PlacementSpec::ClassSteer { p_cores: 2 },
        ] {
            assert_eq!(PlacementSpec::parse(spec.name(), 2).unwrap(), spec);
        }
        assert!(PlacementSpec::parse("steal-everything", 2).is_err());
        assert_eq!(
            PlacementSpec::AvxSteerLazy { avx_cores: 3 }.label(),
            "avx-steer-lazy(3)"
        );
    }
}
