//! Thread-per-core async executor model (glommio-style), layered on the
//! machine simulator: per-core task queues with shares and preemption
//! budgets ([`queue`]), completion batching ([`reactor`]), home-core
//! wakes ([`waker`]), and pluggable AVX-aware placement ([`placement`]).
//!
//! The paper mitigates AVX-induced frequency reduction in the *kernel*
//! scheduler. Thread-per-core runtimes do their own scheduling above the
//! kernel, so the same idea can be applied one layer up: steer
//! AVX-marked futures to a designated executor-core subset at
//! spawn/wake time (`avx-steer`, CoreSpec inside the runtime) or on
//! first observed AVX demand (`avx-steer-lazy`, the runtime analogue of
//! §6.1 fault-and-migrate). `repro runtimespec` compares the two layers
//! head to head.
//!
//! In the simulation, "executor core i" is worker task i: the web
//! workload runs thread-per-core (`workers == cores`), each worker owns
//! queue i, and the machine's scheduler affinity keeps worker i on one
//! physical core — so confining AVX work to K worker queues confines
//! the license damage to ~K physical cores. See `ExecutorTask` in
//! [`crate::workload::webserver`] for the serving loop.

pub mod placement;
pub mod queue;
pub mod reactor;
pub mod waker;

pub use placement::PlacementSpec;
pub use queue::{grant_budgets, TpcJob, TpcQueue};
pub use reactor::Reactor;
pub use waker::wake_core;

use crate::util::table::{fmt_f, Table};
use crate::workload::client::LoadMode;
use crate::workload::webserver::{run_webserver, WebCfg, WebRun};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Runtime configuration carried by [`LoadMode::Executor`].
#[derive(Clone, Debug, PartialEq)]
pub struct TpcParams {
    pub placement: PlacementSpec,
    /// Preemption quantum (instructions) split across cores by share;
    /// `u64::MAX` (the default) disables preemption entirely, which is
    /// also the configuration under which `home-core` on one worker is
    /// byte-identical to the plain open-loop server.
    pub quantum: u64,
    /// Per-core shares (empty = uniform). Shorter vectors repeat their
    /// last element; see [`TpcRuntime::new`].
    pub shares: Vec<u64>,
}

impl Default for TpcParams {
    fn default() -> Self {
        TpcParams { placement: PlacementSpec::HomeCore, quantum: u64::MAX, shares: Vec::new() }
    }
}

/// Counters the runtime accumulates over a run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TpcStats {
    /// Jobs placed (spawned futures).
    pub spawned: u64,
    /// Marked jobs whose spawn/wake target was chosen by AVX awareness.
    pub steered: u64,
    /// Lazy migrations (first AVX demand moved the task).
    pub migrations: u64,
    /// Budget-exhaustion yields.
    pub preemptions: u64,
    /// Wake-path requeues (preempted jobs returning to a queue).
    pub wakes: u64,
}

/// The per-core queue set + placement state for one run. `T` is the job
/// payload (the web workload uses a request plus its saved plan).
#[derive(Clone, Debug)]
pub struct TpcRuntime<T> {
    spec: PlacementSpec,
    n_cores: usize,
    queues: Vec<TpcQueue<T>>,
    budgets: Vec<u64>,
    /// Round-robin cursors: `[all cores, scalar subset, AVX subset]`.
    rr: [usize; 3],
    /// Cores with jobs requeued from *inside* a worker (preemption,
    /// lazy migration) — contexts with no machine handle. The driver
    /// drains these into the [`Reactor`] at the next external event, so
    /// a waiting worker is woken one arrival later (the model's wakeup
    /// latency). Open-loop arrivals guarantee the flush happens.
    pending_wakes: Vec<usize>,
    pub stats: TpcStats,
}

impl<T> TpcRuntime<T> {
    /// `shares` shorter than `n_cores` repeats the last element (empty =
    /// uniform share 1), so `shares = [4, 1]` means "core 0 gets 4, the
    /// rest get 1".
    pub fn new(spec: PlacementSpec, n_cores: usize, quantum: u64, shares: &[u64]) -> Self {
        let n = n_cores.max(1);
        let share_of = |i: usize| -> u64 {
            if shares.is_empty() {
                1
            } else {
                *shares.get(i).unwrap_or_else(|| shares.last().expect("non-empty"))
            }
        };
        let all: Vec<u64> = (0..n).map(share_of).collect();
        TpcRuntime {
            spec,
            n_cores: n,
            queues: all.iter().map(|&s| TpcQueue::new(s)).collect(),
            budgets: grant_budgets(quantum, &all),
            rr: [0; 3],
            pending_wakes: Vec::new(),
            stats: TpcStats::default(),
        }
    }

    pub fn n_cores(&self) -> usize {
        self.n_cores
    }

    pub fn placement(&self) -> &PlacementSpec {
        &self.spec
    }

    /// This core's per-stint instruction budget.
    pub fn budget(&self, core: usize) -> u64 {
        self.budgets[core]
    }

    /// Jobs currently queued across all cores (the overflow guard's
    /// occupancy measure).
    pub fn total_queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Place a newly spawned job; returns the chosen core. Round-robin
    /// within the placement's allowed set, one cursor per distinct set
    /// so policies with disjoint subsets stay independently fair.
    pub fn place(&mut self, marked: bool, payload: T) -> usize {
        let allowed = self.spec.allowed_cores(marked, self.n_cores);
        let slot = if allowed.len() == self.n_cores { 0 } else { 1 + marked as usize };
        let core = allowed[self.rr[slot] % allowed.len()];
        self.rr[slot] += 1;
        self.stats.spawned += 1;
        if marked
            && matches!(
                self.spec,
                PlacementSpec::AvxSteer { .. } | PlacementSpec::ClassSteer { .. }
            )
        {
            self.stats.steered += 1;
        }
        self.queues[core].push_back(TpcJob { payload, marked, home: core, in_avx_phase: false });
        core
    }

    /// Pop the next job on `core`'s queue.
    pub fn pop(&mut self, core: usize) -> Option<TpcJob<T>> {
        self.queues[core].pop_front()
    }

    /// Requeue a runnable job (preemption yield / simulated I/O wake)
    /// via the waker: home core under `home-core`/`avx-steer-lazy`,
    /// subset-corrected under `avx-steer`. Returns the target core and
    /// records it for the driver's next reactor flush.
    pub fn requeue_wake(&mut self, mut job: TpcJob<T>) -> usize {
        let target = wake_core(&self.spec, job.marked, job.home, self.n_cores);
        job.home = target;
        self.queues[target].push_back(job);
        self.stats.wakes += 1;
        self.pending_wakes.push(target);
        target
    }

    /// Where a task observing AVX demand on `core` should migrate under
    /// `avx-steer-lazy`: the next AVX-subset core (round-robin), or
    /// `None` when the policy is not lazy, the subset is degenerate, or
    /// the task already sits inside it.
    pub fn lazy_target(&mut self, core: usize) -> Option<usize> {
        let k = match self.spec {
            PlacementSpec::AvxSteerLazy { avx_cores } => avx_cores.min(self.n_cores),
            _ => return None,
        };
        if k == 0 || k == self.n_cores || self.spec.is_avx_core(core, self.n_cores) {
            return None;
        }
        let first = self.n_cores - k;
        let target = first + self.rr[2] % k;
        self.rr[2] += 1;
        Some(target)
    }

    /// Migrate a job to `target` (its new home) — the `avx-steer-lazy`
    /// move. Recorded for the next reactor flush like any other wake.
    pub fn migrate(&mut self, mut job: TpcJob<T>, target: usize) {
        job.home = target;
        self.queues[target].push_back(job);
        self.stats.migrations += 1;
        self.pending_wakes.push(target);
    }

    /// Drain the cores whose queues grew from inside a worker since the
    /// last external event (for the driver to feed into its reactor).
    pub fn take_pending_wakes(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.pending_wakes)
    }
}

/// One row of the `tpc_report` table: the same web scenario served
/// through the executor under one placement policy.
#[derive(Clone, Debug)]
pub struct TpcRow {
    pub placement: String,
    pub throughput_rps: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    /// Marked futures steered by the placement layer.
    pub steered: u64,
    /// Runtime-level lazy migrations.
    pub runtime_migrations: u64,
    /// Budget-exhaustion yields.
    pub preemptions: u64,
    /// Kernel-level migrations per second (the layer below).
    pub kernel_migrations_per_sec: f64,
    pub mj_per_req: f64,
}

impl TpcRow {
    pub fn from_run(placement: &PlacementSpec, run: &WebRun) -> Self {
        TpcRow {
            placement: placement.label(),
            throughput_rps: run.throughput_rps,
            p99_us: run.tail.p99_us,
            p999_us: run.tail.p999_us,
            steered: run.runtime_steered,
            runtime_migrations: run.runtime_migrations,
            preemptions: run.runtime_preemptions,
            kernel_migrations_per_sec: run.migrations_per_sec,
            mj_per_req: run.j_per_req() * 1e3,
        }
    }
}

/// Render the placement comparison (see `rust/tests/golden/tpc_report.txt`).
pub fn tpc_report(rows: &[TpcRow]) -> Table {
    let mut t = Table::new(
        "tpc_report",
        &[
            "placement", "req/s", "p99 µs", "p999 µs", "steered", "rt-migr", "preempt",
            "k-migr/s", "mJ/req",
        ],
    );
    for r in rows {
        t.row(&[
            r.placement.clone(),
            fmt_f(r.throughput_rps, 0),
            fmt_f(r.p99_us, 1),
            fmt_f(r.p999_us, 1),
            r.steered.to_string(),
            r.runtime_migrations.to_string(),
            r.preemptions.to_string(),
            fmt_f(r.kernel_migrations_per_sec, 1),
            fmt_f(r.mj_per_req, 3),
        ]);
    }
    t
}

/// Run the same open-loop web scenario through the executor under each
/// placement, across up to `threads` OS threads (one run per placement,
/// work-stolen over an atomic cursor, collected by index — byte-identical
/// at any thread count). `cfg.mode` must be open-loop; its arrival
/// process is served through [`LoadMode::Executor`] with `params`'
/// quantum/shares and the row's placement.
pub fn run_tpc(
    cfg: &WebCfg,
    params: &TpcParams,
    placements: &[PlacementSpec],
    threads: usize,
) -> Vec<TpcRow> {
    let process = cfg.mode.process().expect("run_tpc requires an open-loop LoadMode");
    let runs: Vec<WebCfg> = placements
        .iter()
        .map(|&placement| {
            let mut c = cfg.clone();
            c.mode = LoadMode::Executor {
                process: process.clone(),
                tpc: TpcParams { placement, ..params.clone() },
            };
            c
        })
        .collect();
    let n_threads = threads.max(1).min(runs.len().max(1));
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<WebRun>>> = runs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= runs.len() {
                    break;
                }
                *slots[i].lock().expect("slot poisoned") = Some(run_webserver(&runs[i]));
            });
        }
    });
    placements
        .iter()
        .zip(slots)
        .map(|(placement, slot)| {
            let run = slot
                .into_inner()
                .expect("slot poisoned")
                .expect("every placement claimed and executed");
            TpcRow::from_run(placement, &run)
        })
        .collect()
}

/// The three placement policies, comparison order.
pub fn all_placements(avx_cores: usize) -> [PlacementSpec; 3] {
    [
        PlacementSpec::HomeCore,
        PlacementSpec::AvxSteer { avx_cores },
        PlacementSpec::AvxSteerLazy { avx_cores },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn place_round_robins_within_allowed_sets() {
        let mut rt: TpcRuntime<u32> =
            TpcRuntime::new(PlacementSpec::AvxSteer { avx_cores: 2 }, 6, u64::MAX, &[]);
        assert_eq!(rt.place(false, 0), 0);
        assert_eq!(rt.place(false, 1), 1);
        assert_eq!(rt.place(true, 2), 4);
        assert_eq!(rt.place(true, 3), 5);
        assert_eq!(rt.place(true, 4), 4, "AVX cursor wraps within the subset");
        assert_eq!(rt.place(false, 5), 2, "scalar cursor unaffected by AVX spawns");
        assert_eq!(rt.stats.spawned, 6);
        assert_eq!(rt.stats.steered, 3);
        assert_eq!(rt.total_queued(), 6);
    }

    #[test]
    fn home_core_uses_one_cursor_for_both_marks() {
        let mut rt: TpcRuntime<u32> = TpcRuntime::new(PlacementSpec::HomeCore, 3, u64::MAX, &[]);
        assert_eq!(rt.place(false, 0), 0);
        assert_eq!(rt.place(true, 1), 1);
        assert_eq!(rt.place(false, 2), 2);
        assert_eq!(rt.place(true, 3), 0);
        assert_eq!(rt.stats.steered, 0, "home-core never steers");
    }

    #[test]
    fn lazy_target_only_fires_off_subset_under_lazy() {
        let mut rt: TpcRuntime<u32> =
            TpcRuntime::new(PlacementSpec::AvxSteerLazy { avx_cores: 2 }, 6, u64::MAX, &[]);
        assert_eq!(rt.lazy_target(0), Some(4));
        assert_eq!(rt.lazy_target(1), Some(5));
        assert_eq!(rt.lazy_target(2), Some(4), "target cursor wraps");
        assert_eq!(rt.lazy_target(5), None, "already inside the subset");
        let mut steer: TpcRuntime<u32> =
            TpcRuntime::new(PlacementSpec::AvxSteer { avx_cores: 2 }, 6, u64::MAX, &[]);
        assert_eq!(steer.lazy_target(0), None, "eager policy never migrates lazily");
        let mut home: TpcRuntime<u32> = TpcRuntime::new(PlacementSpec::HomeCore, 6, u64::MAX, &[]);
        assert_eq!(home.lazy_target(0), None);
    }

    #[test]
    fn migrate_and_wake_record_pending_notifications() {
        let mut rt: TpcRuntime<u32> =
            TpcRuntime::new(PlacementSpec::AvxSteerLazy { avx_cores: 1 }, 4, u64::MAX, &[]);
        rt.place(true, 7);
        let job = rt.pop(0).unwrap();
        assert_eq!(job.home, 0);
        rt.migrate(job, 3);
        let moved = rt.pop(3).unwrap();
        assert_eq!(moved.home, 3, "migration rehomes the job");
        assert_eq!(rt.stats.migrations, 1);
        let back = rt.requeue_wake(moved);
        assert_eq!(back, 3, "wake returns to the new home");
        assert_eq!(rt.take_pending_wakes(), vec![3, 3]);
        assert!(rt.take_pending_wakes().is_empty(), "drained");
    }

    #[test]
    fn shares_repeat_last_element_into_budgets() {
        let rt: TpcRuntime<u32> =
            TpcRuntime::new(PlacementSpec::HomeCore, 4, 100, &[4, 1]);
        // Shares [4, 1, 1, 1]: core 0 gets 4/7 of the quantum.
        assert_eq!(rt.budget(0), 57);
        assert_eq!(rt.budget(1), 14);
        assert_eq!(rt.budget(3), 14);
        let uniform: TpcRuntime<u32> = TpcRuntime::new(PlacementSpec::HomeCore, 2, 100, &[]);
        assert_eq!(uniform.budget(0), 50);
    }

    #[test]
    fn tpc_report_has_one_row_per_placement() {
        let rows: Vec<TpcRow> = all_placements(2)
            .iter()
            .enumerate()
            .map(|(i, p)| TpcRow {
                placement: p.label(),
                throughput_rps: 1000.0 + i as f64,
                p99_us: 10.0,
                p999_us: 20.0,
                steered: i as u64,
                runtime_migrations: 0,
                preemptions: 0,
                kernel_migrations_per_sec: 0.5,
                mj_per_req: 1.25,
            })
            .collect();
        let t = tpc_report(&rows);
        let text = t.render();
        assert!(text.contains("home-core"));
        assert!(text.contains("avx-steer(2)"));
        assert!(text.contains("avx-steer-lazy(2)"));
        assert!(text.contains("p999 µs"));
    }
}
