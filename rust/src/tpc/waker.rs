//! Wake-target selection: which executor core a task is requeued onto
//! when it becomes runnable again (I/O completion, preemption yield).
//!
//! The glommio model: a woken task returns to its *home* core — the core
//! it was spawned onto (or migrated to) — never a random one; locality
//! is the whole point of thread-per-core. The one exception is
//! `avx-steer`, whose contract covers wakes too ("spawned/*woken* onto a
//! designated core subset"): a marked task whose home has drifted
//! outside the AVX subset is steered back on wake.

use super::placement::PlacementSpec;

/// The core a task with the given mark and home core is requeued onto.
/// Under `home-core` (and `avx-steer-lazy`, which only moves tasks via
/// explicit migration) this is always the home core — the property
/// `rust/tests/tpc.rs` pins.
pub fn wake_core(spec: &PlacementSpec, marked: bool, home: usize, n_cores: usize) -> usize {
    let home = home.min(n_cores.saturating_sub(1));
    match spec {
        PlacementSpec::HomeCore | PlacementSpec::AvxSteerLazy { .. } => home,
        PlacementSpec::AvxSteer { .. } => {
            if spec.is_avx_core(home, n_cores) == marked || spec.avx_cores() == 0 {
                home
            } else {
                // Steer to the nearest core of the right kind: the first
                // AVX core for marked tasks, core 0 for unmarked ones.
                if marked {
                    n_cores - spec.avx_cores().min(n_cores)
                } else {
                    0
                }
            }
        }
        PlacementSpec::ClassSteer { .. } => {
            // Only marked tasks are constrained (to the P-cores, which
            // lead the id space); scalar homes anywhere are fine.
            if !marked || spec.is_avx_core(home, n_cores) || spec.avx_cores() == 0 {
                home
            } else {
                0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn home_core_always_requeues_home() {
        for home in 0..4 {
            for marked in [false, true] {
                assert_eq!(wake_core(&PlacementSpec::HomeCore, marked, home, 4), home);
                assert_eq!(
                    wake_core(&PlacementSpec::AvxSteerLazy { avx_cores: 2 }, marked, home, 4),
                    home
                );
            }
        }
    }

    #[test]
    fn avx_steer_keeps_wakes_inside_the_subset() {
        let spec = PlacementSpec::AvxSteer { avx_cores: 2 };
        // Homes already on the right side stay put.
        assert_eq!(wake_core(&spec, true, 5, 6), 5);
        assert_eq!(wake_core(&spec, false, 1, 6), 1);
        // Drifted homes are steered back.
        assert_eq!(wake_core(&spec, true, 1, 6), 4, "marked → first AVX core");
        assert_eq!(wake_core(&spec, false, 5, 6), 0, "unmarked → scalar side");
    }

    #[test]
    fn class_steer_only_constrains_marked_wakes() {
        let spec = PlacementSpec::ClassSteer { p_cores: 2 };
        // Marked task on a P-core stays; one drifted onto an E-core is
        // steered back to the first P-core.
        assert_eq!(wake_core(&spec, true, 1, 6), 1);
        assert_eq!(wake_core(&spec, true, 5, 6), 0);
        // Scalar tasks keep their home wherever it is.
        assert_eq!(wake_core(&spec, false, 5, 6), 5);
        assert_eq!(wake_core(&spec, false, 0, 6), 0);
    }

    #[test]
    fn out_of_range_home_is_clamped() {
        assert_eq!(wake_core(&PlacementSpec::HomeCore, false, 9, 4), 3);
    }
}
