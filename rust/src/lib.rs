//! `avxfreq` — reproduction of *Mechanism to Mitigate AVX-Induced Frequency
//! Reduction* (Gottschlag & Bellosa, 2018).
//!
//! The crate is organized bottom-up:
//!
//! * [`util`] — deterministic RNG, statistics, histograms, CLI/config parsing.
//! * [`sim`] — discrete-event simulation engine (nanosecond clock).
//! * [`isa`] — instruction-block IR: the "machine code" the simulated CPU runs.
//! * [`cpu`] — Skylake-SP core model: AVX power-license state machine,
//!   pluggable DVFS governors, per-core power/energy model, turbo
//!   tables, IPC model, PMU counters.
//! * [`sched`] — MuQSS baseline scheduler + the paper's core-specialization
//!   extension, plus baselines and the fault-and-migrate future-work feature.
//! * [`traffic`] — arrival processes (Poisson, bursty, diurnal,
//!   multi-tenant) and per-request tail-latency accounting (p50…p999,
//!   SLO-violation fraction).
//! * [`workload`] — nginx-like web server, wrk2-like client, crypto cost
//!   profiles, Fig-7 microbenchmark.
//! * [`faults`] — deterministic fault injection: seeded crash /
//!   degradation / link-fault / clock-skew schedules expanded to a
//!   [`faults::FaultTimeline`] the fleet layers consume; disabled
//!   configs take the literal fault-free code paths.
//! * [`fleet`] — cluster simulation: N machines behind a pluggable
//!   request router (round-robin, least-outstanding, AVX partition) with
//!   cross-machine latency aggregation — core specialization at
//!   datacenter scale.
//! * [`tpc`] — glommio-style thread-per-core executor model: per-core
//!   task queues with shares/preemption budgets, completion batching,
//!   home-core wakes, and AVX-aware placement (`home-core`,
//!   `avx-steer`, `avx-steer-lazy`) — the paper's mitigation applied at
//!   the runtime layer instead of the kernel.
//! * [`scenario`] — declarative scenario matrices (topology × policy ×
//!   workload × ISA × load × arrival × fleet-size × router) executed
//!   across OS threads, deterministically.
//! * [`analysis`] — static AVX-ratio analysis, THROTTLE flame graphs, LBR.
//! * [`runtime`] — PJRT client executing the AOT ChaCha20-Poly1305 kernels.
//! * [`metrics`] — run-level reporting and the matrix comparison table.
//! * [`bench`] — the `avxfreq bench` harness: times the canonical
//!   scenarios with the hot paths on and off, verifies output
//!   equivalence, and writes the `BENCH_*.json` perf trajectory.
//! * [`repro`] — one runner per paper figure/table.
//! * [`testkit`] — in-repo property-testing support (offline substitute for
//!   proptest).
//!
//! See `README.md` for the quickstart and `docs/ARCHITECTURE.md` for the
//! event-loop / scheduler control flow and the socket/NUMA hierarchy.

pub mod util;
pub mod sim;
pub mod isa;
pub mod cpu;
pub mod sched;
pub mod traffic;
pub mod workload;
pub mod faults;
pub mod fleet;
pub mod tpc;
pub mod scenario;
pub mod analysis;
pub mod runtime;
pub mod metrics;
pub mod bench;
pub mod repro;
pub mod testkit;
