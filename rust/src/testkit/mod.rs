//! In-repo property-testing kit (offline substitute for `proptest`).
//!
//! Provides seeded generators and a runner that, on failure, reports the
//! failing case number and seed so the case can be replayed exactly.
//! Shrinking is implemented for the common "vector of cases" shape:
//! the runner retries the failing predicate on progressively simpler
//! inputs produced by the strategy's `simplify`.

use crate::util::Rng;

/// A strategy produces values of `T` from an RNG, and can optionally
/// simplify a failing value toward a minimal counterexample.
pub trait Strategy {
    type Value;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate simplifications, most aggressive first. Default: none.
    fn simplify(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform integer in a range.
pub struct IntRange {
    pub lo: u64,
    pub hi: u64,
}

impl Strategy for IntRange {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.range(self.lo, self.hi)
    }
    fn simplify(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            // Aggressive-first geometric grid toward `lo`, then a unit step.
            // The grid arithmetic widens to u128: at `v - lo` near
            // `u64::MAX` the old `(v - lo) * k` wrapped, producing an
            // unsorted list whose duplicates survived the (adjacent-only)
            // dedup. And when `v - lo < 16` the grid collapses onto `lo`
            // outright — every candidate equal, each one burning a shrink
            // retry on a predicate we already know the answer to.
            let span = (v - self.lo) as u128;
            out.push(self.lo);
            for k in 1..16u64 {
                out.push(self.lo + (span * k as u128 / 16) as u64);
            }
            out.push(v - 1);
            // Candidates are nondecreasing now, so one adjacent pass
            // removes every duplicate while keeping aggressive-first order.
            out.dedup();
            out.retain(|c| c != v);
        }
        out
    }
}

/// Vector of values from an element strategy with length in `[0, max_len]`.
pub struct VecOf<S> {
    pub elem: S,
    pub max_len: usize,
}

impl<S: Strategy> Strategy for VecOf<S>
where
    S::Value: Clone,
{
    type Value = Vec<S::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<S::Value> {
        let len = rng.below(self.max_len as u64 + 1) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn simplify(&self, v: &Vec<S::Value>) -> Vec<Vec<S::Value>> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
            let mut shorter = v.clone();
            shorter.pop();
            out.push(shorter);
        }
        out
    }
}

/// Result of a property check run.
#[derive(Debug)]
pub struct Failure<T> {
    pub case: usize,
    pub seed: u64,
    pub value: T,
    pub message: String,
}

/// Run `predicate` on `cases` generated values. Returns the (shrunk)
/// failure if any. `predicate` returns `Err(msg)` to fail.
pub fn check<S, F>(seed: u64, cases: usize, strategy: &S, predicate: F) -> Result<(), Failure<S::Value>>
where
    S: Strategy,
    S::Value: Clone,
    F: Fn(&S::Value) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let case_seed = rng.next_u64();
        let mut case_rng = Rng::new(case_seed);
        let value = strategy.generate(&mut case_rng);
        if let Err(message) = predicate(&value) {
            // Shrink: greedily accept any simplification that still fails.
            let mut best = value;
            let mut best_msg = message;
            let mut progress = true;
            let mut budget = 200;
            while progress && budget > 0 {
                progress = false;
                for cand in strategy.simplify(&best) {
                    budget -= 1;
                    if let Err(m) = predicate(&cand) {
                        best = cand;
                        best_msg = m;
                        progress = true;
                        break;
                    }
                    if budget == 0 {
                        break;
                    }
                }
            }
            return Err(Failure { case, seed: case_seed, value: best, message: best_msg });
        }
    }
    Ok(())
}

/// Assert a property holds; panics with replay info otherwise.
pub fn assert_prop<S, F>(name: &str, seed: u64, cases: usize, strategy: &S, predicate: F)
where
    S: Strategy,
    S::Value: Clone + std::fmt::Debug,
    F: Fn(&S::Value) -> Result<(), String>,
{
    if let Err(f) = check(seed, cases, strategy, predicate) {
        panic!(
            "property `{name}` failed at case {} (replay seed {:#x}):\n  value: {:?}\n  {}",
            f.case, f.seed, f.value, f.message
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        assert_prop("reflexive", 1, 200, &IntRange { lo: 0, hi: 100 }, |v| {
            if *v < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let res = check(2, 500, &IntRange { lo: 0, hi: 1000 }, |v| {
            if *v < 500 {
                Ok(())
            } else {
                Err(format!("{v} too big"))
            }
        });
        let f = res.unwrap_err();
        assert!(f.value >= 500);
        // Shrinker should walk down toward the boundary.
        assert!(f.value <= 600, "shrunk to {}", f.value);
    }

    #[test]
    fn vec_strategy_shrinks_length() {
        let strat = VecOf { elem: IntRange { lo: 0, hi: 10 }, max_len: 64 };
        let res = check(3, 200, &strat, |v| {
            if v.len() < 4 {
                Ok(())
            } else {
                Err("long".into())
            }
        });
        let f = res.unwrap_err();
        assert!(f.value.len() >= 4 && f.value.len() <= 8, "shrunk len {}", f.value.len());
    }

    #[test]
    fn small_range_simplify_has_no_duplicates() {
        // v - lo < 16: the geometric grid collapses onto `lo`; the
        // candidate list must still be duplicate-free and aggressive-first.
        for (lo, hi, v) in [(10u64, 20u64, 14u64), (0, 4, 3), (100, 102, 101), (0, 1000, 7)] {
            let strat = IntRange { lo, hi };
            let cands = strat.simplify(&v);
            let mut seen = cands.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), cands.len(), "duplicates in {cands:?} for v={v}");
            assert!(cands.iter().all(|c| *c >= lo && *c < v), "bad candidate in {cands:?}");
            assert_eq!(cands.first(), Some(&lo), "most aggressive candidate first");
        }
        assert!(IntRange { lo: 5, hi: 9 }.simplify(&5).is_empty(), "lo itself cannot shrink");
    }

    #[test]
    fn huge_range_simplify_does_not_overflow() {
        let strat = IntRange { lo: 0, hi: u64::MAX };
        let v = u64::MAX - 1;
        let cands = strat.simplify(&v);
        // Monotone nondecreasing (sorted) implies the wrap-around bug is
        // gone and the adjacent dedup was sufficient.
        assert!(cands.windows(2).all(|w| w[0] < w[1]), "unsorted or duplicated: {cands:?}");
        assert!(cands.iter().all(|c| *c < v));
        assert!(cands.contains(&(v - 1)), "unit step must survive");
    }

    #[test]
    fn deterministic_replay() {
        let strat = IntRange { lo: 0, hi: 1_000_000 };
        let f1 = check(7, 100, &strat, |v| if v % 17 != 0 { Ok(()) } else { Err("x".into()) });
        let f2 = check(7, 100, &strat, |v| if v % 17 != 0 { Ok(()) } else { Err("x".into()) });
        match (f1, f2) {
            (Err(a), Err(b)) => {
                assert_eq!(a.case, b.case);
                assert_eq!(a.seed, b.seed);
            }
            _ => panic!("expected both to fail identically"),
        }
    }
}
