//! Fig 7: thread-migration overhead microbenchmark — overhead (%) vs
//! task-type changes per second, and the derived cost per switch pair.
//!
//! Paper: overhead scales with the change rate, 400–500 ns per
//! AVX↔scalar pair, <3% at 100 000 changes/s; the web server performs
//! ~55 000 changes/s.

use super::Repro;
use crate::util::table::{fmt_f, Table};
use crate::workload::microbench::overhead_point;

/// Loop lengths swept (instructions per iteration). An iteration is one
/// switch pair, so shorter loops → higher change rates.
pub fn sweep_lengths(quick: bool) -> Vec<u64> {
    if quick {
        vec![4_000_000, 1_000_000, 250_000, 60_000]
    } else {
        vec![8_000_000, 4_000_000, 2_000_000, 1_000_000, 500_000, 250_000, 120_000, 60_000, 30_000]
    }
}

pub fn run(quick: bool) -> Repro {
    let mut t = Table::new(
        "Fig 7 — core-specialization overhead vs task-type-change rate (26 threads / 12 cores)",
        &["loop insns", "type changes/s", "overhead %", "ns per switch pair"],
    );
    let mut notes = Vec::new();
    let mut pair_costs = Vec::new();
    for len in sweep_lengths(quick) {
        let p = overhead_point(len);
        if p.type_changes_per_sec > 0.0 && p.overhead_pct > 0.0 {
            pair_costs.push(p.ns_per_switch_pair);
        }
        t.row(&[
            len.to_string(),
            fmt_f(p.type_changes_per_sec, 0),
            fmt_f(p.overhead_pct, 2),
            fmt_f(p.ns_per_switch_pair, 0),
        ]);
    }
    if !pair_costs.is_empty() {
        let mean = pair_costs.iter().sum::<f64>() / pair_costs.len() as f64;
        notes.push(format!(
            "mean cost per AVX↔scalar switch pair: {mean:.0} ns (paper: 400–500 ns)"
        ));
    }
    notes.push("paper reference: <3% overhead at 100 000 type changes/s".to_string());
    Repro { id: "fig7", tables: vec![t], notes }
}
