//! Fig 2: workload sensitivity to the SIMD instruction set, normalized to
//! SSE4 — three workload groups:
//!
//! * crypto microbenchmark: AVX-512 fastest (vectorization wins in
//!   isolation),
//! * web server, uncompressed page: AVX2 best (crypto-heavy requests,
//!   small license tax),
//! * web server, compressed page: SSE4 best (scalar-heavy requests taxed
//!   by the 2 ms holds).

use super::cryptobench::throughput_gbps;
use super::Repro;
use crate::sched::PolicyKind;
use crate::sim::{MS, SEC};
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;
use crate::workload::webserver::{run_webserver, WebCfg};

fn web(isa: Isa, compress: bool, quick: bool, seed: u64) -> f64 {
    let mut cfg = if compress {
        WebCfg::paper_default(isa, PolicyKind::Unmodified)
    } else {
        WebCfg::uncompressed(isa, PolicyKind::Unmodified)
    };
    cfg.seed = seed;
    if quick {
        cfg.warmup = 300 * MS;
        cfg.measure = SEC;
    }
    run_webserver(&cfg).throughput_rps
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let mut t = Table::new(
        "Fig 2 — workload sensitivity to SIMD instruction set (normalized to SSE4)",
        &["workload", "sse4", "avx2", "avx512", "winner"],
    );
    let mut notes = Vec::new();

    // Microbenchmark (crypto in isolation).
    let micro: Vec<f64> = Isa::all().iter().map(|i| throughput_gbps(*i, quick, seed)).collect();
    // Web server variants.
    let plain: Vec<f64> = Isa::all().iter().map(|i| web(*i, false, quick, seed)).collect();
    let comp: Vec<f64> = Isa::all().iter().map(|i| web(*i, true, quick, seed)).collect();

    for (name, vals) in [
        ("crypto microbenchmark", &micro),
        ("web, uncompressed", &plain),
        ("web, compressed", &comp),
    ] {
        let norm: Vec<f64> = vals.iter().map(|v| v / vals[0]).collect();
        let winner = Isa::all()[norm
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0]
            .name();
        t.row(&[
            name.to_string(),
            fmt_f(norm[0], 3),
            fmt_f(norm[1], 3),
            fmt_f(norm[2], 3),
            winner.to_string(),
        ]);
    }
    notes.push(
        "paper shape: microbench → AVX-512 wins; uncompressed web → AVX2 wins; \
         compressed web → SSE4 wins (AVX2 −4.2%, AVX-512 −11.2%)"
            .to_string(),
    );
    Repro { id: "fig2", tables: vec![t], notes }
}
