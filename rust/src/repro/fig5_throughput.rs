//! Fig 5: nginx throughput with OpenSSL compiled for SSE4/AVX2/AVX-512,
//! unmodified scheduler vs core specialization (2 of 12 cores AVX).
//!
//! Paper numbers: unmodified −4.2% (AVX2) and −11.2% (AVX-512) vs SSE4;
//! with core specialization −1.1% and −3.2% — reductions of 74% / 71%.

use super::Repro;
use crate::sched::PolicyKind;
use crate::sim::{MS, SEC};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;
use crate::workload::webserver::{run_webserver, WebCfg, WebRun};

pub const PAPER_DROP_UNMOD: [f64; 3] = [0.0, -4.2, -11.2];
pub const PAPER_DROP_SPEC: [f64; 3] = [0.0, -1.1, -3.2];

/// The six runs of the figure. Exposed for fig6/ipc reuse.
pub fn run_grid(quick: bool, seed: u64) -> Vec<(Isa, &'static str, WebRun)> {
    let mut out = Vec::new();
    for isa in Isa::all() {
        for (label, policy) in [
            ("unmodified", PolicyKind::Unmodified),
            ("core-spec", PolicyKind::CoreSpec { avx_cores: 2 }),
        ] {
            let mut cfg = WebCfg::paper_default(isa, policy);
            cfg.seed = seed;
            if quick {
                cfg.warmup = 300 * MS;
                cfg.measure = SEC;
            }
            out.push((isa, label, run_webserver(&cfg)));
        }
    }
    out
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let grid = run_grid(quick, seed);
    let base = grid
        .iter()
        .find(|(isa, label, _)| *isa == Isa::Sse4 && *label == "unmodified")
        .map(|(_, _, r)| r.throughput_rps)
        .unwrap();

    let mut t = Table::new(
        "Fig 5 — nginx HTTPS throughput (compressed page), 12 cores, 2 AVX cores",
        &["isa", "scheduler", "req/s", "vs SSE4 unmod", "paper", "type-chg/s"],
    );
    let mut notes = Vec::new();
    for (isa, label, r) in &grid {
        let drop = pct_change(base, r.throughput_rps);
        let paper = match (isa, *label) {
            (Isa::Sse4, "unmodified") => 0.0,
            (Isa::Avx2, "unmodified") => PAPER_DROP_UNMOD[1],
            (Isa::Avx512, "unmodified") => PAPER_DROP_UNMOD[2],
            (Isa::Sse4, _) => 0.0,
            (Isa::Avx2, _) => PAPER_DROP_SPEC[1],
            (Isa::Avx512, _) => PAPER_DROP_SPEC[2],
        };
        t.row(&[
            isa.name().to_string(),
            label.to_string(),
            fmt_f(r.throughput_rps, 0),
            format!("{drop:+.1}%"),
            format!("{paper:+.1}%"),
            fmt_f(r.type_changes_per_sec, 0),
        ]);
    }

    // Headline: variability reduction.
    let get = |isa: Isa, label: &str| {
        grid.iter()
            .find(|(i, l, _)| *i == isa && *l == label)
            .map(|(_, _, r)| r.throughput_rps)
            .unwrap()
    };
    for isa in [Isa::Avx2, Isa::Avx512] {
        let d_unmod = pct_change(base, get(isa, "unmodified"));
        let d_spec = pct_change(get(Isa::Sse4, "core-spec"), get(isa, "core-spec"));
        let reduction = if d_unmod < 0.0 { (1.0 - d_spec / d_unmod) * 100.0 } else { 0.0 };
        notes.push(format!(
            "{}: drop {:.1}% → {:.1}% with core specialization ({:.0}% reduction; paper: 74%/71%)",
            isa.name(),
            d_unmod,
            d_spec,
            reduction
        ));
    }
    notes.push(format!(
        "webserver type-change reference rate in the paper: 55 000/s; ours: {:.0}/s",
        grid.iter()
            .find(|(i, l, _)| *i == Isa::Avx512 && *l == "core-spec")
            .map(|(_, _, r)| r.type_changes_per_sec)
            .unwrap()
    ));
    Repro { id: "fig5", tables: vec![t], notes }
}

/// Multi-seed variant: repeats the grid over `n_seeds` seeds and reports
/// mean ± 95% CI of the throughput drops (`avxfreq repro fig5 --seeds N`).
pub fn run_multi(quick: bool, base_seed: u64, n_seeds: usize) -> Repro {
    use crate::util::Summary;
    let mut drops: std::collections::BTreeMap<(&str, &str), Summary> = Default::default();
    for i in 0..n_seeds {
        let grid = run_grid(quick, base_seed.wrapping_add(i as u64 * 0x9E37));
        let base = grid
            .iter()
            .find(|(isa, label, _)| *isa == Isa::Sse4 && *label == "unmodified")
            .map(|(_, _, r)| r.throughput_rps)
            .unwrap();
        for (isa, label, r) in &grid {
            drops
                .entry((isa.name(), label))
                .or_insert_with(Summary::new)
                .add(pct_change(base, r.throughput_rps));
        }
    }
    let mut t = Table::new(
        &format!("Fig 5 — throughput drop vs SSE4/unmodified, {n_seeds} seeds (mean ± 95% CI)"),
        &["isa", "scheduler", "drop %", "95% CI", "paper"],
    );
    for ((isa, label), s) in &drops {
        let paper = match (*isa, *label) {
            ("avx2", "unmodified") => PAPER_DROP_UNMOD[1],
            ("avx512", "unmodified") => PAPER_DROP_UNMOD[2],
            ("avx2", "core-spec") => PAPER_DROP_SPEC[1],
            ("avx512", "core-spec") => PAPER_DROP_SPEC[2],
            _ => 0.0,
        };
        t.row(&[
            isa.to_string(),
            label.to_string(),
            format!("{:+.2}", s.mean()),
            format!("±{:.2}", s.ci95()),
            format!("{paper:+.1}"),
        ]);
    }
    let notes = vec![format!(
        "seeds {base_seed:#x}+k·0x9E37, k<{n_seeds}; CI from the normal approximation"
    )];
    Repro { id: "fig5_seeds", tables: vec![t], notes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "multi-second simulation; run with --ignored or via `avxfreq repro fig5`"]
    fn shape_matches_paper() {
        let grid = run_grid(true, 11);
        let get = |isa: Isa, label: &str| {
            grid.iter()
                .find(|(i, l, _)| *i == isa && *l == label)
                .map(|(_, _, r)| r.throughput_rps)
                .unwrap()
        };
        let base = get(Isa::Sse4, "unmodified");
        let avx512_unmod = pct_change(base, get(Isa::Avx512, "unmodified"));
        let avx512_spec = pct_change(get(Isa::Sse4, "core-spec"), get(Isa::Avx512, "core-spec"));
        assert!(avx512_unmod < -5.0, "AVX-512 must hurt unmodified: {avx512_unmod:.1}%");
        assert!(
            avx512_spec > avx512_unmod * 0.6,
            "core-spec must recover most of the drop: {avx512_spec:.1}% vs {avx512_unmod:.1}%"
        );
    }
}
