//! §4.2: instructions-per-request and IPC with/without core
//! specialization on the SSE4 build.
//!
//! Paper: +0.7% instructions per request (annotation syscalls and extra
//! scheduler invocations) but also +0.7% IPC — the smaller per-core code
//! footprint reduces branch mispredictions enough to pay for the
//! overhead.

use super::Repro;
use crate::sched::PolicyKind;
use crate::sim::{MS, SEC};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;
use crate::workload::webserver::{run_webserver_machine, WebCfg};

pub fn run(quick: bool, seed: u64) -> Repro {
    let mut rows = Vec::new();
    for (label, policy) in [
        ("unmodified", PolicyKind::Unmodified),
        ("core-spec", PolicyKind::CoreSpec { avx_cores: 2 }),
    ] {
        let mut cfg = WebCfg::paper_default(Isa::Sse4, policy);
        cfg.seed = seed;
        if quick {
            cfg.warmup = 300 * MS;
            cfg.measure = SEC;
        }
        let (run, m) = run_webserver_machine(&cfg);
        let total = m.total_perf();
        rows.push((label, run, total));
    }
    let (_, base_run, base_perf) = &rows[0];
    let (_, spec_run, spec_perf) = &rows[1];

    let mut t = Table::new(
        "§4.2 — SSE4 build: instruction and IPC effects of core specialization",
        &["metric", "unmodified", "core-spec", "delta", "paper"],
    );
    t.row(&[
        "instructions / request".into(),
        fmt_f(base_run.insns_per_req, 0),
        fmt_f(spec_run.insns_per_req, 0),
        format!("{:+.2}%", pct_change(base_run.insns_per_req, spec_run.insns_per_req)),
        "+0.7%".into(),
    ]);
    t.row(&[
        "IPC".into(),
        fmt_f(base_perf.ipc(), 3),
        fmt_f(spec_perf.ipc(), 3),
        format!("{:+.2}%", pct_change(base_perf.ipc(), spec_perf.ipc())),
        "+0.7%".into(),
    ]);
    let base_mr = base_perf.mispredicts as f64 / base_perf.branches.max(1) as f64;
    let spec_mr = spec_perf.mispredicts as f64 / spec_perf.branches.max(1) as f64;
    t.row(&[
        "branch mispredict rate".into(),
        format!("{:.3}%", base_mr * 100.0),
        format!("{:.3}%", spec_mr * 100.0),
        format!("{:+.2}%", pct_change(base_mr, spec_mr)),
        "reduced (VTune)".into(),
    ]);
    t.row(&[
        "throughput (req/s)".into(),
        fmt_f(base_run.throughput_rps, 0),
        fmt_f(spec_run.throughput_rps, 0),
        format!("{:+.2}%", pct_change(base_run.throughput_rps, spec_run.throughput_rps)),
        "≈0 (SSE4 unaffected)".into(),
    ]);

    let notes = vec![
        "mechanism: restricting the set of functions per core shrinks the branch-history \
         footprint; the misprediction reduction offsets the annotation/migration overhead"
            .to_string(),
    ];
    Repro { id: "ipc", tables: vec![t], notes }
}
