//! Fig 6: average frequency of the server cores for the Fig 5 runs.
//!
//! Paper numbers: AVX2 drop 4.4% → 1.8%, AVX-512 drop 11.4% → 4.0%.

use super::fig5_throughput::run_grid;
use super::Repro;
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;

pub const PAPER_FREQ_DROP_UNMOD: [f64; 3] = [0.0, -4.4, -11.4];
pub const PAPER_FREQ_DROP_SPEC: [f64; 3] = [0.0, -1.8, -4.0];

pub fn run(quick: bool, seed: u64) -> Repro {
    let grid = run_grid(quick, seed);
    let base = grid
        .iter()
        .find(|(isa, label, _)| *isa == Isa::Sse4 && *label == "unmodified")
        .map(|(_, _, r)| r.avg_ghz)
        .unwrap();

    let mut t = Table::new(
        "Fig 6 — average busy frequency of the 12 nginx cores",
        &["isa", "scheduler", "avg GHz", "vs SSE4 unmod", "paper", "L0/L1/L2 time share"],
    );
    let mut notes = Vec::new();
    for (isa, label, r) in &grid {
        let drop = pct_change(base, r.avg_ghz);
        let paper = match (isa, *label) {
            (Isa::Avx2, "unmodified") => PAPER_FREQ_DROP_UNMOD[1],
            (Isa::Avx512, "unmodified") => PAPER_FREQ_DROP_UNMOD[2],
            (Isa::Avx2, _) => PAPER_FREQ_DROP_SPEC[1],
            (Isa::Avx512, _) => PAPER_FREQ_DROP_SPEC[2],
            _ => 0.0,
        };
        t.row(&[
            isa.name().to_string(),
            label.to_string(),
            fmt_f(r.avg_ghz, 3),
            format!("{drop:+.1}%"),
            format!("{paper:+.1}%"),
            format!(
                "{:.0}/{:.0}/{:.0}%",
                r.license_share[0] * 100.0,
                r.license_share[1] * 100.0,
                r.license_share[2] * 100.0
            ),
        ]);
    }
    notes.push(
        "paper note: core specialization concentrates AVX on 2 cores, so the frequency \
         win is smaller than 6x — the unmodified server already runs at full speed part \
         of the time"
            .to_string(),
    );
    Repro { id: "fig6", tables: vec![t], notes }
}
