//! Fig 3: the scheduling asymmetry that motivates the policy (§2.1).
//!
//! (a) A core that mostly executes AVX code intermittently runs scalar
//!     code: only that short scalar section is slowed.
//! (b) A core that mostly executes scalar code intermittently runs AVX
//!     code: *every* burst taxes ≥2 ms of subsequent scalar work.
//!
//! The experiment interleaves the same two instruction streams both ways
//! on a single core and reports how much scalar work ran below full
//! frequency — the hatched regions of the figure.

use super::Repro;
use crate::cpu::freq::FreqParams;
use crate::cpu::ipc::IpcParams;
use crate::cpu::turbo::TurboTable;
use crate::cpu::{Core, License};
use crate::isa::block::{Block, ClassMix, InsnClass};
use crate::sim::{Time, MS};
use crate::util::table::{fmt_f, Table};

struct Outcome {
    scalar_ns_total: Time,
    scalar_ns_slowed: Time,
    avx_ns_total: Time,
}

/// Run `duty_avx` fraction of AVX work against scalar work, interleaved
/// at `burst` granularity, for `duration`.
fn interleave(duty_avx: f64, burst: Time, duration: Time) -> Outcome {
    let turbo = TurboTable::xeon_gold_6130_no_cstates();
    let mut core = Core::new(0, FreqParams::default(), IpcParams::default());
    let scalar = Block { mix: ClassMix::scalar(10_000), mem_ops: 0, branches: 150, license_exempt: false };
    let avx =
        Block { mix: ClassMix::of(InsnClass::Avx512Heavy, 10_000), mem_ops: 0, branches: 50, license_exempt: false };
    let mut t: Time = 0;
    let mut out = Outcome { scalar_ns_total: 0, scalar_ns_slowed: 0, avx_ns_total: 0 };
    let mut phase_avx = duty_avx >= 0.5; // start with the majority phase
    while t < duration {
        let phase_len =
            if phase_avx { (burst as f64 * duty_avx) as Time } else { (burst as f64 * (1.0 - duty_avx)) as Time };
        let phase_end = t + phase_len.max(1);
        while t < phase_end {
            let block = if phase_avx { &avx } else { &scalar };
            let o = core.run_block(t, block, phase_avx as u64, 16, &turbo);
            if phase_avx {
                out.avx_ns_total += o.ns;
            } else {
                out.scalar_ns_total += o.ns;
                if o.license != License::L0 {
                    out.scalar_ns_slowed += o.ns;
                }
            }
            t += o.ns;
        }
        phase_avx = !phase_avx;
    }
    out
}

pub fn run() -> Repro {
    // (a) AVX core, 90% AVX duty, occasionally scalar.
    let a = interleave(0.9, 4 * MS, 400 * MS);
    // (b) scalar core, 10% AVX duty, occasionally AVX.
    let b = interleave(0.1, 4 * MS, 400 * MS);

    let mut t = Table::new(
        "Fig 3 — asymmetry of mixing scalar and AVX work on one core",
        &["scenario", "scalar time", "scalar time at reduced freq", "fraction slowed"],
    );
    let frac = |o: &Outcome| o.scalar_ns_slowed as f64 / o.scalar_ns_total.max(1) as f64;
    t.row(&[
        "(a) AVX core runs occasional scalar".into(),
        crate::sim::fmt_time(a.scalar_ns_total),
        crate::sim::fmt_time(a.scalar_ns_slowed),
        fmt_f(frac(&a) * 100.0, 1) + "%",
    ]);
    t.row(&[
        "(b) scalar core runs occasional AVX".into(),
        crate::sim::fmt_time(b.scalar_ns_total),
        crate::sim::fmt_time(b.scalar_ns_slowed),
        fmt_f(frac(&b) * 100.0, 1) + "%",
    ]);
    let notes = vec![format!(
        "asymmetry: in (a) {:.0}% of the (already small) scalar share is slowed — harmless; \
         in (b) {:.0}% of the dominant scalar share is slowed because every AVX burst taxes \
         ≥2 ms — this is why scalar cores must never run AVX tasks while AVX cores may run \
         scalar tasks",
        frac(&a) * 100.0,
        frac(&b) * 100.0
    )];
    Repro { id: "fig3", tables: vec![t], notes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_core_with_avx_bursts_suffers_more() {
        let a = interleave(0.9, 4 * MS, 200 * MS);
        let b = interleave(0.1, 4 * MS, 200 * MS);
        let _fa = a.scalar_ns_slowed as f64 / a.scalar_ns_total as f64;
        let fb = b.scalar_ns_slowed as f64 / b.scalar_ns_total as f64;
        // In (b), most scalar time is inside a 2ms hold after each burst.
        assert!(fb > 0.5, "case (b) slowed fraction {fb}");
        // The *absolute* slowed scalar time must be far larger in (b).
        assert!(
            b.scalar_ns_slowed > 3 * a.scalar_ns_slowed,
            "asymmetry: {} vs {}",
            b.scalar_ns_slowed,
            a.scalar_ns_slowed
        );
    }
}
