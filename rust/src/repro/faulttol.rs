//! Fault tolerance — closed vs open loop under an identical
//! deterministic fault schedule.
//!
//! The robustness question behind the fault layer: when a machine
//! crashes mid-run, a frequency domain degrades, and the network drops
//! and delays requests, how much of the tail damage does the
//! closed-loop balancer (timeout feedback, retry, ejection,
//! readmission) claw back versus the open-loop front-end that keeps
//! routing into the blast radius? Both arms consume the *same*
//! [`crate::faults::FaultTimeline`] — it is a pure function of the
//! fault config, the measurement window, the machine count, and the
//! fleet seed, none of which differ between the arms — so the
//! comparison isolates the control loop, not the luck of the schedule.
//!
//! Three runs per {policy × governor} combination:
//!
//! * **clean** — open loop, no faults: the undamaged baseline;
//! * **open+faults** — the chaos preset with the open-loop front-end:
//!   full damage;
//! * **closed+faults** — the same chaos schedule with the closed-loop
//!   balancer: whatever damage feedback cannot recover.
//!
//! `recovered %` is the fraction of the fault-induced p99 inflation the
//! closed loop undoes: `(open_fault − closed_fault) ÷ (open_fault −
//! clean)`. The grid crosses {unmodified, core-spec} × {intel-legacy,
//! dim-silicon} so the recovery claim is checked both with and without
//! the paper's mitigation and under both frequency models.

use super::Repro;
use crate::cpu::GovernorSpec;
use crate::faults::FaultsCfg;
use crate::fleet::{run_hier_fleet, BalancerCfg, HierFleetCfg, RouterSpec};
use crate::sched::PolicyKind;
use crate::util::table::{fmt_f, Table};

/// One {policy × governor} row of the faulttol table, separated from
/// the runner so the golden-file test can pin the formatting on
/// synthetic values (same pattern as
/// [`crate::repro::fleetscale::ScaleRow`]).
#[derive(Clone, Debug)]
pub struct TolRow {
    /// Machine-policy label.
    pub policy: String,
    /// DVFS governor label.
    pub governor: String,
    /// Cluster p99 of the fault-free open-loop baseline (µs).
    pub clean_p99_us: f64,
    /// Cluster p99 under the chaos schedule, open loop (µs).
    pub open_fault_p99_us: f64,
    /// Cluster p99 under the same schedule, closed loop (µs).
    pub closed_fault_p99_us: f64,
    /// Requests lost to crash dark windows in the closed-loop run.
    pub lost: u64,
    /// Fault-victim retries the closed loop issued.
    pub retries: u64,
    /// Epochs crash-ejected machines spent unhealthy before
    /// readmission (MTTR, closed loop).
    pub mttr_epochs: u64,
    /// Fraction of the fault-induced p99 inflation the closed loop
    /// recovered, in percent (see [`recovered_pct`]).
    pub recovered_pct: f64,
}

/// `(open_fault − closed_fault) ÷ (open_fault − clean)` as a
/// percentage, clamped to 0 when the faults did not inflate the tail
/// (no damage → nothing to recover).
pub fn recovered_pct(clean: f64, open_fault: f64, closed_fault: f64) -> f64 {
    let damage = open_fault - clean;
    if damage <= f64::EPSILON {
        return 0.0;
    }
    (open_fault - closed_fault) / damage * 100.0
}

/// The faulttol comparison table (formatting contract pinned by
/// `rust/tests/golden/faulttol_report.txt`).
pub fn table(rows: &[TolRow]) -> Table {
    let mut t = Table::new(
        "Fault tolerance — closed vs open loop under an identical fault schedule",
        &[
            "policy", "governor", "clean p99 µs", "open+faults µs", "closed+faults µs",
            "lost", "retries", "mttr ep", "recovered %",
        ],
    );
    for r in rows {
        t.row(&[
            r.policy.clone(),
            r.governor.clone(),
            fmt_f(r.clean_p99_us, 0),
            fmt_f(r.open_fault_p99_us, 0),
            fmt_f(r.closed_fault_p99_us, 0),
            r.lost.to_string(),
            r.retries.to_string(),
            r.mttr_epochs.to_string(),
            fmt_f(r.recovered_pct, 1),
        ]);
    }
    t
}

/// The hierarchical fleet behind one faulttol leg (exposed for tests):
/// fleetvar's bursty multi-tenant machines with the policy and governor
/// overridden, racks of 4, and — on the fault legs — the chaos preset
/// over the run's measurement window. Open and closed legs built from
/// the same `(policy, governor, seed)` share their fleet seed, machine
/// count, and window, so [`HierFleetCfg::fault_timeline`] expands to
/// the identical schedule in both.
pub fn hier_cfg(
    policy: PolicyKind,
    governor: GovernorSpec,
    closed: bool,
    faulted: bool,
    quick: bool,
    seed: u64,
) -> HierFleetCfg {
    let mut fleet = super::fleetvar::fleet_cfg(RouterSpec::RoundRobin, quick, seed);
    fleet.cfg.policy = policy;
    fleet.cfg.governor = governor;
    let bal = if closed { BalancerCfg::closed() } else { BalancerCfg::default() };
    let mut h = HierFleetCfg::new(fleet, bal);
    h.machines_per_rack = 4;
    if faulted {
        h.faults = FaultsCfg::chaos(h.fleet.cfg.measure, h.fleet.machines.max(1));
    }
    h
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let policies: &[(&str, PolicyKind)] = &[
        ("unmodified", PolicyKind::Unmodified),
        ("core-spec(2)", PolicyKind::CoreSpec { avx_cores: 2 }),
    ];
    let governors = [GovernorSpec::IntelLegacy, GovernorSpec::DimSilicon];
    let mut rows = Vec::new();
    for &(plabel, policy) in policies {
        for governor in governors {
            eprintln!(
                "[avxfreq] faulttol: {plabel}/{} — clean, open+faults, closed+faults…",
                governor.name()
            );
            let clean = run_hier_fleet(&hier_cfg(policy, governor, false, false, quick, seed), threads);
            let open = run_hier_fleet(&hier_cfg(policy, governor, false, true, quick, seed), threads);
            let closed = run_hier_fleet(&hier_cfg(policy, governor, true, true, quick, seed), threads);
            rows.push(TolRow {
                policy: plabel.to_string(),
                governor: governor.name().to_string(),
                clean_p99_us: clean.tail.p99_us,
                open_fault_p99_us: open.tail.p99_us,
                closed_fault_p99_us: closed.tail.p99_us,
                lost: closed.fault_outcomes.lost_to_crash,
                retries: closed.fault_outcomes.fault_retries,
                mttr_epochs: closed.fault_outcomes.recovery_epochs,
                recovered_pct: recovered_pct(
                    clean.tail.p99_us,
                    open.tail.p99_us,
                    closed.tail.p99_us,
                ),
            });
        }
    }

    let best = rows
        .iter()
        .cloned()
        .reduce(|a, b| if b.recovered_pct > a.recovered_pct { b } else { a })
        .expect("grid is non-empty");
    let worst = rows
        .iter()
        .cloned()
        .reduce(|a, b| if b.recovered_pct < a.recovered_pct { b } else { a })
        .expect("grid is non-empty");
    let notes = vec![
        format!(
            "both arms consume the identical fault timeline (pure function of config, \
             window, machine count, and fleet seed), so recovered % isolates the \
             control loop: best {}/{} at {:.1}%, worst {}/{} at {:.1}%",
            best.policy, best.governor, best.recovered_pct,
            worst.policy, worst.governor, worst.recovered_pct,
        ),
        format!(
            "closed-loop mechanics under the chaos schedule: {} requests lost to the \
             crash dark window, {} fault-victim retries issued, {} epochs of \
             crash-ejection before readmission — the recovery is timeout feedback + \
             retry + ejection steering traffic off the blast radius, not schedule luck",
            best.lost, best.retries, best.mttr_epochs,
        ),
    ];
    Repro { id: "faulttol", tables: vec![table(&rows)], notes }
}
