//! Fig 5 restated as tail damage: the nginx experiment's headline is a
//! mean-throughput drop (−11.2% for AVX-512), but the harm that
//! motivates core specialization is tail-side — the scalar majority of
//! requests queues behind a frequency-reduced machine long before the
//! mean moves. This runner sweeps the paper's single-socket machine over
//! {unmodified, core specialization} × {sse4, avx512} × ≥3 load levels ×
//! ≥2 arrival processes (Poisson and mean-preserving bursts) and reports
//! each cell's **p99 degradation vs the same-scheduler, same-load,
//! same-arrival SSE4 cell**, plus p999 and the SLO-violation fraction.
//!
//! Being a scenario matrix, the run is deterministic for a given seed at
//! any OS-thread count (byte-identical tables).

use super::Repro;
use crate::scenario::{ArrivalSpec, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use crate::sim::{MS, SEC};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;

/// Build the sweep this figure runs (exposed for tests).
pub fn matrix(quick: bool, seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(seed);
    m.topologies = vec![TopologySpec::single_socket_paper()];
    m.policies = vec![PolicySpec::Unmodified, PolicySpec::CoreSpec { avx_cores: 2 }];
    m.workloads = vec![WorkloadSpec::compressed_page()];
    m.isas = vec![Isa::Sse4, Isa::Avx512];
    m.loads = vec![0.6, 0.85, 1.1];
    m.arrivals = vec![ArrivalSpec::Poisson, ArrivalSpec::bursty_default()];
    if quick {
        m.warmup = 200 * MS;
        m.measure = 600 * MS;
    } else {
        m.warmup = 500 * MS;
        m.measure = 2 * SEC;
    }
    m
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let m = matrix(quick, seed);
    eprintln!("[avxfreq] fig5tail: {} cells across up to {threads} threads…", m.len());
    let loads = m.loads.clone();
    let arrivals: Vec<String> = m.arrivals.iter().map(|a| a.label()).collect();
    let policies: Vec<String> = m.policies.iter().map(|p| p.label()).collect();
    let result = m.run(threads);

    let mut t = Table::new(
        "Fig 5 (tail) — avx512 p99/p999/SLO damage vs same-scheduler sse4",
        &[
            "arrival", "load", "scheduler", "sse4 p99 µs", "avx512 p99 µs", "Δp99",
            "avx512 p999 µs", "avx512 slo %",
        ],
    );
    for arrival in &arrivals {
        for &load in &loads {
            for policy in &policies {
                let sse = result
                    .find_cell("1x12", Isa::Sse4, policy, arrival, load)
                    .expect("sse4 baseline cell present");
                let avx = result
                    .find_cell("1x12", Isa::Avx512, policy, arrival, load)
                    .expect("avx512 cell present");
                t.row(&[
                    arrival.clone(),
                    fmt_f(load, 2),
                    policy.clone(),
                    fmt_f(sse.run.tail.p99_us, 0),
                    fmt_f(avx.run.tail.p99_us, 0),
                    format!("{:+.1}%", pct_change(sse.run.tail.p99_us, avx.run.tail.p99_us)),
                    fmt_f(avx.run.tail.p999_us, 0),
                    fmt_f(avx.run.tail.slo_violation_frac * 100.0, 1),
                ]);
            }
        }
    }

    let mut notes = Vec::new();
    let top_load = loads.iter().copied().fold(f64::MIN, f64::max);
    for arrival in &arrivals {
        let p99 = |policy: &str, isa: Isa| {
            result
                .find_cell("1x12", isa, policy, arrival, top_load)
                .map(|c| c.run.tail.p99_us)
                .unwrap_or(0.0)
        };
        let d_unmod = pct_change(p99(&policies[0], Isa::Sse4), p99(&policies[0], Isa::Avx512));
        let d_spec = pct_change(p99(&policies[1], Isa::Sse4), p99(&policies[1], Isa::Avx512));
        notes.push(format!(
            "{arrival} @ load {top_load:.2}: avx512 inflates p99 by {d_unmod:+.1}% under the \
             unmodified scheduler vs {d_spec:+.1}% with core specialization (paper §5: the \
             mitigation recovers most of the AVX-induced loss)"
        ));
    }
    notes.push(
        "each Δp99 is vs the same scheduler/arrival/load sse4 cell; SLO fraction is \
         exact (counted at completion), percentiles carry the histogram's ~3% bucket \
         error"
            .to_string(),
    );
    Repro {
        id: "fig5tail",
        tables: vec![t, result.tail_table(), result.table()],
        notes,
    }
}
