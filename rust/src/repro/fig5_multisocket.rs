//! Fig 5 scaled out: the nginx throughput experiment on a 2-socket NUMA
//! machine, run as a scenario matrix alongside the original single-socket
//! configuration.
//!
//! The paper measures one socket; the follow-up work (Dim Silicon,
//! Schuchart et al.) argues frequency variation compounds with scale.
//! This runner sweeps {1×12, 2×12} × {unmodified, per-socket core
//! specialization} × {sse4, avx2, avx512} under equal per-core load and
//! reports each cell's throughput drop against the *same topology and
//! same scheduler's* SSE4 cell — the paper's methodology — so the
//! single- and dual-socket columns are directly comparable to its
//! −4.2 % / −11.2 % (unmodified) and −1.1 % / −3.2 % (core
//! specialization) numbers.

use super::Repro;
use crate::scenario::{PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use crate::sim::{MS, SEC};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;

/// Build the sweep this figure runs (exposed for tests).
pub fn matrix(quick: bool, seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(seed);
    m.topologies = vec![
        TopologySpec::single_socket_paper(),
        TopologySpec::dual_socket_paper(),
    ];
    m.policies = vec![
        PolicySpec::Unmodified,
        PolicySpec::CoreSpecNuma { avx_cores_per_socket: 2 },
    ];
    m.workloads = vec![WorkloadSpec::compressed_page()];
    m.isas = vec![Isa::Sse4, Isa::Avx2, Isa::Avx512];
    if quick {
        m.warmup = 300 * MS;
        m.measure = SEC;
    } else {
        m.warmup = SEC;
        m.measure = 4 * SEC;
    }
    m
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let m = matrix(quick, seed);
    eprintln!("[avxfreq] fig5ms: {} cells across up to {threads} threads…", m.len());
    let result = m.run(threads);

    let spec_label = PolicySpec::CoreSpecNuma { avx_cores_per_socket: 2 }.label();
    // Paper methodology (and the notes below): each cell's drop is
    // measured against the *same topology and same scheduler's* SSE4
    // cell, so the core-spec rows are comparable to the paper's
    // −1.1 % / −3.2 % numbers.
    let mut t = Table::new(
        "Fig 5 (multi-socket) — throughput drop vs same-topology, same-scheduler sse4",
        &["topology", "isa", "scheduler", "req/s", "drop", "xsock migr/s"],
    );
    for cell in &result.cells {
        let s = &cell.scenario;
        let base = result
            .throughput(&s.topology, Isa::Sse4, &s.policy)
            .expect("baseline cell present");
        t.row(&[
            s.topology.clone(),
            s.isa.name().to_string(),
            s.policy.clone(),
            fmt_f(cell.run.throughput_rps, 0),
            format!("{:+.1}%", pct_change(base, cell.run.throughput_rps)),
            fmt_f(cell.run.cross_socket_migrations_per_sec, 0),
        ]);
    }

    let mut notes = Vec::new();
    for topo in ["1x12", "2x12"] {
        let base_unmod = result.throughput(topo, Isa::Sse4, "unmodified").unwrap();
        let base_spec = result.throughput(topo, Isa::Sse4, &spec_label).unwrap();
        let d_unmod =
            pct_change(base_unmod, result.throughput(topo, Isa::Avx512, "unmodified").unwrap());
        let d_spec =
            pct_change(base_spec, result.throughput(topo, Isa::Avx512, &spec_label).unwrap());
        let reduction = if d_unmod < 0.0 { (1.0 - d_spec / d_unmod) * 100.0 } else { 0.0 };
        notes.push(format!(
            "{topo}: avx512 drop {d_unmod:.1}% → {d_spec:.1}% with per-socket core \
             specialization ({reduction:.0}% reduction; paper single-socket: 71%)"
        ));
    }
    notes.push(
        "per-core load is equal across topologies (5 000 req/s/core); each row's drop is \
         vs the same topology's sse4 cell under the same scheduler (the paper's \
         methodology)"
            .to_string(),
    );
    Repro { id: "fig5ms", tables: vec![t, result.table()], notes }
}
