//! The paper's tradeoff restated as **energy-delay product**: baseline
//! vs CoreSpec vs CoreSpecNuma, across DVFS governors, at machine and
//! fleet scale.
//!
//! The mitigation moves work between cores so the scalar majority keeps
//! its clock — a *latency* argument. But the license mechanism exists
//! because of *power*, and governor policy (voltage-ramp cost, AVX-timer
//! width) changes both sides of the trade: a widened timer (dim-silicon)
//! avoids PLL stalls but burns more Joules at the AVX voltage; slow
//! ramps (slow-ramp) tax every oscillation the unmitigated scheduler
//! provokes. EDP — energy-per-request × p99 latency — is the standard
//! single number for such trades (Gottschlag et al., "Dim Silicon",
//! argue DVFS policy must be judged on exactly this combination).
//!
//! Each row is one cell of a [`ScenarioMatrix`] over
//! {Unmodified, CoreSpec, CoreSpecNuma} × {intel-legacy, slow-ramp,
//! dim-silicon} × {1 machine, a 4-machine fleet}; being matrix cells,
//! the table is byte-identical at any thread count (pinned in
//! `rust/tests/power.rs`).

use super::Repro;
use crate::cpu::GovernorSpec;
use crate::scenario::{CellResult, MatrixResult, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;

/// One row of the energy-delay table, separated from the runner so the
/// golden-file test can pin the formatting on synthetic values.
#[derive(Clone, Debug)]
pub struct EdpRow {
    /// `machine` or `fleet(N)`.
    pub scale: String,
    pub policy: String,
    pub governor: String,
    pub throughput_rps: f64,
    pub p99_us: f64,
    /// Total energy over the measurement window (J; fleet rows sum
    /// their machines).
    pub energy_j: f64,
    /// Energy per completed request (mJ).
    pub mj_per_req: f64,
    /// Perf-per-watt: requests per Joule (== req/s per W).
    pub req_per_j: f64,
}

impl EdpRow {
    /// Energy-delay product per request: J/req × p99 seconds, reported
    /// in µJ·s (numerically `mJ/req × p99 ms`, i.e. `J/req × p99 µs`).
    pub fn edp_ujs(&self) -> f64 {
        self.mj_per_req * 1e-3 * self.p99_us
    }

    pub fn from_cell(c: &CellResult) -> EdpRow {
        let r = &c.run;
        let scale = if c.scenario.fleet > 1 {
            format!("fleet({})", c.scenario.fleet)
        } else {
            "machine".to_string()
        };
        EdpRow {
            scale,
            policy: c.scenario.policy.clone(),
            governor: c.scenario.governor.name().to_string(),
            throughput_rps: r.throughput_rps,
            p99_us: r.tail.p99_us,
            energy_j: r.energy_j(),
            mj_per_req: r.j_per_req() * 1e3,
            req_per_j: r.req_per_j(),
        }
    }
}

/// The energy-delay comparison table (formatting contract pinned by
/// `rust/tests/golden/energydelay_report.txt`).
pub fn table(rows: &[EdpRow]) -> Table {
    let mut t = Table::new(
        "Energy-delay — baseline vs core specialization across DVFS governors",
        &["scale", "policy", "governor", "req/s", "p99 µs", "total J", "mJ/req", "EDP µJ·s", "req/J"],
    );
    for r in rows {
        t.row(&[
            r.scale.clone(),
            r.policy.clone(),
            r.governor.clone(),
            fmt_f(r.throughput_rps, 0),
            fmt_f(r.p99_us, 0),
            fmt_f(r.energy_j, 2),
            fmt_f(r.mj_per_req, 3),
            fmt_f(r.edp_ujs(), 2),
            fmt_f(r.req_per_j, 1),
        ]);
    }
    t
}

/// The matrix behind `repro energydelay` (exposed so tests can shrink
/// its shape and pin the cross-thread determinism of the same code
/// path): the paper machine, three policies, every governor, at
/// single-machine and 4-machine-fleet scale.
pub fn matrix(quick: bool, base_seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(base_seed);
    m.topologies = vec![TopologySpec::single_socket_paper()];
    m.policies = vec![
        PolicySpec::Unmodified,
        PolicySpec::CoreSpec { avx_cores: 2 },
        PolicySpec::CoreSpecNuma { avx_cores_per_socket: 2 },
    ];
    m.workloads = vec![WorkloadSpec::compressed_page()];
    m.isas = vec![Isa::Avx512];
    m.governors = GovernorSpec::all().to_vec();
    m.fleet_sizes = vec![1, 4];
    if quick {
        m.warmup = 150 * crate::sim::MS;
        m.measure = 300 * crate::sim::MS;
    } else {
        m.warmup = 500 * crate::sim::MS;
        m.measure = crate::sim::SEC;
    }
    m
}

/// Rows of an executed energydelay matrix, in cell order.
pub fn rows(result: &MatrixResult) -> Vec<EdpRow> {
    result.cells.iter().map(EdpRow::from_cell).collect()
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let m = matrix(quick, seed);
    eprintln!(
        "[avxfreq] energydelay: {} cells (3 policies × 3 governors × 2 scales) across up to \
         {} threads…",
        m.len(),
        threads.min(m.len())
    );
    let result = m.run(threads);
    let rows = rows(&result);
    let t = table(&rows);

    let find = |scale: &str, policy: &str, gov: &str| {
        rows.iter()
            .find(|r| r.scale == scale && r.policy.starts_with(policy) && r.governor == gov)
            .expect("grid cell present")
    };
    let mut notes = Vec::new();
    for gov in GovernorSpec::all() {
        let base = find("machine", "unmodified", gov.name());
        let spec = find("machine", "core-spec(", gov.name());
        notes.push(format!(
            "{}: core specialization moves machine EDP {:.2} → {:.2} µJ·s ({:+.1}%), \
             perf-per-watt {:.1} → {:.1} req/J",
            gov.name(),
            base.edp_ujs(),
            spec.edp_ujs(),
            pct_change(base.edp_ujs(), spec.edp_ujs()),
            base.req_per_j,
            spec.req_per_j,
        ));
    }
    let base_legacy = find("machine", "unmodified", "intel-legacy");
    let base_slow = find("machine", "unmodified", "slow-ramp");
    notes.push(format!(
        "governor sensitivity of the unmitigated baseline: slow-ramp moves p99 \
         {:.0} → {:.0} µs vs intel-legacy — the voltage-ramp tax lands on exactly the \
         oscillations core specialization removes",
        base_legacy.p99_us, base_slow.p99_us,
    ));
    notes.push(
        "fleet rows sum machine Joules and merge latency recorders, so the EDP is the \
         cluster's, not an average of per-machine EDPs"
            .to_string(),
    );
    Repro { id: "energydelay", tables: vec![t], notes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edp_units_compose() {
        let r = EdpRow {
            scale: "machine".to_string(),
            policy: "unmodified".to_string(),
            governor: "intel-legacy".to_string(),
            throughput_rps: 50_000.0,
            p99_us: 2_000.0,
            energy_j: 100.0,
            mj_per_req: 2.0,
            req_per_j: 500.0,
        };
        // 2 mJ/req × 2 ms = 4 µJ·s.
        assert!((r.edp_ujs() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn matrix_covers_the_declared_grid() {
        let m = matrix(true, 1);
        assert_eq!(m.len(), 18, "3 policies × 3 governors × 2 fleet sizes");
        let cells = m.cells();
        assert!(cells.iter().any(|c| c.fleet == 4));
        assert!(cells
            .iter()
            .any(|c| c.governor == GovernorSpec::DimSilicon && c.policy.contains("numa")));
    }
}
