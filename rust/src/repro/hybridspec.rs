//! Hybrid P/E-core machines vs the homogeneous baseline, head to head.
//!
//! The paper's machine is homogeneous: every core can execute every
//! instruction class and owns a per-core clock inside one socket
//! frequency domain. Hybrid desktop parts break both assumptions —
//! E-cores lack the 512-bit datapath entirely and share one PLL per
//! 4-core module, so a single licensed sibling holds the whole module's
//! clock down ([`crate::cpu::HybridSpec`]). This experiment runs the
//! compressed-page AVX-512 workload on the 8P+16E hybrid part and on a
//! homogeneous 24-core machine of the same width, under {unmodified,
//! core-spec, class-native} × every DVFS governor, and compares
//! throughput, tails, machine-average frequency, and the per-domain
//! harmonic-mean frequencies that expose module-level clock coupling.
//!
//! `class-native` ([`crate::sched::PolicyKind::ClassNative`]) is the
//! hybrid-native mitigation: the hardware P/E partition *is* the
//! specialization set, so no tuning parameter is needed. On the
//! homogeneous machine the same policy designates the first 8 cores —
//! a fair software-only stand-in.
//!
//! Each row is one cell of a [`ScenarioMatrix`]; being matrix cells, the
//! tables are byte-identical at any thread count (pinned in
//! `rust/tests/hybrid.rs`).

use super::Repro;
use crate::cpu::GovernorSpec;
use crate::scenario::{CellResult, MatrixResult, PolicySpec, ScenarioMatrix, TopologySpec, WorkloadSpec};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;

/// One row of the hybrid-specialization table, separated from the
/// runner so the golden-file test can pin the formatting on synthetic
/// values.
#[derive(Clone, Debug)]
pub struct HsRow {
    /// Machine shape (`8P+16E` or the homogeneous `1x24`).
    pub topology: String,
    pub policy: String,
    pub governor: String,
    pub throughput_rps: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    /// Machine-wide average busy frequency.
    pub avg_ghz: f64,
    /// Slowest frequency domain: `(label, harmonic-mean GHz)`. `None`
    /// on homogeneous machines, which report no per-domain rows.
    pub slow_domain: Option<(String, f64)>,
}

impl HsRow {
    pub fn from_cell(c: &CellResult) -> HsRow {
        let r = &c.run;
        let slow_domain = r
            .domain_ghz
            .iter()
            .filter(|(_, g)| *g > 0.0)
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .cloned();
        HsRow {
            topology: c.scenario.topology.clone(),
            policy: c.scenario.policy.clone(),
            governor: c.scenario.governor.name().to_string(),
            throughput_rps: r.throughput_rps,
            p99_us: r.tail.p99_us,
            p999_us: r.tail.p999_us,
            avg_ghz: r.avg_ghz,
            slow_domain,
        }
    }
}

/// The hybrid-vs-homogeneous comparison table (formatting contract
/// pinned by `rust/tests/golden/hybridspec_report.txt`).
pub fn table(rows: &[HsRow]) -> Table {
    let mut t = Table::new(
        "Hybrid P/E machines vs homogeneous — policy × governor",
        &[
            "topology", "policy", "governor", "req/s", "p99 µs", "p999 µs", "GHz",
            "slow dom", "dom GHz",
        ],
    );
    for r in rows {
        let (dom, dom_ghz) = match &r.slow_domain {
            Some((label, ghz)) => (label.clone(), fmt_f(*ghz, 3)),
            None => ("-".to_string(), "-".to_string()),
        };
        t.row(&[
            r.topology.clone(),
            r.policy.clone(),
            r.governor.clone(),
            fmt_f(r.throughput_rps, 0),
            fmt_f(r.p99_us, 0),
            fmt_f(r.p999_us, 0),
            fmt_f(r.avg_ghz, 3),
            dom,
            dom_ghz,
        ]);
    }
    t
}

/// The matrix behind `repro hybridspec` (exposed so tests can shrink
/// its shape and pin the cross-thread determinism of the same code
/// path): {8P+16E hybrid, homogeneous 1x24} × {unmodified, core-spec(8),
/// class-native(8)} × every governor, compressed-page AVX-512.
pub fn matrix(quick: bool, base_seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(base_seed);
    m.topologies = vec![TopologySpec::hybrid_8p16e(), TopologySpec::multi(1, 24)];
    m.policies = vec![
        PolicySpec::Unmodified,
        PolicySpec::CoreSpec { avx_cores: 8 },
        PolicySpec::ClassNative { p_cores: 8 },
    ];
    m.workloads = vec![WorkloadSpec::compressed_page()];
    m.isas = vec![Isa::Avx512];
    m.governors = GovernorSpec::all().to_vec();
    if quick {
        m.warmup = 150 * crate::sim::MS;
        m.measure = 300 * crate::sim::MS;
    } else {
        m.warmup = 500 * crate::sim::MS;
        m.measure = crate::sim::SEC;
    }
    m
}

/// Rows of an executed hybridspec matrix, in cell order.
pub fn rows(result: &MatrixResult) -> Vec<HsRow> {
    result.cells.iter().map(HsRow::from_cell).collect()
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let m = matrix(quick, seed);
    eprintln!(
        "[avxfreq] hybridspec: {} cells (2 topologies × 3 policies × 3 governors) \
         across up to {} threads…",
        m.len(),
        threads.min(m.len())
    );
    let result = m.run(threads);
    let rows = rows(&result);
    let t = table(&rows);
    // The per-domain restatement: every socket / E-module of every
    // hybrid cell, so module-level clock coupling is visible directly.
    let domains = crate::metrics::hybrid_report(&result.cells);

    let find = |topology: &str, policy: &str, gov: &str| {
        rows.iter()
            .find(|r| {
                r.topology == topology && r.policy.starts_with(policy) && r.governor == gov
            })
            .expect("grid cell present")
    };
    let hybrid = TopologySpec::hybrid_8p16e().name;
    let homog = TopologySpec::multi(1, 24).name;
    let mut notes = Vec::new();
    for gov in GovernorSpec::all() {
        let un = find(&hybrid, "unmodified", gov.name());
        let cn = find(&hybrid, "class-native(", gov.name());
        notes.push(format!(
            "{}: on 8P+16E, class-native moves p99 {:.0} → {:.0} µs ({:+.1}%) vs the \
             confined-unmodified baseline",
            gov.name(),
            un.p99_us,
            cn.p99_us,
            pct_change(un.p99_us, cn.p99_us),
        ));
    }
    let cs = find(&hybrid, "core-spec(", "intel-legacy");
    let cn = find(&hybrid, "class-native(", "intel-legacy");
    notes.push(format!(
        "core-spec(8) remapped onto the P-cores and class-native coincide on this part \
         (both designate all 8 P-cores): p99 {:.0} vs {:.0} µs at intel-legacy",
        cs.p99_us, cn.p99_us,
    ));
    let hyb = find(&hybrid, "unmodified", "intel-legacy");
    let hom = find(&homog, "unmodified", "intel-legacy");
    notes.push(format!(
        "homogeneous 1x24 anchor (unmodified, intel-legacy): p99 {:.0} µs vs {:.0} µs on \
         the hybrid part — the gap is what E-core width limits plus module clock \
         coupling cost before any mitigation",
        hom.p99_us, hyb.p99_us,
    ));
    if let Some((dom, ghz)) = &hyb.slow_domain {
        notes.push(format!(
            "slowest hybrid domain under unmodified/intel-legacy: {dom} at {ghz:.3} GHz \
             harmonic mean — one licensed sibling drags its whole module (see the \
             per-domain table)",
        ));
    }
    Repro { id: "hybridspec", tables: vec![t, domains], notes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::PolicyKind;

    #[test]
    fn matrix_covers_the_declared_grid() {
        let m = matrix(true, 1);
        assert_eq!(m.len(), 18, "2 topologies × 3 policies × 3 governors");
        let cells = m.cells();
        // The hybrid half carries the spec; the homogeneous half doesn't.
        assert_eq!(cells.iter().filter(|c| c.cfg.hybrid.is_some()).count(), 9);
        assert!(cells.iter().any(|c| c.topology == "8P+16E"
            && c.cfg.policy == PolicyKind::ClassNative { p_cores: 8 }
            && c.governor == GovernorSpec::DimSilicon));
        // Both machine shapes are 24 cores wide — same width, different
        // capability structure.
        assert!(cells.iter().all(|c| c.cfg.cores == 24));
    }

    #[test]
    fn row_renders_domain_columns() {
        let hybrid = HsRow {
            topology: "8P+16E".to_string(),
            policy: "class-native(8)".to_string(),
            governor: "intel-legacy".to_string(),
            throughput_rps: 1.0,
            p99_us: 2.0,
            p999_us: 3.0,
            avg_ghz: 3.1,
            slow_domain: Some(("mod2".to_string(), 2.345)),
        };
        let homog = HsRow {
            topology: "1x24".to_string(),
            slow_domain: None,
            ..hybrid.clone()
        };
        let text = table(&[hybrid, homog]).render();
        assert!(text.contains("mod2"));
        assert!(text.contains("2.345"));
        assert!(text.contains("class-native(8)"));
        // The homogeneous row renders `-` for both domain columns.
        assert!(text.lines().any(|l| l.contains("1x24") && l.contains('-')));
    }
}
