//! Fig 1: frequency-level timeline of a core that temporarily executes
//! 512-bit FMA instructions — detection, throttled request phase, grant,
//! and the ~2 ms delayed return to full frequency.

use super::Repro;
use crate::cpu::freq::FreqParams;
use crate::cpu::ipc::IpcParams;
use crate::cpu::turbo::TurboTable;
use crate::cpu::Core;
use crate::isa::block::{Block, ClassMix, InsnClass};
use crate::sim::{Time, MS, US};
use crate::util::table::{fmt_f, Table};

/// Phase labels matching the figure.
fn phase(throttled: bool, lic: crate::cpu::License) -> &'static str {
    if throttled {
        "throttled (license request pending)"
    } else {
        match lic {
            crate::cpu::License::L0 => "full turbo (L0)",
            crate::cpu::License::L1 => "AVX2/512-light turbo (L1)",
            crate::cpu::License::L2 => "AVX-512-heavy turbo (L2)",
        }
    }
}

pub fn run() -> Repro {
    let turbo = TurboTable::xeon_gold_6130_no_cstates();
    let mut core = Core::new(0, FreqParams::default(), IpcParams::default());

    // 1 ms scalar, 0.8 ms of 512-bit FMA, then scalar until recovery.
    let scalar = Block { mix: ClassMix::scalar(20_000), mem_ops: 0, branches: 300, license_exempt: false };
    let fma = Block { mix: ClassMix::of(InsnClass::Avx512Heavy, 20_000), mem_ops: 0, branches: 100, license_exempt: false };

    let mut t: Time = 0;
    let mut series: Vec<(Time, f64, &'static str)> = Vec::new();
    let segment =
        |core: &mut Core, block: &Block, until: Time, t: &mut Time, series: &mut Vec<_>| {
            while *t < until {
                let out = core.run_block(*t, block, 1, 16, &turbo);
                let throttled = out.throttle_cycles > 0.0;
                // "Effective GHz" folds the reduced-dispatch phase into an
                // equivalent frequency for the plot (Fig 1's dip).
                let eff_ghz = if throttled {
                    out.ghz * core.license.params().throttle_ipc_factor
                } else {
                    out.ghz
                };
                series.push((*t, eff_ghz, phase(throttled, out.license)));
                *t += out.ns;
            }
        };
    segment(&mut core, &scalar, MS, &mut t, &mut series);
    let avx_until = t + 800 * US;
    segment(&mut core, &fma, avx_until, &mut t, &mut series);
    segment(&mut core, &scalar, t + 6 * MS, &mut t, &mut series);

    // Compress the series into phase segments.
    let mut table = Table::new(
        "Fig 1 — license transition timeline (Skylake-SP core, 512-bit FMA burst)",
        &["t_start", "t_end", "effective GHz", "phase"],
    );
    let mut notes = Vec::new();
    let mut seg_start = series[0].0;
    let mut cur = series[0].2;
    let mut cur_ghz = series[0].1;
    let mut throttle_ns: Time = 0;
    let mut l2_scalar_ns: Time = 0;
    let mut in_scalar_tail = false;
    for w in series.windows(2) {
        let (t0, _ghz, ph) = w[0];
        let (t1, _, ph1) = w[1];
        if ph == "throttled (license request pending)" {
            throttle_ns += t1 - t0;
        }
        if t0 >= avx_until {
            in_scalar_tail = true;
        }
        if in_scalar_tail && ph == "AVX-512-heavy turbo (L2)" {
            l2_scalar_ns += t1 - t0;
        }
        if ph1 != cur {
            table.row(&[
                crate::sim::fmt_time(seg_start),
                crate::sim::fmt_time(t1),
                fmt_f(cur_ghz, 2),
                cur.to_string(),
            ]);
            seg_start = t1;
            cur = ph1;
            cur_ghz = w[1].1;
        }
    }
    table.row(&[
        crate::sim::fmt_time(seg_start),
        crate::sim::fmt_time(t),
        fmt_f(cur_ghz, 2),
        cur.to_string(),
    ]);

    notes.push(format!(
        "throttled request phase lasted {} (paper/SDM: up to 500 µs)",
        crate::sim::fmt_time(throttle_ns)
    ));
    notes.push(format!(
        "scalar code after the AVX burst ran at the L2 frequency for {} (paper: ~2 ms hold)",
        crate::sim::fmt_time(l2_scalar_ns)
    ));

    Repro { id: "fig1", tables: vec![table], notes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timeline_has_all_phases() {
        let r = run();
        let text = r.render();
        assert!(text.contains("full turbo (L0)"));
        assert!(text.contains("throttled"));
        assert!(text.contains("AVX-512-heavy turbo (L2)"));
    }

    #[test]
    fn scalar_tail_holds_l2_about_two_ms() {
        let r = run();
        let note = r.notes.iter().find(|n| n.contains("hold")).unwrap();
        // The note embeds the measured duration; parse the ms value.
        let ms: f64 = note
            .split("ran at the L2 frequency for ")
            .nth(1)
            .unwrap()
            .split("ms")
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!((1.6..2.6).contains(&ms), "L2 tail {ms}ms");
    }
}
