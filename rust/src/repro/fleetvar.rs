//! Fig 5 restated at fleet scale: cross-machine p99 variance under
//! round-robin vs AVX-aware routing.
//!
//! The paper's Fig 5 shows core specialization recovering the AVX-512
//! throughput loss on *one* machine; Schuchart et al. argue that at
//! scale the loss reappears as performance *variation* — stragglers —
//! which aggregate operations (fan-outs, collectives) feel as the
//! slowest machine, not the mean. This runner simulates a small fleet
//! under the bursty multi-tenant mix and compares two front-ends:
//!
//! * **round-robin** — every machine serves a blend of scalar and AVX
//!   tenants, so every machine pays the ~11% frequency drag and each
//!   machine's p99 depends on how its random share of AVX bursts aligns
//!   with load — high cross-machine variance;
//! * **avx-partition** — AVX tenants are pinned to a dedicated machine
//!   subset (`CoreSpec` at datacenter scale). The scalar majority never
//!   executes a wide instruction and keeps its full clock, and the AVX
//!   subset serves requests that are individually *cheap* (AVX-512
//!   crypto uses ~⅓ the instructions per byte) — so with the subset
//!   sized to the AVX share of *work*, every machine in the fleet runs
//!   at lower utilization than any round-robin machine.
//!
//! The scenario is the paper's **uncompressed** page (crypto-dominated
//! requests): that is where AVX-512 is cheap for itself but poisonous
//! for neighbours, i.e. where routing — not per-request cost — decides
//! who pays the license tax. Machines run the *unmodified* scheduler in
//! both arms, so the comparison isolates the routing policy.
//!
//! Being fleet runs (seeded stream, independent machines), the tables
//! are byte-identical at any thread count.

use super::Repro;
use crate::fleet::{run_fleet, FleetCfg, FleetRun, RouterSpec};
use crate::sched::PolicyKind;
use crate::sim::{MS, SEC};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::client::LoadMode;
use crate::workload::crypto::Isa;
use crate::workload::webserver::WebCfg;

/// Cross-machine p99 dispersion of one routing policy's fleet run — the
/// row type of the fleetvar table, separated from the runner so the
/// golden-file test can pin the formatting on synthetic values.
#[derive(Clone, Debug)]
pub struct RouterVar {
    pub router: String,
    pub machines: usize,
    /// Cluster-wide p99 from the *merged* histograms (µs).
    pub fleet_p99_us: f64,
    /// Mean of the per-machine p99s (µs).
    pub mean_p99_us: f64,
    /// Cross-machine standard deviation of the per-machine p99 (µs).
    pub sigma_us: f64,
    /// Max − min per-machine p99 (µs): the straggler gap.
    pub spread_us: f64,
    /// Cluster-wide exact SLO-violation percentage.
    pub slo_pct: f64,
}

impl RouterVar {
    pub fn from_run(f: &FleetRun) -> RouterVar {
        let s = f.p99_summary();
        RouterVar {
            router: f.router.clone(),
            machines: f.machines.len(),
            fleet_p99_us: f.tail.p99_us,
            mean_p99_us: s.mean(),
            sigma_us: s.stddev(),
            spread_us: f.p99_spread_us(),
            slo_pct: f.tail.slo_violation_frac * 100.0,
        }
    }

    /// Coefficient of variation of the per-machine p99, in percent.
    pub fn cv_pct(&self) -> f64 {
        if self.mean_p99_us <= 0.0 {
            0.0
        } else {
            self.sigma_us / self.mean_p99_us * 100.0
        }
    }
}

/// The fleetvar comparison table (formatting contract pinned by
/// `rust/tests/golden/fleetvar_report.txt`).
pub fn table(rows: &[RouterVar]) -> Table {
    let mut t = Table::new(
        "Fig 5 (fleet) — cross-machine p99 under round-robin vs AVX-aware routing",
        &[
            "router", "machines", "fleet p99 µs", "machine p99 mean µs", "σ µs", "CV %",
            "spread µs", "slo %",
        ],
    );
    for r in rows {
        t.row(&[
            r.router.clone(),
            r.machines.to_string(),
            fmt_f(r.fleet_p99_us, 0),
            fmt_f(r.mean_p99_us, 0),
            fmt_f(r.sigma_us, 1),
            fmt_f(r.cv_pct(), 1),
            fmt_f(r.spread_us, 1),
            fmt_f(r.slo_pct, 1),
        ]);
    }
    t
}

/// The bursty multi-tenant fleet behind `repro fleetvar` (exposed for
/// tests): 6 × the paper's 12-core machine, uncompressed 256 KiB pages,
/// a 30% AVX-512 tenant share with in-phase 1.5× bursts, and one AVX
/// machine — sized so the AVX share of *work* (cheap AVX requests, ~⅙
/// of effective instructions) matches ⅙ of the fleet.
pub fn fleet_cfg(router: RouterSpec, quick: bool, seed: u64) -> FleetCfg {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
    cfg.compress = false;
    cfg.page_bytes = 256 * 1024;
    cfg.annotate = false;
    cfg.seed = seed;
    cfg.slo = 10 * MS;
    cfg.mode = LoadMode::OpenProcess {
        process: crate::traffic::ArrivalProcess::bursty_two_tenant(
            500_000.0, // fleet-total mean rate: the round-robin knee
            0.3,
            1.5,
            0.3,
            90 * MS,
        ),
    };
    cfg.warmup = 500 * MS;
    cfg.measure = 2 * SEC;
    if quick {
        apply_quick(&mut cfg);
    }
    FleetCfg::new(6, router, cfg)
}

/// Clamp a fleet scenario to the quick measurement windows — the single
/// definition shared by `repro fleetvar` and `avxfreq fleet --quick`,
/// so the two quick modes cannot drift apart.
pub fn apply_quick(cfg: &mut WebCfg) {
    cfg.warmup = cfg.warmup.min(200 * MS);
    cfg.measure = cfg.measure.min(600 * MS);
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let specs = [RouterSpec::RoundRobin, RouterSpec::AvxPartition { avx_machines: 1 }];
    let mut rows = Vec::new();
    let mut fleets = Vec::new();
    for spec in specs {
        let cfg = fleet_cfg(spec, quick, seed);
        eprintln!(
            "[avxfreq] fleetvar: {} × {} machines across up to {threads} threads…",
            spec.label(),
            cfg.machines
        );
        let f = run_fleet(&cfg, threads);
        rows.push(RouterVar::from_run(&f));
        fleets.push(f);
    }
    let labeled: Vec<(&str, &FleetRun)> =
        fleets.iter().map(|f| (f.router.as_str(), f)).collect();
    let detail = crate::metrics::fleet_report(&labeled);

    let (rr, part) = (&rows[0], &rows[1]);
    let notes = vec![
        format!(
            "cross-machine p99 σ: {:.1} µs (round-robin) → {:.1} µs (avx-partition), \
             {:+.1}%; spread (max−min): {:.1} → {:.1} µs, {:+.1}% (paper §5 reports the \
             in-machine analogue, core specialization, recovering >70% of the variability)",
            rr.sigma_us,
            part.sigma_us,
            pct_change(rr.sigma_us, part.sigma_us),
            rr.spread_us,
            part.spread_us,
            pct_change(rr.spread_us, part.spread_us),
        ),
        format!(
            "fleet p99 {:.0} → {:.0} µs ({:+.1}%), SLO violations {:.1}% → {:.1}%: \
             confining AVX tenants to 1 of 6 machines removes the frequency drag from \
             the scalar majority without overloading the AVX subset (AVX-512 requests \
             are instruction-cheap on the uncompressed page)",
            rr.fleet_p99_us,
            part.fleet_p99_us,
            pct_change(rr.fleet_p99_us, part.fleet_p99_us),
            rr.slo_pct,
            part.slo_pct,
        ),
        "machines run the unmodified scheduler in both arms; only the front-end \
         routing differs — the fleet-level restatement of with_avx() + CoreSpec"
            .to_string(),
    ];
    Repro { id: "fleetvar", tables: vec![table(&rows), detail], notes }
}
