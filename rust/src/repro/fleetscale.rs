//! AVX-induced variation amplifying with fleet size — and the
//! partition + CoreSpec recovery.
//!
//! Schuchart et al.'s scale argument: per-machine performance
//! *variation* is what aggregate operations feel, and the bigger the
//! fleet the worse it bites, because a bulk-synchronous step completes
//! only when the **slowest** machine does. A single machine's p99 is a
//! 1-in-100 event; across `n` machines per step, *some* machine hits
//! its tail almost every step once `n` is large. So the collective
//! slowdown (makespan ÷ ideal) grows with fleet size even though every
//! machine's own distribution is unchanged — the max-of-`n` amplifier.
//!
//! This runner sweeps fleet size under the bursty multi-tenant mix
//! (fleetvar's per-machine scenario, total rate scaled with the fleet)
//! and compares two arms through the hierarchical fleet:
//!
//! * **round-robin / unmodified** — AVX bursts land everywhere, every
//!   machine carries the frequency drag in its tail, and the collective
//!   pays max-of-`n` over *wide* distributions;
//! * **avx-part / core-spec** — the AVX tenants are confined to ⌈n/6⌉
//!   machines *and* those machines confine AVX to a core subset: the
//!   scalar majority's distributions tighten, so the same max-of-`n`
//!   amplifier has far less variation to amplify.
//!
//! The collective model runs over the merged per-machine digests (see
//! [`crate::fleet::hierarchy::collective_makespan`]): seeded,
//! sequential, byte-identical at any thread count like every other
//! fleet table.

use super::Repro;
use crate::fleet::{run_hier_fleet, BalancerCfg, FleetCfg, HierFleetCfg, RouterSpec};
use crate::sched::PolicyKind;
use crate::sim::MS;
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::client::LoadMode;
use crate::workload::webserver::WebCfg;

/// One (arm, fleet-size) cell of the fleetscale table, separated from
/// the runner so the golden-file test can pin the formatting on
/// synthetic values (same pattern as
/// [`crate::repro::fleetvar::RouterVar`]).
#[derive(Clone, Debug)]
pub struct ScaleRow {
    /// Routing + machine-policy arm label.
    pub arm: String,
    pub machines: usize,
    /// Cluster-wide p99 from the merged histograms (µs).
    pub fleet_p99_us: f64,
    /// Cross-machine standard deviation of the per-machine p99 (µs).
    pub sigma_us: f64,
    /// Max − min per-machine p99 (µs): the straggler gap.
    pub spread_us: f64,
    /// Cluster-wide exact SLO-violation percentage.
    pub slo_pct: f64,
    /// Bulk-synchronous steps modeled.
    pub steps: usize,
    /// Sum over steps of the slowest machine's draw (ms).
    pub makespan_ms: f64,
    /// Collective slowdown: makespan ÷ (median-machine p50 × steps).
    pub slowdown: f64,
}

/// The fleetscale comparison table (formatting contract pinned by
/// `rust/tests/golden/fleetscale_report.txt`).
pub fn table(rows: &[ScaleRow]) -> Table {
    let mut t = Table::new(
        "Fleet scale — collective slowdown vs fleet size, round-robin vs avx-part+core-spec",
        &[
            "arm", "machines", "fleet p99 µs", "σ µs", "spread µs", "slo %", "steps",
            "makespan ms", "slowdown",
        ],
    );
    for r in rows {
        t.row(&[
            r.arm.clone(),
            r.machines.to_string(),
            fmt_f(r.fleet_p99_us, 0),
            fmt_f(r.sigma_us, 1),
            fmt_f(r.spread_us, 1),
            fmt_f(r.slo_pct, 1),
            r.steps.to_string(),
            fmt_f(r.makespan_ms, 1),
            fmt_f(r.slowdown, 2),
        ]);
    }
    t
}

/// Per-machine scenario shared by both arms: fleetvar's bursty
/// multi-tenant machine with the fleet-total arrival rate scaled so
/// every fleet size runs at the same per-machine utilization (fleetvar
/// tunes 500 krps across 6 machines).
fn machine_cfg(policy: PolicyKind, machines: usize, quick: bool, seed: u64) -> WebCfg {
    let mut cfg = super::fleetvar::fleet_cfg(RouterSpec::RoundRobin, quick, seed).cfg;
    cfg.policy = policy;
    if let LoadMode::OpenProcess { process } = &cfg.mode {
        let per_machine = process.mean_rate() / 6.0;
        cfg.mode = LoadMode::OpenProcess {
            process: process.with_mean_rate(per_machine * machines as f64),
        };
    }
    cfg
}

/// The hierarchical fleet behind one `repro fleetscale` cell (exposed
/// for tests): open-loop balancer (the differential-tested path), racks
/// of 4, and the bulk-synchronous collective over `steps`.
pub fn hier_cfg(
    router: RouterSpec,
    policy: PolicyKind,
    machines: usize,
    steps: usize,
    quick: bool,
    seed: u64,
) -> HierFleetCfg {
    let fleet = FleetCfg::new(machines, router, machine_cfg(policy, machines, quick, seed));
    let mut h = HierFleetCfg::new(fleet, BalancerCfg::default());
    h.machines_per_rack = 4;
    h.collective_steps = steps;
    h
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let sizes: &[usize] = if quick { &[2, 4, 8] } else { &[2, 4, 8, 16] };
    let steps = if quick { 200 } else { 500 };
    let arms: &[(&str, PolicyKind)] = &[
        ("rr/unmod", PolicyKind::Unmodified),
        ("avx-part/core-spec", PolicyKind::CoreSpec { avx_cores: 2 }),
    ];
    let mut rows = Vec::new();
    for &(arm, policy) in arms {
        for &n in sizes {
            let router = match policy {
                PolicyKind::Unmodified => RouterSpec::RoundRobin,
                _ => RouterSpec::AvxPartition { avx_machines: (n / 6).max(1) },
            };
            let cfg = hier_cfg(router, policy, n, steps, quick, seed);
            eprintln!(
                "[avxfreq] fleetscale: {arm} × {n} machines across up to {threads} threads…"
            );
            let f = run_hier_fleet(&cfg, threads);
            let s = f.p99_summary();
            let c = f.collective.unwrap_or_default();
            rows.push(ScaleRow {
                arm: arm.to_string(),
                machines: n,
                fleet_p99_us: f.tail.p99_us,
                sigma_us: s.stddev(),
                spread_us: f.p99_spread_us(),
                slo_pct: f.tail.slo_violation_frac * 100.0,
                steps: c.steps,
                makespan_ms: c.makespan_us / 1_000.0,
                slowdown: c.slowdown,
            });
        }
    }

    let per_arm = sizes.len();
    let (rr_small, rr_big) = (&rows[0], &rows[per_arm - 1]);
    let (cs_small, cs_big) = (&rows[per_arm], &rows[2 * per_arm - 1]);
    let notes = vec![
        format!(
            "max-of-n amplification (round-robin): collective slowdown {:.2} at {} \
             machines → {:.2} at {} machines ({:+.1}%) with the per-machine scenario \
             held fixed — the fleet feels the slowest machine, and some machine is in \
             its tail almost every step once the fleet is wide",
            rr_small.slowdown,
            rr_small.machines,
            rr_big.slowdown,
            rr_big.machines,
            pct_change(rr_small.slowdown, rr_big.slowdown),
        ),
        format!(
            "avx-partition + core specialization at {} machines: slowdown {:.2} → {:.2} \
             ({:+.1}%), cross-machine p99 σ {:.1} → {:.1} µs — confining AVX by machine \
             *and* by core shrinks the variation the max-of-n amplifier feeds on \
             (the paper's §5 claim restated at fleet scale)",
            rr_big.machines,
            rr_big.slowdown,
            cs_big.slowdown,
            pct_change(rr_big.slowdown, cs_big.slowdown),
            rr_big.sigma_us,
            cs_big.sigma_us,
        ),
        format!(
            "recovery holds across the sweep: at {} machines slowdown {:.2} vs {:.2}; \
             aggregation is the streaming machine→rack→cluster hierarchy, so the sweep \
             retains O(machines) digests — no per-machine runs",
            cs_small.machines, rr_small.slowdown, cs_small.slowdown,
        ),
    ];
    Repro { id: "fleetscale", tables: vec![table(&rows)], notes }
}
