//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **AVX-core count** — §2.1: too few AVX cores queue the AVX work, too
//!   many shrink the clean scalar set.
//! * **Strict partitioning** — §2.1's strawman: scalar tasks may not use
//!   idle AVX cores → underutilization.
//! * **Work stealing off** — MuQSS's load balancing is the mechanism that
//!   backfills AVX cores with scalar work; without it utilization drops.
//! * **Fault-and-migrate** — §6.1: automatic classification vs manual
//!   annotations.

use super::Repro;
use crate::sched::PolicyKind;
use crate::sim::{MS, SEC};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;
use crate::workload::webserver::{run_webserver, WebCfg, WebRun};

fn cfg_with(policy: PolicyKind, quick: bool, seed: u64) -> WebCfg {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, policy);
    cfg.seed = seed;
    if quick {
        cfg.warmup = 300 * MS;
        cfg.measure = SEC;
    }
    cfg
}

fn run_one(cfg: &WebCfg) -> WebRun {
    run_webserver(cfg)
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let mut tables = Vec::new();
    let mut notes = Vec::new();

    let base = run_one(&cfg_with(PolicyKind::Unmodified, quick, seed));

    // 1. AVX core count sweep.
    let mut t1 = Table::new(
        "Ablation — number of AVX cores (AVX-512 build, 12 cores)",
        &["avx cores", "req/s", "vs unmodified", "avg GHz", "migrations/s"],
    );
    t1.row(&[
        "0 (unmodified)".into(),
        fmt_f(base.throughput_rps, 0),
        "+0.0%".into(),
        fmt_f(base.avg_ghz, 3),
        fmt_f(base.migrations_per_sec, 0),
    ]);
    let mut best = (0usize, base.throughput_rps);
    for k in 1..=4usize {
        let r = run_one(&cfg_with(PolicyKind::CoreSpec { avx_cores: k }, quick, seed));
        if r.throughput_rps > best.1 {
            best = (k, r.throughput_rps);
        }
        t1.row(&[
            k.to_string(),
            fmt_f(r.throughput_rps, 0),
            format!("{:+.1}%", pct_change(base.throughput_rps, r.throughput_rps)),
            fmt_f(r.avg_ghz, 3),
            fmt_f(r.migrations_per_sec, 0),
        ]);
    }
    notes.push(format!(
        "best AVX-core count: {} (paper uses 2 for this workload)",
        best.0
    ));
    tables.push(t1);

    // 2. Strict partition vs core-spec (same AVX core count).
    let mut t2 = Table::new(
        "Ablation — §2.1 strict partitioning vs core specialization (2 AVX cores)",
        &["policy", "req/s", "vs unmodified", "avg GHz"],
    );
    for (name, policy) in [
        ("core-spec (AVX cores may run scalar)", PolicyKind::CoreSpec { avx_cores: 2 }),
        ("strict partition (they may not)", PolicyKind::StrictPartition { avx_cores: 2 }),
    ] {
        let r = run_one(&cfg_with(policy, quick, seed));
        t2.row(&[
            name.into(),
            fmt_f(r.throughput_rps, 0),
            format!("{:+.1}%", pct_change(base.throughput_rps, r.throughput_rps)),
            fmt_f(r.avg_ghz, 3),
        ]);
    }
    notes.push(
        "strict partitioning idles AVX cores whenever no AVX task is runnable — the \
         paper argues (and this shows) backfilling them with deprioritized scalar \
         tasks is strictly better"
            .to_string(),
    );
    tables.push(t2);

    // 3. Work stealing off.
    let mut t3 = Table::new(
        "Ablation — MuQSS cross-core stealing (core-spec, 2 AVX cores)",
        &["stealing", "req/s", "vs unmodified"],
    );
    for steal in [true, false] {
        let cfg = cfg_with(PolicyKind::CoreSpec { avx_cores: 2 }, quick, seed);
        let r = run_webserver_with_steal(&cfg, steal);
        t3.row(&[
            steal.to_string(),
            fmt_f(r.throughput_rps, 0),
            format!("{:+.1}%", pct_change(base.throughput_rps, r.throughput_rps)),
        ]);
    }
    tables.push(t3);

    // 4. Fault-and-migrate vs annotations.
    let mut t4 = Table::new(
        "Ablation — §6.1 fault-and-migrate vs manual annotation (2 AVX cores)",
        &["classification", "req/s", "vs unmodified", "type-chg/s"],
    );
    {
        let r = run_one(&cfg_with(PolicyKind::CoreSpec { avx_cores: 2 }, quick, seed));
        t4.row(&[
            "manual with_avx()/without_avx()".into(),
            fmt_f(r.throughput_rps, 0),
            format!("{:+.1}%", pct_change(base.throughput_rps, r.throughput_rps)),
            fmt_f(r.type_changes_per_sec, 0),
        ]);
        let mut cfg = cfg_with(PolicyKind::CoreSpec { avx_cores: 2 }, quick, seed);
        cfg.annotate = false;
        cfg.fault_migrate = true;
        let r = run_one(&cfg);
        t4.row(&[
            "automatic fault-and-migrate".into(),
            fmt_f(r.throughput_rps, 0),
            format!("{:+.1}%", pct_change(base.throughput_rps, r.throughput_rps)),
            fmt_f(r.type_changes_per_sec, 0),
        ]);
    }
    tables.push(t4);
    notes.push(
        "fault-and-migrate (never evaluated in the paper) classifies correctly but each \
         AVX burst costs a trap + queue round-trip, and tasks hold AVX cores through \
         short scalar stretches until the decay fires — naive automatic classification \
         underperforms manual annotation by ~20% on this workload"
            .to_string(),
    );

    // 5. §3.1/§4.3 adaptive AVX-core allocation: started deliberately
    //    mis-sized (4 cores), the controller must converge to the best
    //    static size from ablation 1.
    let mut t5 = Table::new(
        "Ablation — adaptive AVX-core allocation (started at 4 cores)",
        &["allocation", "req/s", "vs unmodified", "final avx cores", "resizes"],
    );
    {
        let stat = run_one(&cfg_with(PolicyKind::CoreSpec { avx_cores: 4 }, quick, seed));
        t5.row(&[
            "static 4".into(),
            fmt_f(stat.throughput_rps, 0),
            format!("{:+.1}%", pct_change(base.throughput_rps, stat.throughput_rps)),
            "4".into(),
            "0".into(),
        ]);
        let mut cfg = cfg_with(PolicyKind::CoreSpec { avx_cores: 4 }, quick, seed);
        cfg.adaptive = Some(Default::default());
        let adap = run_one(&cfg);
        t5.row(&[
            "adaptive".into(),
            fmt_f(adap.throughput_rps, 0),
            format!("{:+.1}%", pct_change(base.throughput_rps, adap.throughput_rps)),
            adap.final_avx_cores.to_string(),
            adap.adaptive_changes.to_string(),
        ]);
        notes.push(format!(
            "adaptive controller converged from 4 AVX cores to {} ({} resizes), recovering \
             the margin a mis-sized static allocation leaves behind (§4.3 future-work policy)",
            adap.final_avx_cores, adap.adaptive_changes
        ));
    }
    tables.push(t5);

    Repro { id: "ablations", tables, notes }
}

/// Run the web scenario with the scheduler's steal switch overridden.
fn run_webserver_with_steal(cfg: &WebCfg, steal: bool) -> WebRun {
    use crate::workload::webserver::run_webserver_with_params;
    let sp = crate::sched::SchedParams { steal, ..Default::default() };
    run_webserver_with_params(cfg, sp)
}
