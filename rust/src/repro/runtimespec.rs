//! Runtime-level vs kernel-level core specialization, head to head.
//!
//! The paper puts the mitigation in the kernel scheduler. Thread-per-core
//! runtimes (glommio, seastar) bypass kernel queueing entirely — each
//! worker runs one pinned task queue — so the same idea can live in the
//! runtime's placement layer instead ([`crate::tpc`]): steer AVX-marked
//! futures onto a designated executor-core subset (`avx-steer`), or
//! migrate on first observed AVX demand (`avx-steer-lazy`, the runtime
//! analogue of §6.1 fault-and-migrate). This experiment runs the bursty
//! multi-tenant mix through the executor under {home-core, avx-steer,
//! avx-steer-lazy} × kernel {unmodified, core-spec} × every DVFS
//! governor and compares p99/p999, migration rates at both layers, and
//! energy per request.
//!
//! Each row is one cell of a [`ScenarioMatrix`]; being matrix cells, the
//! table is byte-identical at any thread count (pinned in
//! `rust/tests/tpc.rs`).

use super::Repro;
use crate::cpu::GovernorSpec;
use crate::scenario::{
    ArrivalSpec, CellResult, ExecutorSpec, MatrixResult, PolicySpec, ScenarioMatrix,
    TopologySpec, WorkloadSpec,
};
use crate::util::stats::pct_change;
use crate::util::table::{fmt_f, Table};
use crate::workload::crypto::Isa;

/// One row of the runtime-specialization table, separated from the
/// runner so the golden-file test can pin the formatting on synthetic
/// values.
#[derive(Clone, Debug)]
pub struct RtRow {
    /// Runtime placement policy (`home-core`, `avx-steer(K)`, …).
    pub placement: String,
    /// Kernel scheduling policy underneath the executor.
    pub policy: String,
    pub governor: String,
    pub throughput_rps: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    /// Runtime-level lazy migrations per second (`avx-steer-lazy`).
    pub rt_migrations_per_sec: f64,
    /// Kernel-level migrations per second (the layer below).
    pub k_migrations_per_sec: f64,
    /// Energy per completed request (mJ).
    pub mj_per_req: f64,
}

impl RtRow {
    pub fn from_cell(c: &CellResult) -> RtRow {
        let r = &c.run;
        let placement = match &c.scenario.executor {
            ExecutorSpec::Tpc { placement } => placement.label(),
            ExecutorSpec::Kernel => "kernel".to_string(),
        };
        RtRow {
            placement,
            policy: c.scenario.policy.clone(),
            governor: c.scenario.governor.name().to_string(),
            throughput_rps: r.throughput_rps,
            p99_us: r.tail.p99_us,
            p999_us: r.tail.p999_us,
            rt_migrations_per_sec: r.runtime_migrations_per_sec,
            k_migrations_per_sec: r.migrations_per_sec,
            mj_per_req: r.j_per_req() * 1e3,
        }
    }
}

/// The runtime-vs-kernel comparison table (formatting contract pinned by
/// `rust/tests/golden/runtimespec_report.txt`).
pub fn table(rows: &[RtRow]) -> Table {
    let mut t = Table::new(
        "Runtime-level vs kernel-level core specialization",
        &[
            "placement", "policy", "governor", "req/s", "p99 µs", "p999 µs", "rt-migr/s",
            "k-migr/s", "mJ/req",
        ],
    );
    for r in rows {
        t.row(&[
            r.placement.clone(),
            r.policy.clone(),
            r.governor.clone(),
            fmt_f(r.throughput_rps, 0),
            fmt_f(r.p99_us, 0),
            fmt_f(r.p999_us, 0),
            fmt_f(r.rt_migrations_per_sec, 1),
            fmt_f(r.k_migrations_per_sec, 1),
            fmt_f(r.mj_per_req, 3),
        ]);
    }
    t
}

/// The matrix behind `repro runtimespec` (exposed so tests can shrink
/// its shape and pin the cross-thread determinism of the same code
/// path): the paper machine serving the bursty multi-tenant mix on the
/// uncompressed AVX-512 workload thread-per-core, under every placement
/// × {unmodified, core-spec} kernel policy × every governor.
pub fn matrix(quick: bool, base_seed: u64) -> ScenarioMatrix {
    let mut m = ScenarioMatrix::new(base_seed);
    m.topologies = vec![TopologySpec::single_socket_paper()];
    m.policies = vec![PolicySpec::Unmodified, PolicySpec::CoreSpec { avx_cores: 2 }];
    m.workloads = vec![WorkloadSpec::plain_page()];
    m.isas = vec![Isa::Avx512];
    m.arrivals = vec![ArrivalSpec::bursty_mix_default()];
    m.governors = GovernorSpec::all().to_vec();
    m.executors = crate::tpc::all_placements(2)
        .iter()
        .map(|&placement| ExecutorSpec::Tpc { placement })
        .collect();
    if quick {
        m.warmup = 150 * crate::sim::MS;
        m.measure = 300 * crate::sim::MS;
    } else {
        m.warmup = 500 * crate::sim::MS;
        m.measure = crate::sim::SEC;
    }
    m
}

/// Rows of an executed runtimespec matrix, in cell order.
pub fn rows(result: &MatrixResult) -> Vec<RtRow> {
    result.cells.iter().map(RtRow::from_cell).collect()
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let m = matrix(quick, seed);
    eprintln!(
        "[avxfreq] runtimespec: {} cells (3 placements × 2 kernel policies × 3 governors) \
         across up to {} threads…",
        m.len(),
        threads.min(m.len())
    );
    let result = m.run(threads);
    let rows = rows(&result);
    let t = table(&rows);

    let find = |placement: &str, policy: &str, gov: &str| {
        rows.iter()
            .find(|r| {
                r.placement.starts_with(placement)
                    && r.policy.starts_with(policy)
                    && r.governor == gov
            })
            .expect("grid cell present")
    };
    let mut notes = Vec::new();
    for gov in GovernorSpec::all() {
        let home = find("home-core", "unmodified", gov.name());
        let steer = find("avx-steer(", "unmodified", gov.name());
        let lazy = find("avx-steer-lazy(", "unmodified", gov.name());
        notes.push(format!(
            "{}: under an unmodified kernel, runtime steering moves p99 {:.0} → {:.0} µs \
             ({:+.1}%), lazy migration {:.0} µs at {:.0} rt-migr/s",
            gov.name(),
            home.p99_us,
            steer.p99_us,
            pct_change(home.p99_us, steer.p99_us),
            lazy.p99_us,
            lazy.rt_migrations_per_sec,
        ));
    }
    let kernel = find("home-core", "core-spec(", "intel-legacy");
    let runtime = find("avx-steer(", "unmodified", "intel-legacy");
    notes.push(format!(
        "head-to-head at intel-legacy: kernel core-spec under home-core reaches p99 {:.0} µs \
         at {:.1} k-migr/s; runtime avx-steer under an unmodified kernel reaches {:.0} µs \
         with no kernel support — the same mitigation, one layer up",
        kernel.p99_us, kernel.k_migrations_per_sec, runtime.p99_us,
    ));
    notes.push(
        "stacking both layers (avx-steer over core-spec) double-confines AVX work; compare \
         those rows to see whether the layers are redundant or complementary"
            .to_string(),
    );
    Repro { id: "runtimespec", tables: vec![t], notes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpc::PlacementSpec;

    #[test]
    fn matrix_covers_the_declared_grid() {
        let m = matrix(true, 1);
        assert_eq!(m.len(), 18, "3 placements × 2 kernel policies × 3 governors");
        let cells = m.cells();
        assert!(cells.iter().all(|c| matches!(c.executor, ExecutorSpec::Tpc { .. })));
        assert!(cells.iter().any(|c| c.policy.contains("core-spec")
            && c.governor == GovernorSpec::DimSilicon
            && c.executor
                == ExecutorSpec::Tpc {
                    placement: PlacementSpec::AvxSteerLazy { avx_cores: 2 }
                }));
        // Thread-per-core: every cell runs one worker per server core.
        assert!(cells.iter().all(|c| c.cfg.workers == c.cfg.cores));
    }

    #[test]
    fn row_labels_carry_both_layers() {
        let m = matrix(true, 2);
        let cells = m.cells();
        let r = RtRow {
            placement: "avx-steer(2)".to_string(),
            policy: "unmodified".to_string(),
            governor: "intel-legacy".to_string(),
            throughput_rps: 1.0,
            p99_us: 2.0,
            p999_us: 3.0,
            rt_migrations_per_sec: 0.0,
            k_migrations_per_sec: 0.0,
            mj_per_req: 0.5,
        };
        let t = table(&[r]);
        let text = t.render();
        assert!(text.contains("avx-steer(2)"));
        assert!(text.contains("rt-migr/s"));
        // Cell order interleaves the executor axis innermost: the first
        // three cells share the kernel policy and differ by placement.
        assert_eq!(cells[0].policy, cells[2].policy);
        assert_ne!(cells[0].executor, cells[1].executor);
    }
}
