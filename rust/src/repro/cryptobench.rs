//! Crypto microbenchmark (the "microbenchmark" group of Fig 2, and the
//! Cloudflare-style isolated throughput numbers from §1): all cores
//! continuously seal 16 KiB records; throughput in GB/s per ISA.

use super::Repro;
use crate::cpu::turbo::TurboTable;
use crate::sched::machine::{Action, Machine, MachineParams, NullDriver, TaskBody};
use crate::sched::{PolicyKind, TaskType};
use crate::sim::{Time, MS, SEC};
use crate::util::table::{fmt_f, Table};
use crate::util::Rng;
use crate::workload::crypto::{CryptoProfile, Isa};
use std::cell::RefCell;
use std::rc::Rc;

struct SealLoop {
    profile: CryptoProfile,
    rng: Rng,
    queue: Vec<Action>,
    bytes_done: Rc<RefCell<u64>>,
}

impl TaskBody for SealLoop {
    fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
        if let Some(a) = self.queue.pop() {
            return a;
        }
        const RECORD: usize = 16 * 1024;
        *self.bytes_done.borrow_mut() += RECORD as u64;
        let mut blocks = self.profile.record_blocks(RECORD, &mut self.rng);
        blocks.reverse(); // popped back-to-front
        self.queue = blocks
            .into_iter()
            .map(|(sym, block)| Action::Run {
                block,
                func: sym.as_ptr() as u64,
                stack: 0,
            })
            .collect();
        self.queue.pop().unwrap()
    }
}

/// Measured throughput for one ISA.
pub fn throughput_gbps(isa: Isa, quick: bool, seed: u64) -> f64 {
    let cores = 12;
    let mut mp = MachineParams::new(cores, PolicyKind::Unmodified);
    mp.turbo = TurboTable::xeon_gold_6130_no_cstates();
    mp.seed = seed;
    let mut m = Machine::new(mp);
    let bytes = Rc::new(RefCell::new(0u64));
    let mut rng = Rng::new(seed);
    for _ in 0..cores {
        m.spawn(
            TaskType::Untyped,
            0,
            Box::new(SealLoop {
                profile: CryptoProfile::for_isa(isa),
                rng: rng.fork(),
                queue: Vec::new(),
                bytes_done: bytes.clone(),
            }),
        );
    }
    let window = if quick { 300 * MS } else { SEC };
    m.run_until(window / 5, &mut NullDriver);
    let before = *bytes.borrow();
    m.run_until(window / 5 + window, &mut NullDriver);
    let done = *bytes.borrow() - before;
    done as f64 / (window as f64 / SEC as f64) / 1e9
}

pub fn run(quick: bool, seed: u64) -> Repro {
    let mut t = Table::new(
        "Crypto microbenchmark — ChaCha20-Poly1305 sealing, 12 cores (GB/s)",
        &["isa", "GB/s", "vs SSE4"],
    );
    let sse = throughput_gbps(Isa::Sse4, quick, seed);
    let mut notes = Vec::new();
    for isa in Isa::all() {
        let g = if isa == Isa::Sse4 { sse } else { throughput_gbps(isa, quick, seed) };
        t.row(&[isa.name().to_string(), fmt_f(g, 2), format!("{:.2}x", g / sse)]);
    }
    notes.push(
        "paper/Cloudflare reference: AVX-512 ≈ 2.9 GB/s vs AVX2 ≈ 1.6 GB/s in isolation; \
         shape target is AVX-512 > AVX2 > SSE4 despite the frequency drop"
            .to_string(),
    );
    Repro { id: "cryptobench", tables: vec![t], notes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avx512_fastest_in_isolation() {
        let sse = throughput_gbps(Isa::Sse4, true, 1);
        let avx2 = throughput_gbps(Isa::Avx2, true, 1);
        let avx512 = throughput_gbps(Isa::Avx512, true, 1);
        assert!(
            avx512 > avx2 && avx2 > sse,
            "microbench ordering: sse={sse:.2} avx2={avx2:.2} avx512={avx512:.2}"
        );
    }
}
