//! Paper-figure reproduction runners: one module per figure/table of the
//! evaluation, each returning [`Table`]s that `avxfreq repro <fig>`
//! prints and saves as CSV (see DESIGN.md §5 for the experiment index).

pub mod fig1_timeline;
pub mod fig2_sensitivity;
pub mod fig3_asymmetry;
pub mod fig5_throughput;
pub mod fig5_multisocket;
pub mod fig5tail;
pub mod energydelay;
pub mod runtimespec;
pub mod hybridspec;
pub mod fig6_frequency;
pub mod fig7_overhead;
pub mod fleetscale;
pub mod fleetvar;
pub mod faulttol;
pub mod ipc_table;
pub mod cryptobench;
pub mod ablations;

use crate::util::table::Table;

/// A reproduced experiment: tables plus free-form notes comparing against
/// the paper's reported values.
pub struct Repro {
    pub id: &'static str,
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
}

impl Repro {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str("note: ");
            out.push_str(n);
            out.push('\n');
        }
        out
    }

    pub fn save_csvs(&self) -> anyhow::Result<()> {
        for (i, t) in self.tables.iter().enumerate() {
            let name = if self.tables.len() == 1 {
                self.id.to_string()
            } else {
                format!("{}_{}", self.id, i)
            };
            t.save_csv(&name)?;
        }
        Ok(())
    }
}

/// All experiment ids, in paper order (`fig5ms` is the multi-socket
/// extension of fig5, `fig5tail` its tail-latency restatement,
/// `fleetvar` its fleet-scale restatement as cross-machine p99 variance
/// under round-robin vs AVX-aware routing, `fleetscale` the max-of-n
/// amplification of that variance under a bulk-synchronous collective
/// as the fleet grows, `energydelay` the
/// energy-delay-product restatement across DVFS governors, and
/// `runtimespec` the runtime-level vs kernel-level core-specialization
/// head-to-head through the thread-per-core executor, and `hybridspec`
/// the hybrid P/E-core machine vs the homogeneous baseline under
/// {unmodified, core-spec, class-native} with per-module harmonic-mean
/// frequencies, and `faulttol` the closed-vs-open-loop recovery
/// comparison under an identical deterministic fault schedule).
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "fig5", "fig5ms", "fig5tail", "fleetvar", "fleetscale",
    "faulttol", "energydelay", "runtimespec", "hybridspec", "fig6", "ipc", "fig7",
    "cryptobench", "ablations",
];

/// Dispatch by id. `quick` trades precision for speed (shorter windows).
pub fn run(id: &str, quick: bool, seed: u64) -> anyhow::Result<Repro> {
    match id {
        "fig1" => Ok(fig1_timeline::run()),
        "fig2" => Ok(fig2_sensitivity::run(quick, seed)),
        "fig3" => Ok(fig3_asymmetry::run()),
        "fig5" => Ok(fig5_throughput::run(quick, seed)),
        "fig5ms" => Ok(fig5_multisocket::run(quick, seed)),
        "fig5tail" => Ok(fig5tail::run(quick, seed)),
        "fleetvar" => Ok(fleetvar::run(quick, seed)),
        "fleetscale" => Ok(fleetscale::run(quick, seed)),
        "faulttol" => Ok(faulttol::run(quick, seed)),
        "energydelay" => Ok(energydelay::run(quick, seed)),
        "runtimespec" => Ok(runtimespec::run(quick, seed)),
        "hybridspec" => Ok(hybridspec::run(quick, seed)),
        "fig6" => Ok(fig6_frequency::run(quick, seed)),
        "ipc" => Ok(ipc_table::run(quick, seed)),
        "fig7" => Ok(fig7_overhead::run(quick)),
        "cryptobench" => Ok(cryptobench::run(quick, seed)),
        "ablations" => Ok(ablations::run(quick, seed)),
        _ => anyhow::bail!("unknown experiment `{id}`; known: {ALL:?}"),
    }
}
