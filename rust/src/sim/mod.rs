//! Discrete-event simulation engine.
//!
//! The machine model ([`crate::cpu`]) and scheduler ([`crate::sched`]) are
//! driven by a single event queue with a nanosecond clock. The engine is
//! deliberately generic and small: events are an enum supplied by the
//! machine, ordering is `(time, sequence)` so simulation is deterministic
//! for a given seed (property-tested in `testkit`).

pub mod queue;

pub use queue::EventQueue;

/// Simulation time in nanoseconds since simulation start.
pub type Time = u64;

/// Nanoseconds per microsecond/millisecond/second — avoids magic numbers.
pub const US: Time = 1_000;
pub const MS: Time = 1_000_000;
pub const SEC: Time = 1_000_000_000;

/// Format a time as a human-readable string (for traces and logs).
pub fn fmt_time(t: Time) -> String {
    if t >= SEC {
        format!("{:.3}s", t as f64 / SEC as f64)
    } else if t >= MS {
        format!("{:.3}ms", t as f64 / MS as f64)
    } else if t >= US {
        format!("{:.3}µs", t as f64 / US as f64)
    } else {
        format!("{t}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(500), "500ns");
        assert_eq!(fmt_time(1_500), "1.500µs");
        assert_eq!(fmt_time(2 * MS), "2.000ms");
        assert_eq!(fmt_time(3 * SEC), "3.000s");
    }
}
