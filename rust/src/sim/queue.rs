//! Deterministic event queue: a binary heap ordered by `(time, seq)`.
//!
//! The `seq` tie-breaker guarantees that events scheduled at the same
//! simulated instant pop in insertion order regardless of heap internals —
//! the foundation of the simulator's reproducibility guarantee.

use super::Time;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// An event queue over an arbitrary payload type `E`.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: Time,
}

#[derive(Debug)]
struct Entry<E> {
    at: Time,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0 }
    }

    /// Current simulation time: the timestamp of the last popped event.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past is a bug
    /// in the machine model, so it panics rather than silently reordering.
    pub fn schedule_at(&mut self, at: Time, ev: E) {
        debug_assert!(at >= self.now, "event scheduled in the past: {at} < {}", self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { at: at.max(self.now), seq, ev }));
    }

    /// Schedule `ev` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, ev: E) {
        self.schedule_at(self.now.saturating_add(delay), ev);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let Reverse(e) = self.heap.pop()?;
        self.now = e.at;
        Some((e.at, e.ev))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_at_same_instant() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(5, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(10, ());
        q.schedule_at(10, ());
        q.schedule_at(25, ());
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 25);
    }

    #[test]
    fn relative_scheduling() {
        let mut q = EventQueue::new();
        q.schedule_at(100, 1u32);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 100);
        q.schedule_in(50, 2u32);
        let (t2, e) = q.pop().unwrap();
        assert_eq!((t2, e), (150, 2));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn past_scheduling_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(100, ());
        q.pop();
        q.schedule_at(50, ());
    }
}
