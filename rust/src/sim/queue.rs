//! Deterministic event queue ordered by `(time, seq)`.
//!
//! The `seq` tie-breaker guarantees that events scheduled at the same
//! simulated instant pop in insertion order regardless of container
//! internals — the foundation of the simulator's reproducibility
//! guarantee.
//!
//! Two implementations share that contract:
//!
//! * [`EventQueue`] — the production container: a *calendar queue*
//!   (Brown 1988) tuned for the machine loop's access pattern, where
//!   almost every event lands a few microseconds ahead of the clock.
//!   Inserts are O(1); pops scan the handful of entries sharing the
//!   clock's current 4 µs bucket. When the queue goes sparse (events
//!   milliseconds out), the search falls back to one direct sweep over
//!   all buckets rather than spinning bucket-by-bucket through empty
//!   "days".
//! * [`reference::HeapQueue`] — the original `BinaryHeap` ordered by
//!   `Reverse<(time, seq)>`, kept verbatim as the obviously-correct
//!   reference. The property suite in `rust/tests/perf_equiv.rs` drives
//!   both with arbitrary schedule/pop interleavings (including
//!   same-instant FIFO bursts) and requires identical pop streams.
//!
//! Both containers pop the global minimum under the `(time, seq)` total
//! order, so they are observationally equivalent by construction; the
//! calendar only changes *where* entries wait.

use super::Time;

/// Bucket width exponent: 2^12 ns ≈ 4.1 µs per bucket — a few block
/// executions. Chosen so the dense near-future events (Step boundaries,
/// IPIs, arrivals under load) land in the current or next bucket.
const BUCKET_BITS: u32 = 12;
/// Bucket count (power of two). One full wheel revolution ("year")
/// covers ~1.05 ms — about one scheduler quantum of look-ahead before
/// the sparse fallback kicks in.
const N_BUCKETS: usize = 256;
const BUCKET_MASK: u64 = (N_BUCKETS as u64) - 1;

#[derive(Clone, Debug)]
struct Entry<E> {
    at: Time,
    seq: u64,
    ev: E,
}

/// An event queue over an arbitrary payload type `E`.
///
/// `Clone` (for `E: Clone`) is the snapshot primitive behind checkpoint
/// forking ([`crate::scenario`]): every field — the calendar buckets,
/// `len`, the `seq` counter, `now`, the epoch cursor, and the cached
/// minimum — is plain data, so a clone resumes popping at the exact
/// `(time, seq)` continuation the original would have taken. Pinned by
/// `clone_resumes_exact_time_seq_continuation` below.
#[derive(Clone, Debug)]
pub struct EventQueue<E> {
    /// `buckets[(at >> BUCKET_BITS) & BUCKET_MASK]`, unsorted within a
    /// bucket: pops *select* the `(time, seq)` minimum, so insertion
    /// order inside the vec is irrelevant and removal can `swap_remove`.
    buckets: Vec<Vec<Entry<E>>>,
    len: usize,
    seq: u64,
    now: Time,
    /// Epoch (`at >> BUCKET_BITS`) where the minimum search resumes.
    /// Monotone: every live entry's epoch is ≥ this (pushes clamp to
    /// `now`, pops advance it to the popped entry's epoch).
    epoch: u64,
    /// Cached `(time, seq)` of the current queue minimum; `None` when
    /// the cache is dirty (after a pop) or the queue is empty.
    min: Option<(Time, u64)>,
    /// Past-dated schedules clamped to `now` (see [`EventQueue::schedule_at`]).
    clamped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            buckets: (0..N_BUCKETS).map(|_| Vec::new()).collect(),
            len: 0,
            seq: 0,
            now: 0,
            epoch: 0,
            min: None,
            clamped: 0,
        }
    }

    /// Current simulation time: the timestamp of the last popped event.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Schedule `ev` at absolute time `at`.
    ///
    /// Scheduling in the past is a bug in the machine model, so debug
    /// builds panic. Release builds *clamp the event to `now`* — it
    /// fires as the next event at the current instant, after anything
    /// already queued there (its `seq` is newer) — and count the clamp
    /// in [`EventQueue::clamped`] so harnesses can assert the counter
    /// stays zero. Clamping keeps the clock monotone: a past-dated
    /// entry would otherwise pop first and drag `now` backwards.
    pub fn schedule_at(&mut self, at: Time, ev: E) {
        debug_assert!(at >= self.now, "event scheduled in the past: {at} < {}", self.now);
        let at = if at < self.now {
            self.clamped += 1;
            self.now
        } else {
            at
        };
        let seq = self.seq;
        self.seq += 1;
        if match self.min {
            None => self.len == 0,
            Some(m) => (at, seq) < m,
        } {
            // An empty queue's new sole entry, or a new global minimum,
            // refreshes the cache; a dirty cache (post-pop, len > 0)
            // stays dirty — other entries may be smaller.
            self.min = Some((at, seq));
        }
        self.buckets[((at >> BUCKET_BITS) & BUCKET_MASK) as usize].push(Entry { at, seq, ev });
        self.len += 1;
    }

    /// Schedule `ev` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, ev: E) {
        self.schedule_at(self.now.saturating_add(delay), ev);
    }

    /// Locate (and cache) the `(time, seq)` minimum without removing it.
    fn find_min(&mut self) -> Option<(Time, u64)> {
        if self.len == 0 {
            return None;
        }
        if self.min.is_some() {
            return self.min;
        }
        // Walk forward from the current epoch; an entry *belongs* to the
        // wheel position only if its full epoch matches (entries from
        // future "years" share the bucket but are skipped).
        let mut epoch = self.epoch;
        for _ in 0..N_BUCKETS {
            let bucket = &self.buckets[(epoch & BUCKET_MASK) as usize];
            let mut best: Option<(Time, u64)> = None;
            for e in bucket {
                if e.at >> BUCKET_BITS == epoch {
                    let key = (e.at, e.seq);
                    if best.map_or(true, |b| key < b) {
                        best = Some(key);
                    }
                }
            }
            if best.is_some() {
                self.epoch = epoch;
                self.min = best;
                return best;
            }
            epoch += 1;
        }
        // A whole revolution came up empty: the queue is sparse with
        // everything ≥ one year out. One direct sweep finds the true
        // minimum (cheap: N_BUCKETS mostly-empty vecs).
        let mut best: Option<(Time, u64)> = None;
        for bucket in &self.buckets {
            for e in bucket {
                let key = (e.at, e.seq);
                if best.map_or(true, |b| key < b) {
                    best = Some(key);
                }
            }
        }
        let b = best.expect("len > 0 but no entry found");
        self.epoch = b.0 >> BUCKET_BITS;
        self.min = best;
        best
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let (at, seq) = self.find_min()?;
        let bucket = &mut self.buckets[((at >> BUCKET_BITS) & BUCKET_MASK) as usize];
        let pos = bucket
            .iter()
            .position(|e| e.seq == seq)
            .expect("cached minimum must be present in its bucket");
        let entry = bucket.swap_remove(pos);
        self.len -= 1;
        self.min = None;
        self.now = at;
        self.epoch = at >> BUCKET_BITS;
        Some((at, entry.ev))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&mut self) -> Option<Time> {
        self.find_min().map(|(at, _)| at)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of past-dated `schedule_at` calls clamped to `now` (always
    /// 0 in debug builds, which panic instead).
    pub fn clamped(&self) -> u64 {
        self.clamped
    }
}

/// The original `BinaryHeap` event queue, kept as the debug/differential
/// reference implementation for the calendar queue above.
pub mod reference {
    use super::super::Time;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Reference queue: a binary heap ordered by `(time, seq)`.
    #[derive(Debug)]
    pub struct HeapQueue<E> {
        heap: BinaryHeap<Reverse<Entry<E>>>,
        seq: u64,
        now: Time,
    }

    #[derive(Debug)]
    struct Entry<E> {
        at: Time,
        seq: u64,
        ev: E,
    }

    impl<E> PartialEq for Entry<E> {
        fn eq(&self, other: &Self) -> bool {
            self.at == other.at && self.seq == other.seq
        }
    }
    impl<E> Eq for Entry<E> {}
    impl<E> PartialOrd for Entry<E> {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl<E> Ord for Entry<E> {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            (self.at, self.seq).cmp(&(other.at, other.seq))
        }
    }

    impl<E> Default for HeapQueue<E> {
        fn default() -> Self {
            Self::new()
        }
    }

    impl<E> HeapQueue<E> {
        pub fn new() -> Self {
            HeapQueue { heap: BinaryHeap::new(), seq: 0, now: 0 }
        }

        pub fn now(&self) -> Time {
            self.now
        }

        /// Same clamp semantics as [`super::EventQueue::schedule_at`]
        /// (minus the counter): past-dated events panic in debug and
        /// clamp to `now` in release.
        pub fn schedule_at(&mut self, at: Time, ev: E) {
            debug_assert!(at >= self.now, "event scheduled in the past: {at} < {}", self.now);
            let seq = self.seq;
            self.seq += 1;
            self.heap.push(Reverse(Entry { at: at.max(self.now), seq, ev }));
        }

        pub fn schedule_in(&mut self, delay: Time, ev: E) {
            self.schedule_at(self.now.saturating_add(delay), ev);
        }

        pub fn pop(&mut self) -> Option<(Time, E)> {
            let Reverse(e) = self.heap.pop()?;
            self.now = e.at;
            Some((e.at, e.ev))
        }

        pub fn peek_time(&self) -> Option<Time> {
            self.heap.peek().map(|Reverse(e)| e.at)
        }

        pub fn len(&self) -> usize {
            self.heap.len()
        }

        pub fn is_empty(&self) -> bool {
            self.heap.is_empty()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::reference::HeapQueue;
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_at_same_instant() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(5, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(10, ());
        q.schedule_at(10, ());
        q.schedule_at(25, ());
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 25);
    }

    #[test]
    fn relative_scheduling() {
        let mut q = EventQueue::new();
        q.schedule_at(100, 1u32);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 100);
        q.schedule_in(50, 2u32);
        let (t2, e) = q.pop().unwrap();
        assert_eq!((t2, e), (150, 2));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn past_scheduling_panics_in_debug() {
        let mut q = EventQueue::new();
        q.schedule_at(100, ());
        q.pop();
        q.schedule_at(50, ());
    }

    /// Release-profile contract: a past-dated event clamps to `now`
    /// (popping next at the current instant, after anything already
    /// queued there), the clock never runs backwards, and the clamp is
    /// counted. `ci.sh` runs the suites under `--release`, where the
    /// debug assertion above compiles out and this test compiles in.
    #[test]
    #[cfg(not(debug_assertions))]
    fn past_scheduling_clamps_in_release() {
        let mut q = EventQueue::new();
        q.schedule_at(100, "first");
        q.pop();
        assert_eq!(q.clamped(), 0);
        q.schedule_at(100, "same-instant");
        q.schedule_at(50, "late"); // past-dated: clamps to now = 100
        assert_eq!(q.clamped(), 1);
        let (t1, e1) = q.pop().unwrap();
        assert_eq!((t1, e1), (100, "same-instant"), "clamped event keeps FIFO order");
        let (t2, e2) = q.pop().unwrap();
        assert_eq!((t2, e2), (100, "late"), "clamped event fires at now, not in the past");
        assert_eq!(q.now(), 100);
    }

    #[test]
    fn far_future_events_across_wheel_years() {
        // Events far beyond one wheel revolution (~1 ms) exercise the
        // sparse direct-sweep fallback and the same-bucket/different-
        // epoch filtering (entries a whole "year" apart share a bucket).
        let year = (N_BUCKETS as u64) << BUCKET_BITS;
        let mut q = EventQueue::new();
        q.schedule_at(7 * year + 12, "far");
        q.schedule_at(12, "near"); // same wheel position, 7 years earlier
        q.schedule_at(3 * year, "mid");
        assert_eq!(q.peek_time(), Some(12));
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["near", "mid", "far"]);
        assert_eq!(q.now(), 7 * year + 12);
    }

    #[test]
    fn interleaved_push_pop_matches_reference() {
        // Deterministic pseudo-random interleaving: both containers see
        // the same schedule/pop stream and must emit identical pops.
        // (The full property, with shrinking, lives in
        // rust/tests/perf_equiv.rs.)
        let mut cal = EventQueue::new();
        let mut heap = HeapQueue::new();
        let mut x = 0x243F_6A88_85A3_08D3u64;
        for i in 0..5_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            if x % 3 == 0 {
                let a = cal.pop();
                let b = heap.pop();
                assert_eq!(a, b, "pop #{i} diverged");
            } else {
                // Mix of immediate, near, and multi-year-out delays,
                // plus same-instant bursts (delay 0).
                let delay = match x % 7 {
                    0 | 1 => 0,
                    2 | 3 | 4 => x % 10_000,
                    5 => x % 1_000_000,
                    _ => x % 50_000_000,
                };
                cal.schedule_in(delay, i);
                heap.schedule_in(delay, i);
            }
            assert_eq!(cal.len(), heap.len());
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn clone_resumes_exact_time_seq_continuation() {
        // Drive a queue to an arbitrary mid-run point, clone it, then
        // feed both halves the same schedule/pop suffix: the pop streams
        // (time AND payload, which encodes seq order) must be identical,
        // including FIFO ties at shared instants. This is the snapshot
        // contract checkpoint forking builds on.
        let mut q = EventQueue::new();
        let mut x = 0x9E37_79B9_7F4A_7C15u64;
        for i in 0..1_000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            if x % 4 == 0 {
                q.pop();
            } else {
                q.schedule_in(x % 20_000, i);
            }
        }
        let mut fork = q.clone();
        assert_eq!(fork.now(), q.now());
        assert_eq!(fork.len(), q.len());
        // Same suffix applied to both — seq counters must already agree,
        // so same-instant FIFO ordering is preserved across the clone.
        for i in 0..500u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            if x % 3 == 0 {
                assert_eq!(q.pop(), fork.pop(), "pop #{i} diverged after clone");
            } else {
                let delay = x % 10_000;
                q.schedule_in(delay, 1_000 + i);
                fork.schedule_in(delay, 1_000 + i);
            }
        }
        loop {
            let (a, b) = (q.pop(), fork.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn len_and_empty_track() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.schedule_at(1, ());
        q.schedule_at(2, ());
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }
}
