//! Instruction-block IR — the "machine code" of the simulated CPU.
//!
//! The paper's effect is driven entirely by the *instruction class mix* a
//! core executes (density of heavy AVX2 / AVX-512 operations per cycle),
//! not by the semantics of individual instructions. The IR therefore
//! models code as basic blocks annotated with per-class instruction
//! counts, grouped into named functions and binaries. The same IR feeds
//! three consumers:
//!
//! * the core model executes blocks (cycles from the IPC model, license
//!   demand from the class densities),
//! * the static analyzer ([`crate::analysis::static_analysis`]) computes
//!   the paper's AVX-ratio report over functions,
//! * the flame-graph sampler attributes PMU counter cycles to call stacks.

pub mod block;
pub mod function;
pub mod binary;

pub use binary::{Binary, FunctionId};
pub use block::{Block, ClassMix, InsnClass};
pub use function::Function;
