//! Binaries: collections of functions, the unit the static analyzer
//! "disassembles" (paper §3.3 disassembles the application plus all
//! dynamically linked libraries — we model each as a `Binary`).

use super::function::Function;
use std::collections::BTreeMap;

/// Index of a function within a [`Binary`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FunctionId(pub usize);

/// A simulated executable or shared library.
#[derive(Clone, Debug, Default)]
pub struct Binary {
    pub name: String,
    pub functions: Vec<Function>,
    by_name: BTreeMap<String, usize>,
}

impl Binary {
    pub fn new(name: &str) -> Self {
        Binary { name: name.to_string(), ..Default::default() }
    }

    pub fn add(&mut self, f: Function) -> FunctionId {
        assert!(
            !self.by_name.contains_key(&f.name),
            "duplicate function `{}` in binary `{}`",
            f.name,
            self.name
        );
        let id = self.functions.len();
        self.by_name.insert(f.name.clone(), id);
        self.functions.push(f);
        FunctionId(id)
    }

    pub fn get(&self, id: FunctionId) -> &Function {
        &self.functions[id.0]
    }

    pub fn lookup(&self, name: &str) -> Option<FunctionId> {
        self.by_name.get(name).copied().map(FunctionId)
    }

    pub fn len(&self) -> usize {
        self.functions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.functions.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (FunctionId, &Function)> {
        self.functions.iter().enumerate().map(|(i, f)| (FunctionId(i), f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::block::{Block, ClassMix};

    #[test]
    fn add_lookup_roundtrip() {
        let mut b = Binary::new("libcrypto.so");
        let id = b.add(Function::new("poly1305_blocks").push(Block::new(ClassMix::scalar(10))));
        assert_eq!(b.lookup("poly1305_blocks"), Some(id));
        assert_eq!(b.get(id).name, "poly1305_blocks");
        assert!(b.lookup("missing").is_none());
    }

    #[test]
    #[should_panic]
    fn duplicate_name_rejected() {
        let mut b = Binary::new("x");
        b.add(Function::new("f"));
        b.add(Function::new("f"));
    }
}
