//! Basic blocks and instruction classes.

/// Instruction classes that matter for power licensing (Intel SDM §15.26,
/// Xeon Scalable Specification Update).
///
/// * `Scalar` — everything ≤128-bit including SSE4: never affects licenses.
/// * `Avx2Light` — 256-bit loads/stores/integer: license level 0.
/// * `Avx2Heavy` — 256-bit FP multiply/FMA: license level 1.
/// * `Avx512Light` — 512-bit non-multiply: license level 1.
/// * `Avx512Heavy` — 512-bit FP multiply/FMA: license level 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum InsnClass {
    Scalar,
    Avx2Light,
    Avx2Heavy,
    Avx512Light,
    Avx512Heavy,
}

pub const N_CLASSES: usize = 5;

pub const ALL_CLASSES: [InsnClass; N_CLASSES] = [
    InsnClass::Scalar,
    InsnClass::Avx2Light,
    InsnClass::Avx2Heavy,
    InsnClass::Avx512Light,
    InsnClass::Avx512Heavy,
];

impl InsnClass {
    pub fn index(self) -> usize {
        match self {
            InsnClass::Scalar => 0,
            InsnClass::Avx2Light => 1,
            InsnClass::Avx2Heavy => 2,
            InsnClass::Avx512Light => 3,
            InsnClass::Avx512Heavy => 4,
        }
    }

    /// Does this class touch a 256-bit or wider register? (What the static
    /// analyzer counts for the paper's AVX-instruction ratio.)
    pub fn is_wide(self) -> bool {
        !matches!(self, InsnClass::Scalar)
    }

    pub fn name(self) -> &'static str {
        match self {
            InsnClass::Scalar => "scalar",
            InsnClass::Avx2Light => "avx2-light",
            InsnClass::Avx2Heavy => "avx2-heavy",
            InsnClass::Avx512Light => "avx512-light",
            InsnClass::Avx512Heavy => "avx512-heavy",
        }
    }
}

/// Per-class instruction counts of one basic block execution.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassMix {
    pub counts: [u64; N_CLASSES],
}

impl ClassMix {
    pub fn scalar(n: u64) -> Self {
        let mut m = ClassMix::default();
        m.counts[InsnClass::Scalar.index()] = n;
        m
    }

    pub fn of(class: InsnClass, n: u64) -> Self {
        let mut m = ClassMix::default();
        m.counts[class.index()] = n;
        m
    }

    pub fn with(mut self, class: InsnClass, n: u64) -> Self {
        self.counts[class.index()] += n;
        self
    }

    pub fn get(&self, class: InsnClass) -> u64 {
        self.counts[class.index()]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Instructions touching 256-bit+ registers (numerator of the paper's
    /// static-analysis ratio).
    pub fn wide(&self) -> u64 {
        ALL_CLASSES.iter().filter(|c| c.is_wide()).map(|c| self.get(*c)).sum()
    }

    /// Ratio of wide-register instructions to all instructions.
    pub fn wide_ratio(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            self.wide() as f64 / t as f64
        }
    }

    pub fn add(&mut self, other: &ClassMix) {
        for i in 0..N_CLASSES {
            self.counts[i] += other.counts[i];
        }
    }

    /// Scale all counts by an integer factor (loop trip counts).
    pub fn times(mut self, k: u64) -> Self {
        for c in self.counts.iter_mut() {
            *c *= k;
        }
        self
    }
}

/// A basic block: an instruction mix plus memory/branch metadata that the
/// IPC model consumes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Block {
    pub mix: ClassMix,
    /// Memory operations (drive the stall model).
    pub mem_ops: u64,
    /// Branches (drive the misprediction model).
    pub branches: u64,
    /// True if this block's wide-instruction stream does *not* sustain the
    /// hardware's license-trigger condition: the burst retires before the
    /// ~100-instruction detection window closes, or dependency stalls
    /// lower the per-cycle density (paper §2 / §3.3 — "pipeline stalls …
    /// can cause the vector instruction frequency to be decreased enough
    /// to prevent frequency changes"). Exempt blocks still *execute* wide
    /// instructions (the static analyzer sees them) but never demand a
    /// license.
    pub license_exempt: bool,
}

impl Block {
    pub fn new(mix: ClassMix) -> Self {
        // Default metadata: typical integer code is ~1 branch / 6 insns and
        // ~1 memory op / 3.5 insns; workload builders override as needed.
        let total = mix.total();
        Block { mix, mem_ops: total / 4, branches: total / 6, license_exempt: false }
    }

    pub fn with_mem(mut self, mem_ops: u64) -> Self {
        self.mem_ops = mem_ops;
        self
    }

    pub fn with_branches(mut self, branches: u64) -> Self {
        self.branches = branches;
        self
    }

    pub fn insns(&self) -> u64 {
        self.mix.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_indices_unique() {
        let mut seen = [false; N_CLASSES];
        for c in ALL_CLASSES {
            assert!(!seen[c.index()]);
            seen[c.index()] = true;
        }
    }

    #[test]
    fn wide_ratio() {
        let m = ClassMix::scalar(900).with(InsnClass::Avx512Heavy, 100);
        assert_eq!(m.total(), 1000);
        assert_eq!(m.wide(), 100);
        assert!((m.wide_ratio() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn empty_mix_ratio_zero() {
        assert_eq!(ClassMix::default().wide_ratio(), 0.0);
    }

    #[test]
    fn times_scales() {
        let m = ClassMix::scalar(10).with(InsnClass::Avx2Heavy, 5).times(3);
        assert_eq!(m.get(InsnClass::Scalar), 30);
        assert_eq!(m.get(InsnClass::Avx2Heavy), 15);
    }

    #[test]
    fn block_defaults() {
        let b = Block::new(ClassMix::scalar(600));
        assert_eq!(b.branches, 100);
        assert_eq!(b.mem_ops, 150);
        assert_eq!(b.insns(), 600);
    }
}
