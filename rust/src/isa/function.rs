//! Functions: named sequences of basic blocks with static call edges.
//!
//! Function granularity is what the paper's tooling operates on: the
//! static analyzer ranks *functions* by AVX ratio, and the flame graph
//! attributes THROTTLE cycles to *call stacks* of functions.

use super::block::{Block, ClassMix};

/// A named function in a simulated binary.
#[derive(Clone, Debug, Default)]
pub struct Function {
    pub name: String,
    pub blocks: Vec<Block>,
    /// Static call sites (indices into the owning binary), used by the
    /// analyzer to print call-graph context.
    pub callees: Vec<usize>,
}

impl Function {
    pub fn new(name: &str) -> Self {
        Function { name: name.to_string(), ..Default::default() }
    }

    pub fn push(mut self, block: Block) -> Self {
        self.blocks.push(block);
        self
    }

    /// Aggregate instruction mix over all blocks (static view: each block
    /// counted once — trip counts are a dynamic property).
    pub fn static_mix(&self) -> ClassMix {
        let mut m = ClassMix::default();
        for b in &self.blocks {
            m.add(&b.mix);
        }
        m
    }

    /// The paper's §3.3 metric: instructions accessing 256/512-bit
    /// registers over total instructions.
    pub fn avx_ratio(&self) -> f64 {
        self.static_mix().wide_ratio()
    }

    pub fn insns(&self) -> u64 {
        self.static_mix().total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::block::InsnClass;

    #[test]
    fn static_mix_aggregates() {
        let f = Function::new("chacha20_avx512")
            .push(Block::new(ClassMix::scalar(50)))
            .push(Block::new(ClassMix::of(InsnClass::Avx512Heavy, 200).with(InsnClass::Scalar, 50)));
        assert_eq!(f.insns(), 300);
        assert!((f.avx_ratio() - 200.0 / 300.0).abs() < 1e-12);
    }

    #[test]
    fn scalar_function_zero_ratio() {
        let f = Function::new("ngx_http_process_request").push(Block::new(ClassMix::scalar(1000)));
        assert_eq!(f.avx_ratio(), 0.0);
    }
}
