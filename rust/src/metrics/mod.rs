//! Run-level reporting: turn machine counters and workload results into
//! the tables the repro harness prints and saves, including the unified
//! scenario-matrix comparison table ([`matrix_report`]).

use crate::bench::BenchRow;
use crate::cpu::PerfCounters;
use crate::fleet::{FleetRun, HierFleetRun};
use crate::scenario::CellResult;
use crate::sched::machine::Machine;
use crate::util::table::{fmt_f, Table};

/// `avxfreq bench` summary: one row per scenario, both legs plus the
/// speedup ratio and the output-equivalence verdict. Wall-clock columns
/// are machine-dependent; the ratio column is the comparable figure
/// (see `rust/tests/README.md` § bench triage).
pub fn bench_report(rows: &[BenchRow]) -> Table {
    let mut t = Table::new(
        "bench — simulated ns per wall-second, fast paths on vs off",
        &[
            "scenario",
            "sim-ms",
            "fast wall-s",
            "fast sim-ns/s",
            "base wall-s",
            "base sim-ns/s",
            "speedup",
            "outputs",
        ],
    );
    for r in rows {
        t.row(&[
            r.scenario.clone(),
            format!("{:.0}", r.fast.sim_ns as f64 / 1e6),
            format!("{:.2}", r.fast.wall_s),
            format!("{:.3e}", r.fast.sim_ns_per_wall_s()),
            format!("{:.2}", r.baseline.wall_s),
            format!("{:.3e}", r.baseline.sim_ns_per_wall_s()),
            format!("{:.2}x", r.speedup()),
            (if r.outputs_identical { "identical" } else { "DIVERGED" }).to_string(),
        ]);
    }
    t
}

/// One row of the [`energy_report`] table: the energy accounting of one
/// scope (a core, a machine, a fleet machine, or a whole cluster).
/// Separated from the simulator so the golden-file test can pin the
/// formatting on synthetic values (same pattern as
/// [`crate::repro::fleetvar::RouterVar`]).
#[derive(Clone, Debug)]
pub struct EnergyRow {
    /// What this row accounts: `core3`, `machine`, `m0`, `cluster`, or
    /// a scenario label.
    pub scope: String,
    /// Governor the scope ran under.
    pub governor: String,
    /// Energy consumed while executing (J).
    pub active_j: f64,
    /// Energy consumed while idle (J).
    pub idle_j: f64,
    /// Completed requests attributed to this scope; 0 when requests are
    /// not attributable (per-core rows), rendering the per-request
    /// columns as `-`.
    pub completed: u64,
    /// Measurement window (s).
    pub secs: f64,
}

impl EnergyRow {
    pub fn total_j(&self) -> f64 {
        self.active_j + self.idle_j
    }

    /// Average power over the window (W).
    pub fn avg_w(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.total_j() / self.secs
        }
    }

    /// Energy per completed request (mJ).
    pub fn mj_per_req(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.total_j() / self.completed as f64 * 1e3
        }
    }

    /// Perf-per-watt: completed requests per Joule (== req/s per W).
    pub fn req_per_j(&self) -> f64 {
        if self.total_j() <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.total_j()
        }
    }
}

/// Per-core frequency/licensing breakdown of a finished run (Fig 6's
/// underlying data).
pub fn core_report(m: &Machine) -> Table {
    let mut t = Table::new(
        "Per-core frequency & license residency",
        &["core", "avg GHz", "busy %", "L0 %", "L1 %", "L2 %", "throttle %", "requests"],
    );
    for c in &m.cores {
        let p = &c.perf;
        let total_ns = (p.busy_ns + p.idle_ns).max(1);
        let share = p.license_time_share();
        t.row(&[
            c.id.to_string(),
            fmt_f(p.avg_busy_ghz(), 3),
            fmt_f(p.busy_ns as f64 / total_ns as f64 * 100.0, 1),
            fmt_f(share[0] * 100.0, 1),
            fmt_f(share[1] * 100.0, 1),
            fmt_f(share[2] * 100.0, 1),
            fmt_f(p.throttle_ratio() * 100.0, 2),
            p.license_requests.to_string(),
        ]);
    }
    t
}

/// Scheduler activity summary.
pub fn sched_report(m: &Machine, secs: f64) -> Table {
    let s = &m.sched.stats;
    let mut t = Table::new("Scheduler activity", &["metric", "total", "per second"]);
    for (name, v) in [
        ("picks", s.picks),
        ("steals", s.steals),
        ("migrations", s.migrations),
        ("cross-socket migrations", s.cross_socket_migrations),
        ("type changes", s.type_changes),
        ("forced suspends", s.forced_suspends),
        ("IPIs", s.ipis),
        ("preemptions", s.preemptions),
    ] {
        t.row(&[name.to_string(), v.to_string(), fmt_f(v as f64 / secs, 1)]);
    }
    t
}

/// Machine-wide PMU summary.
pub fn perf_report(total: &PerfCounters) -> Table {
    let mut t = Table::new("Aggregate PMU counters", &["counter", "value"]);
    let rows: Vec<(&str, String)> = vec![
        ("instructions", total.instructions.to_string()),
        ("cycles", total.cycles.to_string()),
        ("IPC", fmt_f(total.ipc(), 3)),
        ("branches", total.branches.to_string()),
        ("mispredicts", total.mispredicts.to_string()),
        (
            "mispredict rate",
            fmt_f(total.mispredicts as f64 / total.branches.max(1) as f64 * 100.0, 2),
        ),
        ("CORE_POWER.LVL0_TURBO_LICENSE", total.license_cycles[0].to_string()),
        ("CORE_POWER.LVL1_TURBO_LICENSE", total.license_cycles[1].to_string()),
        ("CORE_POWER.LVL2_TURBO_LICENSE", total.license_cycles[2].to_string()),
        ("CORE_POWER.THROTTLE", total.throttle_cycles.to_string()),
        ("avg busy GHz", fmt_f(total.avg_busy_ghz(), 3)),
        ("license requests", total.license_requests.to_string()),
        ("frequency switches", total.freq_switches.to_string()),
    ];
    for (k, v) in rows {
        t.row(&[k.to_string(), v]);
    }
    t
}

/// Per-scope energy table: Joules split active/idle, average watts, and
/// the per-request efficiency metrics. Fixed-precision formatting keeps
/// the bytes stable for the golden-file test
/// (`rust/tests/golden/energy_report.txt`) and the cross-thread
/// determinism property. Rows with `completed == 0` render `-` for the
/// per-request columns instead of a misleading 0.
pub fn energy_report(rows: &[EnergyRow]) -> Table {
    let mut t = Table::new(
        "Energy — per-scope Joules, watts, and perf-per-watt",
        &["scope", "governor", "active J", "idle J", "total J", "avg W", "mJ/req", "req/J"],
    );
    for r in rows {
        let per_req = |v: f64, d: usize| {
            if r.completed == 0 { "-".to_string() } else { fmt_f(v, d) }
        };
        t.row(&[
            r.scope.clone(),
            r.governor.clone(),
            fmt_f(r.active_j, 3),
            fmt_f(r.idle_j, 3),
            fmt_f(r.total_j(), 3),
            fmt_f(r.avg_w(), 2),
            per_req(r.mj_per_req(), 3),
            per_req(r.req_per_j(), 1),
        ]);
    }
    t
}

/// Per-core + machine-total [`EnergyRow`]s for a finished machine —
/// the `avxfreq energy --config` view. Per-core completions are not
/// attributable, so only the `machine` row carries the per-request
/// metrics.
pub fn machine_energy_rows(m: &Machine, governor: &str, completed: u64, secs: f64) -> Vec<EnergyRow> {
    let mut rows: Vec<EnergyRow> = m
        .cores
        .iter()
        .map(|c| EnergyRow {
            scope: format!("core{}", c.id),
            governor: governor.to_string(),
            active_j: c.perf.active_energy_j,
            idle_j: c.perf.idle_energy_j,
            completed: 0,
            secs,
        })
        .collect();
    let total = m.total_perf();
    rows.push(EnergyRow {
        scope: "machine".to_string(),
        governor: governor.to_string(),
        active_j: total.active_energy_j,
        idle_j: total.idle_energy_j,
        completed,
        secs,
    });
    rows
}

/// Unified comparison table for an executed scenario matrix: one row per
/// cell in expansion order, with fixed-precision formatting so the same
/// results always render to the same bytes (the determinism property the
/// matrix runner is tested against).
pub fn matrix_report(cells: &[CellResult]) -> Table {
    let mut t = Table::new(
        "Scenario matrix — topology × policy × workload × ISA × load × arrival",
        &[
            "cell", "topology", "skts", "isa", "policy", "workload", "arrival", "load",
            "req/s", "p50 µs", "p99 µs", "p999 µs", "slo %", "drops", "GHz", "IPC",
            "migr/s", "xsock/s", "typechg/s",
        ],
    );
    for c in cells {
        let s = &c.scenario;
        let r = &c.run;
        t.row(&[
            s.index.to_string(),
            s.topology.clone(),
            s.sockets.to_string(),
            s.isa.name().to_string(),
            s.policy.clone(),
            s.workload.clone(),
            s.arrival.clone(),
            fmt_f(s.load, 2),
            fmt_f(r.throughput_rps, 0),
            fmt_f(r.tail.p50_us, 0),
            fmt_f(r.tail.p99_us, 0),
            fmt_f(r.tail.p999_us, 0),
            fmt_f(r.tail.slo_violation_frac * 100.0, 1),
            r.dropped.to_string(),
            fmt_f(r.avg_ghz, 3),
            fmt_f(r.ipc, 3),
            fmt_f(r.migrations_per_sec, 0),
            fmt_f(r.cross_socket_migrations_per_sec, 0),
            fmt_f(r.type_changes_per_sec, 0),
        ]);
    }
    t
}

/// Tail-latency table: one row per cell *and tenant* (single-stream
/// processes contribute one `all` row), percentiles in µs plus the exact
/// SLO-violation fraction. Fixed-precision formatting keeps the bytes
/// stable for the golden-file tests and the cross-thread determinism
/// property.
pub fn tail_report(cells: &[CellResult]) -> Table {
    let mut t = Table::new(
        "Tail latency — per cell / tenant",
        &[
            "cell", "arrival", "load", "isa", "policy", "tenant", "done", "p50 µs",
            "p95 µs", "p99 µs", "p999 µs", "max µs", "slo %",
        ],
    );
    for c in cells {
        let s = &c.scenario;
        for (tenant, tail) in &c.run.tenant_tails {
            t.row(&[
                s.index.to_string(),
                s.arrival.clone(),
                fmt_f(s.load, 2),
                s.isa.name().to_string(),
                s.policy.clone(),
                tenant.clone(),
                tail.completed.to_string(),
                fmt_f(tail.p50_us, 0),
                fmt_f(tail.p95_us, 0),
                fmt_f(tail.p99_us, 0),
                fmt_f(tail.p999_us, 0),
                fmt_f(tail.max_us, 0),
                fmt_f(tail.slo_violation_frac * 100.0, 1),
            ]);
        }
    }
    t
}

/// Fleet table: one row per machine of every fleet, then a `cluster`
/// row with the merged tail and the cross-machine p99 dispersion (σ and
/// max−min spread — the fleet restatement of the paper's variability
/// claim). `fleets` pairs a label (e.g. the matrix cell index or a
/// router name) with each run. Fixed-precision formatting keeps the
/// bytes stable for the golden-file tests and the cross-thread
/// determinism property.
pub fn fleet_report(fleets: &[(&str, &FleetRun)]) -> Table {
    let mut t = Table::new(
        "Fleet — per-machine and cluster tails",
        &[
            "fleet", "router", "n", "machine", "arrivals", "done", "p50 µs", "p99 µs",
            "p999 µs", "slo %", "drops", "p99 σ µs", "p99 spread µs",
        ],
    );
    for (label, f) in fleets {
        let n = f.machines.len();
        for (i, m) in f.machines.iter().enumerate() {
            t.row(&[
                label.to_string(),
                f.router.clone(),
                n.to_string(),
                format!("m{i}"),
                f.arrivals_routed.get(i).copied().unwrap_or(0).to_string(),
                m.tail.completed.to_string(),
                fmt_f(m.tail.p50_us, 0),
                fmt_f(m.tail.p99_us, 0),
                fmt_f(m.tail.p999_us, 0),
                fmt_f(m.tail.slo_violation_frac * 100.0, 1),
                m.dropped.to_string(),
                "-".to_string(),
                "-".to_string(),
            ]);
        }
        let s = f.p99_summary();
        t.row(&[
            label.to_string(),
            f.router.clone(),
            n.to_string(),
            "cluster".to_string(),
            f.arrivals_routed.iter().sum::<u64>().to_string(),
            f.completed.to_string(),
            fmt_f(f.tail.p50_us, 0),
            fmt_f(f.tail.p99_us, 0),
            fmt_f(f.tail.p999_us, 0),
            fmt_f(f.tail.slo_violation_frac * 100.0, 1),
            f.dropped.to_string(),
            fmt_f(s.stddev(), 1),
            fmt_f(f.p99_spread_us(), 1),
        ]);
    }
    t
}

/// Hierarchical fleet table: one row per rack, then the cluster row
/// carrying the merged tail plus the closed-loop outcome counters
/// (timeouts / retries / hedges / ejections — `-` on rack rows, which
/// have no front-end of their own). Rack rows summarize the streamed
/// per-rack recorders; the cluster row renders the precomputed
/// [`crate::traffic::TailSummary`], so the golden-file test can pin the
/// formatting on synthetic values (same pattern as [`EnergyRow`]).
pub fn hier_report(fleets: &[(&str, &HierFleetRun)]) -> Table {
    let mut t = Table::new(
        "Hierarchical fleet — per-rack and cluster tails, front-end outcomes",
        &[
            "fleet", "router", "balancer", "scope", "done", "p50 µs", "p99 µs", "p999 µs",
            "slo %", "drops", "timeouts", "retries", "hedges", "ejects",
        ],
    );
    for (label, f) in fleets {
        for (i, rack) in f.racks.iter().enumerate() {
            let s = rack.summary();
            t.row(&[
                label.to_string(),
                f.router.clone(),
                f.balancer.clone(),
                format!("rack{i}"),
                s.completed.to_string(),
                fmt_f(s.p50_us, 0),
                fmt_f(s.p99_us, 0),
                fmt_f(s.p999_us, 0),
                fmt_f(s.slo_violation_frac * 100.0, 1),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ]);
        }
        let o = &f.outcomes;
        t.row(&[
            label.to_string(),
            f.router.clone(),
            f.balancer.clone(),
            "cluster".to_string(),
            f.completed.to_string(),
            fmt_f(f.tail.p50_us, 0),
            fmt_f(f.tail.p99_us, 0),
            fmt_f(f.tail.p999_us, 0),
            fmt_f(f.tail.slo_violation_frac * 100.0, 1),
            f.dropped.to_string(),
            o.timeouts_observed.to_string(),
            format!("{}/{}", o.retries_issued, o.retries_abandoned),
            o.hedges_issued.to_string(),
            o.ejections.to_string(),
        ]);
    }
    t
}

/// Fault-injection table: one row per resolved fault window with the
/// cluster p99 inside vs outside the window (epoch-granularity — see
/// [`crate::faults::FaultWindowStat`]), the SLO violations inside, and
/// the crash rows' time-to-readmission, then a totals row from the
/// run's [`crate::traffic::FaultOutcomes`]. Takes the rows and
/// outcomes directly so the golden-file test can pin the formatting on
/// synthetic values (same pattern as [`EnergyRow`]).
pub fn fault_report(
    windows: &[crate::faults::FaultWindowStat],
    outcomes: &crate::traffic::FaultOutcomes,
) -> Table {
    let mut t = Table::new(
        "Fault windows — cluster p99 during vs outside, SLO damage, MTTR",
        &[
            "fault", "scope", "start ms", "end ms", "p99 in µs", "p99 out µs", "viol in",
            "readmit ep",
        ],
    );
    for w in windows {
        t.row(&[
            w.kind.to_string(),
            w.machine.clone(),
            fmt_f(w.start as f64 / 1e6, 1),
            fmt_f(w.end as f64 / 1e6, 1),
            fmt_f(w.p99_in_us, 0),
            fmt_f(w.p99_out_us, 0),
            w.violations_in.to_string(),
            if w.kind == "crash" { w.readmit_epochs.to_string() } else { "-".to_string() },
        ]);
    }
    t.row(&[
        "totals".to_string(),
        format!(
            "crash={} degrade={}",
            outcomes.crash_windows, outcomes.degrade_windows
        ),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        format!("lost={} dropped={}", outcomes.lost_to_crash, outcomes.dropped_by_net),
        format!("retries={} mttr={}", outcomes.fault_retries, outcomes.recovery_epochs),
    ]);
    t
}

/// Hybrid-topology table: one row per cell *and frequency domain*
/// (sockets, then E-core modules), reporting the domain's harmonic-mean
/// busy frequency — the figure that exposes a shared module PLL being
/// held down by one licensed sibling. Cells on homogeneous machines
/// carry no per-domain rows ([`crate::workload::webserver::WebRun::domain_ghz`]
/// is empty there) and are skipped, so a matrix without a hybrid
/// topology axis renders an empty-bodied table. Fixed-precision
/// formatting keeps the bytes stable for the golden-file test
/// (`rust/tests/golden/hybrid_report.txt`) and the cross-thread
/// determinism property.
pub fn hybrid_report(cells: &[CellResult]) -> Table {
    let mut t = Table::new(
        "Hybrid domains — harmonic-mean busy GHz per socket / E-module",
        &["cell", "topology", "isa", "policy", "governor", "domain", "harm GHz"],
    );
    for c in cells {
        let s = &c.scenario;
        for (domain, ghz) in &c.run.domain_ghz {
            t.row(&[
                s.index.to_string(),
                s.topology.clone(),
                s.isa.name().to_string(),
                s.policy.clone(),
                s.governor.name().to_string(),
                domain.clone(),
                fmt_f(*ghz, 3),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::machine::MachineParams;
    use crate::sched::PolicyKind;

    #[test]
    fn reports_render_for_fresh_machine() {
        let m = Machine::new(MachineParams::new(2, PolicyKind::Unmodified));
        let t = core_report(&m);
        assert_eq!(t.rows.len(), 2);
        let s = sched_report(&m, 1.0);
        assert!(s.render().contains("migrations"));
        let p = perf_report(&m.total_perf());
        assert!(p.render().contains("CORE_POWER.THROTTLE"));
        let rows = machine_energy_rows(&m, "intel-legacy", 0, 1.0);
        assert_eq!(rows.len(), 3, "2 core rows + machine total");
        assert!(energy_report(&rows).render().contains("avg W"));
    }

    #[test]
    fn hybrid_report_is_empty_without_hybrid_cells() {
        let t = hybrid_report(&[]);
        assert!(t.rows.is_empty());
        assert!(t.render().contains("harm GHz"));
    }

    #[test]
    fn energy_row_metrics() {
        let r = EnergyRow {
            scope: "machine".to_string(),
            governor: "intel-legacy".to_string(),
            active_j: 100.0,
            idle_j: 25.0,
            completed: 50_000,
            secs: 2.0,
        };
        assert_eq!(r.total_j(), 125.0);
        assert_eq!(r.avg_w(), 62.5);
        assert!((r.mj_per_req() - 2.5).abs() < 1e-12);
        assert!((r.req_per_j() - 400.0).abs() < 1e-12);
        let empty = EnergyRow { completed: 0, active_j: 0.0, idle_j: 0.0, ..r };
        assert_eq!(empty.mj_per_req(), 0.0);
        assert_eq!(empty.req_per_j(), 0.0);
    }
}
