//! Run-level reporting: turn machine counters and workload results into
//! the tables the repro harness prints and saves, including the unified
//! scenario-matrix comparison table ([`matrix_report`]).

use crate::cpu::PerfCounters;
use crate::fleet::FleetRun;
use crate::scenario::CellResult;
use crate::sched::machine::Machine;
use crate::util::table::{fmt_f, Table};

/// Per-core frequency/licensing breakdown of a finished run (Fig 6's
/// underlying data).
pub fn core_report(m: &Machine) -> Table {
    let mut t = Table::new(
        "Per-core frequency & license residency",
        &["core", "avg GHz", "busy %", "L0 %", "L1 %", "L2 %", "throttle %", "requests"],
    );
    for c in &m.cores {
        let p = &c.perf;
        let total_ns = (p.busy_ns + p.idle_ns).max(1);
        let share = p.license_time_share();
        t.row(&[
            c.id.to_string(),
            fmt_f(p.avg_busy_ghz(), 3),
            fmt_f(p.busy_ns as f64 / total_ns as f64 * 100.0, 1),
            fmt_f(share[0] * 100.0, 1),
            fmt_f(share[1] * 100.0, 1),
            fmt_f(share[2] * 100.0, 1),
            fmt_f(p.throttle_ratio() * 100.0, 2),
            p.license_requests.to_string(),
        ]);
    }
    t
}

/// Scheduler activity summary.
pub fn sched_report(m: &Machine, secs: f64) -> Table {
    let s = &m.sched.stats;
    let mut t = Table::new("Scheduler activity", &["metric", "total", "per second"]);
    for (name, v) in [
        ("picks", s.picks),
        ("steals", s.steals),
        ("migrations", s.migrations),
        ("cross-socket migrations", s.cross_socket_migrations),
        ("type changes", s.type_changes),
        ("forced suspends", s.forced_suspends),
        ("IPIs", s.ipis),
        ("preemptions", s.preemptions),
    ] {
        t.row(&[name.to_string(), v.to_string(), fmt_f(v as f64 / secs, 1)]);
    }
    t
}

/// Machine-wide PMU summary.
pub fn perf_report(total: &PerfCounters) -> Table {
    let mut t = Table::new("Aggregate PMU counters", &["counter", "value"]);
    let rows: Vec<(&str, String)> = vec![
        ("instructions", total.instructions.to_string()),
        ("cycles", total.cycles.to_string()),
        ("IPC", fmt_f(total.ipc(), 3)),
        ("branches", total.branches.to_string()),
        ("mispredicts", total.mispredicts.to_string()),
        (
            "mispredict rate",
            fmt_f(total.mispredicts as f64 / total.branches.max(1) as f64 * 100.0, 2),
        ),
        ("CORE_POWER.LVL0_TURBO_LICENSE", total.license_cycles[0].to_string()),
        ("CORE_POWER.LVL1_TURBO_LICENSE", total.license_cycles[1].to_string()),
        ("CORE_POWER.LVL2_TURBO_LICENSE", total.license_cycles[2].to_string()),
        ("CORE_POWER.THROTTLE", total.throttle_cycles.to_string()),
        ("avg busy GHz", fmt_f(total.avg_busy_ghz(), 3)),
        ("license requests", total.license_requests.to_string()),
        ("frequency switches", total.freq_switches.to_string()),
    ];
    for (k, v) in rows {
        t.row(&[k.to_string(), v]);
    }
    t
}

/// Unified comparison table for an executed scenario matrix: one row per
/// cell in expansion order, with fixed-precision formatting so the same
/// results always render to the same bytes (the determinism property the
/// matrix runner is tested against).
pub fn matrix_report(cells: &[CellResult]) -> Table {
    let mut t = Table::new(
        "Scenario matrix — topology × policy × workload × ISA × load × arrival",
        &[
            "cell", "topology", "skts", "isa", "policy", "workload", "arrival", "load",
            "req/s", "p50 µs", "p99 µs", "p999 µs", "slo %", "drops", "GHz", "IPC",
            "migr/s", "xsock/s", "typechg/s",
        ],
    );
    for c in cells {
        let s = &c.scenario;
        let r = &c.run;
        t.row(&[
            s.index.to_string(),
            s.topology.clone(),
            s.sockets.to_string(),
            s.isa.name().to_string(),
            s.policy.clone(),
            s.workload.clone(),
            s.arrival.clone(),
            fmt_f(s.load, 2),
            fmt_f(r.throughput_rps, 0),
            fmt_f(r.tail.p50_us, 0),
            fmt_f(r.tail.p99_us, 0),
            fmt_f(r.tail.p999_us, 0),
            fmt_f(r.tail.slo_violation_frac * 100.0, 1),
            r.dropped.to_string(),
            fmt_f(r.avg_ghz, 3),
            fmt_f(r.ipc, 3),
            fmt_f(r.migrations_per_sec, 0),
            fmt_f(r.cross_socket_migrations_per_sec, 0),
            fmt_f(r.type_changes_per_sec, 0),
        ]);
    }
    t
}

/// Tail-latency table: one row per cell *and tenant* (single-stream
/// processes contribute one `all` row), percentiles in µs plus the exact
/// SLO-violation fraction. Fixed-precision formatting keeps the bytes
/// stable for the golden-file tests and the cross-thread determinism
/// property.
pub fn tail_report(cells: &[CellResult]) -> Table {
    let mut t = Table::new(
        "Tail latency — per cell / tenant",
        &[
            "cell", "arrival", "load", "isa", "policy", "tenant", "done", "p50 µs",
            "p95 µs", "p99 µs", "p999 µs", "max µs", "slo %",
        ],
    );
    for c in cells {
        let s = &c.scenario;
        for (tenant, tail) in &c.run.tenant_tails {
            t.row(&[
                s.index.to_string(),
                s.arrival.clone(),
                fmt_f(s.load, 2),
                s.isa.name().to_string(),
                s.policy.clone(),
                tenant.clone(),
                tail.completed.to_string(),
                fmt_f(tail.p50_us, 0),
                fmt_f(tail.p95_us, 0),
                fmt_f(tail.p99_us, 0),
                fmt_f(tail.p999_us, 0),
                fmt_f(tail.max_us, 0),
                fmt_f(tail.slo_violation_frac * 100.0, 1),
            ]);
        }
    }
    t
}

/// Fleet table: one row per machine of every fleet, then a `cluster`
/// row with the merged tail and the cross-machine p99 dispersion (σ and
/// max−min spread — the fleet restatement of the paper's variability
/// claim). `fleets` pairs a label (e.g. the matrix cell index or a
/// router name) with each run. Fixed-precision formatting keeps the
/// bytes stable for the golden-file tests and the cross-thread
/// determinism property.
pub fn fleet_report(fleets: &[(&str, &FleetRun)]) -> Table {
    let mut t = Table::new(
        "Fleet — per-machine and cluster tails",
        &[
            "fleet", "router", "n", "machine", "arrivals", "done", "p50 µs", "p99 µs",
            "p999 µs", "slo %", "drops", "p99 σ µs", "p99 spread µs",
        ],
    );
    for (label, f) in fleets {
        let n = f.machines.len();
        for (i, m) in f.machines.iter().enumerate() {
            t.row(&[
                label.to_string(),
                f.router.clone(),
                n.to_string(),
                format!("m{i}"),
                f.arrivals_routed.get(i).copied().unwrap_or(0).to_string(),
                m.tail.completed.to_string(),
                fmt_f(m.tail.p50_us, 0),
                fmt_f(m.tail.p99_us, 0),
                fmt_f(m.tail.p999_us, 0),
                fmt_f(m.tail.slo_violation_frac * 100.0, 1),
                m.dropped.to_string(),
                "-".to_string(),
                "-".to_string(),
            ]);
        }
        let s = f.p99_summary();
        t.row(&[
            label.to_string(),
            f.router.clone(),
            n.to_string(),
            "cluster".to_string(),
            f.arrivals_routed.iter().sum::<u64>().to_string(),
            f.completed.to_string(),
            fmt_f(f.tail.p50_us, 0),
            fmt_f(f.tail.p99_us, 0),
            fmt_f(f.tail.p999_us, 0),
            fmt_f(f.tail.slo_violation_frac * 100.0, 1),
            f.dropped.to_string(),
            fmt_f(s.stddev(), 1),
            fmt_f(f.p99_spread_us(), 1),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::machine::MachineParams;
    use crate::sched::PolicyKind;

    #[test]
    fn reports_render_for_fresh_machine() {
        let m = Machine::new(MachineParams::new(2, PolicyKind::Unmodified));
        let t = core_report(&m);
        assert_eq!(t.rows.len(), 2);
        let s = sched_report(&m, 1.0);
        assert!(s.render().contains("migrations"));
        let p = perf_report(&m.total_perf());
        assert!(p.render().contains("CORE_POWER.THROTTLE"));
    }
}
