//! Per-request lifecycle records and tail-latency accounting.
//!
//! A request is tracked from its arrival timestamp to its completion;
//! the recorder keeps an HDR-style [`LogHistogram`] plus an exact
//! SLO-violation count (counted at record time, so the fraction is not
//! subject to the histogram's ~3% bucket error).

use crate::sim::Time;
use crate::util::LogHistogram;

/// One in-flight request: when it arrived and which tenant issued it.
///
/// Tenant 0 is the only tenant for single-stream arrival processes;
/// multi-tenant mixes use the index into
/// [`crate::traffic::ArrivalProcess::tenant_names`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Request {
    pub arrived: Time,
    pub tenant: u32,
}

impl Request {
    /// A tenant-0 request arriving at `t`.
    pub fn at(t: Time) -> Self {
        Request { arrived: t, tenant: 0 }
    }
}

/// Latency recorder: histogram + exact SLO-violation counting.
#[derive(Clone, Debug)]
pub struct LatencyStats {
    pub hist: LogHistogram,
    /// SLO threshold (ns); completions above it count as violations.
    pub slo: Time,
    violations: u64,
}

impl LatencyStats {
    pub fn new(slo: Time) -> Self {
        LatencyStats { hist: LogHistogram::new(), slo, violations: 0 }
    }

    /// Forget every recorded sample in place (keeping the histogram's
    /// bucket allocation) — equivalent to `*self = LatencyStats::new(self.slo)`
    /// without the reallocation.
    pub fn reset(&mut self) {
        self.hist.clear();
        self.violations = 0;
    }

    /// [`LatencyStats::reset`] plus re-arming for a (possibly different)
    /// SLO threshold — the recycling step behind [`RecorderArena`].
    pub fn reset_with_slo(&mut self, slo: Time) {
        self.reset();
        self.slo = slo;
    }

    /// Record one completed request's latency (ns).
    pub fn record(&mut self, latency: Time) {
        self.hist.record(latency);
        if latency > self.slo {
            self.violations += 1;
        }
    }

    /// Completed requests recorded so far.
    pub fn completed(&self) -> u64 {
        self.hist.count()
    }

    /// Exact number of completions above the SLO threshold.
    pub fn violations(&self) -> u64 {
        self.violations
    }

    /// Fold another recorder's samples into this one, as if every sample
    /// had been recorded here: the histogram buckets add and the exact
    /// violation counters add, so `merge` obeys the same laws as the
    /// sample union — commutative, associative, and equal to recording
    /// the concatenated sample stream (property-tested in
    /// `rust/tests/fleet.rs`; the fleet layer's cross-machine
    /// aggregation depends on them). Panics if the recorders measure
    /// different SLO thresholds — merging those would silently blend two
    /// incomparable violation definitions.
    pub fn merge(&mut self, other: &LatencyStats) {
        assert_eq!(
            self.slo, other.slo,
            "merging latency recorders with different SLO thresholds"
        );
        self.hist.merge(&other.hist);
        self.violations += other.violations;
    }

    /// Exact fraction of completions above the SLO threshold.
    pub fn violation_frac(&self) -> f64 {
        if self.hist.count() == 0 {
            0.0
        } else {
            self.violations as f64 / self.hist.count() as f64
        }
    }

    /// Freeze the recorder into a report row.
    pub fn summary(&self) -> TailSummary {
        // The bucketed query undercounts by at most the one bucket
        // containing the threshold, so it must never exceed the exact
        // counter — catches recorder/histogram drift in debug builds.
        debug_assert!(
            self.hist.fraction_above(self.slo) <= self.violation_frac() + 1e-12,
            "histogram SLO query exceeds the exact violation counter"
        );
        let us = |v: u64| v as f64 / 1_000.0;
        TailSummary {
            completed: self.hist.count(),
            mean_us: self.hist.mean() / 1_000.0,
            p50_us: us(self.hist.percentile(50.0)),
            p95_us: us(self.hist.percentile(95.0)),
            p99_us: us(self.hist.percentile(99.0)),
            p999_us: us(self.hist.percentile(99.9)),
            max_us: us(self.hist.max()),
            slo_us: us(self.slo),
            slo_violation_frac: self.violation_frac(),
        }
    }
}

/// Recycling pool for [`LatencyStats`] recorders, used by incremental
/// scenario sweeps: consecutive forked cells hand their recorders back
/// after summarising so the next cell's fork reuses the histogram bucket
/// allocations instead of growing fresh ones.
///
/// Reuse is byte-safe because a recycled recorder is indistinguishable
/// from a new one: [`RecorderArena::take`] hands it out through
/// [`LatencyStats::reset_with_slo`], and `reset ≡ fresh` is pinned by
/// the `reset_is_equivalent_to_fresh` test below.
#[derive(Debug, Default)]
pub struct RecorderArena {
    pool: Vec<LatencyStats>,
}

impl RecorderArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cleared recorder armed for `slo`: recycled from the pool when
    /// one is available, freshly allocated otherwise.
    pub fn take(&mut self, slo: Time) -> LatencyStats {
        match self.pool.pop() {
            Some(mut s) => {
                s.reset_with_slo(slo);
                s
            }
            None => LatencyStats::new(slo),
        }
    }

    /// Return a recorder to the pool for later reuse.
    pub fn put(&mut self, stats: LatencyStats) {
        self.pool.push(stats);
    }

    /// Recorders currently pooled (reporting/tests only).
    pub fn len(&self) -> usize {
        self.pool.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pool.is_empty()
    }
}

/// Tail-latency report row: percentiles in microseconds plus the SLO
/// damage, the unit every table in [`crate::metrics`] renders.
#[derive(Clone, Copy, Debug, Default)]
pub struct TailSummary {
    pub completed: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub max_us: f64,
    /// The threshold the violation fraction was measured against (µs).
    pub slo_us: f64,
    /// Exact fraction of completions slower than the SLO.
    pub slo_violation_frac: f64,
}

/// Closed-loop front-end outcome accounting: what the balancer *did* in
/// response to the observed latency distribution, kept separate from the
/// latency recorders so open-loop runs stay untouched. All counters are
/// exact event counts, so `merge` is plain addition and obeys the same
/// union laws as [`LatencyStats::merge`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FrontendOutcomes {
    /// Completions the front-end classified as timed out (estimated from
    /// the observed latency distribution at epoch boundaries).
    pub timeouts_observed: u64,
    /// Retry arrivals injected into a later epoch (attempt ≥ 1).
    pub retries_issued: u64,
    /// Timed-out requests already at the retry cap, given up on.
    pub retries_abandoned: u64,
    /// Hedge duplicates issued after the p99-based hedge delay.
    pub hedges_issued: u64,
    /// Machine-epochs ejected from the healthy set.
    pub ejections: u64,
    /// Machine-epochs readmitted after recovering.
    pub readmissions: u64,
}

impl FrontendOutcomes {
    /// Fold another accounting record into this one (exact counters add).
    pub fn merge(&mut self, other: &FrontendOutcomes) {
        self.timeouts_observed += other.timeouts_observed;
        self.retries_issued += other.retries_issued;
        self.retries_abandoned += other.retries_abandoned;
        self.hedges_issued += other.hedges_issued;
        self.ejections += other.ejections;
        self.readmissions += other.readmissions;
    }

    /// True when the balancer took no action at all — the open-loop
    /// differential (`rust/tests/hierfleet.rs`) asserts this on the
    /// feedback-disabled path.
    pub fn is_noop(&self) -> bool {
        *self == FrontendOutcomes::default()
    }
}

/// Fault-injection outcome accounting: what the seeded fault layer
/// (`crate::faults`) *did* to the request stream, kept separate from
/// both the latency recorders and [`FrontendOutcomes`] so fault-free
/// runs stay untouched. All counters are exact event counts, so
/// `merge` is plain addition and obeys the same union laws as
/// [`LatencyStats::merge`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultOutcomes {
    /// Requests lost because their machine was dark (crashed or paying
    /// its cold-start penalty) when they would have been delivered.
    pub lost_to_crash: u64,
    /// Requests dropped on the front-end → machine link by an injected
    /// network fault.
    pub dropped_by_net: u64,
    /// Retry arrivals the closed loop issued *because of* injected
    /// faults (lost/dropped requests re-queued as known timeouts).
    pub fault_retries: u64,
    /// Crash windows that actually took a machine dark inside the
    /// measure window.
    pub crash_windows: u64,
    /// Degradation windows applied to some machine's turbo tables.
    pub degrade_windows: u64,
    /// Epochs spent between a fault window ending and the affected
    /// machine being readmitted to the healthy set (MTTR, in epochs,
    /// summed across fault windows).
    pub recovery_epochs: u64,
}

impl FaultOutcomes {
    /// Fold another accounting record into this one (exact counters add).
    pub fn merge(&mut self, other: &FaultOutcomes) {
        self.lost_to_crash += other.lost_to_crash;
        self.dropped_by_net += other.dropped_by_net;
        self.fault_retries += other.fault_retries;
        self.crash_windows += other.crash_windows;
        self.degrade_windows += other.degrade_windows;
        self.recovery_epochs += other.recovery_epochs;
    }

    /// True when the fault layer touched nothing — the faults-disabled
    /// differential (`rust/tests/faults.rs`) asserts this on every
    /// fault-free path.
    pub fn is_noop(&self) -> bool {
        *self == FaultOutcomes::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    #[test]
    fn records_and_counts_violations_exactly() {
        let mut s = LatencyStats::new(2 * MS);
        for v in [MS, MS, 3 * MS, 5 * MS] {
            s.record(v);
        }
        assert_eq!(s.completed(), 4);
        assert!((s.violation_frac() - 0.5).abs() < 1e-12);
        let t = s.summary();
        assert_eq!(t.completed, 4);
        assert!((t.slo_us - 2_000.0).abs() < 1e-9);
        assert!(t.p50_us >= 900.0 && t.p50_us <= 1_000.0, "p50={}", t.p50_us);
        assert!(t.max_us >= 4_900.0, "max={}", t.max_us);
        assert!(t.p999_us <= t.max_us + 1e-9);
    }

    #[test]
    fn empty_recorder_is_all_zero() {
        let s = LatencyStats::new(MS);
        assert_eq!(s.completed(), 0);
        assert_eq!(s.violation_frac(), 0.0);
        let t = s.summary();
        assert_eq!(t.completed, 0);
        assert_eq!(t.p99_us, 0.0);
    }

    #[test]
    fn exactly_at_slo_is_not_a_violation() {
        let mut s = LatencyStats::new(MS);
        s.record(MS);
        assert_eq!(s.violation_frac(), 0.0);
        s.record(MS + 1);
        assert!((s.violation_frac() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_recording_union() {
        let mut a = LatencyStats::new(2 * MS);
        let mut b = LatencyStats::new(2 * MS);
        let mut u = LatencyStats::new(2 * MS);
        for (i, v) in [MS / 2, MS, 3 * MS, 5 * MS, MS, 7 * MS].iter().enumerate() {
            if i % 2 == 0 { a.record(*v) } else { b.record(*v) }
            u.record(*v);
        }
        a.merge(&b);
        assert_eq!(a.completed(), u.completed());
        assert_eq!(a.violations(), u.violations());
        assert_eq!(a.hist.percentile(99.0), u.hist.percentile(99.0));
        assert_eq!(a.hist.max(), u.hist.max());
        assert!((a.violation_frac() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn reset_is_equivalent_to_fresh() {
        let mut s = LatencyStats::new(2 * MS);
        for v in [MS, 3 * MS, 5 * MS] {
            s.record(v);
        }
        s.reset();
        assert_eq!(s.completed(), 0);
        assert_eq!(s.violations(), 0);
        assert_eq!(s.slo, 2 * MS, "reset keeps the SLO threshold");
        s.record(3 * MS);
        let mut fresh = LatencyStats::new(2 * MS);
        fresh.record(3 * MS);
        assert_eq!(s.completed(), fresh.completed());
        assert_eq!(s.violations(), fresh.violations());
        assert_eq!(s.hist.max(), fresh.hist.max());
    }

    #[test]
    fn arena_recycles_and_rearms_recorders() {
        let mut arena = RecorderArena::new();
        assert!(arena.is_empty());
        // Nothing pooled: take allocates fresh.
        let mut a = arena.take(2 * MS);
        a.record(MS);
        a.record(5 * MS);
        arena.put(a);
        assert_eq!(arena.len(), 1);
        // Recycled with a *different* SLO: cleared and re-armed.
        let b = arena.take(MS);
        assert!(arena.is_empty());
        assert_eq!(b.completed(), 0);
        assert_eq!(b.violations(), 0);
        assert_eq!(b.slo, MS);
    }

    #[test]
    fn recycled_recorder_behaves_like_fresh() {
        let mut arena = RecorderArena::new();
        let mut used = arena.take(2 * MS);
        for v in [MS, 3 * MS, 7 * MS] {
            used.record(v);
        }
        arena.put(used);
        let mut recycled = arena.take(2 * MS);
        let mut fresh = LatencyStats::new(2 * MS);
        for v in [MS / 2, 3 * MS, 4 * MS] {
            recycled.record(v);
            fresh.record(v);
        }
        assert_eq!(recycled.completed(), fresh.completed());
        assert_eq!(recycled.violations(), fresh.violations());
        assert_eq!(recycled.hist.max(), fresh.hist.max());
        assert_eq!(recycled.hist.percentile(99.0), fresh.hist.percentile(99.0));
    }

    #[test]
    #[should_panic]
    fn merge_rejects_mismatched_slo() {
        let mut a = LatencyStats::new(MS);
        a.merge(&LatencyStats::new(2 * MS));
    }

    #[test]
    fn frontend_outcomes_merge_adds_and_noop_detects() {
        let mut a = FrontendOutcomes {
            timeouts_observed: 3,
            retries_issued: 2,
            retries_abandoned: 1,
            hedges_issued: 4,
            ejections: 1,
            readmissions: 0,
        };
        let b = FrontendOutcomes { timeouts_observed: 7, readmissions: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.timeouts_observed, 10);
        assert_eq!(a.retries_issued, 2);
        assert_eq!(a.readmissions, 2);
        assert!(!a.is_noop());
        assert!(FrontendOutcomes::default().is_noop());
        let mut z = FrontendOutcomes::default();
        z.merge(&FrontendOutcomes::default());
        assert!(z.is_noop(), "merging no-ops stays a no-op");
    }

    #[test]
    fn fault_outcomes_merge_adds_and_noop_detects() {
        let mut a = FaultOutcomes {
            lost_to_crash: 5,
            dropped_by_net: 2,
            fault_retries: 3,
            crash_windows: 1,
            degrade_windows: 0,
            recovery_epochs: 2,
        };
        let b = FaultOutcomes { dropped_by_net: 4, degrade_windows: 1, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.lost_to_crash, 5);
        assert_eq!(a.dropped_by_net, 6);
        assert_eq!(a.degrade_windows, 1);
        assert!(!a.is_noop());
        assert!(FaultOutcomes::default().is_noop());
        let mut z = FaultOutcomes::default();
        z.merge(&FaultOutcomes::default());
        assert!(z.is_noop(), "merging no-ops stays a no-op");
    }
}
