//! Arrival processes: deterministic request-stream generators.
//!
//! wrk2 (the paper's load generator) offers a fixed-rate Poisson stream;
//! real web traffic is burstier, periodic, and multi-tenant. Every
//! process here is generated from a seeded [`Rng`] only, so a traffic
//! run is bit-for-bit reproducible and safe to execute on any OS thread
//! of a scenario-matrix sweep.
//!
//! Time-varying processes (bursty, diurnal) are sampled by Lewis–Shedler
//! thinning: candidate arrivals are drawn from a homogeneous Poisson
//! process at the peak rate and accepted with probability
//! `rate(t) / peak`, which is exact for any bounded rate function.

use crate::sim::Time;
use crate::util::Rng;

/// Mean-preserving on/off burst modulation of one tenant's stream:
/// `burst_rate` for `on` nanoseconds, then `base_rate` for `off`
/// nanoseconds, repeating from simulated time 0. Tenants sharing the
/// same phase (`on`/`off`) burst *together* — a flash crowd with a
/// fixed traffic composition, the fleet layer's straggler generator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantBurst {
    pub base_rate: f64,
    pub burst_rate: f64,
    pub on: Time,
    pub off: Time,
}

/// One tenant of a multi-tenant mix.
#[derive(Clone, Debug, PartialEq)]
pub struct Tenant {
    /// Short label used in tables (e.g. `avx`, `scalar`).
    pub name: String,
    /// This tenant's mean arrival rate (requests/second).
    pub rate: f64,
    /// Whether this tenant's requests execute wide (AVX) instructions;
    /// the web server gives non-AVX tenants an SSE4 request pipeline
    /// with no `with_avx()` annotations.
    pub avx: bool,
    /// Optional burst modulation of this tenant's stream (`None` = a
    /// homogeneous Poisson stream at `rate`). When set, the burst shape
    /// is expected to preserve `rate` as the long-run mean (see
    /// [`ArrivalProcess::bursty_two_tenant`]).
    pub burst: Option<TenantBurst>,
}

impl Tenant {
    /// A homogeneous Poisson tenant.
    pub fn steady(name: &str, rate: f64, avx: bool) -> Tenant {
        Tenant { name: name.to_string(), rate, avx, burst: None }
    }

    /// Peak instantaneous rate (the per-tenant thinning envelope).
    pub fn peak_rate(&self) -> f64 {
        match &self.burst {
            Some(b) => b.base_rate.max(b.burst_rate),
            None => self.rate,
        }
    }

    /// Instantaneous rate at simulated time `t`.
    pub fn rate_at(&self, t: Time) -> f64 {
        match &self.burst {
            Some(b) => {
                let cycle = (b.on + b.off).max(1);
                if t % cycle < b.on {
                    b.burst_rate
                } else {
                    b.base_rate
                }
            }
            None => self.rate,
        }
    }
}

/// An open-loop arrival process (requests/second over simulated time).
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals at a fixed mean rate (wrk2's model).
    Poisson { rate: f64 },
    /// On/off burst cycle: `burst_rate` for `on` nanoseconds, then
    /// `base_rate` for `off` nanoseconds, repeating.
    Bursty { base_rate: f64, burst_rate: f64, on: Time, off: Time },
    /// Sinusoidal ramp around a mean: `rate(t) = mean_rate * (1 + swing
    /// * sin(2πt/period))`. A compressed stand-in for diurnal load
    /// (`swing < 1` keeps the rate strictly positive).
    Diurnal { mean_rate: f64, swing: f64, period: Time },
    /// Independent Poisson streams, one per tenant; requests carry the
    /// tenant index so per-tenant tails can be separated.
    MultiTenant { tenants: Vec<Tenant> },
}

impl ArrivalProcess {
    /// Short label used in tables.
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson".to_string(),
            ArrivalProcess::Bursty { .. } => "bursty".to_string(),
            ArrivalProcess::Diurnal { .. } => "diurnal".to_string(),
            // One vocabulary across CLI (`--arrivals mix`), config
            // (`load.process = "mix"`), and both label functions.
            ArrivalProcess::MultiTenant { tenants } => {
                if tenants.iter().any(|t| t.burst.is_some()) {
                    "bursty-mix".to_string()
                } else {
                    "mix".to_string()
                }
            }
        }
    }

    /// Long-run mean offered rate (requests/second).
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, on, off } => {
                let cycle = (*on + *off).max(1) as f64;
                (burst_rate * *on as f64 + base_rate * *off as f64) / cycle
            }
            ArrivalProcess::Diurnal { mean_rate, .. } => *mean_rate,
            ArrivalProcess::MultiTenant { tenants } => tenants.iter().map(|t| t.rate).sum(),
        }
    }

    /// Peak instantaneous rate (the thinning envelope).
    pub fn peak_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, .. } => base_rate.max(*burst_rate),
            ArrivalProcess::Diurnal { mean_rate, swing, .. } => mean_rate * (1.0 + swing),
            ArrivalProcess::MultiTenant { tenants } => {
                tenants.iter().map(|t| t.peak_rate()).sum()
            }
        }
    }

    /// Instantaneous rate at simulated time `t`.
    pub fn rate_at(&self, t: Time) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, on, off } => {
                let cycle = (*on + *off).max(1);
                if t % cycle < *on {
                    *burst_rate
                } else {
                    *base_rate
                }
            }
            ArrivalProcess::Diurnal { mean_rate, swing, period } => {
                let period = (*period).max(1);
                let phase = (t % period) as f64 / period as f64;
                mean_rate * (1.0 + swing * (2.0 * std::f64::consts::PI * phase).sin())
            }
            ArrivalProcess::MultiTenant { tenants } => {
                tenants.iter().map(|s| s.rate_at(t)).sum()
            }
        }
    }

    /// Number of tenants (1 for single-stream processes).
    pub fn n_tenants(&self) -> usize {
        match self {
            ArrivalProcess::MultiTenant { tenants } => tenants.len().max(1),
            _ => 1,
        }
    }

    /// Tenant labels, in tenant-index order (`all` for single-stream).
    pub fn tenant_names(&self) -> Vec<String> {
        match self {
            ArrivalProcess::MultiTenant { tenants } => {
                tenants.iter().map(|t| t.name.clone()).collect()
            }
            _ => vec!["all".to_string()],
        }
    }

    /// Whether tenant `i` carries AVX work (single-stream processes
    /// always do: their pipeline follows the configured ISA).
    pub fn tenant_carries_avx(&self, i: usize) -> bool {
        match self {
            ArrivalProcess::MultiTenant { tenants } => {
                tenants.get(i).map(|t| t.avx).unwrap_or(true)
            }
            _ => true,
        }
    }

    /// The same process shape rescaled to a new long-run mean rate:
    /// every constituent rate (tenant means, burst/base levels, the
    /// diurnal mean) is multiplied by `rate / mean_rate()`, preserving
    /// burst factors, duty cycles, phases, and tenant shares. Lets a
    /// CLI `--rate` override change the offered load without silently
    /// replacing a structured process with plain Poisson. Returns the
    /// process unchanged if its current mean is not positive.
    pub fn with_mean_rate(&self, rate: f64) -> ArrivalProcess {
        let mean = self.mean_rate();
        if mean <= 0.0 {
            return self.clone();
        }
        let k = rate / mean;
        match self {
            ArrivalProcess::Poisson { .. } => ArrivalProcess::Poisson { rate },
            ArrivalProcess::Bursty { base_rate, burst_rate, on, off } => {
                ArrivalProcess::Bursty {
                    base_rate: base_rate * k,
                    burst_rate: burst_rate * k,
                    on: *on,
                    off: *off,
                }
            }
            ArrivalProcess::Diurnal { swing, period, .. } => {
                ArrivalProcess::Diurnal { mean_rate: rate, swing: *swing, period: *period }
            }
            ArrivalProcess::MultiTenant { tenants } => ArrivalProcess::MultiTenant {
                tenants: tenants
                    .iter()
                    .map(|t| Tenant {
                        name: t.name.clone(),
                        rate: t.rate * k,
                        avx: t.avx,
                        burst: t.burst.map(|b| TenantBurst {
                            base_rate: b.base_rate * k,
                            burst_rate: b.burst_rate * k,
                            on: b.on,
                            off: b.off,
                        }),
                    })
                    .collect(),
            },
        }
    }

    /// Mean-preserving bursty process: bursts at `burst_factor × rate`
    /// for a `duty` fraction of each `period`, with the base rate chosen
    /// so the long-run mean stays `rate` (clamped at 0 when the bursts
    /// alone exceed the mean, i.e. `burst_factor × duty > 1`).
    pub fn bursty_mean(rate: f64, burst_factor: f64, duty: f64, period: Time) -> ArrivalProcess {
        let duty = duty.clamp(0.01, 0.99);
        let on = ((period as f64 * duty) as Time).max(1);
        let off = period.saturating_sub(on).max(1);
        let burst_rate = rate * burst_factor.max(0.0);
        let base_rate = ((rate - duty * burst_rate) / (1.0 - duty)).max(0.0);
        ArrivalProcess::Bursty { base_rate, burst_rate, on, off }
    }

    /// A two-tenant mix at total rate `rate`: an `avx` tenant carrying
    /// `avx_share` of the traffic and a `scalar` tenant with the rest.
    pub fn two_tenant(rate: f64, avx_share: f64) -> ArrivalProcess {
        let share = avx_share.clamp(0.0, 1.0);
        ArrivalProcess::MultiTenant {
            tenants: vec![
                Tenant::steady("scalar", rate * (1.0 - share), false),
                Tenant::steady("avx", rate * share, true),
            ],
        }
    }

    /// The bursty multi-tenant mix: [`ArrivalProcess::two_tenant`] where
    /// *both* tenants burst **in phase** — `burst_factor ×` their mean
    /// rate for a `duty` fraction of each `period`, base rate chosen so
    /// each tenant's long-run mean is preserved (a flash crowd whose
    /// AVX/scalar composition stays fixed). This is the fleet layer's
    /// headline scenario: correlated surges hit every machine under
    /// round-robin routing, while an AVX-aware router keeps the scalar
    /// machines' surges free of the frequency drag.
    ///
    /// Panics (like [`ArrivalProcess::bursty_mean`] clamps) are avoided:
    /// `burst_factor × duty > 1` clamps the base rate at 0, so callers
    /// that care about mean preservation must validate the product ≤ 1
    /// (the config layer does).
    pub fn bursty_two_tenant(
        rate: f64,
        avx_share: f64,
        burst_factor: f64,
        duty: f64,
        period: Time,
    ) -> ArrivalProcess {
        let share = avx_share.clamp(0.0, 1.0);
        let duty = duty.clamp(0.01, 0.99);
        let on = ((period as f64 * duty) as Time).max(1);
        let off = period.saturating_sub(on).max(1);
        let burst = |mean: f64| {
            let burst_rate = mean * burst_factor.max(0.0);
            let base_rate = ((mean - duty * burst_rate) / (1.0 - duty)).max(0.0);
            Some(TenantBurst { base_rate, burst_rate, on, off })
        };
        let scalar_rate = rate * (1.0 - share);
        let avx_rate = rate * share;
        ArrivalProcess::MultiTenant {
            tenants: vec![
                Tenant { name: "scalar".to_string(), rate: scalar_rate, avx: false, burst: burst(scalar_rate) },
                Tenant { name: "avx".to_string(), rate: avx_rate, avx: true, burst: burst(avx_rate) },
            ],
        }
    }
}

/// Deterministic arrival-stream generator for one [`ArrivalProcess`].
///
/// [`ArrivalGen::next_after`] returns strictly increasing times, so the
/// driver loop (one pending arrival event, regenerated on delivery)
/// always makes progress.
#[derive(Clone, Debug)]
pub struct ArrivalGen {
    process: ArrivalProcess,
    rng: Rng,
    /// Multi-tenant: next pending arrival per tenant (lazily seeded on
    /// the first call so the stream starts at the caller's clock).
    tenant_next: Vec<Time>,
}

impl ArrivalGen {
    /// Build a generator. Panics if the process can never produce an
    /// arrival (peak rate ≤ 0) — a zero-rate run would hang the driver.
    pub fn new(process: ArrivalProcess, seed: u64) -> Self {
        assert!(
            process.peak_rate() > 0.0,
            "arrival process {:?} has no positive rate",
            process.label()
        );
        ArrivalGen { process, rng: Rng::new(seed), tenant_next: Vec::new() }
    }

    /// The process this generator samples.
    pub fn process(&self) -> &ArrivalProcess {
        &self.process
    }

    /// Next arrival strictly after `now`: `(time, tenant index)`.
    pub fn next_after(&mut self, now: Time) -> (Time, u32) {
        // Disjoint field borrows: the process is read-only while the RNG
        // and the per-tenant state mutate.
        let ArrivalGen { process, rng, tenant_next } = self;
        match &*process {
            ArrivalProcess::Poisson { rate } => {
                let gap = rng.exponential(1e9 / *rate).max(1.0) as Time;
                (now + gap.max(1), 0)
            }
            ArrivalProcess::Bursty { .. } | ArrivalProcess::Diurnal { .. } => {
                // Lewis–Shedler thinning at the peak rate.
                let peak = process.peak_rate();
                let mut t = now as f64;
                loop {
                    t += rng.exponential(1e9 / peak).max(1e-3);
                    let r = process.rate_at(t as Time);
                    if r > 0.0 && rng.chance(r / peak) {
                        return ((t as Time).max(now + 1), 0);
                    }
                }
            }
            ArrivalProcess::MultiTenant { tenants } => {
                if tenant_next.len() != tenants.len() {
                    // First call: seed every tenant's stream at `now`.
                    *tenant_next =
                        tenants.iter().map(|t| tenant_arrival_after(t, now, rng)).collect();
                }
                let (i, t) = tenant_next
                    .iter()
                    .copied()
                    .enumerate()
                    .min_by_key(|&(_, t)| t)
                    .expect("at least one tenant");
                tenant_next[i] = tenant_arrival_after(&tenants[i], t, rng);
                (t.max(now + 1), i as u32)
            }
        }
    }
}

/// Next arrival of one tenant's stream, strictly after `after`:
/// a plain exponential gap for steady tenants, Lewis–Shedler thinning at
/// the tenant's peak rate when a burst shape is set. Zero-rate tenants
/// never arrive (`Time::MAX`).
fn tenant_arrival_after(t: &Tenant, after: Time, rng: &mut Rng) -> Time {
    if t.rate <= 0.0 || t.peak_rate() <= 0.0 || after == Time::MAX {
        return Time::MAX;
    }
    match &t.burst {
        None => after.saturating_add((rng.exponential(1e9 / t.rate).max(1.0) as Time).max(1)),
        Some(_) => {
            let peak = t.peak_rate();
            let mut x = after as f64;
            loop {
                x += rng.exponential(1e9 / peak).max(1e-3);
                let r = t.rate_at(x as Time);
                if r > 0.0 && rng.chance(r / peak) {
                    return (x as Time).max(after.saturating_add(1));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MS, SEC};

    fn drain(gen: &mut ArrivalGen, until: Time) -> Vec<(Time, u32)> {
        let mut out = Vec::new();
        let mut now = 0;
        loop {
            let (t, tenant) = gen.next_after(now);
            if t > until {
                return out;
            }
            out.push((t, tenant));
            now = t;
        }
    }

    #[test]
    fn poisson_rate_and_determinism() {
        let p = ArrivalProcess::Poisson { rate: 10_000.0 };
        let a = drain(&mut ArrivalGen::new(p.clone(), 7), SEC);
        let b = drain(&mut ArrivalGen::new(p, 7), SEC);
        assert_eq!(a, b, "same seed must give the same stream");
        let n = a.len() as f64;
        assert!((n - 10_000.0).abs() / 10_000.0 < 0.05, "got {n} arrivals/s");
        assert!(a.windows(2).all(|w| w[0].0 < w[1].0), "strictly increasing");
    }

    #[test]
    fn bursty_respects_phases() {
        let p = ArrivalProcess::Bursty {
            base_rate: 0.0,
            burst_rate: 50_000.0,
            on: 10 * MS,
            off: 40 * MS,
        };
        assert!((p.mean_rate() - 10_000.0).abs() < 1.0);
        let arrivals = drain(&mut ArrivalGen::new(p, 3), SEC);
        assert!(!arrivals.is_empty());
        for (t, _) in &arrivals {
            assert!(t % (50 * MS) < 10 * MS, "arrival at {t} outside the on-phase");
        }
        // Thinning preserves the mean: ~10 000 arrivals over 1 s.
        let n = arrivals.len() as f64;
        assert!((n - 10_000.0).abs() / 10_000.0 < 0.10, "got {n} arrivals/s");
    }

    #[test]
    fn diurnal_mean_preserved_and_bounded() {
        let p = ArrivalProcess::Diurnal { mean_rate: 20_000.0, swing: 0.6, period: 100 * MS };
        assert!((p.peak_rate() - 32_000.0).abs() < 1.0);
        // Whole periods only, so the sine integrates to zero.
        let arrivals = drain(&mut ArrivalGen::new(p, 11), SEC);
        let n = arrivals.len() as f64;
        assert!((n - 20_000.0).abs() / 20_000.0 < 0.05, "got {n} arrivals/s");
    }

    #[test]
    fn multi_tenant_interleaves_and_labels() {
        let p = ArrivalProcess::two_tenant(20_000.0, 0.25);
        assert_eq!(p.n_tenants(), 2);
        assert_eq!(p.tenant_names(), vec!["scalar".to_string(), "avx".to_string()]);
        assert!(!p.tenant_carries_avx(0));
        assert!(p.tenant_carries_avx(1));
        let arrivals = drain(&mut ArrivalGen::new(p, 5), SEC);
        let avx = arrivals.iter().filter(|(_, t)| *t == 1).count() as f64;
        let scalar = arrivals.iter().filter(|(_, t)| *t == 0).count() as f64;
        assert!((avx - 5_000.0).abs() / 5_000.0 < 0.10, "avx tenant got {avx}");
        assert!((scalar - 15_000.0).abs() / 15_000.0 < 0.10, "scalar tenant got {scalar}");
        assert!(arrivals.windows(2).all(|w| w[0].0 < w[1].0), "merged stream ordered");
    }

    #[test]
    fn bursty_mean_preserves_rate() {
        let p = ArrivalProcess::bursty_mean(10_000.0, 2.0, 0.3, 200 * MS);
        assert!((p.mean_rate() - 10_000.0).abs() < 1.0, "mean={}", p.mean_rate());
        assert!((p.peak_rate() - 20_000.0).abs() < 1.0);
        // Overdriven bursts clamp the base at zero (mean then exceeds
        // nothing — it just equals duty × burst).
        let q = ArrivalProcess::bursty_mean(10_000.0, 4.0, 0.5, 200 * MS);
        match q {
            ArrivalProcess::Bursty { base_rate, .. } => assert_eq!(base_rate, 0.0),
            _ => panic!("bursty expected"),
        }
    }

    #[test]
    #[should_panic]
    fn zero_rate_process_rejected() {
        let _ = ArrivalGen::new(ArrivalProcess::Poisson { rate: 0.0 }, 1);
    }

    #[test]
    fn with_mean_rate_rescales_preserving_shape() {
        let p = ArrivalProcess::bursty_two_tenant(20_000.0, 0.25, 2.0, 0.3, 100 * MS);
        let q = p.with_mean_rate(40_000.0);
        assert!((q.mean_rate() - 40_000.0).abs() < 1e-6);
        assert!((q.peak_rate() - 80_000.0).abs() < 1e-6, "burst factor preserved");
        assert_eq!(q.label(), "bursty-mix");
        assert_eq!(q.tenant_names(), p.tenant_names());
        assert!(q.tenant_carries_avx(1) && !q.tenant_carries_avx(0));
        // Shares preserved: avx tenant still carries 25%.
        match &q {
            ArrivalProcess::MultiTenant { tenants } => {
                assert!((tenants[1].rate - 10_000.0).abs() < 1e-6);
            }
            other => panic!("mix expected, got {other:?}"),
        }
        let b = ArrivalProcess::bursty_mean(10_000.0, 2.0, 0.3, 200 * MS).with_mean_rate(5_000.0);
        assert!((b.mean_rate() - 5_000.0).abs() < 1e-6);
    }

    #[test]
    fn bursty_mix_preserves_means_and_phases() {
        let p = ArrivalProcess::bursty_two_tenant(20_000.0, 0.25, 2.0, 0.3, 100 * MS);
        assert_eq!(p.label(), "bursty-mix");
        assert_eq!(ArrivalProcess::two_tenant(20_000.0, 0.25).label(), "mix");
        assert!((p.mean_rate() - 20_000.0).abs() < 1.0);
        assert!((p.peak_rate() - 40_000.0).abs() < 1.0);
        let arrivals = drain(&mut ArrivalGen::new(p.clone(), 9), SEC);
        let again = drain(&mut ArrivalGen::new(p, 9), SEC);
        assert_eq!(arrivals, again, "same seed must give the same stream");
        let n = arrivals.len() as f64;
        assert!((n - 20_000.0).abs() / 20_000.0 < 0.06, "got {n} arrivals/s");
        let avx = arrivals.iter().filter(|(_, t)| *t == 1).count() as f64;
        assert!((avx - 5_000.0).abs() / 5_000.0 < 0.12, "avx tenant got {avx}");
        // Both tenants burst in phase: 2× bursts at 30% duty put 60% of
        // all arrivals inside the shared on-phase.
        let on = arrivals.iter().filter(|(t, _)| t % (100 * MS) < 30 * MS).count() as f64;
        assert!((on / n - 0.6).abs() < 0.05, "on-phase share {}", on / n);
        assert!(arrivals.windows(2).all(|w| w[0].0 < w[1].0), "merged stream ordered");
    }
}
