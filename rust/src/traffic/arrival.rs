//! Arrival processes: deterministic request-stream generators.
//!
//! wrk2 (the paper's load generator) offers a fixed-rate Poisson stream;
//! real web traffic is burstier, periodic, and multi-tenant. Every
//! process here is generated from a seeded [`Rng`] only, so a traffic
//! run is bit-for-bit reproducible and safe to execute on any OS thread
//! of a scenario-matrix sweep.
//!
//! Time-varying processes (bursty, diurnal) are sampled by Lewis–Shedler
//! thinning: candidate arrivals are drawn from a homogeneous Poisson
//! process at the peak rate and accepted with probability
//! `rate(t) / peak`, which is exact for any bounded rate function.

use crate::sim::Time;
use crate::util::Rng;

/// One tenant of a multi-tenant mix.
#[derive(Clone, Debug, PartialEq)]
pub struct Tenant {
    /// Short label used in tables (e.g. `avx`, `scalar`).
    pub name: String,
    /// This tenant's mean arrival rate (requests/second).
    pub rate: f64,
    /// Whether this tenant's requests execute wide (AVX) instructions;
    /// the web server gives non-AVX tenants an SSE4 request pipeline
    /// with no `with_avx()` annotations.
    pub avx: bool,
}

/// An open-loop arrival process (requests/second over simulated time).
#[derive(Clone, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals at a fixed mean rate (wrk2's model).
    Poisson { rate: f64 },
    /// On/off burst cycle: `burst_rate` for `on` nanoseconds, then
    /// `base_rate` for `off` nanoseconds, repeating.
    Bursty { base_rate: f64, burst_rate: f64, on: Time, off: Time },
    /// Sinusoidal ramp around a mean: `rate(t) = mean_rate * (1 + swing
    /// * sin(2πt/period))`. A compressed stand-in for diurnal load
    /// (`swing < 1` keeps the rate strictly positive).
    Diurnal { mean_rate: f64, swing: f64, period: Time },
    /// Independent Poisson streams, one per tenant; requests carry the
    /// tenant index so per-tenant tails can be separated.
    MultiTenant { tenants: Vec<Tenant> },
}

impl ArrivalProcess {
    /// Short label used in tables.
    pub fn label(&self) -> String {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson".to_string(),
            ArrivalProcess::Bursty { .. } => "bursty".to_string(),
            ArrivalProcess::Diurnal { .. } => "diurnal".to_string(),
            // One vocabulary across CLI (`--arrivals mix`), config
            // (`load.process = "mix"`), and both label functions.
            ArrivalProcess::MultiTenant { .. } => "mix".to_string(),
        }
    }

    /// Long-run mean offered rate (requests/second).
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, on, off } => {
                let cycle = (*on + *off).max(1) as f64;
                (burst_rate * *on as f64 + base_rate * *off as f64) / cycle
            }
            ArrivalProcess::Diurnal { mean_rate, .. } => *mean_rate,
            ArrivalProcess::MultiTenant { tenants } => tenants.iter().map(|t| t.rate).sum(),
        }
    }

    /// Peak instantaneous rate (the thinning envelope).
    pub fn peak_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, .. } => base_rate.max(*burst_rate),
            ArrivalProcess::Diurnal { mean_rate, swing, .. } => mean_rate * (1.0 + swing),
            ArrivalProcess::MultiTenant { tenants } => tenants.iter().map(|t| t.rate).sum(),
        }
    }

    /// Instantaneous rate at simulated time `t`.
    pub fn rate_at(&self, t: Time) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, on, off } => {
                let cycle = (*on + *off).max(1);
                if t % cycle < *on {
                    *burst_rate
                } else {
                    *base_rate
                }
            }
            ArrivalProcess::Diurnal { mean_rate, swing, period } => {
                let period = (*period).max(1);
                let phase = (t % period) as f64 / period as f64;
                mean_rate * (1.0 + swing * (2.0 * std::f64::consts::PI * phase).sin())
            }
            ArrivalProcess::MultiTenant { tenants } => tenants.iter().map(|t| t.rate).sum(),
        }
    }

    /// Number of tenants (1 for single-stream processes).
    pub fn n_tenants(&self) -> usize {
        match self {
            ArrivalProcess::MultiTenant { tenants } => tenants.len().max(1),
            _ => 1,
        }
    }

    /// Tenant labels, in tenant-index order (`all` for single-stream).
    pub fn tenant_names(&self) -> Vec<String> {
        match self {
            ArrivalProcess::MultiTenant { tenants } => {
                tenants.iter().map(|t| t.name.clone()).collect()
            }
            _ => vec!["all".to_string()],
        }
    }

    /// Whether tenant `i` carries AVX work (single-stream processes
    /// always do: their pipeline follows the configured ISA).
    pub fn tenant_carries_avx(&self, i: usize) -> bool {
        match self {
            ArrivalProcess::MultiTenant { tenants } => {
                tenants.get(i).map(|t| t.avx).unwrap_or(true)
            }
            _ => true,
        }
    }

    /// Mean-preserving bursty process: bursts at `burst_factor × rate`
    /// for a `duty` fraction of each `period`, with the base rate chosen
    /// so the long-run mean stays `rate` (clamped at 0 when the bursts
    /// alone exceed the mean, i.e. `burst_factor × duty > 1`).
    pub fn bursty_mean(rate: f64, burst_factor: f64, duty: f64, period: Time) -> ArrivalProcess {
        let duty = duty.clamp(0.01, 0.99);
        let on = ((period as f64 * duty) as Time).max(1);
        let off = period.saturating_sub(on).max(1);
        let burst_rate = rate * burst_factor.max(0.0);
        let base_rate = ((rate - duty * burst_rate) / (1.0 - duty)).max(0.0);
        ArrivalProcess::Bursty { base_rate, burst_rate, on, off }
    }

    /// A two-tenant mix at total rate `rate`: an `avx` tenant carrying
    /// `avx_share` of the traffic and a `scalar` tenant with the rest.
    pub fn two_tenant(rate: f64, avx_share: f64) -> ArrivalProcess {
        let share = avx_share.clamp(0.0, 1.0);
        ArrivalProcess::MultiTenant {
            tenants: vec![
                Tenant { name: "scalar".to_string(), rate: rate * (1.0 - share), avx: false },
                Tenant { name: "avx".to_string(), rate: rate * share, avx: true },
            ],
        }
    }
}

/// Deterministic arrival-stream generator for one [`ArrivalProcess`].
///
/// [`ArrivalGen::next_after`] returns strictly increasing times, so the
/// driver loop (one pending arrival event, regenerated on delivery)
/// always makes progress.
#[derive(Clone, Debug)]
pub struct ArrivalGen {
    process: ArrivalProcess,
    rng: Rng,
    /// Multi-tenant: next pending arrival per tenant (lazily seeded on
    /// the first call so the stream starts at the caller's clock).
    tenant_next: Vec<Time>,
}

impl ArrivalGen {
    /// Build a generator. Panics if the process can never produce an
    /// arrival (peak rate ≤ 0) — a zero-rate run would hang the driver.
    pub fn new(process: ArrivalProcess, seed: u64) -> Self {
        assert!(
            process.peak_rate() > 0.0,
            "arrival process {:?} has no positive rate",
            process.label()
        );
        ArrivalGen { process, rng: Rng::new(seed), tenant_next: Vec::new() }
    }

    /// The process this generator samples.
    pub fn process(&self) -> &ArrivalProcess {
        &self.process
    }

    /// Next arrival strictly after `now`: `(time, tenant index)`.
    pub fn next_after(&mut self, now: Time) -> (Time, u32) {
        // Disjoint field borrows: the process is read-only while the RNG
        // and the per-tenant state mutate.
        let ArrivalGen { process, rng, tenant_next } = self;
        match &*process {
            ArrivalProcess::Poisson { rate } => {
                let gap = rng.exponential(1e9 / *rate).max(1.0) as Time;
                (now + gap.max(1), 0)
            }
            ArrivalProcess::Bursty { .. } | ArrivalProcess::Diurnal { .. } => {
                // Lewis–Shedler thinning at the peak rate.
                let peak = process.peak_rate();
                let mut t = now as f64;
                loop {
                    t += rng.exponential(1e9 / peak).max(1e-3);
                    let r = process.rate_at(t as Time);
                    if r > 0.0 && rng.chance(r / peak) {
                        return ((t as Time).max(now + 1), 0);
                    }
                }
            }
            ArrivalProcess::MultiTenant { tenants } => {
                if tenant_next.len() != tenants.len() {
                    // First call: seed every tenant's stream at `now`.
                    *tenant_next = tenants
                        .iter()
                        .map(|t| {
                            if t.rate > 0.0 {
                                now + (rng.exponential(1e9 / t.rate).max(1.0) as Time).max(1)
                            } else {
                                Time::MAX
                            }
                        })
                        .collect();
                }
                let (i, t) = tenant_next
                    .iter()
                    .copied()
                    .enumerate()
                    .min_by_key(|&(_, t)| t)
                    .expect("at least one tenant");
                let gap = (rng.exponential(1e9 / tenants[i].rate).max(1.0) as Time).max(1);
                tenant_next[i] = t.saturating_add(gap);
                (t.max(now + 1), i as u32)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MS, SEC};

    fn drain(gen: &mut ArrivalGen, until: Time) -> Vec<(Time, u32)> {
        let mut out = Vec::new();
        let mut now = 0;
        loop {
            let (t, tenant) = gen.next_after(now);
            if t > until {
                return out;
            }
            out.push((t, tenant));
            now = t;
        }
    }

    #[test]
    fn poisson_rate_and_determinism() {
        let p = ArrivalProcess::Poisson { rate: 10_000.0 };
        let a = drain(&mut ArrivalGen::new(p.clone(), 7), SEC);
        let b = drain(&mut ArrivalGen::new(p, 7), SEC);
        assert_eq!(a, b, "same seed must give the same stream");
        let n = a.len() as f64;
        assert!((n - 10_000.0).abs() / 10_000.0 < 0.05, "got {n} arrivals/s");
        assert!(a.windows(2).all(|w| w[0].0 < w[1].0), "strictly increasing");
    }

    #[test]
    fn bursty_respects_phases() {
        let p = ArrivalProcess::Bursty {
            base_rate: 0.0,
            burst_rate: 50_000.0,
            on: 10 * MS,
            off: 40 * MS,
        };
        assert!((p.mean_rate() - 10_000.0).abs() < 1.0);
        let arrivals = drain(&mut ArrivalGen::new(p, 3), SEC);
        assert!(!arrivals.is_empty());
        for (t, _) in &arrivals {
            assert!(t % (50 * MS) < 10 * MS, "arrival at {t} outside the on-phase");
        }
        // Thinning preserves the mean: ~10 000 arrivals over 1 s.
        let n = arrivals.len() as f64;
        assert!((n - 10_000.0).abs() / 10_000.0 < 0.10, "got {n} arrivals/s");
    }

    #[test]
    fn diurnal_mean_preserved_and_bounded() {
        let p = ArrivalProcess::Diurnal { mean_rate: 20_000.0, swing: 0.6, period: 100 * MS };
        assert!((p.peak_rate() - 32_000.0).abs() < 1.0);
        // Whole periods only, so the sine integrates to zero.
        let arrivals = drain(&mut ArrivalGen::new(p, 11), SEC);
        let n = arrivals.len() as f64;
        assert!((n - 20_000.0).abs() / 20_000.0 < 0.05, "got {n} arrivals/s");
    }

    #[test]
    fn multi_tenant_interleaves_and_labels() {
        let p = ArrivalProcess::two_tenant(20_000.0, 0.25);
        assert_eq!(p.n_tenants(), 2);
        assert_eq!(p.tenant_names(), vec!["scalar".to_string(), "avx".to_string()]);
        assert!(!p.tenant_carries_avx(0));
        assert!(p.tenant_carries_avx(1));
        let arrivals = drain(&mut ArrivalGen::new(p, 5), SEC);
        let avx = arrivals.iter().filter(|(_, t)| *t == 1).count() as f64;
        let scalar = arrivals.iter().filter(|(_, t)| *t == 0).count() as f64;
        assert!((avx - 5_000.0).abs() / 5_000.0 < 0.10, "avx tenant got {avx}");
        assert!((scalar - 15_000.0).abs() / 15_000.0 < 0.10, "scalar tenant got {scalar}");
        assert!(arrivals.windows(2).all(|w| w[0].0 < w[1].0), "merged stream ordered");
    }

    #[test]
    fn bursty_mean_preserves_rate() {
        let p = ArrivalProcess::bursty_mean(10_000.0, 2.0, 0.3, 200 * MS);
        assert!((p.mean_rate() - 10_000.0).abs() < 1.0, "mean={}", p.mean_rate());
        assert!((p.peak_rate() - 20_000.0).abs() < 1.0);
        // Overdriven bursts clamp the base at zero (mean then exceeds
        // nothing — it just equals duty × burst).
        let q = ArrivalProcess::bursty_mean(10_000.0, 4.0, 0.5, 200 * MS);
        match q {
            ArrivalProcess::Bursty { base_rate, .. } => assert_eq!(base_rate, 0.0),
            _ => panic!("bursty expected"),
        }
    }

    #[test]
    #[should_panic]
    fn zero_rate_process_rejected() {
        let _ = ArrivalGen::new(ArrivalProcess::Poisson { rate: 0.0 }, 1);
    }
}
