//! Open-/closed-loop traffic generation and per-request tail-latency
//! accounting.
//!
//! The paper's headline harm is tail-side: intermittent AVX code slows
//! *the rest of the system*, and at scale such performance variations
//! dominate (Schuchart et al.). Mean throughput cannot express that —
//! a 10% capacity loss shows up as a 10% throughput drop only past
//! saturation, but as a 2–10× p99 blow-up well before it. This module
//! provides the two pieces the reproduction needs to state SLO damage:
//!
//! * [`arrival`] — deterministic arrival processes ([`ArrivalProcess`]):
//!   Poisson (the wrk2 baseline), bursty on/off, a compressed diurnal
//!   ramp, and multi-tenant mixes where only some tenants carry AVX
//!   work. [`ArrivalGen`] turns a process into a reproducible event
//!   stream for the [`crate::sched::machine::Driver`] loop.
//! * [`lifecycle`] — the per-request record ([`Request`]) carried from
//!   arrival to completion, and [`LatencyStats`]: a
//!   [`crate::util::LogHistogram`]-backed recorder producing
//!   p50/p95/p99/p999/max and the SLO-violation fraction
//!   ([`TailSummary`]).
//!
//! The web-server workload ([`crate::workload::client`] /
//! [`crate::workload::webserver`]) consumes both; the scenario matrix
//! ([`crate::scenario`]) sweeps load level × arrival process as first-
//! class axes and [`crate::metrics::tail_report`] renders the table.
//! Everything is seeded and thread-free, so matrix runs stay
//! byte-identical across OS thread counts.

pub mod arrival;
pub mod lifecycle;

pub use arrival::{ArrivalGen, ArrivalProcess, Tenant, TenantBurst};
pub use lifecycle::{
    FaultOutcomes, FrontendOutcomes, LatencyStats, RecorderArena, Request, TailSummary,
};
