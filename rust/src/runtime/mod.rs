//! Runtime: loads the AOT-compiled ChaCha20-Poly1305 HLO artifacts and
//! executes them via the PJRT C API (`xla` crate) — Python never runs on
//! the request path.
//!
//! * [`aead`] — pure-Rust ChaCha20-Poly1305 used to *verify* every PJRT
//!   result in tests and as the client-side of the example server.
//! * [`executor`] — PJRT client wrapper: one compiled executable per
//!   SIMD-width variant (`chacha_w{4,8,16}.hlo.txt`).
//! * [`server`] — `avxfreq serve`: a threaded TLS-record-style server
//!   whose crypto path runs the PJRT executables, with the paper's
//!   core-specialization pattern applied at user level (crypto confined
//!   to a dedicated worker pool pinned to the last cores).
//! * [`calibrate`] — measures per-width sealing cost and compares the
//!   width-scaling shape against the simulator's crypto profiles.

pub mod aead;
pub mod executor;
pub mod server;
pub mod calibrate;

pub use executor::{CryptoExecutor, Width};
