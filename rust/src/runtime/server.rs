//! `avxfreq serve` — a real record-encrypting server on the PJRT crypto
//! path, demonstrating the paper's pattern at user level.
//!
//! Architecture (the "rearchitected application" §1/§5 contrasts with the
//! scheduler approach): scalar work (accept, framing, compression via
//! flate2) runs on the *scalar* worker pool; all AEAD sealing is confined
//! to a dedicated *crypto* pool pinned (via `sched_setaffinity`) to the
//! last cores — the user-space analog of AVX cores. `--no-specialize`
//! runs crypto inline on the scalar workers for comparison.
//!
//! Protocol (length-prefixed, little-endian):
//!   request:  u32 page_bytes (the "file" size to serve)
//!   response: u32 n_records · u64 payload_len · per record:
//!             record_words·4 bytes ciphertext · 16 bytes tag
//! The payload is a deterministic pseudo-HTML page, deflate-compressed
//! on the fly, then sealed record-by-record (16 KiB records).

use super::executor::{CryptoExecutor, Width};
use crate::util::args::Args;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

/// Pin the calling thread to one core (best-effort; ignored on failure).
pub fn pin_to_core(core: usize) {
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_SET(core % num_cpus(), &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
    }
}

pub fn num_cpus() -> usize {
    unsafe { libc::sysconf(libc::_SC_NPROCESSORS_ONLN).max(1) as usize }
}

/// Deterministic pseudo-HTML page of the requested size.
pub fn synth_page(bytes: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(bytes);
    let para = b"<p>The quick brown fox jumps over the lazy dog; AVX-512 drops the clock.</p>\n";
    while out.len() < bytes {
        let take = para.len().min(bytes - out.len());
        out.extend_from_slice(&para[..take]);
    }
    out
}

/// Deflate-compress (the brotli stand-in available offline).
///
/// Encoder errors surface as `Err` so one bad page degrades to one
/// failed connection instead of panicking the accept loop.
pub fn compress(data: &[u8]) -> Result<Vec<u8>> {
    use flate2::write::DeflateEncoder;
    use flate2::Compression;
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::new(4));
    enc.write_all(data).context("deflate write")?;
    enc.finish().context("deflate finish")
}

/// Reassemble an AEAD tag from its wire bytes, rejecting malformed
/// lengths instead of panicking mid-`fetch` — a truncated or corrupt
/// response is a protocol error the caller can report, not a client
/// crash.
fn tag_words(tag: &[u8]) -> Result<[u32; 4]> {
    super::aead::bytes_to_words(tag)
        .try_into()
        .map_err(|_| anyhow::anyhow!("malformed tag: {} bytes, expected 16", tag.len()))
}

struct SealJob {
    payload: Vec<u8>,
    reply: mpsc::Sender<Result<(Vec<super::executor::Sealed>, usize)>>,
}

/// Take the next job off the shared crypto-pool receiver, tolerating a
/// poisoned mutex.
///
/// A crypto worker that panics while holding the receiver lock (e.g. a
/// bug inside `recv`-adjacent code) poisons the `Mutex`; with a plain
/// `rx.lock().unwrap()` every *surviving* worker would then panic on its
/// next job fetch and the whole pool would cascade down from one fault.
/// The receiver itself is still perfectly usable — mutex poisoning only
/// records that *some* thread panicked mid-critical-section, and the
/// only state under this lock is the channel handle — so we recover the
/// guard with `into_inner()` and log the recovery once per occurrence.
/// Returns `None` when the sending side is gone (clean shutdown).
fn recv_job(rx: &Arc<std::sync::Mutex<mpsc::Receiver<SealJob>>>) -> Option<SealJob> {
    let guard = match rx.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            eprintln!(
                "[serve] crypto pool: receiver mutex poisoned by a panicking \
                 worker; recovering the guard and continuing"
            );
            poisoned.into_inner()
        }
    };
    guard.recv().ok()
}

/// Stats shared across connections.
#[derive(Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub records: AtomicU64,
    pub bytes_sealed: AtomicU64,
}

/// Run the server until `max_requests` (0 = forever). Returns the bound port.
#[allow(clippy::too_many_arguments)]
pub fn serve(
    artifacts: &str,
    port: u16,
    width: Width,
    crypto_threads: usize,
    specialize: bool,
    max_requests: u64,
    stats: Arc<ServeStats>,
) -> Result<u16> {
    serve_with_port_callback(
        artifacts,
        port,
        width,
        crypto_threads,
        specialize,
        max_requests,
        stats,
        |_| {},
    )
}

/// Like [`serve`] but reports the bound port through `on_bound` before
/// accepting — lets callers bind port 0 and connect from another thread.
#[allow(clippy::too_many_arguments)]
pub fn serve_with_port_callback(
    artifacts: &str,
    port: u16,
    width: Width,
    crypto_threads: usize,
    specialize: bool,
    max_requests: u64,
    stats: Arc<ServeStats>,
    on_bound: impl FnOnce(u16),
) -> Result<u16> {
    let listener = TcpListener::bind(("127.0.0.1", port)).context("bind")?;
    let bound = listener.local_addr()?.port();
    on_bound(bound);

    // Crypto pool: the user-space AVX cores. The `xla` crate's PJRT
    // handles are not Send, so every crypto worker owns its *own* client
    // and compiled executables (loaded from the same artifacts).
    let (tx, rx) = mpsc::channel::<SealJob>();
    let rx = Arc::new(std::sync::Mutex::new(rx));
    let inline_ex = if specialize {
        for i in 0..crypto_threads {
            let rx = rx.clone();
            let ncpu = num_cpus();
            let artifacts = artifacts.to_string();
            std::thread::spawn(move || {
                // Last cores = AVX cores, mirroring the paper's §4 setup.
                pin_to_core(ncpu - 1 - (i % crypto_threads.max(1)));
                let ex = match CryptoExecutor::load(&artifacts) {
                    Ok(ex) => ex,
                    Err(e) => {
                        eprintln!("[serve] crypto worker {i}: {e:#}");
                        return;
                    }
                };
                let key: [u32; 8] =
                    core::array::from_fn(|k| 0x2400_0001u32.wrapping_mul(k as u32 + 1));
                loop {
                    let job = match recv_job(&rx) {
                        Some(j) => j,
                        None => return,
                    };
                    let nonce = [0u32, 0xC0DE, 0xF00D];
                    let res = ex.seal_bytes(width, &key, &nonce, &job.payload);
                    let _ = job.reply.send(res);
                }
            });
        }
        None
    } else {
        Some(CryptoExecutor::load(artifacts)?)
    };
    eprintln!(
        "[serve] width {:?} ({}) | crypto: {} | 127.0.0.1:{bound}",
        width,
        width.isa_name(),
        if specialize {
            format!("{crypto_threads} pinned workers")
        } else {
            "inline (no specialization)".to_string()
        },
    );

    let mut served = 0u64;
    for stream in listener.incoming() {
        let mut stream = stream?;
        handle_conn(&mut stream, &tx, inline_ex.as_ref(), width, &stats)?;
        served += 1;
        if max_requests > 0 && served >= max_requests {
            break;
        }
    }
    Ok(bound)
}

fn handle_conn(
    stream: &mut TcpStream,
    tx: &mpsc::Sender<SealJob>,
    inline_ex: Option<&CryptoExecutor>,
    width: Width,
    stats: &ServeStats,
) -> Result<()> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let page_bytes = u32::from_le_bytes(len_buf) as usize;
    anyhow::ensure!(page_bytes <= 16 << 20, "page too large");

    // Scalar phase: build + compress the page.
    let page = synth_page(page_bytes);
    let compressed = compress(&page)?;

    // Crypto phase: sealed on the crypto pool (specialized) or inline.
    let (records, payload_len) = match inline_ex {
        None => {
            let (reply_tx, reply_rx) = mpsc::channel();
            tx.send(SealJob { payload: compressed, reply: reply_tx })
                .map_err(|_| anyhow::anyhow!("crypto pool gone"))?;
            reply_rx.recv()??
        }
        Some(ex) => {
            let key: [u32; 8] =
                core::array::from_fn(|k| 0x2400_0001u32.wrapping_mul(k as u32 + 1));
            let nonce = [0u32, 0xC0DE, 0xF00D];
            ex.seal_bytes(width, &key, &nonce, &compressed)?
        }
    };

    stats.requests.fetch_add(1, Ordering::Relaxed);
    stats.records.fetch_add(records.len() as u64, Ordering::Relaxed);
    stats.bytes_sealed.fetch_add(payload_len as u64, Ordering::Relaxed);

    stream.write_all(&(records.len() as u32).to_le_bytes())?;
    stream.write_all(&(payload_len as u64).to_le_bytes())?;
    for r in &records {
        stream.write_all(&super::aead::words_to_bytes(&r.ct_words))?;
        for t in r.tag {
            stream.write_all(&t.to_le_bytes())?;
        }
    }
    Ok(())
}

/// CLI entry point.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let port = args.get_parse::<u16>("port", 8443);
    let width = match args.get_or("width", "16") {
        "4" => Width::W4,
        "8" => Width::W8,
        _ => Width::W16,
    };
    let crypto_threads = args.get_parse::<usize>("crypto-threads", 2);
    let specialize = !args.flag("no-specialize");
    let max_requests = args.get_parse::<u64>("max-requests", 0);
    let stats = Arc::new(ServeStats::default());
    serve(artifacts, port, width, crypto_threads, specialize, max_requests, stats.clone())?;
    eprintln!(
        "[serve] done: {} requests, {} records, {} bytes sealed",
        stats.requests.load(Ordering::Relaxed),
        stats.records.load(Ordering::Relaxed),
        stats.bytes_sealed.load(Ordering::Relaxed)
    );
    Ok(())
}

/// Simple client for tests/examples: request a page, verify every record
/// with the rust reference AEAD, return the decrypted payload.
pub fn fetch(addr: &str, page_bytes: u32) -> Result<Vec<u8>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(&page_bytes.to_le_bytes())?;
    let mut hdr = [0u8; 4];
    stream.read_exact(&mut hdr)?;
    let n_records = u32::from_le_bytes(hdr) as usize;
    let mut len8 = [0u8; 8];
    stream.read_exact(&mut len8)?;
    let payload_len = u64::from_le_bytes(len8) as usize;

    let key: [u32; 8] = core::array::from_fn(|k| 0x2400_0001u32.wrapping_mul(k as u32 + 1));
    let mut plain = Vec::new();
    let record_words = 4096; // RECORD_WORDS (manifest-checked server side)
    for i in 0..n_records {
        let mut ct = vec![0u8; record_words * 4];
        stream.read_exact(&mut ct)?;
        let mut tag = [0u8; 16];
        stream.read_exact(&mut tag)?;
        let ct_words = super::aead::bytes_to_words(&ct);
        let tag_words = tag_words(&tag)?;
        let nonce = [i as u32, 0xC0DE, 0xF00D];
        let pt = super::aead::open_record(&key, &nonce, &ct_words, &tag_words)
            .context("record failed authentication")?;
        plain.extend_from_slice(&super::aead::words_to_bytes(&pt));
    }
    plain.truncate(payload_len);
    Ok(plain)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// Regression: a worker that panics while holding the receiver lock
    /// must not take the surviving workers with it. We poison the mutex
    /// exactly the way a mid-`recv` panic would, then prove `recv_job`
    /// still drains jobs and still signals clean shutdown.
    #[test]
    fn recv_job_survives_a_poisoned_receiver_mutex() {
        let (tx, rx) = mpsc::channel::<SealJob>();
        let rx = Arc::new(Mutex::new(rx));

        // Panic while holding the lock — the cascade trigger.
        let rx2 = rx.clone();
        let _ = std::thread::spawn(move || {
            let _guard = rx2.lock().unwrap();
            panic!("simulated crypto worker fault");
        })
        .join();
        assert!(rx.lock().is_err(), "mutex must actually be poisoned");

        // A surviving worker can still fetch queued work...
        let (reply_tx, _reply_rx) = mpsc::channel();
        tx.send(SealJob { payload: vec![1, 2, 3], reply: reply_tx }).unwrap();
        let job = recv_job(&rx).expect("queued job must survive the poisoning");
        assert_eq!(job.payload, vec![1, 2, 3]);

        // ...and still sees the clean-shutdown signal when senders drop.
        drop(tx);
        assert!(recv_job(&rx).is_none(), "disconnect still exits cleanly");
    }

    /// Regression: `compress` reports failure through `Result` rather
    /// than panicking, and still round-trips on the happy path.
    #[test]
    fn compress_returns_ok_and_roundtrips() {
        let page = synth_page(4096);
        let packed = compress(&page).expect("in-memory deflate must succeed");
        assert!(!packed.is_empty() && packed.len() < page.len());
        use std::io::Read as _;
        let mut plain = Vec::new();
        flate2::read::DeflateDecoder::new(&packed[..])
            .read_to_end(&mut plain)
            .expect("round-trip decode");
        assert_eq!(plain, page);
    }

    /// Regression: a truncated or oversized tag off the wire is a
    /// protocol error, not a client panic.
    #[test]
    fn tag_words_rejects_malformed_lengths() {
        assert!(tag_words(&[0u8; 16]).is_ok());
        for bad in [0usize, 4, 15, 17, 32] {
            let err = tag_words(&vec![0u8; bad])
                .expect_err("wrong-size tag must be rejected");
            assert!(err.to_string().contains("malformed tag"), "{err}");
        }
    }
}
