//! PJRT executor: loads `artifacts/chacha_w{4,8,16}.hlo.txt` and executes
//! the AOT ChaCha20-Poly1305 seal on the request path.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. One compiled executable per SIMD
//! width variant; the loader reads `manifest.txt` for the record size.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// SIMD-width variant (the paper's ISA axis, as lane batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Width {
    W4,
    W8,
    W16,
}

impl Width {
    pub fn lanes(self) -> usize {
        match self {
            Width::W4 => 4,
            Width::W8 => 8,
            Width::W16 => 16,
        }
    }

    pub fn all() -> [Width; 3] {
        [Width::W4, Width::W8, Width::W16]
    }

    /// The ISA each lane width stands in for.
    pub fn isa_name(self) -> &'static str {
        match self {
            Width::W4 => "sse4",
            Width::W8 => "avx2",
            Width::W16 => "avx512",
        }
    }
}

/// Probe the PJRT CPU backend without touching any artifacts: `Ok` with
/// the platform name when a client comes up, `Err` with the backend's
/// own reason otherwise (the vendored offline stub always reports
/// itself unavailable). The `runtime_roundtrip` SKIP notice prints this
/// verdict so a skip distinguishes "no artifacts" from "no backend"
/// straight from the CI log.
pub fn probe_backend() -> std::result::Result<String, String> {
    match xla::PjRtClient::cpu() {
        Ok(client) => Ok(client.platform_name()),
        Err(e) => Err(e.to_string()),
    }
}

/// A sealed record.
#[derive(Clone, Debug, PartialEq)]
pub struct Sealed {
    pub ct_words: Vec<u32>,
    pub tag: [u32; 4],
}

/// PJRT client + one compiled executable per width.
pub struct CryptoExecutor {
    client: xla::PjRtClient,
    exes: BTreeMap<Width, xla::PjRtLoadedExecutable>,
    pub record_words: usize,
}

impl CryptoExecutor {
    /// Load all width variants from an artifacts directory.
    pub fn load(dir: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let manifest = std::fs::read_to_string(Path::new(dir).join("manifest.txt"))
            .with_context(|| format!("read {dir}/manifest.txt — run `make artifacts` first"))?;
        let record_words = manifest
            .lines()
            .find_map(|l| l.strip_prefix("record_words="))
            .context("manifest missing record_words")?
            .parse::<usize>()?;
        let mut exes = BTreeMap::new();
        for w in Width::all() {
            let path = Path::new(dir).join(format!("chacha_w{}.hlo.txt", w.lanes()));
            let path_str = path.to_str().unwrap();
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .with_context(|| format!("parse HLO text {path_str}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compile {path_str}"))?;
            exes.insert(w, exe);
        }
        Ok(CryptoExecutor { client, exes, record_words })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Seal one record (`msg_words.len() == record_words`) with the given
    /// width variant. Executes the AOT HLO on the PJRT CPU device.
    pub fn seal(&self, width: Width, key: &[u32; 8], nonce: &[u32; 3], msg_words: &[u32]) -> Result<Sealed> {
        anyhow::ensure!(
            msg_words.len() == self.record_words,
            "record must be exactly {} words, got {}",
            self.record_words,
            msg_words.len()
        );
        let key_l = xla::Literal::vec1(key.as_slice());
        let nonce_l = xla::Literal::vec1(nonce.as_slice());
        let msg_l = xla::Literal::vec1(msg_words);
        let exe = &self.exes[&width];
        let result = exe.execute::<xla::Literal>(&[key_l, nonce_l, msg_l])?[0][0]
            .to_literal_sync()?;
        let (ct_l, tag_l) = result.to_tuple2()?;
        let ct_words = ct_l.to_vec::<u32>()?;
        let tag_vec = tag_l.to_vec::<u32>()?;
        anyhow::ensure!(tag_vec.len() == 4, "tag must be 4 words");
        Ok(Sealed { ct_words, tag: [tag_vec[0], tag_vec[1], tag_vec[2], tag_vec[3]] })
    }

    /// Seal an arbitrary byte payload: chunk into records (zero-padded
    /// final record), one nonce per record derived from `nonce_base` by
    /// incrementing word 0. Returns per-record seals plus original length.
    pub fn seal_bytes(
        &self,
        width: Width,
        key: &[u32; 8],
        nonce_base: &[u32; 3],
        payload: &[u8],
    ) -> Result<(Vec<Sealed>, usize)> {
        let record_bytes = self.record_words * 4;
        let mut out = Vec::new();
        let mut i = 0usize;
        let mut rec = 0u32;
        while i < payload.len() || (payload.is_empty() && rec == 0) {
            let end = (i + record_bytes).min(payload.len());
            let mut words = vec![0u32; self.record_words];
            for (wi, chunk) in payload[i..end].chunks(4).enumerate() {
                let mut b = [0u8; 4];
                b[..chunk.len()].copy_from_slice(chunk);
                words[wi] = u32::from_le_bytes(b);
            }
            let nonce = [nonce_base[0].wrapping_add(rec), nonce_base[1], nonce_base[2]];
            out.push(self.seal(width, key, &nonce, &words)?);
            i = end;
            rec += 1;
            if payload.is_empty() {
                break;
            }
        }
        Ok((out, payload.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::aead;

    fn artifacts_dir() -> Option<String> {
        let dir = std::env::var("AVXFREQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        std::path::Path::new(&dir).join("manifest.txt").exists().then_some(dir)
    }

    #[test]
    #[ignore = "compiles the HLO modules (~minutes); covered by tests/runtime_roundtrip.rs"]
    fn pjrt_seal_matches_rust_reference() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let ex = CryptoExecutor::load(&dir).expect("load artifacts");
        let key: [u32; 8] = core::array::from_fn(|i| (i as u32 + 1) * 0x01010101);
        let nonce = [7u32, 0xABCD, 42];
        let msg: Vec<u32> = (0..ex.record_words as u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let (want_ct, want_tag) = aead::seal_record(&key, &nonce, &msg);
        for w in Width::all() {
            let sealed = ex.seal(w, &key, &nonce, &msg).expect("seal");
            assert_eq!(sealed.ct_words, want_ct, "{w:?} ciphertext mismatch");
            assert_eq!(sealed.tag, want_tag, "{w:?} tag mismatch");
        }
    }

    #[test]
    #[ignore = "compiles the HLO modules (~minutes); covered by tests/runtime_roundtrip.rs"]
    fn seal_bytes_chunks_and_roundtrips() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let ex = CryptoExecutor::load(&dir).expect("load");
        let key: [u32; 8] = [9; 8];
        let nonce = [1u32, 2, 3];
        let payload: Vec<u8> = (0..20_000u32).map(|i| (i % 251) as u8).collect();
        let (records, len) = ex.seal_bytes(Width::W16, &key, &nonce, &payload).unwrap();
        assert_eq!(len, payload.len());
        assert_eq!(records.len(), 2, "20 kB → two 16 KiB records");
        // Decrypt with the rust reference and compare.
        let mut plain = Vec::new();
        for (i, r) in records.iter().enumerate() {
            let n = [nonce[0] + i as u32, nonce[1], nonce[2]];
            let pt = aead::open_record(&key, &n, &r.ct_words, &r.tag).expect("verify");
            plain.extend_from_slice(&aead::words_to_bytes(&pt));
        }
        assert_eq!(&plain[..len], &payload[..]);
    }
}
