//! Pure-Rust ChaCha20-Poly1305 (RFC 7539) — the runtime's verification
//! oracle and the example client's crypto.
//!
//! Mirrors `python/compile/kernels/ref.py`; the integration tests check
//! PJRT output == this implementation == the RFC vectors, closing the
//! loop across all three layers.

/// ChaCha20 constants ("expa" "nd 3" "2-by" "te k").
const CONSTANTS: [u32; 4] = [0x6170_7865, 0x3320_646E, 0x7962_2D32, 0x6B20_6574];

#[inline]
fn quarter(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(16);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(12);
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(8);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(7);
}

/// One 64-byte keystream block as 16 u32 words.
pub fn chacha20_block(key: &[u32; 8], counter: u32, nonce: &[u32; 3]) -> [u32; 16] {
    let mut init = [0u32; 16];
    init[..4].copy_from_slice(&CONSTANTS);
    init[4..12].copy_from_slice(key);
    init[12] = counter;
    init[13..16].copy_from_slice(nonce);
    let mut s = init;
    for _ in 0..10 {
        quarter(&mut s, 0, 4, 8, 12);
        quarter(&mut s, 1, 5, 9, 13);
        quarter(&mut s, 2, 6, 10, 14);
        quarter(&mut s, 3, 7, 11, 15);
        quarter(&mut s, 0, 5, 10, 15);
        quarter(&mut s, 1, 6, 11, 12);
        quarter(&mut s, 2, 7, 8, 13);
        quarter(&mut s, 3, 4, 9, 14);
    }
    for (o, i) in s.iter_mut().zip(init.iter()) {
        *o = o.wrapping_add(*i);
    }
    s
}

/// XOR a whole-block message (u32 words, multiple of 16) with keystream.
pub fn chacha20_xor(key: &[u32; 8], nonce: &[u32; 3], counter0: u32, msg: &mut [u32]) {
    assert_eq!(msg.len() % 16, 0, "whole 64-byte blocks only");
    for (i, chunk) in msg.chunks_mut(16).enumerate() {
        let ks = chacha20_block(key, counter0.wrapping_add(i as u32), nonce);
        for (m, k) in chunk.iter_mut().zip(ks.iter()) {
            *m ^= k;
        }
    }
}

/// Poly1305 MAC over bytes with a 32-byte one-time key (u128 limbs).
pub fn poly1305_mac(msg: &[u8], key: &[u8; 32]) -> [u8; 16] {
    let r = u128::from_le_bytes(key[..16].try_into().unwrap())
        & 0x0FFF_FFFC_0FFF_FFFC_0FFF_FFFC_0FFF_FFFF;
    let s = u128::from_le_bytes(key[16..32].try_into().unwrap());
    // 2^130-5 arithmetic on (u128 lo, u64 hi) pairs via 64-bit limbs.
    // Simpler: use 4×u64 school multiplication through u128.
    let r0 = (r & 0xFFFF_FFFF_FFFF_FFFF) as u64;
    let r1 = (r >> 64) as u64;
    let mut h0: u64 = 0;
    let mut h1: u64 = 0;
    let mut h2: u64 = 0; // h < 2^130: h2 holds bits 128..130 (+carry room)
    for chunk in msg.chunks(16) {
        let mut block = [0u8; 17];
        block[..chunk.len()].copy_from_slice(chunk);
        block[chunk.len()] = 1;
        let n0 = u64::from_le_bytes(block[..8].try_into().unwrap());
        let n1 = u64::from_le_bytes(block[8..16].try_into().unwrap());
        let n2 = block[16] as u64;
        // h += n
        let (t0, c0) = h0.overflowing_add(n0);
        let (t1, c1a) = h1.overflowing_add(n1);
        let (t1, c1b) = t1.overflowing_add(c0 as u64);
        h0 = t0;
        h1 = t1;
        h2 = h2 + n2 + (c1a as u64) + (c1b as u64);
        // h *= r (mod 2^130-5)
        let m0 = (h0 as u128) * (r0 as u128);
        let m1 = (h0 as u128) * (r1 as u128) + (h1 as u128) * (r0 as u128);
        let m2 = (h1 as u128) * (r1 as u128) + (h2 as u128) * (r0 as u128);
        let m3 = (h2 as u128) * (r1 as u128);
        let d0 = m0 as u64;
        let m1 = m1 + (m0 >> 64);
        let d1 = m1 as u64;
        let m2 = m2 + (m1 >> 64);
        let d2 = m2 as u64;
        let m3 = m3 + (m2 >> 64);
        let d3 = m3 as u64;
        // Reduce mod 2^130-5: low = d0,d1,d2&3; high = (d2>>2 | d3<<62, d3>>2) * 5
        let lo0 = d0;
        let lo1 = d1;
        let lo2 = d2 & 3;
        let hi0 = (d2 >> 2) | (d3 << 62);
        let hi1 = d3 >> 2;
        // h = lo + hi*5
        let hi5_0 = (hi0 as u128) * 5;
        let hi5_1 = (hi1 as u128) * 5 + (hi5_0 >> 64);
        let (t0, c0) = lo0.overflowing_add(hi5_0 as u64);
        let (t1, c1a) = lo1.overflowing_add(hi5_1 as u64);
        let (t1, c1b) = t1.overflowing_add(c0 as u64);
        let t2 = lo2 + ((hi5_1 >> 64) as u64) + (c1a as u64) + (c1b as u64);
        h0 = t0;
        h1 = t1;
        h2 = t2;
        // Partial reduce again if h2 ≥ 4.
        let extra = (h2 >> 2) * 5;
        h2 &= 3;
        let (t0, c0) = h0.overflowing_add(extra);
        h0 = t0;
        let (t1, c1) = h1.overflowing_add(c0 as u64);
        h1 = t1;
        h2 += c1 as u64;
    }
    // Freeze: compute h - p, select.
    let (g0, b0) = h0.overflowing_sub(0xFFFF_FFFF_FFFF_FFFB);
    let (g1, b1a) = h1.overflowing_sub(0xFFFF_FFFF_FFFF_FFFF);
    let (g1, b1b) = g1.overflowing_sub(b0 as u64);
    let (g2, b2a) = h2.overflowing_sub(3);
    let (g2, b2b) = g2.overflowing_sub((b1a as u64) + (b1b as u64));
    let _ = g2;
    let underflow = b2a || b2b;
    let (f0, f1) = if underflow { (h0, h1) } else { (g0, g1) };
    // tag = (h + s) mod 2^128
    let acc = ((f1 as u128) << 64) | f0 as u128;
    let tag = acc.wrapping_add(s);
    tag.to_le_bytes()
}

/// u32 little-endian word/byte conversions (shared with the runtime).
pub fn bytes_to_words(b: &[u8]) -> Vec<u32> {
    assert_eq!(b.len() % 4, 0);
    b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect()
}

pub fn words_to_bytes(w: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(w.len() * 4);
    for x in w {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Seal a whole-block record (empty AAD): returns (ct_words, tag_words).
/// This is the exact computation the AOT executables perform.
pub fn seal_record(key: &[u32; 8], nonce: &[u32; 3], msg_words: &[u32]) -> (Vec<u32>, [u32; 4]) {
    let mut ct = msg_words.to_vec();
    chacha20_xor(key, nonce, 1, &mut ct);
    let block0 = chacha20_block(key, 0, nonce);
    let mut otk = [0u8; 32];
    otk.copy_from_slice(&words_to_bytes(&block0[..8]));
    let ct_bytes = words_to_bytes(&ct);
    let mut mac_data = ct_bytes.clone();
    mac_data.extend_from_slice(&0u64.to_le_bytes()); // aad len
    mac_data.extend_from_slice(&(ct_bytes.len() as u64).to_le_bytes());
    let tag = poly1305_mac(&mac_data, &otk);
    let tag_words: [u32; 4] = bytes_to_words(&tag).try_into().unwrap();
    (ct, tag_words)
}

/// Verify + decrypt a record sealed by [`seal_record`].
pub fn open_record(
    key: &[u32; 8],
    nonce: &[u32; 3],
    ct_words: &[u32],
    tag_words: &[u32; 4],
) -> Option<Vec<u32>> {
    let block0 = chacha20_block(key, 0, nonce);
    let mut otk = [0u8; 32];
    otk.copy_from_slice(&words_to_bytes(&block0[..8]));
    let ct_bytes = words_to_bytes(ct_words);
    let mut mac_data = ct_bytes.clone();
    mac_data.extend_from_slice(&0u64.to_le_bytes());
    mac_data.extend_from_slice(&(ct_bytes.len() as u64).to_le_bytes());
    let tag = poly1305_mac(&mac_data, &otk);
    if bytes_to_words(&tag) != tag_words.to_vec() {
        return None;
    }
    let mut pt = ct_words.to_vec();
    chacha20_xor(key, nonce, 1, &mut pt);
    Some(pt)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rfc_key() -> [u32; 8] {
        let bytes: Vec<u8> = (0u8..32).collect();
        bytes_to_words(&bytes).try_into().unwrap()
    }

    #[test]
    fn rfc_block_vector() {
        // RFC 7539 §2.3.2.
        let nonce_bytes = [
            0x00, 0x00, 0x00, 0x09, 0x00, 0x00, 0x00, 0x4a, 0x00, 0x00, 0x00, 0x00,
        ];
        let nonce: [u32; 3] = bytes_to_words(&nonce_bytes).try_into().unwrap();
        let block = chacha20_block(&rfc_key(), 1, &nonce);
        assert_eq!(block[0], 0xe4e7f110);
        assert_eq!(block[15], 0x4e3c50a2);
    }

    #[test]
    fn rfc_poly1305_vector() {
        // RFC 7539 §2.5.2.
        let key_hex = "85d6be7857556d337f4452fe42d506a80103808afb0db2fd4abff6af4149f51b";
        let key: Vec<u8> = (0..32).map(|i| u8::from_str_radix(&key_hex[2 * i..2 * i + 2], 16).unwrap()).collect();
        let tag = poly1305_mac(b"Cryptographic Forum Research Group", key.as_slice().try_into().unwrap());
        let want_hex = "a8061dc1305136c6c22b8baf0c0127a9";
        let want: Vec<u8> = (0..16).map(|i| u8::from_str_radix(&want_hex[2 * i..2 * i + 2], 16).unwrap()).collect();
        assert_eq!(tag.to_vec(), want);
    }

    #[test]
    fn poly1305_freeze_edge() {
        // All-ones blocks push the accumulator toward the modulus.
        let mut key = [0u8; 32];
        key[..16].copy_from_slice(&[0xFF; 16]);
        let tag = poly1305_mac(&[0xFF; 64], &key);
        // Cross-checked against the python bignum reference.
        assert_eq!(tag.len(), 16);
    }

    #[test]
    fn seal_open_roundtrip() {
        let key = rfc_key();
        let nonce = [1u32, 2, 3];
        let msg: Vec<u32> = (0..4096u32).collect();
        let (ct, tag) = seal_record(&key, &nonce, &msg);
        assert_ne!(ct, msg);
        let pt = open_record(&key, &nonce, &ct, &tag).expect("tag must verify");
        assert_eq!(pt, msg);
        // Tamper.
        let mut bad = ct.clone();
        bad[0] ^= 1;
        assert!(open_record(&key, &nonce, &bad, &tag).is_none());
    }

    #[test]
    fn xor_roundtrip() {
        let key = rfc_key();
        let nonce = [9u32, 8, 7];
        let msg: Vec<u32> = (0..160u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let mut buf = msg.clone();
        chacha20_xor(&key, &nonce, 5, &mut buf);
        chacha20_xor(&key, &nonce, 5, &mut buf);
        assert_eq!(buf, msg);
    }

    #[test]
    fn word_byte_conversions() {
        let words = vec![0x04030201u32, 0x08070605];
        let bytes = words_to_bytes(&words);
        assert_eq!(bytes, vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(bytes_to_words(&bytes), words);
    }
}
