//! `avxfreq calibrate` — execute the AOT kernels and compare the measured
//! width-scaling against the simulator's per-ISA crypto cost profiles.
//!
//! Interpret-mode Pallas on a CPU PJRT backend gives no meaningful
//! absolute throughput, but the *relative* cost of the lane widths is
//! structural (fewer grid steps, wider vector ops per step) and is what
//! the simulator's `CryptoProfile` encodes. The command reports both and
//! their ratio so drift between the cost model and the real kernels is
//! visible.

use super::executor::{CryptoExecutor, Width};
use crate::cpu::ipc::{cost_block, IpcParams};
use crate::util::args::Args;
use crate::util::table::{fmt_f, Table};
use crate::util::Rng;
use crate::workload::crypto::{CryptoProfile, Isa};
use anyhow::Result;
use std::time::Instant;

/// Measured + modeled cost for one width.
#[derive(Clone, Debug)]
pub struct CalPoint {
    pub width: Width,
    pub measured_us_per_record: f64,
    pub model_cycles_per_byte: f64,
}

pub fn measure(ex: &CryptoExecutor, width: Width, records: usize) -> Result<f64> {
    let key: [u32; 8] = [0xAB; 8];
    let nonce = [1u32, 2, 3];
    let msg: Vec<u32> = (0..ex.record_words as u32).collect();
    // Warmup (compilation already done at load; touch caches).
    ex.seal(width, &key, &nonce, &msg)?;
    let start = Instant::now();
    for i in 0..records {
        let n = [nonce[0] + i as u32, nonce[1], nonce[2]];
        ex.seal(width, &key, &n, &msg)?;
    }
    Ok(start.elapsed().as_micros() as f64 / records as f64)
}

pub fn model_cpb(isa: Isa) -> f64 {
    let p = CryptoProfile::for_isa(isa);
    let ipc = IpcParams::default();
    let mut rng = Rng::new(1);
    let bytes = 16384;
    let records = 32;
    let mut cycles = 0.0;
    for _ in 0..records {
        for (_, b) in p.record_blocks(bytes, &mut rng) {
            cycles += cost_block(&ipc, &b, 0.0).cycles;
        }
    }
    cycles / (bytes * records) as f64
}

pub fn calibrate(artifacts: &str, records: usize) -> Result<Vec<CalPoint>> {
    let ex = CryptoExecutor::load(artifacts)?;
    let mut out = Vec::new();
    for (w, isa) in [(Width::W4, Isa::Sse4), (Width::W8, Isa::Avx2), (Width::W16, Isa::Avx512)] {
        out.push(CalPoint {
            width: w,
            measured_us_per_record: measure(&ex, w, records)?,
            model_cycles_per_byte: model_cpb(isa),
        });
    }
    Ok(out)
}

pub fn cmd_calibrate(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let records = args.get_parse::<usize>("records", 16);
    eprintln!("[calibrate] loading + compiling artifacts from {artifacts}…");
    let points = calibrate(artifacts, records)?;
    let base = &points[0];
    let mut t = Table::new(
        "AOT kernel calibration — measured (PJRT, interpret-lowered) vs simulator cost model",
        &["width", "stands for", "µs/record (measured)", "speedup vs w4", "model cpb", "model speedup"],
    );
    for p in &points {
        t.row(&[
            format!("w{}", p.width.lanes()),
            p.width.isa_name().to_string(),
            fmt_f(p.measured_us_per_record, 1),
            format!("{:.2}x", base.measured_us_per_record / p.measured_us_per_record),
            fmt_f(p.model_cycles_per_byte, 3),
            format!("{:.2}x", base.model_cycles_per_byte / p.model_cycles_per_byte),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nnote: absolute µs are CPU-interpret numbers, not TPU/x86 crypto speed; the\n\
         comparison target is the *shape* — wider lanes amortize per-step overhead the\n\
         way wider SIMD amortizes per-instruction work (DESIGN.md §Hardware-Adaptation)."
    );
    Ok(())
}
