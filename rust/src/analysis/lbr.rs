//! Last-branch-record (LBR) analysis for very short AVX bursts
//! (paper §3.3 last part / §6.1 future work).
//!
//! A burst shorter than the core's detection latency (~100 instructions)
//! finishes before the throttle begins, so THROTTLE flame graphs
//! attribute the cycles to *following* code. The paper proposes: program
//! the THROTTLE counter to overflow on its first cycle; in the overflow
//! interrupt, read the CPU's last-branch records and walk *backwards* to
//! find the code that actually contained the wide instructions.
//!
//! The simulation keeps a 32-entry ring of recently executed functions
//! per core (the LBR) and implements exactly that recovery.

use std::collections::VecDeque;

/// Hardware-accurate depth for Skylake LBRs.
pub const LBR_DEPTH: usize = 32;

/// One LBR entry: function id + whether the block contained wide insns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LbrEntry {
    pub func: u64,
    pub had_wide: bool,
}

/// Per-core last-branch-record ring buffer.
#[derive(Clone, Debug, Default)]
pub struct LastBranchRecord {
    ring: VecDeque<LbrEntry>,
}

impl LastBranchRecord {
    pub fn new() -> Self {
        LastBranchRecord { ring: VecDeque::with_capacity(LBR_DEPTH) }
    }

    /// Record a retired block (called per executed block).
    pub fn record(&mut self, func: u64, had_wide: bool) {
        if self.ring.len() == LBR_DEPTH {
            self.ring.pop_front();
        }
        self.ring.push_back(LbrEntry { func, had_wide });
    }

    /// The overflow-interrupt handler's view: entries newest-last.
    pub fn snapshot(&self) -> Vec<LbrEntry> {
        self.ring.iter().copied().collect()
    }

    /// Walk backwards from the interrupt to the most recent function that
    /// executed wide instructions — the true culprit, even if the
    /// throttle started after it returned.
    pub fn find_culprit(&self) -> Option<u64> {
        self.ring.iter().rev().find(|e| e.had_wide).map(|e| e.func)
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }
}

/// Offline LBR-based attribution over a trace of (func, had_wide) blocks,
/// with throttle onset delayed by `detect_blocks` — demonstrates that
/// naive attribution misses short bursts and LBR recovery does not.
pub fn attribute_trace(
    trace: &[(u64, bool)],
    detect_blocks: usize,
) -> Vec<(usize, Option<u64>, u64)> {
    let mut lbr = LastBranchRecord::new();
    let mut out = Vec::new();
    for (i, &(func, wide)) in trace.iter().enumerate() {
        lbr.record(func, wide);
        if wide {
            // The throttle interrupt fires `detect_blocks` later; at that
            // point the naive sample lands on whatever runs then.
            let fire_at = (i + detect_blocks).min(trace.len() - 1);
            let naive = trace[fire_at].0;
            // LBR state at fire time: replay forward.
            let mut fire_lbr = lbr.clone();
            for &(f2, w2) in trace.iter().take(fire_at + 1).skip(i + 1) {
                fire_lbr.record(f2, w2);
            }
            out.push((i, fire_lbr.find_culprit(), naive));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bounded_at_depth() {
        let mut lbr = LastBranchRecord::new();
        for i in 0..100 {
            lbr.record(i, false);
        }
        assert_eq!(lbr.len(), LBR_DEPTH);
        assert_eq!(lbr.snapshot().last().unwrap().func, 99);
        assert_eq!(lbr.snapshot()[0].func, 100 - LBR_DEPTH as u64);
    }

    #[test]
    fn culprit_is_most_recent_wide() {
        let mut lbr = LastBranchRecord::new();
        lbr.record(1, false);
        lbr.record(2, true);
        lbr.record(3, false);
        lbr.record(4, true);
        lbr.record(5, false);
        assert_eq!(lbr.find_culprit(), Some(4));
    }

    #[test]
    fn no_wide_no_culprit() {
        let mut lbr = LastBranchRecord::new();
        lbr.record(1, false);
        assert_eq!(lbr.find_culprit(), None);
    }

    #[test]
    fn short_burst_naive_attribution_wrong_lbr_right() {
        // func 7 is a short AVX burst followed by scalar functions 8,9,10…
        let mut trace: Vec<(u64, bool)> = vec![(1, false), (2, false), (7, true)];
        for f in 8..20 {
            trace.push((f, false));
        }
        let attributions = attribute_trace(&trace, 5);
        assert_eq!(attributions.len(), 1);
        let (_, lbr_culprit, naive) = attributions[0];
        assert_eq!(lbr_culprit, Some(7), "LBR walk must find the burst");
        assert_ne!(naive, 7, "naive sampling lands on later scalar code");
    }

    #[test]
    fn burst_older_than_depth_is_lost() {
        // If >32 blocks pass before the interrupt, even LBR can't see it —
        // matching real hardware limits.
        let mut trace: Vec<(u64, bool)> = vec![(7, true)];
        for f in 100..160 {
            trace.push((f, false));
        }
        let att = attribute_trace(&trace, 50);
        assert_eq!(att[0].1, None);
    }
}
