//! Static AVX-usage analysis (paper §3.3, first stage).
//!
//! "A static analysis tool disassembles the target application as well as
//! all its dynamically linked libraries and analyzes the usage of wide
//! vector registers. For every function, the program calculates the ratio
//! between the number of the instructions accessing 256-bit and 512-bit
//! registers and the total instruction count. […] the program prints a
//! list of functions sorted by this AVX instruction ratio."

use crate::isa::block::{InsnClass, ALL_CLASSES};
use crate::isa::Binary;
use crate::util::table::Table;

/// One row of the analysis report.
#[derive(Clone, Debug)]
pub struct FunctionReport {
    pub binary: String,
    pub function: String,
    pub total_insns: u64,
    pub wide_insns: u64,
    pub avx_ratio: f64,
    /// Dominant wide class (diagnostic: which license it would demand).
    pub dominant_wide: Option<InsnClass>,
}

/// Analyze a set of binaries; returns rows sorted by descending AVX ratio.
pub fn analyze(binaries: &[Binary]) -> Vec<FunctionReport> {
    let mut rows = Vec::new();
    for bin in binaries {
        for (_, f) in bin.iter() {
            let mix = f.static_mix();
            let dominant = ALL_CLASSES
                .iter()
                .filter(|c| c.is_wide() && mix.get(**c) > 0)
                .max_by_key(|c| mix.get(**c))
                .copied();
            rows.push(FunctionReport {
                binary: bin.name.clone(),
                function: f.name.clone(),
                total_insns: mix.total(),
                wide_insns: mix.wide(),
                avx_ratio: mix.wide_ratio(),
                dominant_wide: dominant,
            });
        }
    }
    rows.sort_by(|a, b| {
        b.avx_ratio
            .partial_cmp(&a.avx_ratio)
            .unwrap()
            .then_with(|| b.wide_insns.cmp(&a.wide_insns))
            .then_with(|| a.function.cmp(&b.function))
    });
    rows
}

/// Candidates worth annotating: high ratio *and* non-trivial size. The
/// paper's memcpy caveat: frequently-called functions with sparse wide
/// moves "should not cause the thread to migrate" — the ratio threshold
/// keeps them listed (for the developer to inspect) but the report marks
/// the likely-dense ones.
pub fn candidates(rows: &[FunctionReport], min_ratio: f64) -> Vec<&FunctionReport> {
    rows.iter().filter(|r| r.avx_ratio >= min_ratio).collect()
}

/// Render the report as a table (what the CLI prints).
pub fn report_table(rows: &[FunctionReport]) -> Table {
    let mut t = Table::new(
        "Static analysis: functions by AVX instruction ratio (§3.3)",
        &["binary", "function", "insns", "wide", "ratio", "dominant class"],
    );
    for r in rows {
        t.row(&[
            r.binary.clone(),
            r.function.clone(),
            r.total_insns.to_string(),
            r.wide_insns.to_string(),
            format!("{:.2}", r.avx_ratio),
            r.dominant_wide.map(|c| c.name().to_string()).unwrap_or_else(|| "-".into()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::crypto::Isa;
    use crate::workload::webserver::build_binaries;

    #[test]
    fn crypto_functions_rank_first() {
        let bins = build_binaries(Isa::Avx512);
        let rows = analyze(&bins);
        assert!(!rows.is_empty());
        // The top entries must be the OpenSSL vector kernels.
        let top: Vec<&str> = rows.iter().take(3).map(|r| r.function.as_str()).collect();
        assert!(
            top.iter().any(|f| f.contains("ChaCha20")),
            "ChaCha20 must rank near the top: {top:?}"
        );
        assert!(
            top.iter().any(|f| f.contains("poly1305")),
            "poly1305 must rank near the top: {top:?}"
        );
    }

    #[test]
    fn memcpy_ranks_below_crypto() {
        let bins = build_binaries(Isa::Avx512);
        let rows = analyze(&bins);
        let pos = |name: &str| rows.iter().position(|r| r.function.contains(name)).unwrap();
        assert!(
            pos("ChaCha20") < pos("__memmove_avx_unaligned"),
            "dense crypto must outrank sparse memcpy"
        );
    }

    #[test]
    fn scalar_functions_ratio_zero() {
        let bins = build_binaries(Isa::Sse4);
        let rows = analyze(&bins);
        let nginx_rows: Vec<_> = rows.iter().filter(|r| r.binary == "nginx").collect();
        assert!(nginx_rows.iter().all(|r| r.avx_ratio == 0.0));
    }

    #[test]
    fn candidate_threshold_filters() {
        let bins = build_binaries(Isa::Avx512);
        let rows = analyze(&bins);
        let cands = candidates(&rows, 0.5);
        assert!(!cands.is_empty());
        assert!(cands.iter().all(|r| r.avx_ratio >= 0.5));
        assert!(cands.iter().any(|r| r.function.contains("ChaCha20")));
        assert!(!cands.iter().any(|r| r.function == "malloc"));
    }

    #[test]
    fn sse4_build_has_no_crypto_candidates() {
        let bins = build_binaries(Isa::Sse4);
        let rows = analyze(&bins);
        let cands = candidates(&rows, 0.3);
        // Only the glibc AVX memcpy/memset remain.
        assert!(cands.iter().all(|r| r.binary == "libc.so.6"), "{cands:?}");
    }
}
