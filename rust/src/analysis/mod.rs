//! The paper's §3.3 identification workflow:
//!
//! 1. [`static_analysis`] — "disassemble" binaries and rank functions by
//!    the ratio of 256/512-bit register accesses to total instructions.
//! 2. [`flamegraph`] — visualize where in the call tree the
//!    `CORE_POWER.THROTTLE` counter fires (frequency-change triggers).
//! 3. [`lbr`] — last-branch-record inspection to catch AVX bursts too
//!    short for the counter-based workflow (§3.3 end / §6.1).

pub mod static_analysis;
pub mod flamegraph;
pub mod lbr;
