//! THROTTLE-cycle flame graphs (paper §3.3, second stage).
//!
//! "the user therefore generates a flame graph from this counter. […]
//! Visualizing THROTTLE cycles instead of all CPU cycles shows
//! approximately where in the call tree frequency changes are triggered."
//!
//! The machine records `(stack, cycles, throttle_cycles)` per executed
//! block; this module interns stacks, folds samples Brendan-Gregg-style
//! (`frame;frame;frame count`), and renders a minimal self-contained SVG.

use crate::sched::machine::StackSample;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Interned call stacks. Id 0 is reserved for the empty/unknown stack.
#[derive(Debug, Default)]
pub struct StackTable {
    stacks: Vec<Vec<String>>,
    by_key: BTreeMap<String, u32>,
}

impl StackTable {
    pub fn new() -> Self {
        let mut t = StackTable::default();
        t.stacks.push(vec!["<unknown>".to_string()]);
        t.by_key.insert("<unknown>".to_string(), 0);
        t
    }

    /// Intern a stack (outermost frame first). Returns its id.
    pub fn intern(&mut self, frames: &[&str]) -> u32 {
        let key = frames.join(";");
        if let Some(&id) = self.by_key.get(&key) {
            return id;
        }
        let id = self.stacks.len() as u32;
        self.stacks.push(frames.iter().map(|s| s.to_string()).collect());
        self.by_key.insert(key, id);
        id
    }

    pub fn frames(&self, id: u32) -> &[String] {
        &self.stacks[id as usize]
    }

    pub fn len(&self) -> usize {
        self.stacks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stacks.is_empty()
    }
}

/// Which counter to fold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    Cycles,
    Throttle,
}

/// Fold machine samples into `frame;frame;… value` lines (descending).
pub fn fold(
    samples: &BTreeMap<u32, StackSample>,
    stacks: &StackTable,
    counter: Counter,
) -> Vec<(String, u64)> {
    let mut agg: BTreeMap<String, u64> = BTreeMap::new();
    for (&stack, s) in samples {
        let v = match counter {
            Counter::Cycles => s.cycles,
            Counter::Throttle => s.throttle_cycles,
        }
        .round() as u64;
        if v == 0 {
            continue;
        }
        let key = stacks.frames(stack).join(";");
        *agg.entry(key).or_default() += v;
    }
    let mut rows: Vec<(String, u64)> = agg.into_iter().collect();
    rows.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    rows
}

/// Render folded stacks as standard folded-format text.
pub fn folded_text(rows: &[(String, u64)]) -> String {
    let mut out = String::new();
    for (stack, v) in rows {
        let _ = writeln!(out, "{stack} {v}");
    }
    out
}

// ---- minimal SVG flame graph -------------------------------------------

#[derive(Debug, Default)]
struct Node {
    children: BTreeMap<String, Node>,
    value: u64,
    total: u64,
}

impl Node {
    fn insert(&mut self, frames: &[&str], value: u64) {
        self.total += value;
        match frames.split_first() {
            None => self.value += value,
            Some((first, rest)) => {
                self.children.entry(first.to_string()).or_default().insert(rest, value)
            }
        }
    }
}

fn color(name: &str) -> String {
    // Deterministic warm palette from the name hash.
    let mut h = 2166136261u32;
    for b in name.bytes() {
        h = (h ^ b as u32).wrapping_mul(16777619);
    }
    let r = 205 + (h % 50) as u32;
    let g = 60 + ((h >> 8) % 120) as u32;
    let b = (h >> 16) % 50;
    format!("rgb({r},{g},{b})")
}

fn render_node(
    out: &mut String,
    name: &str,
    node: &Node,
    x: f64,
    width: f64,
    depth: usize,
    height_px: f64,
) {
    if width < 0.5 {
        return;
    }
    let y = height_px - (depth as f64 + 1.0) * 18.0;
    let _ = writeln!(
        out,
        r#"<rect x="{:.1}" y="{:.1}" width="{:.1}" height="17" fill="{}" rx="1"><title>{} ({} cycles)</title></rect>"#,
        x,
        y,
        width,
        color(name),
        name,
        node.total
    );
    if width > 60.0 {
        let label: String = name.chars().take((width / 7.0) as usize).collect();
        let _ = writeln!(
            out,
            r#"<text x="{:.1}" y="{:.1}" font-size="11" font-family="monospace">{}</text>"#,
            x + 2.0,
            y + 12.5,
            label
        );
    }
    let mut cx = x;
    for (child_name, child) in &node.children {
        let w = width * child.total as f64 / node.total.max(1) as f64;
        render_node(out, child_name, child, cx, w, depth + 1, height_px);
        cx += w;
    }
}

/// Render folded rows to a self-contained SVG flame graph.
pub fn render_svg(rows: &[(String, u64)], title: &str) -> String {
    let mut root = Node::default();
    let mut max_depth = 1usize;
    for (stack, v) in rows {
        let frames: Vec<&str> = stack.split(';').collect();
        max_depth = max_depth.max(frames.len());
        root.insert(&frames, *v);
    }
    let width = 1200.0;
    let height = (max_depth as f64 + 2.0) * 18.0 + 30.0;
    let mut out = String::new();
    let _ = writeln!(
        out,
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{width}" height="{height}" viewBox="0 0 {width} {height}">"#
    );
    let _ = writeln!(
        out,
        r#"<text x="8" y="18" font-size="14" font-family="sans-serif">{title}</text>"#
    );
    let mut cx = 0.0;
    for (name, child) in &root.children {
        let w = width * child.total as f64 / root.total.max(1) as f64;
        render_node(&mut out, name, child, cx, w, 0, height - 8.0);
        cx += w;
    }
    let _ = writeln!(out, "</svg>");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> (BTreeMap<u32, StackSample>, StackTable) {
        let mut t = StackTable::new();
        let a = t.intern(&["nginx", "SSL_write", "ChaCha20_ctr32_avx512"]);
        let b = t.intern(&["nginx", "SSL_write", "poly1305_blocks_avx512"]);
        let c = t.intern(&["nginx", "BrotliEncoderCompressStream"]);
        let mut m = BTreeMap::new();
        m.insert(a, StackSample { cycles: 1000.0, throttle_cycles: 300.0 });
        m.insert(b, StackSample { cycles: 500.0, throttle_cycles: 450.0 });
        m.insert(c, StackSample { cycles: 9000.0, throttle_cycles: 0.0 });
        (m, t)
    }

    #[test]
    fn intern_dedups() {
        let mut t = StackTable::new();
        let a = t.intern(&["x", "y"]);
        let b = t.intern(&["x", "y"]);
        let c = t.intern(&["x", "z"]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(t.frames(a), &["x".to_string(), "y".to_string()]);
    }

    #[test]
    fn throttle_fold_isolates_crypto() {
        let (m, t) = sample_data();
        let rows = fold(&m, &t, Counter::Throttle);
        // Brotli has zero throttle cycles → absent; poly tops the list.
        assert_eq!(rows.len(), 2);
        assert!(rows[0].0.contains("poly1305"));
        assert!(!rows.iter().any(|(s, _)| s.contains("Brotli")));
    }

    #[test]
    fn cycles_fold_dominated_by_brotli() {
        let (m, t) = sample_data();
        let rows = fold(&m, &t, Counter::Cycles);
        assert!(rows[0].0.contains("Brotli"), "plain-cycles graph is the wrong tool: {rows:?}");
    }

    #[test]
    fn folded_text_format() {
        let (m, t) = sample_data();
        let txt = folded_text(&fold(&m, &t, Counter::Throttle));
        assert!(txt.contains("nginx;SSL_write;poly1305_blocks_avx512 450"));
    }

    #[test]
    fn svg_renders_and_contains_frames() {
        let (m, t) = sample_data();
        let rows = fold(&m, &t, Counter::Cycles);
        let svg = render_svg(&rows, "test graph");
        assert!(svg.starts_with("<svg"));
        assert!(svg.contains("Brotli"));
        assert!(svg.ends_with("</svg>\n"));
    }
}
