//! Benchmark harness behind `avxfreq bench`: times the canonical
//! scenarios with the hot paths on (the default) and off (the
//! baseline), reports **simulated nanoseconds per wall-clock second**,
//! and writes the machine-readable `BENCH_<pr>.json` that starts the
//! repo's performance trajectory.
//!
//! The harness doubles as an equivalence gate: for every scenario it
//! fingerprints both legs' outputs (completions, drops, exact SLO
//! violations, and the bit patterns of the float aggregates) and
//! reports `outputs_identical`. A mismatch is a correctness bug in the
//! fast paths — `avxfreq bench` exits non-zero on it, and `ci.sh` runs
//! a `--quick` pass so the gate is exercised on every CI run.
//!
//! Wall-clock numbers are load-sensitive; the *ratio* between the two
//! legs of the same invocation is the meaningful figure (both legs run
//! in the same process, same thread budget, back to back). See
//! `rust/tests/README.md` for bench triage.
//!
//! The unit of merit: one simulated machine running 1.2 s of warmup +
//! measurement contributes 1.2e9 simulated ns; a matrix cell or fleet
//! machine each count separately. `sim_ns_per_wall_s = Σ machine
//! sim-time / wall seconds`, so the number is comparable across
//! scenario shapes and thread counts.

use crate::faults::FaultsCfg;
use crate::fleet::{run_fleet, run_hier_fleet, BalancerCfg, HierFleetCfg, RouterSpec};
use crate::scenario::{ArrivalSpec, ScenarioMatrix};
use crate::sched::PolicyKind;
use crate::sim::{Time, MS};
use crate::tpc::{PlacementSpec, TpcParams};
use crate::workload::client::LoadMode;
use crate::workload::crypto::Isa;
use crate::workload::webserver::{run_webserver, WebCfg, WebRun};
use std::fmt::Write as _;
use std::time::Instant;

/// Which PR's trajectory file this harness writes.
pub const BENCH_PR: u32 = 10;

/// Harness configuration (CLI surface of `avxfreq bench`).
#[derive(Clone, Debug)]
pub struct BenchCfg {
    /// Shrink the simulated windows (CI smoke; ratios stay meaningful).
    pub quick: bool,
    pub seed: u64,
    /// OS threads for the matrix/fleet legs (same for both legs).
    pub threads: usize,
    /// Scenario names to run (`single`, `matrix`, `fleet`, `hier`,
    /// `executor`, `incremental`, `chaos`).
    pub scenarios: Vec<String>,
}

impl BenchCfg {
    pub fn new(quick: bool, seed: u64, threads: usize) -> Self {
        BenchCfg {
            quick,
            seed,
            threads: threads.max(1),
            scenarios: ["single", "matrix", "fleet", "hier", "executor", "incremental", "chaos"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        }
    }
}

/// One timed leg (fast paths on or off).
#[derive(Clone, Copy, Debug)]
pub struct Leg {
    pub wall_s: f64,
    /// Total simulated machine-time covered (Σ per-machine warmup+measure).
    pub sim_ns: u64,
}

impl Leg {
    pub fn sim_ns_per_wall_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.sim_ns as f64 / self.wall_s
        }
    }
}

/// Result of one scenario: both legs plus the equivalence verdict.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub scenario: String,
    pub fast: Leg,
    pub baseline: Leg,
    pub outputs_identical: bool,
    /// Simulated warmup nanoseconds the fast leg skipped by forking
    /// warmed checkpoints (matrix-family scenarios; 0 elsewhere). A
    /// deterministic work-avoidance measure — a pure function of the
    /// scenario declaration, never of wall clock — so the trajectory
    /// file records it even where cargo (and thus timing) is absent.
    pub warmup_ns_reused: u64,
}

impl BenchRow {
    /// fast ÷ baseline throughput (simulated-ns-per-wall-second ratio).
    pub fn speedup(&self) -> f64 {
        let b = self.baseline.sim_ns_per_wall_s();
        if b <= 0.0 {
            0.0
        } else {
            self.fast.sim_ns_per_wall_s() / b
        }
    }
}

/// Everything a run can observably produce, floats by bit pattern —
/// equal fingerprints mean the legs are indistinguishable to every
/// report renderer.
fn fingerprint(run: &WebRun, out: &mut Vec<u64>) {
    out.push(run.completed);
    out.push(run.dropped);
    out.push(run.stats.violations());
    out.push(run.throughput_rps.to_bits());
    out.push(run.avg_ghz.to_bits());
    out.push(run.ipc.to_bits());
    out.push(run.insns_per_req.to_bits());
    out.push(run.active_energy_j.to_bits());
    out.push(run.idle_energy_j.to_bits());
    out.push(run.tail.p50_us.to_bits());
    out.push(run.tail.p99_us.to_bits());
    out.push(run.tail.p999_us.to_bits());
    out.push(run.tail.max_us.to_bits());
    out.push(run.tail.slo_violation_frac.to_bits());
    out.push(run.runtime_steered);
    out.push(run.runtime_migrations);
    out.push(run.runtime_migrations_per_sec.to_bits());
    out.push(run.runtime_preemptions);
    for (_, t) in &run.tenant_tails {
        out.push(t.completed);
        out.push(t.p99_us.to_bits());
    }
}

/// The paper's single-machine scenario (`WebCfg::paper_default`),
/// shrunk under `--quick`.
fn single_cfg(quick: bool, seed: u64, fast: bool) -> WebCfg {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 2 });
    cfg.seed = seed;
    cfg.fast_paths = fast;
    if quick {
        cfg.warmup = 150 * MS;
        cfg.measure = 300 * MS;
    }
    cfg
}

fn run_single(quick: bool, seed: u64, fast: bool) -> (Leg, Vec<u64>, u64) {
    let cfg = single_cfg(quick, seed, fast);
    let sim_ns: Time = cfg.warmup + cfg.measure;
    let t0 = Instant::now();
    let run = run_webserver(&cfg);
    let wall_s = t0.elapsed().as_secs_f64();
    let mut fp = Vec::new();
    fingerprint(&run, &mut fp);
    (Leg { wall_s, sim_ns }, fp, 0)
}

fn run_matrix(quick: bool, seed: u64, threads: usize, fast: bool) -> (Leg, Vec<u64>, u64) {
    let mut m = ScenarioMatrix::default_sweep(quick, seed);
    m.fast_paths = fast;
    // Per the unit of merit: each simulated machine counts, so a fleet
    // cell contributes `fleet ×` its window (the default sweep has no
    // fleet axis today, but the accounting must not silently undercount
    // if it grows one).
    let sim_ns: Time =
        m.cells().iter().map(|c| (m.warmup + m.measure) * c.fleet as Time).sum();
    let t0 = Instant::now();
    let result = m.run(threads);
    let wall_s = t0.elapsed().as_secs_f64();
    let mut fp = Vec::new();
    for c in &result.cells {
        fingerprint(&c.run, &mut fp);
    }
    // The rendered tables are pure functions of the cells, but pin the
    // bytes too: this is the same render the golden suite snapshots.
    for b in result.render().bytes() {
        fp.push(b as u64);
    }
    (Leg { wall_s, sim_ns }, fp, result.warmup_ns_reused)
}

/// The incremental sweep run twice — checkpoint forking on (fast leg)
/// vs off (baseline leg) — so the fork path's byte-equivalence against
/// the cold reference sits inside the bench equivalence gate, and the
/// speedup column prices what warmup reuse buys. Both legs keep the hot
/// paths on; `fast` selects the *incremental* flag for this scenario.
fn run_incremental(quick: bool, seed: u64, threads: usize, fast: bool) -> (Leg, Vec<u64>, u64) {
    let mut m = ScenarioMatrix::incremental_sweep(quick, seed);
    m.incremental = fast;
    // Nominal coverage is identical for both legs: the fast leg
    // delivers the same cells while simulating less (the reused warmup
    // prefixes) — exactly the saving the speedup column should price.
    let sim_ns: Time = m
        .cells()
        .iter()
        .map(|c| (c.cfg.warmup + c.cfg.measure) * c.fleet as Time)
        .sum();
    let t0 = Instant::now();
    let result = m.run(threads);
    let wall_s = t0.elapsed().as_secs_f64();
    let mut fp = Vec::new();
    for c in &result.cells {
        fingerprint(&c.run, &mut fp);
    }
    for b in result.render().bytes() {
        fp.push(b as u64);
    }
    (Leg { wall_s, sim_ns }, fp, result.warmup_ns_reused)
}

/// The same single-machine web workload served through the
/// thread-per-core runtime (`LoadMode::Executor`) with the avx-steer
/// placement, so the runtime steering/wake paths sit on the timed path
/// of both legs and inside the equivalence gate.
fn executor_cfg(quick: bool, seed: u64, fast: bool) -> WebCfg {
    let mut cfg = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
    cfg.seed = seed;
    cfg.fast_paths = fast;
    cfg.cores = 4;
    cfg.workers = 4;
    cfg.annotate = true;
    cfg.page_bytes = 16 * 1024;
    if quick {
        cfg.warmup = 150 * MS;
        cfg.measure = 300 * MS;
    }
    let rate = 6_000.0 * cfg.cores as f64;
    cfg.mode = LoadMode::Executor {
        process: ArrivalSpec::bursty_mix_default().instantiate(rate),
        tpc: TpcParams {
            placement: PlacementSpec::AvxSteer { avx_cores: 2 },
            ..TpcParams::default()
        },
    };
    cfg
}

fn run_executor(quick: bool, seed: u64, fast: bool) -> (Leg, Vec<u64>, u64) {
    let cfg = executor_cfg(quick, seed, fast);
    let sim_ns: Time = cfg.warmup + cfg.measure;
    let t0 = Instant::now();
    let run = run_webserver(&cfg);
    let wall_s = t0.elapsed().as_secs_f64();
    let mut fp = Vec::new();
    fingerprint(&run, &mut fp);
    (Leg { wall_s, sim_ns }, fp, 0)
}

fn run_fleet_scenario(
    quick: bool,
    seed: u64,
    threads: usize,
    fast: bool,
) -> (Leg, Vec<u64>, u64) {
    let mut fleet = crate::repro::fleetvar::fleet_cfg(RouterSpec::RoundRobin, quick, seed);
    fleet.cfg.fast_paths = fast;
    let sim_ns = (fleet.cfg.warmup + fleet.cfg.measure) * fleet.machines as Time;
    let t0 = Instant::now();
    let run = run_fleet(&fleet, threads);
    let wall_s = t0.elapsed().as_secs_f64();
    let mut fp = Vec::new();
    fingerprint(&run.cluster_run(), &mut fp);
    for m in &run.machines {
        fingerprint(m, &mut fp);
    }
    (Leg { wall_s, sim_ns }, fp, 0)
}

/// The closed-loop hierarchical fleet (epoch feedback: retries, hedges,
/// health ejection) over the fleetvar scenario, racks of 3 — the
/// streaming machine→rack→cluster aggregation and the balancer
/// bookkeeping sit on the timed path of both legs and inside the
/// equivalence gate (front-end outcome counters, per-machine digests,
/// and the rendered hierarchy table are all fingerprinted).
fn run_hier_scenario(
    quick: bool,
    seed: u64,
    threads: usize,
    fast: bool,
) -> (Leg, Vec<u64>, u64) {
    let mut fleet = crate::repro::fleetvar::fleet_cfg(RouterSpec::RoundRobin, quick, seed);
    fleet.cfg.fast_paths = fast;
    let mut cfg = HierFleetCfg::new(fleet, BalancerCfg::closed());
    cfg.machines_per_rack = 3;
    let sim_ns = (cfg.fleet.cfg.warmup + cfg.fleet.cfg.measure) * cfg.fleet.machines as Time;
    let t0 = Instant::now();
    let run = run_hier_fleet(&cfg, threads);
    let wall_s = t0.elapsed().as_secs_f64();
    let mut fp = Vec::new();
    fingerprint(&run.cluster_run("bench"), &mut fp);
    let o = &run.outcomes;
    fp.extend([
        o.timeouts_observed,
        o.retries_issued,
        o.retries_abandoned,
        o.hedges_issued,
        o.ejections,
        o.readmissions,
    ]);
    for d in &run.digests {
        fp.extend([d.arrivals, d.completed, d.timeouts, d.epochs_ejected]);
    }
    for b in crate::metrics::hier_report(&[("hier", &run)]).render().bytes() {
        fp.push(b as u64);
    }
    (Leg { wall_s, sim_ns }, fp, 0)
}

/// The faults-off ≡ pre-PR differential priced and gated by the bench
/// harness: both legs run the closed-loop hier scenario with the hot
/// paths on, but the "fast" leg carries the full chaos schedule with
/// the `[faults]` master switch off while the baseline carries the
/// default (empty) fault config — the literal pre-fault-layer
/// configuration. `outputs_identical` then asserts that a populated but
/// disabled schedule perturbs nothing: every fault branch must gate out
/// on `FaultsCfg::active()`, not on the schedule being empty. The
/// speedup column is ≈ 1 by construction; the gate is the point.
fn run_chaos_scenario(
    quick: bool,
    seed: u64,
    threads: usize,
    fast: bool,
) -> (Leg, Vec<u64>, u64) {
    let fleet = crate::repro::fleetvar::fleet_cfg(RouterSpec::RoundRobin, quick, seed);
    let mut cfg = HierFleetCfg::new(fleet, BalancerCfg::closed());
    cfg.machines_per_rack = 3;
    if fast {
        cfg.faults = FaultsCfg::chaos(cfg.fleet.cfg.measure, cfg.fleet.machines.max(1));
        cfg.faults.enabled = false;
    }
    let sim_ns = (cfg.fleet.cfg.warmup + cfg.fleet.cfg.measure) * cfg.fleet.machines as Time;
    let t0 = Instant::now();
    let run = run_hier_fleet(&cfg, threads);
    let wall_s = t0.elapsed().as_secs_f64();
    let mut fp = Vec::new();
    fingerprint(&run.cluster_run("bench"), &mut fp);
    let o = &run.outcomes;
    fp.extend([
        o.timeouts_observed,
        o.retries_issued,
        o.retries_abandoned,
        o.hedges_issued,
        o.ejections,
        o.readmissions,
    ]);
    let f = &run.fault_outcomes;
    fp.extend([
        f.lost_to_crash,
        f.dropped_by_net,
        f.fault_retries,
        f.crash_windows,
        f.degrade_windows,
        f.recovery_epochs,
        run.fault_windows.len() as u64,
    ]);
    for b in crate::metrics::hier_report(&[("chaos", &run)]).render().bytes() {
        fp.push(b as u64);
    }
    for b in crate::metrics::fault_report(&run.fault_windows, &run.fault_outcomes)
        .render()
        .bytes()
    {
        fp.push(b as u64);
    }
    (Leg { wall_s, sim_ns }, fp, 0)
}

/// Run the configured scenarios, fast leg then baseline leg each.
/// Every scenario name is resolved *before* the first leg is timed, so
/// a typo fails immediately instead of after minutes of completed legs
/// whose results would be lost.
pub fn run(cfg: &BenchCfg) -> anyhow::Result<Vec<BenchRow>> {
    type Runner = fn(bool, u64, usize, bool) -> (Leg, Vec<u64>, u64);
    let mut plan: Vec<(&str, Runner)> = Vec::new();
    for name in &cfg.scenarios {
        let runner: Runner = match name.as_str() {
            "single" => |q, s, _t, f| run_single(q, s, f),
            "matrix" => run_matrix,
            "fleet" => run_fleet_scenario,
            "hier" => run_hier_scenario,
            "executor" => |q, s, _t, f| run_executor(q, s, f),
            "incremental" => run_incremental,
            "chaos" => run_chaos_scenario,
            other => {
                anyhow::bail!(
                    "unknown bench scenario {other:?} \
                     (single|matrix|fleet|hier|executor|incremental|chaos)"
                )
            }
        };
        plan.push((name, runner));
    }
    let mut rows = Vec::new();
    for (name, runner) in plan {
        eprintln!("[avxfreq] bench: {name} (fast paths on)…");
        let (fast, fp_fast, warmup_ns_reused) = runner(cfg.quick, cfg.seed, cfg.threads, true);
        eprintln!("[avxfreq] bench: {name} (baseline, fast paths off)…");
        let (baseline, fp_base, _) = runner(cfg.quick, cfg.seed, cfg.threads, false);
        rows.push(BenchRow {
            scenario: name.to_string(),
            fast,
            baseline,
            outputs_identical: fp_fast == fp_base,
            warmup_ns_reused,
        });
    }
    Ok(rows)
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

/// Serialize rows as the `BENCH_<pr>.json` trajectory record. The
/// headline is the canonical matrix scenario (both raw numbers
/// recorded); hand-rolled JSON because the offline build vendors no
/// serde.
pub fn to_json(cfg: &BenchCfg, rows: &[BenchRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{{");
    let _ = writeln!(s, "  \"pr\": {BENCH_PR},");
    let _ = writeln!(s, "  \"unit\": \"simulated_ns_per_wall_second\",");
    let _ = writeln!(s, "  \"quick\": {},", cfg.quick);
    let _ = writeln!(s, "  \"threads\": {},", cfg.threads);
    let _ = writeln!(s, "  \"seed\": {},", cfg.seed);
    if let Some(m) = rows.iter().find(|r| r.scenario == "matrix") {
        let _ = writeln!(s, "  \"headline\": {{");
        let _ = writeln!(s, "    \"scenario\": \"matrix\",");
        let _ = writeln!(
            s,
            "    \"fast_sim_ns_per_wall_s\": {},",
            json_f64(m.fast.sim_ns_per_wall_s())
        );
        let _ = writeln!(
            s,
            "    \"baseline_sim_ns_per_wall_s\": {},",
            json_f64(m.baseline.sim_ns_per_wall_s())
        );
        let _ = writeln!(s, "    \"speedup\": {}", json_f64(m.speedup()));
        let _ = writeln!(s, "  }},");
    }
    let _ = writeln!(s, "  \"scenarios\": [");
    for (i, r) in rows.iter().enumerate() {
        let _ = writeln!(s, "    {{");
        let _ = writeln!(s, "      \"name\": \"{}\",", r.scenario);
        let _ = writeln!(s, "      \"sim_ns\": {},", r.fast.sim_ns);
        let _ = writeln!(s, "      \"fast\": {{ \"wall_s\": {}, \"sim_ns_per_wall_s\": {} }},",
            json_f64(r.fast.wall_s), json_f64(r.fast.sim_ns_per_wall_s()));
        let _ = writeln!(
            s,
            "      \"baseline\": {{ \"wall_s\": {}, \"sim_ns_per_wall_s\": {} }},",
            json_f64(r.baseline.wall_s),
            json_f64(r.baseline.sim_ns_per_wall_s())
        );
        let _ = writeln!(s, "      \"speedup\": {},", json_f64(r.speedup()));
        let _ = writeln!(s, "      \"warmup_ns_reused\": {},", r.warmup_ns_reused);
        let _ = writeln!(s, "      \"outputs_identical\": {}", r.outputs_identical);
        let _ = writeln!(s, "    }}{}", if i + 1 < rows.len() { "," } else { "" });
    }
    let _ = writeln!(s, "  ]");
    let _ = writeln!(s, "}}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_scenario_legs_are_equivalent_and_timed() {
        // Micro-sized single-machine bench: both legs must fingerprint
        // identically (the crown constraint) and report plausible legs.
        let leg = |fast: bool| {
            let mut cfg = single_cfg(true, 7, fast);
            cfg.cores = 4;
            cfg.workers = 8;
            cfg.page_bytes = 8 * 1024;
            cfg.warmup = 50 * MS;
            cfg.measure = 100 * MS;
            cfg.mode = crate::workload::client::LoadMode::Open { rate: 20_000.0 };
            let sim_ns = cfg.warmup + cfg.measure;
            let t0 = Instant::now();
            let run = run_webserver(&cfg);
            let mut fp = Vec::new();
            fingerprint(&run, &mut fp);
            (Leg { wall_s: t0.elapsed().as_secs_f64(), sim_ns }, fp)
        };
        let (fast, fp_fast) = leg(true);
        let (base, fp_base) = leg(false);
        assert_eq!(fp_fast, fp_base, "fast and baseline legs must be output-identical");
        assert!(fast.sim_ns_per_wall_s() > 0.0);
        assert!(base.sim_ns_per_wall_s() > 0.0);
    }

    #[test]
    fn json_shape_carries_both_headline_numbers() {
        let cfg = BenchCfg::new(true, 1, 2);
        let rows = vec![
            BenchRow {
                scenario: "matrix".into(),
                fast: Leg { wall_s: 1.0, sim_ns: 9_600_000_000 },
                baseline: Leg { wall_s: 4.0, sim_ns: 9_600_000_000 },
                outputs_identical: true,
                warmup_ns_reused: 1_200_000_000,
            },
        ];
        let j = to_json(&cfg, &rows);
        assert!(j.contains("\"pr\": 10"), "{j}");
        assert!(j.contains("\"fast_sim_ns_per_wall_s\": 9600000000.000000"), "{j}");
        assert!(j.contains("\"baseline_sim_ns_per_wall_s\": 2400000000.000000"), "{j}");
        assert!(j.contains("\"speedup\": 4.000000"), "{j}");
        assert!(j.contains("\"warmup_ns_reused\": 1200000000"), "{j}");
        assert!(j.contains("\"outputs_identical\": true"), "{j}");
        let rows2 = vec![BenchRow {
            scenario: "single".into(),
            fast: Leg { wall_s: 0.0, sim_ns: 1 },
            baseline: Leg { wall_s: 0.0, sim_ns: 1 },
            outputs_identical: false,
            warmup_ns_reused: 0,
        }];
        let j2 = to_json(&cfg, &rows2);
        assert!(!j2.contains("headline"), "no matrix row → no headline block");
        assert!(j2.contains("\"outputs_identical\": false"));
    }

    #[test]
    fn speedup_math() {
        let r = BenchRow {
            scenario: "x".into(),
            fast: Leg { wall_s: 1.0, sim_ns: 300 },
            baseline: Leg { wall_s: 3.0, sim_ns: 300 },
            outputs_identical: true,
            warmup_ns_reused: 0,
        };
        assert!((r.speedup() - 3.0).abs() < 1e-12);
        let z = BenchRow {
            scenario: "x".into(),
            fast: Leg { wall_s: 0.0, sim_ns: 0 },
            baseline: Leg { wall_s: 0.0, sim_ns: 0 },
            outputs_identical: true,
            warmup_ns_reused: 0,
        };
        assert_eq!(z.speedup(), 0.0);
    }
}
