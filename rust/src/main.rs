//! `avxfreq` CLI — leader entrypoint for the reproduction.
//!
//! Subcommands:
//!
//! * `repro [<fig>|all] [--quick] [--seed N]` — regenerate a paper
//!   figure/table (fig1, fig2, fig3, fig5, fig6, ipc, fig7, cryptobench,
//!   ablations); writes CSVs under `results/`.
//! * `analyze [--isa <sse4|avx2|avx512>] [--min-ratio R]` — §3.3 static
//!   analysis report over the simulated nginx/OpenSSL binaries.
//! * `flamegraph [--isa ...] [--counter throttle|cycles] [--out f.svg]` —
//!   §3.3 THROTTLE flame graph from a web-server run.
//! * `sim [--isa ...] [--policy ...] [--avx-cores K] ...` — one
//!   web-server simulation with full reports.
//! * `matrix [--quick] [--seed N] [--threads T] [--full-isa]` — parallel
//!   scenario-matrix sweep: {single-socket, dual-socket NUMA} ×
//!   {unmodified, per-socket core specialization} × ISA, one unified
//!   comparison table (deterministic for a given seed regardless of T).
//! * `traffic [--quick] [--seed N] [--threads T] [--loads L1,L2,…]
//!   [--arrivals poisson,bursty,diurnal,mix] [--slo-ms X]` — the traffic
//!   engine: load level × arrival process sweep on the paper machine,
//!   reporting p50/p95/p99/p999, max, and the SLO-violation fraction
//!   (also deterministic at any thread count).
//! * `serve [--artifacts DIR] [--port P]` — real TLS-record server using
//!   the AOT PJRT ChaCha20-Poly1305 kernels (see `runtime`).
//! * `calibrate [--artifacts DIR]` — execute the AOT kernels and compare
//!   measured width-scaling against the simulator's crypto profiles.

use avxfreq::analysis::{flamegraph, static_analysis};
use avxfreq::metrics;
use avxfreq::repro;
use avxfreq::sched::PolicyKind;
use avxfreq::sim::{MS, SEC};
use avxfreq::util::args::Args;
use avxfreq::workload::crypto::Isa;
use avxfreq::workload::webserver::{build_binaries, run_webserver_machine, WebCfg};

fn parse_isa(s: &str) -> Isa {
    match s {
        "sse4" => Isa::Sse4,
        "avx2" => Isa::Avx2,
        "avx512" => Isa::Avx512,
        other => panic!("unknown --isa {other} (sse4|avx2|avx512)"),
    }
}

fn parse_policy(args: &Args) -> PolicyKind {
    let avx_cores = args.get_parse::<usize>("avx-cores", 2);
    let sockets = args.get_parse::<usize>("sockets", 1).max(1);
    match args.get_or("policy", "corespec") {
        "unmodified" => PolicyKind::Unmodified,
        "corespec" => PolicyKind::CoreSpec { avx_cores },
        "corespec-numa" => PolicyKind::CoreSpecNuma { avx_cores_per_socket: avx_cores, sockets },
        "strict" => PolicyKind::StrictPartition { avx_cores },
        // The hybrid-native policy: the P-core count doubles as the
        // specialization set size (see --hybrid, which re-derives it
        // from the machine shape when the flag is omitted).
        "class-native" => PolicyKind::ClassNative {
            p_cores: args.get_parse::<usize>("p-cores", avx_cores),
        },
        other => panic!(
            "unknown --policy {other} (unmodified|corespec|corespec-numa|strict|class-native)"
        ),
    }
}

const USAGE: &str = "\
avxfreq — reproduction of 'Mechanism to Mitigate AVX-Induced Frequency Reduction'
usage:
  avxfreq repro [<experiment>|all] [--quick] [--seed N]
  avxfreq analyze [--isa sse4|avx2|avx512] [--min-ratio R]
  avxfreq flamegraph [--isa ...] [--counter throttle|cycles] [--out file.svg]
  avxfreq sim [--config file.toml] [--isa ...] [--adaptive]
              [--policy unmodified|corespec|corespec-numa|strict|class-native]
              [--avx-cores K] [--p-cores K] [--hybrid P,E[,M]]
              [--sockets S] [--cores N] [--workers W]
              [--rate R] [--no-compress] [--fault-migrate] [--seconds S] [--seed N]
  avxfreq matrix [--quick] [--seed N] [--threads T] [--full-isa] [--hybrid]
  avxfreq incremental [--quick] [--seed N] [--threads T] [--cold]
  avxfreq traffic [--quick] [--seed N] [--threads T] [--loads 0.6,0.85,1.1]
                  [--arrivals poisson,bursty,diurnal,mix,bursty-mix] [--slo-ms 5]
  avxfreq fleet [--config configs/fleet_slo.toml] [--machines N]
                [--router round-robin|least-outstanding|avx-partition]
                [--avx-machines K] [--service-est-us X] [--rate R]
                [--quick] [--seed N] [--threads T]
                [--hier] [--rack-size M] [--collective STEPS]
                [--closed] [--epochs E] [--timeout-ms X] [--backoff-ms X]
                [--max-retries R] [--hedge-mult X] [--eject-factor X]
  avxfreq energy [--config configs/energy.toml] [--quick] [--seed N] [--threads T]
                 [--governors intel-legacy,slow-ramp,dim-silicon]
  avxfreq tpc [--config configs/tpc.toml] [--quick] [--seed N] [--threads T]
              [--placements home-core,avx-steer,avx-steer-lazy] [--avx-cores K]
  avxfreq chaos [--config configs/chaos.toml] [--quick] [--seed N] [--threads T]
                [--open] [--no-faults]
  avxfreq bench [--quick] [--seed N] [--threads T]
                [--scenarios single,matrix,fleet,hier,executor,incremental,chaos]
                [--out BENCH_10.json] [--min-speedup R]
  avxfreq serve [--artifacts DIR] [--port 8443]
  avxfreq calibrate [--artifacts DIR]
experiments: fig1 fig2 fig3 fig5 fig5ms fig5tail fleetvar fleetscale faulttol
             energydelay runtimespec hybridspec fig6 ipc fig7 cryptobench ablations";

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.subcommand() {
        Some("repro") => cmd_repro(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("flamegraph") => cmd_flamegraph(&args),
        Some("sim") => cmd_sim(&args),
        Some("matrix") => cmd_matrix(&args),
        Some("incremental") => cmd_incremental(&args),
        Some("traffic") => cmd_traffic(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("energy") => cmd_energy(&args),
        Some("tpc") => cmd_tpc(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("bench") => cmd_bench(&args),
        Some("serve") => avxfreq::runtime::server::cmd_serve(&args),
        Some("calibrate") => avxfreq::runtime::calibrate::cmd_calibrate(&args),
        // Bare experiment id (`avxfreq fig5`) = `avxfreq repro fig5`.
        Some(id) if repro::ALL.contains(&id) => cmd_repro_direct(&args, id),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// `avxfreq <experiment>` — the `repro` subcommand without the noun.
/// Shares `run_repro` with `cmd_repro` so flags like `--seeds` behave
/// identically in both spellings.
fn cmd_repro_direct(args: &Args, id: &str) -> anyhow::Result<()> {
    run_repro(args, id)
}

fn cmd_repro(args: &Args) -> anyhow::Result<()> {
    let which = args.rest().first().map(|s| s.as_str()).unwrap_or("all");
    run_repro(args, which)
}

fn run_repro(args: &Args, which: &str) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);
    // Multi-seed statistics for the headline figure.
    if which == "fig5" {
        let n_seeds = args.get_parse::<usize>("seeds", 1);
        if n_seeds > 1 {
            let r = avxfreq::repro::fig5_throughput::run_multi(quick, seed, n_seeds);
            print!("{}", r.render());
            r.save_csvs()?;
            return Ok(());
        }
    }
    let ids: Vec<&str> = if which == "all" { repro::ALL.to_vec() } else { vec![which] };
    for id in ids {
        eprintln!("[avxfreq] running {id}{}…", if quick { " (quick)" } else { "" });
        let r = repro::run(id, quick, seed)?;
        print!("{}", r.render());
        r.save_csvs()?;
        println!();
    }
    println!("CSV output written to results/");
    Ok(())
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let isa = parse_isa(args.get_or("isa", "avx512"));
    let min_ratio = args.get_parse::<f64>("min-ratio", 0.3);
    let bins = build_binaries(isa);
    let rows = static_analysis::analyze(&bins);
    print!("{}", static_analysis::report_table(&rows).render());
    println!("\ncandidates for annotation (ratio ≥ {min_ratio}):");
    for c in static_analysis::candidates(&rows, min_ratio) {
        println!("  {} ({}) — ratio {:.2}", c.function, c.binary, c.avx_ratio);
    }
    Ok(())
}

fn cmd_flamegraph(args: &Args) -> anyhow::Result<()> {
    let isa = parse_isa(args.get_or("isa", "avx512"));
    let counter = match args.get_or("counter", "throttle") {
        "throttle" => flamegraph::Counter::Throttle,
        "cycles" => flamegraph::Counter::Cycles,
        other => panic!("unknown --counter {other}"),
    };
    let out_path = args.get_or("out", "results/flamegraph.svg").to_string();

    let mut cfg = WebCfg::paper_default(isa, PolicyKind::Unmodified);
    cfg.track_flame = true;
    cfg.warmup = 300 * MS;
    cfg.measure = SEC;
    eprintln!("[avxfreq] running instrumented web-server simulation…");
    let (_run, m) = run_webserver_machine(&cfg);

    // The planner interns stacks deterministically; rebuild the same table.
    let stacks = avxfreq::workload::webserver::stack_table_for(isa);
    let rows = flamegraph::fold(&m.flame, &stacks, counter);
    println!("{}", flamegraph::folded_text(&rows));
    if let Some(dir) = std::path::Path::new(&out_path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let title = format!(
        "CORE_POWER.{} flame graph — nginx/{}",
        if counter == flamegraph::Counter::Throttle { "THROTTLE" } else { "cycles" },
        isa.name()
    );
    std::fs::write(&out_path, flamegraph::render_svg(&rows, &title))?;
    eprintln!("[avxfreq] wrote {out_path}");
    Ok(())
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let mut cfg = if let Some(path) = args.get("config") {
        let conf = avxfreq::util::config::Config::load(path)?;
        WebCfg::from_config(&conf)?
    } else {
        let isa = parse_isa(args.get_or("isa", "avx512"));
        let policy = parse_policy(args);
        WebCfg::paper_default(isa, policy)
    };
    if args.get("seed").is_some() || args.get("config").is_none() {
        cfg.seed = args.get_parse::<u64>("seed", 0x5EED);
    }
    if args.get("cores").is_some() {
        cfg.cores = args.get_parse::<usize>("cores", cfg.cores);
        // Re-derive the worker pool (2/core, like nginx) only when no
        // config file pinned an explicit worker count.
        if args.get("config").is_none() {
            cfg.workers = cfg.cores * 2;
        }
    }
    if args.get("workers").is_some() {
        cfg.workers = args.get_parse::<usize>("workers", cfg.workers);
    }
    if args.get("sockets").is_some() {
        cfg.sockets = args.get_parse::<usize>("sockets", 1).max(1);
    }
    if args.flag("no-compress") {
        cfg.compress = false;
    }
    if args.flag("fault-migrate") {
        cfg.fault_migrate = true;
        cfg.annotate = false;
    }
    if let Some(spec) = args.get("hybrid") {
        // --hybrid P,E[,M]: a hybrid machine shape (e.g. 8,16,4 for the
        // desktop 8P+16E part in 4-core modules). Overrides --cores: the
        // shape *is* the core count.
        let parts: Vec<usize> = spec
            .split(',')
            .map(|s| s.trim().parse())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("--hybrid {spec}: {e}"))?;
        anyhow::ensure!(
            parts.len() == 2 || parts.len() == 3,
            "--hybrid P,E[,M] (e.g. 8,16,4), got {spec:?}"
        );
        let module = if parts.len() == 3 { parts[2] } else { 4 };
        let h = avxfreq::cpu::HybridSpec::new(parts[0], parts[1], module)?;
        anyhow::ensure!(
            !(cfg.fault_migrate && h.has_e_cores()),
            "--fault-migrate is incompatible with E-cores: a 512-bit fault on an E-core \
             is #UD, not a migration trigger"
        );
        cfg.cores = h.n_cores();
        if args.get("config").is_none() && args.get("workers").is_none() {
            cfg.workers = cfg.cores * 2;
        }
        // class-native without an explicit size follows the machine.
        if let PolicyKind::ClassNative { ref mut p_cores } = cfg.policy {
            if args.get("p-cores").is_none() && args.get("avx-cores").is_none() {
                *p_cores = h.p_cores;
            }
        }
        cfg.hybrid = Some(h);
    }
    if args.flag("adaptive") {
        anyhow::ensure!(
            matches!(cfg.policy, PolicyKind::CoreSpec { .. }),
            "--adaptive requires --policy corespec (the controller does not manage {} yet)",
            cfg.policy.name()
        );
        cfg.adaptive = Some(Default::default());
    }
    if let Some(rate) = args.get("rate") {
        let rate: f64 = rate.parse()?;
        // A zero/NaN rate would trip ArrivalGen's assert mid-run; fail
        // at the CLI surface like every other bad flag.
        anyhow::ensure!(
            rate.is_finite() && rate > 0.0,
            "--rate must be a finite positive req/s, got {rate}"
        );
        cfg.mode = avxfreq::workload::client::LoadMode::Open { rate };
    }
    if args.get("seconds").is_some() {
        cfg.measure = args.get_parse::<u64>("seconds", 4) * SEC;
    }
    let secs = cfg.measure / SEC;

    eprintln!("[avxfreq] simulating {}…", cfg.isa.name());
    let (run, m) = run_webserver_machine(&cfg);
    println!("== Run summary ==");
    println!("config:            {}", run.cfg_name);
    println!("throughput:        {:.0} req/s", run.throughput_rps);
    println!(
        "latency p50/p95/p99/p999: {:.0} / {:.0} / {:.0} / {:.0} µs (max {:.0})",
        run.tail.p50_us, run.tail.p95_us, run.tail.p99_us, run.tail.p999_us, run.tail.max_us
    );
    println!(
        "SLO ≤ {:.1} ms:       {:.2}% violations, {} drops",
        run.tail.slo_us / 1_000.0,
        run.tail.slo_violation_frac * 100.0,
        run.dropped
    );
    if run.tenant_tails.len() > 1 {
        for (tenant, tail) in &run.tenant_tails {
            println!(
                "  tenant {tenant:<8} p50 {:.0} µs  p99 {:.0} µs  p999 {:.0} µs  slo {:.2}%  ({} done)",
                tail.p50_us,
                tail.p99_us,
                tail.p999_us,
                tail.slo_violation_frac * 100.0,
                tail.completed
            );
        }
    }
    println!("avg busy freq:     {:.3} GHz", run.avg_ghz);
    println!("IPC:               {:.3}", run.ipc);
    println!("type changes:      {:.0}/s", run.type_changes_per_sec);
    println!("migrations:        {:.0}/s", run.migrations_per_sec);
    if cfg.sockets > 1 {
        println!("xsock migrations:  {:.0}/s", run.cross_socket_migrations_per_sec);
    }
    if run.adaptive_changes > 0 || cfg.adaptive.is_some() {
        println!(
            "adaptive:          final {} AVX cores after {} resizes",
            run.final_avx_cores, run.adaptive_changes
        );
    }
    println!();
    print!("{}", metrics::core_report(&m).render());
    println!();
    print!("{}", metrics::sched_report(&m, secs as f64).render());
    println!();
    print!("{}", metrics::perf_report(&m.total_perf()).render());
    Ok(())
}

fn cmd_traffic(args: &Args) -> anyhow::Result<()> {
    use avxfreq::scenario::ArrivalSpec;
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_parse::<usize>("threads", default_threads).max(1);
    let mut m = avxfreq::scenario::ScenarioMatrix::traffic_sweep(quick, seed);
    let slo_ms = args.get_parse::<f64>("slo-ms", 5.0);
    anyhow::ensure!(
        slo_ms.is_finite() && slo_ms > 0.0,
        "--slo-ms must be a finite positive threshold, got {slo_ms}"
    );
    m.slo = (slo_ms * MS as f64) as avxfreq::sim::Time;
    if let Some(spec) = args.get("loads") {
        let loads: Result<Vec<f64>, _> = spec.split(',').map(|s| s.trim().parse()).collect();
        m.loads = loads.map_err(|e| anyhow::anyhow!("--loads {spec}: {e}"))?;
        // Reject here with a CLI error; a zero/NaN rate would otherwise
        // abort inside a matrix worker thread (ArrivalGen's rate assert).
        anyhow::ensure!(
            m.loads.iter().all(|l| l.is_finite() && *l > 0.0),
            "--loads {spec}: every load level must be a finite positive multiplier"
        );
    }
    if let Some(spec) = args.get("arrivals") {
        let mut arrivals = Vec::new();
        for name in spec.split(',') {
            arrivals.push(match name.trim() {
                "poisson" => ArrivalSpec::Poisson,
                "bursty" => ArrivalSpec::bursty_default(),
                "diurnal" => ArrivalSpec::diurnal_default(),
                "mix" => ArrivalSpec::TenantMix { avx_share: 0.3 },
                "bursty-mix" => ArrivalSpec::bursty_mix_default(),
                other => {
                    anyhow::bail!("--arrivals {other}: poisson|bursty|diurnal|mix|bursty-mix")
                }
            });
        }
        m.arrivals = arrivals;
    }
    eprintln!(
        "[avxfreq] traffic: {} cells ({} loads × {} arrivals) across up to {} threads (seed {seed:#x})…",
        m.len(),
        m.loads.len(),
        m.arrivals.len(),
        threads.min(m.len().max(1))
    );
    let t0 = std::time::Instant::now();
    let result = m.run(threads);
    print!("{}", result.render());
    println!();
    print!("{}", result.render_tail());
    let path = result.table().save_csv("traffic")?;
    let tail_path = result.tail_table().save_csv("traffic_tail")?;
    eprintln!(
        "[avxfreq] wrote {} and {} ({} cells in {:.1}s wallclock)",
        path.display(),
        tail_path.display(),
        result.cells.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `avxfreq fleet` — one cluster simulation: N machines behind a
/// request router, per-machine + cluster tail tables. Defaults to the
/// fleetvar scenario (bursty multi-tenant mix on uncompressed pages);
/// `--config` (e.g. `configs/fleet_slo.toml` or `fleet_closed.toml`)
/// replaces the whole template, flags override on top. `--closed` (or
/// `balancer.enabled` in the config) switches to the hierarchical
/// closed-loop front end — epoch-fed retries, hedging and health
/// ejection over the machine → rack → cluster streaming aggregation —
/// and `--hier`/`--rack-size`/`--collective` select the same hierarchy
/// with the loop left open.
fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    use avxfreq::fleet::{
        run_fleet, run_hier_fleet, BalancerCfg, FleetRun, HierFleetCfg, HierFleetRun, RouterSpec,
    };
    use avxfreq::sim::{Time, MS};
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_parse::<usize>("threads", default_threads).max(1);

    let mut hier = if let Some(path) = args.get("config") {
        let conf = avxfreq::util::config::Config::load(path)?;
        let mut h = HierFleetCfg::from_config(&conf)?;
        if args.get("seed").is_some() {
            h.fleet.cfg.seed = seed;
        }
        if quick {
            // --quick shortens a config-loaded scenario too.
            avxfreq::repro::fleetvar::apply_quick(&mut h.fleet.cfg);
        }
        h
    } else {
        HierFleetCfg::new(
            avxfreq::repro::fleetvar::fleet_cfg(RouterSpec::RoundRobin, quick, seed),
            BalancerCfg::default(),
        )
    };
    let fleet = &mut hier.fleet;
    if let Some(n) = args.get("machines") {
        fleet.machines = n.parse::<usize>()?.max(1);
    }
    // --avx-machines composes with whichever router is in effect: it
    // defaults to the config's subset size, parameterizes a --router
    // override, and resizes an already-selected partition router on its
    // own (anything else would silently clobber the config value).
    let avx_default = match fleet.router {
        RouterSpec::AvxPartition { avx_machines } => avx_machines,
        _ => 1,
    };
    let avx_machines = args.get_parse::<usize>("avx-machines", avx_default);
    // --service-est-us mirrors --avx-machines for the least-outstanding
    // router: default from whatever the config selected, override with
    // the flag, and never silently drop an explicit value.
    let est_default_us = match fleet.router {
        RouterSpec::LeastOutstanding { service_est } => service_est as f64 / 1_000.0,
        _ => avxfreq::fleet::DEFAULT_SERVICE_EST_US,
    };
    let service_est =
        avxfreq::fleet::service_est_ns(args.get_parse::<f64>("service-est-us", est_default_us))?;
    if let Some(name) = args.get("router") {
        fleet.router = RouterSpec::parse(name, avx_machines, service_est)?;
    } else if let RouterSpec::AvxPartition { .. } = fleet.router {
        // Resize an already-selected partition router in place.
        fleet.router = RouterSpec::AvxPartition { avx_machines };
    } else if let RouterSpec::LeastOutstanding { .. } = fleet.router {
        // Retune an already-selected least-outstanding router in place.
        fleet.router = RouterSpec::LeastOutstanding { service_est };
    }
    // An explicit subset size must land on a partition router, whatever
    // combination of config and flags produced the final selection —
    // never a silent drop, never a silent router swap.
    anyhow::ensure!(
        args.get("avx-machines").is_none()
            || matches!(fleet.router, RouterSpec::AvxPartition { .. }),
        "--avx-machines only parameterizes the avx-partition router (selected: {})",
        fleet.router.label()
    );
    anyhow::ensure!(
        args.get("service-est-us").is_none()
            || matches!(fleet.router, RouterSpec::LeastOutstanding { .. }),
        "--service-est-us only parameterizes the least-outstanding router (selected: {})",
        fleet.router.label()
    );
    if let Some(rate) = args.get("rate") {
        let rate: f64 = rate.parse()?;
        anyhow::ensure!(
            rate.is_finite() && rate > 0.0,
            "--rate must be a finite positive fleet-total req/s, got {rate}"
        );
        // Rescale the scenario's arrival process instead of replacing
        // it: a structured mix must keep its tenants and burst shape,
        // or the avx-partition router would suddenly see a single
        // all-AVX stream.
        let process = fleet
            .cfg
            .mode
            .process()
            .ok_or_else(|| anyhow::anyhow!("--rate requires an open-loop fleet scenario"))?;
        fleet.cfg.mode = avxfreq::workload::client::LoadMode::OpenProcess {
            process: process.with_mean_rate(rate),
        };
    }
    // Closed-loop balancer flags. `--closed` flips the switch; the
    // tuning flags refine an already-enabled loop (from the flag or the
    // config's `[balancer]` table) and are rejected otherwise, so a
    // typo can't silently run open-loop.
    if args.flag("closed") {
        hier.balancer.enabled = true;
    }
    let ms_flag = |name: &str, current: Time| -> Time {
        let ms = args.get_parse::<f64>(name, current as f64 / MS as f64);
        (ms * MS as f64).round() as Time
    };
    hier.balancer.epochs = args.get_parse::<usize>("epochs", hier.balancer.epochs);
    hier.balancer.timeout = ms_flag("timeout-ms", hier.balancer.timeout);
    hier.balancer.retry_backoff = ms_flag("backoff-ms", hier.balancer.retry_backoff);
    hier.balancer.max_retries = args.get_parse::<u32>("max-retries", hier.balancer.max_retries);
    hier.balancer.hedge_p99_mult =
        args.get_parse::<f64>("hedge-mult", hier.balancer.hedge_p99_mult);
    hier.balancer.eject_factor = args.get_parse::<f64>("eject-factor", hier.balancer.eject_factor);
    let tuning = ["epochs", "timeout-ms", "backoff-ms", "max-retries", "hedge-mult", "eject-factor"];
    anyhow::ensure!(
        hier.balancer.enabled || tuning.iter().all(|f| args.get(f).is_none()),
        "--epochs/--timeout-ms/--backoff-ms/--max-retries/--hedge-mult/--eject-factor tune \
         the closed loop; pass --closed or set balancer.enabled in the config"
    );
    hier.machines_per_rack = args.get_parse::<usize>("rack-size", hier.machines_per_rack).max(1);
    hier.collective_steps = args.get_parse::<usize>("collective", hier.collective_steps);
    // The hierarchy is worth the report change even with the loop open:
    // explicit `--hier`, a rack-size override, or a collective request
    // all select it; otherwise the classic flat-fleet path runs
    // byte-identically to previous releases.
    let use_hier = hier.balancer.enabled
        || args.flag("hier")
        || args.get("rack-size").is_some()
        || args.get("collective").is_some();
    hier.validate()?;

    eprintln!(
        "[avxfreq] fleet: {} machines × {} cores behind {} ({}) across up to {} threads \
         (seed {:#x})…",
        hier.fleet.machines,
        hier.fleet.cfg.cores,
        hier.fleet.router.label(),
        hier.balancer.label(),
        threads.min(hier.fleet.machines),
        // The effective seed (possibly from the config file), not the
        // CLI default — this line is what users copy to reproduce runs.
        hier.fleet.cfg.seed
    );
    let t0 = std::time::Instant::now();
    if use_hier {
        let run = run_hier_fleet(&hier, threads);
        let pairs: Vec<(&str, &HierFleetRun)> = vec![("fleet", &run)];
        let table = metrics::hier_report(&pairs);
        print!("{}", table.render());
        let s = run.p99_summary();
        println!(
            "\ncluster: {} done, {} dropped, p99 {:.0} µs, SLO ≤ {:.1} ms violated {:.2}% \
             ({} exact); cross-machine p99 σ {:.1} µs, spread {:.1} µs",
            run.completed,
            run.dropped,
            run.tail.p99_us,
            run.tail.slo_us / 1_000.0,
            run.tail.slo_violation_frac * 100.0,
            run.violations,
            s.stddev(),
            run.p99_spread_us(),
        );
        if !run.outcomes.is_noop() {
            let o = &run.outcomes;
            println!(
                "front-end: {} timeouts observed, {} retries issued ({} abandoned), \
                 {} hedges, {} ejections, {} readmissions",
                o.timeouts_observed,
                o.retries_issued,
                o.retries_abandoned,
                o.hedges_issued,
                o.ejections,
                o.readmissions
            );
        }
        if let Some(c) = &run.collective {
            println!(
                "collective: {} bulk-synchronous steps, makespan {:.1} ms vs ideal {:.1} ms \
                 — slowdown {:.2}",
                c.steps,
                c.makespan_us / 1_000.0,
                c.ideal_us / 1_000.0,
                c.slowdown
            );
        }
        for (tenant, stats) in &run.tenant_stats {
            let t = stats.summary();
            println!(
                "  tenant {tenant:<8} p50 {:.0} µs  p99 {:.0} µs  slo {:.2}%  ({} done)",
                t.p50_us,
                t.p99_us,
                t.slo_violation_frac * 100.0,
                t.completed
            );
        }
        let path = table.save_csv("fleet_hier")?;
        eprintln!(
            "[avxfreq] wrote {} ({} machines in {} racks in {:.1}s wallclock)",
            path.display(),
            run.machines,
            run.n_racks(),
            t0.elapsed().as_secs_f64()
        );
        return Ok(());
    }
    let run = run_fleet(&hier.fleet, threads);
    let pairs: Vec<(&str, &FleetRun)> = vec![("fleet", &run)];
    let table = metrics::fleet_report(&pairs);
    print!("{}", table.render());
    let s = run.p99_summary();
    println!(
        "\ncluster: {} done, {} dropped, p99 {:.0} µs, SLO ≤ {:.1} ms violated {:.2}% \
         ({} exact); cross-machine p99 σ {:.1} µs, spread {:.1} µs",
        run.completed,
        run.dropped,
        run.tail.p99_us,
        run.tail.slo_us / 1_000.0,
        run.tail.slo_violation_frac * 100.0,
        run.violations,
        s.stddev(),
        run.p99_spread_us(),
    );
    for (tenant, stats) in &run.tenant_stats {
        let t = stats.summary();
        println!(
            "  tenant {tenant:<8} p50 {:.0} µs  p99 {:.0} µs  slo {:.2}%  ({} done)",
            t.p50_us,
            t.p99_us,
            t.slo_violation_frac * 100.0,
            t.completed
        );
    }
    let path = table.save_csv("fleet")?;
    eprintln!(
        "[avxfreq] wrote {} ({} machines in {:.1}s wallclock)",
        path.display(),
        run.machines.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `avxfreq energy` — the power/energy view. With `--config` (e.g.
/// `configs/energy.toml`): one simulation, reported per core (energy
/// split active/idle, watts, perf-per-watt) plus the run summary.
/// Without: the governor sweep (`ScenarioMatrix::energy_sweep`) —
/// {unmodified, core-spec} × every governor — with the matrix table and
/// a per-cell energy table.
fn cmd_energy(args: &Args) -> anyhow::Result<()> {
    use avxfreq::cpu::GovernorSpec;
    use avxfreq::metrics::{energy_report, machine_energy_rows, EnergyRow};
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);

    if let Some(path) = args.get("config") {
        // The config branch runs exactly one simulation under the
        // config's own governor; silently ignoring --governors here
        // would misattribute every table (same rationale as rejecting
        // unknown governor names in the parser).
        anyhow::ensure!(
            args.get("governors").is_none(),
            "--governors selects cells of the sweep; with --config, set power.governor \
             in the file instead"
        );
        let conf = avxfreq::util::config::Config::load(path)?;
        let mut cfg = WebCfg::from_config(&conf)?;
        if args.get("seed").is_some() {
            cfg.seed = seed;
        }
        if quick {
            cfg.warmup = cfg.warmup.min(150 * MS);
            cfg.measure = cfg.measure.min(300 * MS);
        }
        let secs = cfg.measure as f64 / SEC as f64;
        eprintln!(
            "[avxfreq] energy: {} under the {} governor…",
            cfg.isa.name(),
            cfg.governor.name()
        );
        let (run, m) = run_webserver_machine(&cfg);
        println!("== Run summary ==");
        println!("config:            {}", run.cfg_name);
        println!("governor:          {}", cfg.governor.name());
        println!("throughput:        {:.0} req/s", run.throughput_rps);
        println!("p99 latency:       {:.0} µs", run.tail.p99_us);
        println!(
            "energy:            {:.2} J active + {:.2} J idle = {:.2} J ({:.1} W avg)",
            run.active_energy_j,
            run.idle_energy_j,
            run.energy_j(),
            run.energy_j() / secs
        );
        println!(
            "efficiency:        {:.3} mJ/req, {:.1} req/J (perf-per-watt)",
            run.j_per_req() * 1e3,
            run.req_per_j()
        );
        println!();
        let rows = machine_energy_rows(&m, cfg.governor.name(), run.completed, secs);
        let table = energy_report(&rows);
        print!("{}", table.render());
        let p = table.save_csv("energy")?;
        eprintln!("[avxfreq] wrote {}", p.display());
        return Ok(());
    }

    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_parse::<usize>("threads", default_threads).max(1);
    let mut m = avxfreq::scenario::ScenarioMatrix::energy_sweep(quick, seed);
    if let Some(spec) = args.get("governors") {
        let governors: anyhow::Result<Vec<GovernorSpec>> =
            spec.split(',').map(|s| GovernorSpec::parse(s.trim())).collect();
        m.governors = governors?;
        anyhow::ensure!(!m.governors.is_empty(), "--governors must name at least one governor");
    }
    eprintln!(
        "[avxfreq] energy: {} cells ({} policies × {} governors) across up to {} threads \
         (seed {seed:#x})…",
        m.len(),
        m.policies.len(),
        m.governors.len(),
        threads.min(m.len().max(1))
    );
    let t0 = std::time::Instant::now();
    let secs = m.measure as f64 / SEC as f64;
    let result = m.run(threads);
    print!("{}", result.render());
    println!();
    let rows: Vec<EnergyRow> = result
        .cells
        .iter()
        .map(|c| EnergyRow {
            scope: format!("{}|{}", c.scenario.index, c.scenario.policy),
            governor: c.scenario.governor.name().to_string(),
            active_j: c.run.active_energy_j,
            idle_j: c.run.idle_energy_j,
            completed: c.run.completed,
            secs,
        })
        .collect();
    let table = energy_report(&rows);
    print!("{}", table.render());
    let path = table.save_csv("energy")?;
    eprintln!(
        "[avxfreq] wrote {} ({} cells in {:.1}s wallclock)",
        path.display(),
        result.cells.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `avxfreq tpc` — the thread-per-core executor view. With `--config`
/// (e.g. `configs/tpc.toml`): run the configured web scenario through
/// the executor under each placement policy (the config's `[tpc]`
/// section sets quantum/shares and the AVX-core subset) and print the
/// `tpc_report` comparison. Without: the executor sweep
/// (`ScenarioMatrix::tpc_sweep`) — every placement on the bursty
/// multi-tenant mix — with the matrix and tail tables.
fn cmd_tpc(args: &Args) -> anyhow::Result<()> {
    use avxfreq::tpc::{all_placements, run_tpc, tpc_report, PlacementSpec};
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_parse::<usize>("threads", default_threads).max(1);

    if let Some(path) = args.get("config") {
        let conf = avxfreq::util::config::Config::load(path)?;
        let mut cfg = WebCfg::from_config(&conf)?;
        let params = match &cfg.mode {
            avxfreq::workload::client::LoadMode::Executor { tpc, .. } => tpc.clone(),
            _ => anyhow::bail!(
                "avxfreq tpc --config needs a [tpc] section selecting a placement \
                 (see configs/tpc.toml)"
            ),
        };
        if args.get("seed").is_some() {
            cfg.seed = seed;
        }
        if quick {
            cfg.warmup = cfg.warmup.min(150 * MS);
            cfg.measure = cfg.measure.min(300 * MS);
        }
        // Compare all placements over the configured subset size (a
        // home-core config has no subset; fall back to the paper's 2).
        let k = match params.placement.avx_cores() {
            0 => args.get_parse::<usize>("avx-cores", 2),
            k => args.get_parse::<usize>("avx-cores", k),
        };
        let placements: Vec<PlacementSpec> = if let Some(spec) = args.get("placements") {
            spec.split(',')
                .map(|s| PlacementSpec::parse(s.trim(), k))
                .collect::<anyhow::Result<Vec<_>>>()?
        } else {
            all_placements(k).to_vec()
        };
        anyhow::ensure!(!placements.is_empty(), "--placements must name at least one policy");
        eprintln!(
            "[avxfreq] tpc: {} placement(s) × {} executor cores across up to {} threads \
             (seed {:#x})…",
            placements.len(),
            cfg.workers.max(1),
            threads.min(placements.len()),
            cfg.seed
        );
        let t0 = std::time::Instant::now();
        let rows = run_tpc(&cfg, &params, &placements, threads);
        let table = tpc_report(&rows);
        print!("{}", table.render());
        let p = table.save_csv("tpc")?;
        eprintln!(
            "[avxfreq] wrote {} ({} runs in {:.1}s wallclock)",
            p.display(),
            rows.len(),
            t0.elapsed().as_secs_f64()
        );
        return Ok(());
    }

    let m = avxfreq::scenario::ScenarioMatrix::tpc_sweep(quick, seed);
    eprintln!(
        "[avxfreq] tpc: {} executor cells across up to {} threads (seed {seed:#x})…",
        m.len(),
        threads.min(m.len().max(1))
    );
    let t0 = std::time::Instant::now();
    let result = m.run(threads);
    print!("{}", result.render());
    println!();
    print!("{}", result.render_tail());
    let path = result.table().save_csv("tpc")?;
    eprintln!(
        "[avxfreq] wrote {} ({} cells in {:.1}s wallclock)",
        path.display(),
        result.cells.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `avxfreq chaos` — the fault-injection view: one hierarchical fleet
/// run under a deterministic fault schedule, reporting the per-rack
/// table plus the per-fault-window damage table (`fault_report`: p99
/// during vs outside each window, SLO violations inside it, and the
/// crash MTTR in epochs). Defaults to the fleetvar fleet under the
/// chaos preset with the closed loop on; `--config configs/chaos.toml`
/// replaces the template (its `[faults]` section is the full schedule
/// language), `--open` leaves the loop open (full damage), and
/// `--no-faults` runs the identical scenario fault-free — the
/// differential leg: its bytes must match a pre-fault-layer run.
fn cmd_chaos(args: &Args) -> anyhow::Result<()> {
    use avxfreq::faults::FaultsCfg;
    use avxfreq::fleet::{run_hier_fleet, BalancerCfg, HierFleetCfg, HierFleetRun, RouterSpec};
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_parse::<usize>("threads", default_threads).max(1);

    let mut hier = if let Some(path) = args.get("config") {
        let conf = avxfreq::util::config::Config::load(path)?;
        let mut h = HierFleetCfg::from_config(&conf)?;
        if args.get("seed").is_some() {
            h.fleet.cfg.seed = seed;
        }
        if quick {
            avxfreq::repro::fleetvar::apply_quick(&mut h.fleet.cfg);
        }
        h
    } else {
        let mut h = HierFleetCfg::new(
            avxfreq::repro::fleetvar::fleet_cfg(RouterSpec::RoundRobin, quick, seed),
            BalancerCfg::closed(),
        );
        h.machines_per_rack = 4;
        h
    };
    if args.flag("open") {
        hier.balancer.enabled = false;
    }
    if !hier.faults.active() {
        // No [faults] section (or none enabled): the chaos preset over
        // this scenario's window and fleet.
        hier.faults = FaultsCfg::chaos(hier.fleet.cfg.measure, hier.fleet.machines.max(1));
    }
    if args.flag("no-faults") {
        hier.faults = FaultsCfg::default();
    }
    hier.validate()?;

    eprintln!(
        "[avxfreq] chaos: {} machines × {} cores, {} + faults={} across up to {} threads \
         (seed {:#x})…",
        hier.fleet.machines,
        hier.fleet.cfg.cores,
        hier.balancer.label(),
        hier.faults.label(),
        threads.min(hier.fleet.machines),
        hier.fleet.cfg.seed
    );
    let t0 = std::time::Instant::now();
    let run = run_hier_fleet(&hier, threads);
    let pairs: Vec<(&str, &HierFleetRun)> = vec![("fleet", &run)];
    print!("{}", metrics::hier_report(&pairs).render());
    println!();
    let table = metrics::fault_report(&run.fault_windows, &run.fault_outcomes);
    print!("{}", table.render());
    let fo = &run.fault_outcomes;
    println!(
        "\nfaults: {} crash window(s), {} degradation window(s); {} requests lost to dark \
         windows, {} dropped by the network, {} fault-victim retries, {} epoch(s) of \
         crash-ejection before readmission",
        fo.crash_windows,
        fo.degrade_windows,
        fo.lost_to_crash,
        fo.dropped_by_net,
        fo.fault_retries,
        fo.recovery_epochs
    );
    println!(
        "cluster: {} done, {} dropped, p99 {:.0} µs, SLO ≤ {:.1} ms violated {:.2}%",
        run.completed,
        run.dropped,
        run.tail.p99_us,
        run.tail.slo_us / 1_000.0,
        run.tail.slo_violation_frac * 100.0,
    );
    let path = table.save_csv("chaos")?;
    eprintln!(
        "[avxfreq] wrote {} ({} machines in {:.1}s wallclock)",
        path.display(),
        run.machines,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `avxfreq bench` — time the canonical scenarios with the hot paths on
/// (the default simulator) and off (the baseline), print the comparison
/// table, and write the `BENCH_10.json` perf-trajectory record. Exits
/// non-zero if any scenario's two legs are not output-identical — the
/// harness is also the fast-path equivalence gate (`ci.sh` runs
/// `bench --quick`). A speedup below `--min-speedup` (default 0 = off;
/// the acceptance target is 3) is a warning unless the flag is set,
/// because absolute wall-clock on a loaded machine is noise — see
/// `rust/tests/README.md` § bench triage.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_parse::<usize>("threads", default_threads).max(1);
    let mut cfg = avxfreq::bench::BenchCfg::new(quick, seed, threads);
    if let Some(spec) = args.get("scenarios") {
        // Drop empty segments ("matrix," / ",") so the at-least-one
        // guard below is meaningful and a stray comma fails fast in
        // bench::run's name check rather than after minutes of legs.
        cfg.scenarios = spec
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        anyhow::ensure!(!cfg.scenarios.is_empty(), "--scenarios must name at least one scenario");
    }
    let out_path = args.get_or("out", "BENCH_10.json").to_string();
    let min_speedup = args.get_parse::<f64>("min-speedup", 0.0);

    eprintln!(
        "[avxfreq] bench: {} scenario(s) × 2 legs across up to {threads} threads \
         (seed {seed:#x}{})…",
        cfg.scenarios.len(),
        if quick { ", quick" } else { "" }
    );
    let rows = avxfreq::bench::run(&cfg)?;
    print!("{}", metrics::bench_report(&rows).render());

    std::fs::write(&out_path, avxfreq::bench::to_json(&cfg, &rows))?;
    eprintln!("[avxfreq] wrote {out_path}");

    for r in &rows {
        anyhow::ensure!(
            r.outputs_identical,
            "fast-path outputs DIVERGED from the baseline on scenario {:?} — this is a \
             correctness bug, not a perf regression (see rust/tests/perf_equiv.rs)",
            r.scenario
        );
        if r.speedup() < 3.0 {
            eprintln!(
                "[avxfreq] note: {} speedup {:.2}x below the 3x target (wall-clock noise on \
                 loaded machines is expected; compare ratios across runs, not absolutes)",
                r.scenario,
                r.speedup()
            );
        }
        anyhow::ensure!(
            min_speedup <= 0.0 || r.speedup() >= min_speedup,
            "scenario {:?} speedup {:.2}x below --min-speedup {min_speedup}",
            r.scenario,
            r.speedup()
        );
    }
    Ok(())
}

fn cmd_matrix(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_parse::<usize>("threads", default_threads).max(1);
    let mut m = avxfreq::scenario::ScenarioMatrix::default_sweep(quick, seed);
    if args.flag("full-isa") {
        m.isas = avxfreq::workload::crypto::Isa::all().to_vec();
    }
    if args.flag("hybrid") {
        // Add the 8P+16E hybrid part to the topology axis (the default
        // axes stay byte-identical without the flag).
        m.topologies.push(avxfreq::scenario::TopologySpec::hybrid_8p16e());
    }
    eprintln!(
        "[avxfreq] matrix: {} cells across up to {} threads (seed {seed:#x})…",
        m.len(),
        threads.min(m.len().max(1))
    );
    let t0 = std::time::Instant::now();
    let result = m.run(threads);
    print!("{}", result.render());
    let path = result.save_csv()?;
    eprintln!(
        "[avxfreq] wrote {} ({} cells in {:.1}s wallclock)",
        path.display(),
        result.cells.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `avxfreq incremental` — the measurement-window sweep: the default
/// matrix crossed with an innermost `measures` axis, so each warmup
/// group shares a prefix and the checkpoint-forking fast path has work
/// to skip. `--cold` disables forking (`incremental = false`) to
/// demonstrate that the output bytes do not change, only
/// `warmup_ns_reused` does (rust/tests/incremental.rs pins this).
fn cmd_incremental(args: &Args) -> anyhow::Result<()> {
    let quick = args.flag("quick");
    let seed = args.get_parse::<u64>("seed", 0x5EED);
    let default_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = args.get_parse::<usize>("threads", default_threads).max(1);
    let mut m = avxfreq::scenario::ScenarioMatrix::incremental_sweep(quick, seed);
    if args.flag("cold") {
        m.incremental = false;
    }
    eprintln!(
        "[avxfreq] incremental: {} cells in groups of {} across up to {} threads \
         (seed {seed:#x}, forking {})…",
        m.len(),
        m.warmup_group_size(),
        threads.min(m.len().max(1)),
        if m.incremental { "on" } else { "off" }
    );
    let t0 = std::time::Instant::now();
    let result = m.run(threads);
    print!("{}", result.render());
    println!();
    print!("{}", result.render_tail());
    eprintln!(
        "[avxfreq] warmup_ns_reused = {} ({} simulated warmup seconds skipped by forking)",
        result.warmup_ns_reused,
        result.warmup_ns_reused / 1_000_000_000
    );
    eprintln!(
        "[avxfreq] {} cells in {:.1}s wallclock",
        result.cells.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
