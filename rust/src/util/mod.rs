//! Shared utilities: deterministic RNG, statistics, histograms, CLI and
//! config parsing, and table formatting.
//!
//! Everything here is dependency-free by design: the offline build
//! environment only vendors the `xla` crate's closure, so the usual
//! ecosystem crates (`rand`, `serde`, `clap`, `hdrhistogram`) are
//! re-implemented at the scale this project needs.

pub mod rng;
pub mod stats;
pub mod histogram;
pub mod args;
pub mod config;
pub mod table;

pub use histogram::LogHistogram;
pub use rng::{mix64, Rng};
pub use stats::Summary;
