//! TOML-subset config parser (offline substitute for `serde` + `toml`).
//!
//! Supports the subset the project's config files use: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! boolean / homogeneous array values, `#` comments. Values land in a flat
//! `section.key -> Value` map with typed accessors, which is all the
//! launcher needs.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parse error with line information.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Flat dotted-key configuration map.
#[derive(Debug, Default, Clone)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

fn parse_scalar(tok: &str, line: usize) -> Result<Value, ParseError> {
    let t = tok.trim();
    if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = t.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(ParseError { line, msg: format!("cannot parse value `{t}`") })
}

/// Split a `[a, b, c]` body on commas (no nested arrays needed).
fn parse_value(tok: &str, line: usize) -> Result<Value, ParseError> {
    let t = tok.trim();
    if t.starts_with('[') {
        if !t.ends_with(']') {
            return Err(ParseError { line, msg: "unterminated array".into() });
        }
        let inner = &t[1..t.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_scalar(part, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    parse_scalar(t, line)
}

/// Strip a trailing `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            let n = lineno + 1;
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ParseError { line: n, msg: "unterminated section header".into() });
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(ParseError { line: n, msg: "empty section name".into() });
                }
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(ParseError { line: n, msg: format!("expected `key = value`, got `{line}`") });
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(key, parse_value(v, n)?);
        }
        Ok(Config { map })
    }

    pub fn load(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        match self.map.get(key) {
            Some(Value::Str(s)) => s,
            _ => default,
        }
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        match self.map.get(key) {
            Some(Value::Int(i)) => *i,
            _ => default,
        }
    }

    /// Integer read as a count: negative config values clamp to 0 rather
    /// than wrapping through an `as usize` cast at the call site.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        match self.map.get(key) {
            Some(Value::Int(i)) => (*i).max(0) as usize,
            _ => default,
        }
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        match self.map.get(key) {
            Some(Value::Float(x)) => *x,
            Some(Value::Int(i)) => *i as f64,
            _ => default,
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.map.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# simulator config
seed = 42
[machine]
cores = 16            # Xeon Gold 6130
smt = true
turbo_ghz = [2.8, 2.4, 1.9]
name = "xeon-gold-6130"
[sched.corespec]
avx_cores = 2
penalty_ns = 1000000
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.int_or("seed", 0), 42);
        assert_eq!(c.int_or("machine.cores", 0), 16);
        assert!(c.bool_or("machine.smt", false));
        assert_eq!(c.str_or("machine.name", ""), "xeon-gold-6130");
        assert_eq!(c.int_or("sched.corespec.avx_cores", 0), 2);
        match c.get("machine.turbo_ghz").unwrap() {
            Value::Array(xs) => {
                assert_eq!(xs.len(), 3);
                assert_eq!(xs[0], Value::Float(2.8));
            }
            v => panic!("wrong type {v:?}"),
        }
    }

    #[test]
    fn float_from_int_coercion() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.float_or("x", 0.0), 3.0);
    }

    #[test]
    fn usize_clamps_negative() {
        let c = Config::parse("n = -3\nm = 5").unwrap();
        assert_eq!(c.usize_or("n", 7), 0);
        assert_eq!(c.usize_or("m", 7), 5);
        assert_eq!(c.usize_or("missing", 7), 7);
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Config::parse("s = \"a#b\"").unwrap();
        assert_eq!(c.str_or("s", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Config::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn defaults_on_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int_or("nope", 7), 7);
        assert_eq!(c.str_or("nope", "d"), "d");
    }
}
