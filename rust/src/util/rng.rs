//! Deterministic pseudo-random number generation.
//!
//! The simulator must be bit-for-bit reproducible from a seed (a property
//! test asserts this), so we use a fixed, well-understood generator rather
//! than anything platform dependent: xoshiro256**, seeded via splitmix64.

/// xoshiro256** PRNG. Deterministic, seedable, fast (~1 ns/draw).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One SplitMix64 step as a pure function: decorrelates seeds derived
/// from `(base, index)`-style mixes — the scenario matrix's per-cell
/// seeds and the fleet's per-machine seeds both use this, so the
/// derivation lives in exactly one place.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    splitmix64(&mut z)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Any seed (including 0) is fine;
    /// splitmix64 expands it to the full 256-bit state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-entity streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction (the small
    /// modulo bias is irrelevant at simulation scale but we debias anyway).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Debiased multiply-shift (Lemire 2018).
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed value with the given mean (for Poisson
    /// arrival processes in the open-loop load generator).
    #[inline]
    pub fn exponential(&mut self, mean: f64) -> f64 {
        // Avoid ln(0).
        let u = 1.0 - self.f64();
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (used for noisy cost models).
    pub fn normal(&mut self, mean: f64, stddev: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + stddev * z
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX] {
            for _ in 0..100 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let mean = 250.0;
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| r.exponential(mean)).sum();
        let got = sum / n as f64;
        assert!((got - mean).abs() / mean < 0.05, "got={got}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_independent() {
        let mut parent = Rng::new(5);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
