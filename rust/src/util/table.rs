//! Plain-text / markdown / CSV table emission for the repro harness.
//!
//! Every figure runner produces a `Table`; `main.rs` renders it to the
//! terminal and optionally writes CSV next to it so plots can be
//! regenerated externally.

use std::fmt::Write as _;

/// Column-aligned table with a title, used for paper-figure output.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: formats each cell with `Display`.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<width$}  ", c, width = w[i]);
            }
            let _ = writeln!(out);
        };
        line(&self.header, &mut out);
        let _ = writeln!(out, "{}", "-".repeat(w.iter().sum::<usize>() + 2 * w.len()));
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// Render as CSV (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let quote = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.iter().map(|h| quote(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(out, "|{}|", self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in &self.rows {
            let _ = writeln!(out, "| {} |", row.join(" | "));
        }
        out
    }

    /// Write the CSV to `results/<name>.csv` (creating the directory).
    pub fn save_csv(&self, name: &str) -> anyhow::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a float with fixed decimals — the repro tables want stable widths.
pub fn fmt_f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format a percent change as e.g. `-11.2%`.
pub fn fmt_pct(x: f64) -> String {
    format!("{:+.1}%", x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("Fig X", &["isa", "throughput", "delta"]);
        t.row(&["sse4".into(), "100.0".into(), "+0.0%".into()]);
        t.row(&["avx512".into(), "88.8".into(), "-11.2%".into()]);
        t
    }

    #[test]
    fn render_contains_all_cells() {
        let s = sample().render();
        for needle in ["Fig X", "isa", "avx512", "-11.2%"] {
            assert!(s.contains(needle), "missing {needle} in:\n{s}");
        }
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("t", &["a"]);
        t.row(&["x,y".into()]);
        t.row(&["he said \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("| isa | throughput | delta |"));
        assert!(md.contains("|---|---|---|"));
    }
}
