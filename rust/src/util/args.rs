//! Minimal CLI argument parser (offline substitute for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and generates usage text. Used by `main.rs` and the examples.

use std::collections::BTreeMap;

/// Parsed command line: flags, key/value options, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: Vec<String>,
    opts: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process argv (skipping the binary name).
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed lookup with default; panics with a clear message on parse failure.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default,
            Some(s) => match s.parse() {
                Ok(v) => v,
                Err(e) => panic!("--{name}={s}: {e}"),
            },
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Positionals after the subcommand.
    pub fn rest(&self) -> &[String] {
        if self.positional.is_empty() {
            &[]
        } else {
            &self.positional[1..]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_opts_positionals() {
        let a = parse("repro fig5 --seed 7 --isa=avx512 --verbose");
        assert_eq!(a.subcommand(), Some("repro"));
        assert_eq!(a.rest(), &["fig5".to_string()]);
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("isa"), Some("avx512"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("--cores 12");
        assert_eq!(a.get_parse::<usize>("cores", 4), 12);
        assert_eq!(a.get_parse::<usize>("threads", 26), 26);
        assert_eq!(a.get_parse::<f64>("rate", 1.5), 1.5);
    }

    #[test]
    #[should_panic]
    fn bad_value_panics() {
        let a = parse("--cores twelve");
        a.get_parse::<usize>("cores", 4);
    }

    #[test]
    fn trailing_flag_not_eating_value() {
        let a = parse("--verbose --seed 3");
        assert!(a.flag("verbose"));
        assert_eq!(a.get("seed"), Some("3"));
    }
}
