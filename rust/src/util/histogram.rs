//! Log-bucketed latency histogram, HDR-histogram style.
//!
//! wrk2 (the paper's load generator) reports latency percentiles from an
//! HDR histogram; this is the same idea at fixed precision: buckets are
//! `(exponent, 1/32 sub-bucket)` so relative error is bounded by ~3%.

const SUB_BITS: u32 = 5; // 32 sub-buckets per power of two
const SUB: u64 = 1 << SUB_BITS;

/// Histogram over `u64` values (we use nanoseconds) with bounded relative
/// error, supporting percentile queries and merging.
///
/// Every query on an *empty* histogram has a defined return — 0 (or 0.0)
/// across the board: [`LogHistogram::mean`], [`LogHistogram::min`],
/// [`LogHistogram::max`], [`LogHistogram::percentile`],
/// [`LogHistogram::report`], and [`LogHistogram::fraction_above`] — and
/// merging an empty histogram in either direction is the identity
/// (`min`'s internal `u64::MAX` sentinel never leaks). Consumers that
/// aggregate sparse scopes (e.g. the fleet balancer's per-epoch machine
/// histograms, where an idle machine records nothing all epoch) rely on
/// this: no special-casing, no panics, no poisoned statistics. Pinned by
/// `empty_histogram_queries_are_safe_zeroes` and
/// `empty_histogram_merge_edge_cases` below.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    max: u64,
    min: u64,
}

#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as u64;
    let shift = msb - SUB_BITS as u64;
    let sub = (v >> shift) - SUB; // top SUB_BITS+1 bits, minus implied leading 1
    ((msb - SUB_BITS as u64 + 1) * SUB + sub) as usize
}

#[inline]
fn bucket_low(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < SUB {
        return idx;
    }
    let exp = (idx / SUB) - 1 + SUB_BITS as u64;
    let sub = idx % SUB;
    (SUB + sub) << (exp - SUB_BITS as u64)
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        // Enough buckets for values up to 2^63.
        let n = bucket_index(u64::MAX / 2) + 2;
        LogHistogram { buckets: vec![0; n], count: 0, sum: 0, max: 0, min: u64::MAX }
    }

    /// Zero every counter in place, keeping the bucket allocation — the
    /// post-warmup reset path reuses recorders instead of reallocating
    /// their ~2k-bucket vectors per measurement window.
    pub fn clear(&mut self) {
        self.buckets.fill(0);
        self.count = 0;
        self.sum = 0;
        self.max = 0;
        self.min = u64::MAX;
    }

    pub fn record(&mut self, v: u64) {
        // `new()` sizes the vector for values up to 2^63; anything larger
        // (bucket_index(u64::MAX) = 1919 vs the 1889 allocated) clamps
        // into the top bucket. Only the bucketed percentile loses
        // precision there — `sum`, `max`, and `min` stay exact.
        let i = bucket_index(v).min(self.buckets.len() - 1);
        self.buckets[i] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
        self.min = self.min.min(v);
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Value at percentile `p` (0..=100). Returns the lower bound of the
    /// bucket containing the target rank — a ≤3% underestimate at worst.
    /// An empty histogram returns 0 for every percentile.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_low(i).max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// Standard latency report row: p50/p90/p99/p999/max in the value's units.
    pub fn report(&self) -> [u64; 5] {
        [
            self.percentile(50.0),
            self.percentile(90.0),
            self.percentile(99.0),
            self.percentile(99.9),
            self.max,
        ]
    }

    /// Approximate fraction of recorded values strictly above `v`: the
    /// share of counts in buckets whose range lies entirely above `v`.
    /// Under-counts by at most the one bucket containing `v` (~3%
    /// relative value error), so exact threshold accounting (e.g. SLO
    /// violations in [`crate::traffic::LatencyStats`]) is done at record
    /// time instead; the recorder cross-checks this query against its
    /// exact counter in debug builds, and it serves post-hoc thresholds
    /// on merged histograms.
    pub fn fraction_above(&self, v: u64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let boundary = bucket_index(v);
        let above: u64 = self.buckets.iter().skip(boundary + 1).sum();
        above as f64 / self.count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_monotone_and_invertible() {
        let mut values: Vec<u64> = Vec::new();
        for shift in 0..50u64 {
            for off in [0u64, 1, 3] {
                values.push((1u64 << shift) + off * (1 << shift.saturating_sub(4)));
            }
        }
        values.sort_unstable();
        let mut prev = 0;
        for v in values {
            let i = bucket_index(v);
            assert!(i >= prev, "index not monotone at {v}");
            prev = i;
            let lo = bucket_low(i);
            assert!(lo <= v, "bucket_low {lo} > {v}");
            // relative error bound
            if v >= SUB {
                assert!((v - lo) as f64 / v as f64 <= 1.0 / 16.0, "v={v} lo={lo}");
            }
        }
    }

    #[test]
    fn small_values_exact() {
        let mut h = LogHistogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.percentile(100.0), 31);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn percentiles_close() {
        let mut h = LogHistogram::new();
        for v in 1..=10_000u64 {
            h.record(v * 1000); // 1µs .. 10ms in ns
        }
        let p50 = h.percentile(50.0) as f64;
        assert!((p50 - 5_000_000.0).abs() / 5_000_000.0 < 0.05, "p50={p50}");
        let p99 = h.percentile(99.0) as f64;
        assert!((p99 - 9_900_000.0).abs() / 9_900_000.0 < 0.05, "p99={p99}");
    }

    #[test]
    fn merge_matches_union() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut u = LogHistogram::new();
        for v in 0..1000u64 {
            if v % 2 == 0 {
                a.record(v * 17)
            } else {
                b.record(v * 17)
            }
            u.record(v * 17);
        }
        a.merge(&b);
        assert_eq!(a.count(), u.count());
        assert_eq!(a.percentile(90.0), u.percentile(90.0));
        assert_eq!(a.max(), u.max());
    }

    #[test]
    fn fraction_above_tracks_threshold() {
        let mut h = LogHistogram::new();
        for v in 1..=1000u64 {
            h.record(v * 1024); // spread across many buckets
        }
        // Exact fraction above 500*1024 is 0.5; the bucket boundary can
        // only shave up to one bucket's worth (~3%) off.
        let f = h.fraction_above(500 * 1024);
        assert!((0.40..=0.50).contains(&f), "f={f}");
        assert_eq!(h.fraction_above(u64::MAX / 4), 0.0);
        assert!(h.fraction_above(0) > 0.99, "everything is above 0");
        assert_eq!(LogHistogram::new().fraction_above(5), 0.0);
    }

    #[test]
    fn fraction_above_at_exact_bucket_edges() {
        // Small values (< 32) get one bucket each, so the arithmetic is
        // exact at every bucket edge: strictly-above k is (31-k)/32.
        let mut h = LogHistogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        for k in 0..32u64 {
            let expect = (31 - k) as f64 / 32.0;
            assert_eq!(h.fraction_above(k), expect, "edge k={k}");
        }
        // First bucketed edge: 32 is the lower bound of its own bucket
        // (bucket_index(32) starts the exponent range), so a value
        // recorded exactly at an edge is *not* counted above that edge —
        // the documented one-bucket undercount, never an overcount.
        let mut g = LogHistogram::new();
        g.record(32);
        assert_eq!(bucket_low(bucket_index(32)), 32, "32 must start its bucket");
        assert_eq!(g.fraction_above(32), 0.0);
        assert_eq!(g.fraction_above(31), 1.0, "the whole bucket lies above 31's bucket");
        // An edge mid-way up a larger exponent: bucket_low round-trips
        // and fraction_above at that edge excludes the edge bucket.
        let edge = bucket_low(bucket_index(1_000_000));
        let mut m = LogHistogram::new();
        m.record(edge);
        m.record(edge * 4); // several buckets higher
        assert_eq!(m.fraction_above(edge), 0.5, "only the strictly-higher bucket counts");
    }

    #[test]
    fn empty_histogram_queries_are_safe_zeroes() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.percentile(99.9), 0);
        assert_eq!(h.report(), [0; 5]);
        assert_eq!(h.fraction_above(0), 0.0);
        assert_eq!(h.fraction_above(u64::MAX / 2), 0.0);
        // Merging an empty histogram is the identity.
        let mut a = LogHistogram::new();
        a.record(42);
        let before = (a.count(), a.min(), a.max(), a.percentile(50.0));
        a.merge(&h);
        assert_eq!(before, (a.count(), a.min(), a.max(), a.percentile(50.0)));
    }

    #[test]
    fn clear_is_equivalent_to_fresh() {
        let mut h = LogHistogram::new();
        for v in [1u64, 77, 1 << 40, 0] {
            h.record(v);
        }
        h.clear();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(99.0), 0);
        // Recording after clear behaves like a fresh histogram.
        h.record(9);
        let mut fresh = LogHistogram::new();
        fresh.record(9);
        assert_eq!(h.report(), fresh.report());
        assert_eq!(h.mean(), fresh.mean());
        assert_eq!(h.min(), fresh.min());
    }

    #[test]
    fn oversized_values_clamp_into_top_bucket() {
        // Regression: `new()` allocates bucket_index(u64::MAX / 2) + 2
        // buckets, but bucket_index(u64::MAX) is larger — recording any
        // value ≥ 2^63 used to index out of bounds and panic.
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        h.record(1u64 << 63);
        h.record((1u64 << 63) + 12345);
        h.record(100);
        assert_eq!(h.count(), 4);
        // sum / max / min stay exact even for clamped values.
        let expect_sum = u64::MAX as u128 + (1u128 << 63) + ((1u128 << 63) + 12345) + 100;
        assert!((h.mean() - expect_sum as f64 / 4.0).abs() / h.mean() < 1e-9);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.min(), 100);
        // The top percentile reports the clamp bucket's lower bound (2^63),
        // the documented bucketed underestimate — but never panics.
        assert_eq!(h.percentile(100.0), 1u64 << 63);
        assert!(h.percentile(99.9) >= 1u64 << 62);
        // fraction_above with an oversized threshold stays in range.
        assert_eq!(h.fraction_above(u64::MAX), 0.0);
        assert!((h.fraction_above(1000) - 0.75).abs() < 1e-12);
        // Merging histograms holding clamped values is panic-free and
        // matches recording the union directly.
        let mut a = LogHistogram::new();
        a.record(u64::MAX);
        let mut b = LogHistogram::new();
        b.record(u64::MAX - 7);
        a.merge(&b);
        let mut u = LogHistogram::new();
        u.record(u64::MAX);
        u.record(u64::MAX - 7);
        assert_eq!(a.count(), u.count());
        assert_eq!(a.max(), u.max());
        assert_eq!(a.percentile(50.0), u.percentile(50.0));
    }

    #[test]
    fn empty_histogram_merge_edge_cases() {
        // min() uses u64::MAX as its "nothing recorded" sentinel; these
        // pin that the sentinel never leaks through a merge in either
        // direction (previously only implicitly covered).
        let mut empty = LogHistogram::new();
        let mut full = LogHistogram::new();
        full.record(500);
        full.record(9000);
        // empty.merge(full): adopts the other's min/max exactly.
        empty.merge(&full);
        assert_eq!(empty.count(), 2);
        assert_eq!(empty.min(), 500);
        assert_eq!(empty.max(), 9000);
        // empty.merge(empty): still reports the safe zeroes.
        let mut e2 = LogHistogram::new();
        e2.merge(&LogHistogram::new());
        assert_eq!(e2.count(), 0);
        assert_eq!(e2.min(), 0);
        assert_eq!(e2.max(), 0);
        assert_eq!(e2.percentile(99.0), 0);
        // ...and recording afterwards behaves like a fresh histogram.
        e2.record(77);
        assert_eq!(e2.min(), 77);
        assert_eq!(e2.max(), 77);
    }

    #[test]
    fn mean_exact() {
        let mut h = LogHistogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert!((h.mean() - 20.0).abs() < 1e-12);
    }
}
