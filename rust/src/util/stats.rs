//! Summary statistics for benchmark reporting: mean, stddev, min/max,
//! confidence intervals, and relative-change helpers used by the repro
//! harness when comparing against the paper's numbers.

/// Online mean/variance accumulator (Welford) plus extrema.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn from_iter<I: IntoIterator<Item = f64>>(xs: I) -> Self {
        let mut s = Self::new();
        for x in xs {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Half-width of the ~95% confidence interval of the mean
    /// (normal approximation; fine for the n≥10 repetitions we run).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.stddev() / (self.n as f64).sqrt()
        }
    }
}

/// Relative change of `new` versus `base`, in percent. Positive = faster/larger.
pub fn pct_change(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        return 0.0;
    }
    (new - base) / base * 100.0
}

/// Percentile of a *sorted* slice via linear interpolation (inclusive method).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let s = Summary::from_iter(xs.iter().copied());
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // naive sample variance
        let var: f64 = xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / 7.0;
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i * 7 % 13) as f64).collect();
        let mut a = Summary::from_iter(xs[..40].iter().copied());
        let b = Summary::from_iter(xs[40..].iter().copied());
        a.merge(&b);
        let whole = Summary::from_iter(xs.iter().copied());
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
    }

    #[test]
    fn pct_change_signs() {
        assert!((pct_change(100.0, 90.0) + 10.0).abs() < 1e-12);
        assert!((pct_change(100.0, 110.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 4.0);
        assert!((percentile_sorted(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a = Summary::from_iter((0..10).map(|i| i as f64));
        let b = Summary::from_iter((0..1000).map(|i| (i % 10) as f64));
        assert!(b.ci95() < a.ci95());
    }
}
