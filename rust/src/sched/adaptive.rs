//! Dynamic AVX-core allocation (§3.1) and adaptive enablement (§4.3).
//!
//! §3.1: *"The system therefore allocates as many AVX cores as required
//! for the AVX tasks in the system or more."* The prototype in the paper
//! fixes the count; this controller sizes it online from the measured
//! utilization of the current AVX cores, with hysteresis so the set is
//! stable on the 100 ms scale (re-partitioning is cheap — eligibility is
//! evaluated at pick time — but each change perturbs task placement).
//!
//! §4.3: *"policies have to be adaptive to be viable for widespread use.
//! We expect that a good policy has to estimate the impact of core
//! specialization on performance and, depending on the outcome, has to
//! choose whether to use core specialization or not."* The controller
//! implements the first-order estimate: if the AVX work share is too
//! small to justify even one dedicated core (mechanism overhead exceeds
//! the frequency tax it prevents), it returns the allocation to the
//! minimum and the penalty scheme makes the AVX core behave almost like
//! a normal core.
//!
//! Invariants (property-tested over random load traces in
//! `rust/tests/properties.rs::prop_adaptive_bounds_and_hysteresis`):
//! after every tick the count stays within `[min_avx, min(max_avx,
//! n_cores − 1)]`, and the two-window debounce means the count never
//! changes at two consecutive ticks.

use super::machine::Machine;
use super::policy::PolicyKind;
use crate::sim::Time;

/// Controller parameters.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveParams {
    /// Re-evaluation period.
    pub interval: Time,
    /// Bounds on the AVX-core count.
    pub min_avx: usize,
    pub max_avx: usize,
    /// Target utilization of the AVX-core set by *AVX-typed work*: the
    /// set is sized so AVX demand fills this fraction of it (headroom
    /// keeps queueing delay low; backfilled scalar time does not count).
    pub target_util: f64,
}

impl Default for AdaptiveParams {
    fn default() -> Self {
        AdaptiveParams {
            interval: 50 * crate::sim::MS,
            min_avx: 1,
            max_avx: 4,
            target_util: 0.55,
        }
    }
}

/// Online controller; owns the AVX-time baseline between ticks.
///
/// `Clone` carries the baseline, debounce proposal, and decision
/// counters across a checkpoint fork so the controller's post-fork
/// ticks match a cold run exactly.
#[derive(Clone, Debug)]
pub struct Controller {
    pub params: AdaptiveParams,
    last_avx_ns: Vec<Time>,
    last_tick: Time,
    /// Pending resize proposal (must repeat once before applying —
    /// debounces measurement noise at window boundaries).
    proposal: Option<usize>,
    /// Decisions made (for reporting).
    pub grows: u64,
    pub shrinks: u64,
}

impl Controller {
    pub fn new(params: AdaptiveParams, n_cores: usize) -> Self {
        Controller {
            params,
            last_avx_ns: vec![0; n_cores],
            last_tick: 0,
            proposal: None,
            grows: 0,
            shrinks: 0,
        }
    }

    /// Current AVX-core count of the machine's policy (0 when the policy
    /// has no specialization).
    pub fn current_k(m: &Machine) -> usize {
        m.sched.policy.avx_core_count()
    }

    /// Evaluate and, if warranted, resize the AVX-core set. Returns the
    /// (possibly unchanged) count. Call from a periodic driver event.
    ///
    /// Sizing rule (§3.1 "as many AVX cores as required … or more"): the
    /// measured AVX demand over the last window, divided by the target
    /// per-core utilization, rounded up. A proposal must hold for two
    /// consecutive windows before it is applied.
    pub fn tick(&mut self, m: &mut Machine) -> usize {
        let n = m.n_cores();
        let k = match m.sched.policy {
            PolicyKind::CoreSpec { avx_cores } => avx_cores,
            // Controller only manages the paper's policy.
            _ => return 0,
        };
        let now = m.now();
        let window = now.saturating_sub(self.last_tick).max(1);
        self.last_tick = now;

        // Total AVX-typed execution time over the last window (counters
        // may have been reset at the measurement-window start).
        let mut avx_ns: Time = 0;
        for c in 0..n {
            let cur = m.avx_task_ns[c];
            let delta = if cur >= self.last_avx_ns[c] { cur - self.last_avx_ns[c] } else { cur };
            avx_ns += delta;
            self.last_avx_ns[c] = cur;
        }
        let demand_cores = avx_ns as f64 / window as f64 / self.params.target_util;
        let want = (demand_cores.ceil() as usize)
            .clamp(self.params.min_avx, self.params.max_avx.min(n - 1));

        let new_k = if want != k {
            if self.proposal == Some(want) {
                // Confirmed over two windows: apply.
                self.proposal = None;
                if want > k {
                    self.grows += 1;
                } else {
                    self.shrinks += 1;
                }
                m.sched.policy = PolicyKind::CoreSpec { avx_cores: want };
                want
            } else {
                self.proposal = Some(want);
                k
            }
        } else {
            self.proposal = None;
            k
        };
        new_k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::block::{Block, ClassMix, InsnClass};
    use crate::sched::machine::{Action, MachineParams, NullDriver, TaskBody};
    use crate::sched::TaskType;
    use crate::sim::SEC;
    use crate::util::Rng;

    /// Body with a configurable AVX duty cycle.
    struct Duty {
        avx_pct: u64,
        i: u64,
        phase: u8,
    }
    impl TaskBody for Duty {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            self.i += 1;
            let avx_turn = self.i % 100 < self.avx_pct;
            match (self.phase, avx_turn) {
                (0, true) => {
                    self.phase = 1;
                    Action::SetType(TaskType::Avx)
                }
                (1, _) => {
                    self.phase = 2;
                    Action::Run {
                        block: Block {
                            mix: ClassMix::of(InsnClass::Avx512Heavy, 50_000),
                            mem_ops: 0,
                            branches: 100,
                            license_exempt: false,
                        },
                        func: 1,
                        stack: 0,
                    }
                }
                (2, _) => {
                    self.phase = 0;
                    Action::SetType(TaskType::Scalar)
                }
                _ => Action::Run {
                    block: Block {
                        mix: ClassMix::scalar(50_000),
                        mem_ops: 0,
                        branches: 100,
                        license_exempt: false,
                    },
                    func: 2,
                    stack: 0,
                },
            }
        }
    }

    fn run_with_duty(avx_pct: u64, start_k: usize) -> (usize, Controller) {
        let mut p = MachineParams::new(8, PolicyKind::CoreSpec { avx_cores: start_k });
        p.seed = 1;
        let mut m = crate::sched::machine::Machine::new(p);
        for _ in 0..12 {
            m.spawn(TaskType::Scalar, 0, Box::new(Duty { avx_pct, i: 0, phase: 0 }));
        }
        let mut ctl = Controller::new(AdaptiveParams::default(), 8);
        let mut t = 0;
        let mut k = start_k;
        while t < 2 * SEC {
            t += ctl.params.interval;
            m.run_until(t, &mut NullDriver);
            k = ctl.tick(&mut m);
        }
        (k, ctl)
    }

    #[test]
    fn grows_under_avx_heavy_load() {
        let (k, ctl) = run_with_duty(60, 1);
        assert!(k >= 2, "controller should grow the AVX set, got {k}");
        assert!(ctl.grows > 0);
    }

    #[test]
    fn shrinks_when_avx_share_is_tiny() {
        let (k, ctl) = run_with_duty(1, 4);
        assert_eq!(k, 1, "controller should shrink to the minimum");
        assert!(ctl.shrinks > 0);
    }

    #[test]
    fn stable_in_the_hysteresis_band() {
        // A moderate duty cycle should settle, not oscillate forever.
        let (_k, ctl) = run_with_duty(18, 2);
        let changes = ctl.grows + ctl.shrinks;
        assert!(changes < 12, "controller oscillating: {changes} changes in 2s");
    }

    #[test]
    fn ignores_non_corespec_policies() {
        let p = MachineParams::new(4, PolicyKind::Unmodified);
        let mut m = crate::sched::machine::Machine::new(p);
        let mut ctl = Controller::new(AdaptiveParams::default(), 4);
        assert_eq!(ctl.tick(&mut m), 0);
    }

    #[test]
    fn never_exceeds_bounds() {
        let (k, _) = run_with_duty(95, 1);
        assert!(k <= AdaptiveParams::default().max_avx);
        let (k2, _) = run_with_duty(0, 3);
        assert!(k2 >= AdaptiveParams::default().min_avx);
    }
}
