//! The simulated machine: event loop gluing the core models, the
//! scheduler, and workload task bodies.
//!
//! Tasks are coroutines: the machine asks a task's [`TaskBody`] for its
//! next [`Action`] whenever the task holds a core. `Run` actions execute
//! an instruction block on the core model (advancing frequency licenses
//! and PMU counters); `SetType` is the paper's `with_avx()` /
//! `without_avx()` syscall; `Sleep`/`WaitChannel` block the task. All
//! scheduler operations charge simulated overhead so the Fig-7 migration
//! cost measurements are meaningful.

use super::fault_migrate::FaultMigrateParams;
use super::muqss::{SchedParams, Scheduler, TypeChangeOutcome, WakeTarget};
use super::policy::PolicyKind;
use super::task::{TaskId, TaskType};
use crate::cpu::freq::{FreqParams, License};
use crate::cpu::ipc::IpcParams;
use crate::cpu::power::PowerParams;
use crate::cpu::topology::{CoreClass, HybridSpec};
use crate::cpu::turbo::TurboTable;
use crate::cpu::Core;
use crate::faults::DegradeWindow;
use crate::isa::block::{Block, InsnClass};
use crate::sim::{EventQueue, Time};
use crate::util::Rng;
use std::collections::BTreeMap;
use std::collections::VecDeque;

/// What a task does next.
#[derive(Debug, Clone)]
pub enum Action {
    /// Execute an instruction block attributed to `func`, with `stack`
    /// identifying the interned call stack for flame-graph sampling.
    Run { block: Block, func: u64, stack: u32 },
    /// Execute `reps` back-to-back repetitions of the same block — the
    /// steady-state form workload builders emit for homogeneous inner
    /// loops (compression chunks, bulk-cipher records, spin loops).
    ///
    /// Semantics are *exactly* `reps` consecutive [`Action::Run`]s: the
    /// machine still observes the license state machine, footprint
    /// tracker, quantum, and event queue at every repetition boundary,
    /// so counters, energy, and timing are bit-identical whether a body
    /// emits one `RunMany` or `reps` separate `Run`s. The only contract
    /// on the body is the natural one: emitting `RunMany` means its
    /// `next()` would have returned the same `Run` `reps` times without
    /// reading the clock or drawing randomness in between. `reps == 0`
    /// is treated as 1. What the machine *saves* is the per-boundary
    /// event-queue round trip and task dispatch, and only while no
    /// other event wants to interleave (see `Machine::run_action`).
    RunMany { block: Block, reps: u32, func: u64, stack: u32 },
    /// `with_avx()` / `without_avx()` syscall.
    SetType(TaskType),
    /// Block for a fixed duration (timer/disk).
    Sleep(Time),
    /// Block until a credit is posted on the channel (work queues).
    WaitChannel(u32),
    /// Terminate the task.
    Exit,
}

/// A task's behaviour. Bodies capture shared workload state via
/// `Rc<RefCell<…>>` (the simulator is single-threaded by design).
pub trait TaskBody {
    fn next(&mut self, now: Time, rng: &mut Rng) -> Action;

    /// Produce this body's checkpoint-fork twin, rewiring shared
    /// workload state through `ctx` (see [`ForkCtx`]): handles to the
    /// same `Rc` allocation on the original must resolve to the same
    /// cloned allocation on the fork, and immutable `Rc`s may be shared
    /// outright (the copy-on-write half of checkpoint forking).
    ///
    /// The default `None` marks the body as not forkable;
    /// [`Machine::try_fork`] then returns `None` and the caller falls
    /// back to a cold run, so forking is strictly opt-in per workload.
    fn fork(&self, _ctx: &mut ForkCtx) -> Option<Box<dyn TaskBody>> {
        None
    }
}

/// Identity map used while forking a machine: old `Rc` allocation →
/// its one clone on the fork side.
///
/// Task bodies and drivers frequently hold handles to the *same*
/// `Rc<RefCell<…>>` (e.g. every worker shares one `ServerShared`).
/// A fork must clone that allocation exactly once and point every
/// forked handle at the single clone — cloning per handle would split
/// formerly-shared state and silently diverge from the cold run.
#[derive(Default)]
pub struct ForkCtx {
    map: std::collections::HashMap<usize, Box<dyn std::any::Any>>,
}

impl ForkCtx {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fork-side replacement for `rc`: the pointee is deep-cloned the
    /// first time an allocation is seen; every later handle to the same
    /// allocation gets the same replacement `Rc`.
    pub fn fork_rc<T: Clone + 'static>(
        &mut self,
        rc: &std::rc::Rc<std::cell::RefCell<T>>,
    ) -> std::rc::Rc<std::cell::RefCell<T>> {
        let key = std::rc::Rc::as_ptr(rc) as usize;
        if let Some(existing) = self.map.get(&key) {
            return existing
                .downcast_ref::<std::rc::Rc<std::cell::RefCell<T>>>()
                .expect("ForkCtx entry type mismatch for shared allocation")
                .clone();
        }
        let forked = std::rc::Rc::new(std::cell::RefCell::new(rc.borrow().clone()));
        self.map.insert(key, Box::new(forked.clone()));
        forked
    }

    /// Pre-seed the map: `old`'s fork-side replacement is `new`. Lets a
    /// caller build one replacement specially (e.g. recorders drawn from
    /// an arena) while every other handle to `old` still rewires onto
    /// that same replacement through [`ForkCtx::fork_rc`].
    pub fn provide<T: 'static>(
        &mut self,
        old: &std::rc::Rc<std::cell::RefCell<T>>,
        new: &std::rc::Rc<std::cell::RefCell<T>>,
    ) {
        self.map.insert(std::rc::Rc::as_ptr(old) as usize, Box::new(new.clone()));
    }
}

/// External event source driving the simulation (request arrivals, etc.).
pub trait Driver {
    fn on_external(&mut self, tag: u64, m: &mut Machine);
}

/// A no-op driver for workloads that need no external events.
pub struct NullDriver;
impl Driver for NullDriver {
    fn on_external(&mut self, _tag: u64, _m: &mut Machine) {}
}

/// `reps` repetitions of a block as the smallest action expressing them
/// — the single source of the `Run`-vs-`RunMany` packing rule (workload
/// builders emitting batched steps use it too).
pub fn pack_run(block: Block, func: u64, stack: u32, reps: u32) -> Action {
    if reps <= 1 {
        Action::Run { block, func, stack }
    } else {
        Action::RunMany { block, reps, func, stack }
    }
}

/// Machine construction parameters.
#[derive(Clone, Debug)]
pub struct MachineParams {
    pub n_cores: usize,
    /// Sockets (NUMA nodes / frequency domains) the cores are split over
    /// in contiguous balanced chunks; 1 = the paper's machine. Each
    /// socket has its own active-core turbo count, and the scheduler
    /// becomes NUMA-aware (same-node stealing preferred, cross-socket
    /// migrations charged extra).
    pub sockets: usize,
    pub turbo: TurboTable,
    pub freq: FreqParams,
    pub ipc: IpcParams,
    /// Per-core power model (energy accounting; never affects timing).
    pub power: PowerParams,
    pub sched: SchedParams,
    pub policy: PolicyKind,
    pub seed: u64,
    /// Hybrid P/E layout, if any. P-cores come first and use `turbo` /
    /// `freq` unchanged; E-cores are grouped into modules sharing one
    /// clock domain each, carry the L1 license ceiling
    /// ([`FreqParams::efficiency_core`]) and run off the
    /// [`TurboTable::e_core_module`] table. `None` — and any all-P spec —
    /// leaves the machine byte-identical to the homogeneous model.
    pub hybrid: Option<HybridSpec>,
    /// Cores outside the simulated set that are nevertheless awake (the
    /// paper's 4 client cores) — raises the active-core count. Spread
    /// over the sockets, remainder charged to the last sockets (where
    /// the paper's client cores sit).
    pub extra_active_cores: usize,
    /// Collect flame-graph samples (costs memory; off for big sweeps).
    pub track_flame: bool,
    /// §6.1 fault-and-migrate automatic classification, if enabled.
    pub fault_migrate: Option<FaultMigrateParams>,
    /// Enable the hot-path optimizations: steady-state slice coalescing
    /// in the machine loop and memoized block costing in the cores.
    /// Both are bit-exact (differential-tested in
    /// `rust/tests/perf_equiv.rs`), so this toggle exists for the bench
    /// harness's fast-vs-baseline comparison and for bisecting, not for
    /// correctness. Defaults to on.
    pub fast_paths: bool,
    /// Injected degradation windows (thermal events) from
    /// [`crate::faults`]: while a window covers a core, that core's
    /// per-license frequency rows are scaled by the window's factor.
    /// Empty (the default) keeps the literal fault-free code paths —
    /// the faults-disabled differential in `rust/tests/faults.rs`
    /// depends on it.
    pub degrade: Vec<DegradeWindow>,
}

impl MachineParams {
    pub fn new(n_cores: usize, policy: PolicyKind) -> Self {
        MachineParams {
            n_cores,
            sockets: 1,
            turbo: TurboTable::xeon_gold_6130(),
            freq: FreqParams::default(),
            ipc: IpcParams::default(),
            power: PowerParams::default(),
            sched: SchedParams::default(),
            policy,
            seed: 0xA5A5_5A5A,
            hybrid: None,
            extra_active_cores: 0,
            track_flame: false,
            fault_migrate: None,
            fast_paths: true,
            degrade: Vec::new(),
        }
    }
}

/// Events on the machine's queue.
#[derive(Debug, Clone, Copy)]
pub enum Event {
    /// `core` is at a scheduling boundary (block finished / dispatched).
    Step(usize),
    /// A blocked task becomes runnable.
    Wake(TaskId),
    /// Inter-processor interrupt delivery.
    Ipi(usize),
    /// Workload-defined external event.
    External(u64),
}

#[derive(Debug, Clone, Copy)]
enum CoreRun {
    Idle { since: Time },
    Busy { task: TaskId },
}

#[derive(Clone, Default)]
struct Channel {
    credits: u64,
    waiters: VecDeque<TaskId>,
}

/// Aggregated flame-graph sample data per interned stack.
#[derive(Debug, Default, Clone, Copy)]
pub struct StackSample {
    pub cycles: f64,
    pub throttle_cycles: f64,
}

/// The machine.
pub struct Machine {
    pub cores: Vec<Core>,
    pub sched: Scheduler,
    pub rng: Rng,
    turbo: TurboTable,
    bodies: Vec<Option<Box<dyn TaskBody>>>,
    pending_action: Vec<Option<Action>>,
    fm_scalar_streak: Vec<Time>,
    run: Vec<CoreRun>,
    step_pending: Vec<bool>,
    quantum_end: Vec<Time>,
    need_resched: Vec<Time>, // 0 = none, else extra cost to charge (ipi)
    q: EventQueue<Event>,
    channels: Vec<Channel>,
    /// Socket (NUMA node) of each core.
    socket_of: Vec<usize>,
    /// Frequency domain of each core. Domains are the sockets (ids
    /// `0..n_sockets`) followed by the E-core modules (ids `n_sockets..`)
    /// on hybrid parts; on homogeneous and all-P machines this equals
    /// `socket_of` exactly, so the turbo active-core axis is evaluated
    /// per socket as before.
    domain_of: Vec<usize>,
    /// Socket count (domains `0..n_sockets` are the sockets).
    n_sockets: usize,
    /// Hybrid layout, if any (see [`MachineParams::hybrid`]).
    hybrid: Option<HybridSpec>,
    /// E-core turbo table (one module's shared clock), present iff the
    /// hybrid spec has E-cores.
    turbo_e: Option<TurboTable>,
    /// Per-module license floor: until this instant the module's shared
    /// PLL stays at the L1 plateau because some member core recently ran
    /// licensed work (per-module hysteresis, stamped at slice ends).
    module_l1_until: Vec<Time>,
    /// Busy cores per frequency domain — the turbo table's active-core
    /// axis is evaluated per domain.
    busy_per_domain: Vec<usize>,
    /// Always-awake external cores (load generator) per domain; only
    /// socket domains ever carry extras (client cores are big cores).
    extra_per_domain: Vec<usize>,
    track_flame: bool,
    fault_migrate: Option<FaultMigrateParams>,
    fast_paths: bool,
    /// Injected degradation windows ([`MachineParams::degrade`]);
    /// empty on every fault-free machine.
    degrade: Vec<DegradeWindow>,
    /// Horizon of the current `run_until` call: the fast path may not
    /// execute a repetition whose dispatch boundary lies beyond it (the
    /// slow path's boundary Step would never pop).
    horizon: Time,
    /// Flame samples keyed by interned stack id.
    pub flame: BTreeMap<u32, StackSample>,
    /// Repetitions executed by the coalescing fast path *beyond* the
    /// first of each window — i.e. event-queue round trips saved
    /// (diagnostics for the bench harness; never rendered in reports).
    pub coalesced_reps: u64,
    /// Fault-and-migrate trap count (§6.1).
    pub fm_faults: u64,
    /// Per-core time spent running AVX-typed tasks (adaptive controller
    /// input: total AVX demand, regardless of which core carried it).
    pub avx_task_ns: Vec<Time>,
    /// Blocks carrying AVX-512 instructions that executed on an E-core —
    /// must stay 0 (the part has no 512-bit path; the scheduler's
    /// capability confinement is responsible). Asserted by the property
    /// tests, never rendered in reports.
    pub e_wide512_blocks: u64,
}

impl Machine {
    pub fn new(p: MachineParams) -> Self {
        if let Some(h) = p.hybrid {
            assert_eq!(h.n_cores(), p.n_cores, "hybrid spec must cover every core");
            assert!(
                p.fault_migrate.is_none() || !h.has_e_cores(),
                "fault-and-migrate is undefined with E-cores (512-bit code faults for real there)"
            );
        }
        let cores: Vec<Core> = (0..p.n_cores)
            .map(|i| {
                let freq = match p.hybrid {
                    Some(h) if h.class_of(i) == CoreClass::Efficiency => {
                        p.freq.clone().efficiency_core()
                    }
                    _ => p.freq.clone(),
                };
                let mut c = Core::new(i, freq, p.ipc.clone());
                c.power = p.power;
                c.memoize = p.fast_paths;
                c
            })
            .collect();
        let socket_of = crate::cpu::topology::socket_map(p.n_cores, p.sockets);
        let n_sockets = socket_of.iter().copied().max().map_or(1, |m| m + 1);
        // The socket count appears both in the machine shape and inside
        // the NUMA policy; normalize the policy on the machine's actual
        // domain count so no caller can desynchronize the AVX-core
        // layout from the frequency/NUMA domains.
        let mut policy = p.policy.clone();
        if let PolicyKind::CoreSpecNuma { sockets, .. } = &mut policy {
            *sockets = n_sockets;
        }
        // Frequency domains: the sockets, then one domain per E-core
        // module (shared PLL). All-P hybrids collapse to the socket
        // domains, so `domain_of == socket_of` off the hybrid path.
        let n_modules = p.hybrid.map_or(0, |h| h.n_modules());
        let domain_of: Vec<usize> = (0..p.n_cores)
            .map(|c| match p.hybrid.and_then(|h| h.module_of(c)) {
                Some(m) => n_sockets + m,
                None => socket_of[c],
            })
            .collect();
        // Spread the always-awake external cores over the sockets; the
        // remainder lands on the last sockets, where the paper's client
        // cores sit (single-socket machines keep the historical count).
        // Module domains never carry extras.
        let mut extra_per_domain = vec![0; n_sockets + n_modules];
        for s in 0..n_sockets {
            extra_per_domain[s] = p.extra_active_cores / n_sockets;
        }
        for i in 0..p.extra_active_cores % n_sockets {
            extra_per_domain[n_sockets - 1 - i] += 1;
        }
        // Single destructure decides confinement AND supplies the spec:
        // the capability mask can only be read from the very value that
        // proved E-cores exist, so guard drift can never reintroduce a
        // panic here.
        let sched = match p.hybrid.filter(|h| h.has_e_cores()) {
            Some(h) => Scheduler::new_hybrid(
                policy,
                p.sched.clone(),
                socket_of.clone(),
                h.capability_mask(),
            ),
            None => Scheduler::new_numa(policy, p.sched.clone(), socket_of.clone()),
        };
        let turbo_e = p
            .hybrid
            .filter(|h| h.has_e_cores())
            .map(|h| TurboTable::e_core_module(h.module_size));
        Machine {
            cores,
            sched,
            rng: Rng::new(p.seed),
            turbo: p.turbo.clone(),
            bodies: Vec::new(),
            pending_action: Vec::new(),
            fm_scalar_streak: Vec::new(),
            run: vec![CoreRun::Idle { since: 0 }; p.n_cores],
            step_pending: vec![false; p.n_cores],
            quantum_end: vec![0; p.n_cores],
            need_resched: vec![0; p.n_cores],
            q: EventQueue::new(),
            channels: Vec::new(),
            socket_of,
            domain_of,
            n_sockets,
            hybrid: p.hybrid,
            turbo_e,
            module_l1_until: vec![0; n_modules],
            busy_per_domain: vec![0; n_sockets + n_modules],
            extra_per_domain,
            track_flame: p.track_flame,
            fault_migrate: p.fault_migrate,
            fast_paths: p.fast_paths,
            degrade: p.degrade,
            horizon: 0,
            flame: BTreeMap::new(),
            coalesced_reps: 0,
            fm_faults: 0,
            avx_task_ns: vec![0; p.n_cores],
            e_wide512_blocks: 0,
        }
    }

    pub fn now(&self) -> Time {
        self.q.now()
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// Number of sockets (NUMA nodes).
    pub fn n_sockets(&self) -> usize {
        self.n_sockets
    }

    /// Number of frequency domains: the sockets, then the E-core
    /// modules on hybrid parts.
    pub fn n_domains(&self) -> usize {
        self.busy_per_domain.len()
    }

    /// Hybrid layout, if any.
    pub fn hybrid(&self) -> Option<HybridSpec> {
        self.hybrid
    }

    /// Socket of `core`.
    pub fn socket_of(&self, core: usize) -> usize {
        self.socket_of[core]
    }

    /// Human label of frequency domain `d`: `skt0`… for sockets, then
    /// `mod0`… for E-core modules.
    pub fn domain_label(&self, d: usize) -> String {
        if d < self.n_sockets {
            format!("skt{d}")
        } else {
            format!("mod{}", d - self.n_sockets)
        }
    }

    /// Per-domain harmonic-mean busy frequency, cpufetch-style:
    /// `n / Σ(1/ghz_i)` over the domain's cores that ran at all. The
    /// harmonic mean is the right average for "how fast did this clock
    /// domain effectively run": it weights time, not cycles. Domains
    /// that never ran report 0.
    pub fn domain_harmonic_ghz(&self) -> Vec<(String, f64)> {
        (0..self.n_domains())
            .map(|d| {
                let mut inv = 0.0;
                let mut n = 0usize;
                for (c, core) in self.cores.iter().enumerate() {
                    if self.domain_of[c] == d {
                        let ghz = core.perf.avg_busy_ghz();
                        if ghz > 0.0 {
                            inv += 1.0 / ghz;
                            n += 1;
                        }
                    }
                }
                let hm = if n > 0 { n as f64 / inv } else { 0.0 };
                (self.domain_label(d), hm)
            })
            .collect()
    }

    /// Active (busy + external) cores in `core`'s frequency domain — the
    /// value fed to the turbo table's active-core axis.
    fn active_cores(&self, core: usize) -> usize {
        let d = self.domain_of[core];
        (self.busy_per_domain[d] + self.extra_per_domain[d]).max(1)
    }

    /// Is `core` an E-core (member of a module frequency domain)?
    fn is_e_core(&self, core: usize) -> bool {
        self.domain_of[core] >= self.n_sockets
    }

    /// Module index of an E-core.
    fn module_of(&self, core: usize) -> usize {
        self.domain_of[core] - self.n_sockets
    }

    /// License floor an E-core's module imposes at `t`: the shared PLL
    /// stays at the L1 plateau until the per-module hold window expires.
    fn module_floor(&self, core: usize, t: Time) -> License {
        if t < self.module_l1_until[self.module_of(core)] {
            License::L1
        } else {
            License::L0
        }
    }

    /// Per-effective-license frequency row of an E-core at `t`: the
    /// module floor is applied on top of the core's own license, and the
    /// L2 row is pinned at L1 (the license ceiling makes it unreachable;
    /// pinning keeps the row well-defined).
    fn e_core_freqs(&self, core: usize, t: Time, active: usize) -> [f64; 3] {
        let floor = self.module_floor(core, t);
        let te = self.turbo_e.as_ref().expect("E-core without E turbo table");
        [
            te.ghz(License::L0.max(floor), active),
            te.ghz(License::L1.max(floor), active),
            te.ghz(License::L1, active),
        ]
    }

    /// After an E-core slice ending at `end`: while the core holds L1
    /// the module's shared clock stays at the L1 plateau for the hold
    /// window past the slice (per-module hysteresis). Sampled per block
    /// boundary — exactly where the license state machine itself is
    /// observed — so fast and slow paths see identical floors.
    fn stamp_module_floor(&mut self, core: usize, end: Time) {
        if self.cores[core].license.granted() >= License::L1 {
            let until = end + self.cores[core].license.params().hold;
            let m = self.module_of(core);
            if until > self.module_l1_until[m] {
                self.module_l1_until[m] = until;
            }
        }
    }

    /// Combined degradation factor covering `core` at `t` (1.0 when no
    /// injected window applies). A pure function of the frozen window
    /// list and the query time, so fast/slow paths and any thread
    /// interleaving see identical factors.
    fn degrade_factor(&self, core: usize, t: Time) -> f64 {
        let d = self.domain_of[core];
        // Module scopes match E-core modules only; P-cores' domains are
        // sockets, which no Module scope addresses.
        let module = if d >= self.n_sockets { d - self.n_sockets } else { usize::MAX };
        let mut f = 1.0;
        for w in &self.degrade {
            if w.applies(core, module, t) {
                f *= w.scale;
            }
        }
        f
    }

    /// Scale a per-license frequency row by the degradation factor at
    /// `t`. No-op (and not even a multiply) when the machine carries no
    /// windows, keeping the fault-free row bit-identical.
    fn apply_degrade(&self, core: usize, t: Time, row: &mut [f64; 3]) {
        if self.degrade.is_empty() {
            return;
        }
        let f = self.degrade_factor(core, t);
        if f != 1.0 {
            for g in row.iter_mut() {
                *g *= f;
            }
        }
    }

    /// The P-core per-license frequency row the turbo table would give
    /// at `active` cores, degraded as of `t` — the table-lookup
    /// equivalent used whenever degradation windows force the
    /// `run_block_with_freqs` form (bit-identical to `run_block` when
    /// the factor is 1.0; pinned by `cached_freqs_match_table_lookup`).
    fn degraded_p_row(&self, core: usize, t: Time, active: usize) -> [f64; 3] {
        let mut row = [
            self.turbo.ghz(License::L0, active),
            self.turbo.ghz(License::L1, active),
            self.turbo.ghz(License::L2, active),
        ];
        self.apply_degrade(core, t, &mut row);
        row
    }

    /// Create a channel (work queue) and return its id.
    pub fn channel(&mut self) -> u32 {
        self.channels.push(Channel::default());
        (self.channels.len() - 1) as u32
    }

    /// Post one credit to a channel, waking a waiter if any.
    pub fn notify(&mut self, ch: u32) {
        if let Some(waiter) = self.channels[ch as usize].waiters.pop_front() {
            let now = self.q.now();
            self.wake_now(now, waiter);
        } else {
            self.channels[ch as usize].credits += 1;
        }
    }

    /// Number of queued credits + waiters (diagnostics/backpressure).
    pub fn channel_depth(&self, ch: u32) -> (u64, usize) {
        let c = &self.channels[ch as usize];
        (c.credits, c.waiters.len())
    }

    /// Spawn a task; it becomes runnable immediately.
    pub fn spawn(&mut self, ttype: TaskType, nice: i32, body: Box<dyn TaskBody>) -> TaskId {
        let id = self.sched.add_task(ttype, nice);
        self.bodies.push(Some(body));
        self.pending_action.push(None);
        self.fm_scalar_streak.push(0);
        let now = self.q.now();
        self.wake_now(now, id);
        id
    }

    /// Schedule a workload external event.
    pub fn schedule_external(&mut self, at: Time, tag: u64) {
        self.q.schedule_at(at, Event::External(tag));
    }

    fn wake_now(&mut self, now: Time, task: TaskId) {
        let fallback = task.0 % self.cores.len();
        // Split borrow: the scheduler consults step_pending without cloning.
        let Machine { sched, step_pending, .. } = self;
        match sched.enqueue(now, task, fallback, &|c| step_pending[c], None) {
            WakeTarget::DispatchIdle(core) => self.kick(core),
            WakeTarget::Preempt(core) => {
                let lat = self.sched.params.ipi_latency;
                self.q.schedule_in(lat, Event::Ipi(core));
            }
            WakeTarget::Queued => {}
        }
    }

    /// Ensure an idle-or-boundary Step event is queued for `core`.
    fn kick(&mut self, core: usize) {
        if !self.step_pending[core] {
            self.step_pending[core] = true;
            self.q.schedule_in(0, Event::Step(core));
        }
    }

    /// Run the machine until simulated time `until`.
    pub fn run_until(&mut self, until: Time, driver: &mut dyn Driver) {
        // The coalescing fast path consults the horizon so it never
        // executes a repetition the slow path would have left queued.
        self.horizon = until;
        while let Some(t) = self.q.peek_time() {
            if t > until {
                break;
            }
            let (now, ev) = self.q.pop().unwrap();
            match ev {
                Event::Step(core) => {
                    self.step_pending[core] = false;
                    self.on_step(now, core);
                }
                Event::Wake(task) => self.wake_now(now, task),
                Event::Ipi(core) => {
                    match self.run[core] {
                        CoreRun::Busy { .. } => {
                            // Flag checked at the next block boundary; the
                            // receiver charges the interrupt cost there.
                            self.need_resched[core] = self.sched.params.ipi_cost.max(1);
                        }
                        CoreRun::Idle { .. } => self.kick(core),
                    }
                }
                Event::External(tag) => driver.on_external(tag, self),
            }
        }
    }

    /// Core is at a scheduling boundary: preemption/quantum checks, then
    /// either continue the current task or reschedule.
    fn on_step(&mut self, now: Time, core: usize) {
        match self.run[core] {
            CoreRun::Idle { since } => {
                // `since` may sit a reschedule-cost past `now` when a kick
                // lands at the same instant the core went idle.
                self.cores[core].idle_until(since.min(now), now.max(since));
                self.reschedule(now, core, 0);
            }
            CoreRun::Busy { task } => {
                // IPI-requested preemption (charged the interrupt cost).
                let ipi_cost = std::mem::take(&mut self.need_resched[core]);
                if ipi_cost > 0 {
                    self.requeue_current(now, core, false);
                    self.reschedule(now, core, ipi_cost);
                    return;
                }
                // Quantum expiry — only yields if someone else wants the CPU.
                if now >= self.quantum_end[core] {
                    if self.sched.queued_count() > 0 {
                        self.requeue_current(now, core, true);
                        self.reschedule(now, core, 0);
                        return;
                    }
                    self.quantum_end[core] = now + self.sched.params.rr_interval;
                }
                self.drive_task(now, core, task, 0);
            }
        }
    }

    fn handle_wake_target(&mut self, target: WakeTarget) {
        match target {
            WakeTarget::DispatchIdle(core) => self.kick(core),
            WakeTarget::Preempt(core) => {
                let lat = self.sched.params.ipi_latency;
                self.q.schedule_in(lat, Event::Ipi(core));
            }
            WakeTarget::Queued => {}
        }
    }

    /// Account scheduler/syscall overhead on a core's PMU counters the way
    /// real hardware would: kernel code retiring at ~1.4 IPC at the core's
    /// current licensed frequency. Keeps §4.2's instructions-per-request
    /// and IPC comparisons faithful (the paper's counters include kernel
    /// code executed by annotations and extra scheduler invocations).
    fn charge_overhead(&mut self, core: usize, ns: Time) {
        if ns == 0 {
            return;
        }
        const KERNEL_IPC: f64 = 1.4;
        let lic = self.cores[core].license.granted();
        let active = self.active_cores(core);
        let ghz = if self.is_e_core(core) {
            let lic = lic.max(self.module_floor(core, self.q.now()));
            self.turbo_e.as_ref().expect("E-core without E turbo table").ghz(lic, active)
        } else {
            self.turbo.ghz(lic, active)
        };
        // Kernel code on a degraded core runs at the degraded clock too.
        let ghz = if self.degrade.is_empty() {
            ghz
        } else {
            ghz * self.degrade_factor(core, self.q.now())
        };
        let cycles = ns as f64 * ghz;
        let insns = (cycles * KERNEL_IPC) as u64;
        let branches = insns / 6;
        self.cores[core].perf.record_slice(
            lic,
            false,
            cycles,
            ns,
            ghz,
            insns,
            branches,
            branches as f64 * 0.02, // kernel branches mispredict a bit more
            0.0,
        );
        // Kernel time draws active power like any other execution —
        // every nanosecond the frequency model accounts for is also
        // energy-accounted.
        let w = self.cores[core].power.active_w(lic, ghz);
        self.cores[core].perf.record_active_energy(PowerParams::energy_j(w, ns));
    }

    /// Ask `task`'s body for actions until one consumes time or blocks.
    fn drive_task(&mut self, now: Time, core: usize, task: TaskId, mut pending_ns: Time) {
        loop {
            let action = match self.pending_action[task.0].take() {
                Some(a) => a,
                None => {
                    let mut body = self.bodies[task.0].take().expect("task body missing");
                    let a = body.next(now + pending_ns, &mut self.rng);
                    self.bodies[task.0] = Some(body);
                    a
                }
            };
            match action {
                Action::Run { block, func, stack } => {
                    self.run_action(now, core, task, pending_ns, block, func, stack, 1);
                    return;
                }
                Action::RunMany { block, reps, func, stack } => {
                    self.run_action(now, core, task, pending_ns, block, func, stack, reps);
                    return;
                }
                Action::SetType(t) => {
                    pending_ns += self.sched.params.syscall_cost;
                    match self.sched.set_task_type(now + pending_ns, core, t) {
                        TypeChangeOutcome::Continue => continue,
                        TypeChangeOutcome::SuspendSelf => {
                            self.suspend_and_resched(now, core, pending_ns);
                            return;
                        }
                    }
                }
                Action::Sleep(dt) => {
                    self.sched.block_running(core);
                    self.q.schedule_in(pending_ns + dt, Event::Wake(task));
                    self.reschedule(now, core, pending_ns);
                    return;
                }
                Action::WaitChannel(ch) => {
                    let c = &mut self.channels[ch as usize];
                    if c.credits > 0 {
                        c.credits -= 1;
                        continue;
                    }
                    c.waiters.push_back(task);
                    self.sched.block_running(core);
                    self.reschedule(now, core, pending_ns);
                    return;
                }
                Action::Exit => {
                    self.sched.exit_running(core);
                    self.bodies[task.0] = None;
                    self.reschedule(now, core, pending_ns);
                    return;
                }
            }
        }
    }

    /// Execute a `Run`/`RunMany` action on `core`. `reps` is the number
    /// of repetitions of `block` still owed (≥ 1; `RunMany` semantics).
    ///
    /// Slow path (`fast_paths` off, or fault-and-migrate enabled): run
    /// exactly one repetition, stash the remainder in `pending_action`,
    /// and schedule the boundary `Step` — event-for-event the historical
    /// behaviour, with every per-boundary check (IPI flag, quantum,
    /// fault traps) happening in the event loop as before.
    ///
    /// Fast path: *steady-state slice coalescing*. Repetitions execute
    /// back to back in one machine step — per-repetition arithmetic
    /// (license `observe`, footprint EWMA, cycle/energy/PMU updates) is
    /// unchanged and runs at the same simulated timestamps, so state is
    /// bit-identical; what is elided is the event-queue round trip and
    /// task re-dispatch between identical blocks. The window closes —
    /// by scheduling the boundary `Step` and returning to the event
    /// loop, which then behaves exactly as the slow path would at that
    /// boundary — as soon as any of these could interleave:
    ///
    /// * a queued event at or before the boundary
    ///   ([`EventQueue::peek_time`] bounds the window; nothing is
    ///   *added* to the queue inside a window, so relative `(time,
    ///   seq)` order with pre-existing events is preserved),
    /// * quantum expiry (`quantum_end`) — the event loop re-checks and
    ///   either requeues or refreshes exactly as before,
    /// * the `run_until` horizon — a repetition whose dispatch Step
    ///   would never pop must not run,
    /// * the body's next action not being another run of the same
    ///   block (machine-side run-length detection: the body is asked at
    ///   the boundary time with the machine RNG, exactly as the slow
    ///   path would ask it; a non-matching action is parked in
    ///   `pending_action`, which the boundary `Step` consumes).
    ///
    /// License edges need no explicit bound: `Core::run_block` advances
    /// the license state machine per repetition, so grant completions
    /// and hold-window expiries are observed at exactly the boundaries
    /// the slow path observes them.
    /// Flame-graph attribution of one slice (no-op unless tracking).
    fn attribute_flame(&mut self, stack: u32, out: &crate::cpu::SliceOutcome) {
        if self.track_flame {
            let s = self.flame.entry(stack).or_default();
            s.cycles += out.cycles;
            s.throttle_cycles += out.throttle_cycles;
        }
    }

    /// Shared tail of one *non-coalesced* repetition: time accounting,
    /// remainder repack, and the boundary `Step`. Both slow paths
    /// (fault-and-migrate and `fast_paths` off) go through this so
    /// their bookkeeping cannot drift apart.
    #[allow(clippy::too_many_arguments)]
    fn finish_single_rep(
        &mut self,
        core: usize,
        task: TaskId,
        pending_ns: Time,
        block: Block,
        func: u64,
        stack: u32,
        reps: u32,
        out_ns: Time,
    ) {
        self.sched.entity_mut(task).cpu_ns += out_ns;
        if self.sched.entity(task).ttype == TaskType::Avx {
            self.avx_task_ns[core] += out_ns;
        }
        if reps > 1 {
            self.pending_action[task.0] = Some(pack_run(block, func, stack, reps - 1));
        }
        self.step_pending[core] = true;
        self.q.schedule_in(pending_ns + out_ns, Event::Step(core));
    }

    #[allow(clippy::too_many_arguments)]
    fn run_action(
        &mut self,
        now: Time,
        core: usize,
        task: TaskId,
        mut pending_ns: Time,
        block: Block,
        func: u64,
        stack: u32,
        reps: u32,
    ) {
        let reps = reps.max(1);

        // Confinement invariant counter: a block carrying AVX-512
        // instructions on an E-core means the scheduler's capability
        // confinement failed — on hardware this would #UD.
        if self.is_e_core(core)
            && block.mix.get(InsnClass::Avx512Light) + block.mix.get(InsnClass::Avx512Heavy) > 0
        {
            self.e_wide512_blocks += 1;
        }

        // §6.1 fault-and-migrate: an unannotated/scalar task about to
        // execute wide instructions traps, is reclassified AVX, and (if
        // on a scalar core) suspended before the block runs. Trap and
        // decay checks must see every block edge, so fault-and-migrate
        // machines never coalesce: a RunMany unrolls one repetition per
        // scheduling boundary.
        if let Some(fm) = self.fault_migrate {
            let ttype = self.sched.entity(task).ttype;
            if ttype != TaskType::Avx && block.mix.wide() > 0 {
                self.fm_faults += 1;
                pending_ns += fm.fault_cost;
                match self.sched.set_task_type(now + pending_ns, core, TaskType::Avx) {
                    TypeChangeOutcome::Continue => {}
                    TypeChangeOutcome::SuspendSelf => {
                        // All `reps` repetitions (this one included) run
                        // once the task is re-dispatched.
                        self.pending_action[task.0] = Some(pack_run(block, func, stack, reps));
                        self.suspend_and_resched(now, core, pending_ns);
                        return;
                    }
                }
            }
            self.charge_overhead(core, pending_ns);
            let active = self.active_cores(core);
            let out = if self.degrade.is_empty() {
                self.cores[core].run_block(now + pending_ns, &block, func, active, &self.turbo)
            } else {
                let row = self.degraded_p_row(core, now + pending_ns, active);
                self.cores[core].run_block_with_freqs(now + pending_ns, &block, func, &row)
            };
            self.attribute_flame(stack, &out);
            // Fault-and-migrate decay: long scalar streaks revert the
            // task so it can leave the AVX cores.
            if self.sched.entity(task).ttype == TaskType::Avx {
                if block.mix.wide() == 0 {
                    self.fm_scalar_streak[task.0] += out.ns;
                    if self.fm_scalar_streak[task.0] >= fm.decay {
                        self.fm_scalar_streak[task.0] = 0;
                        let outcome = self.sched.set_task_type(
                            now + pending_ns + out.ns,
                            core,
                            TaskType::Scalar,
                        );
                        if outcome == TypeChangeOutcome::SuspendSelf {
                            // Migrate the reverted task off the AVX core
                            // at the upcoming block boundary so queued
                            // AVX work gets the core (same path as an
                            // IPI).
                            self.need_resched[core] = 1;
                        }
                    }
                } else {
                    self.fm_scalar_streak[task.0] = 0;
                }
            }
            self.finish_single_rep(core, task, pending_ns, block, func, stack, reps, out.ns);
            return;
        }

        // Syscall overhead preceding the first repetition retires as
        // kernel instructions on this core.
        self.charge_overhead(core, pending_ns);
        let active = self.active_cores(core);

        let e_core = self.is_e_core(core);

        if !self.fast_paths {
            // Baseline: one repetition per scheduling boundary.
            let t0 = now + pending_ns;
            let out = if e_core {
                let mut freqs = self.e_core_freqs(core, t0, active);
                self.apply_degrade(core, t0, &mut freqs);
                let out = self.cores[core].run_block_with_freqs(t0, &block, func, &freqs);
                self.stamp_module_floor(core, t0 + out.ns);
                out
            } else if self.degrade.is_empty() {
                self.cores[core].run_block(t0, &block, func, active, &self.turbo)
            } else {
                let row = self.degraded_p_row(core, t0, active);
                self.cores[core].run_block_with_freqs(t0, &block, func, &row)
            };
            self.attribute_flame(stack, &out);
            self.finish_single_rep(core, task, pending_ns, block, func, stack, reps, out.ns);
            return;
        }

        // Fast path: coalesced window. The active-core count is
        // constant inside the window (no reschedules, no wakes), so the
        // per-license turbo lookups hoist out of the loop. E-cores
        // re-derive their row per repetition instead: the module floor
        // is sampled at every block boundary (and stamped at every
        // block end) exactly as the slow path does, so the two paths
        // cannot drift — only the event-queue round trip is elided.
        let freqs = [
            self.turbo.ghz(License::L0, active),
            self.turbo.ghz(License::L1, active),
            self.turbo.ghz(License::L2, active),
        ];
        // Task type is constant inside the window (no SetType, no
        // fault-and-migrate), so integer time bookkeeping accumulates
        // locally and lands in one exact add per counter.
        let is_avx = self.sched.entity(task).ttype == TaskType::Avx;
        let mut stack = stack;
        let mut reps_left = reps;
        let mut total_ns: Time = 0;
        let mut first = true;
        loop {
            let t = now + pending_ns + total_ns;
            let out = if e_core {
                let mut row = self.e_core_freqs(core, t, active);
                self.apply_degrade(core, t, &mut row);
                let out = self.cores[core].run_block_with_freqs(t, &block, func, &row);
                self.stamp_module_floor(core, t + out.ns);
                out
            } else if self.degrade.is_empty() {
                self.cores[core].run_block_with_freqs(t, &block, func, &freqs)
            } else {
                // Degradation windows make the P-core row time-dependent:
                // re-derive per repetition at the rep's start time — the
                // E-core pattern — so the hoisted row can't straddle a
                // window edge and drift from the slow path.
                let row = self.degraded_p_row(core, t, active);
                self.cores[core].run_block_with_freqs(t, &block, func, &row)
            };
            self.attribute_flame(stack, &out);
            total_ns += out.ns;
            reps_left -= 1;
            if !first {
                self.coalesced_reps += 1;
            }
            first = false;

            let boundary = now + pending_ns + total_ns;
            let queue_clear = match self.q.peek_time() {
                None => true,
                Some(pt) => pt > boundary,
            };
            if !queue_clear
                || boundary >= self.quantum_end[core]
                || boundary > self.horizon
            {
                break;
            }
            if reps_left == 0 {
                // Run-length detection: fetch the body's next action at
                // the boundary, exactly as the event loop would.
                let mut body = self.bodies[task.0].take().expect("task body missing");
                let a = body.next(boundary, &mut self.rng);
                self.bodies[task.0] = Some(body);
                match a {
                    Action::Run { block: b, func: f, stack: s } if f == func && b == block => {
                        stack = s;
                        reps_left = 1;
                    }
                    Action::RunMany { block: b, reps: r, func: f, stack: s }
                        if f == func && b == block =>
                    {
                        stack = s;
                        reps_left = r.max(1);
                    }
                    other => {
                        self.pending_action[task.0] = Some(other);
                        break;
                    }
                }
            }
        }
        self.sched.entity_mut(task).cpu_ns += total_ns;
        if is_avx {
            self.avx_task_ns[core] += total_ns;
        }
        if reps_left > 0 {
            self.pending_action[task.0] = Some(pack_run(block, func, stack, reps_left));
        }
        self.step_pending[core] = true;
        self.q.schedule_in(pending_ns + total_ns, Event::Step(core));
    }

    /// Requeue the core's current task and fan out its wake target.
    fn requeue_current(&mut self, now: Time, core: usize, refresh: bool) {
        let Machine { sched, step_pending, .. } = self;
        let target = sched.requeue_running(now, core, refresh, &|c| step_pending[c]);
        if let Some(target) = target {
            self.handle_wake_target(target);
        }
    }

    /// Requeue the running task (type-change suspension) and reschedule.
    fn suspend_and_resched(&mut self, now: Time, core: usize, pending_ns: Time) {
        self.requeue_current(now, core, false);
        self.reschedule(now, core, pending_ns);
    }

    /// Pick the next task for `core` (or go idle). A migrating dispatch
    /// charges `migration_cost`, plus `cross_socket_migration_cost` when
    /// the task came from another NUMA node.
    fn reschedule(&mut self, now: Time, core: usize, extra_ns: Time) {
        let was_busy = matches!(self.run[core], CoreRun::Busy { .. });
        let mut cost = extra_ns + self.sched.params.resched_cost;
        let migrations_before = self.sched.stats.migrations;
        let xsocket_before = self.sched.stats.cross_socket_migrations;
        match self.sched.pick(now, core) {
            Some(task) => {
                if self.sched.stats.migrations > migrations_before {
                    cost += self.sched.params.migration_cost;
                }
                if self.sched.stats.cross_socket_migrations > xsocket_before {
                    cost += self.sched.params.cross_socket_migration_cost;
                }
                self.charge_overhead(core, cost);
                if !was_busy {
                    self.busy_per_domain[self.domain_of[core]] += 1;
                }
                self.run[core] = CoreRun::Busy { task };
                self.quantum_end[core] = now + cost + self.sched.params.rr_interval;
                self.step_pending[core] = true;
                self.q.schedule_in(cost, Event::Step(core));
            }
            None => {
                if was_busy {
                    self.busy_per_domain[self.domain_of[core]] -= 1;
                }
                self.run[core] = CoreRun::Idle { since: now + cost };
            }
        }
    }

    /// Zero all measurement state (called after warmup).
    pub fn reset_metrics(&mut self) {
        for c in &mut self.cores {
            c.perf = Default::default();
        }
        self.sched.stats = Default::default();
        self.flame.clear();
        self.coalesced_reps = 0;
        self.fm_faults = 0;
    }

    /// Merge all cores' counters (for run-level reporting).
    pub fn total_perf(&self) -> crate::cpu::PerfCounters {
        let mut total = crate::cpu::PerfCounters::default();
        for c in &self.cores {
            total.merge(&c.perf);
        }
        total
    }

    /// Checkpoint-fork the machine: a deep copy whose continuation is
    /// bit-identical to continuing the original (same event `(time,
    /// seq)` order, same RNG stream, same scheduler decisions).
    ///
    /// All machine-owned state clones directly — cores, scheduler,
    /// RNG, event queue (with its seq counter and calendar buckets),
    /// channels, per-core bookkeeping, counters. Task bodies are the
    /// one part the machine cannot clone itself (trait objects holding
    /// workload `Rc`s), so each live body is asked to
    /// [`TaskBody::fork`] through the shared `ctx`; any body that
    /// declines makes the whole fork decline (`None`), and the caller
    /// must fall back to a cold run. Exited tasks (body slot `None`)
    /// stay exited.
    pub fn try_fork(&self, ctx: &mut ForkCtx) -> Option<Machine> {
        let mut bodies = Vec::with_capacity(self.bodies.len());
        for slot in &self.bodies {
            match slot {
                None => bodies.push(None),
                Some(body) => bodies.push(Some(body.fork(ctx)?)),
            }
        }
        Some(Machine {
            cores: self.cores.clone(),
            sched: self.sched.clone(),
            rng: self.rng.clone(),
            turbo: self.turbo.clone(),
            bodies,
            pending_action: self.pending_action.clone(),
            fm_scalar_streak: self.fm_scalar_streak.clone(),
            run: self.run.clone(),
            step_pending: self.step_pending.clone(),
            quantum_end: self.quantum_end.clone(),
            need_resched: self.need_resched.clone(),
            q: self.q.clone(),
            channels: self.channels.clone(),
            socket_of: self.socket_of.clone(),
            domain_of: self.domain_of.clone(),
            n_sockets: self.n_sockets,
            hybrid: self.hybrid,
            turbo_e: self.turbo_e.clone(),
            module_l1_until: self.module_l1_until.clone(),
            busy_per_domain: self.busy_per_domain.clone(),
            extra_per_domain: self.extra_per_domain.clone(),
            track_flame: self.track_flame,
            fault_migrate: self.fault_migrate,
            fast_paths: self.fast_paths,
            degrade: self.degrade.clone(),
            horizon: self.horizon,
            flame: self.flame.clone(),
            coalesced_reps: self.coalesced_reps,
            fm_faults: self.fm_faults,
            avx_task_ns: self.avx_task_ns.clone(),
            e_wide512_blocks: self.e_wide512_blocks,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::block::{ClassMix, InsnClass};
    use crate::sim::{MS, SEC};
    use std::cell::RefCell;
    use std::rc::Rc;

    /// Body that runs `n` scalar blocks then exits.
    struct ScalarLoop {
        remaining: u64,
        done: Rc<RefCell<u64>>,
    }
    impl TaskBody for ScalarLoop {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            if self.remaining == 0 {
                *self.done.borrow_mut() += 1;
                return Action::Exit;
            }
            self.remaining -= 1;
            Action::Run {
                block: Block { mix: ClassMix::scalar(10_000), mem_ops: 100, branches: 200, license_exempt: false },
                func: 1,
                stack: 0,
            }
        }
    }

    fn small_machine(policy: PolicyKind, cores: usize) -> Machine {
        let mut p = MachineParams::new(cores, policy);
        p.turbo = TurboTable::flat(2.8, 2.4, 1.9, cores);
        Machine::new(p)
    }

    #[test]
    fn tasks_run_to_completion() {
        let mut m = small_machine(PolicyKind::Unmodified, 2);
        let done = Rc::new(RefCell::new(0u64));
        for _ in 0..4 {
            m.spawn(
                TaskType::Untyped,
                0,
                Box::new(ScalarLoop { remaining: 50, done: done.clone() }),
            );
        }
        m.run_until(SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 4);
        let perf = m.total_perf();
        // Workload instructions plus a little accounted kernel overhead.
        let submitted = 4 * 50 * 10_000;
        assert!(perf.instructions >= submitted);
        assert!(perf.instructions < submitted + submitted / 50, "{}", perf.instructions);
    }

    #[test]
    fn oversubscription_time_shares() {
        // 4 tasks, 1 core: all must finish; busy time ≈ serial sum.
        let mut m = small_machine(PolicyKind::Unmodified, 1);
        let done = Rc::new(RefCell::new(0u64));
        for _ in 0..4 {
            m.spawn(
                TaskType::Untyped,
                0,
                Box::new(ScalarLoop { remaining: 100, done: done.clone() }),
            );
        }
        m.run_until(10 * SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 4);
    }

    #[test]
    fn degradation_scales_execution_and_inert_windows_change_nothing() {
        use crate::faults::DegradeScope;
        let run = |degrade: Vec<DegradeWindow>| {
            let mut p = MachineParams::new(1, PolicyKind::Unmodified);
            p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 1);
            p.degrade = degrade;
            let mut m = Machine::new(p);
            let done = Rc::new(RefCell::new(0u64));
            m.spawn(
                TaskType::Untyped,
                0,
                Box::new(ScalarLoop { remaining: 300, done: done.clone() }),
            );
            m.run_until(SEC, &mut NullDriver);
            assert_eq!(*done.borrow(), 1);
            m.now()
        };
        let clean = run(Vec::new());
        let window = |start, end, scale, scope| DegradeWindow { start, end, scale, scope };
        // A scale-1.0 window forces the with-freqs path but must be
        // bit-inert (the pinned run_block ≡ run_block_with_freqs
        // equivalence) — the in-module form of the faults-disabled
        // differential.
        let unit = run(vec![window(0, SEC, 1.0, DegradeScope::Machine)]);
        assert_eq!(clean, unit, "scale-1.0 window must be inert");
        // Out-of-window and out-of-scope windows are equally inert.
        let past = run(vec![window(SEC, 2 * SEC, 0.5, DegradeScope::Machine)]);
        assert_eq!(clean, past, "window past the work must be inert");
        let other = run(vec![window(0, SEC, 0.5, DegradeScope::Core(7))]);
        assert_eq!(clean, other, "window scoped to another core must be inert");
        // A real degradation halves the clock, so the same work
        // finishes strictly later.
        let slow = run(vec![window(0, SEC, 0.5, DegradeScope::Machine)]);
        assert!(slow > clean, "degraded run must finish later: {slow} vs {clean}");
    }

    /// Body alternating scalar work and AVX work wrapped in SetType.
    struct AnnotatedAvx {
        iters: u64,
        done: Rc<RefCell<u64>>,
    }
    impl TaskBody for AnnotatedAvx {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            if self.iters == 0 {
                *self.done.borrow_mut() += 1;
                return Action::Exit;
            }
            self.iters -= 1;
            match self.iters % 4 {
                3 => Action::SetType(TaskType::Avx),
                2 => Action::Run {
                    block: Block {
                        mix: ClassMix::of(InsnClass::Avx512Heavy, 20_000),
                        mem_ops: 100,
                        branches: 50, license_exempt: false,
                    },
                    func: 7,
                    stack: 1,
                },
                1 => Action::SetType(TaskType::Scalar),
                _ => Action::Run {
                    block: Block { mix: ClassMix::scalar(20_000), mem_ops: 100, branches: 300, license_exempt: false },
                    func: 3,
                    stack: 2,
                },
            }
        }
    }

    #[test]
    fn corespec_confines_avx_to_avx_cores() {
        let mut m = small_machine(PolicyKind::CoreSpec { avx_cores: 1 }, 4);
        let done = Rc::new(RefCell::new(0u64));
        for _ in 0..6 {
            m.spawn(
                TaskType::Scalar,
                0,
                Box::new(AnnotatedAvx { iters: 400, done: done.clone() }),
            );
        }
        m.run_until(20 * SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 6, "all tasks finish");
        // Scalar cores (0..3) must never see AVX-license cycles.
        for c in 0..3 {
            assert_eq!(
                m.cores[c].perf.license_cycles[2], 0,
                "scalar core {c} executed AVX-512 license cycles"
            );
            assert_eq!(m.cores[c].perf.license_requests, 0);
        }
        // The AVX core must have done the AVX work.
        assert!(m.cores[3].perf.license_cycles[2] > 0, "AVX core ran the AVX work");
        assert!(m.sched.stats.type_changes > 0);
        assert!(m.sched.stats.migrations > 0, "threads must migrate");
    }

    #[test]
    fn unmodified_spreads_avx_everywhere() {
        let mut m = small_machine(PolicyKind::Unmodified, 4);
        let done = Rc::new(RefCell::new(0u64));
        for _ in 0..6 {
            m.spawn(
                TaskType::Scalar,
                0,
                Box::new(AnnotatedAvx { iters: 400, done: done.clone() }),
            );
        }
        m.run_until(20 * SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 6);
        let polluted =
            (0..4).filter(|&c| m.cores[c].perf.license_cycles[2] > 0).count();
        assert!(polluted >= 3, "unmodified scheduler lets AVX hit most cores, got {polluted}");
    }

    #[test]
    fn channels_deliver_work() {
        struct Worker {
            ch: u32,
            served: Rc<RefCell<u64>>,
        }
        impl TaskBody for Worker {
            fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
                if *self.served.borrow() >= 10 {
                    return Action::Exit;
                }
                *self.served.borrow_mut() += 1;
                Action::WaitChannel(self.ch)
            }
        }
        struct Arrivals {
            ch: u32,
        }
        impl Driver for Arrivals {
            fn on_external(&mut self, _tag: u64, m: &mut Machine) {
                m.notify(self.ch);
            }
        }
        let mut m = small_machine(PolicyKind::Unmodified, 1);
        let ch = m.channel();
        let served = Rc::new(RefCell::new(0u64));
        m.spawn(TaskType::Untyped, 0, Box::new(Worker { ch, served: served.clone() }));
        for i in 0..12 {
            m.schedule_external(i * MS, 1);
        }
        let mut d = Arrivals { ch };
        m.run_until(SEC, &mut d);
        assert_eq!(*served.borrow(), 10);
    }

    #[test]
    fn per_socket_frequency_domains() {
        // 8 cores over 2 sockets with an active-core-sensitive turbo
        // table. Six equal tasks land on cores 0..5 (4 on socket 0, 2 on
        // socket 1), so socket 1's cores run at a higher turbo bin than
        // socket 0's — on a single package they would all share one bin.
        let mut p = MachineParams::new(8, PolicyKind::Unmodified);
        p.sockets = 2;
        let mut m = Machine::new(p);
        assert_eq!(m.n_sockets(), 2);
        assert_eq!(m.socket_of(3), 0);
        assert_eq!(m.socket_of(4), 1);
        let done = Rc::new(RefCell::new(0u64));
        for _ in 0..6 {
            m.spawn(
                TaskType::Untyped,
                0,
                Box::new(ScalarLoop { remaining: 200, done: done.clone() }),
            );
        }
        m.run_until(SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 6);
        // Xeon 6130 L0 bins: 4 active → 3.5 GHz, 2 active → 3.7 GHz.
        let s0 = m.cores[0].perf.avg_busy_ghz();
        let s1 = m.cores[4].perf.avg_busy_ghz();
        assert!(
            s1 > s0 + 0.1,
            "socket 1 (2 active) must turbo above socket 0 (4 active): {s1} vs {s0}"
        );
    }

    #[test]
    fn cross_socket_migration_charged_and_counted() {
        // One core per socket, so any migration is cross-socket. Core 0
        // is oversubscribed (two long tasks cycling on the 6 ms quantum);
        // core 1 runs one shorter task and, once it exits, steals a task
        // that already ran on core 0 — a cross-socket migration.
        let mut p = MachineParams::new(2, PolicyKind::Unmodified);
        p.sockets = 2;
        p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 2);
        let mut m = Machine::new(p);
        let done = Rc::new(RefCell::new(0u64));
        for remaining in [20_000u64, 6_000, 20_000] {
            // Tasks 0 and 2 wake onto core 0, task 1 onto core 1.
            m.spawn(
                TaskType::Untyped,
                0,
                Box::new(ScalarLoop { remaining, done: done.clone() }),
            );
        }
        m.run_until(10 * SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 3);
        let s = &m.sched.stats;
        assert!(s.migrations > 0, "core 1 must steal from the oversubscribed socket");
        assert_eq!(
            s.cross_socket_migrations, s.migrations,
            "with one core per socket every migration crosses sockets"
        );
    }

    #[test]
    fn single_socket_has_no_cross_socket_migrations() {
        let mut m = small_machine(PolicyKind::CoreSpec { avx_cores: 1 }, 4);
        let done = Rc::new(RefCell::new(0u64));
        for _ in 0..6 {
            m.spawn(
                TaskType::Scalar,
                0,
                Box::new(AnnotatedAvx { iters: 200, done: done.clone() }),
            );
        }
        m.run_until(20 * SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 6);
        assert!(m.sched.stats.migrations > 0);
        assert_eq!(m.sched.stats.cross_socket_migrations, 0);
    }

    /// Body alternating compute and sleep — the sleeps create idle
    /// periods that end in a wake, so the core's idle time is observed
    /// (idle accounting happens at the Step event that ends the gap).
    struct SleepyLoop {
        remaining: u64,
        sleeping: bool,
        done: Rc<RefCell<u64>>,
    }
    impl TaskBody for SleepyLoop {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            if self.remaining == 0 {
                *self.done.borrow_mut() += 1;
                return Action::Exit;
            }
            self.sleeping = !self.sleeping;
            if self.sleeping {
                Action::Sleep(MS)
            } else {
                self.remaining -= 1;
                Action::Run {
                    block: Block { mix: ClassMix::scalar(10_000), mem_ops: 100, branches: 200, license_exempt: false },
                    func: 1,
                    stack: 0,
                }
            }
        }
    }

    #[test]
    fn energy_accounted_for_busy_and_idle_time() {
        let mut m = small_machine(PolicyKind::Unmodified, 1);
        let done = Rc::new(RefCell::new(0u64));
        m.spawn(
            TaskType::Untyped,
            0,
            Box::new(SleepyLoop { remaining: 50, sleeping: true, done: done.clone() }),
        );
        m.run_until(SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 1);
        let total = m.total_perf();
        assert!(total.active_energy_j > 0.0, "busy time must draw active power");
        assert!(total.idle_energy_j > 0.0, "sleep gaps must draw idle power");
        // The split matches the accounted time × the model's rates:
        // active power ≥ idle power over busy time, idle energy exactly
        // idle_w × accounted idle time.
        let p = PowerParams::default();
        let idle_expected = p.idle_w * total.idle_ns as f64 * 1e-9;
        assert!((total.idle_energy_j - idle_expected).abs() < 1e-9);
        let floor = p.idle_w * total.busy_ns as f64 * 1e-9;
        assert!(total.active_energy_j > floor, "{} vs {}", total.active_energy_j, floor);
    }

    /// Body oscillating across the hold window: one AVX burst, then
    /// ~2.7 ms of scalar blocks (long enough for the stock 2 ms timer
    /// to relax, short enough that a widened timer does not).
    struct OscillatingAvx {
        cycles: u64,
        step: u64,
        done: Rc<RefCell<u64>>,
    }
    impl TaskBody for OscillatingAvx {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            const SCALAR_STEPS: u64 = 170; // ≈2.7 ms of 100k-insn blocks
            if self.cycles == 0 {
                *self.done.borrow_mut() += 1;
                return Action::Exit;
            }
            let step = self.step;
            self.step += 1;
            if self.step > SCALAR_STEPS {
                self.step = 0;
                self.cycles -= 1;
            }
            if step == 0 {
                Action::Run {
                    block: Block {
                        mix: ClassMix::of(InsnClass::Avx512Heavy, 30_000),
                        mem_ops: 0,
                        branches: 100,
                        license_exempt: false,
                    },
                    func: 7,
                    stack: 0,
                }
            } else {
                Action::Run {
                    block: Block {
                        mix: ClassMix::scalar(100_000),
                        mem_ops: 0,
                        branches: 500,
                        license_exempt: false,
                    },
                    func: 3,
                    stack: 0,
                }
            }
        }
    }

    #[test]
    fn governor_selectable_per_machine() {
        let run = |gov: crate::cpu::GovernorSpec| {
            let mut p = MachineParams::new(1, PolicyKind::Unmodified);
            p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 1);
            p.freq.governor = gov;
            let mut m = Machine::new(p);
            let done = Rc::new(RefCell::new(0u64));
            m.spawn(
                TaskType::Untyped,
                0,
                Box::new(OscillatingAvx { cycles: 30, step: 0, done: done.clone() }),
            );
            m.run_until(20 * SEC, &mut NullDriver);
            assert_eq!(*done.borrow(), 1);
            m.total_perf().freq_switches
        };
        let legacy = run(crate::cpu::GovernorSpec::IntelLegacy);
        let dim = run(crate::cpu::GovernorSpec::DimSilicon);
        assert!(legacy >= 30, "legacy must oscillate every cycle, got {legacy}");
        assert!(
            dim * 2 < legacy,
            "dim-silicon widens the AVX timer under churn, so it must switch far less: \
             {dim} vs {legacy}"
        );
    }

    /// Fingerprint of everything a run can observably produce, with the
    /// float accumulators compared by bit pattern.
    fn fingerprint(m: &Machine) -> (u64, u64, u64, u64, u64, u64, u64, u64, u64) {
        let p = m.total_perf();
        (
            p.instructions,
            p.cycles,
            p.busy_ns,
            p.idle_ns,
            p.freq_integral.to_bits(),
            p.active_energy_j.to_bits(),
            p.idle_energy_j.to_bits(),
            m.sched.stats.migrations,
            m.sched.stats.type_changes,
        )
    }

    #[test]
    fn fast_paths_bit_identical_to_slow_paths() {
        // The same mixed AVX/scalar workload (annotations, migrations,
        // quantum churn from oversubscription) with the fast paths on
        // and off must produce bit-identical counters and stats.
        let run = |fast: bool| {
            let mut p = MachineParams::new(2, PolicyKind::CoreSpec { avx_cores: 1 });
            p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 2);
            p.fast_paths = fast;
            let mut m = Machine::new(p);
            let done = Rc::new(RefCell::new(0u64));
            for _ in 0..5 {
                m.spawn(
                    TaskType::Scalar,
                    0,
                    Box::new(AnnotatedAvx { iters: 300, done: done.clone() }),
                );
            }
            m.run_until(20 * SEC, &mut NullDriver);
            assert_eq!(*done.borrow(), 5);
            fingerprint(&m)
        };
        assert_eq!(run(true), run(false));
    }

    /// Forkable variant of [`ScalarLoop`]: rewires its shared counter
    /// through the [`ForkCtx`] so both tasks land on one cloned cell.
    struct ForkableLoop {
        remaining: u64,
        done: Rc<RefCell<u64>>,
    }
    impl TaskBody for ForkableLoop {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            if self.remaining == 0 {
                *self.done.borrow_mut() += 1;
                return Action::Exit;
            }
            self.remaining -= 1;
            if self.remaining % 7 == 0 {
                return Action::Sleep(5_000);
            }
            Action::Run {
                block: Block { mix: ClassMix::scalar(10_000), mem_ops: 100, branches: 200, license_exempt: false },
                func: 1,
                stack: 0,
            }
        }
        fn fork(&self, ctx: &mut ForkCtx) -> Option<Box<dyn TaskBody>> {
            Some(Box::new(ForkableLoop {
                remaining: self.remaining,
                done: ctx.fork_rc(&self.done),
            }))
        }
    }

    #[test]
    fn try_fork_declines_when_a_body_cannot_fork() {
        // `ScalarLoop` keeps the default `fork` (None): the machine must
        // refuse to fork rather than produce a half-wired copy.
        let mut m = small_machine(PolicyKind::Unmodified, 2);
        let done = Rc::new(RefCell::new(0u64));
        m.spawn(TaskType::Untyped, 0, Box::new(ScalarLoop { remaining: 50, done }));
        m.run_until(MS, &mut NullDriver);
        assert!(m.try_fork(&mut ForkCtx::new()).is_none());
    }

    #[test]
    fn forked_machine_continues_bit_identically_and_independently() {
        // Warm a machine to an arbitrary mid-run point, fork it, then run
        // both to the same horizon: identical fingerprints and identical
        // shared-counter values, on *separate* allocations (mutating one
        // side's outcome must not leak into the other).
        let mut m = small_machine(PolicyKind::Unmodified, 2);
        let done = Rc::new(RefCell::new(0u64));
        for _ in 0..4 {
            m.spawn(
                TaskType::Untyped,
                0,
                Box::new(ForkableLoop { remaining: 2_000, done: done.clone() }),
            );
        }
        m.run_until(2 * MS, &mut NullDriver);

        let mut ctx = ForkCtx::new();
        let mut f = m.try_fork(&mut ctx).expect("all bodies forkable");
        // All four bodies share one counter; the fork must too.
        let forked_done = ctx.fork_rc(&done);
        assert_eq!(*forked_done.borrow(), *done.borrow());

        m.run_until(SEC, &mut NullDriver);
        f.run_until(SEC, &mut NullDriver);
        assert_eq!(fingerprint(&m), fingerprint(&f));
        assert_eq!(*done.borrow(), 4);
        assert_eq!(*forked_done.borrow(), 4);
        // Independence: the two counters are distinct allocations.
        *forked_done.borrow_mut() += 1;
        assert_eq!(*done.borrow(), 4);
    }

    /// Body emitting one `RunMany` batch then exiting.
    struct BatchedLoop {
        reps: u32,
        emitted: bool,
        done: Rc<RefCell<u64>>,
    }
    impl TaskBody for BatchedLoop {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            if self.emitted {
                *self.done.borrow_mut() += 1;
                return Action::Exit;
            }
            self.emitted = true;
            Action::RunMany {
                block: Block { mix: ClassMix::scalar(10_000), mem_ops: 100, branches: 200, license_exempt: false },
                reps: self.reps,
                func: 1,
                stack: 0,
            }
        }
    }

    #[test]
    fn run_many_equivalent_to_repeated_runs() {
        // `RunMany { reps }` ≡ `reps` consecutive `Run`s, with the fast
        // paths on and off: four runs, one fingerprint. Oversubscribed
        // (4 tasks, 1 core) so quantum expiry slices the batches.
        let run = |batched: bool, fast: bool| {
            let mut p = MachineParams::new(1, PolicyKind::Unmodified);
            p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 1);
            p.fast_paths = fast;
            let mut m = Machine::new(p);
            let done = Rc::new(RefCell::new(0u64));
            for _ in 0..4 {
                if batched {
                    m.spawn(
                        TaskType::Untyped,
                        0,
                        Box::new(BatchedLoop { reps: 100, emitted: false, done: done.clone() }),
                    );
                } else {
                    m.spawn(
                        TaskType::Untyped,
                        0,
                        Box::new(ScalarLoop { remaining: 100, done: done.clone() }),
                    );
                }
            }
            m.run_until(10 * SEC, &mut NullDriver);
            assert_eq!(*done.borrow(), 4);
            fingerprint(&m)
        };
        let base = run(false, false);
        assert_eq!(run(false, true), base, "fast Run path drifted");
        assert_eq!(run(true, false), base, "slow RunMany unrolling drifted");
        assert_eq!(run(true, true), base, "coalesced RunMany drifted");
    }

    #[test]
    fn coalescing_engages_on_steady_batches() {
        let mut p = MachineParams::new(1, PolicyKind::Unmodified);
        p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 1);
        let mut m = Machine::new(p);
        let done = Rc::new(RefCell::new(0u64));
        m.spawn(
            TaskType::Untyped,
            0,
            Box::new(BatchedLoop { reps: 200, emitted: false, done: done.clone() }),
        );
        m.run_until(SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 1);
        assert!(
            m.coalesced_reps > 100,
            "a lone steady batch must coalesce almost entirely, got {}",
            m.coalesced_reps
        );
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let run = || {
            let mut m = small_machine(PolicyKind::CoreSpec { avx_cores: 1 }, 4);
            let done = Rc::new(RefCell::new(0u64));
            for _ in 0..5 {
                m.spawn(
                    TaskType::Scalar,
                    0,
                    Box::new(AnnotatedAvx { iters: 100, done: done.clone() }),
                );
            }
            m.run_until(5 * SEC, &mut NullDriver);
            let p = m.total_perf();
            (p.instructions, p.cycles, p.busy_ns, m.sched.stats.migrations)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn fault_migrate_reclassifies_unannotated_tasks() {
        struct Unannotated {
            iters: u64,
        }
        impl TaskBody for Unannotated {
            fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
                if self.iters == 0 {
                    return Action::Exit;
                }
                self.iters -= 1;
                if self.iters % 8 == 0 {
                    Action::Run {
                        block: Block {
                            mix: ClassMix::of(InsnClass::Avx512Heavy, 20_000),
                            mem_ops: 0,
                            branches: 50, license_exempt: false,
                        },
                        func: 7,
                        stack: 0,
                    }
                } else {
                    Action::Run {
                        block: Block { mix: ClassMix::scalar(20_000), mem_ops: 0, branches: 300, license_exempt: false },
                        func: 3,
                        stack: 0,
                    }
                }
            }
        }
        let mut p = MachineParams::new(4, PolicyKind::CoreSpec { avx_cores: 1 });
        p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 4);
        p.fault_migrate = Some(FaultMigrateParams::default());
        let mut m = Machine::new(p);
        for _ in 0..4 {
            m.spawn(TaskType::Scalar, 0, Box::new(Unannotated { iters: 200 }));
        }
        m.run_until(20 * SEC, &mut NullDriver);
        assert!(m.fm_faults > 0, "wide blocks must fault");
        for c in 0..3 {
            assert_eq!(
                m.cores[c].perf.license_cycles[2], 0,
                "fault-and-migrate must keep AVX off scalar core {c}"
            );
        }
    }

    /// 2P+4E (modules of 2) hybrid machine.
    fn hybrid_machine(policy: PolicyKind) -> Machine {
        let spec = crate::cpu::HybridSpec::new(2, 4, 2).unwrap();
        let mut p = MachineParams::new(spec.n_cores(), policy);
        p.turbo = TurboTable::flat(2.8, 2.4, 1.9, spec.n_cores());
        p.hybrid = Some(spec);
        Machine::new(p)
    }

    #[test]
    fn hybrid_avx512_confined_to_p_cores() {
        // Annotated AVX-512 work on a hybrid machine, under both a
        // specializing policy and the stock one: no 512-bit block may
        // ever execute on an E-core.
        for policy in [PolicyKind::CoreSpec { avx_cores: 2 }, PolicyKind::Unmodified] {
            let mut m = hybrid_machine(policy.clone());
            let done = Rc::new(RefCell::new(0u64));
            for _ in 0..8 {
                m.spawn(
                    TaskType::Scalar,
                    0,
                    Box::new(AnnotatedAvx { iters: 200, done: done.clone() }),
                );
            }
            m.run_until(20 * SEC, &mut NullDriver);
            assert_eq!(*done.borrow(), 8, "{policy:?}");
            assert_eq!(m.e_wide512_blocks, 0, "{policy:?}: AVX-512 block ran on an E-core");
            assert!(
                (0..2).any(|c| m.cores[c].perf.license_cycles[2] > 0),
                "{policy:?}: the P-cores must have carried the AVX-512 work"
            );
        }
    }

    #[test]
    fn all_p_hybrid_is_byte_identical_to_homogeneous() {
        // A hybrid spec with zero E-cores must not perturb anything:
        // same domains, same scheduler, same bytes.
        let run = |hybrid: bool| {
            let mut p = MachineParams::new(4, PolicyKind::CoreSpec { avx_cores: 1 });
            p.turbo = TurboTable::flat(2.8, 2.4, 1.9, 4);
            if hybrid {
                p.hybrid = Some(crate::cpu::HybridSpec::new(4, 0, 0).unwrap());
            }
            let mut m = Machine::new(p);
            let done = Rc::new(RefCell::new(0u64));
            for _ in 0..5 {
                m.spawn(
                    TaskType::Scalar,
                    0,
                    Box::new(AnnotatedAvx { iters: 300, done: done.clone() }),
                );
            }
            m.run_until(20 * SEC, &mut NullDriver);
            assert_eq!(*done.borrow(), 5);
            fingerprint(&m)
        };
        assert_eq!(run(true), run(false));
    }

    /// Untyped body alternating heavy-AVX2 bursts (L1 demand — legal on
    /// E-cores) and scalar stretches: exercises the license ceiling and
    /// the per-module floor stamping/expiry on E-cores.
    struct Avx2Churn {
        iters: u64,
        done: Rc<RefCell<u64>>,
    }
    impl TaskBody for Avx2Churn {
        fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
            if self.iters == 0 {
                *self.done.borrow_mut() += 1;
                return Action::Exit;
            }
            self.iters -= 1;
            if self.iters % 5 == 0 {
                Action::Run {
                    block: Block {
                        mix: ClassMix::of(InsnClass::Avx2Heavy, 30_000),
                        mem_ops: 100,
                        branches: 100,
                        license_exempt: false,
                    },
                    func: 9,
                    stack: 0,
                }
            } else {
                Action::RunMany {
                    block: Block {
                        mix: ClassMix::scalar(25_000),
                        mem_ops: 100,
                        branches: 300,
                        license_exempt: false,
                    },
                    reps: 8,
                    func: 3,
                    stack: 0,
                }
            }
        }
    }

    #[test]
    fn hybrid_fast_paths_bit_identical_to_slow_paths() {
        // Module-floor sampling happens per block boundary in both
        // paths, so coalescing on E-cores must not drift.
        let run = |fast: bool| {
            let spec = crate::cpu::HybridSpec::new(2, 4, 2).unwrap();
            let mut p = MachineParams::new(spec.n_cores(), PolicyKind::CoreSpec { avx_cores: 2 });
            p.turbo = TurboTable::flat(2.8, 2.4, 1.9, spec.n_cores());
            p.hybrid = Some(spec);
            p.fast_paths = fast;
            let mut m = Machine::new(p);
            let done = Rc::new(RefCell::new(0u64));
            for i in 0..10 {
                if i % 2 == 0 {
                    m.spawn(
                        TaskType::Scalar,
                        0,
                        Box::new(AnnotatedAvx { iters: 200, done: done.clone() }),
                    );
                } else {
                    m.spawn(
                        TaskType::Untyped,
                        0,
                        Box::new(Avx2Churn { iters: 200, done: done.clone() }),
                    );
                }
            }
            m.run_until(30 * SEC, &mut NullDriver);
            assert_eq!(*done.borrow(), 10);
            assert_eq!(m.e_wide512_blocks, 0);
            fingerprint(&m)
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn e_core_module_floor_holds_the_shared_clock_down() {
        // One E-core module of two cores: core 2 hammers AVX2-heavy
        // blocks (L1), core 3 runs scalar work in the same module — the
        // shared PLL must drag core 3's busy frequency below what the
        // scalar-only E-core in the *other* module achieves.
        let mut m = hybrid_machine(PolicyKind::Unmodified);
        let done = Rc::new(RefCell::new(0u64));
        // Fill all six cores so placement is stable (spawn i lands on
        // core i: each wake dispatches to the first unreserved idle
        // core in ascending order).
        let heavy = |done: &Rc<RefCell<u64>>| {
            Box::new(Avx2Churn { iters: 400, done: done.clone() }) as Box<dyn TaskBody>
        };
        let scalar = |done: &Rc<RefCell<u64>>| {
            Box::new(ScalarLoop { remaining: 2_000, done: done.clone() }) as Box<dyn TaskBody>
        };
        let bodies: Vec<Box<dyn TaskBody>> = vec![
            scalar(&done),
            scalar(&done),
            heavy(&done),  // module 0, core 2
            scalar(&done), // module 0, core 3
            scalar(&done), // module 1, core 4
            scalar(&done), // module 1, core 5
        ];
        for b in bodies {
            m.spawn(TaskType::Untyped, 0, b);
        }
        m.run_until(30 * SEC, &mut NullDriver);
        assert_eq!(*done.borrow(), 6);
        let hm = m.domain_harmonic_ghz();
        assert_eq!(hm.len(), 3, "1 socket + 2 modules");
        assert_eq!(hm[0].0, "skt0");
        assert_eq!(hm[1].0, "mod0");
        assert_eq!(hm[2].0, "mod1");
        assert!(
            hm[1].1 < hm[2].1,
            "module 0 (licensed neighbour) must clock below module 1: {:?}",
            hm
        );
    }
}
