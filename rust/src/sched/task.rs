//! Task model: type classification, nice weights, virtual deadlines.

use crate::sim::Time;

/// Scheduler-visible task identifier (index into the machine's task slab).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// The paper's task classification (§3.2):
///
/// * `Scalar` — declared (via `without_avx()`) not to execute wide vector
///   instructions; may run anywhere but *must not* run AVX code.
/// * `Avx` — declared (via `with_avx()`) to execute wide vector
///   instructions soon; restricted to AVX cores.
/// * `Untyped` — never declared anything (all tasks outside the
///   instrumented application, including per-CPU kernel threads); may run
///   anywhere and must not be starved by AVX tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskType {
    Scalar,
    Avx,
    Untyped,
}

impl TaskType {
    pub fn queue_index(self) -> usize {
        match self {
            TaskType::Scalar => 0,
            TaskType::Avx => 1,
            TaskType::Untyped => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TaskType::Scalar => "scalar",
            TaskType::Avx => "avx",
            TaskType::Untyped => "untyped",
        }
    }
}

/// Run state of a task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunState {
    /// Executing on the given core.
    Running(usize),
    /// Enqueued on the given core's runqueue.
    Queued(usize),
    /// Blocked (sleeping or waiting on a channel).
    Blocked,
    /// Finished.
    Exited,
}

/// MuQSS-style nice-to-weight mapping: deadline offsets scale by
/// `prio_ratio^nice_level` steps; we keep the standard CFS-ish weights
/// for the narrow nice range the workloads use.
pub fn nice_weight(nice: i32) -> f64 {
    // 10% per nice step, like prio_ratios in MuQSS/BFS.
    1.1f64.powi(nice)
}

/// Scheduler bookkeeping per task.
#[derive(Clone, Debug)]
pub struct SchedEntity {
    pub id: TaskId,
    pub ttype: TaskType,
    pub nice: i32,
    /// Virtual deadline: earlier = runs sooner.
    pub vdeadline: Time,
    pub state: RunState,
    /// Core the task last ran on (for migration accounting).
    pub last_core: Option<usize>,
    /// Total CPU time consumed.
    pub cpu_ns: Time,
    /// Number of cross-core migrations.
    pub migrations: u64,
    /// Number of type changes (`with_avx`/`without_avx` calls).
    pub type_changes: u64,
}

impl SchedEntity {
    pub fn new(id: TaskId, ttype: TaskType, nice: i32) -> Self {
        SchedEntity {
            id,
            ttype,
            nice,
            vdeadline: 0,
            state: RunState::Blocked,
            last_core: None,
            cpu_ns: 0,
            migrations: 0,
            type_changes: 0,
        }
    }

    /// Refresh the virtual deadline after the task consumed its quantum
    /// (MuQSS: `deadline = niffies + prio_ratio * rr_interval`).
    pub fn refresh_deadline(&mut self, now: Time, rr_interval: Time) {
        self.vdeadline = now + (rr_interval as f64 * nice_weight(self.nice)) as Time;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_indices_distinct() {
        assert_ne!(TaskType::Scalar.queue_index(), TaskType::Avx.queue_index());
        assert_ne!(TaskType::Avx.queue_index(), TaskType::Untyped.queue_index());
    }

    #[test]
    fn nice_weight_ordering() {
        assert!(nice_weight(-5) < nice_weight(0));
        assert!(nice_weight(0) < nice_weight(10));
        assert_eq!(nice_weight(0), 1.0);
    }

    #[test]
    fn deadline_refresh_uses_weight() {
        let mut a = SchedEntity::new(TaskId(0), TaskType::Scalar, 0);
        let mut b = SchedEntity::new(TaskId(1), TaskType::Scalar, 5);
        a.refresh_deadline(1000, 6_000_000);
        b.refresh_deadline(1000, 6_000_000);
        assert!(a.vdeadline < b.vdeadline, "higher nice → later deadline");
    }
}
