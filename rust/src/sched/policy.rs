//! Core-specialization policies (§2.1, §3.1) and the baselines.
//!
//! The central asymmetry (Fig 3): letting an AVX core run scalar code
//! briefly wastes only that scalar section's speed, but letting a scalar
//! core run AVX code taxes *at least two milliseconds* of subsequent
//! scalar work. Policies therefore:
//!
//! * restrict AVX tasks to the AVX-core set — a scalar core never picks
//!   from an AVX queue,
//! * let AVX cores pick scalar tasks only at a large deadline penalty, so
//!   any runnable AVX/untyped task wins (the paper's idle-priority-like
//!   scheme),
//! * never restrict untyped tasks (they would otherwise be starved on
//!   AVX cores — §3.2).
//!
//! On multi-socket machines ([`PolicyKind::CoreSpecNuma`]) the AVX-core
//! set is distributed so every socket keeps its own AVX cores: an AVX
//! task can stay on its NUMA node instead of crossing the interconnect
//! to reach the machine-global AVX cores, and a socket whose AVX cores
//! hold the low-frequency license never drags the other sockets down
//! (each socket is its own frequency domain).

use super::task::TaskType;
use crate::cpu::topology::{socket_of_core, socket_span};
use crate::sim::{Time, MS};

/// Which scheduling policy a simulation runs.
///
/// # Examples
///
/// Per-socket core specialization on a 2-socket, 12-core machine — the
/// last two cores of *each* socket are AVX cores:
///
/// ```
/// use avxfreq::sched::PolicyKind;
/// use avxfreq::sched::TaskType;
///
/// let p = PolicyKind::CoreSpecNuma { avx_cores_per_socket: 2, sockets: 2 };
/// assert_eq!(p.avx_core_count(), 4);
/// // Socket 0 spans cores 0..6: cores 4 and 5 are its AVX cores.
/// assert!(!p.is_avx_core(3, 12));
/// assert!(p.is_avx_core(4, 12) && p.is_avx_core(5, 12));
/// // Socket 1 spans cores 6..12: cores 10 and 11 are its AVX cores.
/// assert!(!p.is_avx_core(9, 12));
/// assert!(p.is_avx_core(10, 12) && p.is_avx_core(11, 12));
/// // AVX tasks are restricted to AVX cores; scalar tasks run anywhere
/// // (deprioritized on AVX cores).
/// assert!(!p.eligible(3, 12, TaskType::Avx));
/// assert!(p.eligible(4, 12, TaskType::Avx));
/// assert!(p.eligible(4, 12, TaskType::Scalar));
/// assert!(p.deadline_penalty(4, 12, TaskType::Scalar) > 0);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyKind {
    /// Unmodified MuQSS: task types are ignored; `with_avx()` syscalls do
    /// not exist (zero overhead). The paper's "unmodified" blue bars.
    Unmodified,
    /// The paper's design: the last `avx_cores` cores of the server set
    /// are AVX cores; AVX tasks restricted to them; scalar tasks allowed
    /// there at deprioritized deadlines.
    CoreSpec { avx_cores: usize },
    /// NUMA-aware core specialization: the last `avx_cores_per_socket`
    /// cores of **each socket** are AVX cores (the machine's cores are
    /// split over `sockets` contiguous balanced chunks, matching
    /// [`crate::cpu::topology::socket_of_core`]). With `sockets: 1` this
    /// is exactly [`PolicyKind::CoreSpec`].
    CoreSpecNuma { avx_cores_per_socket: usize, sockets: usize },
    /// §2.1 strawman: strict partitioning — scalar tasks may *not* run on
    /// AVX cores. Underutilizes whenever the core ratio mismatches the
    /// workload mix (evaluated in the ablation benches).
    StrictPartition { avx_cores: usize },
    /// Hybrid-native specialization: the hardware's own class partition
    /// *is* the AVX-core set — the **first** `p_cores` cores (P-cores come
    /// first in [`crate::cpu::HybridSpec`]'s layout, deliberately
    /// inverting the last-K convention above). CoreSpec semantics
    /// otherwise: AVX confined to the set, scalar deprioritized there.
    /// On a homogeneous machine this is simply CoreSpec over the first K
    /// cores — the head-to-head `repro hybridspec` asks whether the
    /// hardware partition gives the paper's mitigation "for free".
    ClassNative { p_cores: usize },
}

impl PolicyKind {
    /// Short stable name used in tables and CSV output.
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Unmodified => "unmodified",
            PolicyKind::CoreSpec { .. } => "core-spec",
            PolicyKind::CoreSpecNuma { .. } => "core-spec-numa",
            PolicyKind::StrictPartition { .. } => "strict-partition",
            PolicyKind::ClassNative { .. } => "class-native",
        }
    }

    /// Number of AVX cores this policy dedicates machine-wide.
    pub fn avx_core_count(&self) -> usize {
        match self {
            PolicyKind::Unmodified => 0,
            PolicyKind::CoreSpec { avx_cores } | PolicyKind::StrictPartition { avx_cores } => {
                *avx_cores
            }
            PolicyKind::CoreSpecNuma { avx_cores_per_socket, sockets } => {
                *avx_cores_per_socket * (*sockets).max(1)
            }
            PolicyKind::ClassNative { p_cores } => *p_cores,
        }
    }

    /// Is `core` (an index into the server-core list, 0-based) an AVX core?
    /// Following the paper's evaluation, the *last* cores are AVX cores
    /// ("restrict execution of these functions to the last two physical
    /// cores", §4) — of the machine for [`PolicyKind::CoreSpec`] /
    /// [`PolicyKind::StrictPartition`], of each socket for
    /// [`PolicyKind::CoreSpecNuma`].
    pub fn is_avx_core(&self, core: usize, n_cores: usize) -> bool {
        match self {
            PolicyKind::Unmodified => false,
            PolicyKind::CoreSpec { .. } | PolicyKind::StrictPartition { .. } => {
                let k = self.avx_core_count().min(n_cores);
                core >= n_cores - k
            }
            PolicyKind::CoreSpecNuma { avx_cores_per_socket, sockets } => {
                let s = (*sockets).max(1);
                let socket = socket_of_core(core, n_cores, s);
                let (start, end) = socket_span(socket, n_cores, s);
                let k = (*avx_cores_per_socket).min(end - start);
                core >= end - k
            }
            PolicyKind::ClassNative { p_cores } => core < (*p_cores).min(n_cores),
        }
    }

    /// May `core` pick tasks from the queue of `ttype` at all?
    pub fn eligible(&self, core: usize, n_cores: usize, ttype: TaskType) -> bool {
        match self {
            PolicyKind::Unmodified => true,
            PolicyKind::CoreSpec { .. }
            | PolicyKind::CoreSpecNuma { .. }
            | PolicyKind::ClassNative { .. } => match ttype {
                TaskType::Avx => self.is_avx_core(core, n_cores),
                TaskType::Scalar | TaskType::Untyped => true,
            },
            PolicyKind::StrictPartition { .. } => match ttype {
                TaskType::Avx => self.is_avx_core(core, n_cores),
                TaskType::Scalar => !self.is_avx_core(core, n_cores),
                TaskType::Untyped => true,
            },
        }
    }

    /// Deadline penalty applied when `core` considers a task of `ttype`
    /// (§3.2: "adding a large value to the deadline of scalar tasks so
    /// that the deadline of all other tasks is guaranteed to be lower").
    pub fn deadline_penalty(&self, core: usize, n_cores: usize, ttype: TaskType) -> Time {
        match self {
            PolicyKind::CoreSpec { .. }
            | PolicyKind::CoreSpecNuma { .. }
            | PolicyKind::ClassNative { .. }
                if ttype == TaskType::Scalar && self.is_avx_core(core, n_cores) =>
            {
                SCALAR_ON_AVX_PENALTY
            }
            _ => 0,
        }
    }
}

/// "A large value": beyond any virtual deadline reachable by nice levels
/// within a scheduling epoch, mirroring MuQSS's idle-priority offset.
pub const SCALAR_ON_AVX_PENALTY: Time = 1_000_000 * MS;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unmodified_allows_everything() {
        let p = PolicyKind::Unmodified;
        for core in 0..12 {
            for t in [TaskType::Scalar, TaskType::Avx, TaskType::Untyped] {
                assert!(p.eligible(core, 12, t));
                assert_eq!(p.deadline_penalty(core, 12, t), 0);
            }
        }
    }

    #[test]
    fn corespec_restricts_avx_to_last_cores() {
        let p = PolicyKind::CoreSpec { avx_cores: 2 };
        assert!(!p.eligible(0, 12, TaskType::Avx));
        assert!(!p.eligible(9, 12, TaskType::Avx));
        assert!(p.eligible(10, 12, TaskType::Avx));
        assert!(p.eligible(11, 12, TaskType::Avx));
        // Scalar allowed everywhere under CoreSpec…
        assert!(p.eligible(11, 12, TaskType::Scalar));
        // …but deprioritized on AVX cores.
        assert!(p.deadline_penalty(11, 12, TaskType::Scalar) > 0);
        assert_eq!(p.deadline_penalty(0, 12, TaskType::Scalar), 0);
        // Untyped never penalized (kernel threads pinned to AVX cores
        // must not be starved).
        assert_eq!(p.deadline_penalty(11, 12, TaskType::Untyped), 0);
    }

    #[test]
    fn strict_partition_excludes_scalar_from_avx_cores() {
        let p = PolicyKind::StrictPartition { avx_cores: 3 };
        assert!(!p.eligible(9, 12, TaskType::Scalar));
        assert!(p.eligible(8, 12, TaskType::Scalar));
        assert!(p.eligible(9, 12, TaskType::Untyped));
        assert!(p.eligible(9, 12, TaskType::Avx));
        assert!(!p.eligible(8, 12, TaskType::Avx));
    }

    #[test]
    fn avx_core_count_clamped() {
        let p = PolicyKind::CoreSpec { avx_cores: 99 };
        assert!(p.is_avx_core(0, 4));
    }

    #[test]
    fn numa_variant_reserves_avx_cores_per_socket() {
        // 12 cores / 2 sockets: sockets span 0..6 and 6..12.
        let p = PolicyKind::CoreSpecNuma { avx_cores_per_socket: 2, sockets: 2 };
        let avx: Vec<usize> = (0..12).filter(|&c| p.is_avx_core(c, 12)).collect();
        assert_eq!(avx, vec![4, 5, 10, 11]);
        assert_eq!(p.avx_core_count(), 4);
        // AVX tasks may use either socket's AVX cores.
        assert!(p.eligible(4, 12, TaskType::Avx));
        assert!(p.eligible(10, 12, TaskType::Avx));
        assert!(!p.eligible(6, 12, TaskType::Avx));
        // Scalar deprioritized on both sockets' AVX cores.
        assert!(p.deadline_penalty(5, 12, TaskType::Scalar) > 0);
        assert!(p.deadline_penalty(11, 12, TaskType::Scalar) > 0);
        assert_eq!(p.deadline_penalty(6, 12, TaskType::Scalar), 0);
    }

    #[test]
    fn numa_variant_with_one_socket_matches_corespec() {
        let numa = PolicyKind::CoreSpecNuma { avx_cores_per_socket: 2, sockets: 1 };
        let flat = PolicyKind::CoreSpec { avx_cores: 2 };
        for core in 0..12 {
            assert_eq!(numa.is_avx_core(core, 12), flat.is_avx_core(core, 12), "core {core}");
            for t in [TaskType::Scalar, TaskType::Avx, TaskType::Untyped] {
                assert_eq!(numa.eligible(core, 12, t), flat.eligible(core, 12, t));
                assert_eq!(
                    numa.deadline_penalty(core, 12, t),
                    flat.deadline_penalty(core, 12, t)
                );
            }
        }
    }

    #[test]
    fn class_native_reserves_the_first_cores() {
        // Inverted convention: the *first* K cores (the P-cores of a
        // hybrid part) make up the specialization set.
        let p = PolicyKind::ClassNative { p_cores: 2 };
        assert_eq!(p.name(), "class-native");
        assert_eq!(p.avx_core_count(), 2);
        let avx: Vec<usize> = (0..6).filter(|&c| p.is_avx_core(c, 6)).collect();
        assert_eq!(avx, vec![0, 1]);
        // CoreSpec semantics over the inverted set.
        assert!(p.eligible(0, 6, TaskType::Avx));
        assert!(!p.eligible(2, 6, TaskType::Avx));
        assert!(p.eligible(0, 6, TaskType::Scalar));
        assert!(p.deadline_penalty(0, 6, TaskType::Scalar) > 0);
        assert_eq!(p.deadline_penalty(2, 6, TaskType::Scalar), 0);
        assert_eq!(p.deadline_penalty(0, 6, TaskType::Untyped), 0);
        // Oversized set clamps.
        let all = PolicyKind::ClassNative { p_cores: 99 };
        assert!(all.is_avx_core(3, 4));
    }

    #[test]
    fn numa_variant_clamps_to_socket_size() {
        // 4 cores / 2 sockets, 9 AVX cores per socket requested: every
        // core becomes an AVX core, nothing panics.
        let p = PolicyKind::CoreSpecNuma { avx_cores_per_socket: 9, sockets: 2 };
        for core in 0..4 {
            assert!(p.is_avx_core(core, 4));
        }
    }
}
