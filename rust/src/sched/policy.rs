//! Core-specialization policies (§2.1, §3.1) and the baselines.
//!
//! The central asymmetry (Fig 3): letting an AVX core run scalar code
//! briefly wastes only that scalar section's speed, but letting a scalar
//! core run AVX code taxes *at least two milliseconds* of subsequent
//! scalar work. Policies therefore:
//!
//! * restrict AVX tasks to the AVX-core set — a scalar core never picks
//!   from an AVX queue,
//! * let AVX cores pick scalar tasks only at a large deadline penalty, so
//!   any runnable AVX/untyped task wins (the paper's idle-priority-like
//!   scheme),
//! * never restrict untyped tasks (they would otherwise be starved on
//!   AVX cores — §3.2).

use super::task::TaskType;
use crate::sim::{Time, MS};

/// Which scheduling policy a simulation runs.
#[derive(Clone, Debug, PartialEq)]
pub enum PolicyKind {
    /// Unmodified MuQSS: task types are ignored; `with_avx()` syscalls do
    /// not exist (zero overhead). The paper's "unmodified" blue bars.
    Unmodified,
    /// The paper's design: the last `avx_cores` cores of the server set
    /// are AVX cores; AVX tasks restricted to them; scalar tasks allowed
    /// there at deprioritized deadlines.
    CoreSpec { avx_cores: usize },
    /// §2.1 strawman: strict partitioning — scalar tasks may *not* run on
    /// AVX cores. Underutilizes whenever the core ratio mismatches the
    /// workload mix (evaluated in the ablation benches).
    StrictPartition { avx_cores: usize },
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Unmodified => "unmodified",
            PolicyKind::CoreSpec { .. } => "core-spec",
            PolicyKind::StrictPartition { .. } => "strict-partition",
        }
    }

    /// Number of AVX cores for a server-core count.
    pub fn avx_core_count(&self) -> usize {
        match self {
            PolicyKind::Unmodified => 0,
            PolicyKind::CoreSpec { avx_cores } | PolicyKind::StrictPartition { avx_cores } => {
                *avx_cores
            }
        }
    }

    /// Is `core` (an index into the server-core list, 0-based) an AVX core?
    /// Following the paper's evaluation, the *last* cores are AVX cores
    /// ("restrict execution of these functions to the last two physical
    /// cores", §4).
    pub fn is_avx_core(&self, core: usize, n_cores: usize) -> bool {
        let k = self.avx_core_count().min(n_cores);
        core >= n_cores - k
    }

    /// May `core` pick tasks from the queue of `ttype` at all?
    pub fn eligible(&self, core: usize, n_cores: usize, ttype: TaskType) -> bool {
        match self {
            PolicyKind::Unmodified => true,
            PolicyKind::CoreSpec { .. } => match ttype {
                TaskType::Avx => self.is_avx_core(core, n_cores),
                TaskType::Scalar | TaskType::Untyped => true,
            },
            PolicyKind::StrictPartition { .. } => match ttype {
                TaskType::Avx => self.is_avx_core(core, n_cores),
                TaskType::Scalar => !self.is_avx_core(core, n_cores),
                TaskType::Untyped => true,
            },
        }
    }

    /// Deadline penalty applied when `core` considers a task of `ttype`
    /// (§3.2: "adding a large value to the deadline of scalar tasks so
    /// that the deadline of all other tasks is guaranteed to be lower").
    pub fn deadline_penalty(&self, core: usize, n_cores: usize, ttype: TaskType) -> Time {
        match self {
            PolicyKind::CoreSpec { .. }
                if ttype == TaskType::Scalar && self.is_avx_core(core, n_cores) =>
            {
                SCALAR_ON_AVX_PENALTY
            }
            _ => 0,
        }
    }
}

/// "A large value": beyond any virtual deadline reachable by nice levels
/// within a scheduling epoch, mirroring MuQSS's idle-priority offset.
pub const SCALAR_ON_AVX_PENALTY: Time = 1_000_000 * MS;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unmodified_allows_everything() {
        let p = PolicyKind::Unmodified;
        for core in 0..12 {
            for t in [TaskType::Scalar, TaskType::Avx, TaskType::Untyped] {
                assert!(p.eligible(core, 12, t));
                assert_eq!(p.deadline_penalty(core, 12, t), 0);
            }
        }
    }

    #[test]
    fn corespec_restricts_avx_to_last_cores() {
        let p = PolicyKind::CoreSpec { avx_cores: 2 };
        assert!(!p.eligible(0, 12, TaskType::Avx));
        assert!(!p.eligible(9, 12, TaskType::Avx));
        assert!(p.eligible(10, 12, TaskType::Avx));
        assert!(p.eligible(11, 12, TaskType::Avx));
        // Scalar allowed everywhere under CoreSpec…
        assert!(p.eligible(11, 12, TaskType::Scalar));
        // …but deprioritized on AVX cores.
        assert!(p.deadline_penalty(11, 12, TaskType::Scalar) > 0);
        assert_eq!(p.deadline_penalty(0, 12, TaskType::Scalar), 0);
        // Untyped never penalized (kernel threads pinned to AVX cores
        // must not be starved).
        assert_eq!(p.deadline_penalty(11, 12, TaskType::Untyped), 0);
    }

    #[test]
    fn strict_partition_excludes_scalar_from_avx_cores() {
        let p = PolicyKind::StrictPartition { avx_cores: 3 };
        assert!(!p.eligible(9, 12, TaskType::Scalar));
        assert!(p.eligible(8, 12, TaskType::Scalar));
        assert!(p.eligible(9, 12, TaskType::Untyped));
        assert!(p.eligible(9, 12, TaskType::Avx));
        assert!(!p.eligible(8, 12, TaskType::Avx));
    }

    #[test]
    fn avx_core_count_clamped() {
        let p = PolicyKind::CoreSpec { avx_cores: 99 };
        assert!(p.is_avx_core(0, 4));
    }
}
