//! The paper's contribution: a MuQSS-style deadline scheduler extended
//! with *core specialization* for AVX tasks, plus the baselines it is
//! evaluated against.
//!
//! Structure mirrors the paper's §3:
//!
//! * [`task`] — task types (`Scalar` / `Avx` / `Untyped`), virtual
//!   deadlines, nice weights.
//! * [`skiplist`] — the sorted runqueue structure MuQSS uses.
//! * [`policy`] — which cores may run which task types, and the deadline
//!   penalty that makes AVX cores prefer AVX/untyped work (§3.1).
//! * [`muqss`] — the scheduler proper: per-core triple runqueues, pick,
//!   cross-core stealing (NUMA-aware: same-socket queues are scanned
//!   first and remote-socket steals carry a deadline penalty), preemption
//!   via IPI, the `with_avx()` / `without_avx()` type-change path (§3.2).
//! * [`machine`] — the event loop gluing scheduler, cores, and workloads;
//!   on multi-socket machines each socket is its own frequency domain
//!   and cross-socket migrations charge extra dispatch cost.
//! * [`fault_migrate`] — the paper's §6.1 future-work mechanism: make the
//!   first wide instruction of an unannotated task fault and reclassify
//!   it automatically.
//! * [`adaptive`] — §3.1's "as many AVX cores as required" as an online
//!   controller, plus the §4.3 adaptive-policy future work.
//!
//! `docs/ARCHITECTURE.md` (repo root) walks through the event-queue /
//! machine / scheduler control flow end to end, including the sequence
//! diagram of a task's `with_avx()` migration path and the socket/NUMA
//! hierarchy introduced for multi-socket machines.

pub mod task;
pub mod skiplist;
pub mod policy;
pub mod muqss;
pub mod machine;
pub mod fault_migrate;
pub mod adaptive;

pub use machine::{Action, Event, Machine, MachineParams, TaskBody};
pub use muqss::{SchedParams, SchedStats, Scheduler};
pub use policy::PolicyKind;
pub use task::{TaskId, TaskType};
