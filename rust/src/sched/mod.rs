//! The paper's contribution: a MuQSS-style deadline scheduler extended
//! with *core specialization* for AVX tasks, plus the baselines it is
//! evaluated against.
//!
//! Structure mirrors the paper's §3:
//!
//! * [`task`] — task types (`Scalar` / `Avx` / `Untyped`), virtual
//!   deadlines, nice weights.
//! * [`skiplist`] — the sorted runqueue structure MuQSS uses.
//! * [`policy`] — which cores may run which task types, and the deadline
//!   penalty that makes AVX cores prefer AVX/untyped work (§3.1).
//! * [`muqss`] — the scheduler proper: per-core triple runqueues, pick,
//!   cross-core stealing, preemption via IPI, the `with_avx()` /
//!   `without_avx()` type-change path (§3.2).
//! * [`machine`] — the event loop gluing scheduler, cores, and workloads.
//! * [`fault_migrate`] — the paper's §6.1 future-work mechanism: make the
//!   first wide instruction of an unannotated task fault and reclassify
//!   it automatically.
//! * [`adaptive`] — §3.1's "as many AVX cores as required" as an online
//!   controller, plus the §4.3 adaptive-policy future work.

pub mod task;
pub mod skiplist;
pub mod policy;
pub mod muqss;
pub mod machine;
pub mod fault_migrate;
pub mod adaptive;

pub use machine::{Action, Event, Machine, MachineParams, TaskBody};
pub use muqss::{SchedParams, SchedStats, Scheduler};
pub use policy::PolicyKind;
pub use task::{TaskId, TaskType};
