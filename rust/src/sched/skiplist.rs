//! Skiplist runqueue — the central data structure of MuQSS ("Multiple
//! Queue Skiplist Scheduler"). Keys are `(virtual deadline, sequence)`;
//! the scheduler needs `O(log n)` insert, `O(1)` peek/pop of the earliest
//! deadline, and keyed removal (for dequeues on migration/type change).
//!
//! The level generator is a deterministic xorshift so simulations are
//! reproducible.

use crate::sched::task::TaskId;
use crate::sim::Time;

const MAX_LEVEL: usize = 12; // plenty for thousands of runnable tasks

/// Sort key: earliest virtual deadline first, FIFO within a deadline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Key {
    pub vdeadline: Time,
    pub seq: u64,
}

#[derive(Clone, Debug)]
struct Node {
    key: Key,
    task: TaskId,
    /// Number of levels this node participates in.
    levels: u8,
    /// next[i] = index of next node at level i (usize::MAX = nil).
    /// Fixed-size array: no per-insert allocation on the pick hot path.
    next: [usize; MAX_LEVEL],
}

const NIL: usize = usize::MAX;

/// Skiplist keyed by [`Key`], storing task ids.
///
/// `Clone` snapshots the whole structure — node arena, free list, level
/// links, and the deterministic level-generator state — so a cloned
/// scheduler resumes with identical pick order and identical future
/// level choices (checkpoint forking, [`crate::scenario`]).
#[derive(Clone, Debug)]
pub struct SkipList {
    // Node arena; freed slots are reused via a free list.
    nodes: Vec<Node>,
    free: Vec<usize>,
    /// head.next[i] per level.
    head: [usize; MAX_LEVEL],
    level: usize,
    len: usize,
    rng_state: u64,
    seq: u64,
}

impl Default for SkipList {
    fn default() -> Self {
        Self::new()
    }
}

impl SkipList {
    pub fn new() -> Self {
        SkipList {
            nodes: Vec::new(),
            free: Vec::new(),
            head: [NIL; MAX_LEVEL],
            level: 1,
            len: 0,
            rng_state: 0x9E3779B97F4A7C15,
            seq: 0,
        }
    }

    fn random_level(&mut self) -> usize {
        // xorshift64*; one level promotion per set bit pair (p = 1/4).
        let mut x = self.rng_state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng_state = x;
        let bits = x.wrapping_mul(0x2545F4914F6CDD1D);
        let mut level = 1;
        let mut b = bits;
        while level < MAX_LEVEL && (b & 3) == 3 {
            level += 1;
            b >>= 2;
        }
        level
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert a task with the given deadline; returns the full key
    /// (including the tie-breaking sequence number).
    pub fn insert(&mut self, vdeadline: Time, task: TaskId) -> Key {
        let key = Key { vdeadline, seq: self.seq };
        self.seq += 1;
        let level = self.random_level();

        // Find predecessors at every level.
        let mut update = [NIL; MAX_LEVEL]; // NIL here means "head"
        let mut cur = NIL; // NIL = head sentinel
        for i in (0..self.level.max(level)).rev() {
            loop {
                let next = if cur == NIL { self.head[i] } else { self.nodes[cur].next[i] };
                if next != NIL && self.nodes[next].key < key {
                    cur = next;
                } else {
                    break;
                }
            }
            update[i] = cur;
        }
        if level > self.level {
            self.level = level;
        }

        let node = Node { key, task, levels: level as u8, next: [NIL; MAX_LEVEL] };
        let idx = if let Some(i) = self.free.pop() {
            self.nodes[i] = node;
            i
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        };

        for (i, slot) in update.iter().enumerate().take(level) {
            if *slot == NIL {
                self.nodes[idx].next[i] = self.head[i];
                self.head[i] = idx;
            } else {
                self.nodes[idx].next[i] = self.nodes[*slot].next[i];
                self.nodes[*slot].next[i] = idx;
            }
        }
        self.len += 1;
        key
    }

    /// Earliest (key, task) without removing it.
    pub fn peek(&self) -> Option<(Key, TaskId)> {
        let first = self.head[0];
        if first == NIL {
            None
        } else {
            Some((self.nodes[first].key, self.nodes[first].task))
        }
    }

    /// Remove and return the earliest entry.
    pub fn pop(&mut self) -> Option<(Key, TaskId)> {
        let first = self.head[0];
        if first == NIL {
            return None;
        }
        let key = self.nodes[first].key;
        let task = self.nodes[first].task;
        let levels = self.nodes[first].levels as usize;
        for i in 0..levels {
            if self.head[i] == first {
                self.head[i] = self.nodes[first].next[i];
            }
        }
        self.free.push(first);
        self.len -= 1;
        Some((key, task))
    }

    /// Remove a specific entry by its key (returned from `insert`).
    /// Returns true if found.
    pub fn remove(&mut self, key: Key) -> bool {
        let mut found = false;
        let mut cur = NIL;
        let mut target = NIL;
        for i in (0..self.level).rev() {
            loop {
                let next = if cur == NIL { self.head[i] } else { self.nodes[cur].next[i] };
                if next != NIL && self.nodes[next].key < key {
                    cur = next;
                } else {
                    if next != NIL && self.nodes[next].key == key {
                        // unlink at this level
                        target = next;
                        let after = self.nodes[next].next[i];
                        if cur == NIL {
                            self.head[i] = after;
                        } else {
                            self.nodes[cur].next[i] = after;
                        }
                        found = true;
                    }
                    break;
                }
            }
        }
        if found {
            self.free.push(target);
            self.len -= 1;
        }
        found
    }

    /// Iterate entries in deadline order (test/diagnostic use).
    pub fn iter(&self) -> impl Iterator<Item = (Key, TaskId)> + '_ {
        let mut cur = self.head[0];
        std::iter::from_fn(move || {
            if cur == NIL {
                None
            } else {
                let n = &self.nodes[cur];
                cur = n.next[0];
                Some((n.key, n.task))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_deadline_order() {
        let mut s = SkipList::new();
        let deadlines = [50u64, 10, 30, 10, 90, 20];
        for (i, d) in deadlines.iter().enumerate() {
            s.insert(*d, TaskId(i));
        }
        let order: Vec<Time> = std::iter::from_fn(|| s.pop()).map(|(k, _)| k.vdeadline).collect();
        assert_eq!(order, vec![10, 10, 20, 30, 50, 90]);
    }

    #[test]
    fn fifo_within_equal_deadline() {
        let mut s = SkipList::new();
        for i in 0..10 {
            s.insert(5, TaskId(i));
        }
        let order: Vec<usize> = std::iter::from_fn(|| s.pop()).map(|(_, t)| t.0).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn remove_by_key() {
        let mut s = SkipList::new();
        let k1 = s.insert(10, TaskId(1));
        let _k2 = s.insert(20, TaskId(2));
        let k3 = s.insert(5, TaskId(3));
        assert!(s.remove(k1));
        assert!(!s.remove(k1), "double remove fails");
        assert_eq!(s.len(), 2);
        assert_eq!(s.peek().unwrap().1, TaskId(3));
        assert!(s.remove(k3));
        assert_eq!(s.pop().unwrap().1, TaskId(2));
        assert!(s.is_empty());
    }

    #[test]
    fn stress_against_btreemap() {
        use std::collections::BTreeMap;
        let mut s = SkipList::new();
        let mut reference: BTreeMap<Key, TaskId> = BTreeMap::new();
        let mut state = 12345u64;
        let mut rand = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        let mut keys = Vec::new();
        for i in 0..5000usize {
            let op = rand() % 3;
            if op < 2 || keys.is_empty() {
                let d = rand() % 1000;
                let k = s.insert(d, TaskId(i));
                reference.insert(k, TaskId(i));
                keys.push(k);
            } else {
                let k = keys.swap_remove((rand() % keys.len() as u64) as usize);
                let in_ref = reference.remove(&k).is_some();
                assert_eq!(s.remove(k), in_ref);
            }
            assert_eq!(s.len(), reference.len());
            assert_eq!(
                s.peek().map(|(k, t)| (k, t)),
                reference.iter().next().map(|(k, t)| (*k, *t))
            );
        }
        // Drain and compare full order.
        let drained: Vec<_> = std::iter::from_fn(|| s.pop()).collect();
        let expect: Vec<_> = std::mem::take(&mut reference).into_iter().collect();
        assert_eq!(drained, expect);
    }

    #[test]
    fn arena_reuse_after_pop() {
        let mut s = SkipList::new();
        for round in 0..50 {
            for i in 0..20 {
                s.insert(i, TaskId(i as usize));
            }
            for _ in 0..20 {
                s.pop();
            }
            assert!(s.is_empty(), "round {round}");
        }
        // The arena should not have grown unboundedly.
        assert!(s.nodes.len() <= 64, "arena grew to {}", s.nodes.len());
    }
}
