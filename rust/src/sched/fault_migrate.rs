//! Fault-and-migrate (§6.1 future work): automatic task classification
//! without source annotations.
//!
//! The paper proposes restricting the memory region used by `FXSTOR`
//! during context switches so that the first wide (AVX-512) instruction a
//! task executes raises an exception; the handler reclassifies the task
//! as an AVX task and migrates it *before* any frequency reduction is
//! triggered (cf. Li et al. [15], who emulate ISA asymmetry by disabling
//! the FPU).
//!
//! In the simulation, a task whose next instruction block contains wide
//! instructions while its type is not `Avx` "traps": the machine charges
//! the exception cost, switches the task type, and — if it sits on a
//! scalar core — suspends it so the AVX-core path picks it up, exactly as
//! the annotated `with_avx()` path would. Reverting is the part the paper
//! leaves open; we implement the natural decay heuristic: after a
//! sufficiently long streak of scalar-only execution, the task reverts to
//! `Scalar`.

use crate::sim::{Time, US};

/// Parameters for the automatic classification mechanism.
#[derive(Clone, Copy, Debug)]
pub struct FaultMigrateParams {
    /// Cost of the #UD/#NM-style trap plus kernel handling (µs scale:
    /// exception entry, state inspection, runqueue operations).
    pub fault_cost: Time,
    /// Scalar-only streak after which an `Avx` task reverts to `Scalar`.
    ///
    /// Tradeoff: the revert must be *short* relative to the workload's
    /// AVX-burst cadence, or every thread that ever faulted stays pinned
    /// to the (few) AVX cores and the machine collapses onto them — the
    /// scalar phases between SSL calls are ~1 ms, so the default reverts
    /// well within that. Reverting early is safe for the *core* (its
    /// license is held regardless for 2 ms); the cost of reverting too
    /// eagerly is just an extra fault on the next burst (~µs).
    pub decay: Time,
}

impl Default for FaultMigrateParams {
    fn default() -> Self {
        FaultMigrateParams { fault_cost: 3 * US, decay: 30 * US }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    #[test]
    fn decay_reverts_between_request_scale_bursts() {
        let p = FaultMigrateParams::default();
        assert!(
            p.decay < MS,
            "decay must be shorter than inter-burst scalar phases (~1 ms) \
             or faulted threads pin to the AVX cores permanently"
        );
    }

    #[test]
    fn fault_cost_is_microseconds() {
        let p = FaultMigrateParams::default();
        assert!(p.fault_cost >= US && p.fault_cost <= 100 * US);
    }
}
