//! MuQSS-style scheduler with the paper's core-specialization extension.
//!
//! Faithful to the structures §3.2 describes:
//!
//! * one runqueue set per physical core, **replicated ×3** (scalar / AVX /
//!   untyped), each a skiplist sorted by virtual deadline;
//! * pick = earliest *effective* deadline over all queues the core may
//!   look at, where the effective deadline of a scalar task examined by
//!   an AVX core carries a large penalty (idle-priority-like);
//! * on every pick the core also (locklessly, in the real kernel) checks
//!   the other cores' queue heads and steals the globally earliest
//!   eligible task — this is MuQSS's only load-balancing mechanism and
//!   the paper relies on it for scalar/AVX balance;
//! * `set_task_type` implements the `with_avx()` / `without_avx()`
//!   syscalls: becoming an AVX task on a scalar core suspends the thread
//!   immediately; a scalar task occupying an AVX core is preempted via
//!   IPI so the core can take the new AVX task (§3.2).

use super::policy::{PolicyKind, SCALAR_ON_AVX_PENALTY};
use super::skiplist::{Key, SkipList};
use super::task::{RunState, SchedEntity, TaskId, TaskType};
use crate::sim::Time;

/// Scheduler cost/behaviour parameters. Costs are charged as simulated
/// time on the core that performs the operation; defaults are calibrated
/// so an AVX↔scalar switch pair lands in the paper's measured 400–500 ns
/// (§4.3).
#[derive(Clone, Debug)]
pub struct SchedParams {
    /// MuQSS default round-robin interval (timeslice).
    pub rr_interval: Time,
    /// Cost of one `with_avx()`/`without_avx()` syscall.
    pub syscall_cost: Time,
    /// Cost of one scheduler invocation (pick_next_task incl. queue scan).
    pub resched_cost: Time,
    /// IPI delivery latency (sender → receiver interrupt).
    pub ipi_latency: Time,
    /// Cost paid by the IPI receiver (interrupt entry + resched).
    pub ipi_cost: Time,
    /// Extra cost when a task starts on a core it did not last run on
    /// (cold register/TLB state; cache effects come from the footprint
    /// model instead).
    pub migration_cost: Time,
    /// Additional cost when that migration crosses a socket boundary:
    /// the working set must be re-fetched over the interconnect
    /// (QPI/UPI) and first-touch pages stay remote. Charged on top of
    /// `migration_cost`; never charged on single-socket machines.
    pub cross_socket_migration_cost: Time,
    /// Effective-deadline penalty a core applies to tasks queued on a
    /// *remote socket's* runqueues, biasing the steal scan toward
    /// NUMA-local work. Remote tasks still win once their deadline is
    /// this much earlier, so nothing starves; zero on single-socket
    /// machines by construction (every queue is local).
    pub numa_steal_penalty: Time,
    /// Whether cross-core stealing is enabled (ablation switch).
    pub steal: bool,
}

impl Default for SchedParams {
    fn default() -> Self {
        SchedParams {
            rr_interval: 6_000_000, // 6 ms, MuQSS default
            syscall_cost: 55,
            resched_cost: 70,
            ipi_latency: 900,
            ipi_cost: 220,
            migration_cost: 110,
            cross_socket_migration_cost: 650,
            numa_steal_penalty: 3_000_000, // half an rr_interval
            steal: true,
        }
    }
}

/// Counters the evaluation reports.
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    pub picks: u64,
    pub steals: u64,
    pub ipis: u64,
    pub migrations: u64,
    /// Subset of `migrations` that crossed a socket (NUMA) boundary.
    pub cross_socket_migrations: u64,
    pub type_changes: u64,
    pub forced_suspends: u64,
    pub preemptions: u64,
}

/// Directive returned by [`Scheduler::set_task_type`] telling the machine
/// what must happen next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TypeChangeOutcome {
    /// Nothing further (policy ignores types, or task keeps its core).
    Continue,
    /// The calling task must be suspended and requeued; its core must
    /// reschedule (scalar core whose task became AVX, or strict-partition
    /// violations).
    SuspendSelf,
}

/// Where a newly runnable task should go.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WakeTarget {
    /// An idle eligible core should dispatch immediately.
    DispatchIdle(usize),
    /// A busy core should be preempted via IPI.
    Preempt(usize),
    /// Stay queued until some core naturally reschedules.
    Queued,
}

/// One core's replicated runqueues.
#[derive(Clone, Debug, Default)]
struct CoreQueues {
    queues: [SkipList; 3],
}

/// The scheduler.
///
/// `Clone` snapshots the complete scheduling state — runqueues (with
/// their deterministic skiplist level generators), entities, placement
/// maps, and stats — for checkpoint forking ([`crate::scenario`]): a
/// cloned scheduler makes bit-identical decisions from the fork point on.
#[derive(Clone, Debug)]
pub struct Scheduler {
    pub policy: PolicyKind,
    pub params: SchedParams,
    n_cores: usize,
    rq: Vec<CoreQueues>,
    entities: Vec<SchedEntity>,
    /// Where each queued task sits: (core, queue index, key).
    queued_at: Vec<Option<(usize, usize, Key)>>,
    /// What each core is running.
    running: Vec<Option<TaskId>>,
    /// Socket (NUMA node) of each core; all zeros on single-socket.
    socket_of: Vec<usize>,
    /// Per-core core-scan order: own core, same-socket cores (wrapping),
    /// then remote sockets by distance. Drives the steal scan in
    /// [`Scheduler::pick`].
    scan_order: Vec<Vec<usize>>,
    /// Per-core idle-core search order for wakeups: same-socket cores
    /// ascending, then remote sockets by distance. For a single socket
    /// this is exactly the historical `0..n_cores` scan, so the paper's
    /// single-socket placement is unchanged.
    wake_order: Vec<Vec<usize>>,
    /// Per-core AVX-512 capability on hybrid machines (`true` = P-core).
    /// `None` — every core capable — leaves every decision byte-identical
    /// to the pre-hybrid scheduler. When present, AVX-typed tasks are
    /// *never* eligible on an incapable core, whatever the policy says:
    /// the hardware has no 512-bit path there.
    avx_capable: Option<Vec<bool>>,
    /// Effective AVX-core set when the policy's index arithmetic must be
    /// remapped onto the capable cores. On a hybrid part CoreSpec's
    /// "last K cores" *are* the E-cores — exactly the incapable ones —
    /// so intersecting naively would leave AVX work with nowhere to run;
    /// instead the last-K (or per-socket last-k) selection is re-applied
    /// over the capable core list once at construction. `None` = use the
    /// policy's own arithmetic (homogeneous machines).
    avx_set: Option<Vec<bool>>,
    pub stats: SchedStats,
}

/// Remap the policy's AVX-core selection onto the capable (P) cores of a
/// hybrid machine. `ClassNative` takes the hardware partition verbatim;
/// the last-K policies re-run their selection over the capable id list;
/// `Unmodified` has no set.
fn remap_avx_set(
    policy: &PolicyKind,
    socket_of: &[usize],
    capable: &[bool],
) -> Option<Vec<bool>> {
    let n = capable.len();
    let mark_last_k = |ids: &[usize], k: usize, set: &mut [bool]| {
        let k = k.min(ids.len());
        for &c in &ids[ids.len() - k..] {
            set[c] = true;
        }
    };
    match policy {
        PolicyKind::Unmodified => None,
        PolicyKind::ClassNative { .. } => Some(capable.to_vec()),
        PolicyKind::CoreSpec { avx_cores } | PolicyKind::StrictPartition { avx_cores } => {
            let ids: Vec<usize> = (0..n).filter(|&c| capable[c]).collect();
            let mut set = vec![false; n];
            mark_last_k(&ids, *avx_cores, &mut set);
            Some(set)
        }
        PolicyKind::CoreSpecNuma { avx_cores_per_socket, .. } => {
            let n_sockets = socket_of.iter().copied().max().map_or(1, |m| m + 1);
            let mut set = vec![false; n];
            for s in 0..n_sockets {
                let ids: Vec<usize> =
                    (0..n).filter(|&c| socket_of[c] == s && capable[c]).collect();
                mark_last_k(&ids, *avx_cores_per_socket, &mut set);
            }
            Some(set)
        }
    }
}

/// Per-core scan order over `socket_of`: same-socket cores ascending and
/// rotated to start at the owning core (for one socket this reproduces
/// the historical `(core + i) % n_cores` scan exactly), then remote
/// sockets by ascending socket distance, members ascending.
fn build_scan_order(socket_of: &[usize]) -> Vec<Vec<usize>> {
    let n = socket_of.len();
    (0..n)
        .map(|core| {
            let s = socket_of[core];
            let mut order = Vec::with_capacity(n);
            let locals: Vec<usize> = (0..n).filter(|&c| socket_of[c] == s).collect();
            // `core` is in `locals` by construction; if a malformed
            // socket map ever breaks that, scan unrotated from the
            // first local core rather than taking the scheduler down.
            let pos = locals.iter().position(|&c| c == core).unwrap_or(0);
            order.extend(locals[pos..].iter().copied());
            order.extend(locals[..pos].iter().copied());
            append_remote_sockets(&mut order, socket_of, s);
            order
        })
        .collect()
}

/// Per-core wakeup order: same-socket cores in ascending id order, then
/// remote sockets by distance. Unlike the pick order this is *not*
/// rotated to the owning core, so a single socket yields the historical
/// `0..n_cores` idle scan bit-for-bit.
fn build_wake_order(socket_of: &[usize]) -> Vec<Vec<usize>> {
    let n = socket_of.len();
    (0..n)
        .map(|core| {
            let s = socket_of[core];
            let mut order: Vec<usize> = (0..n).filter(|&c| socket_of[c] == s).collect();
            append_remote_sockets(&mut order, socket_of, s);
            order
        })
        .collect()
}

/// Append every core outside socket `s`, sockets ordered by distance
/// (ties to the lower id), members ascending.
fn append_remote_sockets(order: &mut Vec<usize>, socket_of: &[usize], s: usize) {
    let n = socket_of.len();
    let n_sockets = socket_of.iter().copied().max().map_or(1, |m| m + 1);
    let mut remote: Vec<usize> = (0..n_sockets).filter(|&x| x != s).collect();
    remote.sort_by_key(|&x| (x.abs_diff(s), x));
    for rs in remote {
        order.extend((0..n).filter(|&c| socket_of[c] == rs));
    }
}

impl Scheduler {
    /// Single-socket scheduler (the paper's machine).
    pub fn new(policy: PolicyKind, params: SchedParams, n_cores: usize) -> Self {
        Self::new_numa(policy, params, vec![0; n_cores])
    }

    /// NUMA-aware scheduler: `socket_of[c]` is core `c`'s socket id.
    /// Socket ids must be contiguous from 0 (see
    /// [`crate::cpu::topology::socket_map`]).
    pub fn new_numa(policy: PolicyKind, params: SchedParams, socket_of: Vec<usize>) -> Self {
        Self::with_capability(policy, params, socket_of, None)
    }

    /// Hybrid-aware scheduler: `capable[c]` says whether core `c` has the
    /// AVX-512 path (P-core). Installing a mask turns on *confinement* —
    /// AVX-typed tasks never become eligible on incapable cores, and the
    /// stock (`Unmodified`) policy keeps typed queues so the constraint
    /// is enforceable at all.
    pub fn new_hybrid(
        policy: PolicyKind,
        params: SchedParams,
        socket_of: Vec<usize>,
        capable: Vec<bool>,
    ) -> Self {
        assert_eq!(capable.len(), socket_of.len(), "capability mask must cover every core");
        Self::with_capability(policy, params, socket_of, Some(capable))
    }

    fn with_capability(
        policy: PolicyKind,
        params: SchedParams,
        socket_of: Vec<usize>,
        capable: Option<Vec<bool>>,
    ) -> Self {
        let n_cores = socket_of.len();
        let scan_order = build_scan_order(&socket_of);
        let wake_order = build_wake_order(&socket_of);
        let avx_set = capable.as_ref().and_then(|cap| remap_avx_set(&policy, &socket_of, cap));
        Scheduler {
            policy,
            params,
            n_cores,
            rq: (0..n_cores).map(|_| CoreQueues::default()).collect(),
            entities: Vec::new(),
            queued_at: Vec::new(),
            running: vec![None; n_cores],
            socket_of,
            scan_order,
            wake_order,
            avx_capable: capable,
            avx_set,
            stats: SchedStats::default(),
        }
    }

    /// Is the hybrid capability mask installed?
    fn confined(&self) -> bool {
        self.avx_capable.is_some()
    }

    /// Effective AVX-core membership: the remapped hybrid set when
    /// installed, the policy's own arithmetic otherwise.
    fn core_is_avx(&self, core: usize) -> bool {
        match &self.avx_set {
            Some(set) => set[core],
            None => self.policy.is_avx_core(core, self.n_cores),
        }
    }

    /// May `core` run a task of `ttype`? Capability first (AVX work never
    /// lands on an incapable core), then the policy — over the remapped
    /// set when one is installed.
    fn core_eligible(&self, core: usize, ttype: TaskType) -> bool {
        if ttype == TaskType::Avx {
            if let Some(cap) = &self.avx_capable {
                if !cap[core] {
                    return false;
                }
            }
        }
        if let Some(set) = &self.avx_set {
            return match self.policy {
                PolicyKind::StrictPartition { .. } => match ttype {
                    TaskType::Avx => set[core],
                    TaskType::Scalar => !set[core],
                    TaskType::Untyped => true,
                },
                _ => match ttype {
                    TaskType::Avx => set[core],
                    _ => true,
                },
            };
        }
        // Confined Unmodified has no remapped set: the capability gate
        // above is its only constraint.
        if matches!(self.policy, PolicyKind::Unmodified) {
            return true;
        }
        self.policy.eligible(core, self.n_cores, ttype)
    }

    /// Deadline penalty `core` applies to a task of `ttype`, over the
    /// remapped AVX set when one is installed.
    fn core_penalty(&self, core: usize, ttype: TaskType) -> Time {
        match &self.avx_set {
            Some(set) => match self.policy {
                PolicyKind::CoreSpec { .. }
                | PolicyKind::CoreSpecNuma { .. }
                | PolicyKind::ClassNative { .. }
                    if ttype == TaskType::Scalar && set[core] =>
                {
                    SCALAR_ON_AVX_PENALTY
                }
                _ => 0,
            },
            None => self.policy.deadline_penalty(core, self.n_cores, ttype),
        }
    }

    pub fn n_cores(&self) -> usize {
        self.n_cores
    }

    /// Socket (NUMA node) of `core`.
    pub fn socket_of(&self, core: usize) -> usize {
        self.socket_of[core]
    }

    pub fn entity(&self, t: TaskId) -> &SchedEntity {
        &self.entities[t.0]
    }

    pub fn entity_mut(&mut self, t: TaskId) -> &mut SchedEntity {
        &mut self.entities[t.0]
    }

    pub fn running_on(&self, core: usize) -> Option<TaskId> {
        self.running[core]
    }

    pub fn n_tasks(&self) -> usize {
        self.entities.len()
    }

    /// Total runnable (queued) tasks.
    pub fn queued_count(&self) -> usize {
        self.rq.iter().map(|c| c.queues.iter().map(|q| q.len()).sum::<usize>()).sum()
    }

    /// Register a new task (initially blocked).
    pub fn add_task(&mut self, ttype: TaskType, nice: i32) -> TaskId {
        let id = TaskId(self.entities.len());
        self.entities.push(SchedEntity::new(id, ttype, nice));
        self.queued_at.push(None);
        id
    }

    /// Queue index a task of this type uses. Under `Unmodified` all tasks
    /// live in the untyped queue (the stock scheduler has one queue per
    /// core; using index 2 for everything models that exactly) — *unless*
    /// a capability mask is installed: on a hybrid part even the stock
    /// kernel distinguishes AVX-512 tasks (the 512-bit path simply does
    /// not exist on an E-core), so typed queues stay on to make the
    /// capability constraint enforceable.
    fn queue_index(&self, ttype: TaskType) -> usize {
        match self.policy {
            PolicyKind::Unmodified if !self.confined() => TaskType::Untyped.queue_index(),
            _ => ttype.queue_index(),
        }
    }

    /// Effective deadline of a queue-head entry from `core`'s viewpoint
    /// (kept for diagnostics; the pick fast path inlines this).
    #[allow(dead_code)]
    fn effective(&self, core: usize, qi: usize, key: Key) -> u128 {
        let ttype = match qi {
            0 => TaskType::Scalar,
            1 => TaskType::Avx,
            _ => TaskType::Untyped,
        };
        key.vdeadline as u128 + self.core_penalty(core, ttype) as u128
    }

    fn eligible_queue(&self, core: usize, qi: usize) -> bool {
        let ttype = match qi {
            0 => TaskType::Scalar,
            1 => TaskType::Avx,
            _ => TaskType::Untyped,
        };
        match self.policy {
            PolicyKind::Unmodified if !self.confined() => qi == 2,
            _ => self.core_eligible(core, ttype),
        }
    }

    /// Enqueue a runnable task on its home core's queue (last core it ran
    /// on, or `fallback`). Returns the wake target for preemption checks.
    ///
    /// `reserved` reports cores that are idle but already have a dispatch
    /// pending (the machine's Step event), so two wakes at the same
    /// instant fan out to different cores. `exclude` skips the core the
    /// task was just requeued from — that core is about to reschedule
    /// anyway.
    pub fn enqueue(
        &mut self,
        now: Time,
        task: TaskId,
        fallback: usize,
        reserved: &dyn Fn(usize) -> bool,
        exclude: Option<usize>,
    ) -> WakeTarget {
        let ttype = self.entities[task.0].ttype;
        let qi = self.queue_index(ttype);
        let home = self.entities[task.0].last_core.unwrap_or(fallback).min(self.n_cores - 1);
        if self.entities[task.0].vdeadline == 0 {
            let rr = self.params.rr_interval;
            self.entities[task.0].refresh_deadline(now, rr);
        }
        let key = self.rq[home].queues[qi].insert(self.entities[task.0].vdeadline, task);
        self.queued_at[task.0] = Some((home, qi, key));
        self.entities[task.0].state = RunState::Queued(home);
        self.wake_target(task, ttype, home, reserved, exclude)
    }

    /// Decide whether the newly queued `task` should trigger a dispatch or
    /// an IPI (§3.2's preemption path). Idle cores are searched in
    /// `home`'s NUMA scan order, so a waking task prefers an idle core on
    /// its own socket before waking a remote one.
    fn wake_target(
        &mut self,
        task: TaskId,
        ttype: TaskType,
        home: usize,
        reserved: &dyn Fn(usize) -> bool,
        exclude: Option<usize>,
    ) -> WakeTarget {
        let deadline = self.entities[task.0].vdeadline;
        // Idle eligible core?
        let effective_type = match self.policy {
            PolicyKind::Unmodified if !self.confined() => TaskType::Untyped,
            _ => ttype,
        };
        for i in 0..self.n_cores {
            let core = self.wake_order[home][i];
            if Some(core) != exclude
                && self.running[core].is_none()
                && !reserved(core)
                && self.core_eligible(core, effective_type)
            {
                return WakeTarget::DispatchIdle(core);
            }
        }
        // Busy core running something with a later effective deadline?
        // From the viewpoint of an eligible core, the new task's effective
        // deadline carries its own penalty too — including the NUMA steal
        // penalty a remote-socket core would apply at pick time, so we
        // never IPI a core that would then refuse to take the task.
        let home_socket = self.socket_of[home];
        let mut best: Option<(u128, usize)> = None;
        for core in 0..self.n_cores {
            if Some(core) == exclude || !self.core_eligible(core, effective_type) {
                continue;
            }
            let Some(cur) = self.running[core] else { continue };
            let cur_e = &self.entities[cur.0];
            let cur_type = match self.policy {
                PolicyKind::Unmodified if !self.confined() => TaskType::Untyped,
                _ => cur_e.ttype,
            };
            let cur_eff =
                cur_e.vdeadline as u128 + self.core_penalty(core, cur_type) as u128;
            let mut new_eff =
                deadline as u128 + self.core_penalty(core, effective_type) as u128;
            if self.socket_of[core] != home_socket {
                new_eff += self.params.numa_steal_penalty as u128;
            }
            if new_eff < cur_eff {
                let margin = cur_eff - new_eff;
                if best.map(|(m, _)| margin > m).unwrap_or(true) {
                    best = Some((margin, core));
                }
            }
        }
        match best {
            Some((_, core)) => {
                self.stats.ipis += 1;
                WakeTarget::Preempt(core)
            }
            None => WakeTarget::Queued,
        }
    }

    /// Remove a queued task (reserved for future explicit-dequeue paths).
    #[allow(dead_code)]
    fn dequeue(&mut self, task: TaskId) {
        if let Some((core, qi, key)) = self.queued_at[task.0].take() {
            let removed = self.rq[core].queues[qi].remove(key);
            debug_assert!(removed, "task {task:?} not found in queue");
        }
    }

    /// Core `core` picks its next task: the earliest effective deadline
    /// over all queues it may use, across all cores (stealing). The scan
    /// walks the core's NUMA order — own queues, same-socket cores, then
    /// remote sockets — and queues on a remote socket carry the
    /// `numa_steal_penalty` on top of any policy penalty, so same-node
    /// work (in particular same-node AVX work for an AVX core) wins
    /// unless the remote task's deadline is substantially earlier.
    pub fn pick(&mut self, now: Time, core: usize) -> Option<TaskId> {
        self.stats.picks += 1;
        let mut best: Option<(u128, usize, usize, Key, TaskId)> = None;
        // Eligibility and penalties depend only on the *picking* core —
        // hoist them out of the scan.
        let mut eligible = [false; 3];
        let mut penalty = [0u128; 3];
        for (qi, (e, p)) in eligible.iter_mut().zip(penalty.iter_mut()).enumerate() {
            *e = self.eligible_queue(core, qi);
            let ttype = match qi {
                0 => TaskType::Scalar,
                1 => TaskType::Avx,
                _ => TaskType::Untyped,
            };
            *p = self.core_penalty(core, ttype) as u128;
        }
        let my_socket = self.socket_of[core];
        // Local queues first (ties go to local because of strict `<`).
        let n = if self.params.steal { self.n_cores } else { 1 };
        for i in 0..n {
            let c = self.scan_order[core][i];
            let numa = if self.socket_of[c] == my_socket {
                0u128
            } else {
                self.params.numa_steal_penalty as u128
            };
            for qi in 0..3 {
                if !eligible[qi] {
                    continue;
                }
                if let Some((key, task)) = self.rq[c].queues[qi].peek() {
                    let eff = key.vdeadline as u128 + penalty[qi] + numa;
                    if best.map(|(b, ..)| eff < b).unwrap_or(true) {
                        best = Some((eff, c, qi, key, task));
                    }
                }
            }
        }
        let (_, from_core, qi, key, task) = best?;
        let removed = self.rq[from_core].queues[qi].remove(key);
        debug_assert!(removed);
        self.queued_at[task.0] = None;
        if from_core != core {
            self.stats.steals += 1;
        }
        let e = &mut self.entities[task.0];
        if let Some(last) = e.last_core {
            if last != core {
                e.migrations += 1;
                self.stats.migrations += 1;
                if self.socket_of[last] != my_socket {
                    self.stats.cross_socket_migrations += 1;
                }
            }
        }
        e.last_core = Some(core);
        e.state = RunState::Running(core);
        self.running[core] = Some(task);
        let _ = now;
        Some(task)
    }

    /// Extra dispatch cost for `task` starting on `core` (migration).
    pub fn dispatch_cost(&self, task: TaskId, core: usize) -> Time {
        // last_core has already been updated by pick; cost is decided by
        // whether this dispatch was counted as a migration — callers ask
        // before running, so compare against the entity's migration flag
        // via last_core (== core after pick). We instead expose the cost
        // knob directly; the machine charges it when pick reports a
        // migration through `took_migration`.
        let _ = (task, core);
        self.params.migration_cost
    }

    /// The running task on `core` gives up the CPU (blocked/exited).
    pub fn block_running(&mut self, core: usize) -> Option<TaskId> {
        let t = self.running[core].take()?;
        self.entities[t.0].state = RunState::Blocked;
        Some(t)
    }

    /// The running task on `core` is preempted or quantum-expired: requeue.
    pub fn requeue_running(
        &mut self,
        now: Time,
        core: usize,
        refresh: bool,
        reserved: &dyn Fn(usize) -> bool,
    ) -> Option<WakeTarget> {
        let t = self.running[core].take()?;
        if refresh {
            let rr = self.params.rr_interval;
            self.entities[t.0].refresh_deadline(now, rr);
        }
        self.stats.preemptions += u64::from(!refresh);
        Some(self.enqueue(now, t, core, reserved, Some(core)))
    }

    /// Mark a task exited.
    pub fn exit_running(&mut self, core: usize) -> Option<TaskId> {
        let t = self.running[core].take()?;
        self.entities[t.0].state = RunState::Exited;
        Some(t)
    }

    /// The `with_avx()` / `without_avx()` syscall (§3.2), called for the
    /// task currently running on `core`. Under `Unmodified` the syscall
    /// does not exist and this is never invoked.
    pub fn set_task_type(&mut self, now: Time, core: usize, new_type: TaskType) -> TypeChangeOutcome {
        // A fault window can vacate a core out from under the workload
        // layer (a machine restart discards running state); a
        // type-change syscall arriving for an idle core is a no-op,
        // not a scheduler panic.
        let Some(task) = self.running[core] else {
            return TypeChangeOutcome::Continue;
        };
        let e = &mut self.entities[task.0];
        if e.ttype == new_type {
            return TypeChangeOutcome::Continue;
        }
        e.ttype = new_type;
        e.type_changes += 1;
        self.stats.type_changes += 1;
        let _ = now;
        if matches!(self.policy, PolicyKind::Unmodified) && !self.confined() {
            return TypeChangeOutcome::Continue;
        }
        // If the current core may no longer run this task type, the thread
        // is suspended immediately and the core schedules something else.
        // (For confined `Unmodified` this is the capability check and
        // nothing more: the stock policy never yields a core to queued
        // AVX work, it only refuses to run 512-bit code where no 512-bit
        // path exists.)
        if !self.core_eligible(core, new_type) {
            self.stats.forced_suspends += 1;
            return TypeChangeOutcome::SuspendSelf;
        }
        if matches!(self.policy, PolicyKind::Unmodified) {
            return TypeChangeOutcome::Continue;
        }
        // `without_avx()` on an AVX core "reverts the task type change and
        // potentially migrates the task to a scalar core" (Fig 4): if AVX
        // work is runnable anywhere this core could take it from, yield the
        // core — scalar work must not occupy an AVX core while AVX tasks
        // queue (§3.1: AVX cores only run scalar tasks when nothing else
        // is available).
        if new_type == TaskType::Scalar && self.core_is_avx(core) && self.avx_work_runnable() {
            self.stats.forced_suspends += 1;
            return TypeChangeOutcome::SuspendSelf;
        }
        TypeChangeOutcome::Continue
    }

    /// Any runnable AVX task on any runqueue (AVX cores steal globally).
    fn avx_work_runnable(&self) -> bool {
        let qi = TaskType::Avx.queue_index();
        self.rq.iter().any(|c| !c.queues[qi].is_empty())
    }

    /// Diagnostic: all queued + running task ids per type (invariant checks).
    pub fn debug_census(&self) -> [usize; 3] {
        let mut counts = [0usize; 3];
        for c in &self.rq {
            for (qi, q) in c.queues.iter().enumerate() {
                counts[qi] += q.len();
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    fn sched(policy: PolicyKind, cores: usize) -> Scheduler {
        Scheduler::new(policy, SchedParams::default(), cores)
    }

    #[test]
    fn pick_earliest_deadline() {
        let mut s = sched(PolicyKind::Unmodified, 2);
        let a = s.add_task(TaskType::Untyped, 0);
        let b = s.add_task(TaskType::Untyped, -5); // lower nice → earlier deadline
        s.enqueue(0, a, 0, &|_| false, None);
        s.enqueue(0, b, 0, &|_| false, None);
        let picked = s.pick(0, 0).unwrap();
        assert_eq!(picked, b);
    }

    #[test]
    fn scalar_core_never_picks_avx_task() {
        let mut s = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 4);
        let avx = s.add_task(TaskType::Avx, 0);
        s.enqueue(0, avx, 0, &|_| false, None);
        for scalar_core in 0..3 {
            assert!(s.pick(0, scalar_core).is_none(), "core {scalar_core} must not pick AVX");
        }
        assert_eq!(s.pick(0, 3), Some(avx), "AVX core takes it");
    }

    #[test]
    fn avx_core_prefers_avx_over_scalar() {
        let mut s = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 2);
        let scalar = s.add_task(TaskType::Scalar, -10); // much earlier deadline
        let avx = s.add_task(TaskType::Avx, 10); // later deadline
        s.enqueue(0, scalar, 1, &|_| false, None);
        s.enqueue(0, avx, 1, &|_| false, None);
        assert_eq!(s.pick(0, 1), Some(avx), "penalty must trump deadline");
        // Scalar still runnable by the AVX core when nothing else is left.
        assert_eq!(s.pick(0, 1), Some(scalar));
    }

    #[test]
    fn untyped_not_starved_on_avx_core() {
        let mut s = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 1);
        let avx = s.add_task(TaskType::Avx, 0);
        let sys = s.add_task(TaskType::Untyped, 0);
        s.enqueue(0, avx, 0, &|_| false, None);
        s.enqueue(1, sys, 0, &|_| false, None);
        // Both compete at unpenalized deadlines; earlier wins.
        let first = s.pick(0, 0).unwrap();
        assert_eq!(first, avx, "earlier enqueue wins, no starvation offset");
        assert_eq!(s.pick(0, 0), Some(sys));
    }

    #[test]
    fn stealing_moves_tasks_across_cores() {
        let mut s = sched(PolicyKind::Unmodified, 2);
        let t = s.add_task(TaskType::Untyped, 0);
        s.enqueue(0, t, 0, &|_| false, None); // queued on core 0
        let picked = s.pick(0, 1).unwrap(); // core 1 steals
        assert_eq!(picked, t);
        assert_eq!(s.stats.steals, 1);
        assert_eq!(s.entity(t).last_core, Some(1));
    }

    #[test]
    fn steal_disabled_keeps_task_local() {
        let mut s = Scheduler::new(
            PolicyKind::Unmodified,
            SchedParams { steal: false, ..Default::default() },
            2,
        );
        let t = s.add_task(TaskType::Untyped, 0);
        s.enqueue(0, t, 0, &|_| false, None);
        assert!(s.pick(0, 1).is_none());
        assert_eq!(s.pick(0, 0), Some(t));
    }

    #[test]
    fn type_change_on_scalar_core_suspends() {
        let mut s = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 4);
        let t = s.add_task(TaskType::Scalar, 0);
        s.enqueue(0, t, 0, &|_| false, None);
        assert_eq!(s.pick(0, 0), Some(t));
        let out = s.set_task_type(10, 0, TaskType::Avx);
        assert_eq!(out, TypeChangeOutcome::SuspendSelf);
        assert_eq!(s.stats.forced_suspends, 1);
    }

    #[test]
    fn type_change_on_avx_core_continues() {
        let mut s = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 4);
        let t = s.add_task(TaskType::Scalar, 0);
        s.enqueue(0, t, 3, &|_| false, None);
        assert_eq!(s.pick(0, 3), Some(t));
        assert_eq!(s.set_task_type(10, 3, TaskType::Avx), TypeChangeOutcome::Continue);
        // And back: AVX→scalar may also continue (migration happens via
        // normal load balancing).
        assert_eq!(s.set_task_type(20, 3, TaskType::Scalar), TypeChangeOutcome::Continue);
    }

    /// Regression for the fault era: a type-change syscall landing on a
    /// core a restart vacated must be a no-op, not a scheduler panic.
    #[test]
    fn type_change_on_vacated_core_is_a_noop() {
        let mut s = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 2);
        assert!(s.running[0].is_none(), "core 0 starts idle");
        assert_eq!(s.set_task_type(10, 0, TaskType::Avx), TypeChangeOutcome::Continue);
        assert_eq!(s.stats.type_changes, 0, "no task, no type change recorded");
        assert_eq!(s.stats.forced_suspends, 0);
    }

    /// Regression: the per-core scan order must be built (covering every
    /// core exactly once) even for degenerate socket maps — sparse,
    /// non-contiguous socket ids must not panic the constructor.
    #[test]
    fn scan_order_tolerates_sparse_socket_ids() {
        let order = build_scan_order(&[5, 5, 9]);
        assert_eq!(order.len(), 3);
        for (core, o) in order.iter().enumerate() {
            let mut seen = o.clone();
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2], "core {core} scan must cover every core once");
            assert_eq!(o[0], core, "scan starts at the owning core");
        }
    }

    #[test]
    fn wake_prefers_idle_core_then_preempts() {
        let mut s = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 2);
        // Occupy the AVX core (core 1) with a scalar task.
        let filler = s.add_task(TaskType::Scalar, 0);
        s.enqueue(0, filler, 1, &|_| false, None);
        assert_eq!(s.pick(0, 1), Some(filler));
        // Waking an AVX task: core 0 is idle but ineligible → must IPI core 1.
        let avx = s.add_task(TaskType::Avx, 0);
        match s.enqueue(MS, avx, 0, &|_| false, None) {
            WakeTarget::Preempt(core) => assert_eq!(core, 1),
            other => panic!("expected preempt, got {other:?}"),
        }
        assert_eq!(s.stats.ipis, 1);
    }

    #[test]
    fn wake_dispatches_to_idle_eligible_core() {
        let mut s = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 2);
        let t = s.add_task(TaskType::Scalar, 0);
        match s.enqueue(0, t, 0, &|_| false, None) {
            WakeTarget::DispatchIdle(c) => assert_eq!(c, 0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unmodified_ignores_types_single_queue() {
        let mut s = sched(PolicyKind::Unmodified, 2);
        let avx = s.add_task(TaskType::Avx, 0);
        s.enqueue(0, avx, 0, &|_| false, None);
        // Any core may run it; it lives in the untyped queue.
        assert_eq!(s.debug_census(), [0, 0, 1]);
        assert_eq!(s.pick(0, 0), Some(avx));
    }

    #[test]
    fn requeue_refresh_pushes_deadline() {
        let mut s = sched(PolicyKind::Unmodified, 1);
        let t = s.add_task(TaskType::Untyped, 0);
        s.enqueue(0, t, 0, &|_| false, None);
        s.pick(0, 0);
        let d0 = s.entity(t).vdeadline;
        s.requeue_running(10 * MS, 0, true, &|_| false);
        assert!(s.entity(t).vdeadline > d0);
    }

    #[test]
    fn strict_partition_blocks_scalar_from_avx_core() {
        let mut s = sched(PolicyKind::StrictPartition { avx_cores: 1 }, 2);
        let t = s.add_task(TaskType::Scalar, 0);
        s.enqueue(0, t, 1, &|_| false, None);
        assert!(s.pick(0, 1).is_none(), "AVX core must not pick scalar under strict");
        assert_eq!(s.pick(0, 0), Some(t));
    }

    /// 4 cores over 2 sockets: cores 0,1 on socket 0; cores 2,3 on socket 1.
    fn numa_sched(policy: PolicyKind) -> Scheduler {
        Scheduler::new_numa(policy, SchedParams::default(), vec![0, 0, 1, 1])
    }

    #[test]
    fn scan_order_visits_own_socket_first() {
        let s = numa_sched(PolicyKind::Unmodified);
        assert_eq!(s.scan_order[0], vec![0, 1, 2, 3]);
        assert_eq!(s.scan_order[1], vec![1, 0, 2, 3]);
        assert_eq!(s.scan_order[2], vec![2, 3, 0, 1]);
        assert_eq!(s.scan_order[3], vec![3, 2, 0, 1]);
        // Wake order: same-socket ascending (no rotation), then remote.
        assert_eq!(s.wake_order[2], vec![2, 3, 0, 1]);
        assert_eq!(s.wake_order[3], vec![2, 3, 0, 1]);
    }

    #[test]
    fn single_socket_scan_order_matches_historical_rotation() {
        let s = sched(PolicyKind::Unmodified, 4);
        for core in 0..4 {
            let want: Vec<usize> = (0..4).map(|i| (core + i) % 4).collect();
            assert_eq!(s.scan_order[core], want, "core {core}");
            // Historical wakeup scan was `0..n_cores` for every waker.
            assert_eq!(s.wake_order[core], vec![0, 1, 2, 3], "core {core}");
        }
    }

    #[test]
    fn numa_steal_prefers_local_socket_despite_earlier_remote_deadline() {
        let mut s = numa_sched(PolicyKind::Unmodified);
        let remote = s.add_task(TaskType::Untyped, 0);
        let local = s.add_task(TaskType::Untyped, 0);
        // Force deadlines: remote slightly earlier than local, but by
        // less than the NUMA steal penalty.
        s.entity_mut(remote).vdeadline = 1_000_000;
        s.entity_mut(local).vdeadline = 1_000_500;
        s.enqueue(0, remote, 0, &|_| false, None); // queued on socket 0
        s.enqueue(0, local, 2, &|_| false, None); // queued on socket 1
        assert_eq!(s.pick(0, 3), Some(local), "core 3 must keep work on its node");
        assert_eq!(s.stats.cross_socket_migrations, 0);
    }

    #[test]
    fn numa_steal_crosses_sockets_when_remote_deadline_much_earlier() {
        let mut s = numa_sched(PolicyKind::Unmodified);
        let remote = s.add_task(TaskType::Untyped, 0);
        let local = s.add_task(TaskType::Untyped, 0);
        let penalty = s.params.numa_steal_penalty;
        s.entity_mut(remote).vdeadline = 1_000_000;
        s.entity_mut(local).vdeadline = 1_000_000 + penalty + 1_000_000;
        s.enqueue(0, remote, 0, &|_| false, None);
        s.enqueue(0, local, 2, &|_| false, None);
        assert_eq!(s.pick(0, 3), Some(remote), "far-earlier remote work must still be stolen");
        assert_eq!(s.stats.steals, 1);
    }

    #[test]
    fn cross_socket_migration_counted() {
        let mut s = numa_sched(PolicyKind::Unmodified);
        let t = s.add_task(TaskType::Untyped, 0);
        s.enqueue(0, t, 0, &|_| false, None);
        assert_eq!(s.pick(0, 0), Some(t)); // first dispatch: no migration
        s.requeue_running(10, 0, true, &|_| false);
        // Same-socket migration: counted, but not cross-socket.
        assert_eq!(s.pick(20, 1), Some(t));
        assert_eq!(s.stats.migrations, 1);
        assert_eq!(s.stats.cross_socket_migrations, 0);
        s.requeue_running(30, 1, true, &|_| false);
        // Cross-socket migration: both counters move.
        assert_eq!(s.pick(40, 3), Some(t));
        assert_eq!(s.stats.migrations, 2);
        assert_eq!(s.stats.cross_socket_migrations, 1);
    }

    #[test]
    fn wake_prefers_idle_core_on_home_socket() {
        let mut s = numa_sched(PolicyKind::Unmodified);
        let t = s.add_task(TaskType::Untyped, 0);
        // Home = fallback core 3 (socket 1); all cores idle, so the wake
        // scan must offer a socket-1 core (lowest id first).
        match s.enqueue(0, t, 3, &|_| false, None) {
            WakeTarget::DispatchIdle(c) => assert_eq!(c, 2),
            other => panic!("{other:?}"),
        }
        // With socket 1 reserved, the wake falls over to socket 0.
        let u = s.add_task(TaskType::Untyped, 0);
        match s.enqueue(0, u, 2, &|c| c >= 2, None) {
            WakeTarget::DispatchIdle(c) => assert!(c < 2, "got {c}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wake_does_not_ipi_remote_core_that_would_refuse_the_task() {
        // One core per socket, both busy; the remote core's task has a
        // later deadline, but by less than the NUMA steal penalty — an
        // IPI would make it reschedule and then pick its own task right
        // back. The wake must stay Queued instead.
        let mut s =
            Scheduler::new_numa(PolicyKind::Unmodified, SchedParams::default(), vec![0, 1]);
        let local_run = s.add_task(TaskType::Untyped, 0);
        let remote_run = s.add_task(TaskType::Untyped, 0);
        s.enqueue(0, local_run, 0, &|_| false, None);
        s.enqueue(0, remote_run, 1, &|_| false, None);
        assert_eq!(s.pick(0, 0), Some(local_run));
        assert_eq!(s.pick(0, 1), Some(remote_run));
        // Local runner is earlier than the new task (no preemption on
        // socket 0); remote runner is later, but within the penalty.
        s.entity_mut(local_run).vdeadline = 1_000_000;
        s.entity_mut(remote_run).vdeadline = 2_000_000;
        let new = s.add_task(TaskType::Untyped, 0);
        s.entity_mut(new).vdeadline = 1_500_000;
        match s.enqueue(0, new, 0, &|_| false, None) {
            WakeTarget::Queued => {}
            other => panic!("expected Queued, got {other:?}"),
        }
        assert_eq!(s.stats.ipis, 0);
        // A far-later remote deadline (beyond the penalty) is preempted.
        s.entity_mut(remote_run).vdeadline =
            1_500_000 + s.params.numa_steal_penalty + 1_000_000;
        let new2 = s.add_task(TaskType::Untyped, 0);
        s.entity_mut(new2).vdeadline = 1_500_000;
        match s.enqueue(0, new2, 0, &|_| false, None) {
            WakeTarget::Preempt(c) => assert_eq!(c, 1),
            other => panic!("expected Preempt(1), got {other:?}"),
        }
    }

    #[test]
    fn numa_corespec_keeps_avx_on_socket_avx_cores() {
        // 2 sockets × 2 cores, one AVX core per socket (cores 1 and 3).
        let mut s = Scheduler::new_numa(
            PolicyKind::CoreSpecNuma { avx_cores_per_socket: 1, sockets: 2 },
            SchedParams::default(),
            vec![0, 0, 1, 1],
        );
        let avx = s.add_task(TaskType::Avx, 0);
        s.enqueue(0, avx, 0, &|_| false, None);
        assert!(s.pick(0, 0).is_none(), "scalar core 0 must not pick AVX");
        assert!(s.pick(0, 2).is_none(), "scalar core 2 must not pick AVX");
        assert_eq!(s.pick(0, 1), Some(avx), "socket-0 AVX core takes it");
    }

    /// 2P+2E on one socket: cores 0,1 capable; cores 2,3 not.
    fn hybrid_sched(policy: PolicyKind) -> Scheduler {
        Scheduler::new_hybrid(
            policy,
            SchedParams::default(),
            vec![0, 0, 0, 0],
            vec![true, true, false, false],
        )
    }

    #[test]
    fn hybrid_corespec_remaps_avx_set_onto_p_cores() {
        // CoreSpec's "last 2 cores" would be the E-cores; the remap must
        // land the AVX set on the capable list instead: cores {0, 1}.
        let mut s = hybrid_sched(PolicyKind::CoreSpec { avx_cores: 2 });
        assert_eq!(s.avx_set, Some(vec![true, true, false, false]));
        let avx = s.add_task(TaskType::Avx, 0);
        s.enqueue(0, avx, 0, &|_| false, None);
        assert!(s.pick(0, 2).is_none(), "E-core must not pick AVX");
        assert!(s.pick(0, 3).is_none(), "E-core must not pick AVX");
        assert_eq!(s.pick(0, 0), Some(avx), "remapped AVX core takes it");
        // Scalar work pays the AVX-core penalty on the remapped set: an
        // AVX task with a later deadline still wins on core 1.
        let scalar = s.add_task(TaskType::Scalar, -10);
        let avx2 = s.add_task(TaskType::Avx, 10);
        s.enqueue(0, scalar, 1, &|_| false, None);
        s.enqueue(0, avx2, 1, &|_| false, None);
        assert_eq!(s.pick(0, 1), Some(avx2), "penalty must follow the remap");
    }

    #[test]
    fn hybrid_unmodified_confines_avx_to_capable_cores() {
        // Even the stock policy keeps typed queues under confinement, and
        // AVX work never lands on an E-core — but scalar work still runs
        // anywhere, and nothing else changes.
        let mut s = hybrid_sched(PolicyKind::Unmodified);
        assert!(s.avx_set.is_none(), "Unmodified has no remapped set");
        let avx = s.add_task(TaskType::Avx, 0);
        s.enqueue(0, avx, 0, &|_| false, None);
        assert_eq!(s.debug_census(), [0, 1, 0], "typed queues stay on");
        assert!(s.pick(0, 2).is_none(), "E-core must not pick AVX");
        assert_eq!(s.pick(0, 1), Some(avx));
        // Scalar → AVX transition on an E-core suspends (no 512-bit path);
        // on a P-core it continues.
        let t = s.add_task(TaskType::Scalar, 0);
        s.enqueue(0, t, 3, &|_| false, None);
        assert_eq!(s.pick(0, 3), Some(t));
        assert_eq!(s.set_task_type(10, 3, TaskType::Avx), TypeChangeOutcome::SuspendSelf);
    }

    #[test]
    fn hybrid_class_native_uses_the_hardware_partition() {
        let mut s = hybrid_sched(PolicyKind::ClassNative { p_cores: 2 });
        assert_eq!(s.avx_set, Some(vec![true, true, false, false]));
        let avx = s.add_task(TaskType::Avx, 0);
        s.enqueue(0, avx, 0, &|_| false, None);
        assert!(s.pick(0, 2).is_none());
        assert_eq!(s.pick(0, 0), Some(avx));
        // Untyped work remains runnable everywhere.
        let u = s.add_task(TaskType::Untyped, 0);
        s.enqueue(0, u, 2, &|_| false, None);
        assert_eq!(s.pick(0, 2), Some(u));
    }

    #[test]
    fn hybrid_wake_never_targets_an_incapable_core_for_avx() {
        let mut s = hybrid_sched(PolicyKind::CoreSpec { avx_cores: 2 });
        // All P-cores busy with AVX work; waking another AVX task must not
        // dispatch to the idle E-cores.
        for core in 0..2 {
            let t = s.add_task(TaskType::Avx, 0);
            s.enqueue(0, t, core, &|_| false, None);
            assert_eq!(s.pick(0, core), Some(t));
        }
        let w = s.add_task(TaskType::Avx, 0);
        match s.enqueue(MS, w, 2, &|_| false, None) {
            WakeTarget::DispatchIdle(c) => panic!("dispatched AVX to idle E-core {c}"),
            WakeTarget::Preempt(c) => assert!(c < 2, "preempted incapable core {c}"),
            WakeTarget::Queued => {}
        }
    }

    #[test]
    fn homogeneous_hybrid_mask_changes_nothing() {
        // An all-capable mask remaps CoreSpec's set onto… the same last-K
        // cores, so every decision matches the unmasked scheduler.
        let mut a = sched(PolicyKind::CoreSpec { avx_cores: 1 }, 4);
        let mut b = Scheduler::new_hybrid(
            PolicyKind::CoreSpec { avx_cores: 1 },
            SchedParams::default(),
            vec![0; 4],
            vec![true; 4],
        );
        assert_eq!(b.avx_set, Some(vec![false, false, false, true]));
        for s in [&mut a, &mut b] {
            let avx = s.add_task(TaskType::Avx, 0);
            s.enqueue(0, avx, 0, &|_| false, None);
            assert!(s.pick(0, 0).is_none());
            assert_eq!(s.pick(0, 3), Some(avx));
        }
    }
}
