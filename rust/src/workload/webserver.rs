//! The paper's §4 evaluation workload: nginx serving a (optionally
//! brotli-compressed) static page over HTTPS with OpenSSL
//! ChaCha20-Poly1305, compiled for SSE4 / AVX2 / AVX-512.
//!
//! Worker tasks execute the per-request pipeline as instruction blocks;
//! with `annotate = true`, the SSL entry points are wrapped in
//! `with_avx()` / `without_avx()` exactly like the paper's 9-line nginx
//! patch (SSL_read, SSL_write, SSL_do_handshake, SSL_shutdown).

use super::client::{
    FaultTraceDriver, LoadMode, ServerShared, Shared, TraceDriver, TrafficDriver, DEFAULT_SLO,
};
use super::compress::CompressProfile;
use super::crypto::{CryptoProfile, Isa};
use crate::analysis::flamegraph::StackTable;
use crate::cpu::{GovernorSpec, PowerParams};
use crate::isa::block::{Block, ClassMix};
use crate::isa::{Binary, Function};
use crate::sched::machine::{Action, Driver, ForkCtx, Machine, MachineParams, TaskBody};
use crate::sched::{PolicyKind, TaskType};
use crate::sim::{Time, MS, SEC};
use crate::tpc::{Reactor, TpcJob, TpcRuntime};
use crate::traffic::{ArrivalProcess, LatencyStats, RecorderArena, Request, TailSummary};
use crate::util::Rng;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

/// Scenario configuration.
#[derive(Clone, Debug)]
pub struct WebCfg {
    pub isa: Isa,
    /// Compress the page on the fly (the paper's main scenario).
    pub compress: bool,
    /// Uncompressed page size in bytes.
    pub page_bytes: usize,
    /// Emit `with_avx()`/`without_avx()` around SSL calls.
    pub annotate: bool,
    pub policy: PolicyKind,
    /// Worker tasks (nginx: 2 per physical core ≈ one per hw thread).
    pub workers: usize,
    /// Physical server cores (paper: 12 of 16).
    pub cores: usize,
    /// Sockets (NUMA nodes / frequency domains) the server cores span;
    /// 1 = the paper's single-socket machine.
    pub sockets: usize,
    /// Hybrid P/E core layout (`None` = the paper's homogeneous part).
    /// When the spec has E-cores, `cores` must equal its total and
    /// AVX-512 runs are forced to annotate (the scheduler needs typed
    /// work to keep 512-bit code off the E-cores).
    pub hybrid: Option<crate::cpu::HybridSpec>,
    pub mode: LoadMode,
    /// Latency SLO threshold (ns) for the violation-fraction metric.
    pub slo: Time,
    /// Full TLS handshake every N requests per connection (keepalive).
    pub handshake_every: u64,
    pub seed: u64,
    /// Simulated warmup before measurement.
    pub warmup: Time,
    /// Measurement window.
    pub measure: Time,
    /// Collect flame-graph samples.
    pub track_flame: bool,
    /// Enable §6.1 fault-and-migrate instead of annotations.
    pub fault_migrate: bool,
    /// §3.1/§4.3 adaptive AVX-core allocation (CoreSpec policies only).
    pub adaptive: Option<crate::sched::adaptive::AdaptiveParams>,
    /// DVFS governor the machine runs under (`intel-legacy` = the
    /// pre-governor behaviour, bit for bit).
    pub governor: GovernorSpec,
    /// Per-core power model for the energy accounting.
    pub power: PowerParams,
    /// Hot-path optimizations (slice coalescing + memoized costing) —
    /// bit-exact either way; off only for the bench harness's baseline
    /// (see `MachineParams::fast_paths`).
    pub fast_paths: bool,
    /// Injected frequency-degradation windows ([`crate::faults`]),
    /// machine-local time. Empty (the default) keeps the machine on the
    /// literal fault-free code paths (see `MachineParams::degrade`).
    pub degrade: Vec<crate::faults::DegradeWindow>,
}

impl WebCfg {
    /// The paper's compressed-page scenario at a load slightly above the
    /// fastest variant's capacity, so throughput reflects capacity.
    pub fn paper_default(isa: Isa, policy: PolicyKind) -> Self {
        WebCfg {
            isa,
            compress: true,
            page_bytes: 72 * 1024,
            annotate: !matches!(policy, PolicyKind::Unmodified),
            policy,
            workers: 24,
            cores: 12,
            sockets: 1,
            hybrid: None,
            mode: LoadMode::Open { rate: 60_000.0 },
            slo: DEFAULT_SLO,
            handshake_every: 20,
            seed: 0x5EED,
            warmup: SEC,
            measure: 4 * SEC,
            track_flame: false,
            fault_migrate: false,
            adaptive: None,
            governor: GovernorSpec::IntelLegacy,
            power: PowerParams::default(),
            fast_paths: true,
            degrade: Vec::new(),
        }
    }

    /// Uncompressed variant (Fig 2 middle group).
    pub fn uncompressed(isa: Isa, policy: PolicyKind) -> Self {
        let mut c = Self::paper_default(isa, policy);
        c.compress = false;
        c.mode = LoadMode::Open { rate: 400_000.0 };
        c
    }

    /// Build a scenario from a TOML config (see `configs/*.toml`).
    /// Unspecified keys keep the paper defaults.
    pub fn from_config(conf: &crate::util::config::Config) -> anyhow::Result<Self> {
        let isa = match conf.str_or("server.isa", "avx512") {
            "sse4" => Isa::Sse4,
            "avx2" => Isa::Avx2,
            "avx512" => Isa::Avx512,
            other => anyhow::bail!("server.isa = {other:?} (sse4|avx2|avx512)"),
        };
        let avx_cores = conf.int_or("sched.avx_cores", 2) as usize;
        let sockets = conf.int_or("machine.sockets", 1).max(1) as usize;
        // [topology] section: hybrid P/E parts. Presence of
        // topology.p_cores switches the machine to the hybrid layout;
        // machine.cores, when also set, must agree with the spec.
        let hybrid = match conf.get("topology.p_cores") {
            None => None,
            Some(_) => {
                let p = conf.int_or("topology.p_cores", 0).max(0) as usize;
                let e = conf.int_or("topology.e_cores", 0).max(0) as usize;
                let module = conf.int_or("topology.module_size", 4).max(0) as usize;
                Some(crate::cpu::HybridSpec::new(p, e, module)?)
            }
        };
        let policy = match conf.str_or("sched.policy", "corespec") {
            "unmodified" => PolicyKind::Unmodified,
            "corespec" => PolicyKind::CoreSpec { avx_cores },
            "corespec-numa" => {
                PolicyKind::CoreSpecNuma { avx_cores_per_socket: avx_cores, sockets }
            }
            "strict" => PolicyKind::StrictPartition { avx_cores },
            // The hardware partition is the specialization set; without
            // a [topology] section fall back to sched.avx_cores.
            "class-native" => PolicyKind::ClassNative {
                p_cores: hybrid.map(|h| h.p_cores).unwrap_or(avx_cores),
            },
            other => {
                anyhow::bail!(
                    "sched.policy = {other:?} \
                     (unmodified|corespec|corespec-numa|strict|class-native)"
                )
            }
        };
        let mut cfg = WebCfg::paper_default(isa, policy);
        cfg.compress = conf.bool_or("server.compress", cfg.compress);
        cfg.page_bytes = conf.int_or("server.page_kib", (cfg.page_bytes / 1024) as i64) as usize * 1024;
        cfg.workers = conf.int_or("server.workers", cfg.workers as i64) as usize;
        cfg.cores = conf.int_or("machine.cores", cfg.cores as i64) as usize;
        cfg.sockets = sockets;
        cfg.handshake_every = conf.int_or("server.handshake_every", cfg.handshake_every as i64) as u64;
        cfg.annotate = conf.bool_or("sched.annotate", cfg.annotate);
        cfg.fault_migrate = conf.bool_or("sched.fault_migrate", false);
        cfg.fast_paths = conf.bool_or("machine.fast_paths", cfg.fast_paths);
        cfg.hybrid = hybrid;
        if let Some(h) = hybrid {
            let cores = conf.int_or("machine.cores", -1);
            anyhow::ensure!(
                cores < 0 || cores as usize == h.n_cores(),
                "machine.cores = {cores} contradicts [topology] ({} = {} cores)",
                h.label(),
                h.n_cores()
            );
            cfg.cores = h.n_cores();
            anyhow::ensure!(
                !(cfg.fault_migrate && h.has_e_cores()),
                "sched.fault_migrate = true is incompatible with E-cores \
                 (512-bit code faults for real there, it cannot be migrated after the fact)"
            );
        }
        if conf.bool_or("sched.adaptive", false) {
            // The adaptive controller manages only the machine-global
            // CoreSpec set; rejecting other policies here beats a
            // silent no-op run reporting "0 resizes".
            anyhow::ensure!(
                matches!(cfg.policy, PolicyKind::CoreSpec { .. }),
                "sched.adaptive = true requires sched.policy = \"corespec\" \
                 (the controller does not manage {} yet)",
                cfg.policy.name()
            );
            cfg.adaptive = Some(Default::default());
        }
        cfg.seed = conf.int_or("seed", cfg.seed as i64) as u64;
        // [power] section: governor selection + power-model overrides.
        // Unknown governor names — or a non-string value — are a hard
        // error (a typo would run the wrong policy and label every
        // table with it).
        use crate::util::config::Value;
        cfg.governor = match conf.get("power.governor") {
            None => cfg.governor,
            Some(Value::Str(s)) => GovernorSpec::parse(s)?,
            Some(other) => anyhow::bail!(
                "power.governor must be a string governor name \
                 (intel-legacy|slow-ramp|dim-silicon), got {other}"
            ),
        };
        cfg.power.idle_w = conf.float_or("power.idle_w", cfg.power.idle_w);
        if let Some(v) = conf.get("power.active_w_per_ghz") {
            let xs = match v {
                Value::Array(xs) if xs.len() == 3 => xs,
                other => anyhow::bail!(
                    "power.active_w_per_ghz must be a 3-element array (W/GHz at L0, L1, L2), \
                     got {other}"
                ),
            };
            for (slot, x) in cfg.power.active_w_per_ghz.iter_mut().zip(xs) {
                *slot = match x {
                    Value::Float(f) => *f,
                    Value::Int(i) => *i as f64,
                    other => anyhow::bail!("power.active_w_per_ghz entries must be numbers, got {other}"),
                };
            }
        }
        cfg.power.validate().map_err(|e| anyhow::anyhow!(e))?;
        let rate = conf.float_or("load.rate", -1.0);
        let conns = conf.int_or("load.connections", -1);
        match (rate > 0.0, conns > 0) {
            (true, true) => anyhow::bail!("set load.rate or load.connections, not both"),
            (true, false) => cfg.mode = LoadMode::Open { rate },
            (false, true) => cfg.mode = LoadMode::Closed { connections: conns as usize },
            (false, false) => {}
        }
        // Window and SLO floats convert through `as Time` (u64), which
        // *saturates*: a negative `load.warmup_s` would silently become
        // 0 and skip warmup (and a negative measure/SLO would zero the
        // measurement window / violation threshold). Reject at the
        // config edge instead of running a quietly different experiment.
        let warmup_s = conf.float_or("load.warmup_s", cfg.warmup as f64 / SEC as f64);
        anyhow::ensure!(
            warmup_s.is_finite() && warmup_s >= 0.0,
            "load.warmup_s = {warmup_s}: must be a finite value ≥ 0 \
             (a negative warmup would silently truncate to 0)"
        );
        let measure_s = conf.float_or("load.measure_s", cfg.measure as f64 / SEC as f64);
        anyhow::ensure!(
            measure_s.is_finite() && measure_s > 0.0,
            "load.measure_s = {measure_s}: must be a finite value > 0 \
             (a non-positive window would leave nothing to measure)"
        );
        let slo_ms = conf.float_or("load.slo_ms", cfg.slo as f64 / MS as f64);
        anyhow::ensure!(
            slo_ms.is_finite() && slo_ms > 0.0,
            "load.slo_ms = {slo_ms}: must be a finite value > 0 \
             (a non-positive SLO would count every completion as a violation)"
        );
        cfg.warmup = (warmup_s * SEC as f64) as Time;
        cfg.measure = (measure_s * SEC as f64) as Time;
        cfg.slo = (slo_ms * MS as f64) as Time;
        // Non-Poisson arrival processes reshape the open-loop rate.
        let process = conf.str_or("load.process", "poisson");
        if process != "poisson" {
            let rate = match &cfg.mode {
                LoadMode::Open { rate } => *rate,
                _ => anyhow::bail!("load.process = {process:?} requires load.rate (open loop)"),
            };
            let period = (conf.float_or("load.period_ms", 200.0) * MS as f64) as Time;
            // Shared burst-shape read for the bursty arms. Past the
            // `factor × duty ≤ 1` bound the base rate clamps to 0 and
            // the long-run mean silently exceeds load.rate —
            // cross-process comparisons at "the same load" would
            // compare different offered loads.
            let burst_shape = |default_factor: f64| -> anyhow::Result<(f64, f64)> {
                let burst_factor = conf.float_or("load.burst_factor", default_factor);
                let duty = conf.float_or("load.duty", 0.3);
                anyhow::ensure!(
                    burst_factor * duty <= 1.0,
                    "load.burst_factor × load.duty = {:.2} > 1: bursts alone exceed \
                     load.rate, so the declared mean cannot be preserved",
                    burst_factor * duty
                );
                Ok((burst_factor, duty))
            };
            cfg.mode = LoadMode::OpenProcess {
                process: match process {
                    "bursty" => {
                        let (burst_factor, duty) = burst_shape(2.0)?;
                        ArrivalProcess::bursty_mean(rate, burst_factor, duty, period)
                    }
                    "diurnal" => ArrivalProcess::Diurnal {
                        mean_rate: rate,
                        swing: conf.float_or("load.swing", 0.6).clamp(0.0, 0.95),
                        period,
                    },
                    "mix" => ArrivalProcess::two_tenant(
                        rate,
                        conf.float_or("load.avx_share", 0.3),
                    ),
                    "bursty-mix" => {
                        let (burst_factor, duty) = burst_shape(1.5)?;
                        ArrivalProcess::bursty_two_tenant(
                            rate,
                            conf.float_or("load.avx_share", 0.3),
                            burst_factor,
                            duty,
                            period,
                        )
                    }
                    other => anyhow::bail!(
                        "load.process = {other:?} (poisson|bursty|diurnal|mix|bursty-mix)"
                    ),
                },
            };
        }
        // [tpc] section: serve the open-loop load through the
        // thread-per-core executor (`workers` becomes the executor-core
        // count; run thread-per-core by setting it equal to
        // machine.cores).
        match conf.get("tpc.placement") {
            None => {}
            Some(Value::Str(s)) => {
                let placement = crate::tpc::PlacementSpec::parse(
                    s,
                    conf.int_or("tpc.avx_cores", 2).max(0) as usize,
                )?;
                let process = cfg.mode.process().ok_or_else(|| {
                    anyhow::anyhow!("[tpc] requires an open-loop load (set load.rate)")
                })?;
                let quantum = match conf.get("tpc.quantum") {
                    None => u64::MAX,
                    Some(Value::Int(i)) if *i > 0 => *i as u64,
                    Some(other) => anyhow::bail!(
                        "tpc.quantum must be a positive instruction count, got {other}"
                    ),
                };
                let shares = match conf.get("tpc.shares") {
                    None => Vec::new(),
                    Some(Value::Array(xs)) => xs
                        .iter()
                        .map(|x| match x {
                            Value::Int(i) if *i >= 0 => Ok(*i as u64),
                            other => anyhow::bail!(
                                "tpc.shares entries must be non-negative integers, got {other}"
                            ),
                        })
                        .collect::<anyhow::Result<Vec<u64>>>()?,
                    Some(other) => {
                        anyhow::bail!("tpc.shares must be an array of integers, got {other}")
                    }
                };
                cfg.mode = LoadMode::Executor {
                    process,
                    tpc: crate::tpc::TpcParams { placement, quantum, shares },
                };
            }
            Some(other) => anyhow::bail!(
                "tpc.placement must be a string placement name \
                 (home-core|avx-steer|avx-steer-lazy), got {other}"
            ),
        }
        Ok(cfg)
    }
}

/// One step of a request plan. `Exec` carries a repetition count so
/// homogeneous inner loops (brotli chunks, back-to-back cipher records)
/// reach the machine as one [`Action::RunMany`] — the steady-state form
/// its coalescing fast path consumes — instead of N identical actions.
#[derive(Clone, Debug)]
enum Step {
    Set(TaskType),
    Exec { func: u64, stack: u32, block: Block, reps: u32 },
}

/// Append an execution step, run-length-merging into the tail when it
/// repeats the same `(func, stack, block)`. Merging never crosses a
/// `Set` boundary (the tail would not match) and never merges blocks
/// that differ only in their per-burst license-eligibility draw — block
/// equality covers `license_exempt`.
fn push_exec(steps: &mut VecDeque<Step>, func: u64, stack: u32, block: Block) {
    if let Some(Step::Exec { func: f, stack: s, block: b, reps }) = steps.back_mut() {
        if *f == func && *s == stack && *b == block {
            *reps += 1;
            return;
        }
    }
    steps.push_back(Step::Exec { func, stack, block, reps: 1 });
}

/// Interned symbols + precomputed stacks for the request pipeline.
struct Symbols {
    stacks: Rc<RefCell<StackTable>>,
}

fn fnv(name: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Symbols {
    fn stack(&self, frames: &[&str]) -> u32 {
        self.stacks.borrow_mut().intern(frames)
    }
}

/// Builds per-request step plans.
struct Planner {
    cfg: WebCfg,
    crypto: CryptoProfile,
    compress: CompressProfile,
    syms: Symbols,
    // Precomputed stack ids.
    st_process: u32,
    st_ssl_read: u32,
    st_chacha_r: u32,
    st_poly_r: u32,
    st_static: u32,
    st_brotli: u32,
    st_chacha_w: u32,
    st_poly_w: u32,
    st_finalize: u32,
    st_handshake: u32,
}

impl Planner {
    fn new(cfg: WebCfg, stacks: Rc<RefCell<StackTable>>) -> Self {
        let crypto = CryptoProfile::for_isa(cfg.isa);
        let syms = Symbols { stacks };
        let chacha = crypto.chacha_symbol();
        let poly = crypto.poly_symbol();
        let st_process = syms.stack(&["nginx", "ngx_http_process_request"]);
        let st_ssl_read = syms.stack(&["nginx", "ngx_ssl_recv", "SSL_read"]);
        let st_chacha_r = syms.stack(&["nginx", "ngx_ssl_recv", "SSL_read", chacha]);
        let st_poly_r = syms.stack(&["nginx", "ngx_ssl_recv", "SSL_read", poly]);
        let st_static = syms.stack(&["nginx", "ngx_http_static_handler"]);
        let st_brotli =
            syms.stack(&["nginx", "ngx_http_brotli_filter", "BrotliEncoderCompressStream"]);
        let st_chacha_w = syms.stack(&["nginx", "ngx_ssl_send_chain", "SSL_write", chacha]);
        let st_poly_w = syms.stack(&["nginx", "ngx_ssl_send_chain", "SSL_write", poly]);
        let st_finalize = syms.stack(&["nginx", "ngx_http_finalize_request"]);
        let st_handshake = syms.stack(&["nginx", "ngx_ssl_handshake", "SSL_do_handshake"]);
        Planner {
            cfg,
            crypto,
            compress: CompressProfile::default(),
            syms,
            st_process,
            st_ssl_read,
            st_chacha_r,
            st_poly_r,
            st_static,
            st_brotli,
            st_chacha_w,
            st_poly_w,
            st_finalize,
            st_handshake,
        }
    }

    fn scalar_step(&self, steps: &mut VecDeque<Step>, name: &str, stack: u32, insns: u64) {
        push_exec(steps, fnv(name), stack, Block::new(ClassMix::scalar(insns)));
    }

    fn crypto_steps(&self, bytes: usize, read: bool, rng: &mut Rng, out: &mut VecDeque<Step>) {
        for (sym, block) in self.crypto.record_blocks(bytes, rng) {
            let stack = if sym.contains("ChaCha") {
                if read {
                    self.st_chacha_r
                } else {
                    self.st_chacha_w
                }
            } else if read {
                self.st_poly_r
            } else {
                self.st_poly_w
            };
            push_exec(out, fnv(sym), stack, block);
        }
    }

    /// Build the step plan for one request into `steps` (cleared first —
    /// workers reuse one buffer across requests instead of allocating a
    /// fresh plan per request). `reqno` drives the keepalive handshake
    /// cadence.
    fn plan_into(&self, reqno: u64, rng: &mut Rng, steps: &mut VecDeque<Step>) {
        steps.clear();
        let annotate = self.cfg.annotate;
        let _ = &self.syms;

        // Accept/parse (scalar).
        self.scalar_step(steps, "ngx_http_process_request", self.st_process, 45_000);

        // Occasional full TLS handshake (keepalive connections).
        if self.cfg.handshake_every > 0 && reqno % self.cfg.handshake_every == 0 {
            if annotate {
                steps.push_back(Step::Set(TaskType::Avx));
            }
            // ECDHE/bignum: predominantly scalar with a small AEAD finish.
            self.scalar_step(steps, "SSL_do_handshake", self.st_handshake, 280_000);
            self.crypto_steps(512, false, rng, steps);
            if annotate {
                steps.push_back(Step::Set(TaskType::Scalar));
            }
        }

        // SSL_read: decrypt the (small) request.
        if annotate {
            steps.push_back(Step::Set(TaskType::Avx));
        }
        self.scalar_step(steps, "SSL_read", self.st_ssl_read, 6_000);
        self.crypto_steps(512, true, rng, steps);
        if annotate {
            steps.push_back(Step::Set(TaskType::Scalar));
        }

        // Static file handling (scalar).
        self.scalar_step(steps, "ngx_http_static_handler", self.st_static, 35_000);

        // Optional on-the-fly compression (scalar, the big chunk): the
        // equal-size 8 KiB chunks run-length-merge into one RunMany.
        let body_bytes = if self.cfg.compress {
            for (sym, block) in self.compress.blocks(self.cfg.page_bytes) {
                push_exec(steps, fnv(sym), self.st_brotli, block);
            }
            self.compress.output_bytes(self.cfg.page_bytes)
        } else {
            self.cfg.page_bytes
        };

        // SSL_write: encrypt the response in 16 KiB TLS records.
        if annotate {
            steps.push_back(Step::Set(TaskType::Avx));
        }
        let mut left = body_bytes;
        while left > 0 {
            let rec = left.min(16 * 1024);
            self.crypto_steps(rec, false, rng, steps);
            left -= rec;
        }
        if annotate {
            steps.push_back(Step::Set(TaskType::Scalar));
        }

        // Finalize/log (scalar).
        self.scalar_step(steps, "ngx_http_finalize_request", self.st_finalize, 18_000);
    }
}

/// Worker task body: pulls requests from the shared queue, executes the
/// plan step by step. One planner per tenant: tenants that carry no AVX
/// work get an SSE4 pipeline without `with_avx()` annotations.
struct Worker {
    planners: Rc<Vec<Rc<Planner>>>,
    shared: Shared,
    ch: u32,
    rng: Rng,
    reqno: u64,
    current: Option<Request>,
    /// Step buffer reused across requests (filled by
    /// [`Planner::plan_into`]; no per-request plan allocation).
    steps: VecDeque<Step>,
}

impl TaskBody for Worker {
    fn next(&mut self, now: Time, _rng: &mut Rng) -> Action {
        loop {
            if let Some(req) = self.current {
                match self.steps.pop_front() {
                    Some(Step::Set(t)) => return Action::SetType(t),
                    Some(Step::Exec { func, stack, block, reps }) => {
                        return crate::sched::machine::pack_run(block, func, stack, reps)
                    }
                    None => {
                        self.current = None;
                        self.shared.borrow_mut().complete(now, req);
                    }
                }
            } else {
                let work = self.shared.borrow_mut().queue.pop_front();
                match work {
                    Some(req) => {
                        self.reqno += 1;
                        let planner =
                            &self.planners[req.tenant as usize % self.planners.len()];
                        planner.plan_into(self.reqno, &mut self.rng, &mut self.steps);
                        self.current = Some(req);
                    }
                    None => return Action::WaitChannel(self.ch),
                }
            }
        }
    }

    fn fork(&self, ctx: &mut ForkCtx) -> Option<Box<dyn TaskBody>> {
        Some(Box::new(Worker {
            // Planners (and the stack table inside) are immutable after
            // construction: shared outright, the copy-on-write half.
            planners: self.planners.clone(),
            shared: ctx.fork_rc(&self.shared),
            ch: self.ch,
            rng: self.rng.clone(),
            reqno: self.reqno,
            current: self.current,
            steps: self.steps.clone(),
        }))
    }
}

/// Payload carried by thread-per-core executor jobs: the request plus,
/// after a preemption yield or a lazy migration, the remaining step
/// plan. Fresh jobs carry `resume: None` and are planned at first pop
/// *on the serving worker* with that worker's own RNG and request
/// counter — exactly the [`Worker`] protocol, which is what makes
/// `home-core` on one worker byte-identical to the shared-queue server.
#[derive(Clone)]
struct ExecJob {
    req: Request,
    resume: Option<VecDeque<Step>>,
}

/// Worker task body for [`LoadMode::Executor`]: executor core `core` of
/// the [`TpcRuntime`], serving its own queue and waiting on its own
/// channel. Differences from [`Worker`]: jobs come from the per-core
/// queue instead of the shared one; a `with_avx()` step observed off
/// the AVX subset triggers the `avx-steer-lazy` migration; and an
/// instruction stint exceeding the core's granted budget yields the
/// task back to its queue (cooperative preemption).
struct ExecutorTask {
    planners: Rc<Vec<Rc<Planner>>>,
    shared: Shared,
    rt: Rc<RefCell<TpcRuntime<ExecJob>>>,
    core: usize,
    ch: u32,
    rng: Rng,
    reqno: u64,
    current: Option<TpcJob<ExecJob>>,
    steps: VecDeque<Step>,
    /// Instructions issued since the last pop/yield on this core.
    stint: u64,
    /// Per-stint instruction budget granted from the runtime quantum
    /// (`u64::MAX` = never preempt).
    budget: u64,
}

impl ExecutorTask {
    /// Park the running job back into the runtime with its remaining
    /// plan (the popped step has already been pushed back by the
    /// caller), then hand it to `requeue` for queue selection.
    fn park(&mut self, requeue: impl FnOnce(&mut TpcRuntime<ExecJob>, TpcJob<ExecJob>)) {
        let mut job = self.current.take().expect("a job is running");
        job.payload.resume = Some(std::mem::take(&mut self.steps));
        requeue(&mut self.rt.borrow_mut(), job);
        self.stint = 0;
    }
}

impl TaskBody for ExecutorTask {
    fn next(&mut self, now: Time, _rng: &mut Rng) -> Action {
        loop {
            if self.current.is_some() {
                match self.steps.pop_front() {
                    Some(Step::Set(t)) => {
                        let job = self.current.as_mut().expect("a job is running");
                        if t == TaskType::Avx {
                            if !job.in_avx_phase {
                                job.in_avx_phase = true;
                                // First AVX demand of the phase: under
                                // `avx-steer-lazy`, hand the task to the
                                // AVX subset *before* the license is
                                // requested — the Set replays there.
                                let target = self.rt.borrow_mut().lazy_target(self.core);
                                if let Some(target) = target {
                                    self.steps.push_front(Step::Set(t));
                                    self.park(|rt, job| rt.migrate(job, target));
                                    continue;
                                }
                            }
                        } else {
                            job.in_avx_phase = false;
                        }
                        return Action::SetType(t);
                    }
                    Some(Step::Exec { func, stack, block, reps }) => {
                        if self.stint > 0 && self.stint >= self.budget {
                            // Budget exhausted: yield to the next job on
                            // this queue. The wake path re-homes via the
                            // runtime's waker; the preempted job keeps
                            // its remaining plan.
                            self.steps.push_front(Step::Exec { func, stack, block, reps });
                            self.park(|rt, job| {
                                rt.stats.preemptions += 1;
                                rt.requeue_wake(job);
                            });
                            continue;
                        }
                        self.stint =
                            self.stint.saturating_add(block.insns().saturating_mul(reps.max(1) as u64));
                        return crate::sched::machine::pack_run(block, func, stack, reps);
                    }
                    None => {
                        let job = self.current.take().expect("a job is running");
                        self.shared.borrow_mut().complete(now, job.payload.req);
                        self.stint = 0;
                    }
                }
            } else {
                let job = self.rt.borrow_mut().pop(self.core);
                match job {
                    Some(mut job) => {
                        self.stint = 0;
                        match job.payload.resume.take() {
                            // Mid-request job (preempted or migrated
                            // here): resume its saved plan.
                            Some(saved) => self.steps = saved,
                            None => {
                                self.reqno += 1;
                                let planner = &self.planners
                                    [job.payload.req.tenant as usize % self.planners.len()];
                                planner.plan_into(self.reqno, &mut self.rng, &mut self.steps);
                            }
                        }
                        self.current = Some(job);
                    }
                    None => return Action::WaitChannel(self.ch),
                }
            }
        }
    }

    fn fork(&self, ctx: &mut ForkCtx) -> Option<Box<dyn TaskBody>> {
        Some(Box::new(ExecutorTask {
            planners: self.planners.clone(),
            shared: ctx.fork_rc(&self.shared),
            // Every executor task (and the drain driver) holds the same
            // runtime Rc: the ctx clones it once and rewires all of them.
            rt: ctx.fork_rc(&self.rt),
            core: self.core,
            ch: self.ch,
            rng: self.rng.clone(),
            reqno: self.reqno,
            current: self.current.clone(),
            steps: self.steps.clone(),
            stint: self.stint,
            budget: self.budget,
        }))
    }
}

/// Periodic untyped housekeeping task (kernel threads / softirq): keeps
/// the untyped queue non-empty so the §3.2 starvation rule is exercised.
struct Housekeeper {
    period: Time,
}

impl TaskBody for Housekeeper {
    fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
        let work = Action::Run {
            block: Block::new(ClassMix::scalar(4_000)),
            func: fnv("kworker"),
            stack: 0,
        };
        // Alternate run/sleep via a 2-phase toggle on the period sign.
        if self.period & 1 == 0 {
            self.period |= 1;
            work
        } else {
            self.period &= !1;
            Action::Sleep(self.period)
        }
    }

    fn fork(&self, _ctx: &mut ForkCtx) -> Option<Box<dyn TaskBody>> {
        Some(Box::new(Housekeeper { period: self.period }))
    }
}

/// Results of one web-server run.
#[derive(Clone, Debug)]
pub struct WebRun {
    pub cfg_name: String,
    pub throughput_rps: f64,
    pub avg_ghz: f64,
    pub ipc: f64,
    pub insns_per_req: f64,
    /// Full tail-latency summary (p50/p95/p99/p999/max, SLO fraction).
    pub tail: TailSummary,
    /// Per-tenant tails, in tenant-index order (`("all", …)` for
    /// single-stream arrival processes).
    pub tenant_tails: Vec<(String, TailSummary)>,
    /// The aggregate latency recorder behind [`WebRun::tail`] — carried
    /// whole (histogram + exact violation counter) so fleet-level
    /// aggregation can [`LatencyStats::merge`] runs across machines
    /// instead of averaging frozen percentiles (which is wrong: p99s do
    /// not average).
    pub stats: LatencyStats,
    /// Per-tenant recorders, index-aligned with [`WebRun::tenant_tails`].
    pub tenant_stats: Vec<LatencyStats>,
    /// Arrivals rejected by the overflow guard during measurement.
    pub dropped: u64,
    pub type_changes_per_sec: f64,
    pub migrations_per_sec: f64,
    /// Migrations that crossed a socket (NUMA) boundary; 0 on
    /// single-socket machines.
    pub cross_socket_migrations_per_sec: f64,
    /// Runtime-level placements steered by AVX awareness
    /// ([`LoadMode::Executor`] with `avx-steer`; 0 otherwise).
    pub runtime_steered: u64,
    /// Runtime-level lazy migrations (`avx-steer-lazy`; 0 otherwise).
    pub runtime_migrations: u64,
    /// [`WebRun::runtime_migrations`] over the measurement window (per
    /// second) — comparable with the kernel-level
    /// [`WebRun::migrations_per_sec`] one layer down.
    pub runtime_migrations_per_sec: f64,
    /// Runtime-level budget-exhaustion yields (0 with preemption off).
    pub runtime_preemptions: u64,
    /// Energy consumed while executing during the measurement window
    /// (J, all cores). Adds across machines (fleet aggregation sums).
    pub active_energy_j: f64,
    /// Energy consumed while idle during the measurement window (J).
    pub idle_energy_j: f64,
    pub throttle_ratio: f64,
    pub license_share: [f64; 3],
    pub completed: u64,
    /// AVX-core count at the end of the run (≠ initial when adaptive).
    pub final_avx_cores: usize,
    /// Number of adaptive grow/shrink decisions taken.
    pub adaptive_changes: u64,
    /// Per-frequency-domain harmonic-mean busy GHz, labelled (`skt0`…,
    /// then `mod0`… for E-core modules). Populated only on hybrid
    /// machines with E-cores; empty otherwise.
    pub domain_ghz: Vec<(String, f64)>,
}

impl Default for WebRun {
    /// An all-zero run (empty recorders at [`DEFAULT_SLO`]) — the base
    /// for synthetic rows in report/golden tests and digest unit tests.
    fn default() -> Self {
        WebRun {
            cfg_name: String::new(),
            throughput_rps: 0.0,
            avg_ghz: 0.0,
            ipc: 0.0,
            insns_per_req: 0.0,
            tail: TailSummary::default(),
            tenant_tails: Vec::new(),
            stats: LatencyStats::new(DEFAULT_SLO),
            tenant_stats: Vec::new(),
            dropped: 0,
            type_changes_per_sec: 0.0,
            migrations_per_sec: 0.0,
            cross_socket_migrations_per_sec: 0.0,
            runtime_steered: 0,
            runtime_migrations: 0,
            runtime_migrations_per_sec: 0.0,
            runtime_preemptions: 0,
            active_energy_j: 0.0,
            idle_energy_j: 0.0,
            throttle_ratio: 0.0,
            license_share: [0.0; 3],
            completed: 0,
            final_avx_cores: 0,
            adaptive_changes: 0,
            domain_ghz: Vec::new(),
        }
    }
}

impl WebRun {
    /// Total energy consumed over the measurement window (J).
    pub fn energy_j(&self) -> f64 {
        self.active_energy_j + self.idle_energy_j
    }

    /// Energy per completed request (J); 0.0 with no completions.
    pub fn j_per_req(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.energy_j() / self.completed as f64
        }
    }

    /// Perf-per-watt: completed requests per Joule (identically,
    /// req/s per W); 0.0 with no energy accounted.
    pub fn req_per_j(&self) -> f64 {
        let e = self.energy_j();
        if e <= 0.0 {
            0.0
        } else {
            self.completed as f64 / e
        }
    }
}

/// Run the web-server scenario and report run-level metrics.
pub fn run_webserver(cfg: &WebCfg) -> WebRun {
    let (run, _m) = run_webserver_machine(cfg);
    run
}

/// Like [`run_webserver`] but also returns the machine (for flame graphs
/// and counter inspection).
pub fn run_webserver_machine(cfg: &WebCfg) -> (WebRun, Machine) {
    run_webserver_impl(cfg, crate::sched::SchedParams::default(), None)
}

/// Run with explicit scheduler parameters (ablation hook).
pub fn run_webserver_with_params(cfg: &WebCfg, sched: crate::sched::SchedParams) -> WebRun {
    run_webserver_impl(cfg, sched, None).0
}

/// Run one machine of a fleet: arrivals come from the precomputed
/// `(time, tenant)` trace (this machine's share of the cluster stream)
/// instead of a live generator, via [`TraceDriver`]. `cfg.mode` must
/// still carry the fleet's arrival process — it supplies the tenant
/// metadata (names, per-tenant AVX pipelines) the planners need; only
/// the arrival *times* are replaced. Replaying a machine's own full
/// stream reproduces [`run_webserver`] exactly (the fleet differential
/// test pins this).
pub fn run_webserver_trace(cfg: &WebCfg, trace: Vec<(Time, u32)>) -> WebRun {
    run_webserver_impl(cfg, crate::sched::SchedParams::default(), TraceInput::Plain(trace)).0
}

/// Fault-injected variant of [`run_webserver_trace`]: each entry is
/// `(deliver, arrival stamp, tenant)` — delivery delayed by link
/// faults, the stamp shifted by clock skew (see
/// [`crate::workload::client::FaultTraceDriver`]). With
/// `deliver == stamp` everywhere this is event-for-event identical to
/// [`run_webserver_trace`]; fault-free fleet paths never call it.
pub fn run_webserver_trace_faulted(cfg: &WebCfg, trace: Vec<(Time, Time, u32)>) -> WebRun {
    run_webserver_impl(cfg, crate::sched::SchedParams::default(), TraceInput::Faulted(trace)).0
}

/// Arrival-source selector for the private build path: live generator,
/// replayed fleet trace, or a fault-injected trace.
enum TraceInput {
    None,
    Plain(Vec<(Time, u32)>),
    Faulted(Vec<(Time, Time, u32)>),
}

fn run_webserver_impl(
    cfg: &WebCfg,
    sched: crate::sched::SchedParams,
    trace: TraceInput,
) -> (WebRun, Machine) {
    let (run, m, _shared) = WebSim::build(cfg, sched, trace).finish_impl();
    (run, m)
}

/// A web-server simulation split at its phase boundaries — build,
/// warmup, measurement — so the scenario matrix can checkpoint-fork a
/// warmed simulation instead of re-running the shared warmup prefix for
/// every cell (see `crate::scenario`).
///
/// `WebSim::new → run_warmup → finish` is the historical
/// [`run_webserver`] control flow, phase by phase. [`WebSim::fork`]
/// (valid at any prefix point before measurement) produces an
/// independent simulation whose continuation is byte-identical to
/// continuing the original: mutable shared workload state is
/// deep-cloned exactly once through a [`ForkCtx`], while immutable plan
/// state (planners, the interned stack table) is shared copy-on-write.
pub struct WebSim {
    cfg: WebCfg,
    process: Option<ArrivalProcess>,
    m: Machine,
    driver: WebDriver,
    shared: Shared,
}

impl WebSim {
    /// Build a ready-to-run simulation for `cfg`: workers spawned,
    /// arrival driver installed, nothing simulated yet.
    pub fn new(cfg: &WebCfg) -> Self {
        Self::build(cfg, crate::sched::SchedParams::default(), TraceInput::None)
    }

    fn build(cfg: &WebCfg, sched: crate::sched::SchedParams, trace: TraceInput) -> Self {
        // Confinement requires typed AVX work: on a hybrid part with
        // E-cores, 512-bit code must be visible to the scheduler (the
        // hardware thread director makes it so whether or not the server
        // binary is patched), so annotations are forced on.
        let cfg = &{
            let mut cfg = cfg.clone();
            if cfg.hybrid.is_some_and(|h| h.has_e_cores()) && matches!(cfg.isa, Isa::Avx512) {
                cfg.annotate = true;
            }
            cfg
        };
        let stacks = Rc::new(RefCell::new(StackTable::new()));
        // Open-loop arrival process (None = closed loop) and one planner per
        // tenant: non-AVX tenants serve an SSE4 pipeline, unannotated.
        let process = cfg.mode.process();
        let n_tenants = process.as_ref().map(|p| p.n_tenants()).unwrap_or(1);
        let planners: Rc<Vec<Rc<Planner>>> = Rc::new(
            (0..n_tenants)
                .map(|t| {
                    let carries_avx =
                        process.as_ref().map(|p| p.tenant_carries_avx(t)).unwrap_or(true);
                    let mut pcfg = cfg.clone();
                    if !carries_avx {
                        pcfg.isa = Isa::Sse4;
                        pcfg.annotate = false;
                    }
                    Rc::new(Planner::new(pcfg, stacks.clone()))
                })
                .collect(),
        );

        // `Machine::new` normalizes a CoreSpecNuma policy's socket count on
        // the machine's actual domain count, so a caller overriding only
        // `cfg.sockets` cannot desynchronize the AVX-core layout.
        let mut mp = MachineParams::new(cfg.cores, cfg.policy.clone());
        mp.sockets = cfg.sockets;
        mp.sched = sched;
        mp.seed = cfg.seed;
        mp.freq.governor = cfg.governor;
        mp.power = cfg.power;
        mp.fast_paths = cfg.fast_paths;
        mp.hybrid = cfg.hybrid;
        // wrk2 client cores keep the package(s) awake: 4 per socket, like
        // the paper's single-socket evaluation.
        mp.extra_active_cores = 4 * cfg.sockets.max(1);
        mp.track_flame = cfg.track_flame;
        mp.degrade = cfg.degrade.clone();
        if cfg.fault_migrate {
            mp.fault_migrate = Some(Default::default());
        }
        let mut m = Machine::new(mp);
        let ch = m.channel();

        let closed = matches!(cfg.mode, LoadMode::Closed { .. });
        let shared = ServerShared::new(closed, cfg.slo, n_tenants);

        // nginx workers start untyped-equivalent: the paper's patch types
        // them scalar on first classification; we spawn them scalar.
        let ttype = if cfg.annotate { TaskType::Scalar } else { TaskType::Untyped };
        let mut seed_rng = Rng::new(cfg.seed ^ 0xC0FFEE);
        let mut exec: Option<ExecState> = None;
        if let LoadMode::Executor { tpc, .. } = &cfg.mode {
            // Thread-per-core executor: worker i owns runtime queue i and
            // waits on its own channel. The worker spawn protocol (fork +
            // below per worker, same order) matches the shared-queue branch,
            // so `home-core` on one worker replays the same RNG stream.
            let n_exec = cfg.workers.max(1);
            let core_chs: Vec<u32> = (0..n_exec).map(|_| m.channel()).collect();
            let rt = Rc::new(RefCell::new(TpcRuntime::new(
                tpc.placement,
                n_exec,
                tpc.quantum,
                &tpc.shares,
            )));
            for core in 0..n_exec {
                let budget = rt.borrow().budget(core);
                let body = ExecutorTask {
                    planners: planners.clone(),
                    shared: shared.clone(),
                    rt: rt.clone(),
                    core,
                    ch: core_chs[core],
                    rng: seed_rng.fork(),
                    reqno: seed_rng.below(1_000) as u64, // desync handshake phases
                    current: None,
                    steps: VecDeque::with_capacity(24),
                    stint: 0,
                    budget,
                };
                m.spawn(ttype, 0, Box::new(body));
            }
            let avx_tenants: Vec<bool> = (0..n_tenants)
                .map(|t| process.as_ref().map(|p| p.tenant_carries_avx(t)).unwrap_or(true))
                .collect();
            exec = Some(ExecState {
                shared: shared.clone(),
                rt,
                avx_tenants,
                core_chs,
                reactor: Reactor::new(),
            });
        } else {
            for _ in 0..cfg.workers {
                let body = Worker {
                    planners: planners.clone(),
                    shared: shared.clone(),
                    ch,
                    rng: seed_rng.fork(),
                    reqno: seed_rng.below(1_000) as u64, // desync handshake phases
                    current: None,
                    steps: VecDeque::with_capacity(24),
                };
                m.spawn(ttype, 0, Box::new(body));
            }
        }
        // A couple of untyped housekeeping tasks.
        for _ in 0..2 {
            m.spawn(TaskType::Untyped, 0, Box::new(Housekeeper { period: 2 * MS }));
        }

        // Composite driver: arrivals (tag 0) + adaptive controller (tag 1).
        // Fleet machines replay their routed share of the cluster stream;
        // standalone runs sample a live generator.
        let open = match (&process, trace) {
            (Some(_), TraceInput::Plain(t)) => {
                Some(ArrivalDriver::Trace(TraceDriver::new(shared.clone(), ch, t)))
            }
            (Some(_), TraceInput::Faulted(t)) => {
                Some(ArrivalDriver::FaultTrace(FaultTraceDriver::new(shared.clone(), ch, t)))
            }
            (Some(p), TraceInput::None) => Some(ArrivalDriver::Live(TrafficDriver::new(
                shared.clone(),
                ch,
                p.clone(),
                cfg.seed ^ 0xDEAD,
            ))),
            (None, trace) => {
                assert!(
                    matches!(trace, TraceInput::None),
                    "a closed-loop run cannot replay an arrival trace"
                );
                let connections = match cfg.mode {
                    LoadMode::Closed { connections } => connections,
                    _ => unreachable!("process() is None only for closed loop"),
                };
                {
                    let mut s = shared.borrow_mut();
                    for _ in 0..connections {
                        s.queue.push_back(Request::at(0));
                    }
                }
                for _ in 0..connections.min(cfg.workers) {
                    m.notify(ch);
                }
                None
            }
        };
        let ctl = cfg
            .adaptive
            .map(|params| crate::sched::adaptive::Controller::new(params, cfg.cores));
        let mut driver = WebDriver { open, ctl, exec };
        if let Some(o) = &mut driver.open {
            o.start(&mut m);
        }
        if let Some(c) = &driver.ctl {
            m.schedule_external(m.now() + c.params.interval, 1);
        }
        WebSim { cfg: cfg.clone(), process, m, driver, shared }
    }

    /// Simulated time the machine has reached.
    pub fn now(&self) -> Time {
        self.m.now()
    }

    /// Run the shared warmup prefix (`cfg.warmup`).
    pub fn run_warmup(&mut self) {
        let until = self.cfg.warmup;
        self.run_to(until);
    }

    /// Advance the simulation to absolute time `until` (a no-op once
    /// `now` has passed it). Exposed so the fork-equivalence properties
    /// can checkpoint at *arbitrary* prefix points, not just the warmup
    /// boundary.
    pub fn run_to(&mut self, until: Time) {
        self.m.run_until(until, &mut self.driver);
    }

    /// Re-aim the measurement window. The only configuration field that
    /// may differ between cells sharing one warmup checkpoint: nothing
    /// before [`WebSim::finish`] reads it, so changing it on a warmed or
    /// forked simulation is exactly equivalent to having built the
    /// simulation with this window from the start.
    pub fn set_measure(&mut self, measure: Time) {
        self.cfg.measure = measure;
    }

    /// Checkpoint-fork the simulation: an independent copy whose
    /// continuation is byte-identical to continuing `self`. Mutable
    /// shared state (server queue/recorders, the executor runtime) is
    /// deep-cloned once through one [`ForkCtx`]; the fork's recorders
    /// come from `arena` so their histogram bucket allocations are
    /// reused across cells. Returns `None` if any live task body does
    /// not support forking — callers fall back to a cold run.
    ///
    /// Must be called before measurement starts (any prefix point up to
    /// the warmup boundary): the arena-backed recorders are handed over
    /// cleared, which is only equivalent because `start_measuring`
    /// resets every recorder before the first measured sample.
    pub fn fork(&self, arena: &mut RecorderArena) -> Option<WebSim> {
        debug_assert!(
            !self.shared.borrow().measuring,
            "WebSim::fork after start_measuring would drop recorded samples"
        );
        let mut ctx = ForkCtx::new();
        let forked_shared =
            Rc::new(RefCell::new(self.shared.borrow().fork_with_arena(arena)));
        ctx.provide(&self.shared, &forked_shared);
        let m = self.m.try_fork(&mut ctx)?;
        let driver = self.driver.fork(&mut ctx);
        Some(WebSim {
            cfg: self.cfg.clone(),
            process: self.process.clone(),
            m,
            driver,
            shared: forked_shared,
        })
    }

    /// Measurement phase: reset the warmup counters, run the
    /// measurement window, and freeze the report (plus the machine, for
    /// flame graphs and counter inspection).
    pub fn finish(self) -> (WebRun, Machine) {
        let (run, m, _shared) = self.finish_impl();
        (run, m)
    }

    /// Like [`WebSim::finish`], additionally returning the simulation's
    /// latency recorders to `arena` for the next forked cell to reuse
    /// (the report keeps its own copies).
    pub fn finish_into_arena(self, arena: &mut RecorderArena) -> WebRun {
        let (run, m, shared) = self.finish_impl();
        // The machine's task bodies and the driver held the other
        // handles; with them gone the recorders can be reclaimed.
        drop(m);
        if let Ok(cell) = Rc::try_unwrap(shared) {
            let s = cell.into_inner();
            arena.put(s.stats);
            for t in s.tenant_stats {
                arena.put(t);
            }
        }
        run
    }

    fn finish_impl(self) -> (WebRun, Machine, Shared) {
        let WebSim { cfg, process, mut m, mut driver, shared } = self;
        let cfg = &cfg;
        // Complete any un-run warmup prefix (a no-op when the caller —
        // or the checkpoint this fork came from — already ran it).
        m.run_until(cfg.warmup, &mut driver);
        m.reset_metrics();
        shared.borrow_mut().start_measuring();
        // Runtime counters reset with the machine counters: reported
        // steer/migration/preemption figures cover the measurement window
        // only, like the kernel-level migration rates they sit next to.
        if let Some(e) = &driver.exec {
            e.rt.borrow_mut().stats = crate::tpc::TpcStats::default();
        }
        m.run_until(cfg.warmup + cfg.measure, &mut driver);
        let tpc_stats = driver.exec.as_ref().map(|e| e.rt.borrow().stats).unwrap_or_default();
        let final_avx_cores = m.sched.policy.avx_core_count();
        let adaptive_changes = driver.ctl.as_ref().map(|c| c.grows + c.shrinks).unwrap_or(0);

        let total = m.total_perf();
        let s = shared.borrow();
        let secs = cfg.measure as f64 / SEC as f64;
        let completed = s.completed();
        let tail = s.stats.summary();
        let tenant_names = process
            .as_ref()
            .map(|p| p.tenant_names())
            .unwrap_or_else(|| vec!["all".to_string()]);
        let tenant_tails = tenant_names
            .into_iter()
            .zip(s.tenant_stats.iter().map(|t| t.summary()))
            .collect();
        let run = WebRun {
            cfg_name: format!(
                "{}/{}/{}",
                cfg.isa.name(),
                if cfg.compress { "compressed" } else { "plain" },
                cfg.policy.name()
            ),
            throughput_rps: completed as f64 / secs,
            avg_ghz: total.avg_busy_ghz(),
            ipc: total.ipc(),
            insns_per_req: if completed > 0 { total.instructions as f64 / completed as f64 } else { 0.0 },
            tail,
            tenant_tails,
            stats: s.stats.clone(),
            tenant_stats: s.tenant_stats.clone(),
            dropped: s.dropped,
            type_changes_per_sec: m.sched.stats.type_changes as f64 / secs,
            migrations_per_sec: m.sched.stats.migrations as f64 / secs,
            cross_socket_migrations_per_sec: m.sched.stats.cross_socket_migrations as f64 / secs,
            runtime_steered: tpc_stats.steered,
            runtime_migrations: tpc_stats.migrations,
            runtime_migrations_per_sec: tpc_stats.migrations as f64 / secs,
            runtime_preemptions: tpc_stats.preemptions,
            active_energy_j: total.active_energy_j,
            idle_energy_j: total.idle_energy_j,
            throttle_ratio: total.throttle_ratio(),
            license_share: total.license_time_share(),
            completed,
            final_avx_cores,
            adaptive_changes,
            domain_ghz: if m.hybrid().is_some_and(|h| h.has_e_cores()) {
                m.domain_harmonic_ghz()
            } else {
                Vec::new()
            },
        };
        drop(s);
        (run, m, shared)
    }
}

/// Arrival source for the composite driver: a live seeded generator
/// (standalone runs) or a replayed fleet trace (one machine of a
/// cluster). Both produce identical event choreography for the same
/// arrival stream.
enum ArrivalDriver {
    Live(TrafficDriver),
    Trace(TraceDriver),
    FaultTrace(FaultTraceDriver),
}

impl ArrivalDriver {
    fn start(&mut self, m: &mut Machine) {
        match self {
            ArrivalDriver::Live(d) => d.start(m),
            ArrivalDriver::Trace(d) => d.start(m),
            ArrivalDriver::FaultTrace(d) => d.start(m),
        }
    }

    fn on_external(&mut self, tag: u64, m: &mut Machine) {
        match self {
            ArrivalDriver::Live(d) => d.on_external(tag, m),
            ArrivalDriver::Trace(d) => d.on_external(tag, m),
            ArrivalDriver::FaultTrace(d) => d.on_external(tag, m),
        }
    }

    fn fork(&self, ctx: &mut ForkCtx) -> ArrivalDriver {
        match self {
            ArrivalDriver::Live(d) => ArrivalDriver::Live(d.fork(ctx)),
            ArrivalDriver::Trace(d) => ArrivalDriver::Trace(d.fork(ctx)),
            ArrivalDriver::FaultTrace(d) => ArrivalDriver::FaultTrace(d.fork(ctx)),
        }
    }
}

/// Driver-side half of the thread-per-core executor: after each arrival
/// event, drain the shared intake queue into the runtime's per-core
/// queues via the placement policy, collect every wake target in the
/// [`Reactor`], and flush one notification per distinct core — the
/// completion-batching protocol of the glommio model.
struct ExecState {
    shared: Shared,
    rt: Rc<RefCell<TpcRuntime<ExecJob>>>,
    /// `tenant_carries_avx` per tenant index: whether the runtime should
    /// treat the tenant's futures as AVX-marked for placement.
    avx_tenants: Vec<bool>,
    /// Per-executor-core wake channels, index = core.
    core_chs: Vec<u32>,
    reactor: Reactor,
}

impl ExecState {
    fn drain(&mut self, m: &mut Machine) {
        {
            let mut rt = self.rt.borrow_mut();
            // In-worker requeues (preemption yields, lazy migrations)
            // happen while no Machine handle is in scope; they recorded
            // their targets in the runtime. Fold them into this batch.
            for core in rt.take_pending_wakes() {
                self.reactor.note(core);
            }
            loop {
                let req = { self.shared.borrow_mut().queue.pop_front() };
                let Some(req) = req else { break };
                // Occupancy guard: same bound as the shared-queue server,
                // measured over the runtime's total queued jobs.
                let max_queue = self.shared.borrow().max_queue;
                if rt.total_queued() >= max_queue {
                    self.shared.borrow_mut().dropped += 1;
                    continue;
                }
                let marked = self.avx_tenants[req.tenant as usize % self.avx_tenants.len()];
                let core = rt.place(marked, ExecJob { req, resume: None });
                self.reactor.note(core);
            }
        }
        for core in self.reactor.flush() {
            m.notify(self.core_chs[core]);
        }
    }

    fn fork(&self, ctx: &mut ForkCtx) -> ExecState {
        ExecState {
            shared: ctx.fork_rc(&self.shared),
            rt: ctx.fork_rc(&self.rt),
            avx_tenants: self.avx_tenants.clone(),
            core_chs: self.core_chs.clone(),
            reactor: self.reactor.clone(),
        }
    }
}

/// Composite web driver: open-loop arrivals + the adaptive controller
/// (+ the executor drain in [`LoadMode::Executor`] runs).
struct WebDriver {
    open: Option<ArrivalDriver>,
    ctl: Option<crate::sched::adaptive::Controller>,
    exec: Option<ExecState>,
}

impl WebDriver {
    fn fork(&self, ctx: &mut ForkCtx) -> WebDriver {
        WebDriver {
            open: self.open.as_ref().map(|o| o.fork(ctx)),
            ctl: self.ctl.clone(),
            exec: self.exec.as_ref().map(|e| e.fork(ctx)),
        }
    }
}

impl Driver for WebDriver {
    fn on_external(&mut self, tag: u64, m: &mut Machine) {
        match tag {
            0 => {
                if let Some(o) = &mut self.open {
                    o.on_external(0, m);
                }
                if let Some(e) = &mut self.exec {
                    e.drain(m);
                }
            }
            1 => {
                if let Some(c) = &mut self.ctl {
                    c.tick(m);
                    let next = m.now() + c.params.interval;
                    m.schedule_external(next, 1);
                }
            }
            _ => {}
        }
    }
}

/// Rebuild the stack table a run's planner used (interning is
/// deterministic per ISA), for decoding `Machine::flame` samples.
pub fn stack_table_for(isa: Isa) -> StackTable {
    let stacks = Rc::new(RefCell::new(StackTable::new()));
    let cfg = WebCfg::paper_default(isa, PolicyKind::Unmodified);
    let _planner = Planner::new(cfg, stacks.clone());
    drop(_planner);
    Rc::try_unwrap(stacks).expect("sole owner").into_inner()
}

/// The simulated `nginx` + `libcrypto.so` binaries for the static
/// analyzer (paper §3.3 disassembles the server and its libraries).
pub fn build_binaries(isa: Isa) -> Vec<Binary> {
    let crypto = CryptoProfile::for_isa(isa);
    let mut rng = Rng::new(7);

    let mut nginx = Binary::new("nginx");
    for (name, insns) in [
        ("ngx_http_process_request", 45_000u64),
        ("ngx_http_static_handler", 35_000),
        ("ngx_http_finalize_request", 18_000),
        ("ngx_event_accept", 9_000),
        ("ngx_http_log_handler", 6_000),
    ] {
        nginx.add(Function::new(name).push(Block::new(ClassMix::scalar(insns))));
    }

    let mut libbrotli = Binary::new("libbrotli.so");
    {
        let mut f = Function::new("BrotliEncoderCompressStream");
        for (_, b) in CompressProfile::default().blocks(8192) {
            f.blocks.push(b);
        }
        libbrotli.add(f);
    }

    let mut libcrypto = Binary::new("libcrypto.so");
    {
        let mut chacha = Function::new(crypto.chacha_symbol());
        chacha.blocks.push(crypto.chacha_block(4096, &mut rng));
        libcrypto.add(chacha);
        let mut poly = Function::new(crypto.poly_symbol());
        poly.blocks.push(crypto.poly_block(16384, &mut rng));
        libcrypto.add(poly);
        libcrypto.add(
            Function::new("EVP_EncryptUpdate").push(Block::new(ClassMix::scalar(2_000))),
        );
        libcrypto.add(Function::new("bn_mul_mont").push(Block::new(ClassMix::scalar(40_000))));
    }

    let mut libc = Binary::new("libc.so.6");
    // memcpy uses wide registers *sparsely* — the §3.3 false positive.
    libc.add(Function::new("__memmove_avx_unaligned").push(Block {
        mix: ClassMix::scalar(60).with(crate::isa::block::InsnClass::Avx2Light, 40),
        mem_ops: 48,
        branches: 6, license_exempt: false,
    }));
    libc.add(Function::new("__memset_avx2_unaligned").push(Block {
        mix: ClassMix::scalar(40).with(crate::isa::block::InsnClass::Avx2Light, 24),
        mem_ops: 30,
        branches: 4, license_exempt: false,
    }));
    libc.add(Function::new("malloc").push(Block::new(ClassMix::scalar(900))));
    // glibc profiling code with AVX-512 (the paper's static-analysis hit).
    libc.add(Function::new("__memcpy_avx512_no_vzeroupper").push(Block {
        mix: ClassMix::scalar(50).with(crate::isa::block::InsnClass::Avx512Light, 44),
        mem_ops: 50,
        branches: 5, license_exempt: false,
    }));

    vec![nginx, libcrypto, libbrotli, libc]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(isa: Isa, policy: PolicyKind) -> WebCfg {
        let mut c = WebCfg::paper_default(isa, policy);
        c.cores = 4;
        c.workers = 8;
        c.page_bytes = 8 * 1024; // small pages: fast under debug builds
        c.warmup = 150 * MS;
        c.measure = 300 * MS;
        c.mode = LoadMode::Open { rate: 30_000.0 };
        c
    }

    #[test]
    fn serves_requests_and_reports() {
        let run = run_webserver(&quick_cfg(Isa::Sse4, PolicyKind::Unmodified));
        assert!(run.completed > 100, "completed={}", run.completed);
        assert!(run.throughput_rps > 0.0);
        assert!(run.avg_ghz > 1.8 && run.avg_ghz < 3.8, "ghz={}", run.avg_ghz);
        assert!(run.tail.p50_us > 0.0);
    }

    #[test]
    fn sse4_faster_than_avx512_when_unmodified() {
        let sse = run_webserver(&quick_cfg(Isa::Sse4, PolicyKind::Unmodified));
        let avx = run_webserver(&quick_cfg(Isa::Avx512, PolicyKind::Unmodified));
        assert!(
            avx.avg_ghz < sse.avg_ghz * 0.97,
            "AVX-512 must drag frequency: {} vs {}",
            avx.avg_ghz,
            sse.avg_ghz
        );
    }

    #[test]
    fn corespec_keeps_scalar_cores_clean() {
        let cfg = quick_cfg(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
        let (_run, m) = run_webserver_machine(&cfg);
        for c in 0..3 {
            assert_eq!(
                m.cores[c].perf.license_cycles[2],
                0,
                "scalar core {c} saw L2 cycles"
            );
        }
    }

    #[test]
    fn annotations_produce_type_changes() {
        let run = run_webserver(&quick_cfg(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 }));
        assert!(run.type_changes_per_sec > 1000.0, "rate={}", run.type_changes_per_sec);
    }

    #[test]
    fn tail_summary_is_consistent() {
        let run = run_webserver(&quick_cfg(Isa::Sse4, PolicyKind::Unmodified));
        assert_eq!(run.tail.completed, run.completed);
        assert!(run.tail.p50_us <= run.tail.p95_us + 1e-9);
        assert!(run.tail.p95_us <= run.tail.p99_us + 1e-9);
        assert!(run.tail.p99_us <= run.tail.p999_us + 1e-9);
        assert!(run.tail.p999_us <= run.tail.max_us + 1e-9);
        assert!((0.0..=1.0).contains(&run.tail.slo_violation_frac));
        assert_eq!(run.tenant_tails.len(), 1);
        assert_eq!(run.tenant_tails[0].0, "all");
    }

    #[test]
    fn multi_tenant_mix_separates_tails() {
        let mut c = quick_cfg(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
        c.mode = LoadMode::OpenProcess {
            process: ArrivalProcess::two_tenant(30_000.0, 0.3),
        };
        let (run, m) = run_webserver_machine(&c);
        assert_eq!(run.tenant_tails.len(), 2);
        assert_eq!(run.tenant_tails[0].0, "scalar");
        assert_eq!(run.tenant_tails[1].0, "avx");
        assert!(run.tenant_tails[0].1.completed > 500, "{:?}", run.tenant_tails[0].1);
        assert!(run.tenant_tails[1].1.completed > 100, "{:?}", run.tenant_tails[1].1);
        // Only the AVX tenant's pipeline is annotated, and the scalar
        // cores stay clean even under the mix.
        for core in 0..3 {
            assert_eq!(m.cores[core].perf.license_cycles[2], 0, "core {core} saw L2");
        }
    }

    #[test]
    fn trace_replay_reproduces_live_run() {
        // Replaying the full stream of a run's own generator through
        // TraceDriver must be event-for-event identical to the live
        // TrafficDriver — the invariant the fleet layer builds on.
        let mut cfg = quick_cfg(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
        cfg.mode = LoadMode::OpenProcess {
            process: ArrivalProcess::two_tenant(30_000.0, 0.3),
        };
        let live = run_webserver(&cfg);
        let process = cfg.mode.process().expect("open loop");
        let mut gen = crate::traffic::ArrivalGen::new(process, cfg.seed ^ 0xDEAD);
        let horizon = cfg.warmup + cfg.measure;
        let mut trace = Vec::new();
        let mut now = 0;
        loop {
            let (t, tenant) = gen.next_after(now);
            if t > horizon {
                break;
            }
            trace.push((t, tenant));
            now = t;
        }
        let replay = run_webserver_trace(&cfg, trace);
        assert_eq!(live.completed, replay.completed);
        assert_eq!(live.dropped, replay.dropped);
        assert_eq!(live.stats.violations(), replay.stats.violations());
        assert_eq!(live.tail.p50_us, replay.tail.p50_us);
        assert_eq!(live.tail.p99_us, replay.tail.p99_us);
        assert_eq!(live.tail.max_us, replay.tail.max_us);
        assert_eq!(live.throughput_rps, replay.throughput_rps);
        assert_eq!(live.avg_ghz, replay.avg_ghz);
    }

    #[test]
    fn plans_run_length_merge_homogeneous_chunks() {
        // The compressed 72 KiB page is exactly nine identical 8 KiB
        // brotli chunks — the plan must carry them as one Exec with
        // reps = 9, not nine steps.
        let cfg = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
        let stacks = Rc::new(RefCell::new(StackTable::new()));
        let planner = Planner::new(cfg, stacks);
        let mut rng = Rng::new(1);
        let mut steps = VecDeque::new();
        planner.plan_into(1, &mut rng, &mut steps);
        let brotli = fnv("BrotliEncoderCompressStream");
        let brotli_steps: Vec<u32> = steps
            .iter()
            .filter_map(|s| match s {
                Step::Exec { func, reps, .. } if *func == brotli => Some(*reps),
                _ => None,
            })
            .collect();
        assert_eq!(brotli_steps, vec![9], "9 equal chunks must merge into one batch");
        // Reused buffer: a second plan clears and refills.
        planner.plan_into(2, &mut rng, &mut steps);
        assert!(steps.iter().any(|s| matches!(s, Step::Exec { .. })));
    }

    #[test]
    fn fast_paths_off_reproduces_fast_on_bit_for_bit() {
        // End-to-end crown constraint: the full web-server run with the
        // hot paths disabled must be indistinguishable from the default
        // — same completions, same tails, bit-equal floats and energy.
        let on = quick_cfg(Isa::Avx512, PolicyKind::CoreSpec { avx_cores: 1 });
        let mut off = on.clone();
        off.fast_paths = false;
        let a = run_webserver(&on);
        let b = run_webserver(&off);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.stats.violations(), b.stats.violations());
        assert_eq!(a.throughput_rps.to_bits(), b.throughput_rps.to_bits());
        assert_eq!(a.avg_ghz.to_bits(), b.avg_ghz.to_bits());
        assert_eq!(a.ipc.to_bits(), b.ipc.to_bits());
        assert_eq!(a.active_energy_j.to_bits(), b.active_energy_j.to_bits());
        assert_eq!(a.idle_energy_j.to_bits(), b.idle_energy_j.to_bits());
        assert_eq!(a.tail.p50_us.to_bits(), b.tail.p50_us.to_bits());
        assert_eq!(a.tail.p99_us.to_bits(), b.tail.p99_us.to_bits());
        assert_eq!(a.tail.max_us.to_bits(), b.tail.max_us.to_bits());
    }

    #[test]
    fn run_reports_energy() {
        let run = run_webserver(&quick_cfg(Isa::Avx512, PolicyKind::Unmodified));
        assert!(run.active_energy_j > 0.0);
        assert!(run.idle_energy_j > 0.0, "4 cores at 30k req/s must have idle time");
        assert!((run.energy_j() - run.active_energy_j - run.idle_energy_j).abs() < 1e-12);
        assert!(run.j_per_req() > 0.0);
        assert!(run.req_per_j() > 0.0);
        // Sanity scale: 4 cores, 0.3 s window, per-core power within
        // [a fraction of idle_w, the L2 max of ~12 W].
        let secs = 0.3;
        assert!(run.energy_j() < 4.0 * 12.0 * secs, "energy {} J", run.energy_j());
        assert!(run.energy_j() > 4.0 * 0.5 * secs, "energy {} J", run.energy_j());
    }

    #[test]
    fn config_parses_power_and_governor_keys() {
        let conf = crate::util::config::Config::parse(
            "[power]\ngovernor = \"dim-silicon\"\nidle_w = 2.0\nactive_w_per_ghz = [1.0, 2.0, 3.0]\n",
        )
        .unwrap();
        let cfg = WebCfg::from_config(&conf).unwrap();
        assert_eq!(cfg.governor, GovernorSpec::DimSilicon);
        assert_eq!(cfg.power.idle_w, 2.0);
        assert_eq!(cfg.power.active_w_per_ghz, [1.0, 2.0, 3.0]);
        // Unset [power] keys keep the defaults.
        let plain = WebCfg::from_config(&crate::util::config::Config::parse("").unwrap()).unwrap();
        assert_eq!(plain.governor, GovernorSpec::IntelLegacy);
        assert_eq!(plain.power, PowerParams::default());
    }

    #[test]
    fn config_rejects_unknown_governor_and_bad_power() {
        let unknown =
            crate::util::config::Config::parse("[power]\ngovernor = \"ondemand\"\n").unwrap();
        let err = WebCfg::from_config(&unknown).unwrap_err().to_string();
        assert!(err.contains("ondemand"), "error must name the bad governor: {err}");

        let short = crate::util::config::Config::parse(
            "[power]\nactive_w_per_ghz = [1.0, 2.0]\n",
        )
        .unwrap();
        assert!(WebCfg::from_config(&short).is_err(), "2-element power array must be rejected");

        let negative =
            crate::util::config::Config::parse("[power]\nidle_w = -3.0\n").unwrap();
        assert!(WebCfg::from_config(&negative).is_err(), "negative power must be rejected");

        let nonstring =
            crate::util::config::Config::parse("[power]\ngovernor = 2\n").unwrap();
        let err = WebCfg::from_config(&nonstring).unwrap_err().to_string();
        assert!(
            err.contains("power.governor"),
            "a non-string governor must be rejected, not silently defaulted: {err}"
        );
    }

    #[test]
    fn config_rejects_invalid_load_windows() {
        // Before the validation, `(-1.0 * SEC) as Time` saturated to 0
        // and a negative warmup silently became "no warmup" — the run
        // proceeded and just measured from a cold machine. These must
        // all be loud errors that name the offending key.
        let reject = |toml: &str, key: &str| {
            let conf = crate::util::config::Config::parse(toml).unwrap();
            let err = WebCfg::from_config(&conf).unwrap_err().to_string();
            assert!(err.contains(key), "error for {toml:?} must name {key}: {err}");
        };
        reject("[load]\nwarmup_s = -1.0\n", "load.warmup_s");
        reject("[load]\nwarmup_s = nan\n", "load.warmup_s");
        reject("[load]\nmeasure_s = 0.0\n", "load.measure_s");
        reject("[load]\nmeasure_s = -2.5\n", "load.measure_s");
        reject("[load]\nmeasure_s = inf\n", "load.measure_s");
        reject("[load]\nslo_ms = 0.0\n", "load.slo_ms");
        reject("[load]\nslo_ms = -5.0\n", "load.slo_ms");

        // Boundary legality: zero warmup is allowed (measure-from-cold
        // is a legitimate experiment); positive values pass through.
        let ok = crate::util::config::Config::parse(
            "[load]\nwarmup_s = 0.0\nmeasure_s = 0.5\nslo_ms = 5.0\n",
        )
        .unwrap();
        let cfg = WebCfg::from_config(&ok).unwrap();
        assert_eq!(cfg.warmup, 0);
        assert_eq!(cfg.measure, SEC / 2);
        assert_eq!(cfg.slo, 5 * MS);
    }

    #[test]
    fn binaries_contain_expected_symbols() {
        let bins = build_binaries(Isa::Avx512);
        let names: Vec<&str> = bins.iter().map(|b| b.name.as_str()).collect();
        assert!(names.contains(&"nginx") && names.contains(&"libcrypto.so"));
        let crypto = &bins[1];
        assert!(crypto.lookup("ChaCha20_ctr32_avx512").is_some());
    }
}
