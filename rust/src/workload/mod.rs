//! Workload models: the paper's evaluation scenarios.
//!
//! * [`crypto`] — ChaCha20-Poly1305 record processing per SIMD instruction
//!   set (the OpenSSL code the paper compiles for SSE4/AVX2/AVX-512).
//! * [`compress`] — brotli-style on-the-fly compression (scalar work).
//! * [`webserver`] — the nginx HTTPS scenario of §4: worker tasks serving
//!   requests whose SSL functions are (optionally) annotated.
//! * [`client`] — wrk2-style load generation (open-loop fixed rate and
//!   closed-loop) plus latency/throughput accounting.
//! * [`microbench`] — the §4.3 thread-migration overhead microbenchmark.

pub mod crypto;
pub mod compress;
pub mod client;
pub mod webserver;
pub mod microbench;

pub use crypto::Isa;
