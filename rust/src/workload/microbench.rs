//! §4.3 thread-migration overhead microbenchmark.
//!
//! "Our microbenchmark executes a simple loop consisting solely of scalar
//! instructions without any memory accesses. For core specialization, 5%
//! of the loop is marked **as if** it was AVX code." — the marked section
//! stays scalar, so any runtime difference is pure mechanism overhead
//! (syscalls, requeues, IPIs, migrations), which is what Fig 7 plots
//! against the task-type-change rate.
//!
//! Setup mirrors the paper: 26 threads on 12 physical cores (4 cores
//! idle, C-states disabled so turbo does not inflate the baseline), loop
//! length swept to vary the change rate.

use crate::cpu::turbo::TurboTable;
use crate::isa::block::{Block, ClassMix};
use crate::sched::machine::{Action, Machine, MachineParams, NullDriver, TaskBody};
use crate::sched::{PolicyKind, TaskType};
use crate::sim::{Time, SEC};
use crate::util::Rng;
use std::cell::RefCell;
use std::rc::Rc;

/// Configuration for one microbenchmark run.
#[derive(Clone, Debug)]
pub struct MicrobenchCfg {
    /// Instructions per loop iteration (the swept parameter).
    pub loop_insns: u64,
    /// Fraction of the loop marked as AVX (paper: 5%).
    pub avx_fraction: f64,
    /// Whether the marked section is annotated (core-spec run) or the
    /// loop runs unannotated (baseline run).
    pub annotate: bool,
    pub policy: PolicyKind,
    pub threads: usize,
    pub cores: usize,
    pub duration: Time,
    pub seed: u64,
}

impl MicrobenchCfg {
    pub fn paper_default(loop_insns: u64, annotate: bool) -> Self {
        MicrobenchCfg {
            loop_insns,
            avx_fraction: 0.05,
            annotate,
            policy: if annotate {
                PolicyKind::CoreSpec { avx_cores: 2 }
            } else {
                PolicyKind::Unmodified
            },
            threads: 26,
            cores: 12,
            duration: 2 * SEC,
            seed: 42,
        }
    }
}

/// Loop body: `avx_fraction` of each iteration is wrapped in
/// `with_avx()`/`without_avx()` when annotated. All work is scalar and
/// memory-free, per the paper.
struct LoopBody {
    cfg: MicrobenchCfg,
    iters_done: Rc<RefCell<u64>>,
    phase: u8,
}

impl TaskBody for LoopBody {
    fn next(&mut self, _now: Time, _rng: &mut Rng) -> Action {
        let marked = (self.cfg.loop_insns as f64 * self.cfg.avx_fraction) as u64;
        let unmarked = self.cfg.loop_insns - marked;
        let block = |n: u64| Block { mix: ClassMix::scalar(n), mem_ops: 0, branches: n / 40, license_exempt: false };
        if self.cfg.annotate {
            match self.phase {
                0 => {
                    self.phase = 1;
                    Action::SetType(TaskType::Avx)
                }
                1 => {
                    self.phase = 2;
                    Action::Run { block: block(marked.max(1)), func: 0xAAA, stack: 0 }
                }
                2 => {
                    self.phase = 3;
                    Action::SetType(TaskType::Scalar)
                }
                _ => {
                    self.phase = 0;
                    *self.iters_done.borrow_mut() += 1;
                    Action::Run { block: block(unmarked.max(1)), func: 0xBBB, stack: 0 }
                }
            }
        } else {
            // Baseline: same instruction stream, no annotations.
            match self.phase {
                0 => {
                    self.phase = 1;
                    Action::Run { block: block(marked.max(1)), func: 0xAAA, stack: 0 }
                }
                _ => {
                    self.phase = 0;
                    *self.iters_done.borrow_mut() += 1;
                    Action::Run { block: block(unmarked.max(1)), func: 0xBBB, stack: 0 }
                }
            }
        }
    }
}

/// Result of one run.
#[derive(Clone, Debug)]
pub struct MicrobenchRun {
    pub loop_insns: u64,
    pub iterations: u64,
    /// Aggregate iteration throughput (iters/s across all threads).
    pub iters_per_sec: f64,
    /// Task-type changes per second (2 per iteration when annotated).
    pub type_changes_per_sec: f64,
    pub migrations_per_sec: f64,
}

/// Execute one microbenchmark configuration.
pub fn run_microbench(cfg: &MicrobenchCfg) -> MicrobenchRun {
    let mut mp = MachineParams::new(cfg.cores, cfg.policy.clone());
    // C-states disabled: all-core turbo regardless of idle cores (§4.3).
    mp.turbo = TurboTable::xeon_gold_6130_no_cstates();
    mp.seed = cfg.seed;
    let mut m = Machine::new(mp);
    let iters = Rc::new(RefCell::new(0u64));
    for _ in 0..cfg.threads {
        m.spawn(
            if cfg.annotate { TaskType::Scalar } else { TaskType::Untyped },
            0,
            Box::new(LoopBody { cfg: cfg.clone(), iters_done: iters.clone(), phase: 0 }),
        );
    }
    // Warmup 10% then measure.
    let warmup = cfg.duration / 10;
    m.run_until(warmup, &mut NullDriver);
    m.reset_metrics();
    let base_iters = *iters.borrow();
    m.run_until(warmup + cfg.duration, &mut NullDriver);
    let done = *iters.borrow() - base_iters;
    let secs = cfg.duration as f64 / SEC as f64;
    MicrobenchRun {
        loop_insns: cfg.loop_insns,
        iterations: done,
        iters_per_sec: done as f64 / secs,
        type_changes_per_sec: m.sched.stats.type_changes as f64 / secs,
        migrations_per_sec: m.sched.stats.migrations as f64 / secs,
    }
}

/// Fig 7's derived metrics for one loop length: overhead vs baseline and
/// cost per switch pair.
#[derive(Clone, Debug)]
pub struct OverheadPoint {
    pub type_changes_per_sec: f64,
    pub overhead_pct: f64,
    pub ns_per_switch_pair: f64,
}

/// Run annotated + baseline at one loop length and derive the Fig 7 point.
pub fn overhead_point(loop_insns: u64) -> OverheadPoint {
    let ann = run_microbench(&MicrobenchCfg::paper_default(loop_insns, true));
    let base = run_microbench(&MicrobenchCfg::paper_default(loop_insns, false));
    let overhead = (base.iters_per_sec - ann.iters_per_sec) / base.iters_per_sec;
    // Each iteration performs one with_avx + one without_avx = 1 pair.
    // Lost time per pair = overhead fraction × total cpu time / pairs.
    let total_cpu_ns = 12.0 * 1e9; // 12 cores × 1 s, normalized basis
    let pairs_per_sec_all_cores = ann.type_changes_per_sec / 2.0;
    let ns_per_pair = if pairs_per_sec_all_cores > 0.0 {
        overhead * total_cpu_ns / pairs_per_sec_all_cores
    } else {
        0.0
    };
    OverheadPoint {
        type_changes_per_sec: ann.type_changes_per_sec,
        overhead_pct: overhead * 100.0,
        ns_per_switch_pair: ns_per_pair,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    fn quick(loop_insns: u64, annotate: bool) -> MicrobenchCfg {
        let mut c = MicrobenchCfg::paper_default(loop_insns, annotate);
        c.duration = 300 * MS;
        c.threads = 8;
        c.cores = 4;
        c
    }

    #[test]
    fn annotated_run_counts_type_changes() {
        let r = run_microbench(&quick(200_000, true));
        assert!(r.iterations > 100);
        assert!(r.type_changes_per_sec > 1_000.0, "rate={}", r.type_changes_per_sec);
    }

    #[test]
    fn baseline_has_no_type_changes() {
        let r = run_microbench(&quick(200_000, false));
        assert_eq!(r.type_changes_per_sec, 0.0);
        assert!(r.iterations > 100);
    }

    #[test]
    fn overhead_grows_with_change_rate() {
        // Shorter loops → more type changes/s → more overhead. Uses small
        // configs (debug builds run this); the full-size sweep is Fig 7.
        let point = |loop_insns: u64| {
            let ann = run_microbench(&quick(loop_insns, true));
            let base = run_microbench(&quick(loop_insns, false));
            let overhead = (base.iters_per_sec - ann.iters_per_sec) / base.iters_per_sec;
            (ann.type_changes_per_sec, overhead)
        };
        let (slow_rate, slow_ovh) = point(2_000_000);
        let (fast_rate, fast_ovh) = point(100_000);
        assert!(fast_rate > slow_rate * 5.0);
        assert!(fast_ovh >= slow_ovh, "fast={fast_ovh} slow={slow_ovh}");
    }
}
