//! wrk2-style load generation and measurement.
//!
//! The paper drives nginx with wrk2 on 4 dedicated cores. wrk2 is an
//! *open-loop, fixed-rate* generator (it corrects for coordinated
//! omission); throughput differences between variants appear when the
//! offered rate exceeds a variant's capacity. The open-loop side is now
//! generalized over [`ArrivalProcess`] (Poisson, bursty on/off, diurnal
//! ramp, multi-tenant mixes — see [`crate::traffic`]); a closed-loop
//! mode (fixed number of in-flight connections) is also provided — it
//! drives every variant exactly at its own capacity.
//!
//! Per-request lifecycle: the driver pushes a [`Request`] (arrival
//! timestamp + tenant) onto the shared queue, a worker pops and serves
//! it, and [`ServerShared::complete`] feeds the latency into
//! [`LatencyStats`] — aggregate and per tenant — from which the
//! p50/p95/p99/p999/SLO tables are produced.

use crate::sched::machine::{Driver, ForkCtx, Machine};
use crate::sim::{Time, MS};
use crate::traffic::{ArrivalGen, ArrivalProcess, LatencyStats, Request};
use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

/// Load-generation mode.
#[derive(Clone, Debug)]
pub enum LoadMode {
    /// Poisson arrivals at a fixed rate (requests/second) — wrk2's
    /// model, kept as sugar for `OpenProcess { Poisson }`.
    Open { rate: f64 },
    /// Open-loop arrivals from an arbitrary [`ArrivalProcess`].
    OpenProcess { process: ArrivalProcess },
    /// Open-loop arrivals served through the thread-per-core executor
    /// ([`crate::tpc`]): requests are placed onto per-worker task queues
    /// by the runtime's placement policy instead of one shared queue.
    /// With the default [`crate::tpc::TpcParams`] (`home-core`,
    /// preemption off) on a single worker this is byte-identical to
    /// `OpenProcess` — pinned by `rust/tests/tpc.rs`.
    Executor { process: ArrivalProcess, tpc: crate::tpc::TpcParams },
    /// Fixed number of always-pending connections; a completed request
    /// immediately enqueues the connection's next request.
    Closed { connections: usize },
}

impl LoadMode {
    /// The open-loop arrival process this mode denotes (`None` for
    /// closed-loop).
    pub fn process(&self) -> Option<ArrivalProcess> {
        match self {
            LoadMode::Open { rate } => Some(ArrivalProcess::Poisson { rate: *rate }),
            LoadMode::OpenProcess { process } => Some(process.clone()),
            LoadMode::Executor { process, .. } => Some(process.clone()),
            LoadMode::Closed { .. } => None,
        }
    }
}

/// Default SLO threshold: 5 ms, a common interactive-page budget at the
/// paper's request sizes.
pub const DEFAULT_SLO: Time = 5 * MS;

/// State shared between the arrival driver and the worker task bodies.
#[derive(Clone, Debug)]
pub struct ServerShared {
    /// Pending requests, oldest first.
    pub queue: VecDeque<Request>,
    /// Completions only count once measuring is on (post-warmup).
    pub measuring: bool,
    /// Aggregate latency/SLO recorder.
    pub stats: LatencyStats,
    /// Per-tenant recorders (single entry for single-stream processes).
    pub tenant_stats: Vec<LatencyStats>,
    /// Closed-loop: completed requests respawn themselves.
    pub closed_loop: bool,
    /// Drops (queue overflow guard for pathological overload).
    pub max_queue: usize,
    pub dropped: u64,
}

pub type Shared = Rc<RefCell<ServerShared>>;

impl ServerShared {
    /// Shared state for `n_tenants` request streams (≥ 1) measured
    /// against the given SLO threshold (ns).
    pub fn new(closed_loop: bool, slo: Time, n_tenants: usize) -> Shared {
        let n = n_tenants.max(1);
        Rc::new(RefCell::new(ServerShared {
            queue: VecDeque::new(),
            measuring: false,
            stats: LatencyStats::new(slo),
            tenant_stats: (0..n).map(|_| LatencyStats::new(slo)).collect(),
            closed_loop,
            max_queue: 100_000,
            dropped: 0,
        }))
    }

    /// Completed requests recorded in the measurement window.
    pub fn completed(&self) -> u64 {
        self.stats.completed()
    }

    /// Record a completed request; in closed-loop mode the connection
    /// immediately issues its next request.
    pub fn complete(&mut self, now: Time, req: Request) {
        if self.measuring {
            let latency = now.saturating_sub(req.arrived);
            self.stats.record(latency);
            if let Some(t) = self.tenant_stats.get_mut(req.tenant as usize) {
                t.record(latency);
            }
        }
        if self.closed_loop {
            self.queue.push_back(Request { arrived: now, tenant: req.tenant });
        }
    }

    pub fn push_arrival(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.max_queue {
            self.dropped += 1;
            return false;
        }
        self.queue.push_back(req);
        true
    }

    /// Begin the measurement window (after warmup) — zero the recorders
    /// in place (the histogram bucket vectors are reused, not
    /// reallocated).
    pub fn start_measuring(&mut self) {
        self.measuring = true;
        self.stats.reset();
        for t in &mut self.tenant_stats {
            t.reset();
        }
        self.dropped = 0;
    }

    /// Checkpoint-fork twin with recorders drawn from `arena` instead of
    /// deep-cloned. Only valid before measurement starts: a recycled
    /// recorder arrives cleared, which is indistinguishable from a clone
    /// exactly because [`ServerShared::start_measuring`] resets every
    /// recorder before the first sample is recorded.
    pub fn fork_with_arena(&self, arena: &mut crate::traffic::RecorderArena) -> ServerShared {
        debug_assert!(
            !self.measuring,
            "forking mid-measurement would discard recorded samples"
        );
        ServerShared {
            queue: self.queue.clone(),
            measuring: self.measuring,
            stats: arena.take(self.stats.slo),
            tenant_stats: self.tenant_stats.iter().map(|t| arena.take(t.slo)).collect(),
            closed_loop: self.closed_loop,
            max_queue: self.max_queue,
            dropped: self.dropped,
        }
    }
}

/// Open-loop arrival driver (external tag 0 = next arrival): samples an
/// [`ArrivalGen`] stream, pushes [`Request`]s, and wakes a worker.
pub struct TrafficDriver {
    pub shared: Shared,
    pub ch: u32,
    gen: ArrivalGen,
    /// Tenant of the already-scheduled next arrival.
    next_tenant: u32,
}

impl TrafficDriver {
    pub fn new(shared: Shared, ch: u32, process: ArrivalProcess, seed: u64) -> Self {
        TrafficDriver { shared, ch, gen: ArrivalGen::new(process, seed), next_tenant: 0 }
    }

    /// Install the driver's first arrival event.
    pub fn start(&mut self, m: &mut Machine) {
        let now = m.now();
        let (t, tenant) = self.gen.next_after(now);
        self.next_tenant = tenant;
        m.schedule_external(t, 0);
    }

    /// Checkpoint-fork twin: generator state is cloned (the arrival
    /// stream continues bit-identically), the shared queue rewires
    /// through `ctx` onto the fork's copy.
    pub fn fork(&self, ctx: &mut ForkCtx) -> TrafficDriver {
        TrafficDriver {
            shared: ctx.fork_rc(&self.shared),
            ch: self.ch,
            gen: self.gen.clone(),
            next_tenant: self.next_tenant,
        }
    }
}

impl Driver for TrafficDriver {
    fn on_external(&mut self, _tag: u64, m: &mut Machine) {
        let now = m.now();
        let req = Request { arrived: now, tenant: self.next_tenant };
        if self.shared.borrow_mut().push_arrival(req) {
            m.notify(self.ch);
        }
        let (t, tenant) = self.gen.next_after(now);
        self.next_tenant = tenant;
        m.schedule_external(t, 0);
    }
}

/// Open-loop arrival driver fed from a *precomputed* `(time, tenant)`
/// stream instead of a live generator — the fleet layer demultiplexes
/// one shared [`ArrivalGen`] stream across machines and replays each
/// machine's share through this driver.
///
/// The event choreography deliberately mirrors [`TrafficDriver`]
/// (push → notify → schedule next, all inside one external event), so a
/// machine replaying the *full* stream of its own generator is
/// event-for-event identical to the live driver: the only difference is
/// that no arrival is scheduled past the end of the trace, and an event
/// scheduled beyond the run horizon never fires anyway. This is the
/// invariant behind the size-1-fleet ≡ single-machine differential test
/// in `rust/tests/fleet.rs`.
pub struct TraceDriver {
    pub shared: Shared,
    pub ch: u32,
    trace: Vec<(Time, u32)>,
    pos: usize,
    /// Tenant of the already-scheduled next arrival.
    next_tenant: u32,
}

impl TraceDriver {
    /// `trace` must be strictly increasing in time (as produced by
    /// [`ArrivalGen::next_after`] chaining).
    pub fn new(shared: Shared, ch: u32, trace: Vec<(Time, u32)>) -> Self {
        debug_assert!(trace.windows(2).all(|w| w[0].0 < w[1].0), "trace must be ordered");
        TraceDriver { shared, ch, trace, pos: 0, next_tenant: 0 }
    }

    /// Install the first arrival event (no-op for an empty trace — a
    /// machine the router never picks simply idles).
    pub fn start(&mut self, m: &mut Machine) {
        if let Some(&(t, tenant)) = self.trace.first() {
            self.pos = 1;
            self.next_tenant = tenant;
            m.schedule_external(t, 0);
        }
    }

    /// Checkpoint-fork twin: replay position is cloned, the shared queue
    /// rewires through `ctx` onto the fork's copy.
    pub fn fork(&self, ctx: &mut ForkCtx) -> TraceDriver {
        TraceDriver {
            shared: ctx.fork_rc(&self.shared),
            ch: self.ch,
            trace: self.trace.clone(),
            pos: self.pos,
            next_tenant: self.next_tenant,
        }
    }
}

impl Driver for TraceDriver {
    fn on_external(&mut self, _tag: u64, m: &mut Machine) {
        let now = m.now();
        let req = Request { arrived: now, tenant: self.next_tenant };
        if self.shared.borrow_mut().push_arrival(req) {
            m.notify(self.ch);
        }
        if let Some(&(t, tenant)) = self.trace.get(self.pos) {
            self.pos += 1;
            self.next_tenant = tenant;
            m.schedule_external(t, 0);
        }
    }
}

/// [`TraceDriver`] variant for fault-injected runs: each entry carries
/// a *delivery* time (when the request reaches the machine, i.e. the
/// front-end send time plus any injected link delay) and a separate
/// *arrival stamp* (what the machine's — possibly skewed — clock
/// records on the [`Request`]). With `deliver == stamp` on every entry
/// the event choreography is identical to [`TraceDriver`]; the
/// fault-free fleet paths keep using [`TraceDriver`] itself, so this
/// type only ever executes when faults are active.
///
/// Delivery times may tie (a delay window can push two sends onto the
/// same instant), so the order requirement is non-decreasing rather
/// than strictly increasing.
pub struct FaultTraceDriver {
    pub shared: Shared,
    pub ch: u32,
    /// `(deliver, arrival stamp, tenant)`, sorted by `deliver`.
    trace: Vec<(Time, Time, u32)>,
    pos: usize,
    /// `(arrival stamp, tenant)` of the already-scheduled next arrival.
    next: (Time, u32),
}

impl FaultTraceDriver {
    /// `trace` must be non-decreasing in delivery time (the fleet layer
    /// sorts after applying link delays).
    pub fn new(shared: Shared, ch: u32, trace: Vec<(Time, Time, u32)>) -> Self {
        debug_assert!(
            trace.windows(2).all(|w| w[0].0 <= w[1].0),
            "fault trace must be ordered by delivery time"
        );
        FaultTraceDriver { shared, ch, trace, pos: 0, next: (0, 0) }
    }

    /// Install the first arrival event (no-op for an empty trace).
    pub fn start(&mut self, m: &mut Machine) {
        if let Some(&(t, stamp, tenant)) = self.trace.first() {
            self.pos = 1;
            self.next = (stamp, tenant);
            m.schedule_external(t, 0);
        }
    }

    /// Checkpoint-fork twin, mirroring [`TraceDriver::fork`].
    pub fn fork(&self, ctx: &mut ForkCtx) -> FaultTraceDriver {
        FaultTraceDriver {
            shared: ctx.fork_rc(&self.shared),
            ch: self.ch,
            trace: self.trace.clone(),
            pos: self.pos,
            next: self.next,
        }
    }
}

impl Driver for FaultTraceDriver {
    fn on_external(&mut self, _tag: u64, m: &mut Machine) {
        let (stamp, tenant) = self.next;
        let req = Request { arrived: stamp, tenant };
        if self.shared.borrow_mut().push_arrival(req) {
            m.notify(self.ch);
        }
        if let Some(&(t, stamp, tenant)) = self.trace.get(self.pos) {
            self.pos += 1;
            self.next = (stamp, tenant);
            m.schedule_external(t, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_counts_only_while_measuring() {
        let s = ServerShared::new(false, DEFAULT_SLO, 1);
        s.borrow_mut().complete(100, Request::at(50));
        assert_eq!(s.borrow().completed(), 0);
        s.borrow_mut().start_measuring();
        s.borrow_mut().complete(200, Request::at(60));
        assert_eq!(s.borrow().completed(), 1);
        assert_eq!(s.borrow().stats.hist.max(), 140);
    }

    #[test]
    fn closed_loop_respawns_with_tenant() {
        let s = ServerShared::new(true, DEFAULT_SLO, 2);
        s.borrow_mut().complete(100, Request { arrived: 50, tenant: 1 });
        assert_eq!(s.borrow().queue.len(), 1);
        assert_eq!(s.borrow().queue[0], Request { arrived: 100, tenant: 1 });
    }

    #[test]
    fn queue_overflow_drops() {
        let s = ServerShared::new(false, DEFAULT_SLO, 1);
        s.borrow_mut().max_queue = 2;
        assert!(s.borrow_mut().push_arrival(Request::at(1)));
        assert!(s.borrow_mut().push_arrival(Request::at(2)));
        assert!(!s.borrow_mut().push_arrival(Request::at(3)));
        assert_eq!(s.borrow().dropped, 1);
    }

    #[test]
    fn per_tenant_stats_separate() {
        let s = ServerShared::new(false, DEFAULT_SLO, 2);
        s.borrow_mut().start_measuring();
        s.borrow_mut().complete(1_000, Request { arrived: 0, tenant: 0 });
        s.borrow_mut().complete(9_000, Request { arrived: 0, tenant: 1 });
        let sh = s.borrow();
        assert_eq!(sh.completed(), 2);
        assert_eq!(sh.tenant_stats[0].completed(), 1);
        assert_eq!(sh.tenant_stats[1].completed(), 1);
        assert!(sh.tenant_stats[1].hist.max() > sh.tenant_stats[0].hist.max());
    }

    #[test]
    fn open_mode_desugars_to_poisson() {
        let m = LoadMode::Open { rate: 1_000.0 };
        assert_eq!(m.process(), Some(ArrivalProcess::Poisson { rate: 1_000.0 }));
        assert!(LoadMode::Closed { connections: 4 }.process().is_none());
    }

    #[test]
    fn executor_mode_is_open_loop() {
        let m = LoadMode::Executor {
            process: ArrivalProcess::Poisson { rate: 500.0 },
            tpc: crate::tpc::TpcParams::default(),
        };
        assert_eq!(m.process(), Some(ArrivalProcess::Poisson { rate: 500.0 }));
    }
}
