//! wrk2-style load generation and measurement.
//!
//! The paper drives nginx with wrk2 on 4 dedicated cores. wrk2 is an
//! *open-loop, fixed-rate* generator (it corrects for coordinated
//! omission); throughput differences between variants appear when the
//! offered rate exceeds a variant's capacity. A closed-loop mode
//! (fixed number of in-flight connections) is also provided — it drives
//! every variant exactly at its own capacity.

use crate::sched::machine::{Driver, Machine};
use crate::sim::Time;
use crate::util::{LogHistogram, Rng};
use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

/// Load-generation mode.
#[derive(Clone, Copy, Debug)]
pub enum LoadMode {
    /// Poisson arrivals at a fixed rate (requests/second).
    Open { rate: f64 },
    /// Fixed number of always-pending connections; a completed request
    /// immediately enqueues the connection's next request.
    Closed { connections: usize },
}

/// State shared between the arrival driver and the worker task bodies.
#[derive(Debug)]
pub struct ServerShared {
    /// Pending requests (arrival timestamps).
    pub queue: VecDeque<Time>,
    /// Completions only count once measuring is on (post-warmup).
    pub measuring: bool,
    pub completed: u64,
    pub latency: LogHistogram,
    /// Closed-loop: completed requests respawn themselves.
    pub closed_loop: bool,
    /// Drops (queue overflow guard for pathological overload).
    pub max_queue: usize,
    pub dropped: u64,
}

pub type Shared = Rc<RefCell<ServerShared>>;

impl ServerShared {
    pub fn new(closed_loop: bool) -> Shared {
        Rc::new(RefCell::new(ServerShared {
            queue: VecDeque::new(),
            measuring: false,
            completed: 0,
            latency: LogHistogram::new(),
            closed_loop,
            max_queue: 100_000,
            dropped: 0,
        }))
    }

    /// Record a completed request; in closed-loop mode the connection
    /// immediately issues its next request.
    pub fn complete(&mut self, now: Time, arrived: Time) {
        if self.measuring {
            self.completed += 1;
            self.latency.record(now.saturating_sub(arrived));
        }
        if self.closed_loop {
            self.queue.push_back(now);
        }
    }

    pub fn push_arrival(&mut self, now: Time) -> bool {
        if self.queue.len() >= self.max_queue {
            self.dropped += 1;
            return false;
        }
        self.queue.push_back(now);
        true
    }

    /// Begin the measurement window (after warmup) — zero the counters.
    pub fn start_measuring(&mut self) {
        self.measuring = true;
        self.completed = 0;
        self.latency = LogHistogram::new();
        self.dropped = 0;
    }
}

/// Poisson arrival driver (external tag 0 = next arrival).
pub struct OpenLoopDriver {
    pub shared: Shared,
    pub ch: u32,
    pub rate: f64,
    pub rng: Rng,
}

impl Driver for OpenLoopDriver {
    fn on_external(&mut self, _tag: u64, m: &mut Machine) {
        let now = m.now();
        if self.shared.borrow_mut().push_arrival(now) {
            m.notify(self.ch);
        }
        let mean_gap_ns = 1e9 / self.rate;
        let gap = self.rng.exponential(mean_gap_ns).max(1.0) as Time;
        m.schedule_external(now + gap, 0);
    }
}

impl OpenLoopDriver {
    /// Install the driver's first arrival event.
    pub fn start(&self, m: &mut Machine) {
        m.schedule_external(m.now() + 1, 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn complete_counts_only_while_measuring() {
        let s = ServerShared::new(false);
        s.borrow_mut().complete(100, 50);
        assert_eq!(s.borrow().completed, 0);
        s.borrow_mut().start_measuring();
        s.borrow_mut().complete(200, 60);
        assert_eq!(s.borrow().completed, 1);
        assert_eq!(s.borrow().latency.max(), 140);
    }

    #[test]
    fn closed_loop_respawns() {
        let s = ServerShared::new(true);
        s.borrow_mut().complete(100, 50);
        assert_eq!(s.borrow().queue.len(), 1);
    }

    #[test]
    fn queue_overflow_drops() {
        let s = ServerShared::new(false);
        s.borrow_mut().max_queue = 2;
        assert!(s.borrow_mut().push_arrival(1));
        assert!(s.borrow_mut().push_arrival(2));
        assert!(!s.borrow_mut().push_arrival(3));
        assert_eq!(s.borrow().dropped, 1);
    }
}
