//! ChaCha20-Poly1305 instruction-stream model per SIMD instruction set.
//!
//! The model mirrors how OpenSSL's implementations actually map onto
//! Intel's license classes:
//!
//! * **SSE4** — 128-bit operations: always license L0 (the paper's
//!   baseline that "does not cause any frequency drop").
//! * **AVX2** — ChaCha20 is 256-bit *integer* code (light AVX2 → L0);
//!   Poly1305's multiplies are heavy AVX2 → L1 when dense.
//! * **AVX-512** — ChaCha20 is 512-bit integer (light AVX-512 → L1);
//!   Poly1305's 52-bit multiplies are heavy AVX-512 → L2 when dense.
//!
//! "When dense" is the paper's own caveat (§2, §3.3): the hardware only
//! reduces frequency when roughly one wide instruction per cycle is
//! *sustained*; detection itself takes ~100 instructions, and *"pipeline
//! stalls during execution due to dependencies can cause the vector
//! instruction frequency to be decreased enough to prevent frequency
//! changes."* TLS record processing interleaves short (µs-scale) vector
//! bursts with framing code, so only a fraction of bursts sustains the
//! trigger condition. The model draws trigger-eligibility per burst
//! (`license_exempt` on the block); the probabilities below are
//! calibrated so the unmodified web server reproduces the paper's Fig 5/6
//! drops (see EXPERIMENTS.md §Calibration).
//!
//! Instruction-per-byte budgets are set so the cycles-per-byte resulting
//! from the IPC model land on published OpenSSL/BoringSSL throughput
//! ratios (Cloudflare [11]: ~2.9 GB/s AVX-512 vs ~1.6 GB/s AVX2 in
//! isolation, SSE4 ~½ of AVX2).

use crate::isa::block::{Block, ClassMix, InsnClass};
use crate::util::Rng;

/// SIMD instruction set OpenSSL is compiled for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Isa {
    Sse4,
    Avx2,
    Avx512,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Sse4 => "sse4",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
        }
    }

    pub fn all() -> [Isa; 3] {
        [Isa::Sse4, Isa::Avx2, Isa::Avx512]
    }
}

/// Cost/classification parameters for one ISA variant.
#[derive(Clone, Debug)]
pub struct CryptoProfile {
    pub isa: Isa,
    /// ChaCha20 instructions per byte (the bulk cipher).
    pub chacha_insn_per_byte: f64,
    /// Poly1305 instructions per byte (the MAC).
    pub poly_insn_per_byte: f64,
    /// Scalar framing/dispatch instructions per byte (record headers, IV
    /// setup, loop control) — common to all variants.
    pub framing_insn_per_byte: f64,
    /// Instruction class of the ChaCha20 stream.
    pub chacha_class: InsnClass,
    /// Instruction class of the Poly1305 multiply stream.
    pub poly_class: InsnClass,
    /// Probability that a ChaCha burst sustains the license trigger.
    pub chacha_dense_prob: f64,
    /// Probability that a MAC burst sustains the (heavy) license trigger.
    pub poly_dense_prob: f64,
}

impl CryptoProfile {
    pub fn for_isa(isa: Isa) -> Self {
        match isa {
            // 128-bit: ~3.3 insn/B total → ~1.5 cpb at IPC 2.2.
            Isa::Sse4 => CryptoProfile {
                isa,
                chacha_insn_per_byte: 2.30,
                poly_insn_per_byte: 0.85,
                framing_insn_per_byte: 0.15,
                chacha_class: InsnClass::Scalar,
                poly_class: InsnClass::Scalar,
                chacha_dense_prob: 0.0,
                poly_dense_prob: 0.0,
            },
            // 256-bit: ~0.95 cpb; integer ChaCha is license-free.
            Isa::Avx2 => CryptoProfile {
                isa,
                chacha_insn_per_byte: 1.05,
                poly_insn_per_byte: 0.52,
                framing_insn_per_byte: 0.15,
                chacha_class: InsnClass::Avx2Light,
                poly_class: InsnClass::Avx2Heavy,
                chacha_dense_prob: 1.0, // light AVX2 never demands anyway
                poly_dense_prob: 0.04,
            },
            // 512-bit: ~0.62 cpb; integer ChaCha is light AVX-512 (L1).
            Isa::Avx512 => CryptoProfile {
                isa,
                chacha_insn_per_byte: 0.55,
                poly_insn_per_byte: 0.30,
                framing_insn_per_byte: 0.15,
                chacha_class: InsnClass::Avx512Light,
                poly_class: InsnClass::Avx512Heavy,
                chacha_dense_prob: 0.034,
                poly_dense_prob: 0.028,
            },
        }
    }

    /// Function names as they appear in the simulated `libcrypto.so`
    /// (used by the static analyzer and the flame graph).
    pub fn chacha_symbol(&self) -> &'static str {
        match self.isa {
            Isa::Sse4 => "ChaCha20_ctr32_ssse3",
            Isa::Avx2 => "ChaCha20_ctr32_avx2",
            Isa::Avx512 => "ChaCha20_ctr32_avx512",
        }
    }

    pub fn poly_symbol(&self) -> &'static str {
        match self.isa {
            Isa::Sse4 => "poly1305_blocks_sse2",
            Isa::Avx2 => "poly1305_blocks_avx2",
            Isa::Avx512 => "poly1305_blocks_avx512",
        }
    }

    /// ChaCha20 block for `bytes` of payload; `rng` draws whether this
    /// burst sustains the hardware trigger condition (§3.3).
    pub fn chacha_block(&self, bytes: usize, rng: &mut Rng) -> Block {
        let n = (bytes as f64 * self.chacha_insn_per_byte) as u64;
        let framing = (bytes as f64 * self.framing_insn_per_byte * 0.5) as u64;
        let exempt = self.chacha_class.is_wide() && !rng.chance(self.chacha_dense_prob);
        Block {
            mix: ClassMix::of(self.chacha_class, n).with(InsnClass::Scalar, framing),
            mem_ops: (bytes / 64) as u64, // streaming loads/stores, cache-line granular
            branches: n / 64,
            license_exempt: exempt,
        }
    }

    /// Poly1305 block for `bytes`; trigger-eligibility drawn per burst.
    pub fn poly_block(&self, bytes: usize, rng: &mut Rng) -> Block {
        let n = (bytes as f64 * self.poly_insn_per_byte) as u64;
        let framing = (bytes as f64 * self.framing_insn_per_byte * 0.5) as u64;
        let exempt = self.poly_class.is_wide() && !rng.chance(self.poly_dense_prob);
        Block {
            mix: ClassMix::of(self.poly_class, n).with(InsnClass::Scalar, framing),
            mem_ops: (bytes / 64) as u64,
            branches: n / 48,
            license_exempt: exempt,
        }
    }

    /// Full AEAD record: ChaCha20 in 4 KiB chunks plus one MAC pass.
    /// Returns (symbol, block) pairs in execution order.
    pub fn record_blocks(&self, bytes: usize, rng: &mut Rng) -> Vec<(&'static str, Block)> {
        let mut out = Vec::new();
        let mut left = bytes;
        while left > 0 {
            let chunk = left.min(4096);
            out.push((self.chacha_symbol(), self.chacha_block(chunk, rng)));
            left -= chunk;
        }
        out.push((self.poly_symbol(), self.poly_block(bytes, rng)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::freq::FreqParams;
    use crate::cpu::ipc::{cost_block, license_demand, IpcParams};

    fn cpb(isa: Isa) -> f64 {
        // Mean cycles per byte over many 16 KiB records (averages the
        // per-record density draw).
        let p = CryptoProfile::for_isa(isa);
        let ipc = IpcParams::default();
        let mut rng = Rng::new(1);
        let mut cycles = 0.0;
        let bytes = 16384;
        let records = 64;
        for _ in 0..records {
            for (_, b) in p.record_blocks(bytes, &mut rng) {
                cycles += cost_block(&ipc, &b, 0.0).cycles;
            }
        }
        cycles / (bytes * records) as f64
    }

    #[test]
    fn cycles_per_byte_ordering() {
        let sse = cpb(Isa::Sse4);
        let avx2 = cpb(Isa::Avx2);
        let avx512 = cpb(Isa::Avx512);
        assert!(sse > avx2 && avx2 > avx512, "sse={sse} avx2={avx2} avx512={avx512}");
        // Rough published ratios: AVX2 ~1.5–1.9× faster than SSE4,
        // AVX-512 ~1.4–1.8× faster than AVX2 at equal frequency.
        assert!((1.3..2.2).contains(&(sse / avx2)), "sse/avx2 = {}", sse / avx2);
        assert!((1.2..2.2).contains(&(avx2 / avx512)), "avx2/avx512 = {}", avx2 / avx512);
    }

    #[test]
    fn sse4_never_demands_license() {
        let p = CryptoProfile::for_isa(Isa::Sse4);
        let fp = FreqParams::default();
        let ipc = IpcParams::default();
        let mut rng = Rng::new(2);
        for (_, b) in p.record_blocks(16384, &mut rng) {
            let c = cost_block(&ipc, &b, 0.0);
            assert_eq!(license_demand(&fp, &b, c.cycles), crate::cpu::License::L0);
        }
    }

    #[test]
    fn avx512_chacha_demands_l1_poly_l2_when_dense() {
        let mut p = CryptoProfile::for_isa(Isa::Avx512);
        p.poly_dense_prob = 1.0;
        p.chacha_dense_prob = 1.0;
        let fp = FreqParams::default();
        let ipc = IpcParams::default();
        let mut rng = Rng::new(3);
        let cb = p.chacha_block(4096, &mut rng);
        let cc = cost_block(&ipc, &cb, 0.0);
        assert_eq!(license_demand(&fp, &cb, cc.cycles), crate::cpu::License::L1);
        let pb = p.poly_block(16384, &mut rng);
        let pc = cost_block(&ipc, &pb, 0.0);
        assert_eq!(license_demand(&fp, &pb, pc.cycles), crate::cpu::License::L2);
    }

    #[test]
    fn non_dense_poly_stays_below_trigger() {
        let mut p = CryptoProfile::for_isa(Isa::Avx512);
        p.poly_dense_prob = 0.0;
        let fp = FreqParams::default();
        let ipc = IpcParams::default();
        let mut rng = Rng::new(4);
        let pb = p.poly_block(16384, &mut rng);
        let pc = cost_block(&ipc, &pb, 0.0);
        assert!(
            license_demand(&fp, &pb, pc.cycles) < crate::cpu::License::L2,
            "stalled MAC stream must not trigger the heavy license"
        );
    }

    #[test]
    fn avx2_chacha_is_license_free() {
        let mut p = CryptoProfile::for_isa(Isa::Avx2);
        p.chacha_dense_prob = 1.0; // even dense 256-bit integer code is L0
        let fp = FreqParams::default();
        let ipc = IpcParams::default();
        let mut rng = Rng::new(5);
        let cb = p.chacha_block(4096, &mut rng);
        let cc = cost_block(&ipc, &cb, 0.0);
        assert_eq!(
            license_demand(&fp, &cb, cc.cycles),
            crate::cpu::License::L0,
            "256-bit integer code must not reduce frequency"
        );
    }

    #[test]
    fn trigger_probability_respected() {
        let p = CryptoProfile::for_isa(Isa::Avx512);
        let mut rng = Rng::new(6);
        let n = 4000;
        let eligible = (0..n)
            .filter(|_| !p.chacha_block(4096, &mut rng).license_exempt)
            .count();
        let frac = eligible as f64 / n as f64;
        assert!(
            (frac - p.chacha_dense_prob).abs() < 0.015,
            "trigger fraction {frac} vs configured {}",
            p.chacha_dense_prob
        );
    }

    #[test]
    fn record_blocks_cover_payload() {
        let p = CryptoProfile::for_isa(Isa::Avx512);
        let mut rng = Rng::new(5);
        let blocks = p.record_blocks(10_000, &mut rng);
        // 3 chacha chunks (4096+4096+1808) + 1 poly.
        assert_eq!(blocks.len(), 4);
        assert!(blocks.iter().take(3).all(|(s, _)| s.contains("ChaCha20")));
        assert!(blocks[3].0.contains("poly1305"));
    }
}
