//! On-the-fly compression model (the paper's brotli stage).
//!
//! Brotli at nginx's default on-the-fly quality (q4–q5) costs on the
//! order of 25–60 cycles/byte of *input* and compresses typical HTML to
//! 20–30% of its size. Compression is pure scalar/branchy code — exactly
//! the work the AVX-induced frequency reduction taxes. The model emits
//! 8 KiB-chunk blocks so license transitions interleave realistically.

use crate::isa::block::{Block, ClassMix};

/// Compression cost/ratio model.
#[derive(Clone, Debug)]
pub struct CompressProfile {
    /// Scalar instructions per input byte (≈ cycles/byte × IPC).
    pub insn_per_byte: f64,
    /// Output bytes per input byte.
    pub ratio: f64,
    /// Branches per instruction (compression is branch-heavy).
    pub branch_frac: f64,
    /// Memory ops per instruction (dictionary/window lookups).
    pub mem_frac: f64,
}

impl Default for CompressProfile {
    fn default() -> Self {
        // ~45 cpb at IPC ~1.6 effective (branchy, lookup-heavy).
        CompressProfile { insn_per_byte: 36.0, ratio: 0.28, branch_frac: 0.16, mem_frac: 0.22 }
    }
}

impl CompressProfile {
    /// Compressed size for an input size.
    pub fn output_bytes(&self, input: usize) -> usize {
        ((input as f64 * self.ratio) as usize).max(64)
    }

    /// Blocks for compressing `input` bytes, in 8 KiB chunks, attributed
    /// to the brotli encoder symbol.
    pub fn blocks(&self, input: usize) -> Vec<(&'static str, Block)> {
        let mut out = Vec::new();
        let mut left = input;
        while left > 0 {
            let chunk = left.min(8192);
            let n = (chunk as f64 * self.insn_per_byte) as u64;
            out.push((
                "BrotliEncoderCompressStream",
                Block {
                    mix: ClassMix::scalar(n),
                    mem_ops: (n as f64 * self.mem_frac) as u64,
                    branches: (n as f64 * self.branch_frac) as u64, license_exempt: false,
                },
            ));
            left -= chunk;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::ipc::{cost_block, IpcParams};

    #[test]
    fn ratio_shrinks_output() {
        let p = CompressProfile::default();
        assert!(p.output_bytes(65536) < 65536 / 3);
        assert!(p.output_bytes(10) >= 64, "floor for tiny inputs");
    }

    #[test]
    fn cost_in_published_brotli_range() {
        let p = CompressProfile::default();
        let ipc = IpcParams::default();
        let cycles: f64 =
            p.blocks(65536).iter().map(|(_, b)| cost_block(&ipc, b, 0.0).cycles).sum();
        let cpb = cycles / 65536.0;
        assert!((20.0..80.0).contains(&cpb), "brotli-q4-ish cpb, got {cpb}");
    }

    #[test]
    fn blocks_are_chunked_and_scalar() {
        let p = CompressProfile::default();
        let blocks = p.blocks(20_000);
        assert_eq!(blocks.len(), 3);
        for (_, b) in blocks {
            assert_eq!(b.mix.wide(), 0, "compression must be scalar");
        }
    }
}
