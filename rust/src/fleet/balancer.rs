//! Closed-loop front-end balancer: epoch-based feedback over the
//! hierarchical fleet.
//!
//! A real cluster front-end reacts to *observed* signals — it times
//! requests out and retries them, hedges slow requests after a
//! p99-based delay, and ejects machines whose tails blow up. Reacting
//! to per-request completions would couple routing to simulated
//! machine state and destroy the fleet's determinism contract
//! (byte-identical output at any OS thread count). The resolution is
//! **epoch-based feedback**: the run is sliced into epochs, every
//! machine in epoch *k* simulates independently (embarrassingly
//! parallel, exactly like the open loop), and the balancer adjusts
//! routing for epoch *k + 1* only from epoch *k*'s *merged* statistics.
//! Within an epoch routing is still a pure function of the arrival
//! stream; across epochs the feedback inputs are exact merged counters,
//! which are identical for every thread schedule — so the whole closed
//! loop stays byte-identical at any thread count.
//!
//! Feedback mechanisms (all estimated from the observed latency
//! distribution, never from per-request logs — memory stays O(machines)
//! scalars):
//!
//! * **Timeout + retry with backoff** — each machine's per-tenant share
//!   of completions above the timeout (histogram `fraction_above`, the
//!   front-end's observed-distribution estimate, ≤ ~3% bucket error) is
//!   re-injected into the next epoch as seeded retry arrivals after a
//!   backoff; attempts beyond `max_retries` are abandoned.
//! * **Hedging** — after a delay of `hedge_p99_mult ×` the previous
//!   epoch's cluster p99, the observed fraction of requests still
//!   outstanding is duplicated to the next healthy machine. Duplicates
//!   are modelled on the load side (the front-end takes whichever copy
//!   answers; both completions are recorded — a documented
//!   simplification).
//! * **Health ejection** — a machine whose epoch p99 exceeds
//!   `eject_factor ×` the healthy median is ejected for the next epoch;
//!   routing probes to the next healthy machine. An ejected machine
//!   receives no traffic, so its next epoch p99 reads 0 and it is
//!   readmitted — a one-epoch cooldown.
//!
//! Epoch boundaries are also the model's stated approximation: each
//! (machine, epoch) is a fresh simulation (queues and license state are
//! not carried across the boundary, in-flight work at the boundary is
//! lost), the same semantics the open loop applies at its horizon. The
//! feedback-disabled configuration does not approximate anything: it
//! runs the *identical* whole-horizon demux/simulate path as
//! [`run_fleet`], only the aggregation streams — the differential test
//! in `rust/tests/hierfleet.rs` pins byte equality.
//!
//! [`run_fleet`]: super::cluster::run_fleet

use super::cluster::{route_stream, FleetCfg};
use super::hierarchy::{collective_makespan, HierFleetRun, HierarchyAgg};
use crate::faults::{FaultTimeline, FaultWindowStat, FaultsCfg, LinkWindow};
use crate::sim::{Time, MS, SEC};
use crate::traffic::{ArrivalGen, FaultOutcomes, FrontendOutcomes, LatencyStats};
use crate::util::{mix64, Rng};
use crate::workload::webserver::{run_webserver_trace, run_webserver_trace_faulted, WebCfg};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Closed-loop balancer parameters. `Default` is the open loop (all
/// feedback off); [`BalancerCfg::closed`] enables every mechanism at
/// the defaults.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BalancerCfg {
    /// Master switch: `false` routes exactly like PR 3's open loop.
    pub enabled: bool,
    /// Feedback epochs over the measure window (the warmup window is an
    /// extra cold epoch, observed for feedback but never reported).
    pub epochs: usize,
    /// Per-request timeout (ns) the front-end measures against.
    pub timeout: Time,
    /// Retry attempts before a timed-out request is abandoned.
    pub max_retries: u32,
    /// Backoff before a retry is re-injected (ns).
    pub retry_backoff: Time,
    /// Hedge duplicates fire after `hedge_p99_mult ×` the previous
    /// epoch's cluster p99; `0.0` disables hedging.
    pub hedge_p99_mult: f64,
    /// Eject a machine whose epoch p99 exceeds `eject_factor ×` the
    /// healthy median; `0.0` disables ejection.
    pub eject_factor: f64,
}

impl Default for BalancerCfg {
    fn default() -> Self {
        BalancerCfg {
            enabled: false,
            epochs: 4,
            timeout: 20 * MS,
            max_retries: 2,
            retry_backoff: MS,
            hedge_p99_mult: 3.0,
            eject_factor: 3.0,
        }
    }
}

impl BalancerCfg {
    /// Every mechanism on at the defaults.
    pub fn closed() -> Self {
        BalancerCfg { enabled: true, ..Default::default() }
    }

    /// Short label for tables and cell identifiers.
    pub fn label(&self) -> String {
        if self.enabled {
            format!("closed({}ep)", self.epochs)
        } else {
            "open-loop".to_string()
        }
    }

    /// Read the `[balancer]` config section (all keys optional; absent
    /// section = open loop).
    ///
    /// ```toml
    /// [balancer]
    /// enabled = true
    /// epochs = 4
    /// timeout_ms = 20.0
    /// max_retries = 2
    /// retry_backoff_ms = 1.0
    /// hedge_p99_mult = 3.0    # 0 disables hedging
    /// eject_factor = 3.0      # 0 disables health ejection
    /// ```
    pub fn from_config(conf: &crate::util::config::Config) -> anyhow::Result<BalancerCfg> {
        let d = BalancerCfg::default();
        let ms = |x: f64| (x * MS as f64).round() as Time;
        let cfg = BalancerCfg {
            enabled: conf.bool_or("balancer.enabled", d.enabled),
            epochs: conf.usize_or("balancer.epochs", d.epochs),
            timeout: ms(conf.float_or("balancer.timeout_ms", d.timeout as f64 / MS as f64)),
            max_retries: conf.usize_or("balancer.max_retries", d.max_retries as usize) as u32,
            retry_backoff: ms(conf
                .float_or("balancer.retry_backoff_ms", d.retry_backoff as f64 / MS as f64)),
            hedge_p99_mult: conf.float_or("balancer.hedge_p99_mult", d.hedge_p99_mult),
            eject_factor: conf.float_or("balancer.eject_factor", d.eject_factor),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject parameterizations the loop cannot execute sensibly.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.epochs >= 1, "balancer.epochs must be ≥ 1");
        anyhow::ensure!(self.timeout > 0, "balancer timeout must be positive");
        anyhow::ensure!(
            self.hedge_p99_mult.is_finite() && self.hedge_p99_mult >= 0.0,
            "balancer.hedge_p99_mult must be finite and ≥ 0"
        );
        anyhow::ensure!(
            self.eject_factor.is_finite() && self.eject_factor >= 0.0,
            "balancer.eject_factor must be finite and ≥ 0"
        );
        Ok(())
    }
}

/// Hierarchical fleet configuration: the flat [`FleetCfg`] plus rack
/// shape, balancer, and the optional collective model.
#[derive(Clone, Debug)]
pub struct HierFleetCfg {
    pub fleet: FleetCfg,
    /// Machines per rack (contiguous chunks; the last rack may be
    /// short).
    pub machines_per_rack: usize,
    pub balancer: BalancerCfg,
    /// Bulk-synchronous collective steps to model over the digests
    /// (0 = skip).
    pub collective_steps: usize,
    /// Deterministic fault injection (`[faults]` section). The default
    /// is disabled, and a disabled config takes the literal fault-free
    /// code paths — `rust/tests/faults.rs` pins byte equality.
    pub faults: FaultsCfg,
}

impl HierFleetCfg {
    pub fn new(fleet: FleetCfg, balancer: BalancerCfg) -> Self {
        HierFleetCfg {
            fleet,
            machines_per_rack: 8,
            balancer,
            collective_steps: 0,
            faults: FaultsCfg::default(),
        }
    }

    /// Extend [`FleetCfg::from_config`] with the `[balancer]` and
    /// `[faults]` sections plus `fleet.machines_per_rack` /
    /// `fleet.collective_steps`.
    pub fn from_config(conf: &crate::util::config::Config) -> anyhow::Result<HierFleetCfg> {
        let fleet = FleetCfg::from_config(conf)?;
        let faults = FaultsCfg::from_config(conf, fleet.cfg.measure)?;
        let cfg = HierFleetCfg {
            fleet,
            machines_per_rack: conf.usize_or("fleet.machines_per_rack", 8).max(1),
            balancer: BalancerCfg::from_config(conf)?,
            collective_steps: conf.usize_or("fleet.collective_steps", 0),
            faults,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.fleet.validate()?;
        self.balancer.validate()?;
        self.faults.validate(self.fleet.cfg.measure, self.fleet.machines.max(1))?;
        if self.balancer.enabled {
            anyhow::ensure!(
                self.fleet.cfg.measure / self.balancer.epochs as Time > 0,
                "measure window too short for {} feedback epochs",
                self.balancer.epochs
            );
        }
        Ok(())
    }

    /// The fault timeline both loops consume: expanded once over the
    /// measure window from the fleet seed, so open and closed loops see
    /// the *identical* fault schedule (`repro faulttol` relies on
    /// this). `None` when faults are disabled — the signal for every
    /// consumer to take the literal pre-PR path.
    fn fault_timeline(&self) -> Option<FaultTimeline> {
        self.faults.active().then(|| {
            FaultTimeline::build(
                &self.faults,
                self.fleet.cfg.measure,
                self.fleet.machines.max(1),
                self.fleet.cfg.seed,
            )
        })
    }
}

/// One front-end arrival inside an epoch.
#[derive(Clone, Copy, Debug)]
struct Arr {
    t: Time,
    tenant: u32,
    /// 0 for base arrivals, n ≥ 1 for the n-th retry attempt.
    attempt: u32,
    /// Hedge duplicates never retry or re-hedge, and carry a
    /// pre-assigned machine when they spill into a later epoch.
    hedge: bool,
    machine: Option<usize>,
}

/// Per-machine observations from one epoch, computed on the worker
/// thread before the machine's run is dropped.
#[derive(Clone, Debug, Default)]
struct EpochObs {
    completed: u64,
    p99: Time,
    /// Per-tenant fraction of completions above the timeout.
    tenant_frac: Vec<f64>,
}

/// Run the hierarchical fleet. Feedback disabled (`!balancer.enabled`)
/// executes the identical whole-horizon path as [`run_fleet`] — same
/// traces, same machine seeds, same per-machine simulations — with the
/// streaming aggregation in place of retained `WebRun`s. Feedback
/// enabled runs the epoch loop described in the module docs. Both are
/// byte-identical at any `threads` value.
///
/// [`run_fleet`]: super::cluster::run_fleet
pub fn run_hier_fleet(cfg: &HierFleetCfg, threads: usize) -> HierFleetRun {
    cfg.validate().expect("invalid hierarchical fleet configuration");
    if cfg.balancer.enabled {
        run_closed_loop(cfg, threads)
    } else {
        run_open_loop(cfg, threads)
    }
}

/// One machine's work for one simulation window.
///
/// `Plain` is the pre-PR path, verbatim: one whole-window
/// [`run_webserver_trace`] call — every fault-free configuration uses
/// it, which is what keeps faults-disabled runs byte-identical to
/// pre-PR output. `Segments` is the fault path: a crashed machine's
/// window splits into up-segments, each a *fresh* simulation (cold
/// caches, reset license/EWMA state — the restart semantics) replaying
/// a `(deliver, arrived-stamp, tenant)` trace that already carries the
/// link delays and clock skew.
enum MachineJob {
    Plain(WebCfg, Vec<(Time, u32)>),
    Segments(Vec<(WebCfg, Vec<(Time, Time, u32)>)>),
}

/// Simulate a set of per-machine jobs across worker threads, absorbing
/// each run into the aggregation as it finishes (the `WebRun` is
/// dropped on the worker thread). `observe` optionally captures epoch
/// observations per machine before the drop.
///
/// A `Segments` job runs its segments *sequentially on one worker*, in
/// segment order: the per-machine digest accumulates `f64` sums, so
/// segment absorption order must be fixed — and it is, because machine
/// `i`'s digest slot is only ever touched by the worker that claimed
/// job `i`.
fn simulate_into(
    jobs: Vec<MachineJob>,
    threads: usize,
    agg: &HierarchyAgg,
    absorb: bool,
    secs: f64,
    observe: Option<(&Mutex<LatencyStats>, &[Mutex<Option<EpochObs>>], Time, usize)>,
) {
    let n_jobs = jobs.len();
    let jobs: Vec<Mutex<Option<MachineJob>>> =
        jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let n_threads = threads.max(1).min(n_jobs.max(1));
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n_jobs {
                    break;
                }
                let job = jobs[i]
                    .lock()
                    .expect("trace poisoned")
                    .take()
                    .expect("each machine's trace is claimed exactly once");
                match job {
                    MachineJob::Plain(mcfg, trace) => {
                        let run = run_webserver_trace(&mcfg, trace);
                        if absorb {
                            agg.absorb(i, &run, secs);
                        }
                        if let Some((epoch_cluster, obs_slots, timeout, n_tenants)) = observe {
                            let obs = EpochObs {
                                completed: run.completed,
                                p99: run.stats.hist.percentile(99.0),
                                tenant_frac: (0..n_tenants)
                                    .map(|t| {
                                        run.tenant_stats
                                            .get(t)
                                            .map(|s| s.hist.fraction_above(timeout))
                                            .unwrap_or(0.0)
                                    })
                                    .collect(),
                            };
                            epoch_cluster
                                .lock()
                                .expect("epoch recorder poisoned")
                                .merge(&run.stats);
                            *obs_slots[i].lock().expect("obs slot poisoned") = Some(obs);
                        }
                        // `run` dropped here — nothing retains the WebRun.
                    }
                    MachineJob::Segments(segs) => {
                        let slo = segs.first().map(|(c, _)| c.slo).unwrap_or(0);
                        let mut merged = LatencyStats::new(slo);
                        let mut tenant_merged: Vec<LatencyStats> = Vec::new();
                        let mut completed = 0u64;
                        for (mcfg, strace) in segs {
                            let seg_secs = mcfg.measure as f64 / SEC as f64;
                            let run = run_webserver_trace_faulted(&mcfg, strace);
                            if absorb {
                                agg.absorb(i, &run, seg_secs);
                            }
                            if observe.is_some() {
                                merged.merge(&run.stats);
                                if tenant_merged.is_empty() {
                                    tenant_merged = run.tenant_stats.clone();
                                } else {
                                    for (acc, ts) in
                                        tenant_merged.iter_mut().zip(&run.tenant_stats)
                                    {
                                        acc.merge(ts);
                                    }
                                }
                                completed += run.completed;
                            }
                        }
                        if let Some((epoch_cluster, obs_slots, timeout, n_tenants)) = observe {
                            let obs = EpochObs {
                                completed,
                                p99: merged.hist.percentile(99.0),
                                tenant_frac: (0..n_tenants)
                                    .map(|t| {
                                        tenant_merged
                                            .get(t)
                                            .map(|s| s.hist.fraction_above(timeout))
                                            .unwrap_or(0.0)
                                    })
                                    .collect(),
                            };
                            epoch_cluster
                                .lock()
                                .expect("epoch recorder poisoned")
                                .merge(&merged);
                            *obs_slots[i].lock().expect("obs slot poisoned") = Some(obs);
                        }
                    }
                }
            });
        }
    });
}

fn finish(
    cfg: &HierFleetCfg,
    agg: HierarchyAgg,
    arrivals_routed: Vec<u64>,
    outcomes: FrontendOutcomes,
    fault_outcomes: FaultOutcomes,
    fault_windows: Vec<FaultWindowStat>,
) -> HierFleetRun {
    let snap = agg.finish(&arrivals_routed);
    let collective = (cfg.collective_steps > 0)
        .then(|| collective_makespan(&snap.digests, cfg.collective_steps, cfg.fleet.cfg.seed));
    HierFleetRun {
        router: cfg.fleet.router.label(),
        balancer: cfg.balancer.label(),
        machines: cfg.fleet.machines,
        machines_per_rack: cfg.machines_per_rack.max(1),
        tail: snap.cluster.summary(),
        completed: snap.cluster.completed(),
        violations: snap.cluster.violations(),
        digests: snap.digests,
        racks: snap.racks,
        stats: snap.cluster,
        tenant_stats: snap.tenants,
        outcomes,
        fault_outcomes,
        fault_windows,
        dropped: snap.dropped,
        measure_secs: cfg.fleet.cfg.measure as f64 / SEC as f64,
        collective,
    }
}

/// Fork a decorrelated seed for segment `j` of a crashed machine's
/// window; segment 0 keeps the window's own seed so a crash-free
/// machine's single segment is seeded exactly like its plain run.
fn segment_seed(base: u64, j: usize) -> u64 {
    if j == 0 {
        base
    } else {
        mix64(base ^ (j as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// Split one machine's faulted window `[w0, w1)` (absolute run time,
/// `rel0 = w0 - warmup` in fault-timeline time; the cold window passes
/// `segs = [(w0, w1)]` with no rebasing) into per-segment jobs. `trace`
/// entries are `(deliver, stamp, tenant)` in absolute run time; every
/// delivery is known to land in an up segment (dark deliveries were
/// counted lost at routing), except horizon-edge arrivals which ride
/// the last segment and simply never complete — the pre-PR horizon
/// semantics.
fn segment_jobs(
    fleet: &FleetCfg,
    tl: &FaultTimeline,
    i: usize,
    segs: &[(Time, Time)],
    base_seed: u64,
    trace: Vec<(Time, Time, u32)>,
    embed_warmup: bool,
    lost: &mut u64,
) -> MachineJob {
    let warmup = fleet.cfg.warmup;
    let mut seg_traces: Vec<Vec<(Time, Time, u32)>> = vec![Vec::new(); segs.len()];
    for (deliver, stamp, tenant) in trace {
        let idx = segs.iter().position(|&(s, e)| deliver >= s && deliver < e);
        match idx {
            Some(j) => {
                let s = segs[j].0;
                seg_traces[j].push((deliver - s, stamp.saturating_sub(s), tenant));
            }
            None if segs.last().is_some_and(|&(_, e)| deliver >= e) => {
                // Horizon edge: attach to the last segment; the local
                // deliver time is past its measure window, so the
                // request arrives but never completes.
                let &(s, _) = segs.last().expect("checked non-empty");
                seg_traces.last_mut().expect("checked").push((
                    deliver - s,
                    stamp.saturating_sub(s),
                    tenant,
                ));
            }
            None => *lost += 1, // delivered into a gap before the first up segment
        }
    }
    let jobs = segs
        .iter()
        .zip(seg_traces)
        .enumerate()
        .map(|(j, (&(s, e), strace))| {
            let mut mcfg = fleet.cfg.clone();
            // In the open loop a segment starting at absolute 0 keeps
            // the warmup inside it (the common no-crash-before-measure
            // case); later segments are cold restarts with no warmup.
            // The closed loop's windows never embed warmup — its cold
            // window is already a separate observation-only epoch.
            if embed_warmup && s == 0 {
                mcfg.warmup = warmup.min(e);
                mcfg.measure = e - mcfg.warmup;
            } else {
                mcfg.warmup = 0;
                mcfg.measure = e - s;
            }
            mcfg.seed = segment_seed(base_seed, j);
            // Degrade windows are fault-timeline time; machine-local
            // time 0 is absolute `s`, so shift by the embedded warmup
            // when the segment starts before the measure window.
            let (rel_s, rel_e) = (s.saturating_sub(warmup), e.saturating_sub(warmup));
            let mut dw = tl.degrade_in(i, rel_s, rel_e);
            let shift = (rel_s + warmup).saturating_sub(s);
            if shift > 0 {
                for w in &mut dw {
                    w.start += shift;
                    w.end += shift;
                }
            }
            mcfg.degrade = dw;
            (mcfg, strace)
        })
        .collect();
    MachineJob::Segments(jobs)
}

/// Feedback disabled: PR 3's open-loop demux/simulate path verbatim
/// (same `route_stream`, same `machine_seed`s, same whole-horizon
/// per-machine runs), streamed into the hierarchy instead of retained.
/// With faults active, each machine's routed trace is filtered through
/// the link faults (drops, delays, skew) and split at its crash
/// windows; there is no front end reacting, so lost requests are
/// simply lost — the open-loop half of the `repro faulttol`
/// comparison.
fn run_open_loop(cfg: &HierFleetCfg, threads: usize) -> HierFleetRun {
    let fleet = &cfg.fleet;
    let timeline = cfg.fault_timeline();
    let traces = route_stream(fleet);
    let arrivals_routed: Vec<u64> = traces.iter().map(|t| t.len() as u64).collect();
    let names =
        fleet.cfg.mode.process().expect("validate() rejects closed-loop fleets").tenant_names();
    let agg = HierarchyAgg::new(fleet.machines, cfg.machines_per_rack, fleet.cfg.slo, &names);
    let secs = fleet.cfg.measure as f64 / SEC as f64;
    let mut fault_out = FaultOutcomes::default();
    let jobs: Vec<MachineJob> = match &timeline {
        None => traces
            .into_iter()
            .enumerate()
            .map(|(i, trace)| {
                let mut mcfg = fleet.cfg.clone();
                mcfg.seed = fleet.machine_seed(i);
                MachineJob::Plain(mcfg, trace)
            })
            .collect(),
        Some(tl) => {
            let warmup = fleet.cfg.warmup;
            traces
                .into_iter()
                .enumerate()
                .map(|(i, trace)| {
                    let mut ftrace: Vec<(Time, Time, u32)> = Vec::with_capacity(trace.len());
                    for (t, tenant) in trace {
                        match t.checked_sub(warmup) {
                            Some(rel) if tl.dropped(i, rel) => fault_out.dropped_by_net += 1,
                            Some(rel) => {
                                let deliver = t.saturating_add(tl.delay(i, rel));
                                if tl.is_dark(i, deliver.saturating_sub(warmup)) {
                                    fault_out.lost_to_crash += 1;
                                } else {
                                    ftrace.push((deliver, tl.skewed(i, deliver), tenant));
                                }
                            }
                            // Warmup arrivals predate the fault window.
                            None => ftrace.push((t, t, tenant)),
                        }
                    }
                    // Delays at window edges can reorder deliveries.
                    ftrace.sort_unstable_by_key(|&(d, s, tn)| (d, s, tn));
                    let segs: Vec<(Time, Time)> = tl
                        .up_segments(i, 0, fleet.cfg.measure)
                        .into_iter()
                        .map(|(s, e)| {
                            (if s == 0 { 0 } else { s + warmup }, e + warmup)
                        })
                        .collect();
                    segment_jobs(
                        fleet,
                        tl,
                        i,
                        &segs,
                        fleet.machine_seed(i),
                        ftrace,
                        true,
                        &mut fault_out.lost_to_crash,
                    )
                })
                .collect()
        }
    };
    if let Some(tl) = &timeline {
        let (c, d, _) = tl.window_counts();
        fault_out.crash_windows = c;
        fault_out.degrade_windows = d;
    }
    simulate_into(jobs, threads, &agg, true, secs, None);
    finish(cfg, agg, arrivals_routed, FrontendOutcomes::default(), fault_out, Vec::new())
}

/// Seed for (machine `i`, epoch window `k`): window 0 keeps the
/// machine's open-loop seed; later windows fork so each epoch's worker
/// RNG streams decorrelate.
fn epoch_machine_seed(fleet: &FleetCfg, i: usize, k: usize) -> u64 {
    let base = fleet.machine_seed(i);
    if k == 0 {
        base
    } else {
        mix64(base ^ (k as u64).wrapping_mul(0xD1B5_4A32_D192_ED03))
    }
}

/// First healthy machine at or after `from` (wrapping); `from` itself
/// if the whole fleet is ejected (the guard in the ejection pass makes
/// that unreachable, but routing must never fail).
fn pick_healthy(from: usize, healthy: &[bool]) -> usize {
    let n = healthy.len();
    (0..n).map(|d| (from + d) % n).find(|&m| healthy[m]).unwrap_or(from)
}

/// First healthy machine strictly after `primary` (wrapping, ≠
/// `primary` if any other healthy machine exists).
fn next_healthy_after(primary: usize, healthy: &[bool]) -> usize {
    let n = healthy.len();
    (1..n).map(|d| (primary + d) % n).find(|&m| healthy[m]).unwrap_or(primary)
}

fn run_closed_loop(cfg: &HierFleetCfg, threads: usize) -> HierFleetRun {
    let fleet = &cfg.fleet;
    let bal = &cfg.balancer;
    let n = fleet.machines.max(1);
    let process = fleet.cfg.mode.process().expect("validate() rejects closed-loop fleets");
    let names = process.tenant_names();
    let n_tenants = process.n_tenants();
    let agg = HierarchyAgg::new(n, cfg.machines_per_rack, fleet.cfg.slo, &names);

    // Epoch windows: a cold window over [0, warmup) (observed for
    // feedback, never absorbed into the reported aggregates), then
    // `epochs` slices of the measure window (the last takes the integer
    // remainder).
    let mut windows: Vec<(Time, Time)> = Vec::new();
    if fleet.cfg.warmup > 0 {
        windows.push((0, fleet.cfg.warmup));
    }
    let measured_from = windows.len();
    let horizon = fleet.cfg.warmup + fleet.cfg.measure;
    let e_len = fleet.cfg.measure / bal.epochs as Time;
    let mut start = fleet.cfg.warmup;
    for k in 0..bal.epochs {
        let end = if k + 1 == bal.epochs { horizon } else { start + e_len };
        windows.push((start, end));
        start = end;
    }

    // The base arrival stream: identical generation to the open loop.
    let mut gen = ArrivalGen::new(process.clone(), fleet.cfg.seed ^ 0xDEAD);
    let mut base: Vec<(Time, u32)> = Vec::new();
    let mut now = 0;
    loop {
        let (t, tenant) = gen.next_after(now);
        if t > horizon {
            break;
        }
        base.push((t, tenant));
        now = t;
    }

    // Front-end state carried across epochs. The router's bookkeeping
    // persists (it is still a pure function of what it was asked to
    // route); the health mask and hedge/retry queues are the feedback.
    let mut router = fleet.router.build(n);
    let mut healthy = vec![true; n];
    let mut outcomes = FrontendOutcomes::default();
    let mut arrivals_routed = vec![0u64; n];
    let mut injected: Vec<Arr> = Vec::new();
    let mut hedge_frac = 0.0f64;
    let mut hedge_delay: Time = 0;

    // Fault state. `timeline.is_none()` on every fault-free run, and
    // every fault branch below gates on it, so the fault-free closed
    // loop is the literal pre-PR code.
    let timeline = cfg.fault_timeline();
    let warmup = fleet.cfg.warmup;
    let mut fault_out = FaultOutcomes::default();
    if let Some(tl) = &timeline {
        let (c, d, _) = tl.window_counts();
        fault_out.crash_windows = c;
        fault_out.degrade_windows = d;
    }
    // Machines ejected for crash losses (MTTR accounting) and the
    // per-epoch cluster recorders the fault-window report reads.
    let mut crash_ejected = vec![false; n];
    let mut recovery_by_machine = vec![0u64; n];
    let mut epoch_records: Vec<(Time, Time, LatencyStats)> = Vec::new();

    let mut base_iter = base.into_iter().peekable();
    let last = windows.len() - 1;
    for (k, &(w0, w1)) in windows.iter().enumerate() {
        // 1. This epoch's arrivals: base stream in [w0, w1) (the last
        // window also takes the horizon-edge arrival), plus any
        // injections that landed here. Stable sort on a total key keeps
        // the order independent of construction order.
        let mut epoch: Vec<Arr> = Vec::new();
        while let Some(&(t, tenant)) = base_iter.peek() {
            if t >= w1 && k != last {
                break;
            }
            epoch.push(Arr { t, tenant, attempt: 0, hedge: false, machine: None });
            base_iter.next();
        }
        let (now_batch, later): (Vec<Arr>, Vec<Arr>) =
            injected.drain(..).partition(|a| a.t < w1 || k == last);
        epoch.extend(now_batch);
        injected = later;
        epoch.sort_by_key(|a| (a.t, a.hedge, a.attempt, a.tenant));

        // 2. Route. Retry/attempt composition is tracked per
        // (machine, tenant, attempt) so next epoch's timeouts can be
        // attributed; hedge draws come from a per-epoch seeded stream.
        // With faults active, each routed request passes through the
        // fault timeline: link drops, delivery delay, crash-window
        // loss, and the machine's clock skew on the arrived stamp.
        let mut traces: Vec<Vec<(Time, u32)>> = vec![Vec::new(); n];
        let mut ftraces: Vec<Vec<(Time, Time, u32)>> = vec![Vec::new(); n];
        let mut hedges: Vec<(Time, u32, usize)> = Vec::new();
        let attempts = bal.max_retries as usize + 1;
        let mut counts = vec![0u64; n * n_tenants * attempts];
        let mut epoch_routed = vec![0u64; n];
        let mut epoch_lost = vec![0u64; n];
        // Requests the front end *knows* faults ate this epoch: fed
        // back as timeouts (and retries) in §4a'.
        let mut victims: Vec<(usize, u32, u32)> = Vec::new();
        let mut hedge_rng =
            Rng::new(mix64(fleet.cfg.seed ^ (k as u64).wrapping_mul(0x9E37_79B9) ^ 0x4ED6));
        // Fate of one routed request: `None` = no faults (pre-PR
        // delivery), `Some(Ok((deliver, stamp)))` = delivered with link
        // delay + skewed arrival stamp, `Some(Err(lost))` = dropped on
        // the link (`false`) or delivered into a crash window (`true`).
        let fate = |m: usize, t: Time| -> Option<Result<(Time, Time), bool>> {
            let tl = timeline.as_ref()?;
            let Some(rel) = t.checked_sub(warmup) else {
                return Some(Ok((t, t))); // warmup predates the fault window
            };
            Some(if tl.dropped(m, rel) {
                Err(false)
            } else {
                let deliver = t.saturating_add(tl.delay(m, rel));
                if tl.is_dark(m, deliver.saturating_sub(warmup)) {
                    Err(true)
                } else {
                    Ok((deliver, tl.skewed(m, deliver)))
                }
            })
        };
        for a in &epoch {
            let avx = process.tenant_carries_avx(a.tenant as usize);
            let m = match a.machine {
                Some(m) => pick_healthy(m, &healthy),
                None => pick_healthy(router.route(a.t, avx), &healthy),
            };
            arrivals_routed[m] += 1;
            epoch_routed[m] += 1;
            let mut delivered = true;
            match fate(m, a.t) {
                None => traces[m].push((a.t, a.tenant)),
                Some(Ok((deliver, stamp))) => {
                    if deliver >= w1 && k != last {
                        // A link delay pushed the delivery past the
                        // epoch boundary: re-route it next epoch with
                        // its machine pre-assigned (hedge semantics —
                        // retry bookkeeping does not survive a spill).
                        arrivals_routed[m] -= 1;
                        epoch_routed[m] -= 1;
                        delivered = false;
                        injected.push(Arr {
                            t: deliver,
                            tenant: a.tenant,
                            attempt: a.attempt,
                            hedge: true,
                            machine: Some(m),
                        });
                    } else {
                        ftraces[m].push((deliver, stamp, a.tenant));
                    }
                }
                Some(Err(lost)) => {
                    delivered = false;
                    if lost {
                        fault_out.lost_to_crash += 1;
                        epoch_lost[m] += 1;
                    } else {
                        fault_out.dropped_by_net += 1;
                    }
                    if !a.hedge {
                        victims.push((m, a.tenant, a.attempt));
                    }
                }
            }
            if !a.hedge && delivered {
                counts[(m * n_tenants + a.tenant as usize) * attempts + a.attempt as usize] += 1;
                if hedge_frac > 0.0 && hedge_delay > 0 && hedge_rng.chance(hedge_frac) {
                    let hm = next_healthy_after(m, &healthy);
                    if hm != m {
                        outcomes.hedges_issued += 1;
                        let ht = a.t.saturating_add(hedge_delay);
                        if ht < w1 {
                            hedges.push((ht, a.tenant, hm));
                        } else if k != last {
                            injected.push(Arr {
                                t: ht,
                                tenant: a.tenant,
                                attempt: 0,
                                hedge: true,
                                machine: Some(hm),
                            });
                        }
                    }
                }
            }
        }
        for (ht, tenant, hm) in hedges {
            match fate(hm, ht) {
                None => {
                    traces[hm].push((ht, tenant));
                    arrivals_routed[hm] += 1;
                }
                Some(Ok((deliver, stamp))) => {
                    if deliver >= w1 && k != last {
                        injected.push(Arr {
                            t: deliver,
                            tenant,
                            attempt: 0,
                            hedge: true,
                            machine: Some(hm),
                        });
                    } else {
                        ftraces[hm].push((deliver, stamp, tenant));
                        arrivals_routed[hm] += 1;
                        epoch_routed[hm] += 1;
                    }
                }
                Some(Err(lost)) => {
                    // Hedges are best-effort duplicates: counted, never
                    // retried.
                    arrivals_routed[hm] += 1;
                    epoch_routed[hm] += 1;
                    if lost {
                        fault_out.lost_to_crash += 1;
                        epoch_lost[hm] += 1;
                    } else {
                        fault_out.dropped_by_net += 1;
                    }
                }
            }
        }
        for trace in traces.iter_mut() {
            trace.sort_by_key(|&(t, _)| t);
        }
        for trace in ftraces.iter_mut() {
            // Delays at link-window edges can reorder deliveries.
            trace.sort_unstable_by_key(|&(d, s, tn)| (d, s, tn));
        }

        // 3. Simulate the epoch: every machine is an independent fresh
        // run over [0, w1 - w0) with epoch-local arrival times. Crashed
        // machines split into up-segments, each its own fresh (cold)
        // simulation.
        let e_secs = (w1 - w0) as f64 / SEC as f64;
        let measured = k >= measured_from;
        let jobs: Vec<MachineJob> = match &timeline {
            None => traces
                .into_iter()
                .enumerate()
                .map(|(i, mut trace)| {
                    for a in trace.iter_mut() {
                        a.0 -= w0;
                    }
                    let mut mcfg = fleet.cfg.clone();
                    mcfg.warmup = 0;
                    mcfg.measure = w1 - w0;
                    mcfg.seed = epoch_machine_seed(fleet, i, k);
                    MachineJob::Plain(mcfg, trace)
                })
                .collect(),
            Some(tl) => ftraces
                .into_iter()
                .enumerate()
                .map(|(i, trace)| {
                    let segs: Vec<(Time, Time)> = if w0 >= warmup {
                        tl.up_segments(i, w0 - warmup, w1 - warmup)
                            .into_iter()
                            .map(|(s, e)| (s + warmup, e + warmup))
                            .collect()
                    } else {
                        // The cold window predates the fault timeline.
                        vec![(w0, w1)]
                    };
                    segment_jobs(
                        fleet,
                        tl,
                        i,
                        &segs,
                        epoch_machine_seed(fleet, i, k),
                        trace,
                        false,
                        &mut fault_out.lost_to_crash,
                    )
                })
                .collect(),
        };
        let epoch_cluster = Mutex::new(LatencyStats::new(fleet.cfg.slo));
        let obs_slots: Vec<Mutex<Option<EpochObs>>> = (0..n).map(|_| Mutex::new(None)).collect();
        simulate_into(
            jobs,
            threads,
            &agg,
            measured,
            e_secs,
            Some((&epoch_cluster, &obs_slots, bal.timeout, n_tenants)),
        );
        let obs: Vec<EpochObs> = obs_slots
            .into_iter()
            .map(|s| s.into_inner().expect("obs poisoned").unwrap_or_default())
            .collect();

        // The epoch's merged cluster recorder: kept for the
        // fault-window report, then read by the hedge feedback.
        let ec = epoch_cluster.into_inner().expect("epoch recorder poisoned");
        if timeline.is_some() && measured {
            epoch_records.push((w0, w1, ec.clone()));
        }

        // 4. Feedback for epoch k+1, from epoch k's merged statistics
        // only — sequential and deterministic.
        if k == last {
            break;
        }
        let (nw0, nw1) = windows[k + 1];

        // 4a. Timeouts → retries with backoff (or abandonment at the
        // attempt cap). Estimated per (machine, tenant, attempt) from
        // the observed per-tenant distribution.
        let mut retry_rng =
            Rng::new(mix64(fleet.cfg.seed ^ (k as u64).wrapping_mul(0xA076_1D64_78BD_642F)));
        let jitter_span = ((nw1 - nw0) / 2).max(1);
        for m in 0..n {
            for t in 0..n_tenants {
                let frac = obs[m].tenant_frac.get(t).copied().unwrap_or(0.0);
                if frac <= 0.0 {
                    continue;
                }
                for a in 0..attempts {
                    let c = counts[(m * n_tenants + t) * attempts + a];
                    let timed_out = (frac * c as f64).round() as u64;
                    if timed_out == 0 {
                        continue;
                    }
                    outcomes.timeouts_observed += timed_out;
                    agg.note_timeouts(m, timed_out);
                    if a as u32 >= bal.max_retries {
                        outcomes.retries_abandoned += timed_out;
                        continue;
                    }
                    outcomes.retries_issued += timed_out;
                    for _ in 0..timed_out {
                        let rt = nw0
                            .saturating_add(bal.retry_backoff)
                            .saturating_add(retry_rng.below(jitter_span));
                        injected.push(Arr {
                            t: rt,
                            tenant: t as u32,
                            attempt: a as u32 + 1,
                            hedge: false,
                            machine: None,
                        });
                    }
                }
            }
        }

        // 4a'. Fault-induced losses feed back as *known* timeouts: the
        // front end saw every request it routed into a drop or a dark
        // machine vanish, so they re-enter the retry machinery exactly
        // like observed timeouts (attempt caps included).
        if timeline.is_some() {
            for &(m, tenant, attempt) in &victims {
                outcomes.timeouts_observed += 1;
                agg.note_timeouts(m, 1);
                if attempt >= bal.max_retries {
                    outcomes.retries_abandoned += 1;
                    continue;
                }
                outcomes.retries_issued += 1;
                fault_out.fault_retries += 1;
                let rt = nw0
                    .saturating_add(bal.retry_backoff)
                    .saturating_add(retry_rng.below(jitter_span));
                injected.push(Arr {
                    t: rt,
                    tenant,
                    attempt: attempt + 1,
                    hedge: false,
                    machine: None,
                });
            }
        }

        // 4b. Hedge threshold for the next epoch from this epoch's
        // merged cluster distribution.
        if bal.hedge_p99_mult > 0.0 {
            let p99 = ec.hist.percentile(99.0);
            hedge_delay = (bal.hedge_p99_mult * p99 as f64).round() as Time;
            hedge_frac =
                if hedge_delay > 0 { ec.hist.fraction_above(hedge_delay) } else { 0.0 };
        }

        // 4c. Health view: eject slow machines, readmit recovered ones.
        if bal.eject_factor > 0.0 {
            let mut healthy_p99s: Vec<Time> = (0..n)
                .filter(|&m| healthy[m] && obs[m].completed > 0)
                .map(|m| obs[m].p99)
                .collect();
            healthy_p99s.sort_unstable();
            if !healthy_p99s.is_empty() {
                let median = healthy_p99s[healthy_p99s.len() / 2];
                let threshold = (bal.eject_factor * median as f64).round() as Time;
                if threshold > 0 {
                    for m in 0..n {
                        if !healthy[m] && obs[m].p99 <= threshold {
                            healthy[m] = true;
                            outcomes.readmissions += 1;
                        }
                    }
                    for m in 0..n {
                        let would_remain = healthy.iter().filter(|&&h| h).count() > 1;
                        if healthy[m] && obs[m].p99 > threshold && would_remain {
                            healthy[m] = false;
                            outcomes.ejections += 1;
                        }
                    }
                }
            }
        }
        // 4d. Crash visibility: a machine that lost a majority of its
        // routed traffic went dark mid-epoch — eject it now instead of
        // waiting for its (empty) p99 to read as healthy. Readmission
        // rides the standard §4c cooldown; the epochs in between are
        // the MTTR the fault report publishes.
        if timeline.is_some() && bal.eject_factor > 0.0 {
            for m in 0..n {
                let would_remain = healthy.iter().filter(|&&h| h).count() > 1;
                if healthy[m] && epoch_lost[m] * 2 > epoch_routed[m] && would_remain {
                    healthy[m] = false;
                    crash_ejected[m] = true;
                    outcomes.ejections += 1;
                }
            }
            for m in 0..n {
                if crash_ejected[m] {
                    if healthy[m] {
                        crash_ejected[m] = false; // readmitted in §4c
                    } else {
                        fault_out.recovery_epochs += 1;
                        recovery_by_machine[m] += 1;
                    }
                }
            }
        }
        // Attribute ejected machine-epochs to the digests (next epoch
        // is the one they sit out; only measured epochs are reported).
        if k + 1 >= measured_from {
            for m in 0..n {
                if !healthy[m] {
                    agg.note_ejected_epoch(m);
                }
            }
        }
    }

    let fault_windows = match &timeline {
        None => Vec::new(),
        Some(tl) => fault_window_stats(
            tl,
            &epoch_records,
            warmup,
            fleet.cfg.slo,
            n,
            &recovery_by_machine,
        ),
    };
    finish(cfg, agg, arrivals_routed, outcomes, fault_out, fault_windows)
}

/// Epoch-granularity SLO damage per fault window: the cluster
/// recorders of the epochs overlapping each window, merged and
/// compared against every other measured epoch. Only the closed loop
/// produces these (the open loop has no epoch slicing to attribute
/// damage with).
fn fault_window_stats(
    tl: &FaultTimeline,
    epochs: &[(Time, Time, LatencyStats)],
    warmup: Time,
    slo: Time,
    n: usize,
    recovery_by_machine: &[u64],
) -> Vec<FaultWindowStat> {
    let stat = |kind: &'static str, machine: String, s: Time, e: Time, readmit: u64| {
        let mut inside = LatencyStats::new(slo);
        let mut outside = LatencyStats::new(slo);
        for (e0, e1, st) in epochs {
            let (m0, m1) = (e0.saturating_sub(warmup), e1.saturating_sub(warmup));
            if s < m1 && e > m0 {
                inside.merge(st);
            } else {
                outside.merge(st);
            }
        }
        FaultWindowStat {
            kind,
            machine,
            start: s,
            end: e,
            p99_in_us: inside.hist.percentile(99.0) as f64 / 1_000.0,
            p99_out_us: outside.hist.percentile(99.0) as f64 / 1_000.0,
            violations_in: inside.violations(),
            readmit_epochs: readmit,
        }
    };
    let mut rows = Vec::new();
    for (m, wins) in tl.dark.iter().enumerate() {
        for &(s, e) in wins {
            let readmit = recovery_by_machine.get(m).copied().unwrap_or(0);
            rows.push(stat("crash", format!("m{m}"), s, e, readmit));
        }
    }
    for (m, wins) in tl.degrade.iter().enumerate() {
        for w in wins {
            rows.push(stat("degrade", format!("m{m}"), w.start, w.end, 0));
        }
    }
    // Every-machine link faults collapse to one "all" row instead of
    // repeating per machine.
    let mut seen: Vec<(LinkWindow, Vec<usize>)> = Vec::new();
    for (m, wins) in tl.link.iter().enumerate() {
        for w in wins {
            match seen.iter_mut().find(|entry| entry.0 == *w) {
                Some(entry) => entry.1.push(m),
                None => seen.push((*w, vec![m])),
            }
        }
    }
    for (w, ms) in seen {
        let machine = if ms.len() == n {
            "all".to_string()
        } else {
            ms.iter().map(|m| format!("m{m}")).collect::<Vec<_>>().join("+")
        };
        rows.push(stat("link", machine, w.start, w.end, 0));
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::router::RouterSpec;
    use crate::sched::PolicyKind;
    use crate::traffic::ArrivalProcess;
    use crate::workload::client::LoadMode;
    use crate::workload::crypto::Isa;

    fn tiny_cfg() -> WebCfg {
        let mut c = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
        c.cores = 2;
        c.workers = 4;
        c.page_bytes = 8 * 1024;
        c.warmup = 40 * MS;
        c.measure = 160 * MS;
        c.mode =
            LoadMode::OpenProcess { process: ArrivalProcess::two_tenant(30_000.0, 0.25) };
        c
    }

    fn hier(machines: usize, closed: bool) -> HierFleetCfg {
        let fleet = FleetCfg::new(machines, RouterSpec::RoundRobin, tiny_cfg());
        let bal = if closed { BalancerCfg::closed() } else { BalancerCfg::default() };
        let mut h = HierFleetCfg::new(fleet, bal);
        h.machines_per_rack = 2;
        h
    }

    #[test]
    fn balancer_labels_and_validation() {
        assert_eq!(BalancerCfg::default().label(), "open-loop");
        assert_eq!(BalancerCfg::closed().label(), "closed(4ep)");
        let bad = BalancerCfg { epochs: 0, ..BalancerCfg::closed() };
        assert!(bad.validate().is_err());
        let bad = BalancerCfg { timeout: 0, ..BalancerCfg::closed() };
        assert!(bad.validate().is_err());
        let bad = BalancerCfg { hedge_p99_mult: -1.0, ..BalancerCfg::closed() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn health_probes_pick_deterministically() {
        let healthy = vec![true, false, false, true];
        assert_eq!(pick_healthy(0, &healthy), 0);
        assert_eq!(pick_healthy(1, &healthy), 3);
        assert_eq!(pick_healthy(2, &healthy), 3);
        assert_eq!(next_healthy_after(0, &healthy), 3);
        assert_eq!(next_healthy_after(3, &healthy), 0);
        let none = vec![false, false];
        assert_eq!(pick_healthy(1, &none), 1, "routing must never fail");
        let solo = vec![true];
        assert_eq!(next_healthy_after(0, &solo), 0, "no other machine to hedge to");
    }

    /// Edge cases the fault era makes reachable: a crash schedule can
    /// eject everything, shrink the fleet to one machine, or leave the
    /// primary as the only survivor — routing must stay total and
    /// wrap-around must terminate in every case.
    #[test]
    fn health_routing_edge_cases() {
        // All machines ejected: both helpers fall back to the argument
        // (any index, including the last, wraps without diverging).
        let none = vec![false, false, false, false];
        for m in 0..none.len() {
            assert_eq!(pick_healthy(m, &none), m, "all-ejected fallback from {m}");
            assert_eq!(next_healthy_after(m, &none), m, "all-ejected hedge from {m}");
        }

        // Single-machine fleet: healthy or not, there is nowhere else.
        assert_eq!(pick_healthy(0, &[true]), 0);
        assert_eq!(pick_healthy(0, &[false]), 0);
        assert_eq!(next_healthy_after(0, &[false]), 0);

        // Primary is the only healthy machine: every route lands on it,
        // and the hedge has no distinct target so it returns the primary.
        let only = vec![false, false, true, false];
        for m in 0..only.len() {
            assert_eq!(pick_healthy(m, &only), 2, "route from {m} onto sole survivor");
        }
        assert_eq!(next_healthy_after(2, &only), 2, "no distinct hedge target");

        // Wrap-around off the end of the fleet: from the last index the
        // scan must wrap to a healthy low index, not run off the slice.
        let low = vec![true, false, false, false];
        assert_eq!(pick_healthy(3, &low), 0);
        assert_eq!(next_healthy_after(3, &low), 0);
    }

    #[test]
    fn open_loop_hier_counts_match_flat_fleet() {
        // The streaming aggregation must reproduce the flat fleet's
        // exact counters (the full byte-differential lives in
        // rust/tests/hierfleet.rs).
        let h = hier(3, false);
        let flat = super::super::cluster::run_fleet(&h.fleet, 2);
        let run = run_hier_fleet(&h, 2);
        assert_eq!(run.completed, flat.completed);
        assert_eq!(run.violations, flat.violations);
        assert_eq!(run.dropped, flat.dropped);
        assert_eq!(run.tail.p99_us.to_bits(), flat.tail.p99_us.to_bits());
        assert!(run.outcomes.is_noop(), "open loop must not act: {:?}", run.outcomes);
        assert_eq!(run.n_racks(), 2);
        let rack_sum: u64 = run.racks.iter().map(|r| r.completed()).sum();
        assert_eq!(rack_sum, run.completed, "racks must partition the cluster");
    }

    #[test]
    fn closed_loop_runs_and_is_thread_count_invariant() {
        let h = hier(3, true);
        let a = run_hier_fleet(&h, 1);
        let b = run_hier_fleet(&h, 4);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.violations, b.violations);
        assert_eq!(a.tail.p99_us.to_bits(), b.tail.p99_us.to_bits());
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.arrivals_routed(), b.arrivals_routed());
        assert!(a.completed > 100, "closed loop served {}", a.completed);
        assert_eq!(a.balancer, "closed(4ep)");
    }

    impl HierFleetRun {
        fn arrivals_routed(&self) -> Vec<u64> {
            self.digests.iter().map(|d| d.arrivals).collect()
        }
    }

    #[test]
    fn all_idle_epochs_neither_eject_nor_panic() {
        // Regression for the §4b/§4c empty-histogram edges: with an
        // arrival rate so low that every epoch is (essentially) idle,
        // every machine's epoch histogram is empty. The hedge threshold
        // must collapse to "off" (p99 of an empty histogram is the
        // documented 0), and the ejection pass must see an empty
        // healthy-median list and do nothing — never eject the whole
        // fleet off zero data, never divide by an empty median, never
        // panic.
        let mut h = hier(3, true);
        h.fleet.cfg.mode = LoadMode::OpenProcess {
            process: ArrivalProcess::two_tenant(1e-6, 0.25),
        };
        let run = run_hier_fleet(&h, 2);
        assert_eq!(run.outcomes.ejections, 0, "no machine may be ejected off no data");
        assert_eq!(run.outcomes.hedges_issued, 0);
        assert_eq!(run.outcomes.retries_issued, 0);
        assert_eq!(run.outcomes.timeouts_observed, 0);
        assert_eq!(run.machines, 3);
        // The merged cluster statistics are the safe zeroes, not NaNs
        // (a stray arrival from the 1e-6 req/s stream would be benign
        // but is astronomically unlikely over a 200 ms horizon).
        assert!(run.completed <= 1, "idle fleet served {}", run.completed);
        assert!(run.tail.p99_us.is_finite());
        if run.completed == 0 {
            assert_eq!(run.tail.p99_us.to_bits(), 0.0f64.to_bits());
        }
    }

    #[test]
    fn idle_fleet_is_thread_count_invariant() {
        // The all-idle loop exercises the empty-histogram feedback path
        // on every epoch; it must stay byte-identical across thread
        // counts like any other configuration.
        let mut h = hier(3, true);
        h.fleet.cfg.mode = LoadMode::OpenProcess {
            process: ArrivalProcess::two_tenant(1e-6, 0.25),
        };
        let a = run_hier_fleet(&h, 1);
        let b = run_hier_fleet(&h, 4);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.arrivals_routed(), b.arrivals_routed());
        assert_eq!(a.tail.p99_us.to_bits(), b.tail.p99_us.to_bits());
    }
}
