//! Closed-loop front-end balancer: epoch-based feedback over the
//! hierarchical fleet.
//!
//! A real cluster front-end reacts to *observed* signals — it times
//! requests out and retries them, hedges slow requests after a
//! p99-based delay, and ejects machines whose tails blow up. Reacting
//! to per-request completions would couple routing to simulated
//! machine state and destroy the fleet's determinism contract
//! (byte-identical output at any OS thread count). The resolution is
//! **epoch-based feedback**: the run is sliced into epochs, every
//! machine in epoch *k* simulates independently (embarrassingly
//! parallel, exactly like the open loop), and the balancer adjusts
//! routing for epoch *k + 1* only from epoch *k*'s *merged* statistics.
//! Within an epoch routing is still a pure function of the arrival
//! stream; across epochs the feedback inputs are exact merged counters,
//! which are identical for every thread schedule — so the whole closed
//! loop stays byte-identical at any thread count.
//!
//! Feedback mechanisms (all estimated from the observed latency
//! distribution, never from per-request logs — memory stays O(machines)
//! scalars):
//!
//! * **Timeout + retry with backoff** — each machine's per-tenant share
//!   of completions above the timeout (histogram `fraction_above`, the
//!   front-end's observed-distribution estimate, ≤ ~3% bucket error) is
//!   re-injected into the next epoch as seeded retry arrivals after a
//!   backoff; attempts beyond `max_retries` are abandoned.
//! * **Hedging** — after a delay of `hedge_p99_mult ×` the previous
//!   epoch's cluster p99, the observed fraction of requests still
//!   outstanding is duplicated to the next healthy machine. Duplicates
//!   are modelled on the load side (the front-end takes whichever copy
//!   answers; both completions are recorded — a documented
//!   simplification).
//! * **Health ejection** — a machine whose epoch p99 exceeds
//!   `eject_factor ×` the healthy median is ejected for the next epoch;
//!   routing probes to the next healthy machine. An ejected machine
//!   receives no traffic, so its next epoch p99 reads 0 and it is
//!   readmitted — a one-epoch cooldown.
//!
//! Epoch boundaries are also the model's stated approximation: each
//! (machine, epoch) is a fresh simulation (queues and license state are
//! not carried across the boundary, in-flight work at the boundary is
//! lost), the same semantics the open loop applies at its horizon. The
//! feedback-disabled configuration does not approximate anything: it
//! runs the *identical* whole-horizon demux/simulate path as
//! [`run_fleet`], only the aggregation streams — the differential test
//! in `rust/tests/hierfleet.rs` pins byte equality.
//!
//! [`run_fleet`]: super::cluster::run_fleet

use super::cluster::{route_stream, FleetCfg};
use super::hierarchy::{collective_makespan, HierFleetRun, HierarchyAgg};
use crate::sim::{Time, MS, SEC};
use crate::traffic::{ArrivalGen, FrontendOutcomes, LatencyStats};
use crate::util::{mix64, Rng};
use crate::workload::webserver::{run_webserver_trace, WebCfg};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Closed-loop balancer parameters. `Default` is the open loop (all
/// feedback off); [`BalancerCfg::closed`] enables every mechanism at
/// the defaults.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BalancerCfg {
    /// Master switch: `false` routes exactly like PR 3's open loop.
    pub enabled: bool,
    /// Feedback epochs over the measure window (the warmup window is an
    /// extra cold epoch, observed for feedback but never reported).
    pub epochs: usize,
    /// Per-request timeout (ns) the front-end measures against.
    pub timeout: Time,
    /// Retry attempts before a timed-out request is abandoned.
    pub max_retries: u32,
    /// Backoff before a retry is re-injected (ns).
    pub retry_backoff: Time,
    /// Hedge duplicates fire after `hedge_p99_mult ×` the previous
    /// epoch's cluster p99; `0.0` disables hedging.
    pub hedge_p99_mult: f64,
    /// Eject a machine whose epoch p99 exceeds `eject_factor ×` the
    /// healthy median; `0.0` disables ejection.
    pub eject_factor: f64,
}

impl Default for BalancerCfg {
    fn default() -> Self {
        BalancerCfg {
            enabled: false,
            epochs: 4,
            timeout: 20 * MS,
            max_retries: 2,
            retry_backoff: MS,
            hedge_p99_mult: 3.0,
            eject_factor: 3.0,
        }
    }
}

impl BalancerCfg {
    /// Every mechanism on at the defaults.
    pub fn closed() -> Self {
        BalancerCfg { enabled: true, ..Default::default() }
    }

    /// Short label for tables and cell identifiers.
    pub fn label(&self) -> String {
        if self.enabled {
            format!("closed({}ep)", self.epochs)
        } else {
            "open-loop".to_string()
        }
    }

    /// Read the `[balancer]` config section (all keys optional; absent
    /// section = open loop).
    ///
    /// ```toml
    /// [balancer]
    /// enabled = true
    /// epochs = 4
    /// timeout_ms = 20.0
    /// max_retries = 2
    /// retry_backoff_ms = 1.0
    /// hedge_p99_mult = 3.0    # 0 disables hedging
    /// eject_factor = 3.0      # 0 disables health ejection
    /// ```
    pub fn from_config(conf: &crate::util::config::Config) -> anyhow::Result<BalancerCfg> {
        let d = BalancerCfg::default();
        let ms = |x: f64| (x * MS as f64).round() as Time;
        let cfg = BalancerCfg {
            enabled: conf.bool_or("balancer.enabled", d.enabled),
            epochs: conf.usize_or("balancer.epochs", d.epochs),
            timeout: ms(conf.float_or("balancer.timeout_ms", d.timeout as f64 / MS as f64)),
            max_retries: conf.usize_or("balancer.max_retries", d.max_retries as usize) as u32,
            retry_backoff: ms(conf
                .float_or("balancer.retry_backoff_ms", d.retry_backoff as f64 / MS as f64)),
            hedge_p99_mult: conf.float_or("balancer.hedge_p99_mult", d.hedge_p99_mult),
            eject_factor: conf.float_or("balancer.eject_factor", d.eject_factor),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Reject parameterizations the loop cannot execute sensibly.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.epochs >= 1, "balancer.epochs must be ≥ 1");
        anyhow::ensure!(self.timeout > 0, "balancer timeout must be positive");
        anyhow::ensure!(
            self.hedge_p99_mult.is_finite() && self.hedge_p99_mult >= 0.0,
            "balancer.hedge_p99_mult must be finite and ≥ 0"
        );
        anyhow::ensure!(
            self.eject_factor.is_finite() && self.eject_factor >= 0.0,
            "balancer.eject_factor must be finite and ≥ 0"
        );
        Ok(())
    }
}

/// Hierarchical fleet configuration: the flat [`FleetCfg`] plus rack
/// shape, balancer, and the optional collective model.
#[derive(Clone, Debug)]
pub struct HierFleetCfg {
    pub fleet: FleetCfg,
    /// Machines per rack (contiguous chunks; the last rack may be
    /// short).
    pub machines_per_rack: usize,
    pub balancer: BalancerCfg,
    /// Bulk-synchronous collective steps to model over the digests
    /// (0 = skip).
    pub collective_steps: usize,
}

impl HierFleetCfg {
    pub fn new(fleet: FleetCfg, balancer: BalancerCfg) -> Self {
        HierFleetCfg { fleet, machines_per_rack: 8, balancer, collective_steps: 0 }
    }

    /// Extend [`FleetCfg::from_config`] with the `[balancer]` section
    /// plus `fleet.machines_per_rack` / `fleet.collective_steps`.
    pub fn from_config(conf: &crate::util::config::Config) -> anyhow::Result<HierFleetCfg> {
        let fleet = FleetCfg::from_config(conf)?;
        let cfg = HierFleetCfg {
            fleet,
            machines_per_rack: conf.usize_or("fleet.machines_per_rack", 8).max(1),
            balancer: BalancerCfg::from_config(conf)?,
            collective_steps: conf.usize_or("fleet.collective_steps", 0),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        self.fleet.validate()?;
        self.balancer.validate()?;
        if self.balancer.enabled {
            anyhow::ensure!(
                self.fleet.cfg.measure / self.balancer.epochs as Time > 0,
                "measure window too short for {} feedback epochs",
                self.balancer.epochs
            );
        }
        Ok(())
    }
}

/// One front-end arrival inside an epoch.
#[derive(Clone, Copy, Debug)]
struct Arr {
    t: Time,
    tenant: u32,
    /// 0 for base arrivals, n ≥ 1 for the n-th retry attempt.
    attempt: u32,
    /// Hedge duplicates never retry or re-hedge, and carry a
    /// pre-assigned machine when they spill into a later epoch.
    hedge: bool,
    machine: Option<usize>,
}

/// Per-machine observations from one epoch, computed on the worker
/// thread before the machine's run is dropped.
#[derive(Clone, Debug, Default)]
struct EpochObs {
    completed: u64,
    p99: Time,
    /// Per-tenant fraction of completions above the timeout.
    tenant_frac: Vec<f64>,
}

/// Run the hierarchical fleet. Feedback disabled (`!balancer.enabled`)
/// executes the identical whole-horizon path as [`run_fleet`] — same
/// traces, same machine seeds, same per-machine simulations — with the
/// streaming aggregation in place of retained `WebRun`s. Feedback
/// enabled runs the epoch loop described in the module docs. Both are
/// byte-identical at any `threads` value.
///
/// [`run_fleet`]: super::cluster::run_fleet
pub fn run_hier_fleet(cfg: &HierFleetCfg, threads: usize) -> HierFleetRun {
    cfg.validate().expect("invalid hierarchical fleet configuration");
    if cfg.balancer.enabled {
        run_closed_loop(cfg, threads)
    } else {
        run_open_loop(cfg, threads)
    }
}

/// Simulate a set of per-machine jobs across worker threads, absorbing
/// each run into the aggregation as it finishes (the `WebRun` is
/// dropped on the worker thread). `observe` optionally captures epoch
/// observations per machine before the drop.
fn simulate_into(
    jobs: Vec<(WebCfg, Vec<(Time, u32)>)>,
    threads: usize,
    agg: &HierarchyAgg,
    absorb: bool,
    secs: f64,
    observe: Option<(&Mutex<LatencyStats>, &[Mutex<Option<EpochObs>>], Time, usize)>,
) {
    let jobs: Vec<(WebCfg, Mutex<Option<Vec<(Time, u32)>>>)> = jobs
        .into_iter()
        .map(|(mcfg, trace)| (mcfg, Mutex::new(Some(trace))))
        .collect();
    let n_threads = threads.max(1).min(jobs.len().max(1));
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..n_threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= jobs.len() {
                    break;
                }
                let (mcfg, trace_slot) = &jobs[i];
                let trace = trace_slot
                    .lock()
                    .expect("trace poisoned")
                    .take()
                    .expect("each machine's trace is claimed exactly once");
                let run = run_webserver_trace(mcfg, trace);
                if absorb {
                    agg.absorb(i, &run, secs);
                }
                if let Some((epoch_cluster, obs_slots, timeout, n_tenants)) = observe {
                    let obs = EpochObs {
                        completed: run.completed,
                        p99: run.stats.hist.percentile(99.0),
                        tenant_frac: (0..n_tenants)
                            .map(|t| {
                                run.tenant_stats
                                    .get(t)
                                    .map(|s| s.hist.fraction_above(timeout))
                                    .unwrap_or(0.0)
                            })
                            .collect(),
                    };
                    epoch_cluster.lock().expect("epoch recorder poisoned").merge(&run.stats);
                    *obs_slots[i].lock().expect("obs slot poisoned") = Some(obs);
                }
                // `run` dropped here — nothing retains the WebRun.
            });
        }
    });
}

fn finish(
    cfg: &HierFleetCfg,
    agg: HierarchyAgg,
    arrivals_routed: Vec<u64>,
    outcomes: FrontendOutcomes,
) -> HierFleetRun {
    let snap = agg.finish(&arrivals_routed);
    let collective = (cfg.collective_steps > 0)
        .then(|| collective_makespan(&snap.digests, cfg.collective_steps, cfg.fleet.cfg.seed));
    HierFleetRun {
        router: cfg.fleet.router.label(),
        balancer: cfg.balancer.label(),
        machines: cfg.fleet.machines,
        machines_per_rack: cfg.machines_per_rack.max(1),
        tail: snap.cluster.summary(),
        completed: snap.cluster.completed(),
        violations: snap.cluster.violations(),
        digests: snap.digests,
        racks: snap.racks,
        stats: snap.cluster,
        tenant_stats: snap.tenants,
        outcomes,
        dropped: snap.dropped,
        measure_secs: cfg.fleet.cfg.measure as f64 / SEC as f64,
        collective,
    }
}

/// Feedback disabled: PR 3's open-loop demux/simulate path verbatim
/// (same `route_stream`, same `machine_seed`s, same whole-horizon
/// per-machine runs), streamed into the hierarchy instead of retained.
fn run_open_loop(cfg: &HierFleetCfg, threads: usize) -> HierFleetRun {
    let fleet = &cfg.fleet;
    let traces = route_stream(fleet);
    let arrivals_routed: Vec<u64> = traces.iter().map(|t| t.len() as u64).collect();
    let names =
        fleet.cfg.mode.process().expect("validate() rejects closed-loop fleets").tenant_names();
    let agg = HierarchyAgg::new(fleet.machines, cfg.machines_per_rack, fleet.cfg.slo, &names);
    let secs = fleet.cfg.measure as f64 / SEC as f64;
    let jobs: Vec<(WebCfg, Vec<(Time, u32)>)> = traces
        .into_iter()
        .enumerate()
        .map(|(i, trace)| {
            let mut mcfg = fleet.cfg.clone();
            mcfg.seed = fleet.machine_seed(i);
            (mcfg, trace)
        })
        .collect();
    simulate_into(jobs, threads, &agg, true, secs, None);
    finish(cfg, agg, arrivals_routed, FrontendOutcomes::default())
}

/// Seed for (machine `i`, epoch window `k`): window 0 keeps the
/// machine's open-loop seed; later windows fork so each epoch's worker
/// RNG streams decorrelate.
fn epoch_machine_seed(fleet: &FleetCfg, i: usize, k: usize) -> u64 {
    let base = fleet.machine_seed(i);
    if k == 0 {
        base
    } else {
        mix64(base ^ (k as u64).wrapping_mul(0xD1B5_4A32_D192_ED03))
    }
}

/// First healthy machine at or after `from` (wrapping); `from` itself
/// if the whole fleet is ejected (the guard in the ejection pass makes
/// that unreachable, but routing must never fail).
fn pick_healthy(from: usize, healthy: &[bool]) -> usize {
    let n = healthy.len();
    (0..n).map(|d| (from + d) % n).find(|&m| healthy[m]).unwrap_or(from)
}

/// First healthy machine strictly after `primary` (wrapping, ≠
/// `primary` if any other healthy machine exists).
fn next_healthy_after(primary: usize, healthy: &[bool]) -> usize {
    let n = healthy.len();
    (1..n).map(|d| (primary + d) % n).find(|&m| healthy[m]).unwrap_or(primary)
}

fn run_closed_loop(cfg: &HierFleetCfg, threads: usize) -> HierFleetRun {
    let fleet = &cfg.fleet;
    let bal = &cfg.balancer;
    let n = fleet.machines.max(1);
    let process = fleet.cfg.mode.process().expect("validate() rejects closed-loop fleets");
    let names = process.tenant_names();
    let n_tenants = process.n_tenants();
    let agg = HierarchyAgg::new(n, cfg.machines_per_rack, fleet.cfg.slo, &names);

    // Epoch windows: a cold window over [0, warmup) (observed for
    // feedback, never absorbed into the reported aggregates), then
    // `epochs` slices of the measure window (the last takes the integer
    // remainder).
    let mut windows: Vec<(Time, Time)> = Vec::new();
    if fleet.cfg.warmup > 0 {
        windows.push((0, fleet.cfg.warmup));
    }
    let measured_from = windows.len();
    let horizon = fleet.cfg.warmup + fleet.cfg.measure;
    let e_len = fleet.cfg.measure / bal.epochs as Time;
    let mut start = fleet.cfg.warmup;
    for k in 0..bal.epochs {
        let end = if k + 1 == bal.epochs { horizon } else { start + e_len };
        windows.push((start, end));
        start = end;
    }

    // The base arrival stream: identical generation to the open loop.
    let mut gen = ArrivalGen::new(process.clone(), fleet.cfg.seed ^ 0xDEAD);
    let mut base: Vec<(Time, u32)> = Vec::new();
    let mut now = 0;
    loop {
        let (t, tenant) = gen.next_after(now);
        if t > horizon {
            break;
        }
        base.push((t, tenant));
        now = t;
    }

    // Front-end state carried across epochs. The router's bookkeeping
    // persists (it is still a pure function of what it was asked to
    // route); the health mask and hedge/retry queues are the feedback.
    let mut router = fleet.router.build(n);
    let mut healthy = vec![true; n];
    let mut outcomes = FrontendOutcomes::default();
    let mut arrivals_routed = vec![0u64; n];
    let mut injected: Vec<Arr> = Vec::new();
    let mut hedge_frac = 0.0f64;
    let mut hedge_delay: Time = 0;

    let mut base_iter = base.into_iter().peekable();
    let last = windows.len() - 1;
    for (k, &(w0, w1)) in windows.iter().enumerate() {
        // 1. This epoch's arrivals: base stream in [w0, w1) (the last
        // window also takes the horizon-edge arrival), plus any
        // injections that landed here. Stable sort on a total key keeps
        // the order independent of construction order.
        let mut epoch: Vec<Arr> = Vec::new();
        while let Some(&(t, tenant)) = base_iter.peek() {
            if t >= w1 && k != last {
                break;
            }
            epoch.push(Arr { t, tenant, attempt: 0, hedge: false, machine: None });
            base_iter.next();
        }
        let (now_batch, later): (Vec<Arr>, Vec<Arr>) =
            injected.drain(..).partition(|a| a.t < w1 || k == last);
        epoch.extend(now_batch);
        injected = later;
        epoch.sort_by_key(|a| (a.t, a.hedge, a.attempt, a.tenant));

        // 2. Route. Retry/attempt composition is tracked per
        // (machine, tenant, attempt) so next epoch's timeouts can be
        // attributed; hedge draws come from a per-epoch seeded stream.
        let mut traces: Vec<Vec<(Time, u32)>> = vec![Vec::new(); n];
        let mut hedges: Vec<(Time, u32, usize)> = Vec::new();
        let attempts = bal.max_retries as usize + 1;
        let mut counts = vec![0u64; n * n_tenants * attempts];
        let mut hedge_rng =
            Rng::new(mix64(fleet.cfg.seed ^ (k as u64).wrapping_mul(0x9E37_79B9) ^ 0x4ED6));
        for a in &epoch {
            let avx = process.tenant_carries_avx(a.tenant as usize);
            let m = match a.machine {
                Some(m) => pick_healthy(m, &healthy),
                None => pick_healthy(router.route(a.t, avx), &healthy),
            };
            traces[m].push((a.t, a.tenant));
            arrivals_routed[m] += 1;
            if !a.hedge {
                counts[(m * n_tenants + a.tenant as usize) * attempts + a.attempt as usize] += 1;
                if hedge_frac > 0.0 && hedge_delay > 0 && hedge_rng.chance(hedge_frac) {
                    let hm = next_healthy_after(m, &healthy);
                    if hm != m {
                        outcomes.hedges_issued += 1;
                        let ht = a.t.saturating_add(hedge_delay);
                        if ht < w1 {
                            hedges.push((ht, a.tenant, hm));
                        } else if k != last {
                            injected.push(Arr {
                                t: ht,
                                tenant: a.tenant,
                                attempt: 0,
                                hedge: true,
                                machine: Some(hm),
                            });
                        }
                    }
                }
            }
        }
        for (ht, tenant, hm) in hedges {
            traces[hm].push((ht, tenant));
            arrivals_routed[hm] += 1;
        }
        for trace in traces.iter_mut() {
            trace.sort_by_key(|&(t, _)| t);
        }

        // 3. Simulate the epoch: every machine is an independent fresh
        // run over [0, w1 - w0) with epoch-local arrival times.
        let e_secs = (w1 - w0) as f64 / SEC as f64;
        let measured = k >= measured_from;
        let jobs: Vec<(WebCfg, Vec<(Time, u32)>)> = traces
            .into_iter()
            .enumerate()
            .map(|(i, mut trace)| {
                for a in trace.iter_mut() {
                    a.0 -= w0;
                }
                let mut mcfg = fleet.cfg.clone();
                mcfg.warmup = 0;
                mcfg.measure = w1 - w0;
                mcfg.seed = epoch_machine_seed(fleet, i, k);
                (mcfg, trace)
            })
            .collect();
        let epoch_cluster = Mutex::new(LatencyStats::new(fleet.cfg.slo));
        let obs_slots: Vec<Mutex<Option<EpochObs>>> = (0..n).map(|_| Mutex::new(None)).collect();
        simulate_into(
            jobs,
            threads,
            &agg,
            measured,
            e_secs,
            Some((&epoch_cluster, &obs_slots, bal.timeout, n_tenants)),
        );
        let obs: Vec<EpochObs> = obs_slots
            .into_iter()
            .map(|s| s.into_inner().expect("obs poisoned").unwrap_or_default())
            .collect();

        // 4. Feedback for epoch k+1, from epoch k's merged statistics
        // only — sequential and deterministic.
        if k == last {
            break;
        }
        let (nw0, nw1) = windows[k + 1];

        // 4a. Timeouts → retries with backoff (or abandonment at the
        // attempt cap). Estimated per (machine, tenant, attempt) from
        // the observed per-tenant distribution.
        let mut retry_rng =
            Rng::new(mix64(fleet.cfg.seed ^ (k as u64).wrapping_mul(0xA076_1D64_78BD_642F)));
        let jitter_span = ((nw1 - nw0) / 2).max(1);
        for m in 0..n {
            for t in 0..n_tenants {
                let frac = obs[m].tenant_frac.get(t).copied().unwrap_or(0.0);
                if frac <= 0.0 {
                    continue;
                }
                for a in 0..attempts {
                    let c = counts[(m * n_tenants + t) * attempts + a];
                    let timed_out = (frac * c as f64).round() as u64;
                    if timed_out == 0 {
                        continue;
                    }
                    outcomes.timeouts_observed += timed_out;
                    agg.note_timeouts(m, timed_out);
                    if a as u32 >= bal.max_retries {
                        outcomes.retries_abandoned += timed_out;
                        continue;
                    }
                    outcomes.retries_issued += timed_out;
                    for _ in 0..timed_out {
                        let rt = nw0
                            .saturating_add(bal.retry_backoff)
                            .saturating_add(retry_rng.below(jitter_span));
                        injected.push(Arr {
                            t: rt,
                            tenant: t as u32,
                            attempt: a as u32 + 1,
                            hedge: false,
                            machine: None,
                        });
                    }
                }
            }
        }

        // 4b. Hedge threshold for the next epoch from this epoch's
        // merged cluster distribution.
        if bal.hedge_p99_mult > 0.0 {
            let ec = epoch_cluster.into_inner().expect("epoch recorder poisoned");
            let p99 = ec.hist.percentile(99.0);
            hedge_delay = (bal.hedge_p99_mult * p99 as f64).round() as Time;
            hedge_frac =
                if hedge_delay > 0 { ec.hist.fraction_above(hedge_delay) } else { 0.0 };
        }

        // 4c. Health view: eject slow machines, readmit recovered ones.
        if bal.eject_factor > 0.0 {
            let mut healthy_p99s: Vec<Time> = (0..n)
                .filter(|&m| healthy[m] && obs[m].completed > 0)
                .map(|m| obs[m].p99)
                .collect();
            healthy_p99s.sort_unstable();
            if !healthy_p99s.is_empty() {
                let median = healthy_p99s[healthy_p99s.len() / 2];
                let threshold = (bal.eject_factor * median as f64).round() as Time;
                if threshold > 0 {
                    for m in 0..n {
                        if !healthy[m] && obs[m].p99 <= threshold {
                            healthy[m] = true;
                            outcomes.readmissions += 1;
                        }
                    }
                    for m in 0..n {
                        let would_remain = healthy.iter().filter(|&&h| h).count() > 1;
                        if healthy[m] && obs[m].p99 > threshold && would_remain {
                            healthy[m] = false;
                            outcomes.ejections += 1;
                        }
                    }
                }
            }
        }
        // Attribute ejected machine-epochs to the digests (next epoch
        // is the one they sit out; only measured epochs are reported).
        if k + 1 >= measured_from {
            for m in 0..n {
                if !healthy[m] {
                    agg.note_ejected_epoch(m);
                }
            }
        }
    }

    finish(cfg, agg, arrivals_routed, outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::router::RouterSpec;
    use crate::sched::PolicyKind;
    use crate::traffic::ArrivalProcess;
    use crate::workload::client::LoadMode;
    use crate::workload::crypto::Isa;

    fn tiny_cfg() -> WebCfg {
        let mut c = WebCfg::paper_default(Isa::Avx512, PolicyKind::Unmodified);
        c.cores = 2;
        c.workers = 4;
        c.page_bytes = 8 * 1024;
        c.warmup = 40 * MS;
        c.measure = 160 * MS;
        c.mode =
            LoadMode::OpenProcess { process: ArrivalProcess::two_tenant(30_000.0, 0.25) };
        c
    }

    fn hier(machines: usize, closed: bool) -> HierFleetCfg {
        let fleet = FleetCfg::new(machines, RouterSpec::RoundRobin, tiny_cfg());
        let bal = if closed { BalancerCfg::closed() } else { BalancerCfg::default() };
        let mut h = HierFleetCfg::new(fleet, bal);
        h.machines_per_rack = 2;
        h
    }

    #[test]
    fn balancer_labels_and_validation() {
        assert_eq!(BalancerCfg::default().label(), "open-loop");
        assert_eq!(BalancerCfg::closed().label(), "closed(4ep)");
        let bad = BalancerCfg { epochs: 0, ..BalancerCfg::closed() };
        assert!(bad.validate().is_err());
        let bad = BalancerCfg { timeout: 0, ..BalancerCfg::closed() };
        assert!(bad.validate().is_err());
        let bad = BalancerCfg { hedge_p99_mult: -1.0, ..BalancerCfg::closed() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn health_probes_pick_deterministically() {
        let healthy = vec![true, false, false, true];
        assert_eq!(pick_healthy(0, &healthy), 0);
        assert_eq!(pick_healthy(1, &healthy), 3);
        assert_eq!(pick_healthy(2, &healthy), 3);
        assert_eq!(next_healthy_after(0, &healthy), 3);
        assert_eq!(next_healthy_after(3, &healthy), 0);
        let none = vec![false, false];
        assert_eq!(pick_healthy(1, &none), 1, "routing must never fail");
        let solo = vec![true];
        assert_eq!(next_healthy_after(0, &solo), 0, "no other machine to hedge to");
    }

    #[test]
    fn open_loop_hier_counts_match_flat_fleet() {
        // The streaming aggregation must reproduce the flat fleet's
        // exact counters (the full byte-differential lives in
        // rust/tests/hierfleet.rs).
        let h = hier(3, false);
        let flat = super::super::cluster::run_fleet(&h.fleet, 2);
        let run = run_hier_fleet(&h, 2);
        assert_eq!(run.completed, flat.completed);
        assert_eq!(run.violations, flat.violations);
        assert_eq!(run.dropped, flat.dropped);
        assert_eq!(run.tail.p99_us.to_bits(), flat.tail.p99_us.to_bits());
        assert!(run.outcomes.is_noop(), "open loop must not act: {:?}", run.outcomes);
        assert_eq!(run.n_racks(), 2);
        let rack_sum: u64 = run.racks.iter().map(|r| r.completed()).sum();
        assert_eq!(rack_sum, run.completed, "racks must partition the cluster");
    }

    #[test]
    fn closed_loop_runs_and_is_thread_count_invariant() {
        let h = hier(3, true);
        let a = run_hier_fleet(&h, 1);
        let b = run_hier_fleet(&h, 4);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.violations, b.violations);
        assert_eq!(a.tail.p99_us.to_bits(), b.tail.p99_us.to_bits());
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.arrivals_routed(), b.arrivals_routed());
        assert!(a.completed > 100, "closed loop served {}", a.completed);
        assert_eq!(a.balancer, "closed(4ep)");
    }

    impl HierFleetRun {
        fn arrivals_routed(&self) -> Vec<u64> {
            self.digests.iter().map(|d| d.arrivals).collect()
        }
    }

    #[test]
    fn all_idle_epochs_neither_eject_nor_panic() {
        // Regression for the §4b/§4c empty-histogram edges: with an
        // arrival rate so low that every epoch is (essentially) idle,
        // every machine's epoch histogram is empty. The hedge threshold
        // must collapse to "off" (p99 of an empty histogram is the
        // documented 0), and the ejection pass must see an empty
        // healthy-median list and do nothing — never eject the whole
        // fleet off zero data, never divide by an empty median, never
        // panic.
        let mut h = hier(3, true);
        h.fleet.cfg.mode = LoadMode::OpenProcess {
            process: ArrivalProcess::two_tenant(1e-6, 0.25),
        };
        let run = run_hier_fleet(&h, 2);
        assert_eq!(run.outcomes.ejections, 0, "no machine may be ejected off no data");
        assert_eq!(run.outcomes.hedges_issued, 0);
        assert_eq!(run.outcomes.retries_issued, 0);
        assert_eq!(run.outcomes.timeouts_observed, 0);
        assert_eq!(run.machines, 3);
        // The merged cluster statistics are the safe zeroes, not NaNs
        // (a stray arrival from the 1e-6 req/s stream would be benign
        // but is astronomically unlikely over a 200 ms horizon).
        assert!(run.completed <= 1, "idle fleet served {}", run.completed);
        assert!(run.tail.p99_us.is_finite());
        if run.completed == 0 {
            assert_eq!(run.tail.p99_us.to_bits(), 0.0f64.to_bits());
        }
    }

    #[test]
    fn idle_fleet_is_thread_count_invariant() {
        // The all-idle loop exercises the empty-histogram feedback path
        // on every epoch; it must stay byte-identical across thread
        // counts like any other configuration.
        let mut h = hier(3, true);
        h.fleet.cfg.mode = LoadMode::OpenProcess {
            process: ArrivalProcess::two_tenant(1e-6, 0.25),
        };
        let a = run_hier_fleet(&h, 1);
        let b = run_hier_fleet(&h, 4);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.outcomes, b.outcomes);
        assert_eq!(a.arrivals_routed(), b.arrivals_routed());
        assert_eq!(a.tail.p99_us.to_bits(), b.tail.p99_us.to_bits());
    }
}
