//! Hierarchical (machine → rack → cluster) streaming aggregation.
//!
//! PR 3's flat fleet kept every machine's full [`WebRun`] alive until
//! the end of the run — fine for 6 machines, hopeless for the ROADMAP's
//! 1000-machine "fleet-of-fleets" sweeps. This module keeps the memory
//! profile at **O(machines) scalar counters plus O(racks + 1)
//! histograms**: as each machine finishes, its latency recorder is
//! merged into its rack's and the cluster's [`LatencyStats`] and the
//! `WebRun` is dropped; all that survives per machine is a compact
//! [`MachineDigest`] of exact counters and frozen tail points.
//!
//! Determinism at any thread count relies on a split by arithmetic
//! kind:
//!
//! * **Histograms and exact counters** (`u64`/`u128` adds) are merged
//!   under a mutex *as machines finish*, in whatever order the OS
//!   schedules them — integer addition is commutative and associative,
//!   so the merged buckets are identical for every completion order.
//! * **Floating-point quantities** (energy, GHz, rates) are *not*
//!   reorderable, so they are never reduced in completion order: each
//!   lands in its machine's index-keyed digest slot, and any
//!   cross-machine reduction happens once, in machine-index order, from
//!   the frozen digests.
//!
//! The same digests feed the bulk-synchronous collective model
//! ([`collective_makespan`]): every step of an N-machine collective
//! waits on the slowest participant, so per-machine tail variation
//! amplifies with N — Schuchart et al.'s scale-out argument, and the
//! `repro fleetscale` table's headline column.

use crate::faults::FaultWindowStat;
use crate::sim::Time;
use crate::traffic::{FaultOutcomes, FrontendOutcomes, LatencyStats, TailSummary};
use crate::util::{mix64, Rng, Summary};
use crate::workload::webserver::WebRun;
use std::sync::Mutex;

/// Compact per-machine summary kept after the machine's [`WebRun`] is
/// dropped: exact event counters, frozen tail points (µs), and
/// completion-weighted machine-quality metrics. Accumulates across
/// closed-loop epochs (each epoch's run is absorbed and dropped).
#[derive(Clone, Debug, Default)]
pub struct MachineDigest {
    /// Rack this machine belongs to.
    pub rack: usize,
    /// Arrivals the front-end sent here (set by the caller at finalize —
    /// routing happens outside the aggregation).
    pub arrivals: u64,
    pub completed: u64,
    pub dropped: u64,
    pub violations: u64,
    /// Completions the front-end classified as timed out (closed loop).
    pub timeouts: u64,
    /// Epochs this machine spent ejected from the healthy set.
    pub epochs_ejected: u32,
    // Frozen tail points (µs), completion-weighted across epochs. A
    // weighted mean of per-epoch percentiles is an approximation (exact
    // percentiles live in the rack/cluster histograms); `max_us` is
    // exact.
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub max_us: f64,
    // Machine-quality metrics, completion-weighted across epochs.
    pub avg_ghz: f64,
    pub ipc: f64,
    pub insns_per_req: f64,
    pub throttle_ratio: f64,
    pub license_share: [f64; 3],
    // Joules add across epochs, like the recorders.
    pub active_energy_j: f64,
    pub idle_energy_j: f64,
    // Scheduler/runtime event totals (rates are rebuilt from these and
    // the accumulated simulated seconds).
    pub runtime_steered: u64,
    pub runtime_migrations: u64,
    pub runtime_preemptions: u64,
    pub adaptive_changes: u64,
    pub final_avx_cores: usize,
    type_change_events: f64,
    migration_events: f64,
    cross_socket_events: f64,
    secs: f64,
    weight: f64,
}

impl MachineDigest {
    /// Fold one (machine, epoch) run into the digest. `secs` is the
    /// run's measurement window (rates are events, not averaged rates).
    fn add_run(&mut self, run: &WebRun, secs: f64) {
        self.completed += run.completed;
        self.dropped += run.dropped;
        self.violations += run.stats.violations();
        let w = run.completed as f64;
        self.mean_us += run.tail.mean_us * w;
        self.p50_us += run.tail.p50_us * w;
        self.p95_us += run.tail.p95_us * w;
        self.p99_us += run.tail.p99_us * w;
        self.p999_us += run.tail.p999_us * w;
        self.max_us = self.max_us.max(run.tail.max_us);
        self.avg_ghz += run.avg_ghz * w;
        self.ipc += run.ipc * w;
        self.insns_per_req += run.insns_per_req * w;
        self.throttle_ratio += run.throttle_ratio * w;
        for (acc, v) in self.license_share.iter_mut().zip(run.license_share) {
            *acc += v * w;
        }
        self.active_energy_j += run.active_energy_j;
        self.idle_energy_j += run.idle_energy_j;
        self.runtime_steered += run.runtime_steered;
        self.runtime_migrations += run.runtime_migrations;
        self.runtime_preemptions += run.runtime_preemptions;
        self.adaptive_changes += run.adaptive_changes;
        self.final_avx_cores = run.final_avx_cores;
        self.type_change_events += run.type_changes_per_sec * secs;
        self.migration_events += run.migrations_per_sec * secs;
        self.cross_socket_events += run.cross_socket_migrations_per_sec * secs;
        self.secs += secs;
        self.weight += w;
    }

    /// Turn the accumulated weighted sums into reportable values.
    fn finalize(&mut self) {
        if self.weight > 0.0 {
            let w = self.weight;
            self.mean_us /= w;
            self.p50_us /= w;
            self.p95_us /= w;
            self.p99_us /= w;
            self.p999_us /= w;
            self.avg_ghz /= w;
            self.ipc /= w;
            self.insns_per_req /= w;
            self.throttle_ratio /= w;
            for acc in self.license_share.iter_mut() {
                *acc /= w;
            }
        }
    }

    /// Events-per-second rates over the accumulated simulated time.
    pub fn type_changes_per_sec(&self) -> f64 {
        if self.secs > 0.0 { self.type_change_events / self.secs } else { 0.0 }
    }
    pub fn migrations_per_sec(&self) -> f64 {
        if self.secs > 0.0 { self.migration_events / self.secs } else { 0.0 }
    }
    pub fn cross_socket_migrations_per_sec(&self) -> f64 {
        if self.secs > 0.0 { self.cross_socket_events / self.secs } else { 0.0 }
    }
    pub fn runtime_migrations_per_sec(&self) -> f64 {
        if self.secs > 0.0 { self.runtime_migrations as f64 / self.secs } else { 0.0 }
    }
}

/// Number of racks for `machines` machines in contiguous chunks of
/// `machines_per_rack`.
pub fn n_racks(machines: usize, machines_per_rack: usize) -> usize {
    let per = machines_per_rack.max(1);
    machines.max(1).div_ceil(per)
}

/// Rack index of machine `i` (contiguous balanced chunks, the same
/// idiom the NUMA socket map uses for cores).
pub fn rack_of(i: usize, machines_per_rack: usize) -> usize {
    i / machines_per_rack.max(1)
}

struct AggInner {
    racks: Vec<LatencyStats>,
    cluster: LatencyStats,
    tenants: Vec<(String, LatencyStats)>,
    dropped: u64,
}

/// Streaming machine → rack → cluster aggregation. `absorb` is called
/// from worker threads as machines finish; everything merged there is
/// exact integer arithmetic (order-independent), and per-machine `f64`
/// state goes into index-keyed digest slots (see the module docs for
/// why that split is what keeps runs byte-identical at any thread
/// count).
pub struct HierarchyAgg {
    machines_per_rack: usize,
    inner: Mutex<AggInner>,
    digests: Vec<Mutex<MachineDigest>>,
}

impl HierarchyAgg {
    /// `tenant_names` fixes the tenant order up front (every machine is
    /// stamped from the same template, so the order is the arrival
    /// process's tenant index order — never "whichever machine finished
    /// first").
    pub fn new(machines: usize, machines_per_rack: usize, slo: Time, tenant_names: &[String]) -> Self {
        let machines = machines.max(1);
        let per = machines_per_rack.max(1);
        HierarchyAgg {
            machines_per_rack: per,
            inner: Mutex::new(AggInner {
                racks: (0..n_racks(machines, per)).map(|_| LatencyStats::new(slo)).collect(),
                cluster: LatencyStats::new(slo),
                tenants: tenant_names
                    .iter()
                    .map(|n| (n.clone(), LatencyStats::new(slo)))
                    .collect(),
                dropped: 0,
            }),
            digests: (0..machines)
                .map(|i| {
                    Mutex::new(MachineDigest { rack: rack_of(i, per), ..Default::default() })
                })
                .collect(),
        }
    }

    /// Merge machine `i`'s finished run into its rack and the cluster,
    /// then record its digest. The caller drops the `WebRun` right
    /// after — nothing here retains it.
    pub fn absorb(&self, i: usize, run: &WebRun, secs: f64) {
        {
            let mut inner = self.inner.lock().expect("aggregation poisoned");
            let rack = rack_of(i, self.machines_per_rack);
            inner.racks[rack].merge(&run.stats);
            inner.cluster.merge(&run.stats);
            for ((_, acc), ts) in inner.tenants.iter_mut().zip(&run.tenant_stats) {
                acc.merge(ts);
            }
            inner.dropped += run.dropped;
        }
        self.digests[i].lock().expect("digest poisoned").add_run(run, secs);
    }

    /// Record that machine `i` spent an epoch ejected.
    pub fn note_ejected_epoch(&self, i: usize) {
        self.digests[i].lock().expect("digest poisoned").epochs_ejected += 1;
    }

    /// Attribute front-end-observed timeouts to machine `i`.
    pub fn note_timeouts(&self, i: usize, n: u64) {
        self.digests[i].lock().expect("digest poisoned").timeouts += n;
    }

    /// Freeze the aggregation: rack/cluster recorders out, digests
    /// finalized in machine-index order (the only place `f64`s cross
    /// machines). `arrivals_routed` comes from the router, which lives
    /// outside the aggregation.
    pub fn finish(self, arrivals_routed: &[u64]) -> HierSnapshot {
        let inner = self.inner.into_inner().expect("aggregation poisoned");
        let digests: Vec<MachineDigest> = self
            .digests
            .into_iter()
            .enumerate()
            .map(|(i, d)| {
                let mut d = d.into_inner().expect("digest poisoned");
                d.arrivals = arrivals_routed.get(i).copied().unwrap_or(0);
                d.finalize();
                d
            })
            .collect();
        HierSnapshot {
            racks: inner.racks,
            cluster: inner.cluster,
            tenants: inner.tenants,
            dropped: inner.dropped,
            digests,
        }
    }
}

/// Frozen output of a [`HierarchyAgg`].
pub struct HierSnapshot {
    pub racks: Vec<LatencyStats>,
    pub cluster: LatencyStats,
    pub tenants: Vec<(String, LatencyStats)>,
    pub dropped: u64,
    pub digests: Vec<MachineDigest>,
}

/// Results of one hierarchical fleet run (open- or closed-loop). The
/// closed-loop path fills [`HierFleetRun::outcomes`]; the open-loop
/// path leaves it a no-op record.
#[derive(Clone, Debug)]
pub struct HierFleetRun {
    /// Router label (see [`super::RouterSpec::label`]).
    pub router: String,
    /// Balancer label (`"open-loop"` or `"closed(..)"`).
    pub balancer: String,
    pub machines: usize,
    pub machines_per_rack: usize,
    /// Per-machine scalar digests, machine-index order — the only
    /// per-machine state retained.
    pub digests: Vec<MachineDigest>,
    /// Per-rack merged recorders.
    pub racks: Vec<LatencyStats>,
    /// Cluster-wide merged recorder.
    pub stats: LatencyStats,
    /// Cluster tail frozen from [`HierFleetRun::stats`].
    pub tail: TailSummary,
    /// Cluster-wide per-tenant recorders, tenant-index order.
    pub tenant_stats: Vec<(String, LatencyStats)>,
    /// What the closed-loop front-end did (all zero for open loop).
    pub outcomes: FrontendOutcomes,
    /// What injected faults did to the run (all zero when faults are
    /// disabled — the fault-free differential asserts it).
    pub fault_outcomes: FaultOutcomes,
    /// Per-fault-window SLO damage (closed loop with faults only; the
    /// open loop has no epoch slicing to attribute damage with).
    pub fault_windows: Vec<FaultWindowStat>,
    pub completed: u64,
    pub dropped: u64,
    pub violations: u64,
    pub measure_secs: f64,
    /// Bulk-synchronous collective model over the digests, if requested.
    pub collective: Option<CollectiveSummary>,
}

impl HierFleetRun {
    pub fn n_racks(&self) -> usize {
        self.racks.len()
    }

    /// Per-machine p99 (µs) from the digests, machine-index order.
    pub fn p99s_us(&self) -> Vec<f64> {
        self.digests.iter().map(|d| d.p99_us).collect()
    }

    /// Cross-machine summary of per-machine p99 — same statistic the
    /// flat fleet reports, now from digests instead of retained runs.
    pub fn p99_summary(&self) -> Summary {
        Summary::from_iter(self.p99s_us())
    }

    /// Max − min of the per-machine p99 (µs): the straggler gap.
    pub fn p99_spread_us(&self) -> f64 {
        let s = self.p99_summary();
        if s.count() == 0 { 0.0 } else { s.max() - s.min() }
    }

    /// Synthesize a cluster-level [`WebRun`] so hierarchical cells slot
    /// into the same matrix tables as single-machine cells — the digest
    /// analogue of `FleetRun::cluster_run`.
    pub fn cluster_run(&self, template_name: &str) -> WebRun {
        let n = self.digests.len().max(1) as f64;
        let secs = self.measure_secs.max(1e-9);
        let mean = |f: &dyn Fn(&MachineDigest) -> f64| {
            self.digests.iter().map(f).sum::<f64>() / n
        };
        let sum = |f: &dyn Fn(&MachineDigest) -> f64| self.digests.iter().map(f).sum::<f64>();
        let mut license_share = [0.0f64; 3];
        for d in &self.digests {
            for (acc, v) in license_share.iter_mut().zip(d.license_share) {
                *acc += v / n;
            }
        }
        let insns: f64 =
            self.digests.iter().map(|d| d.insns_per_req * d.completed as f64).sum();
        WebRun {
            cfg_name: format!(
                "hier({}x{})/{}/{}/{}",
                self.n_racks(),
                self.machines_per_rack,
                self.router,
                self.balancer,
                template_name
            ),
            throughput_rps: self.completed as f64 / secs,
            avg_ghz: mean(&|d| d.avg_ghz),
            ipc: mean(&|d| d.ipc),
            insns_per_req: if self.completed > 0 { insns / self.completed as f64 } else { 0.0 },
            tail: self.tail,
            tenant_tails: self
                .tenant_stats
                .iter()
                .map(|(name, s)| (name.clone(), s.summary()))
                .collect(),
            stats: self.stats.clone(),
            tenant_stats: self.tenant_stats.iter().map(|(_, s)| s.clone()).collect(),
            dropped: self.dropped,
            type_changes_per_sec: sum(&|d| d.type_changes_per_sec()),
            migrations_per_sec: sum(&|d| d.migrations_per_sec()),
            cross_socket_migrations_per_sec: sum(&|d| d.cross_socket_migrations_per_sec()),
            runtime_steered: self.digests.iter().map(|d| d.runtime_steered).sum(),
            runtime_migrations: self.digests.iter().map(|d| d.runtime_migrations).sum(),
            runtime_migrations_per_sec: sum(&|d| d.runtime_migrations_per_sec()),
            runtime_preemptions: self.digests.iter().map(|d| d.runtime_preemptions).sum(),
            active_energy_j: sum(&|d| d.active_energy_j),
            idle_energy_j: sum(&|d| d.idle_energy_j),
            throttle_ratio: mean(&|d| d.throttle_ratio),
            license_share,
            completed: self.completed,
            final_avx_cores: self.digests.iter().map(|d| d.final_avx_cores).sum(),
            adaptive_changes: self.digests.iter().map(|d| d.adaptive_changes).sum(),
            // Per-domain clocks are a machine-local concept; hierarchy
            // rows keep the aggregate avg_ghz instead.
            domain_ghz: Vec::new(),
        }
    }
}

/// Bulk-synchronous collective model: `steps` synchronization rounds
/// where every machine draws a step duration from its own latency
/// distribution and the round takes the **max** over machines.
#[derive(Clone, Copy, Debug, Default)]
pub struct CollectiveSummary {
    pub steps: usize,
    /// Sum over steps of the slowest machine's draw (µs).
    pub makespan_us: f64,
    /// The same steps if every machine ran at the cluster median (µs).
    pub ideal_us: f64,
    /// `makespan / ideal` — how much straggling amplifies with N.
    pub slowdown: f64,
}

/// Piecewise-linear quantile through a digest's frozen tail points.
/// Clamped monotone so a weighted-mean digest can never hand back an
/// inverted tail.
fn digest_quantile_us(d: &MachineDigest, u: f64) -> f64 {
    let pts = [
        (0.0, d.p50_us * 0.5),
        (0.5, d.p50_us),
        (0.95, d.p95_us),
        (0.99, d.p99_us),
        (0.999, d.p999_us),
        (1.0, d.max_us),
    ];
    let mut prev = pts[0];
    let mut lo = pts[0].1;
    for &(q, v) in &pts[1..] {
        let v = v.max(lo);
        if u <= q {
            let span = q - prev.0;
            let frac = if span > 0.0 { (u - prev.0) / span } else { 1.0 };
            return prev.1 + (v - prev.1) * frac;
        }
        prev = (q, v);
        lo = v;
    }
    prev.1
}

/// Simulate `steps` bulk-synchronous collective rounds over the fleet's
/// digests. Machines that completed nothing (never routed to) sit the
/// collective out. Draws are seeded and sequential, so the model is
/// deterministic for a given digest set.
pub fn collective_makespan(digests: &[MachineDigest], steps: usize, seed: u64) -> CollectiveSummary {
    let active: Vec<&MachineDigest> = digests.iter().filter(|d| d.completed > 0).collect();
    if active.is_empty() || steps == 0 {
        return CollectiveSummary { steps, ..Default::default() };
    }
    // Ideal: every machine at the median of the *median* machine — the
    // no-variation fleet.
    let mut p50s: Vec<f64> = active.iter().map(|d| d.p50_us).collect();
    p50s.sort_by(|a, b| a.partial_cmp(b).expect("p50 is finite"));
    let median_p50 = p50s[p50s.len() / 2];
    let mut rng = Rng::new(mix64(seed ^ 0xC0_11EC_71FE));
    let mut makespan = 0.0;
    for _ in 0..steps {
        let mut slowest = 0.0f64;
        for d in &active {
            slowest = slowest.max(digest_quantile_us(d, rng.f64()));
        }
        makespan += slowest;
    }
    let ideal = median_p50 * steps as f64;
    CollectiveSummary {
        steps,
        makespan_us: makespan,
        ideal_us: ideal,
        slowdown: if ideal > 0.0 { makespan / ideal } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    fn digest(p50: f64, p99: f64, completed: u64) -> MachineDigest {
        MachineDigest {
            completed,
            p50_us: p50,
            p95_us: p99 * 0.8,
            p99_us: p99,
            p999_us: p99 * 1.2,
            max_us: p99 * 2.0,
            ..Default::default()
        }
    }

    #[test]
    fn rack_mapping_is_contiguous_and_covers() {
        assert_eq!(n_racks(16, 8), 2);
        assert_eq!(n_racks(17, 8), 3);
        assert_eq!(n_racks(1, 8), 1);
        for i in 0..17 {
            let r = rack_of(i, 8);
            assert_eq!(r, i / 8);
            assert!(r < n_racks(17, 8));
        }
    }

    #[test]
    fn absorb_streams_into_rack_and_cluster() {
        // Two synthetic runs into a 2-rack hierarchy: rack recorders
        // hold only their machines, the cluster holds the union.
        let names = vec!["all".to_string()];
        let agg = HierarchyAgg::new(2, 1, 2 * MS, &names);
        for (i, lat) in [(0usize, MS), (1usize, 3 * MS)] {
            let mut stats = LatencyStats::new(2 * MS);
            stats.record(lat);
            let mut run = crate::workload::webserver::WebRun::default();
            run.completed = 1;
            run.tail = stats.summary();
            run.tenant_stats = vec![stats.clone()];
            run.stats = stats;
            agg.absorb(i, &run, 1.0);
        }
        let snap = agg.finish(&[1, 1]);
        assert_eq!(snap.racks.len(), 2);
        assert_eq!(snap.racks[0].completed(), 1);
        assert_eq!(snap.racks[1].completed(), 1);
        assert_eq!(snap.racks[1].violations(), 1);
        assert_eq!(snap.cluster.completed(), 2);
        assert_eq!(snap.cluster.violations(), 1);
        assert_eq!(snap.tenants[0].1.completed(), 2);
        assert_eq!(snap.digests[0].arrivals, 1);
        assert_eq!(snap.digests[1].violations, 1);
    }

    #[test]
    fn digest_quantile_is_monotone() {
        let d = digest(100.0, 900.0, 10);
        let mut prev = -1.0;
        for i in 0..=100 {
            let u = i as f64 / 100.0;
            let q = digest_quantile_us(&d, u);
            assert!(q >= prev, "quantile inverted at u={u}: {q} < {prev}");
            prev = q;
        }
        assert!((digest_quantile_us(&d, 0.5) - 100.0).abs() < 1e-9);
        assert!((digest_quantile_us(&d, 1.0) - 1800.0).abs() < 1e-9);
    }

    #[test]
    fn collective_slowdown_amplifies_with_fleet_size() {
        // Same per-machine distribution, more machines ⇒ the max-of-N
        // step draw grows ⇒ worse slowdown. The paper's variation claim
        // at collective scale.
        let small: Vec<MachineDigest> = (0..2).map(|_| digest(100.0, 400.0, 10)).collect();
        let large: Vec<MachineDigest> = (0..64).map(|_| digest(100.0, 400.0, 10)).collect();
        let a = collective_makespan(&small, 200, 7);
        let b = collective_makespan(&large, 200, 7);
        assert!(a.slowdown >= 1.0, "slowdown below ideal: {}", a.slowdown);
        assert!(
            b.slowdown > a.slowdown,
            "64 machines ({}) must straggle more than 2 ({})",
            b.slowdown,
            a.slowdown
        );
    }

    #[test]
    fn collective_is_deterministic_and_handles_idle_machines() {
        let mut ds: Vec<MachineDigest> = (0..8).map(|_| digest(100.0, 300.0, 10)).collect();
        ds.push(MachineDigest::default()); // never routed to
        let a = collective_makespan(&ds, 50, 42);
        let b = collective_makespan(&ds, 50, 42);
        assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
        assert_eq!(a.slowdown.to_bits(), b.slowdown.to_bits());
        let none = collective_makespan(&[MachineDigest::default()], 50, 42);
        assert_eq!(none.makespan_us, 0.0);
        assert_eq!(none.slowdown, 0.0);
    }
}
